"""Kernel-vs-ref correctness for the MJX decode kernel (dequant + IDCT).

This is the core L1 correctness signal: the Pallas kernel must match the
pure-jnp oracle bit-for-bit up to f32 reassociation, across block counts,
coefficient magnitudes and quant tables (hypothesis sweeps).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dct, ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _rand_coefs(rng, n):
    # Quantized coefficients are small integers concentrated at low freqs.
    c = rng.normal(0.0, 30.0, (n, 8, 8))
    decay = np.exp(-0.3 * (np.arange(8)[:, None] + np.arange(8)[None, :]))
    return np.round(c * decay).astype(np.float32)


def _rand_qtable(rng):
    return (1.0 + rng.uniform(0.0, 40.0, (8, 8))).astype(np.float32)


def test_dct_matrix_orthonormal():
    c = np.asarray(dct.dct_matrix())
    np.testing.assert_allclose(c @ c.T, np.eye(8), atol=1e-6)


def test_fdct_idct_roundtrip_unquantized():
    """IDCT(q=1) inverts the forward DCT exactly (within f32 eps)."""
    rng = np.random.default_rng(1)
    blocks = rng.uniform(-128, 127, (dct.BLOCK_N, 8, 8)).astype(np.float32)
    coefs = ref.fdct_blocks(blocks)
    q = np.ones((8, 8), np.float32)
    out = dct.dequant_idct(jnp.asarray(coefs), jnp.asarray(q))
    expect = np.clip(blocks + 128.0, 0, 255)
    np.testing.assert_allclose(np.asarray(out), expect, atol=2e-3)


@given(nb=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_dequant_idct_matches_ref(nb, seed):
    rng = np.random.default_rng(seed)
    n = nb * dct.BLOCK_N
    coefs = jnp.asarray(_rand_coefs(rng, n))
    q = jnp.asarray(_rand_qtable(rng))
    got = dct.dequant_idct(coefs, q)
    want = ref.dequant_idct_ref(coefs, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-2)


@given(b=st.sampled_from([1, 2, 8]), seed=st.integers(0, 2**31 - 1))
def test_decode_images_matches_ref(b, seed):
    rng = np.random.default_rng(seed)
    coefs = jnp.asarray(_rand_coefs(rng, b * 3 * 64).reshape(b, 3, 8, 8, 8, 8))
    q = jnp.asarray(_rand_qtable(rng))
    got = dct.decode_images(coefs, q)
    want = ref.decode_images_ref(coefs, q)
    assert got.shape == (b, 3, 64, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-2)


def test_decode_output_range():
    rng = np.random.default_rng(7)
    coefs = jnp.asarray(_rand_coefs(rng, dct.BLOCK_N) * 100.0)
    q = jnp.asarray(_rand_qtable(rng))
    out = np.asarray(dct.dequant_idct(coefs, q))
    assert out.min() >= 0.0 and out.max() <= 255.0


def test_dequant_idct_rejects_ragged():
    with pytest.raises(ValueError):
        dct.dequant_idct(jnp.zeros((dct.BLOCK_N + 1, 8, 8)), jnp.ones((8, 8)))


def test_dc_only_block_is_flat():
    """A DC-only coefficient block decodes to a constant patch."""
    coefs = np.zeros((dct.BLOCK_N, 8, 8), np.float32)
    coefs[:, 0, 0] = 16.0  # DC
    q = np.full((8, 8), 2.0, np.float32)
    out = np.asarray(dct.dequant_idct(jnp.asarray(coefs), jnp.asarray(q)))
    # DC term: C^T F C with F=dc*e00 -> dc/8 everywhere; dc=32 -> +4, +128
    np.testing.assert_allclose(out, np.full_like(out, 132.0), atol=1e-3)
