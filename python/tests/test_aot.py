"""AOT pipeline tests: artifact specs are consistent and lowerable,
and lowered HLO text is accepted by the XLA text parser contract
(non-empty, ENTRY present, correct parameter count)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def built():
    return aot.build_artifacts()


def test_artifact_inventory(built):
    arts, models = built
    for b in (aot.BATCH_TEST, aot.BATCH_MAIN):
        for stem in ("decode", "augment", "fused_pre"):
            assert f"{stem}_b{b}" in arts
    for m in M.MODELS:
        assert f"train_{m}_b{aot.BATCH_MAIN}" in arts
        assert f"predict_{m}_b{aot.BATCH_MAIN}" in arts
    assert set(models) == set(M.MODELS)


def test_arg_names_match_specs(built):
    arts, _ = built
    for name, (fn, specs, argnames) in arts.items():
        assert len(specs) == len(argnames), name
        assert len(set(argnames)) == len(argnames), f"dup arg names in {name}"


def test_train_artifact_roundtrips_params(built):
    """train outputs = (loss, new leaves) with shapes identical to inputs."""
    arts, models = built
    name = f"train_resnet_t_b{aot.BATCH_TEST}"
    fn, specs, argnames = arts[name]
    outs = jax.eval_shape(fn, *specs)
    nleaf = len(models["resnet_t"]["names"])
    assert len(outs) == 1 + nleaf
    assert outs[0].shape == ()
    for o, s in zip(outs[1:], specs[:nleaf]):
        assert o.shape == s.shape


def test_lowered_hlo_text_is_wellformed(built):
    arts, _ = built
    name = f"decode_b{aot.BATCH_TEST}"
    fn, specs, _ = arts[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text and "HloModule" in text
    # At least one HLO parameter per positional arg (fusion subcomputations
    # contribute additional parameter() lines).
    assert text.count("parameter(") >= len(specs)


def test_lowered_decode_executes_like_direct_call(built):
    arts, _ = built
    fn, specs, _ = arts[f"decode_b{aot.BATCH_TEST}"]
    rng = np.random.default_rng(0)
    coefs = jnp.asarray(np.round(rng.normal(0, 10, specs[0].shape)).astype(np.float32))
    q = jnp.asarray((1 + np.arange(64).reshape(8, 8)).astype(np.float32))
    direct = fn(coefs, q)[0]
    jitted = jax.jit(fn)(coefs, q)[0]
    np.testing.assert_allclose(np.asarray(direct), np.asarray(jitted), atol=1e-4)


def test_param_schema_offsets_contiguous(built):
    _, models = built
    for mname, info in models.items():
        leaves = jax.tree_util.tree_leaves(info["params"])
        off = 0
        for leaf, nm in zip(leaves, info["names"]):
            nbytes = int(np.prod(leaf.shape)) * 4
            off += nbytes
        total = sum(int(np.prod(l.shape)) for l in leaves)
        assert total == M.param_count(info["params"])
