"""L2 model tests: shapes, gradients, learnability of every model."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


def _synthetic_batch(rng, b):
    """Class-separable toy batch: class sets a channel-wise stripe phase."""
    labels = rng.integers(0, M.NUM_CLASSES, b)
    x = rng.normal(0, 0.3, (b, 3, M.OUT_HW, M.OUT_HW)).astype(np.float32)
    ii = np.arange(M.OUT_HW)
    for i, y in enumerate(labels):
        freq = 1 + (y % 4)
        phase = (y // 4) * np.pi / 4
        stripe = np.sin(2 * np.pi * freq * ii / M.OUT_HW + phase).astype(np.float32)
        x[i, y % 3] += stripe[None, :]
    return jnp.asarray(x), jnp.asarray(labels.astype(np.int32))


@pytest.mark.parametrize("name", sorted(M.MODELS))
def test_forward_shape(name):
    init, apply = M.MODELS[name]
    params = init(jax.random.PRNGKey(0))
    x = jnp.zeros((4, 3, M.OUT_HW, M.OUT_HW), jnp.float32)
    logits = apply(params, x)
    assert logits.shape == (4, M.NUM_CLASSES)
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("name", sorted(M.MODELS))
def test_param_counts_reasonable(name):
    init, _ = M.MODELS[name]
    n = M.param_count(init(jax.random.PRNGKey(0)))
    assert 10_000 < n < 5_000_000


def test_cross_entropy_uniform():
    logits = jnp.zeros((4, M.NUM_CLASSES))
    labels = jnp.asarray([0, 3, 7, 15], jnp.int32)
    ce = M.cross_entropy(logits, labels)
    np.testing.assert_allclose(float(ce), np.log(M.NUM_CLASSES), rtol=1e-5)


def test_cross_entropy_perfect_prediction():
    logits = jnp.full((2, M.NUM_CLASSES), -100.0)
    logits = logits.at[0, 1].set(100.0).at[1, 5].set(100.0)
    ce = M.cross_entropy(logits, jnp.asarray([1, 5], jnp.int32))
    assert float(ce) < 1e-3


# Norm-free tiny nets want model-specific step sizes (the coordinator's
# RunConfig carries the same per-model lr).
LR = {"alexnet_t": 0.1, "resnet_t": 0.2, "shufflenet_t": 0.1}


@pytest.mark.parametrize("name", sorted(M.MODELS))
def test_loss_decreases(name):
    """A few SGD steps on a fixed separable batch must reduce the loss."""
    init, apply = M.MODELS[name]
    params = init(jax.random.PRNGKey(1))
    step = jax.jit(M.make_train_step(apply))
    rng = np.random.default_rng(0)
    x, y = _synthetic_batch(rng, 32)
    lr = jnp.float32(LR[name])
    loss0, params = step(params, x, y, lr)
    loss = loss0
    for _ in range(29):
        loss, params = step(params, x, y, lr)
    assert float(loss) < 0.7 * float(loss0), (float(loss0), float(loss))


def test_train_step_gradient_direction():
    """Single step against a frozen batch never increases loss at tiny lr."""
    init, apply = M.MODELS["resnet_t"]
    params = init(jax.random.PRNGKey(2))
    step = jax.jit(M.make_train_step(apply))
    rng = np.random.default_rng(5)
    x, y = _synthetic_batch(rng, 16)
    l0, p1 = step(params, x, y, jnp.float32(1e-3))
    l1, _ = step(p1, x, y, jnp.float32(1e-3))
    assert float(l1) <= float(l0) + 1e-4


def test_fused_preprocess_composes():
    """fused_preprocess == augment(decode(.)) on random coefficients."""
    rng = np.random.default_rng(11)
    b = 4
    coefs = jnp.asarray(np.round(rng.normal(0, 10, (b, 3, 8, 8, 8, 8))).astype(np.float32))
    q = jnp.asarray((1 + np.arange(64).reshape(8, 8)).astype(np.float32))
    par = jnp.asarray(
        np.stack([[2, 3, 50, 52, i % 2, 0] for i in range(b)]).astype(np.float32)
    )
    fused = M.fused_preprocess(coefs, q, par)
    staged = M.augment_batch(M.decode_batch(coefs, q), par)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(staged), atol=1e-5)
    assert fused.shape == (b, 3, M.OUT_HW, M.OUT_HW)
