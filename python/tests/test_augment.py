"""Kernel-vs-ref correctness for the fused augmentation kernel."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import augment, ref

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

H = W = 64
OUT = (56, 56)


def _params(rng, b, h=H, w=W):
    rows = []
    for _ in range(b):
        ch = rng.integers(8, h + 1)
        cw = rng.integers(8, w + 1)
        y0 = rng.integers(0, h - ch + 1)
        x0 = rng.integers(0, w - cw + 1)
        flip = rng.integers(0, 2)
        rows.append([y0, x0, ch, cw, flip, 0])
    return np.asarray(rows, np.float32)


@given(b=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**31 - 1))
def test_augment_matches_ref(b, seed):
    rng = np.random.default_rng(seed)
    imgs = rng.uniform(0, 255, (b, 3, H, W)).astype(np.float32)
    par = _params(rng, b)
    got = augment.augment_batch(jnp.asarray(imgs), jnp.asarray(par), OUT)
    want = ref.augment_batch_ref(jnp.asarray(imgs), jnp.asarray(par), OUT)
    assert got.shape == (b, 3, *OUT)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_identity_crop_on_constant_image():
    """Full-window crop of a constant image is the normalized constant."""
    v = 100.0
    imgs = np.full((1, 3, H, W), v, np.float32)
    par = np.asarray([[0, 0, H, W, 0, 0]], np.float32)
    out = np.asarray(augment.augment_batch(jnp.asarray(imgs), jnp.asarray(par), OUT))
    expect = (v - ref.NORM_MEAN) / ref.NORM_STD
    for c in range(3):
        np.testing.assert_allclose(out[0, c], expect[c], atol=1e-4)


def test_flip_mirrors_output():
    """Flipped sample of a symmetric-size crop equals reversed unflipped."""
    rng = np.random.default_rng(3)
    imgs = rng.uniform(0, 255, (1, 3, H, W)).astype(np.float32)
    base = np.asarray([[4, 6, 48, 48, 0, 0]], np.float32)
    flip = base.copy()
    flip[0, 4] = 1.0
    o0 = np.asarray(augment.augment_batch(jnp.asarray(imgs), jnp.asarray(base), OUT))
    o1 = np.asarray(augment.augment_batch(jnp.asarray(imgs), jnp.asarray(flip), OUT))
    np.testing.assert_allclose(o1, o0[:, :, :, ::-1], atol=1e-3)


def test_crop_selects_window():
    """Cropping a quadrant picks pixels only from that quadrant."""
    imgs = np.zeros((1, 3, H, W), np.float32)
    imgs[:, :, :32, :32] = 200.0  # bright top-left
    par = np.asarray([[0, 0, 32, 32, 0, 0]], np.float32)
    out = np.asarray(augment.augment_batch(jnp.asarray(imgs), jnp.asarray(par), OUT))
    expect = (200.0 - ref.NORM_MEAN) / ref.NORM_STD
    for c in range(3):
        np.testing.assert_allclose(out[0, c], expect[c], atol=1e-4)


@given(seed=st.integers(0, 2**31 - 1))
def test_output_is_finite(seed):
    rng = np.random.default_rng(seed)
    imgs = rng.uniform(0, 255, (2, 3, H, W)).astype(np.float32)
    par = _params(rng, 2)
    out = np.asarray(augment.augment_batch(jnp.asarray(imgs), jnp.asarray(par), OUT))
    assert np.isfinite(out).all()
