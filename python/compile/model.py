"""L2: JAX compute graphs — tiny CNN models, fwd/bwd train step, fused preprocess.

These are the training-side compute graphs of the paper's end-to-end
pipeline (Fig. 1 "DNN model" stage).  The paper trains AlexNet /
ShuffleNet / ResNet{18,50,152} on V100s; here the same *roles* are played
by scaled-down pure-JAX models (see DESIGN.md Substitutions):

  alexnet_t    — the "fast data consumer" (shallow, cheap per step)
  shufflenet_t — grouped 1x1 convs + channel shuffle, mid-speed
  resnet_t     — residual stages, the "slow, GPU-bound consumer"

Everything is a pure function over an explicit parameter pytree; no
framework state.  `train_step` does softmax cross-entropy + plain SGD so
the AOT artifact is (params..., images, labels, lr) -> (loss, params'...),
which the rust runtime keeps fully on-device between steps.

The preprocessing graphs (`fused_preprocess`) chain the L1 Pallas kernels
so the hybrid/gpu placement executes decode+augment as ONE artifact with
no host round-trip — the DALI "GPU stage" equivalent.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import augment as _augment
from .kernels import dct as _dct

NUM_CLASSES = 16
IMG_HW = 64  # decoded image side
OUT_HW = 56  # post-augment side fed to the models


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def _conv_init(key, kh, kw, cin, cout, groups=1):
    fan_in = kh * kw * cin // groups
    scale = np.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (cout, cin // groups, kh, kw), jnp.float32) * scale


def _fc_init(key, cin, cout, scale=None):
    scale = np.sqrt(2.0 / cin) if scale is None else scale
    k1, _ = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (cin, cout), jnp.float32) * scale,
        "b": jnp.zeros((cout,), jnp.float32),
    }


def _conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
    )


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def _gap(x):
    return jnp.mean(x, axis=(2, 3))


# ---------------------------------------------------------------------------
# alexnet_t — shallow & cheap: the paper's fast data consumer
# ---------------------------------------------------------------------------

def alexnet_t_init(key):
    ks = jax.random.split(key, 5)
    return {
        "c1": _conv_init(ks[0], 5, 5, 3, 32),
        "c2": _conv_init(ks[1], 3, 3, 32, 64),
        "c3": _conv_init(ks[2], 3, 3, 64, 96),
        "fc1": _fc_init(ks[3], 96 * 7 * 7, 256),
        "fc2": _fc_init(ks[4], 256, NUM_CLASSES, scale=0.01),
    }


def alexnet_t_apply(params, x):
    x = jax.nn.relu(_conv(x, params["c1"], stride=2))   # 56 -> 28
    x = _maxpool2(x)                                    # -> 14
    x = jax.nn.relu(_conv(x, params["c2"]))
    x = _maxpool2(x)                                    # -> 7
    x = jax.nn.relu(_conv(x, params["c3"]))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


# ---------------------------------------------------------------------------
# resnet_t — residual stages: the slow, training-bound consumer
# ---------------------------------------------------------------------------

def _block_init(key, cin, cout, downsample):
    ks = jax.random.split(key, 3)
    p = {
        "c1": _conv_init(ks[0], 3, 3, cin, cout),
        "c2": _conv_init(ks[1], 3, 3, cout, cout),
    }
    if downsample:
        p["proj"] = _conv_init(ks[2], 1, 1, cin, cout)
    return p


_RES_SCALE = 0.3  # residual branch scaling; stabilizes the norm-free net


def _block_apply(p, x, stride):
    h = jax.nn.relu(_conv(x, p["c1"], stride=stride))
    h = _conv(h, p["c2"])
    if "proj" in p:
        x = _conv(x, p["proj"], stride=stride)
    return jax.nn.relu(x + _RES_SCALE * h)


def resnet_t_init(key):
    ks = jax.random.split(key, 8)
    return {
        "stem": _conv_init(ks[0], 3, 3, 3, 16),
        "s1b1": _block_init(ks[1], 16, 16, False),
        "s1b2": _block_init(ks[2], 16, 16, False),
        "s2b1": _block_init(ks[3], 16, 32, True),
        "s2b2": _block_init(ks[4], 32, 32, False),
        "s3b1": _block_init(ks[5], 32, 64, True),
        "s3b2": _block_init(ks[6], 64, 64, False),
        "fc": _fc_init(ks[7], 64, NUM_CLASSES, scale=0.01),
    }


def resnet_t_apply(params, x):
    x = jax.nn.relu(_conv(x, params["stem"]))           # 56
    x = _block_apply(params["s1b1"], x, 1)
    x = _block_apply(params["s1b2"], x, 1)
    x = _block_apply(params["s2b1"], x, 2)              # -> 28
    x = _block_apply(params["s2b2"], x, 1)
    x = _block_apply(params["s3b1"], x, 2)              # -> 14
    x = _block_apply(params["s3b2"], x, 1)
    x = _gap(x)
    return x @ params["fc"]["w"] + params["fc"]["b"]


# ---------------------------------------------------------------------------
# shufflenet_t — grouped 1x1 + channel shuffle + depthwise 3x3
# ---------------------------------------------------------------------------

_SHUF_GROUPS = 4


def _shuffle(x, groups):
    b, c, h, w = x.shape
    x = x.reshape(b, groups, c // groups, h, w)
    x = x.transpose(0, 2, 1, 3, 4)
    return x.reshape(b, c, h, w)


def _sunit_init(key, cin, cout):
    ks = jax.random.split(key, 3)
    return {
        "g1": _conv_init(ks[0], 1, 1, cin, cout, groups=_SHUF_GROUPS),
        "dw": _conv_init(ks[1], 3, 3, cout, cout, groups=cout),
        "g2": _conv_init(ks[2], 1, 1, cout, cout, groups=_SHUF_GROUPS),
    }


def _sunit_apply(p, x, stride):
    h = jax.nn.relu(_conv(x, p["g1"], groups=_SHUF_GROUPS))
    h = _shuffle(h, _SHUF_GROUPS)
    h = _conv(h, p["dw"], stride=stride, groups=h.shape[1])
    h = _conv(h, p["g2"], groups=_SHUF_GROUPS)
    if stride == 1 and x.shape == h.shape:
        h = h + x
    return jax.nn.relu(h)


def shufflenet_t_init(key):
    ks = jax.random.split(key, 6)
    return {
        "stem": _conv_init(ks[0], 3, 3, 3, 32),
        "u1": _sunit_init(ks[1], 32, 64),
        "u2": _sunit_init(ks[2], 64, 64),
        "u3": _sunit_init(ks[3], 64, 128),
        "u4": _sunit_init(ks[4], 128, 128),
        "fc": _fc_init(ks[5], 128, NUM_CLASSES, scale=0.01),
    }


def shufflenet_t_apply(params, x):
    x = jax.nn.relu(_conv(x, params["stem"], stride=2))  # 56 -> 28
    x = _sunit_apply(params["u1"], x, 2)                 # -> 14
    x = _sunit_apply(params["u2"], x, 1)
    x = _sunit_apply(params["u3"], x, 2)                 # -> 7
    x = _sunit_apply(params["u4"], x, 1)
    x = _gap(x)
    return x @ params["fc"]["w"] + params["fc"]["b"]


MODELS = {
    "alexnet_t": (alexnet_t_init, alexnet_t_apply),
    "resnet_t": (resnet_t_init, resnet_t_apply),
    "shufflenet_t": (shufflenet_t_init, shufflenet_t_apply),
}


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Loss / train step
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def make_train_step(apply_fn):
    """(params, images, labels, lr) -> (loss, new_params) — plain SGD."""

    def loss_fn(params, images, labels):
        return cross_entropy(apply_fn(params, images), labels)

    def step(params, images, labels, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, images, labels)
        new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
        return loss, new_params

    return step


# ---------------------------------------------------------------------------
# Preprocessing graphs (call the L1 Pallas kernels)
# ---------------------------------------------------------------------------

def decode_batch(coefs, qtable):
    """[B,C,8,8,8,8] coeffs -> [B,C,64,64] pixels (hybrid decode GPU half)."""
    return _dct.decode_images(coefs, qtable)


def augment_batch(imgs, aug_params):
    """[B,C,64,64] pixels + [B,6] params -> [B,C,56,56] normalized."""
    return _augment.augment_batch(imgs, aug_params, (OUT_HW, OUT_HW))


def fused_preprocess(coefs, qtable, aug_params):
    """Full accelerator-side preprocessing: dequant+IDCT then fused augment.

    One artifact, no host round-trip between the stages — the 'gpu'
    placement in the paper's terms (everything after entropy decode is on
    the accelerator).
    """
    return augment_batch(decode_batch(coefs, qtable), aug_params)
