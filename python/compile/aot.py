"""AOT compiler: lower every L2 graph to HLO TEXT + manifest for the rust runtime.

Run once at build time (`make artifacts`); python is never on the request
path.  Interchange format is HLO *text*, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Outputs (in --out, default ../artifacts):
  <name>.hlo.txt          one per artifact (see ARTIFACTS below)
  params_<model>.bin      initial parameters, raw little-endian f32 in
                          flatten order (jax.tree_util.tree_leaves)
  manifest.json           arg/output specs per artifact + param schemas

Usage:  cd python && python -m compile.aot [--out DIR] [--only NAME_PREFIX]
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

BATCH_MAIN = 32
BATCH_TEST = 8
PARAM_SEED = 42


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer
    # elides big constants as `constant({...})`, which the XLA 0.5.1 text
    # parser silently reads back as ZEROS (e.g. the 8x8 DCT matrices in
    # the decode kernel) — caught by rust/tests/artifact_parity.rs.
    text = comp.as_hlo_text(print_large_constants=True)
    if "{...}" in text:
        raise RuntimeError("HLO text still contains elided constants")
    return text


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _leaf_paths(params):
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    names = []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path).replace("'", "").strip("[]").replace("][", "."))
    return names, [leaf for _, leaf in flat]


def build_artifacts():
    """Return {name: (fn, arg_specs, arg_names)} plus model param schemas."""
    arts = {}
    models = {}

    for b in (BATCH_TEST, BATCH_MAIN):
        coefs = _sds((b, 3, 8, 8, 8, 8))
        q = _sds((8, 8))
        imgs = _sds((b, 3, M.IMG_HW, M.IMG_HW))
        aug = _sds((b, 6))
        arts[f"decode_b{b}"] = (
            lambda c, qt: (M.decode_batch(c, qt),),
            [coefs, q],
            ["coefs", "qtable"],
        )
        arts[f"augment_b{b}"] = (
            lambda i, a: (M.augment_batch(i, a),),
            [imgs, aug],
            ["images", "aug_params"],
        )
        arts[f"fused_pre_b{b}"] = (
            lambda c, qt, a: (M.fused_preprocess(c, qt, a),),
            [coefs, q, aug],
            ["coefs", "qtable", "aug_params"],
        )

    key = jax.random.PRNGKey(PARAM_SEED)
    for mi, (mname, (init_fn, apply_fn)) in enumerate(sorted(M.MODELS.items())):
        params = init_fn(jax.random.fold_in(key, mi))
        names, leaves = _leaf_paths(params)
        treedef = jax.tree_util.tree_structure(params)
        models[mname] = {"params": params, "names": names, "treedef": treedef}

        step = M.make_train_step(apply_fn)
        nleaf = len(leaves)

        def train_flat(*args, _treedef=treedef, _n=nleaf, _step=step):
            p = jax.tree_util.tree_unflatten(_treedef, args[:_n])
            images, labels, lr = args[_n], args[_n + 1], args[_n + 2]
            loss, newp = _step(p, images, labels, lr)
            return (loss, *jax.tree_util.tree_leaves(newp))

        def predict_flat(*args, _treedef=treedef, _n=nleaf, _apply=apply_fn):
            p = jax.tree_util.tree_unflatten(_treedef, args[:_n])
            return (_apply(p, args[_n]),)

        leaf_specs = [_sds(l.shape) for l in leaves]
        batches = (BATCH_MAIN, BATCH_TEST) if mname == "resnet_t" else (BATCH_MAIN,)
        for b in batches:
            x = _sds((b, 3, M.OUT_HW, M.OUT_HW))
            y = _sds((b,), jnp.int32)
            lr = _sds((), jnp.float32)
            arts[f"train_{mname}_b{b}"] = (
                train_flat,
                leaf_specs + [x, y, lr],
                names + ["images", "labels", "lr"],
            )
        xm = _sds((BATCH_MAIN, 3, M.OUT_HW, M.OUT_HW))
        arts[f"predict_{mname}_b{BATCH_MAIN}"] = (
            predict_flat,
            leaf_specs + [xm],
            names + ["images"],
        )

    return arts, models


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="prefix filter for artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    arts, models = build_artifacts()
    manifest = {"format": 1, "batch_main": BATCH_MAIN, "batch_test": BATCH_TEST,
                "img_hw": M.IMG_HW, "out_hw": M.OUT_HW, "num_classes": M.NUM_CLASSES,
                "param_seed": PARAM_SEED, "artifacts": {}, "models": {}}

    for name, (fn, specs, argnames) in sorted(arts.items()):
        if args.only and not name.startswith(args.only):
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_specs = [_spec(o.shape, "f32" if o.dtype == jnp.float32 else str(o.dtype))
                     for o in jax.eval_shape(fn, *specs)]
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [{"name": n, **_spec(s.shape,
                      "i32" if s.dtype == jnp.int32 else "f32")}
                     for n, s in zip(argnames, specs)],
            "outs": out_specs,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"  lowered {name}: {len(text)} chars")

    for mname, info in sorted(models.items()):
        leaves = jax.tree_util.tree_leaves(info["params"])
        blob = b"".join(np.asarray(l, np.float32).tobytes() for l in leaves)
        pfile = f"params_{mname}.bin"
        with open(os.path.join(args.out, pfile), "wb") as f:
            f.write(blob)
        off = 0
        schema = []
        for n, l in zip(info["names"], leaves):
            size = int(np.prod(l.shape)) * 4
            schema.append({"name": n, "shape": list(l.shape), "offset": off, "bytes": size})
            off += size
        manifest["models"][mname] = {
            "param_file": pfile,
            "param_count": M.param_count(info["params"]),
            "leaves": schema,
        }
        print(f"  params {mname}: {off} bytes, {M.param_count(info['params'])} params")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts -> {args.out}")


if __name__ == "__main__":
    main()
