"""L1 Pallas kernel: MJX block decode = dequantize + 8x8 inverse DCT.

This is the paper's "hybrid decode" hot spot (Fig. 3: image decoding is
47.7% of per-image preprocessing time; DALI offloads it to the GPU).  The
MJX codec (rust/src/codec) entropy-decodes on the CPU -- exactly like
nvJPEG's CPU Huffman stage -- and ships *quantized coefficient blocks* to
the accelerator, where this kernel performs dequant + IDCT.

Hardware adaptation (paper targets CUDA threadblocks): the 8x8 IDCT is
expressed as two batched 8x8 matmuls, X = C^T (F*Q) C, the MXU-friendly
systolic-array form.  The grid streams BLOCK_N coefficient blocks per step
through VMEM (BLOCK_N*8*8*4 B = 48 KiB at BLOCK_N=192, well under VMEM);
the quant table is broadcast and stays resident.

Pallas is lowered with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls.  Structure (BlockSpec/grid) is still the TPU schedule.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Number of 8x8 coefficient blocks processed per grid step.  One 64x64 RGB
# image is 3*8*8 = 192 blocks, so BLOCK_N=192 keeps whole images per step.
BLOCK_N = 192


def dct_matrix(dtype=jnp.float32) -> jax.Array:
    """Orthonormal 8x8 DCT-II matrix C, so fwd F = C X C^T, inv X = C^T F C."""
    k = np.arange(8)[:, None].astype(np.float64)
    n = np.arange(8)[None, :].astype(np.float64)
    c = np.cos((2 * n + 1) * k * np.pi / 16.0)
    c *= np.where(k == 0, np.sqrt(1.0 / 8.0), np.sqrt(2.0 / 8.0))
    return jnp.asarray(c, dtype=dtype)


def _dequant_idct_kernel(coef_ref, q_ref, c_ref, out_ref):
    """coef_ref: [BLOCK_N,8,8] quantized coeffs; q_ref: [8,8] quant table;
    c_ref: [8,8] DCT matrix (kept VMEM-resident across the grid)."""
    cmat = c_ref[...]
    f = coef_ref[...] * q_ref[...][None, :, :]  # dequantize
    # X = C^T F C as two batched matmuls (MXU form).
    x = jnp.matmul(cmat.T, f)  # [8,8]x[N,8,8] -> [N,8,8]
    x = jnp.matmul(x, cmat)
    # Level shift and clamp to pixel range.
    out_ref[...] = jnp.clip(x + 128.0, 0.0, 255.0)


@functools.partial(jax.jit, static_argnames=())
def dequant_idct(coefs: jax.Array, qtable: jax.Array) -> jax.Array:
    """Dequantize + inverse-DCT a stream of 8x8 blocks.

    Args:
      coefs: [N, 8, 8] float32 -- quantized DCT coefficients (natural row
        order, i.e. already inverse-zigzagged by the entropy decoder).
        N must be a multiple of BLOCK_N (the AOT artifacts use padded,
        fixed batch shapes).
      qtable: [8, 8] float32 quantization table.

    Returns:
      [N, 8, 8] float32 pixel blocks in [0, 255].
    """
    n = coefs.shape[0]
    if n % BLOCK_N != 0:
        raise ValueError(f"N={n} must be a multiple of BLOCK_N={BLOCK_N}")
    cmat = dct_matrix(coefs.dtype)
    return pl.pallas_call(
        _dequant_idct_kernel,
        grid=(n // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N, 8, 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, 8, 8), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 8, 8), coefs.dtype),
        interpret=True,
    )(coefs, qtable, cmat)


def decode_images(coefs: jax.Array, qtable: jax.Array) -> jax.Array:
    """Decode a batch of coefficient tensors into images.

    Args:
      coefs: [B, C, H/8, W/8, 8, 8] quantized coefficients.
      qtable: [8, 8].

    Returns:
      [B, C, H, W] float32 pixels in [0, 255].
    """
    b, c, bh, bw, _, _ = coefs.shape
    flat = coefs.reshape(b * c * bh * bw, 8, 8)
    # Pad the block stream to a BLOCK_N multiple for the kernel grid.
    n = flat.shape[0]
    pad = (-n) % BLOCK_N
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad, 8, 8), flat.dtype)], 0)
    pix = dequant_idct(flat, qtable)[:n]
    # [B,C,bh,bw,8,8] -> [B,C,bh,8,bw,8] -> [B,C,H,W]
    pix = pix.reshape(b, c, bh, bw, 8, 8).transpose(0, 1, 2, 4, 3, 5)
    return pix.reshape(b, c, bh * 8, bw * 8)
