"""Pure-jnp oracles for the Pallas kernels (correctness references).

Every kernel in this package has a reference implementation here written
with plain jax.numpy ops and no Pallas.  python/tests asserts
allclose(kernel, ref) across shape/parameter sweeps (hypothesis), and the
rust integration tests compare the AOT artifacts against the rust CPU
implementations of the same operators.
"""

import jax
import jax.numpy as jnp
import numpy as np

# ImageNet-style normalization constants, scaled to the 0..255 pixel range.
NORM_MEAN = np.array([0.485, 0.456, 0.406], np.float32) * 255.0
NORM_STD = np.array([0.229, 0.224, 0.225], np.float32) * 255.0


def dct_matrix_np() -> np.ndarray:
    k = np.arange(8)[:, None].astype(np.float64)
    n = np.arange(8)[None, :].astype(np.float64)
    c = np.cos((2 * n + 1) * k * np.pi / 16.0)
    c *= np.where(k == 0, np.sqrt(1.0 / 8.0), np.sqrt(2.0 / 8.0))
    return c.astype(np.float32)


def fdct_blocks(blocks: np.ndarray) -> np.ndarray:
    """Forward DCT of [N,8,8] level-shifted pixel blocks (test helper)."""
    c = dct_matrix_np()
    return np.einsum("ij,njk,lk->nil", c, blocks, c)


def dequant_idct_ref(coefs: jax.Array, qtable: jax.Array) -> jax.Array:
    """Reference for kernels.dct.dequant_idct: [N,8,8] -> [N,8,8]."""
    c = jnp.asarray(dct_matrix_np(), coefs.dtype)
    f = coefs * qtable[None, :, :]
    x = jnp.einsum("ji,njk,kl->nil", c, f, c)  # C^T F C
    return jnp.clip(x + 128.0, 0.0, 255.0)


def decode_images_ref(coefs: jax.Array, qtable: jax.Array) -> jax.Array:
    """Reference for kernels.dct.decode_images."""
    b, ch, bh, bw, _, _ = coefs.shape
    flat = coefs.reshape(b * ch * bh * bw, 8, 8)
    pix = dequant_idct_ref(flat, qtable)
    pix = pix.reshape(b, ch, bh, bw, 8, 8).transpose(0, 1, 2, 4, 3, 5)
    return pix.reshape(b, ch, bh * 8, bw * 8)


def augment_ref(img: jax.Array, params: jax.Array, out_hw: tuple) -> jax.Array:
    """Reference for kernels.augment.augment_batch, one image.

    img: [C, H, W] pixels in [0,255].
    params: [6] = (y0, x0, crop_h, crop_w, flip, _pad) as float32.
    out_hw: static (OH, OW).

    Crop the window, optionally horizontally flip it, bilinear-resize to
    out_hw, then normalize with ImageNet mean/std.
    """
    c, h, w = img.shape
    oh, ow = out_hw
    y0, x0, ch_, cw_, flip = params[0], params[1], params[2], params[3], params[4]

    iy = (jnp.arange(oh, dtype=img.dtype) + 0.5) * ch_ / oh - 0.5
    ix = (jnp.arange(ow, dtype=img.dtype) + 0.5) * cw_ / ow - 0.5
    # Horizontal flip mirrors the sample coordinate inside the crop window.
    ix = jnp.where(flip > 0.5, (cw_ - 1.0) - ix, ix)
    # Clamp inside the crop window so the crop never bleeds neighbours,
    # then into the image (defensive; a valid window is already inside).
    sy = jnp.clip(jnp.clip(iy, 0.0, ch_ - 1.0) + y0, 0.0, h - 1.0)
    sx = jnp.clip(jnp.clip(ix, 0.0, cw_ - 1.0) + x0, 0.0, w - 1.0)

    y0i = jnp.floor(sy).astype(jnp.int32)
    x0i = jnp.floor(sx).astype(jnp.int32)
    y1i = jnp.minimum(y0i + 1, h - 1)
    x1i = jnp.minimum(x0i + 1, w - 1)
    wy = (sy - y0i.astype(img.dtype))[:, None]
    wx = (sx - x0i.astype(img.dtype))[None, :]

    def gather(yi, xi):
        return img[:, yi, :][:, :, xi]  # [C, OH, OW]

    v00 = gather(y0i, x0i)
    v01 = gather(y0i, x1i)
    v10 = gather(y1i, x0i)
    v11 = gather(y1i, x1i)
    top = v00 * (1 - wx) + v01 * wx
    bot = v10 * (1 - wx) + v11 * wx
    out = top * (1 - wy) + bot * wy

    mean = jnp.asarray(NORM_MEAN, img.dtype)[:, None, None]
    std = jnp.asarray(NORM_STD, img.dtype)[:, None, None]
    return (out - mean) / std


def augment_batch_ref(imgs: jax.Array, params: jax.Array, out_hw: tuple) -> jax.Array:
    """Reference for the batched fused augment: [B,C,H,W],[B,6] -> [B,C,OH,OW]."""
    return jax.vmap(lambda i, p: augment_ref(i, p, out_hw))(imgs, params)
