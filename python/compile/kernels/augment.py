"""L1 Pallas kernel: fused augmentation (crop + flip + bilinear resize + normalize).

Fig. 3 of the paper shows crop/resize/flip/normalize together cost ~47% of
per-image preprocessing (everything except decode and read).  DALI fuses
them into one GPU stage; we fuse them into one Pallas kernel so the whole
augmentation is a single VMEM-resident pass per image.

Layout: grid over the batch dimension; each grid step holds one [C,H,W]
image in VMEM (64x64x3 f32 = 48 KiB) plus its [6] parameter row, and writes
a [C,OH,OW] normalized tile.  Sampling coordinates are computed in-kernel
from the parameter row (y0, x0, crop_h, crop_w, flip); randomness lives in
the *coordinator* (rust samples the crop/flip), which keeps the compiled
artifact deterministic -- the same trick DALI uses for reproducible runs.

interpret=True: see kernels/dct.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref


def _augment_kernel(img_ref, par_ref, norm_ref, out_ref, *, out_hw):
    img = img_ref[...][0]  # [C, H, W]
    par = par_ref[...][0]  # [6]
    norm = norm_ref[...]  # [2, C] = (mean; std)
    c, h, w = img.shape
    oh, ow = out_hw
    y0, x0, ch_, cw_, flip = par[0], par[1], par[2], par[3], par[4]

    iy = (jnp.arange(oh, dtype=img.dtype) + 0.5) * ch_ / oh - 0.5
    ix = (jnp.arange(ow, dtype=img.dtype) + 0.5) * cw_ / ow - 0.5
    ix = jnp.where(flip > 0.5, (cw_ - 1.0) - ix, ix)
    # Clamp inside the crop window (no bleed), then into the image.
    sy = jnp.clip(jnp.clip(iy, 0.0, ch_ - 1.0) + y0, 0.0, h - 1.0)
    sx = jnp.clip(jnp.clip(ix, 0.0, cw_ - 1.0) + x0, 0.0, w - 1.0)

    y0i = jnp.floor(sy).astype(jnp.int32)
    x0i = jnp.floor(sx).astype(jnp.int32)
    y1i = jnp.minimum(y0i + 1, h - 1)
    x1i = jnp.minimum(x0i + 1, w - 1)
    wy = (sy - y0i.astype(img.dtype))[:, None]
    wx = (sx - x0i.astype(img.dtype))[None, :]

    # Bilinear gather: one flattened take per corner keeps this a dense
    # vector op (VPU-friendly) instead of 4*OH*OW scalar loads.
    flat = img.reshape(c, h * w)

    def gather(yi, xi):
        idx = (yi[:, None] * w + xi[None, :]).reshape(-1)  # [OH*OW]
        g = jnp.take(flat, idx, axis=1)
        return g.reshape(c, *out_hw)

    v00 = gather(y0i, x0i)
    v01 = gather(y0i, x1i)
    v10 = gather(y1i, x0i)
    v11 = gather(y1i, x1i)
    top = v00 * (1 - wx) + v01 * wx
    bot = v10 * (1 - wx) + v11 * wx
    out = top * (1 - wy) + bot * wy

    mean = norm[0][:, None, None]
    std = norm[1][:, None, None]
    out_ref[...] = ((out - mean) / std)[None]


def augment_batch(imgs: jax.Array, params: jax.Array, out_hw=(56, 56)) -> jax.Array:
    """Fused crop+flip+resize+normalize over a batch.

    Args:
      imgs: [B, C, H, W] float32 pixels in [0, 255].
      params: [B, 6] float32 rows (y0, x0, crop_h, crop_w, flip, _pad),
        sampled by the rust coordinator's RNG.
      out_hw: static output spatial size.

    Returns:
      [B, C, OH, OW] float32, ImageNet-normalized.
    """
    b, c, h, w = imgs.shape
    oh, ow = out_hw
    kernel = functools.partial(_augment_kernel, out_hw=(oh, ow))
    norm = jnp.stack(
        [jnp.asarray(_ref.NORM_MEAN, imgs.dtype), jnp.asarray(_ref.NORM_STD, imgs.dtype)]
    )
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, 6), lambda i: (i, 0)),
            pl.BlockSpec((2, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, oh, ow), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c, oh, ow), imgs.dtype),
        interpret=True,
    )(imgs, params, norm)
