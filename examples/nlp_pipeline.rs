//! NLP pipeline example — the paper's §5 future-work direction: the SAME
//! record/storage/shuffle/batch machinery, with a text front-end
//! (normalize → tokenize → vocab encode → pad) instead of image decode.
//!
//! Reports the end-to-end tokenization throughput and the per-operator
//! breakdown (the Fig. 3 analysis applied to text preprocessing).
//!
//! Run with: `cargo run --release --example nlp_pipeline [-- --docs 2000]`

use dpp::nlp::{self, Vocab};
use dpp::record::ShardWriter;
use dpp::pipeline::shuffle::ShuffleBuffer;
use dpp::pipeline::source::{list_shards, stream_shards};
use dpp::storage::{DirStore, Storage};
use dpp::util::cli::Args;
use dpp::util::rng::Rng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_docs = args.get_usize("docs", 2000);
    let seq_len = args.get_usize("seq-len", 128);
    let batch = args.get_usize("batch", 32);
    let dir = std::env::temp_dir().join("dpp-nlp");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("records"))?;

    // Offline: synthesize a labeled corpus, pack into record shards
    // (records are format-agnostic payloads — same shards as images).
    let mut rng = Rng::new(42);
    let mut docs = Vec::with_capacity(n_docs);
    for i in 0..n_docs {
        let class = (rng.gen_range(4)) as u16;
        let words = 60 + rng.gen_range(120) as usize;
        docs.push((i as u64, class, nlp::gen_document(&mut rng.fork(i as u64), class, words)));
    }
    let mut w = ShardWriter::create(&dir.join("records/shard-00000.rec"))?;
    for (id, label, text) in &docs {
        w.append(*id, *label, text.as_bytes())?;
    }
    w.finish()?;

    // Vocabulary built offline from a sample (what a tokenizer-training
    // step would do).
    let vocab = Vocab::build(docs.iter().take(500).map(|(_, _, t)| t.as_str()), 4096);
    println!("corpus: {n_docs} docs, vocab size {}", vocab.size);

    // Online: stream records sequentially, shuffle-buffer, tokenize+pad,
    // collate [B, L] batches; time the operator breakdown.
    let store: Arc<dyn Storage> = Arc::new(DirStore::new(&dir)?);
    let shards = list_shards(store.as_ref(), "records/")?;
    let mut sb = ShuffleBuffer::new(256, Rng::new(7));
    let (mut norm_ns, mut tok_ns, mut enc_ns, mut read_bytes) = (0u64, 0u64, 0u64, 0u64);
    let mut seqs: Vec<Vec<i32>> = Vec::new();
    let mut labels: Vec<i32> = Vec::new();
    let mut batches = 0usize;
    let t0 = std::time::Instant::now();
    let mut handle = |id: u64, label: u16, payload: &[u8]| -> anyhow::Result<()> {
        let _ = id;
        read_bytes += payload.len() as u64;
        let text = std::str::from_utf8(payload)?;
        let (ids, t) = nlp::timed_encode(&vocab, text, seq_len);
        norm_ns += t.normalize_ns;
        tok_ns += t.tokenize_ns;
        enc_ns += t.encode_ns;
        seqs.push(ids);
        labels.push(label as i32);
        if seqs.len() == batch {
            let (flat, ls) = nlp::collate_text(std::mem::take(&mut seqs), std::mem::take(&mut labels))?;
            assert_eq!(flat.len(), batch * seq_len);
            assert_eq!(ls.len(), batch);
            batches += 1;
        }
        Ok(())
    };
    stream_shards(store, &shards, 1 << 20, |rec| {
        if let Some(ev) = sb.push(rec) {
            handle(ev.id, ev.label, &ev.payload)?;
        }
        Ok(true)
    })?;
    for rec in sb.drain() {
        handle(rec.id, rec.label, &rec.payload)?;
    }
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "tokenized {n_docs} docs into {batches} [{}x{}] batches in {wall:.2}s = {:.0} docs/s",
        batch,
        seq_len,
        n_docs as f64 / wall
    );
    let total = (norm_ns + tok_ns + enc_ns) as f64;
    println!("per-operator breakdown (text analogue of Fig. 3):");
    println!("  normalize {:>5.1}%", norm_ns as f64 / total * 100.0);
    println!("  tokenize  {:>5.1}%", tok_ns as f64 / total * 100.0);
    println!("  encode+pad{:>5.1}%", enc_ns as f64 / total * 100.0);
    println!("  payload bytes streamed: {}", dpp::util::human_bytes(read_bytes));
    Ok(())
}
