//! End-to-end validation driver (DESIGN.md "End-to-end validation"):
//!
//! * generates a 4096-image, 16-class synthetic MJX corpus + record shards
//! * runs the REAL pipeline (record + hybrid: rust entropy decode → AOT
//!   dequant+IDCT+augment artifact → batcher → train artifact) for several
//!   hundred steps
//! * logs the loss curve (must fall), throughput of both pipeline halves,
//!   and per-resource utilization
//! * then compares placements (cpu / hybrid / hybrid0) and the ideal mode
//!   on a shorter budget — the Fig. 2 experiment, for real, at mini scale.
//!
//! Results are recorded in EXPERIMENTS.md.  Run:
//!   cargo run --release --example train_e2e [-- --images 4096 --steps 300]

use dpp::config::{Method, Placement, RunConfig};
use dpp::coordinator;
use dpp::dataset::GenConfig;
use dpp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_images = args.get_usize("images", 4096);
    let steps = args.get_usize("steps", 300);
    let data_dir = std::env::temp_dir().join("dpp-e2e");

    println!("== e2e: preparing {n_images}-image corpus ==");
    let layout = coordinator::prepare_data(
        &data_dir,
        &GenConfig { n_images, ..Default::default() },
        4,
    )?;
    anyhow::ensure!(layout.entries.len() == n_images, "stale corpus at {data_dir:?}; delete it");

    let base = RunConfig {
        data_dir: data_dir.clone(),
        artifact_dir: "artifacts".into(),
        method: Method::Record,
        placement: Placement::Hybrid,
        model: "resnet_t".into(),
        batch_size: 32,
        cpu_workers: 2,
        steps,
        lr: 0.15,
        sample_period: 2.0,
        ..Default::default()
    };

    println!("== e2e: training resnet_t for {steps} steps (record-hybrid) ==");
    let report = coordinator::run(&base)?;
    report.print_summary("e2e record-hybrid");
    let losses = &report.losses;
    let k = 10.min(losses.len());
    let first_avg: f32 = losses.iter().take(k).map(|(_, l)| l).sum::<f32>() / k as f32;
    let last_avg: f32 = losses.iter().rev().take(k).map(|(_, l)| l).sum::<f32>() / k as f32;
    println!("loss curve: first-{k} avg {first_avg:.3} -> last-{k} avg {last_avg:.3}");
    for (s, l) in losses.iter().step_by((losses.len() / 12).max(1)) {
        println!("  step {s:>4}  loss {l:.4}");
    }
    anyhow::ensure!(
        last_avg < 0.8 * first_avg,
        "loss did not fall: {first_avg} -> {last_avg}"
    );
    if !report.util_trace.is_empty() {
        println!("utilization trace (cpu / device / io):");
        for u in report.util_trace.iter().step_by(2) {
            println!(
                "  t={:>5.1}s cpu={:>5.1}% dev={:>5.1}% io={:>6.2} MB/s",
                u.t,
                u.cpu * 100.0,
                u.device * 100.0,
                u.io_mbps
            );
        }
    }

    println!("\n== e2e: placement comparison (mini Fig. 2, {} steps each) ==", steps / 4);
    let mut rows = Vec::new();
    for (name, method, placement, ideal) in [
        ("raw-cpu", Method::Raw, Placement::Cpu, false),
        ("record-cpu", Method::Record, Placement::Cpu, false),
        ("record-hybrid0", Method::Record, Placement::Hybrid0, false),
        ("record-hybrid", Method::Record, Placement::Hybrid, false),
        ("ideal", Method::Record, Placement::Hybrid, true),
    ] {
        let cfg = RunConfig {
            method,
            placement,
            ideal,
            steps: (steps / 4).max(10),
            sample_period: 0.0,
            ..base.clone()
        };
        let r = coordinator::run(&cfg)?;
        println!(
            "  {name:<16} train {:>7.1} img/s  preproc {:>7.1} img/s  dev {:>3.0}%",
            r.train_ips,
            r.preproc_ips,
            r.device_util * 100.0
        );
        rows.push((name, r.train_ips));
    }
    let get = |n: &str| rows.iter().find(|(m, _)| *m == n).unwrap().1;
    println!(
        "\n  record-hybrid / ideal = {:.0}%   (the paper's GPU-starvation headline, Fig. 2)",
        get("record-hybrid") / get("ideal") * 100.0
    );
    Ok(())
}
