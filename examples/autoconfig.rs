//! The paper's proposed tool (§4/§5), demonstrated: model-specific,
//! fine-grained cloud resource configuration.
//!
//! For each paper model it reports the best configuration for (a) max
//! throughput and (b) min $/image, then shows a what-if: the default
//! "always rent the full p3.16xlarge" versus the recommendation.
//!
//! Run with: `cargo run --release --example autoconfig`

use dpp::autoconf::{self, Objective};
use dpp::sim::{analytic_throughput, Scenario};

fn main() -> anyhow::Result<()> {
    println!("=== model-specific resource auto-configuration (Table 1 catalog) ===\n");
    for model in ["alexnet", "shufflenet", "resnet18", "resnet50", "resnet152"] {
        println!("--- {model} ---");
        for obj in [Objective::Throughput, Objective::Cost] {
            let rec = autoconf::recommend(model, obj, f64::INFINITY)?;
            println!("{:?} best: {}", obj, rec.best.row());
        }
        // What-if versus the naive full-box default.
        let naive = Scenario { model: model.into(), gpus: 8, vcpus: 64, ..Default::default() };
        let naive_t = analytic_throughput(&naive);
        let naive_price = 24.48;
        let naive_cost = naive_price / (naive_t * 3600.0) * 1e6;
        let rec = autoconf::recommend(model, Objective::Cost, f64::INFINITY)?;
        println!(
            "naive p3.16xlarge default: {naive_t:.0} img/s at ${naive_price}/h = ${naive_cost:.2}/Mimg"
        );
        println!(
            "=> cost-optimal config saves {:.0}% per image\n",
            (1.0 - rec.best.dollars_per_mimg / naive_cost) * 100.0
        );
    }

    println!("=== budgeted recommendation (max throughput under $5/h) ===");
    for model in ["alexnet", "resnet50"] {
        let rec = autoconf::recommend(model, Objective::Throughput, 5.0)?;
        println!("{model}: {}", rec.best.row());
    }
    Ok(())
}
