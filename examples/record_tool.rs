//! Record-file tooling example: the offline record generation workflow of
//! the paper (Fig. 1 steps ①–③) plus shard inspection and integrity
//! verification — what a dataset engineer would run before training.
//!
//! Run with: `cargo run --release --example record_tool [-- --images 256]`

use dpp::codec;
use dpp::dataset::{self, GenConfig};
use dpp::pipeline::source::{list_shards, stream_shards, StorageReader};
use dpp::record::{self, ShardReader};
use dpp::storage::{DirStore, Storage};
use dpp::util::cli::Args;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("images", 256);
    let shards_n = args.get_usize("shards", 4);
    let dir = std::env::temp_dir().join("dpp-record-tool");
    let _ = std::fs::remove_dir_all(&dir);

    // 1. Raw corpus.
    let store = DirStore::new(&dir)?;
    let entries = dataset::generate_raw(&store, &GenConfig { n_images: n, ..Default::default() })?;
    let raw_bytes: u64 = entries.iter().map(|e| store.len(&e.path).unwrap()).sum();
    println!(
        "raw corpus: {} files, {} total",
        entries.len(),
        dpp::util::human_bytes(raw_bytes)
    );

    // 2. Pack into record shards (offline step of the record method).
    let rec_dir = dir.join("records");
    let names = dataset::build_records(&store, &entries, &rec_dir, shards_n)?;
    for name in &names {
        let len = std::fs::metadata(rec_dir.join(name))?.len();
        println!("  shard {name}: {}", dpp::util::human_bytes(len));
    }

    // 3. Inspect: per-shard index stats and label histogram.
    let mut label_hist = vec![0u32; 16];
    for name in &names {
        let idx = std::fs::read(rec_dir.join(name).with_extension("idx"))?;
        let metas = record::read_index(&idx)?;
        for m in &metas {
            label_hist[m.label as usize % 16] += 1;
        }
    }
    println!("label histogram: {label_hist:?}");

    // 4. Verify: stream every record sequentially (checksums validate on
    //    parse) and ensure each payload decodes and matches its raw file.
    let store: Arc<dyn Storage> = Arc::new(DirStore::new(&dir)?);
    let shard_names = list_shards(store.as_ref(), "records/")?;
    let mut verified = 0usize;
    stream_shards(store.clone(), &shard_names, 1 << 20, |rec| {
        let raw = store.read(&entries[rec.id as usize].path)?;
        anyhow::ensure!(raw[..] == rec.payload[..], "record {} differs from raw file", rec.id);
        let img = codec::decode_cpu(&rec.payload)?;
        anyhow::ensure!(img.c == 3, "bad channels");
        verified += 1;
        Ok(true)
    })?;
    println!("verified {verified}/{n} records (checksum + decode + raw-file equality)");

    // 5. Chunk-size experiment: sequential read efficiency per chunk size.
    println!("chunked streaming of shard 0 (records/sec by chunk size):");
    for chunk in [4usize << 10, 64 << 10, 1 << 20] {
        let t = std::time::Instant::now();
        let reader = StorageReader::open(store.clone(), &shard_names[0])?;
        let mut sr = ShardReader::new(reader, chunk);
        let mut cnt = 0;
        while sr.next_record()?.is_some() {
            cnt += 1;
        }
        println!(
            "  chunk {:>10}: {cnt} records in {:?}",
            dpp::util::human_bytes(chunk as u64),
            t.elapsed()
        );
    }
    Ok(())
}
