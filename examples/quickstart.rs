//! Quickstart: generate a tiny corpus, run the full preprocessing
//! pipeline with hybrid placement, and train a small CNN for a handful of
//! steps — the 60-second tour of the public API.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` to have produced artifacts/.)

use dpp::config::{Method, Placement, RunConfig};
use dpp::coordinator;
use dpp::dataset::GenConfig;

fn main() -> anyhow::Result<()> {
    let data_dir = std::env::temp_dir().join("dpp-quickstart");

    // 1. Offline phase: synthesize a labeled corpus and pack record shards
    //    (the paper's Fig. 1 offline steps).
    let layout = coordinator::prepare_data(
        &data_dir,
        &GenConfig { n_images: 128, ..Default::default() },
        2,
    )?;
    println!(
        "corpus: {} images in {} record shards",
        layout.entries.len(),
        layout.shards.len()
    );

    // 2. Online phase: record-file loading, hybrid placement (CPU entropy
    //    decode -> accelerator dequant+IDCT+augment), then train.
    let cfg = RunConfig {
        data_dir,
        artifact_dir: "artifacts".into(),
        method: Method::Record,
        placement: Placement::Hybrid,
        model: "resnet_t".into(),
        batch_size: 8,
        cpu_workers: 2,
        steps: 10,
        lr: 0.2,
        ..Default::default()
    };
    let report = coordinator::run(&cfg)?;
    report.print_summary("quickstart");

    let first = report.losses.first().expect("losses recorded").1;
    let last = report.losses.last().unwrap().1;
    println!("loss: {first:.3} -> {last:.3} over {} steps", report.steps);

    // 3. The same scenario at the paper's scale, via the simulator.
    let scen = dpp::sim::Scenario {
        model: "resnet50".into(),
        ..Default::default()
    };
    println!(
        "paper-scale sim (resnet50 record-hybrid, 8xV100): {:.0} img/s",
        dpp::sim::analytic_throughput(&scen)
    );
    Ok(())
}
