//! Acceptance tests for the fused ROI + fractional-scale decode:
//!
//! * a seeded property harness asserting that the fused ROI decode is
//!   **bit-identical** to crop-of-full-decode across random dims, crops
//!   (block-aligned or not), qualities, and flips — both at the codec
//!   layer and through the `cpu`-placement pipeline stage;
//! * the ISSUE's counter-based acceptance: the representative
//!   RandomResizedCrop (64×64 image, ~0.4-area crop, out_hw = 56) must
//!   dequant+IDCT ≥2× fewer blocks with `--fused-decode on` vs `off`;
//! * the sim contract: the calibrated decoded-block fraction agrees with
//!   the engine's measured plan fraction (within 20%), and the analytic
//!   decode service time reflects it;
//! * tolerance checks for the opt-in fractional scale.

use dpp::codec::{self, DecodePlan};
use dpp::config::Placement;
use dpp::ops::{self, AugParams};
use dpp::pipeline::{DecodeOpts, Payload, StageCtx};
use dpp::sim::calib;
use dpp::testing::{check, PropConfig};
use dpp::util::rng::Rng;

/// The unified chain with the plain full decode (the old `cpu_stage`).
fn full_ctx(out_hw: usize) -> StageCtx {
    StageCtx::new(Placement::Cpu, out_hw)
}

/// The unified chain with the fused plan (the old `cpu_stage_planned`).
fn fused_ctx(out_hw: usize, max_scale_log2: u8) -> StageCtx {
    StageCtx::new(Placement::Cpu, out_hw)
        .with_opts(DecodeOpts { fused: true, max_scale_log2 })
}

fn smooth_image(rng: &mut Rng, c: usize, h: usize, w: usize) -> codec::Image {
    let mut img = codec::Image::new(c, h, w);
    let fx = rng.uniform(0.02, 0.2);
    let fy = rng.uniform(0.02, 0.2);
    let phase = rng.uniform(0.0, 3.0);
    for ch in 0..c {
        for y in 0..h {
            for x in 0..w {
                let v = 128.0
                    + 80.0 * ((x as f64 * fx + phase).sin() * (y as f64 * fy).cos())
                    + 15.0 * ch as f64;
                img.data[ch * h * w + y * w + x] = v.clamp(0.0, 255.0) as u8;
            }
        }
    }
    img
}

/// ROI parity, codec layer: fused full-scale decode == the same window
/// of the full decode, bit for bit, for arbitrary non-aligned crops.
#[test]
fn prop_roi_decode_is_bitwise_crop_of_full_decode() {
    check(
        "roi-decode-parity",
        PropConfig { cases: 40, ..Default::default() },
        |rng, size| {
            // Dims are 8-aligned (codec requirement), 16..=64 px.
            let h = 8 * (2 + rng.gen_range(1 + 6 * size as u64 / 100) as usize).min(8);
            let w = 8 * (2 + rng.gen_range(1 + 6 * size as u64 / 100) as usize).min(8);
            let c = if rng.bool() { 3 } else { 1 };
            let quality = 20 + rng.gen_range(80) as u8;
            // Arbitrary (non-block-aligned) crop inside the image.
            let ch = 1 + rng.gen_range(h as u64) as usize;
            let cw = 1 + rng.gen_range(w as u64) as usize;
            let y0 = rng.gen_range((h - ch + 1) as u64) as usize;
            let x0 = rng.gen_range((w - cw + 1) as u64) as usize;
            let seed = rng.next_u32() as u64;
            (c, h, w, quality, (y0, x0, ch, cw), seed)
        },
        |&(c, h, w, quality, crop, seed)| {
            let img = smooth_image(&mut Rng::new(seed), c, h, w);
            let bytes = codec::encode(&img, quality).unwrap();
            let full = codec::decode_cpu(&bytes).unwrap();
            let plan = DecodePlan::new(c, h, w, crop, 56, 0);
            let (roi, stats) = codec::decode_cpu_planned(&bytes, &plan).unwrap();
            let (oy, ox) = plan.origin();
            for ch in 0..c {
                for y in 0..roi.h {
                    for x in 0..roi.w {
                        if roi.pixel(ch, y, x) != full.pixel(ch, oy + y, ox + x) {
                            return false;
                        }
                    }
                }
            }
            stats.blocks_idct + stats.blocks_skipped == (c * (h / 8) * (w / 8)) as u64
        },
    );
}

/// ROI parity, pipeline layer: the fused `cpu` stage produces the exact
/// f32 tensor of the full stage (decode + view-augment bit-identity
/// composed), for sampled RandomResizedCrop params.
#[test]
fn prop_fused_cpu_stage_matches_full_stage_bitwise() {
    check(
        "fused-cpu-stage-parity",
        PropConfig { cases: 30, ..Default::default() },
        |rng, _| {
            let seed = rng.next_u32() as u64;
            let aug_seed = rng.next_u32() as u64;
            (seed, aug_seed)
        },
        |&(seed, aug_seed)| {
            let img = smooth_image(&mut Rng::new(seed), 3, 64, 64);
            let bytes = codec::encode(&img, 85).unwrap();
            let aug = ops::sample_aug_params(&mut Rng::new(aug_seed), 64, 64);
            let (full, _) = full_ctx(56).run_stage(&bytes, 0, aug).unwrap();
            let (fused, _) = fused_ctx(56, 0).run_stage(&bytes, 0, aug).unwrap();
            match (full, fused) {
                (Payload::Ready(a), Payload::Ready(b)) => a == b,
                _ => false,
            }
        },
    );
}

/// The ISSUE acceptance: representative RandomResizedCrop (64×64 image,
/// ~0.4-area crop = 40×40, out 56) does ≥2× fewer dequant+IDCT block
/// operations fused vs full.
#[test]
fn fused_decode_halves_block_operations_on_representative_crop() {
    let img = smooth_image(&mut Rng::new(3), 3, 64, 64);
    let bytes = codec::encode(&img, 85).unwrap();
    let aug = AugParams { y0: 0, x0: 0, crop_h: 40, crop_w: 40, flip: true };
    let (_, on) = fused_ctx(56, 0).run_stage(&bytes, 0, aug).unwrap();
    let (_, off) = full_ctx(56).run_stage(&bytes, 0, aug).unwrap();
    assert_eq!(off.blocks_idct, 3 * 64);
    assert_eq!(on.blocks_idct, 3 * 25, "40x40 at the origin covers 5x5 blocks");
    assert!(
        on.blocks_idct * 2 <= off.blocks_idct,
        "fused {} vs full {}: must be >= 2x fewer",
        on.blocks_idct,
        off.blocks_idct
    );
    assert_eq!(on.blocks_idct + on.blocks_skipped, off.blocks_idct);
}

/// Sim contract: the calibrated block fraction tracks the engine's mean
/// planned fraction under the real aug distribution within 20%, and the
/// analytic CPU service time thins by exactly the calibrated amount.
#[test]
fn sim_decode_service_time_reflects_measured_block_fraction() {
    let mut rng = Rng::new(0xB10C);
    let n = 2000;
    let mut sum = 0.0;
    for _ in 0..n {
        let aug = ops::sample_aug_params(&mut rng, 64, 64);
        let crop =
            (aug.y0 as usize, aug.x0 as usize, aug.crop_h as usize, aug.crop_w as usize);
        sum += DecodePlan::new(3, 64, 64, crop, 56, 0).block_fraction();
    }
    let measured = sum / n as f64;
    let rel = (calib::FUSED_BLOCK_FRACTION - measured).abs() / measured;
    assert!(
        rel < 0.20,
        "calibrated fraction {} vs measured {measured:.3} ({rel:.3})",
        calib::FUSED_BLOCK_FRACTION
    );
    // Analytic model: fused thins the cpu cost by SHARE_XFORM*(1-frac).
    let base = dpp::sim::Scenario {
        model: "alexnet".into(),
        placement: Placement::Cpu,
        ..Default::default()
    };
    let fused = dpp::sim::Scenario { fused_decode: true, ..base.clone() };
    let saved = base.cpu_cost_ms() - fused.cpu_cost_ms();
    let want = calib::SHARE_XFORM * (1.0 - calib::FUSED_BLOCK_FRACTION) * calib::CPU_PREPROC_MS;
    assert!((saved - want).abs() < 1e-9, "saved {saved} want {want}");
}

/// The opt-in fractional scale: tolerance-checked against the full-path
/// output (never bit-checked — it is a quality trade-off), and the
/// scaled path must actually engage when the geometry allows it.
#[test]
fn fractional_scale_stays_within_tolerance_of_full_path() {
    let mut worst: f32 = 0.0;
    for seed in 0..8u64 {
        let img = smooth_image(&mut Rng::new(100 + seed), 3, 64, 64);
        let bytes = codec::encode(&img, 95).unwrap();
        // A 32x32 crop feeding a 16x16 output allows 1/2 scale.
        let aug = AugParams { y0: 8, x0: 16, crop_h: 32, crop_w: 32, flip: seed % 2 == 0 };
        let (full, _) = full_ctx(16).run_stage(&bytes, 0, aug).unwrap();
        let (scaled, stats) = fused_ctx(16, 3).run_stage(&bytes, 0, aug).unwrap();
        assert_eq!(stats.scale_log2, 1, "1/2 scale must engage");
        let (Payload::Ready(a), Payload::Ready(b)) = (full, scaled) else { panic!() };
        assert_eq!(a.len(), b.len());
        // Outputs are ImageNet-normalized (std ≈ 57..64 pixel levels):
        // a mean abs error of 0.15 is ≈ 9 pixel levels — the half-band
        // resample against smooth content sits well inside that.
        let mae: f32 =
            a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32;
        worst = worst.max(mae);
        assert!(mae < 0.15, "seed {seed}: mean abs error {mae}");
    }
    // The comparison is not vacuous: the paths genuinely differ.
    assert!(worst > 0.0, "scaled path should not be bit-identical");
}
