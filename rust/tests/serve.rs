//! Acceptance gates for multi-tenant serve mode (ISSUE 10).
//!
//! Three end-to-end gates, each run through the real engine
//! (`dpp::service::engine`) against the closed-form shared-tier model
//! (`dpp::sim::serve`):
//!
//! 1. **hit-rate isolation** — an aggressor joining mid-epoch cannot
//!    collapse a victim's steady-state hit rate when per-job quotas are
//!    on; with quotas off the same churn demonstrates the collapse;
//! 2. **admission control** — the model's predicted tenant ceiling is
//!    the engine's observed one: the (N+1)-th job is rejected, and the
//!    admitted N keep measured goodput within 15% of the floor the
//!    model promised;
//! 3. **failure isolation** — a job exhausting its per-epoch skip
//!    budget under seeded faults fails alone; its co-tenants complete
//!    every epoch, with their retries/hedges in their own sections.

use dpp::pipeline::prep_cache::PrepCachePolicy;
use dpp::service::engine::{run, JobSpec, ServeScenario};
use dpp::sim::serve::{max_admissible_jobs, standalone_goodput, SharedTier, TenantJob};

fn job(name: &str) -> JobSpec {
    JobSpec { name: name.into(), ..JobSpec::default() }
}

/// Gate 1 geometry: a 384 KiB victim that fits any quota slice this
/// scenario produces, plus a 16 MiB aggressor that floods the shared
/// 2 MiB LRU cache when nothing fences it.
fn isolation_scenario(quotas: bool) -> ServeScenario {
    ServeScenario {
        jobs: vec![
            JobSpec { dataset_items: 48, demand: 16, epochs: 8, ..job("victim") },
            JobSpec {
                dataset_items: 2048,
                demand: 128,
                epochs: 2,
                join_round: 4,
                ..job("aggressor")
            },
        ],
        seed: 42,
        cache_bytes: 2 << 20,
        quotas,
        goodput_floor: 0.5,
        workers_min: 1,
        workers_max: 32,
        policy: PrepCachePolicy::Lru,
    }
}

#[test]
fn quotas_isolate_a_victims_hit_rate_from_an_aggressor_joining_mid_epoch() {
    // Baseline: the victim alone holds a perfect steady-state hit rate.
    let mut solo = isolation_scenario(true);
    solo.jobs.truncate(1);
    let h0 = run(&solo).unwrap().section("victim").unwrap().hit_rate;
    assert!(h0 > 0.99, "solo victim should hit everything, got {h0}");

    // Quotas on: the aggressor joins mid-run, the registry rebalances,
    // and the victim's slice still covers its working set — at most a
    // 10% relative dent in its final-epoch hit rate.
    let on = run(&isolation_scenario(true)).unwrap();
    let v_on = on.section("victim").unwrap();
    assert_eq!(v_on.status, "done");
    assert_eq!(v_on.epochs_done, 8);
    assert!(
        v_on.hit_rate >= 0.9 * h0,
        "quotas on: victim hit rate {} fell more than 10% below solo {h0}",
        v_on.hit_rate
    );
    // The aggressor was admitted, not silently throttled out.
    assert_eq!(on.section("aggressor").unwrap().status, "done");
    assert!(on.rejected.is_empty());

    // Quotas off: one shared pool, and the aggressor's flood evicts the
    // victim's working set between revisits — the collapse the quota
    // layer exists to prevent.
    let off = run(&isolation_scenario(false)).unwrap();
    let v_off = off.section("victim").unwrap();
    assert_eq!(v_off.status, "done");
    assert!(
        v_off.hit_rate < 0.5 * h0,
        "quotas off should collapse the victim's hit rate, got {} vs solo {h0}",
        v_off.hit_rate
    );
}

/// Gate 2 geometry: six identical jobs against a pool of 128 units and
/// a 4 MiB MinIO cache.  Standalone each job is demand-bound at 48
/// items/round; the floor of 0.5 admits exactly five.
fn admission_scenario(n_jobs: usize) -> ServeScenario {
    let jobs = (0..n_jobs)
        .map(|i| JobSpec {
            dataset_items: 256,
            bytes_per_item: 2 << 10,
            demand: 48,
            epochs: 3,
            ..job(&format!("tenant_{i}"))
        })
        .collect();
    ServeScenario {
        jobs,
        seed: 42,
        cache_bytes: 4 << 20,
        quotas: true,
        goodput_floor: 0.5,
        workers_min: 1,
        workers_max: 4,
        policy: PrepCachePolicy::Minio,
    }
}

#[test]
fn admission_rejects_the_job_the_model_predicts_and_the_floor_holds() {
    let sc = admission_scenario(6);
    let tier = SharedTier {
        cache_bytes: sc.cache_bytes as f64,
        capacity_units: (sc.workers_max as u64 * dpp::service::engine::WORKER_UNITS) as f64,
        hit_cost: dpp::service::engine::HIT_COST as f64,
        miss_cost: dpp::service::engine::MISS_COST as f64,
        policy: sc.policy,
    };
    let tenant = TenantJob {
        dataset_bytes: (256 * (2 << 10)) as f64,
        demand_items: 48.0,
    };
    // The closed form says five identical tenants fit above the floor
    // and a sixth does not.
    let n_star = max_admissible_jobs(&tier, &tenant, sc.goodput_floor, 8);
    assert_eq!(n_star, 5, "model ceiling moved — retune the gate geometry");
    let alone = standalone_goodput(&tier, &tenant);
    assert!((alone - 48.0).abs() < 1e-9, "standalone should be demand-bound at 48");

    // The engine agrees: jobs 0..5 are admitted, the sixth is rejected
    // by name, loudly.
    let r = run(&sc).unwrap();
    assert_eq!(r.rejected, vec!["tenant_5".to_string()]);
    assert!(r.section("tenant_5").unwrap().status.starts_with("rejected"));

    // And the promise admission made holds in the discrete execution:
    // every admitted job finishes and its measured steady-state goodput
    // stays within 15% of the floor the model guaranteed.
    let floor_ips = sc.goodput_floor * alone;
    for i in 0..5 {
        let s = r.section(&format!("tenant_{i}")).unwrap();
        assert_eq!(s.status, "done", "tenant_{i} did not finish");
        assert_eq!(s.epochs_done, 3);
        assert!(
            s.goodput_ips >= floor_ips * 0.85,
            "tenant_{i} measured goodput {} fell >15% below the promised floor {floor_ips}",
            s.goodput_ips
        );
    }
}

#[test]
fn a_job_exhausting_its_skip_budget_fails_alone() {
    let sc = ServeScenario {
        jobs: vec![
            // Zero skip budget, no retries, 90% faults: dead on the
            // first unrecovered sample.
            JobSpec {
                dataset_items: 64,
                demand: 8,
                epochs: 4,
                fault_rate: 0.9,
                retries: 0,
                max_skip_rate: 0.0,
                ..job("doomed")
            },
            // The victims ride out a 20% fault rate with retries and a
            // 5% per-epoch skip window.
            JobSpec {
                dataset_items: 400,
                demand: 32,
                epochs: 3,
                fault_rate: 0.2,
                retries: 3,
                max_skip_rate: 0.05,
                ..job("victim_a")
            },
            JobSpec {
                dataset_items: 400,
                demand: 32,
                epochs: 3,
                fault_rate: 0.2,
                retries: 3,
                max_skip_rate: 0.05,
                ..job("victim_b")
            },
        ],
        seed: 42,
        cache_bytes: 16 << 20,
        quotas: true,
        goodput_floor: 0.5,
        workers_min: 1,
        workers_max: 32,
        policy: PrepCachePolicy::Minio,
    };
    let r = run(&sc).unwrap();

    let doomed = r.section("doomed").unwrap();
    assert!(
        doomed.status.starts_with("failed"),
        "doomed job should fail its skip budget, got {:?}",
        doomed.status
    );
    assert!(
        doomed.status.contains("skip budget exceeded"),
        "failure must name the budget: {:?}",
        doomed.status
    );
    assert!(doomed.faults_injected > 0);

    // Failure stays in its lane: both victims complete every epoch,
    // with their retry/fault accounting in their own sections.
    for name in ["victim_a", "victim_b"] {
        let s = r.section(name).unwrap();
        assert_eq!(s.status, "done", "{name} must survive the doomed tenant");
        assert_eq!(s.epochs_done, 3);
        assert!(s.retries > 0, "{name} should have retried seeded faults");
        assert!(s.faults_injected > 0);
        assert!(
            s.goodput_ips > 0.0 && s.hit_rate > 0.9,
            "{name} steady state intact: hit {} goodput {}",
            s.hit_rate,
            s.goodput_ips
        );
    }
    // Nothing was rejected — this is failure isolation, not admission.
    assert!(r.rejected.is_empty());
}
