//! ByteLru vs a naive reference model (satellite of the concurrency
//! correctness PR): seeded random workloads drive the real tick-indexed
//! implementation and a deliberately dumb `Vec`-backed model through the
//! same operation stream, comparing after *every* operation:
//!
//! * exact byte accounting (`bytes == Σ resident sizes <= budget`),
//! * the resident key set,
//! * full **eviction order** (`lru_order` vs the model's recency list) —
//!   which pins who-goes-next, not just what-is-resident, so a recency
//!   bug that happens to keep the byte totals intact still fails.
//!
//! The model is obviously-correct by inspection: a recency-ordered
//! `Vec<(key, size)>` (LRU at the front) with O(n) scans everywhere.
//! Runs under plain `cargo test` and under miri (reduced case counts —
//! the interpreter is ~2 orders of magnitude slower).

use dpp::util::bytelru::ByteLru;
use dpp::util::rng::Rng;

/// The reference: recency list, LRU first, O(n) everything.
struct NaiveLru {
    budget: usize,
    /// `(key, size)` ordered least-recently-used → most-recently-used.
    entries: Vec<(u64, usize)>,
}

impl NaiveLru {
    fn new(budget: usize) -> Self {
        NaiveLru { budget, entries: Vec::new() }
    }

    fn bytes(&self) -> usize {
        self.entries.iter().map(|&(_, s)| s).sum()
    }

    fn get(&mut self, key: u64) -> bool {
        if let Some(i) = self.entries.iter().position(|&(k, _)| k == key) {
            let e = self.entries.remove(i);
            self.entries.push(e); // most recently used: back
            true
        } else {
            false
        }
    }

    fn insert(&mut self, key: u64, size: usize) {
        if size > self.budget {
            return; // oversized values bypass, mirroring ByteLru
        }
        // Replacement credits the old entry before sizing the eviction
        // target — the exact contract the real implementation documents.
        if let Some(i) = self.entries.iter().position(|&(k, _)| k == key) {
            self.entries.remove(i);
        }
        while self.bytes() + size > self.budget {
            self.entries.remove(0); // evict the LRU head
        }
        self.entries.push((key, size));
    }

    fn order(&self) -> Vec<u64> {
        self.entries.iter().map(|&(k, _)| k).collect()
    }
}

/// Drive both implementations through one seeded workload, comparing
/// bytes, resident set, and eviction order after every operation.
fn run_case(case: u64, ops: usize, keyspace: u64, budget: usize, max_size: usize) {
    let mut rng = Rng::new(0x1b17_e1b1).fork(case);
    let mut real: ByteLru<u64, usize> = ByteLru::new(budget);
    let mut model = NaiveLru::new(budget);
    for op in 0..ops {
        let key = rng.gen_range(keyspace);
        if rng.bool() {
            let size = 1 + rng.gen_range(max_size as u64) as usize;
            real.insert(key, size, size);
            model.insert(key, size);
        } else {
            let hit_real = real.get(&key).is_some();
            let hit_model = model.get(key);
            assert_eq!(
                hit_real, hit_model,
                "case {case} op {op}: hit/miss diverged on key {key}"
            );
        }
        // Byte accounting: exact and within budget.
        assert_eq!(real.bytes(), model.bytes(), "case {case} op {op}: byte totals diverged");
        assert!(real.bytes() <= budget, "case {case} op {op}: budget exceeded");
        let recount: usize = real.iter().map(|(_, &s)| s).sum();
        assert_eq!(real.bytes(), recount, "case {case} op {op}: bytes() != Σ resident");
        // Eviction order: identical key sequence, LRU first.  This also
        // subsumes the resident-set comparison.
        assert_eq!(
            real.lru_order(),
            model.order(),
            "case {case} op {op}: eviction order diverged"
        );
        assert_eq!(real.len(), model.entries.len(), "case {case} op {op}: len diverged");
    }
}

#[test]
fn bytelru_matches_reference_model_small_keyspace() {
    // Small keyspace → heavy replacement + recency churn.
    let (cases, ops) = if cfg!(miri) { (4, 60) } else { (64, 400) };
    for case in 0..cases {
        run_case(case, ops, 8, 64 + (case as usize * 37) % 512, 96);
    }
}

#[test]
fn bytelru_matches_reference_model_wide_keyspace() {
    // Wide keyspace → eviction-dominated (most inserts are fresh keys).
    let (cases, ops) = if cfg!(miri) { (4, 60) } else { (64, 400) };
    for case in 0..cases {
        run_case(1000 + case, ops, 64, 128 + (case as usize * 53) % 1024, 160);
    }
}

#[test]
fn bytelru_matches_reference_model_tight_budget() {
    // Budget barely above max item size → near-every insert evicts, and
    // oversized-bypass triggers regularly.
    let (cases, ops) = if cfg!(miri) { (4, 60) } else { (32, 300) };
    for case in 0..cases {
        run_case(2000 + case, ops, 16, 100, 110);
    }
}
