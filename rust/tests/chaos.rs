//! Chaos acceptance tests for the fault-tolerant data plane: seeded
//! fault injection (`FaultyStore`), retry + hedging (`Resilience`), and
//! bounded graceful degradation (`Quarantine`), both at the record
//! stream layer (always run) and through the full coordinator (gated on
//! `make artifacts`, like the rest of the e2e suite).
//!
//! The acceptance gates from the issue:
//! * seeded 1% faults + retry/hedging => the epoch completes with zero
//!   trainer-visible errors and goodput within 10% of fault-free;
//! * faults on + retries off => the same seed reproduces the same
//!   failure, deterministically;
//! * skip budget exceeded => the run fails loudly, naming the
//!   quarantined samples.

use dpp::config::{Method, RunConfig};
use dpp::coordinator::{self, prepare_data};
use dpp::dataset::GenConfig;
use dpp::metrics::trace::Tracer;
use dpp::pipeline::quarantine::Quarantine;
use dpp::pipeline::source::stream_shards_resilient;
use dpp::record::{ShardWriter, REC_HEADER_LEN};
use dpp::storage::prefetch::Resilience;
use dpp::storage::{
    FaultProfile, FaultyStore, MemStore, PrefetchPlan, RetryPolicy, RetryStats, Storage,
};
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

const RECORDS: u64 = 1200;
const PART: usize = 8 << 10;
const SHARD: &str = "records/shard-00000.rec";

/// One record shard with variable-length payloads, built once.
fn shard_bytes() -> &'static Vec<u8> {
    static BYTES: OnceLock<Vec<u8>> = OnceLock::new();
    BYTES.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("dpp-chaos-it-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.rec");
        let mut w = ShardWriter::create(&path).unwrap();
        for i in 0..RECORDS {
            w.append(i, (i % 7) as u16, &vec![i as u8; 150 + (i as usize % 277)]).unwrap();
        }
        w.finish().unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_dir_all(dir).ok();
        bytes
    })
}

struct StreamOutcome {
    records: u64,
    faults: u64,
    retried: u64,
    /// Successful reads the backing store served.
    reads: u64,
    /// First error the stream surfaced (empty when it completed).
    error: String,
}

/// Stream the shard through a seeded fault layer with the given retry
/// budget; corrupt-record skips go to `quarantine`.
fn run_stream(spec: &str, retries: u32, quarantine: &Quarantine) -> StreamOutcome {
    let m = MemStore::new();
    m.write(SHARD, shard_bytes().clone());
    let profile = FaultProfile::parse(spec).unwrap().unwrap_or_default();
    let faulty = Arc::new(FaultyStore::new(m, profile));
    let store: Arc<dyn Storage> = faulty.clone();
    let policy = if retries > 0 {
        RetryPolicy::with_retries(retries, 30.0, 7)
    } else {
        RetryPolicy::none()
    };
    let stats = Arc::new(RetryStats::default());
    let res = Resilience::new(policy, true, stats.clone());
    let mut records = 0u64;
    let streamed = stream_shards_resilient(
        store.clone(),
        &[SHARD.to_string()],
        PART,
        PrefetchPlan::new(4, PART, 16 * PART),
        Tracer::off(),
        res,
        |id, e| quarantine.admit(format!("record {id}"), e),
        |_rec| {
            records += 1;
            Ok(true)
        },
    );
    StreamOutcome {
        records,
        faults: faulty.counts().total(),
        retried: stats.snapshot().0,
        reads: store.stats().1,
        error: streamed.err().map(|e| format!("{e:#}")).unwrap_or_default(),
    }
}

/// Acceptance gate 1, stream layer: 1% seeded transients under
/// retry+hedging deliver every record with zero consumer-visible errors,
/// and the goodput overhead (re-issued attempts per delivered read — the
/// service capacity faults burned) stays within 10% of fault-free.
#[test]
fn one_percent_faults_with_retries_complete_within_goodput_budget() {
    let clean = run_stream("off", 3, &Quarantine::zero());
    assert_eq!(clean.records, RECORDS);
    assert_eq!((clean.faults, clean.retried), (0, 0), "baseline must be untouched");
    assert!(clean.error.is_empty(), "{}", clean.error);

    let faulty = run_stream("transient=0.01,seed=7", 3, &Quarantine::zero());
    assert_eq!(faulty.records, RECORDS, "faulty epoch must still deliver every record");
    assert!(faulty.error.is_empty(), "trainer saw an error: {}", faulty.error);
    assert!(faulty.faults > 0, "1% profile injected nothing — seed drift?");
    let overhead = faulty.retried as f64 / faulty.reads.max(1) as f64;
    assert!(
        overhead <= 0.10,
        "goodput overhead {:.1}% exceeds the 10% budget",
        overhead * 100.0
    );
}

/// Acceptance gate 2, stream layer: with retries disabled the stream
/// fails — and the same seed replays the identical failure, fault for
/// fault, so a chaos run is a reproducible bug report.
#[test]
fn retries_off_fails_and_same_seed_replays_the_same_failure() {
    let a = run_stream("transient=0.5,seed=7", 0, &Quarantine::zero());
    assert!(!a.error.is_empty(), "50% transients with no retries must fail");
    assert!(a.records < RECORDS);
    let b = run_stream("transient=0.5,seed=7", 0, &Quarantine::zero());
    assert_eq!(a.error, b.error, "same seed must reproduce the same failure");
    assert_eq!(a.faults, b.faults);
    assert_eq!(a.records, b.records);
}

/// Payload corruption (bit flips survive retries — they are not read
/// errors) is absorbed by the skip budget up to its bound, then fails
/// loudly naming the quarantined records.
#[test]
fn skip_budget_absorbs_corrupt_records_then_fails_naming_them() {
    // Corrupt two known payload bytes: record 0 and a mid-shard record.
    // Frames are meta (18 B) + payload; record 0's payload starts at
    // header(16) + 18 = 34, so offset 60 is inside it.  bytes.len()/2
    // lands mid-payload of a middle record (payloads dwarf metas).
    let mut bytes = shard_bytes().clone();
    bytes[60] ^= 0x01;
    let n = bytes.len();
    bytes[n / 2] ^= 0x01;
    assert!(60 > REC_HEADER_LEN as usize);

    let stream = |budget: &Quarantine| {
        let m = MemStore::new();
        m.write(SHARD, bytes.clone());
        let store: Arc<dyn Storage> = Arc::new(m);
        let mut records = 0u64;
        let r = stream_shards_resilient(
            store,
            &[SHARD.to_string()],
            PART,
            PrefetchPlan::new(4, PART, 16 * PART),
            Tracer::off(),
            Resilience::none(),
            |id, e| budget.admit(format!("record {id}"), e),
            |_rec| {
                records += 1;
                Ok(true)
            },
        );
        (records, r)
    };

    // Budget of 2 (0.2% of 1200 -> floor 2): both skips absorbed.
    let q = Quarantine::new(2.0 / RECORDS as f64, RECORDS);
    let (records, r) = stream(&q);
    r.unwrap();
    assert_eq!(records, RECORDS - 2, "exactly the two corrupt records are skipped");
    assert_eq!(q.count(), 2);
    assert!(q.names().iter().any(|n| n == "record 0"), "{:?}", q.names());

    // Zero tolerance: the first corrupt record fails the stream, and the
    // error names it with its checksum cause intact.
    let q0 = Quarantine::zero();
    let (_, r0) = stream(&q0);
    let msg = format!("{:#}", r0.unwrap_err());
    assert!(msg.contains("skip budget exceeded"), "{msg}");
    assert!(msg.contains("record 0"), "{msg}");
    assert!(msg.contains("checksum mismatch"), "{msg}");
}

/// Serve-mode churn under seeded faults: while one tenant leaves
/// mid-run and another joins late, the job that exhausts its per-epoch
/// skip budget fails alone — the survivor completes every epoch with
/// the retries/faults counted in its own report section, and the whole
/// episode replays identically per seed.
#[test]
fn serve_churn_with_seeded_faults_isolates_the_failing_tenant() {
    use dpp::pipeline::prep_cache::PrepCachePolicy;
    use dpp::service::engine::{run, JobSpec, ServeScenario};
    let job = |name: &str| JobSpec { name: name.into(), ..JobSpec::default() };
    let sc = ServeScenario {
        jobs: vec![
            JobSpec {
                dataset_items: 200,
                demand: 16,
                epochs: 4,
                fault_rate: 0.15,
                retries: 3,
                max_skip_rate: 0.05,
                ..job("survivor")
            },
            JobSpec {
                dataset_items: 200,
                demand: 16,
                epochs: 8,
                leave_round: Some(30),
                ..job("churner")
            },
            // Joins mid-run, faults at 90% with one retry and a zero
            // skip budget: the first unrecovered sample kills it.
            JobSpec {
                dataset_items: 64,
                demand: 8,
                epochs: 4,
                join_round: 10,
                fault_rate: 0.9,
                retries: 1,
                ..job("doomed")
            },
        ],
        seed: 7,
        cache_bytes: 8 << 20,
        quotas: true,
        goodput_floor: 0.5,
        workers_min: 1,
        workers_max: 16,
        policy: PrepCachePolicy::Minio,
    };
    let r = run(&sc).unwrap();

    let doomed = r.section("doomed").unwrap();
    assert!(doomed.status.starts_with("failed"), "{:?}", doomed.status);
    assert!(doomed.status.contains("skip budget exceeded"), "{:?}", doomed.status);
    assert!(doomed.faults_injected > 0);

    let s = r.section("survivor").unwrap();
    assert_eq!(s.status, "done", "survivor must outlive the doomed tenant");
    assert_eq!(s.epochs_done, 4);
    assert!(s.retries > 0, "15% faults with retries must retry something");
    assert!(s.faults_injected > 0);

    let c = r.section("churner").unwrap();
    assert_eq!(c.status, "left");
    assert!(c.epochs_done < 8, "the churner left before finishing");
    assert!(r.rejected.is_empty());

    // Churn + faults replay identically per seed.
    let r2 = run(&sc).unwrap();
    assert_eq!(r2.rounds, r.rounds);
    assert_eq!(r2.section("survivor").unwrap().retries, s.retries);
    assert_eq!(r2.section("doomed").unwrap().status, doomed.status);
}

// ---------------------------------------------------------------------------
// Full-coordinator chaos runs (gated on `make artifacts`, like the e2e
// suite: the device loop needs compiled model artifacts).
// ---------------------------------------------------------------------------

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.json").exists()
}

fn corpus() -> &'static PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("dpp-chaos-e2e-{}", std::process::id()));
        prepare_data(&dir, &GenConfig { n_images: 80, ..Default::default() }, 3).unwrap();
        dir
    })
}

fn base_cfg() -> RunConfig {
    RunConfig {
        data_dir: corpus().clone(),
        artifact_dir: artifact_dir(),
        model: "resnet_t".into(),
        batch_size: 8,
        cpu_workers: 2,
        steps: 0,
        lr: 0.2,
        ..Default::default()
    }
}

/// Seeded transients through the whole pipeline: the run completes the
/// epoch, the trainer sees every image, nothing is quarantined, and the
/// report carries the fault-plane telemetry.
#[test]
fn full_run_completes_under_seeded_transient_faults() {
    if !have_artifacts() {
        return;
    }
    let cfg = RunConfig {
        method: Method::Record,
        faults: "transient=0.05,seed=11".into(),
        ..base_cfg()
    };
    let r = coordinator::run(&cfg).unwrap();
    assert_eq!(r.images, 80, "faulty epoch must still train on every image");
    assert_eq!(r.samples_skipped, 0);
    assert!(r.faults_injected > 0, "5% profile injected nothing — seed drift?");
    assert!(r.retries > 0, "retries absorbed nothing at a 5% fault rate?");
    assert!(r.losses.iter().all(|(_, l)| l.is_finite()));
}

/// Give-ups (retries off) with a zero skip budget fail the run loudly,
/// naming the quarantined sample — and the same seed reproduces the
/// same failure.
#[test]
fn full_run_skip_budget_failure_is_loud_and_deterministic() {
    if !have_artifacts() {
        return;
    }
    let cfg = RunConfig {
        method: Method::Raw,
        faults: "transient=0.9,seed=3".into(),
        retries: 0,
        cpu_workers: 1,
        ..base_cfg()
    };
    let msg = format!("{:#}", coordinator::run(&cfg).unwrap_err());
    assert!(msg.contains("skip budget exceeded"), "{msg}");
    assert!(msg.contains("raw "), "failure must name the quarantined sample: {msg}");
    let again = format!("{:#}", coordinator::run(&cfg).unwrap_err());
    assert_eq!(msg, again, "same seed must reproduce the same failure");
}

/// A nonzero `--max-skip-rate` absorbs give-ups: the epoch completes
/// short of a full corpus, and the report counts what was dropped.
#[test]
fn full_run_nonzero_skip_budget_degrades_gracefully() {
    if !have_artifacts() {
        return;
    }
    let cfg = RunConfig {
        method: Method::Raw,
        faults: "transient=0.3,seed=5".into(),
        retries: 0,
        max_skip_rate: 1.0,
        ..base_cfg()
    };
    let r = coordinator::run(&cfg).unwrap();
    assert!(r.samples_skipped > 0, "30% give-ups must quarantine something");
    assert_eq!(
        r.images + r.samples_skipped,
        80,
        "every sample is either trained on or quarantined"
    );
}
