//! Model-checked concurrency invariants (tentpole of the correctness
//! PR).  Compiled only under `RUSTFLAGS="--cfg loom"`, where the
//! `util::sync` facade swaps every `Mutex`/`Condvar`/atomic/`thread`
//! in the crate for the in-tree bounded model checker (`util::loom`):
//! each test body is re-run once per explored thread schedule, with a
//! preemption bound (`LOOM_MAX_PREEMPTIONS`, default 2) keeping the
//! state space tractable.
//!
//! What the checker covers — and what it does not: it explores
//! *interleavings* of sequentially-consistent executions (every atomic
//! is modeled as SeqCst, every lock/condvar op is a scheduling point),
//! so lost-wakeup, deadlock, double-handout, and torn-read-under-
//! interleaving bugs are in scope; *weak-memory* reorderings are not.
//! The `// ordering:` audit rule (`dpp audit`) and the ThreadSanitizer
//! CI job carry the weak-memory half of the argument.
//!
//! Run with:
//! ```sh
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=2 \
//!     cargo test --release --test loom_models
//! ```
#![cfg(loom)]

use dpp::metrics::trace::{Stage, Tracer};
use dpp::pipeline::channel::bounded;
use dpp::pipeline::exec::Gate;
use dpp::service::registry::JobRegistry;
use dpp::util::bytelru::ByteLru;
use dpp::util::loom::model;
use dpp::util::slab::{seal, SlabPool};
use dpp::util::sync::thread;
use dpp::util::sync::{Arc, Mutex};

// ---------------------------------------------------------------------------
// Slab pool: slots are handed out exactly once, and seal happens-after
// every slot write.
// ---------------------------------------------------------------------------

#[test]
fn slab_slots_never_handed_out_twice_and_seal_sees_all_writes() {
    model(|| {
        // batch = 2: the two workers' checkouts must land on distinct
        // slots of one slab, whatever the interleaving.
        let pool = SlabPool::new(4, 2, 2);
        let mut handles = Vec::new();
        for w in 0..2u32 {
            let pool = pool.clone();
            handles.push(thread::spawn(move || {
                let mut s = pool.slice();
                let fill = (w + 1) as f32;
                for x in s.as_mut_slice() {
                    *x = fill;
                }
                (s.slab_seq(), s.slot(), fill, s)
            }));
        }
        let outs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Handout uniqueness: no (slab, slot) pair appears twice.
        assert_ne!(
            (outs[0].0, outs[0].1),
            (outs[1].0, outs[1].1),
            "one slot handed out to two workers"
        );
        // Seal happens-after the writes: the sealed read-only view shows
        // each worker's fill in its own slot, never zeros or a mix.
        let mut expect = [0f32; 2];
        let mut slices = Vec::new();
        for (_seq, slot, fill, s) in outs {
            expect[slot] = fill;
            slices.push(s);
        }
        let t = seal(slices).expect("both slots of one slab");
        for slot in 0..2 {
            assert_eq!(&t[slot * 4..(slot + 1) * 4], &[expect[slot]; 4], "slot {slot} torn");
        }
    });
}

// ---------------------------------------------------------------------------
// Trace ring: a drain racing the writer never observes a torn span, and
// the dropped counter is exact after the writer joins.
// ---------------------------------------------------------------------------

#[test]
fn trace_ring_drain_never_tears_a_span() {
    model(|| {
        // sample_rate 1.0 → every record lands; cap 4 → no wrap, so the
        // Release-cursor contract alone must order the slot words.
        let tracer = Tracer::with_capacity(1.0, 4);
        let t2 = tracer.clone();
        let writer = thread::spawn(move || {
            for v in 1..=2u64 {
                // Pin all controllable words of span v to v: epoch rides
                // in the meta word, v in the sample word.  A torn read
                // (words from two different pushes) breaks the equality.
                t2.set_epoch(v);
                let started = t2.start();
                t2.record(Stage::Decode, v, started);
            }
        });
        // Race a drain against the two pushes: whatever prefix the
        // Acquire-load of the cursor admits must be fully written.
        let mid = tracer.drain();
        assert!(mid.span_count() <= 2);
        assert_eq!(mid.dropped, 0);
        for track in &mid.tracks {
            for s in &track.spans {
                assert_eq!(s.sample, s.epoch, "torn span: sample/meta words from different pushes");
            }
        }
        writer.join().unwrap();
        // Post-join the dump is total and ordered.
        let fin = tracer.drain();
        assert_eq!(fin.span_count(), 2);
        assert_eq!(fin.dropped, 0);
        let samples: Vec<u64> =
            fin.tracks.iter().flat_map(|t| t.spans.iter().map(|s| s.sample)).collect();
        assert_eq!(samples, vec![1, 2]);
    });
}

#[test]
fn trace_ring_dropped_counter_is_exact_after_wrap() {
    model(|| {
        // cap 2, 5 spans: exactly the 2 newest survive, exactly 3 drop.
        let tracer = Tracer::with_capacity(1.0, 2);
        let t2 = tracer.clone();
        let writer = thread::spawn(move || {
            for v in 1..=5u64 {
                t2.set_epoch(v);
                let started = t2.start();
                t2.record(Stage::Decode, v, started);
            }
        });
        writer.join().unwrap();
        let dump = tracer.drain();
        assert_eq!(dump.span_count(), 2);
        assert_eq!(dump.dropped, 3);
        let samples: Vec<u64> =
            dump.tracks.iter().flat_map(|t| t.spans.iter().map(|s| s.sample)).collect();
        assert_eq!(samples, vec![4, 5], "wrap must keep the newest spans in order");
    });
}

// ---------------------------------------------------------------------------
// Channel: items are delivered exactly once, and the blocked-time
// accounting leaves no phantom waiter behind (the double-charge bug
// shape: a waiter that is counted in the in-flight term after it
// already added its completed wait to the clock).
// ---------------------------------------------------------------------------

#[test]
fn channel_delivers_exactly_once_and_waiter_accounting_drains_to_zero() {
    model(|| {
        // cap 1 with two senders: at least one schedule blocks a sender;
        // a consumer thread drains both items.
        let (tx, rx) = bounded::<u32>(1);
        let probe = tx.probe();
        let mut senders = Vec::new();
        for v in 1..=2u32 {
            let tx = tx.clone();
            senders.push(thread::spawn(move || {
                tx.send(v).unwrap();
            }));
        }
        drop(tx);
        let consumer = thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = rx.recv() {
                got.push(v);
            }
            got
        });
        for s in senders {
            s.join().unwrap();
        }
        let mut got = consumer.join().unwrap();
        got.sort();
        assert_eq!(got, vec![1, 2], "channel lost or duplicated an item");
        // Every waiter has returned: the in-flight term must be exactly
        // zero, so the stats clock is frozen.  A leaked waiter count
        // keeps charging wall time, so two reads straddling a real
        // ~300µs spin would diverge by ≥ 3e-4 s.
        let s1 = probe.stats();
        assert_eq!(s1.len, 0);
        let t0 = std::time::Instant::now();
        while t0.elapsed() < std::time::Duration::from_micros(300) {
            std::hint::spin_loop();
        }
        let s2 = probe.stats();
        assert!(
            (s2.send_wait_secs - s1.send_wait_secs).abs() < 1e-4
                && (s2.recv_wait_secs - s1.recv_wait_secs).abs() < 1e-4,
            "blocked-time clock still running: phantom waiter ({s1:?} -> {s2:?})"
        );
    });
}

// ---------------------------------------------------------------------------
// ByteLru under the callers' Mutex: byte accounting stays exact under
// concurrent replacement of the same key.
// ---------------------------------------------------------------------------

#[test]
fn bytelru_accounting_exact_under_concurrent_replacement() {
    model(|| {
        let lru: Arc<Mutex<ByteLru<u32, u32>>> = Arc::new(Mutex::new(ByteLru::new(100)));
        let mut handles = Vec::new();
        for w in 0..2u32 {
            let lru = Arc::clone(&lru);
            handles.push(thread::spawn(move || {
                // Both threads fight over key 0 (replacement path) and
                // add a private key (eviction path).
                let size = 50 + w as usize * 10;
                lru.lock().unwrap().insert(0, w, size);
                lru.lock().unwrap().insert(10 + w, w, 40);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let l = lru.lock().unwrap();
        // Sizes aren't stored in the value, but each value determines
        // the size its insert charged (key 0 ↔ 50 + 10·w, others ↔ 40),
        // so an exact recount is possible: bytes() must equal the sum of
        // the resident entries' charges on EVERY interleaving.
        let recount: usize =
            l.iter().map(|(k, &v)| if *k == 0 { 50 + v as usize * 10 } else { 40 }).sum();
        assert_eq!(l.bytes(), recount, "byte accounting diverged from resident charges");
        assert!(l.bytes() <= 100, "budget exceeded");
        let order = l.lru_order();
        assert_eq!(order.len(), l.len(), "tick index and map diverged");
        for k in &order {
            assert!(l.peek(k).is_some(), "index names a non-resident key");
        }
    });
}

// ---------------------------------------------------------------------------
// Executor gate: a resize (set_target) or shutdown notification is never
// lost — a parked worker and a sleeping controller always wake.  A lost
// wakeup shows up as a deadlock, which the model checker reports.
// ---------------------------------------------------------------------------

#[test]
fn gate_never_loses_a_resize_notification() {
    model(|| {
        let gate = Gate::new(1);
        let g = Arc::clone(&gate);
        // Worker 1 starts outside the target (1 < 1 is false), so on
        // most schedules it parks on the condvar.  The resize to 2 must
        // wake it — a lost set_target notification is a deadlock, which
        // the model checker reports as such.
        let worker = thread::spawn(move || {
            assert!(g.wait_active(1), "resize to 2 must activate worker 1");
        });
        let g2 = Arc::clone(&gate);
        let controller = thread::spawn(move || {
            g2.set_target(2);
        });
        controller.join().unwrap();
        worker.join().unwrap();
        assert_eq!(gate.target(), 2);
        assert!(gate.is_active(1));
    });
}

#[test]
fn gate_never_loses_a_shutdown_notification() {
    model(|| {
        let gate = Gate::new(1);
        let g = Arc::clone(&gate);
        // Worker 1 parks (never inside the target); shutdown must wake
        // it with `false`.  Lost shutdown = deadlock = model failure.
        let worker = thread::spawn(move || {
            assert!(!g.wait_active(1), "shutdown must release the parked worker");
        });
        gate.shutdown();
        worker.join().unwrap();
        assert!(!gate.is_active(0), "no worker is active after shutdown");
        assert!(!gate.wait_active(1), "post-shutdown wait must return immediately");
    });
}

#[test]
fn gate_sleep_always_wakes_for_shutdown() {
    model(|| {
        let gate = Gate::new(1);
        let g = Arc::clone(&gate);
        // The controller loop shape from exec.rs: sleep until shutdown.
        let ctl = thread::spawn(move || {
            let mut ticks = 0u32;
            while !g.sleep(0.25) {
                ticks += 1;
                assert!(ticks < 100, "sleep never observed shutdown");
            }
        });
        gate.shutdown();
        ctl.join().unwrap();
    });
}

// ---------------------------------------------------------------------------
// Serve-mode job registry: join/leave churn never loses a quota
// rebalance or double-counts the budget, and the admission gauge
// (in-flight joins) always drains to zero.
// ---------------------------------------------------------------------------

#[test]
fn registry_concurrent_joins_never_lose_a_quota_rebalance() {
    model(|| {
        // Prime total: every 2-way split has a remainder byte, so a lost
        // or half-applied rebalance shows up as a wrong sum.
        let r = Arc::new(JobRegistry::new(101));
        let mut handles = Vec::new();
        for id in 1..=2u64 {
            let r = Arc::clone(&r);
            handles.push(thread::spawn(move || r.join_with(id, |_| true)));
        }
        for h in handles {
            assert!(h.join().unwrap(), "both distinct ids must be admitted");
        }
        let q = r.quotas();
        assert_eq!(q.len(), 2);
        let sum: usize = q.iter().map(|j| j.quota).sum();
        assert_eq!(sum, 101, "quota conservation broken by racing joins");
        assert_eq!(r.in_flight(), 0, "admission gauge must drain");
    });
}

#[test]
fn registry_racing_duplicate_joins_admit_exactly_once() {
    model(|| {
        let r = Arc::new(JobRegistry::new(64));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let r = Arc::clone(&r);
            handles.push(thread::spawn(move || r.join_with(7, |_| true)));
        }
        let wins: Vec<bool> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(
            wins.iter().filter(|&&w| w).count(),
            1,
            "the same id was admitted twice (or not at all): {wins:?}"
        );
        assert_eq!(r.len(), 1);
        assert_eq!(r.quotas()[0].quota, 64);
        assert_eq!(r.in_flight(), 0);
    });
}

#[test]
fn registry_join_racing_leave_conserves_quota_and_drains_in_flight() {
    model(|| {
        let r = Arc::new(JobRegistry::new(97));
        assert!(r.join_with(1, |_| true));
        let ra = Arc::clone(&r);
        let joiner = thread::spawn(move || ra.join_with(2, |_| true));
        let rb = Arc::clone(&r);
        let leaver = thread::spawn(move || rb.leave(1));
        assert!(joiner.join().unwrap());
        assert!(leaver.join().unwrap());
        // Whatever the interleaving, exactly job 2 survives and holds
        // the whole budget — a half-rebalanced split would leave it with
        // the old 2-way share.
        let q = r.quotas();
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].id, 2);
        assert_eq!(q[0].quota, 97, "survivor must absorb the leaver's quota");
        assert_eq!(r.in_flight(), 0);
    });
}
