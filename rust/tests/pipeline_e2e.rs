//! Integration tests over the full coordinator pipeline: every
//! method × placement combination on a small real corpus, loss descent,
//! epoch semantics, storage emulation, and property tests on the
//! coordinator invariants (routing, batching, shuffling).

use dpp::config::{Method, Placement, RunConfig};
use dpp::coordinator::{self, prepare_data};
use dpp::dataset::GenConfig;
use dpp::testing::{check, PropConfig};
use dpp::util::rng::Rng;
use std::path::PathBuf;
use std::sync::OnceLock;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.json").exists()
}

/// Shared corpus, generated once per test binary.
fn corpus() -> &'static PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("dpp-it-{}", std::process::id()));
        prepare_data(&dir, &GenConfig { n_images: 80, ..Default::default() }, 3).unwrap();
        dir
    })
}

fn base_cfg() -> RunConfig {
    RunConfig {
        data_dir: corpus().clone(),
        artifact_dir: artifact_dir(),
        model: "resnet_t".into(),
        batch_size: 8,
        cpu_workers: 2,
        steps: 2,
        lr: 0.2,
        ..Default::default()
    }
}

#[test]
fn every_method_placement_combination_trains() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    for method in [Method::Raw, Method::Record] {
        for placement in [Placement::Cpu, Placement::Hybrid, Placement::Hybrid0] {
            let cfg = RunConfig { method, placement, ..base_cfg() };
            let r = coordinator::run(&cfg)
                .unwrap_or_else(|e| panic!("{method:?}/{placement:?}: {e:#}"));
            assert_eq!(r.steps, 2, "{method:?}/{placement:?}");
            assert_eq!(r.losses.len(), 2);
            assert!(r.losses.iter().all(|(_, l)| l.is_finite()));
            assert!(r.images >= 16, "{method:?}/{placement:?}: {} images", r.images);
        }
    }
}

#[test]
fn full_epoch_consumes_every_image_once() {
    if !have_artifacts() {
        return;
    }
    // 80 images, batch 8, no step limit => exactly 10 steps, all decoded.
    let cfg = RunConfig { steps: 0, ..base_cfg() };
    let r = coordinator::run(&cfg).unwrap();
    assert_eq!(r.steps, 10);
    assert_eq!(r.images, 80);
}

#[test]
fn partial_trailing_batch_is_dropped() {
    if !have_artifacts() {
        return;
    }
    // Batch 32 over 80 images => 2 full batches, 16 leftover dropped.
    let cfg = RunConfig { batch_size: 32, steps: 0, ..base_cfg() };
    let r = coordinator::run(&cfg).unwrap();
    assert_eq!(r.steps, 2);
}

#[test]
fn loss_falls_within_one_epoch_of_repeats() {
    if !have_artifacts() {
        return;
    }
    // Train 8 steps on the small corpus: loss must move down on average.
    let cfg = RunConfig { steps: 8, lr: 0.25, ..base_cfg() };
    let r = coordinator::run(&cfg).unwrap();
    let first = r.losses.first().unwrap().1;
    let last2: f32 = r.losses.iter().rev().take(2).map(|(_, l)| l).sum::<f32>() / 2.0;
    assert!(last2 < first, "loss {first} -> {last2}");
}

#[test]
fn ideal_mode_trains_without_pipeline() {
    if !have_artifacts() {
        return;
    }
    let cfg = RunConfig { ideal: true, steps: 5, ..base_cfg() };
    let r = coordinator::run(&cfg).unwrap();
    assert_eq!(r.steps, 5);
    // Ideal mode decodes at most ~one queue fill, far fewer than 5 batches.
    assert!(r.images <= 80);
    assert!(r.train_ips > 0.0);
}

#[test]
fn preprocessing_only_mode_runs_without_model() {
    if !have_artifacts() {
        return;
    }
    let cfg = RunConfig { train: false, steps: 0, ..base_cfg() };
    let r = coordinator::run(&cfg).unwrap();
    assert_eq!(r.images, 80);
    assert!(r.losses.is_empty());
}

#[test]
fn emulated_storage_profiles_run_and_slow_down() {
    if !have_artifacts() {
        return;
    }
    // dram (memstore preload) must work end to end.
    let dram = coordinator::run(&RunConfig { storage: "dram".into(), ..base_cfg() }).unwrap();
    assert_eq!(dram.steps, 2);
    // Identical configs except the throttle scale.  I/O overlaps with
    // compute, so the throttle must be large enough that the serialized
    // device time (~16 reads x ~0.4 s at scale 800) strictly exceeds the
    // compute+compile time of the run even in debug builds.
    let mk = |scale: f64| RunConfig {
        storage: "ebs".into(),
        method: Method::Raw,
        time_scale: scale,
        ..base_cfg()
    };
    let fast = coordinator::run(&mk(1e-6)).unwrap();
    let slow = coordinator::run(&mk(800.0)).unwrap();
    assert!(
        slow.wall_secs > fast.wall_secs + 1.5,
        "throttle had no effect: {:.3}s vs {:.3}s",
        slow.wall_secs,
        fast.wall_secs
    );
}

#[test]
fn deterministic_loss_curve_per_seed() {
    if !have_artifacts() {
        return;
    }
    // Same seed + single worker => identical batch composition and losses.
    let cfg = RunConfig { cpu_workers: 1, steps: 3, seed: 99, ..base_cfg() };
    let a = coordinator::run(&cfg).unwrap();
    let b = coordinator::run(&cfg).unwrap();
    assert_eq!(a.losses, b.losses);
}

// ---------------------------------------------------------------------------
// Property tests on coordinator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_epoch_order_is_always_a_permutation() {
    check(
        "epoch-order-permutation",
        PropConfig { cases: 40, ..Default::default() },
        |rng, size| {
            let n = 1 + rng.gen_range(20 * size as u64 + 1) as usize;
            let seq = 1 + rng.gen_range(16) as usize;
            let seed = rng.next_u64();
            let epoch = rng.gen_range(4);
            (n, seq, seed, epoch)
        },
        |&(n, seq, seed, epoch)| {
            let s = dpp::dataset::EpochSampler::new((0..n as u64).collect(), seq, seed);
            let mut order = s.epoch_order(epoch);
            order.sort();
            order == (0..n as u64).collect::<Vec<_>>()
        },
    );
}

#[test]
fn prop_collate_preserves_labels_and_sizes() {
    use dpp::pipeline::{collate, Batch, Payload, Sample};
    check(
        "collate-preserves",
        PropConfig { cases: 40, ..Default::default() },
        |rng, size| {
            let b = 1 + rng.gen_range(size as u64 + 1) as usize;
            let elems = 1 + rng.gen_range(64) as usize;
            let labels: Vec<u16> = (0..b).map(|_| rng.gen_range(16) as u16).collect();
            (elems, labels)
        },
        |(elems, labels)| {
            let samples: Vec<Sample> = labels
                .iter()
                .enumerate()
                .map(|(i, &l)| Sample {
                    id: i as u64,
                    label: l,
                    payload: Payload::Ready(vec![i as f32; *elems]),
                })
                .collect();
            match collate(samples) {
                Ok(Batch::Ready { data, labels: got }) => {
                    data.len() == elems * labels.len()
                        && got == labels.iter().map(|&l| l as i32).collect::<Vec<_>>()
                }
                _ => false,
            }
        },
    );
}

#[test]
fn prop_record_roundtrip_any_payload() {
    check(
        "record-roundtrip",
        PropConfig { cases: 30, ..Default::default() },
        |rng, size| {
            let n = 1 + rng.gen_range(size as u64 + 1) as usize;
            let recs: Vec<(u64, u16, Vec<u8>)> = (0..n)
                .map(|i| {
                    let len = rng.gen_range(2048) as usize;
                    let payload = (0..len).map(|_| rng.next_u32() as u8).collect();
                    (i as u64 * 3, rng.gen_range(1 << 16) as u16, payload)
                })
                .collect();
            recs
        },
        |recs| {
            let dir = std::env::temp_dir()
                .join(format!("dpp-prop-{}-{}", std::process::id(), recs.len()));
            std::fs::create_dir_all(&dir).unwrap();
            let shard = dir.join("p.rec");
            let mut w = dpp::record::ShardWriter::create(&shard).unwrap();
            for (id, label, p) in recs {
                w.append(*id, *label, p).unwrap();
            }
            w.finish().unwrap();
            let parsed = dpp::record::parse_shard(&std::fs::read(&shard).unwrap()).unwrap();
            std::fs::remove_dir_all(&dir).ok();
            parsed.len() == recs.len()
                && parsed
                    .iter()
                    .zip(recs)
                    .all(|(r, (id, label, p))| r.id == *id && r.label == *label && &r.payload == p)
        },
    );
}

#[test]
fn prop_codec_roundtrip_bounded_error() {
    check(
        "codec-roundtrip-bounded",
        PropConfig { cases: 20, ..Default::default() },
        |rng, _| {
            // Smooth-ish image + random quality >= 60.
            let q = 60 + rng.gen_range(41) as u8;
            let seed = rng.next_u64();
            (seed, q)
        },
        |&(seed, q)| {
            let img = dpp::dataset::gen_image(&mut Rng::new(seed), 3, 3, 32, 32);
            let bytes = dpp::codec::encode(&img, q).unwrap();
            let dec = dpp::codec::decode_cpu(&bytes).unwrap();
            let mse = img
                .data
                .iter()
                .zip(&dec.data)
                .map(|(&a, &b)| ((a as f64) - (b as f64)).powi(2))
                .sum::<f64>()
                / img.data.len() as f64;
            mse < 120.0 // generous bound; q>=60 on smooth content is ~<40
        },
    );
}

#[test]
fn prop_shuffle_buffer_is_exactly_once_delivery() {
    use dpp::pipeline::shuffle::ShuffleBuffer;
    check(
        "shuffle-once",
        PropConfig { cases: 40, ..Default::default() },
        |rng, size| {
            let cap = 1 + rng.gen_range(32) as usize;
            let n = rng.gen_range(20 * size as u64 + 1) as usize;
            let seed = rng.next_u64();
            (cap, n, seed)
        },
        |&(cap, n, seed)| {
            let mut sb = ShuffleBuffer::new(cap, Rng::new(seed));
            let mut out = Vec::new();
            for i in 0..n as u32 {
                if let Some(v) = sb.push(i) {
                    out.push(v);
                }
            }
            out.extend(sb.drain());
            out.sort();
            out == (0..n as u32).collect::<Vec<_>>()
        },
    );
}

/// Tentpole property harness: slab-path batches are bit-identical to
/// Vec-path batches across placement × fused × prep-cache combinations.
/// The slab hand-off exists only where the CPU hand-off is the final
/// tensor (cpu placement); for the device placements the property is
/// that the chain is untouched — same payloads either way — so the
/// whole placement axis is exercised, not just the slab arm.
#[test]
fn prop_slab_batches_bit_identical_to_vec_batches() {
    use dpp::config::Placement;
    use dpp::ops;
    use dpp::pipeline::{collate, Batch, DecodeOpts, Payload, Sample, StageCtx, StageScratch};
    use dpp::pipeline::prep_cache::{PrepCache, PrepCachePolicy};
    use dpp::util::slab::SlabPool;
    use std::sync::Arc;

    check(
        "slab-vec-batch-identity",
        PropConfig { cases: 10, ..Default::default() },
        |rng, _| {
            let seed = rng.next_u32() as u64;
            let placement = match rng.gen_range(3) {
                0 => Placement::Cpu,
                1 => Placement::Hybrid,
                _ => Placement::Hybrid0,
            };
            let fused = rng.bool();
            let cache = rng.bool();
            let b = 2 + rng.gen_range(4) as usize;
            (seed, placement, fused, cache, b)
        },
        |&(seed, placement, fused, cache, b)| {
            let mk_cache = || {
                cache.then(|| Arc::new(PrepCache::new(1 << 22, PrepCachePolicy::Minio)))
            };
            let opts = DecodeOpts { fused, max_scale_log2: 0 };
            let mk_ctx = |c: Option<Arc<PrepCache>>| {
                let ctx = StageCtx::new(placement, 56).with_opts(opts);
                match c {
                    Some(c) => ctx.with_cache(c),
                    None => ctx,
                }
            };
            let vec_ctx = mk_ctx(mk_cache());
            let slab_ctx = mk_ctx(mk_cache());
            let pool = SlabPool::new(3 * 56 * 56, b, 2);
            let mut scratch = StageScratch::new();
            let enc: Vec<Vec<u8>> = (0..b as u64)
                .map(|i| {
                    let img = dpp::dataset::gen_image(&mut Rng::new(seed ^ i), 1, 3, 64, 64);
                    dpp::codec::encode(&img, 85).unwrap()
                })
                .collect();
            // Two epochs so a cache run exercises admission AND hits.
            for epoch in 0..2u64 {
                let mut vec_samples = Vec::new();
                let mut slab_samples = Vec::new();
                for (i, bytes) in enc.iter().enumerate() {
                    let id = i as u64;
                    let aug = {
                        let mut rng = Rng::new(seed ^ 0x5EED).fork(id).fork(epoch);
                        ops::sample_aug_params(&mut rng, 64, 64)
                    };
                    let vp = match vec_ctx.prep_cache.as_ref().and_then(|c| c.get(id)) {
                        Some(s) => vec_ctx.run_stage_cached(&s, aug),
                        None => vec_ctx.run_stage(bytes, id, aug).unwrap().0,
                    };
                    // The slab hand-off is cpu-placement only; for the
                    // device placements both sides run the same chain.
                    let sp = if placement == Placement::Cpu {
                        let mut slice = pool.slice();
                        match slab_ctx.prep_cache.as_ref().and_then(|c| c.get(id)) {
                            Some(s) => slab_ctx.run_stage_cached_into(
                                &s,
                                aug,
                                &mut scratch,
                                slice.as_mut_slice(),
                            ),
                            None => {
                                slab_ctx
                                    .run_stage_into(
                                        bytes,
                                        id,
                                        aug,
                                        &mut scratch,
                                        slice.as_mut_slice(),
                                    )
                                    .unwrap();
                            }
                        }
                        Payload::Slot(slice)
                    } else {
                        match slab_ctx.prep_cache.as_ref().and_then(|c| c.get(id)) {
                            Some(s) => slab_ctx.run_stage_cached(&s, aug),
                            None => slab_ctx.run_stage(bytes, id, aug).unwrap().0,
                        }
                    };
                    vec_samples.push(Sample { id, label: i as u16, payload: vp });
                    slab_samples.push(Sample { id, label: i as u16, payload: sp });
                }
                let bv = collate(vec_samples).unwrap();
                let bs = collate(slab_samples).unwrap();
                let same = match (&bv, &bs) {
                    (
                        Batch::Ready { data: dv, labels: lv },
                        Batch::Ready { data: ds, labels: ls },
                    ) => dv[..] == ds[..] && lv == ls,
                    (
                        Batch::Coefs { data: dv, labels: lv, aug: av, .. },
                        Batch::Coefs { data: ds, labels: ls, aug: aa, .. },
                    ) => dv == ds && lv == ls && av == aa,
                    (
                        Batch::Pixels { data: dv, labels: lv, aug: av },
                        Batch::Pixels { data: ds, labels: ls, aug: aa },
                    ) => dv == ds && lv == ls && av == aa,
                    _ => false,
                };
                if !same {
                    return false;
                }
            }
            true
        },
    );
}

/// Satellite: `images_read` counts at the *actual storage read* on both
/// paths — the record stream callback and the raw worker read — so a
/// full epoch over the same corpus must report identical counts.
#[test]
fn images_read_parity_between_raw_and_record() {
    if !have_artifacts() {
        return;
    }
    let run = |method: Method| {
        coordinator::run(&RunConfig { method, steps: 0, ..base_cfg() }).unwrap()
    };
    let raw = run(Method::Raw);
    let record = run(Method::Record);
    assert_eq!(raw.images_read, 80, "raw must read each image exactly once");
    assert_eq!(
        raw.images_read, record.images_read,
        "raw ({}) vs record ({}) read counts diverged",
        raw.images_read, record.images_read
    );
    // Both decoded the whole corpus too.
    assert_eq!(raw.images, record.images);
}

/// `--workers auto` smoke through the full coordinator: the run
/// completes, the converged count stays inside the configured bounds,
/// and the report carries the elastic telemetry.
#[test]
fn auto_workers_run_completes_and_reports_timeline() {
    if !have_artifacts() {
        return;
    }
    let cfg = RunConfig {
        workers_auto: true,
        workers_min: 1,
        workers_max: 4,
        workers_interval_secs: 0.05,
        steps: 0,
        ..base_cfg()
    };
    let r = coordinator::run(&cfg).unwrap();
    assert_eq!(r.steps, 10);
    assert_eq!(r.images, 80);
    assert!(r.workers_auto);
    assert!(
        (1..=4).contains(&r.workers_final),
        "workers_final {} outside [1, 4]",
        r.workers_final
    );
    assert!(!r.workers_timeline.is_empty());
    assert_eq!(r.workers_timeline[0].1, 1, "auto pools start at workers_min");
    assert!(r.workers_timeline.iter().all(|&(_, n)| (1..=4).contains(&n)));
    // Queue telemetry flows end to end (the batch queue must have held
    // at least one batch for the device to have trained).
    assert!(r.batch_queue_peak >= 1);
    assert!(r.work_queue_peak >= 1);
}

/// End-to-end A/B: the slab path must be invisible in the training
/// math.  Single worker + fixed seed makes batch composition
/// deterministic, so slab-on and slab-off runs must produce the exact
/// same loss curve — and the slab run's pool telemetry must show the
/// zero-copy path actually engaged.
#[test]
fn slab_pool_run_matches_vec_path_losses_exactly() {
    use dpp::config::SlabPoolCfg;
    if !have_artifacts() {
        return;
    }
    let mk = |slab: SlabPoolCfg| RunConfig {
        placement: Placement::Cpu,
        cpu_workers: 1,
        steps: 3,
        seed: 5,
        slab_pool: slab,
        ..base_cfg()
    };
    let on = coordinator::run(&mk(SlabPoolCfg::Auto)).unwrap();
    let off = coordinator::run(&mk(SlabPoolCfg::Off)).unwrap();
    assert_eq!(on.losses, off.losses, "slab path changed the training math");
    assert!(on.slab_hits + on.slab_grows > 0, "slab pool never engaged");
    assert_eq!(off.slab_hits + off.slab_grows, 0, "off must mean off");
    // bytes_alloc_hot is process-global (parallel test threads pollute
    // it), so the alloc-reduction gate lives in `dpp bench alloc`, not
    // here — this just checks the counter flows into the report.
    assert!(on.bytes_alloc_hot > 0);
}

/// End-to-end A/B for the SIMD kernel layer: `--simd on` and `--simd
/// off` must produce the exact same loss curve, because every vector
/// kernel is bit-identical to its scalar reference (same per-lane f32
/// ops in the same order — see DESIGN.md "SIMD kernels").  Crossed with
/// the fused-decode, prep-cache, and slab axes so the identity holds in
/// every kernel mix, not just the default path.  The mode is a
/// process-global dispatch switch, but a racing parallel test can only
/// change *which* bit-identical kernel runs, never the output, so the
/// assertion stays sound under the parallel test harness.
#[test]
fn simd_run_matches_scalar_losses_exactly() {
    use dpp::config::SlabPoolCfg;
    use dpp::simd::SimdMode;
    if !have_artifacts() {
        return;
    }
    for (fused, cache_mb, slab) in [
        (true, 0, SlabPoolCfg::Off),
        (false, 0, SlabPoolCfg::Auto),
        (true, 64, SlabPoolCfg::Auto),
    ] {
        let mk = |simd: SimdMode| RunConfig {
            placement: Placement::Cpu,
            cpu_workers: 1,
            steps: 3,
            seed: 11,
            fused_decode: fused,
            prep_cache_mb: cache_mb,
            slab_pool: slab,
            simd,
            ..base_cfg()
        };
        let on = coordinator::run(&mk(SimdMode::On)).unwrap();
        let off = coordinator::run(&mk(SimdMode::Off)).unwrap();
        assert_eq!(
            on.losses, off.losses,
            "simd changed the training math (fused={fused} cache={cache_mb} slab={slab:?})"
        );
        assert_eq!(on.steps, off.steps);
        assert_eq!(on.images, off.images);
    }
}

#[test]
fn multi_epoch_run_repeats_the_corpus() {
    if !have_artifacts() {
        return;
    }
    // 2 epochs x 80 images, batch 8 => 20 steps, 160 images decoded.
    let cfg = RunConfig { steps: 0, epochs: 2, ..base_cfg() };
    let r = coordinator::run(&cfg).unwrap();
    assert_eq!(r.steps, 20);
    assert_eq!(r.images, 160);
}

#[test]
fn cache_layer_serves_second_epoch_from_memory() {
    if !have_artifacts() {
        return;
    }
    // Raw method so every image is a whole-object read; cache fits all.
    let cfg = RunConfig {
        method: Method::Raw,
        steps: 0,
        epochs: 2,
        cache_mb: 64,
        ..base_cfg()
    };
    let r = coordinator::run(&cfg).unwrap();
    assert_eq!(r.steps, 20);
    // The backing store must see each file ~once (metadata + 80 images),
    // not twice: epoch 2 hits the cache.
    let no_cache = coordinator::run(&RunConfig { cache_mb: 0, ..cfg }).unwrap();
    assert!(
        r.io_bytes < no_cache.io_bytes * 6 / 10,
        "cache did not absorb epoch 2: {} vs {}",
        r.io_bytes,
        no_cache.io_bytes
    );
}
