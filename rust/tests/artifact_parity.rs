//! Cross-layer parity: the rust CPU implementations (codec + ops) and the
//! AOT-compiled Pallas/JAX artifacts must compute the SAME functions, so
//! the `cpu`, `hybrid` and `hybrid0` placements produce identical batches.
//!
//! This is the correctness keystone of the three-layer design: L1 kernels
//! were checked against the jnp oracle in pytest; here the L3 CPU path is
//! checked against the compiled L1/L2 artifacts through PJRT.

use dpp::codec;
use dpp::dataset;
use dpp::ops;
use dpp::runtime::{lit_f32, to_vec_f32, Engine};
use dpp::util::rng::Rng;
use std::path::{Path, PathBuf};

fn artifact_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine_or_skip() -> Option<Engine> {
    if artifact_dir().join("manifest.json").exists() {
        Some(Engine::new(&artifact_dir()).expect("engine"))
    } else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        None
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Batch of encoded images + their entropy-decoded coefficients.
fn test_batch(b: usize, quality: u8) -> (Vec<Vec<u8>>, Vec<codec::CoefImage>) {
    let mut enc = Vec::new();
    let mut cis = Vec::new();
    for i in 0..b {
        let img = dataset::gen_image(&mut Rng::new(100 + i as u64), (i % 16) as u16, 3, 64, 64);
        let bytes = codec::encode(&img, quality).unwrap();
        cis.push(codec::entropy_decode(&bytes).unwrap());
        enc.push(bytes);
    }
    (enc, cis)
}

#[test]
fn decode_artifact_matches_rust_cpu_decode() {
    let Some(mut eng) = engine_or_skip() else { return };
    let b = eng.manifest.batch_test;
    let (enc, cis) = test_batch(b, 85);

    // Assemble the artifact input [B, C, 8, 8, 8, 8].
    let mut coefs = Vec::with_capacity(b * 3 * 64 * 64);
    for ci in &cis {
        coefs.extend_from_slice(&ci.coefs);
    }
    let q = cis[0].qtable;
    let cl = lit_f32(&[b, 3, 8, 8, 8, 8], &coefs).unwrap();
    let ql = lit_f32(&[8, 8], &q).unwrap();
    let outs = eng.execute(&eng.manifest.decode_artifact(b).clone(), &[cl, ql]).unwrap();
    let gpu_pixels = to_vec_f32(&outs[0]).unwrap();

    // Rust CPU decode of the same bitstreams.
    for (i, bytes) in enc.iter().enumerate() {
        let cpu = codec::decode_cpu(bytes).unwrap();
        let gpu = &gpu_pixels[i * 3 * 64 * 64..(i + 1) * 3 * 64 * 64];
        // CPU path rounds to u8; artifact returns f32 — compare within 0.51.
        let max = cpu
            .data
            .iter()
            .zip(gpu)
            .map(|(&c, &g)| (c as f32 - g).abs())
            .fold(0.0f32, f32::max);
        assert!(max <= 0.51, "image {i}: max pixel diff {max}");
    }
}

#[test]
fn augment_artifact_matches_rust_ops() {
    let Some(mut eng) = engine_or_skip() else { return };
    let b = eng.manifest.batch_test;
    let hw = eng.manifest.img_hw;
    let out_hw = eng.manifest.out_hw;
    let mut rng = Rng::new(7);

    // Random pixel batch + random aug params.
    let mut imgs = vec![0f32; b * 3 * hw * hw];
    for v in imgs.iter_mut() {
        *v = (rng.f32() * 255.0).round();
    }
    let params: Vec<ops::AugParams> =
        (0..b).map(|_| ops::sample_aug_params(&mut rng, hw as u32, hw as u32)).collect();
    let aug_rows: Vec<f32> = params.iter().flat_map(|p| p.to_row()).collect();

    let il = lit_f32(&[b, 3, hw, hw], &imgs).unwrap();
    let al = lit_f32(&[b, 6], &aug_rows).unwrap();
    let outs = eng.execute(&eng.manifest.augment_artifact(b).clone(), &[il, al]).unwrap();
    let got = to_vec_f32(&outs[0]).unwrap();

    let mut want = vec![0f32; b * 3 * out_hw * out_hw];
    for (i, p) in params.iter().enumerate() {
        ops::augment_fused(
            &imgs[i * 3 * hw * hw..(i + 1) * 3 * hw * hw],
            3,
            hw,
            hw,
            p,
            out_hw,
            out_hw,
            &mut want[i * 3 * out_hw * out_hw..(i + 1) * 3 * out_hw * out_hw],
        );
    }
    let max = max_abs_diff(&got, &want);
    assert!(max < 1e-3, "augment parity: max diff {max}");
}

#[test]
fn fused_artifact_equals_decode_then_augment() {
    let Some(mut eng) = engine_or_skip() else { return };
    let b = eng.manifest.batch_test;
    let (_, cis) = test_batch(b, 70);
    let mut coefs = Vec::new();
    for ci in &cis {
        coefs.extend_from_slice(&ci.coefs);
    }
    let q = cis[0].qtable;
    let mut rng = Rng::new(9);
    let params: Vec<ops::AugParams> =
        (0..b).map(|_| ops::sample_aug_params(&mut rng, 64, 64)).collect();
    let aug_rows: Vec<f32> = params.iter().flat_map(|p| p.to_row()).collect();

    // Fused artifact.
    let outs = eng
        .execute(
            &eng.manifest.fused_artifact(b).clone(),
            &[
                lit_f32(&[b, 3, 8, 8, 8, 8], &coefs).unwrap(),
                lit_f32(&[8, 8], &q).unwrap(),
                lit_f32(&[b, 6], &aug_rows).unwrap(),
            ],
        )
        .unwrap();
    let fused = to_vec_f32(&outs[0]).unwrap();

    // Staged: decode artifact then augment artifact.
    let outs = eng
        .execute(
            &eng.manifest.decode_artifact(b).clone(),
            &[lit_f32(&[b, 3, 8, 8, 8, 8], &coefs).unwrap(), lit_f32(&[8, 8], &q).unwrap()],
        )
        .unwrap();
    let pixels = to_vec_f32(&outs[0]).unwrap();
    let outs = eng
        .execute(
            &eng.manifest.augment_artifact(b).clone(),
            &[
                lit_f32(&[b, 3, 64, 64], &pixels).unwrap(),
                lit_f32(&[b, 6], &aug_rows).unwrap(),
            ],
        )
        .unwrap();
    let staged = to_vec_f32(&outs[0]).unwrap();

    let max = max_abs_diff(&fused, &staged);
    assert!(max < 1e-4, "fusion parity: max diff {max}");
}

#[test]
fn hybrid_and_cpu_placements_produce_identical_batches() {
    // End-to-end placement parity at the pipeline layer: the exact tensors
    // the trainer would see, via the unified StageCtx chain + artifacts.
    use dpp::config::Placement;
    use dpp::pipeline::{collate, Batch, Sample, StageCtx};

    let Some(mut eng) = engine_or_skip() else { return };
    let b = eng.manifest.batch_test;
    let (enc, _) = test_batch(b, 85);
    let mut rng = Rng::new(11);
    let params: Vec<ops::AugParams> =
        (0..b).map(|_| ops::sample_aug_params(&mut rng, 64, 64)).collect();

    let make = |pl: Placement| -> Vec<Sample> {
        let ctx = StageCtx::new(pl, 56);
        enc.iter()
            .enumerate()
            .map(|(i, bytes)| Sample {
                id: i as u64,
                label: 0,
                payload: ctx.run_stage(bytes, i as u64, params[i]).unwrap().0,
            })
            .collect()
    };

    // cpu placement: batch is final.
    let Batch::Ready { data: cpu_data, .. } = collate(make(Placement::Cpu)).unwrap() else {
        panic!()
    };
    // hybrid placement: run fused artifact.
    let Batch::Coefs { data, qtable, aug, .. } = collate(make(Placement::Hybrid)).unwrap() else {
        panic!()
    };
    let outs = eng
        .execute(
            &eng.manifest.fused_artifact(b).clone(),
            &[
                lit_f32(&[b, 3, 8, 8, 8, 8], &data).unwrap(),
                lit_f32(&[8, 8], &qtable).unwrap(),
                lit_f32(&[b, 6], &aug).unwrap(),
            ],
        )
        .unwrap();
    let hybrid_data = to_vec_f32(&outs[0]).unwrap();

    // The CPU path rounds decoded pixels to u8 before augmenting, the
    // artifact path keeps f32 — bounded by 0.5 pixel / NORM_STD ≈ 0.01.
    let max = max_abs_diff(&cpu_data, &hybrid_data);
    assert!(max < 0.02, "placement parity: max diff {max}");
}
