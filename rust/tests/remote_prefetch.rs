//! Integration tests for the remote object-store subsystem: the emulated
//! S3 tier (`storage/remote.rs`) + the parallel range-GET prefetcher
//! (`storage/prefetch.rs`) streaming a multi-shard corpus through the
//! pipeline source, and the agreement between the real engine's measured
//! remote-tier throughput and the simulator's analytic model.

use dpp::pipeline::source::{list_shards, stream_shards_prefetched};
use dpp::record::ShardWriter;
use dpp::sim::{calib, Scenario};
use dpp::storage::{
    fetch_parallel, DirStore, MemStore, NetProfile, PrefetchPlan, RemoteStore, Storage,
};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

fn corpus_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dpp-remote-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Small corpus tuned so per-request latency dominates transfer+parse
/// even in debug builds: `n_shards` shards x 32 records x 1 KiB.
fn build_shards(dir: &Path, n_shards: u64) -> usize {
    let mut total = 0;
    for s in 0..n_shards {
        let path = dir.join(format!("records/shard-{s:05}.rec"));
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        let mut w = ShardWriter::create(&path).unwrap();
        for i in 0..32u64 {
            let id = s * 32 + i;
            w.append(id, (id % 11) as u16, &vec![id as u8; 1024]).unwrap();
            total += 1;
        }
        w.finish().unwrap();
    }
    total
}

fn stream_all(
    store: Arc<dyn Storage>,
    shards: &[String],
    chunk: usize,
    plan: PrefetchPlan,
) -> (Vec<u64>, f64) {
    let mut ids = Vec::new();
    let t = Instant::now();
    stream_shards_prefetched(store, shards, chunk, plan, |r| {
        ids.push(r.id);
        Ok(true)
    })
    .unwrap();
    (ids, t.elapsed().as_secs_f64())
}

/// Acceptance check: with the `s3` profile, streaming a multi-shard
/// corpus with net_conns=8 achieves >= 3x the emulated-wall-clock
/// throughput of net_conns=1 (the prefetcher hides first-byte latency).
#[test]
fn parallel_range_gets_hide_latency_3x() {
    let dir = corpus_dir("3x");
    // 6 shards x 5 parts: the serial path pays 30 first-byte latencies,
    // the parallel path ~6 — ample margin over the 3x bar even under
    // noisy CI scheduling.
    let n_records = build_shards(&dir, 6);
    let chunk = 8 << 10; // part-sized ranged GETs: latency-dominated
    let scale = 0.3; // 30 ms emulated first byte -> 9 ms real per request

    let open = || {
        let base = DirStore::new(&dir).unwrap();
        Arc::new(RemoteStore::with_time_scale(base, NetProfile::s3(), scale))
    };

    let serial_store = open();
    let shards = list_shards(serial_store.as_ref(), "records/").unwrap();
    assert_eq!(shards.len(), 6);
    let (serial_ids, serial_secs) =
        stream_all(serial_store.clone(), &shards, chunk, PrefetchPlan::serial(chunk));
    assert_eq!(serial_ids.len(), n_records);

    let parallel_store = open();
    let plan = PrefetchPlan::new(8, chunk, 16 * chunk);
    let (parallel_ids, parallel_secs) =
        stream_all(parallel_store.clone(), &shards, chunk, plan);

    assert_eq!(serial_ids, parallel_ids, "prefetcher must preserve record order");
    assert!(
        parallel_store.in_flight.peak() >= 4,
        "prefetcher kept only {} connections in flight",
        parallel_store.in_flight.peak()
    );
    let speedup = serial_secs / parallel_secs;
    assert!(
        speedup >= 3.0,
        "net_conns=8 must be >=3x net_conns=1: {serial_secs:.3}s vs {parallel_secs:.3}s \
         ({speedup:.2}x)"
    );
    std::fs::remove_dir_all(dir).ok();
}

/// Acceptance check: the simulator's analytic remote model agrees with
/// the real engine's measured remote-tier throughput within 20% on the
/// same scenario (same NetProfile, same part size, same conns).
#[test]
fn sim_analytic_model_matches_engine_within_20pct() {
    let profile = NetProfile::s3();
    let conns = 8usize;
    let part = 1usize << 20;
    // 48 parts -> 6 waves across 8 connections: ~250 ms of emulated
    // transfer, so a few ms of real scheduling noise stays well inside
    // the 20% agreement bar.
    let len = 48usize << 20;

    let mem = MemStore::new();
    mem.write("blob", vec![7u8; len]);
    // time_scale 1.0: measured wall clock IS the emulated wall clock.
    let store: Arc<dyn Storage> = Arc::new(RemoteStore::new(mem, profile));

    let t = Instant::now();
    let bytes = fetch_parallel(store, "blob", conns, part).unwrap();
    let secs = t.elapsed().as_secs_f64();
    assert_eq!(bytes.len(), len);

    let engine_bps = len as f64 / secs;
    let model_bps = profile.throughput_bps(conns, part as u64);
    let rel = (engine_bps - model_bps).abs() / model_bps;
    assert!(
        rel < 0.20,
        "engine {:.1} MB/s vs analytic {:.1} MB/s ({:.1}% off)",
        engine_bps / 1e6,
        model_bps / 1e6,
        rel * 100.0
    );

    // And the sim Scenario uses exactly that formula for its remote
    // storage ceiling, so sim and engine stay chained together.
    let s = Scenario { storage: "s3".into(), net_conns: conns, ..Default::default() };
    let sim_bps = s.storage_cap_ips() * calib::IMG_BYTES;
    let want = profile.throughput_bps(conns, calib::REMOTE_PART_BYTES as u64);
    assert!((sim_bps - want).abs() < 1e-6, "sim {sim_bps} vs shared formula {want}");
}

/// The cold tier is strictly slower than the warm tier at equal
/// concurrency, on the real engine (not just in the model).
#[test]
fn cold_tier_is_slower_than_warm_on_the_engine() {
    let mem = || {
        let m = MemStore::new();
        m.write("blob", vec![1u8; 256 << 10]);
        m
    };
    let scale = 0.2;
    let time = |p: NetProfile| {
        let store: Arc<dyn Storage> = Arc::new(RemoteStore::with_time_scale(mem(), p, scale));
        let t = Instant::now();
        fetch_parallel(store, "blob", 4, 64 << 10).unwrap();
        t.elapsed().as_secs_f64()
    };
    let warm = time(NetProfile::s3());
    let cold = time(NetProfile::s3_cold());
    assert!(cold > warm * 2.0, "cold {cold:.4}s vs warm {warm:.4}s");
}

/// End of the pipeline wiring: a remote store behind the prefetcher
/// delivers byte-identical records to a plain local read.
#[test]
fn remote_streaming_matches_local_bytes() {
    let dir = corpus_dir("bytes");
    build_shards(&dir, 4);
    let local: Arc<dyn Storage> = Arc::new(DirStore::new(&dir).unwrap());
    let shards = list_shards(local.as_ref(), "records/").unwrap();

    let collect = |store: Arc<dyn Storage>, plan: PrefetchPlan| {
        let mut recs = Vec::new();
        stream_shards_prefetched(store, &shards, 8 << 10, plan, |r| {
            recs.push((r.id, r.label, r.payload));
            Ok(true)
        })
        .unwrap();
        recs
    };
    let want = collect(local, PrefetchPlan::serial(8 << 10));
    // Aggressive scale-down so this stays fast; fidelity is unaffected.
    let remote: Arc<dyn Storage> = Arc::new(RemoteStore::with_time_scale(
        DirStore::new(&dir).unwrap(),
        NetProfile::s3(),
        1e-4,
    ));
    let got = collect(remote, PrefetchPlan::new(8, 8 << 10, 16 * (8 << 10)));
    assert_eq!(want.len(), 128);
    assert_eq!(want, got);
    std::fs::remove_dir_all(dir).ok();
}
