//! Acceptance tests for the decoded-sample cache (CoorDL/MinIO-style):
//! policy behavior under re-shuffled epoch orders, agreement between the
//! engine's measured hit rate and the simulator's closed-form model,
//! epoch-2+ wall-clock gains on a throttled tier through the full
//! coordinator, and the two satellite bug regressions (raw-byte cache
//! accounting, recv-wait flush at drain).

use dpp::config::{Method, Placement, RunConfig};
use dpp::coordinator::{self, prepare_data};
use dpp::dataset::GenConfig;
use dpp::pipeline::prep_cache::{
    steady_state_hit_rate, DecodedSample, PrepCache, PrepCachePolicy,
};
use dpp::util::rng::Rng;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{Arc, OnceLock};

const SAMPLE_PX: usize = 3 * 8 * 8; // 768 B per decoded sample

fn sample() -> Arc<DecodedSample> {
    Arc::new(DecodedSample::new(3, 8, 8, vec![0.25; SAMPLE_PX]))
}

/// Drive `epochs` freshly re-shuffled passes over `n` samples through a
/// cache holding `cache_frac` of the decoded corpus; returns the
/// per-epoch hit rates.
fn drive(policy: PrepCachePolicy, cache_frac: f64, epochs: u64, n: u64) -> Vec<f64> {
    let sample_bytes = SAMPLE_PX * 4;
    let budget = (n as f64 * sample_bytes as f64 * cache_frac) as usize;
    let cache = PrepCache::new(budget, policy);
    let mut order: Vec<u64> = (0..n).collect();
    let mut rates = Vec::new();
    for epoch in 0..epochs {
        Rng::new(0xCAFE).fork(epoch).shuffle(&mut order);
        let h0 = cache.hits.load(Ordering::Relaxed);
        for &id in &order {
            if cache.get(id).is_none() {
                cache.admit(id, sample());
            }
        }
        let h1 = cache.hits.load(Ordering::Relaxed);
        rates.push((h1 - h0) as f64 / n as f64);
    }
    rates
}

/// Acceptance: at a half-corpus cache over 3 re-shuffled epochs, the
/// eviction-free minio policy sustains >= 0.4 hit rate from epoch 2 on,
/// while LRU collapses below it (the CoorDL thrash result).
#[test]
fn minio_sustains_hit_rate_while_lru_collapses() {
    let minio = drive(PrepCachePolicy::Minio, 0.5, 3, 400);
    let lru = drive(PrepCachePolicy::Lru, 0.5, 3, 400);
    assert_eq!(minio[0], 0.0, "epoch 1 is all misses");
    for e in 1..3 {
        assert!(minio[e] >= 0.4, "minio epoch {e}: {:.3}", minio[e]);
        assert!(
            lru[e] < minio[e],
            "lru must collapse below minio in epoch {e}: {:.3} vs {:.3}",
            lru[e],
            minio[e]
        );
    }
    // LRU specifically thrashes far below the cache fraction.
    assert!(lru[2] < 0.3, "lru steady state {:.3} should be far below 0.5", lru[2]);
}

/// Acceptance: the engine's measured steady-state hit rate agrees with
/// the simulator's closed-form model within 20%, for both policies
/// across cache fractions — this is what keeps simulated multi-epoch
/// remote runs comparable to real ones.
#[test]
fn sim_model_matches_engine_hit_rate_within_20pct() {
    let n = 600u64;
    let dataset_bytes = (n as usize * SAMPLE_PX * 4) as f64;
    for policy in [PrepCachePolicy::Minio, PrepCachePolicy::Lru] {
        for frac in [0.3, 0.5, 0.8] {
            let rates = drive(policy, frac, 4, n);
            let engine: f64 = rates[1..].iter().sum::<f64>() / 3.0;
            let model = steady_state_hit_rate(policy, dataset_bytes * frac, dataset_bytes);
            let rel = (engine - model).abs() / model.max(1e-9);
            assert!(
                rel < 0.20,
                "{policy:?} f={frac}: engine {engine:.3} vs model {model:.3} ({rel:.3})"
            );
        }
    }
    // And the sim Scenario exposes exactly this model (same formula, the
    // paper-scale decoded corpus as denominator).
    let s = dpp::sim::Scenario { prep_cache_gb: 385.0, ..Default::default() };
    let want = steady_state_hit_rate(
        PrepCachePolicy::Minio,
        385.0e9,
        dpp::sim::calib::decoded_dataset_bytes(),
    );
    assert!((s.prep_cache_hit() - want).abs() < 1e-12);
}

// ---------------------------------------------------------------------------
// Full-coordinator runs (need the AOT artifacts, like pipeline_e2e.rs)
// ---------------------------------------------------------------------------

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.json").exists()
}

/// Shared corpus, generated once per test binary (tests run in parallel).
fn corpus() -> &'static PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("dpp-pc-{}", std::process::id()));
        prepare_data(&dir, &GenConfig { n_images: 80, ..Default::default() }, 3).unwrap();
        dir
    })
}

/// Acceptance: on a throttled tier with a whole-corpus decoded cache,
/// epoch 2+ wall-clock beats epoch 1 (read+decode amortized away), the
/// hit rate converges to (epochs-1)/epochs, and every skipped decode is
/// counted.
#[test]
fn epoch_two_beats_epoch_one_on_throttled_tier() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let cfg = RunConfig {
        data_dir: corpus().clone(),
        artifact_dir: artifact_dir(),
        model: "resnet_t".into(),
        method: Method::Raw, // whole-object reads pay the throttle per image
        placement: Placement::Cpu,
        storage: "ebs".into(),
        time_scale: 60.0,
        batch_size: 8,
        // One worker: epoch boundaries stay strict, so the hit counts
        // below are exact (two workers could race the last sample of
        // epoch N against its epoch-N+1 reappearance).
        cpu_workers: 1,
        steps: 0,
        epochs: 3,
        train: false,
        prep_cache_mb: 64, // whole decoded corpus (~3.8 MB) fits
        prep_cache_policy: PrepCachePolicy::Minio,
        ..Default::default()
    };
    let r = coordinator::run(&cfg).unwrap();
    assert_eq!(r.images, 240, "3 epochs x 80 images");
    assert_eq!(r.decode_skipped, 160, "epochs 2+3 must be all cache hits");
    assert!(
        (r.prep_cache_hit_rate - 2.0 / 3.0).abs() < 0.01,
        "hit rate {:.3}",
        r.prep_cache_hit_rate
    );
    assert_eq!(r.epoch_secs.len(), 3, "{:?}", r.epoch_secs);
    for e in 1..3 {
        assert!(
            r.epoch_secs[e] < r.epoch_secs[0] * 0.8,
            "epoch {e} ({:.3}s) must beat epoch 1 ({:.3}s): cached epochs skip the \
             throttled read + decode",
            r.epoch_secs[e],
            r.epoch_secs[0]
        );
    }
}

/// The hybrid placement with a warm cache interleaves coef and pixel
/// payloads; the per-kind batcher must keep training correct end to end.
#[test]
fn hybrid_placement_trains_with_warm_cache() {
    if !have_artifacts() {
        return;
    }
    let cfg = RunConfig {
        data_dir: corpus().clone(),
        artifact_dir: artifact_dir(),
        model: "resnet_t".into(),
        placement: Placement::Hybrid,
        batch_size: 8,
        cpu_workers: 1, // keep per-epoch hit counts exact (see above)
        steps: 0,
        epochs: 2,
        lr: 0.1,
        prep_cache_mb: 64,
        prep_cache_policy: PrepCachePolicy::Minio,
        ..Default::default()
    };
    let r = coordinator::run(&cfg).unwrap();
    assert_eq!(r.steps, 20, "2 epochs x 10 batches");
    assert_eq!(r.decode_skipped, 80, "epoch 2 skips every decode");
    assert!(r.losses.iter().all(|(_, l)| l.is_finite()));
}

// ---------------------------------------------------------------------------
// Satellite regressions
// ---------------------------------------------------------------------------

/// Regression (storage/cache.rs byte accounting): two concurrent misses
/// of the same key with different lengths race to admit; the loser's
/// entry must be credited so `cached_bytes` stays exact and <= budget.
/// (Failed before the fix: `bytes` kept the first admission's length.)
#[test]
fn concurrent_misses_of_different_lengths_keep_cache_bytes_exact() {
    use anyhow::Result;
    use dpp::storage::{CachedStore, Storage};
    use std::sync::atomic::AtomicU64;
    use std::sync::Barrier;

    /// Both readers enter `read` before either admits (barrier), and each
    /// call returns a different length.
    struct RacingStore {
        barrier: Barrier,
        calls: AtomicU64,
    }

    impl Storage for RacingStore {
        fn read(&self, _name: &str) -> Result<Arc<[u8]>> {
            let call = self.calls.fetch_add(1, Ordering::SeqCst);
            if call < 2 {
                self.barrier.wait();
            }
            let len = if call == 0 { 60 } else { 20 };
            Ok(vec![call as u8; len].into())
        }
        fn read_range(&self, name: &str, _offset: u64, len: u64) -> Result<Arc<[u8]>> {
            let v = self.read(name)?;
            Ok(v[..(len as usize).min(v.len())].into())
        }
        fn len(&self, _name: &str) -> Result<u64> {
            Ok(60)
        }
        fn list(&self) -> Result<Vec<String>> {
            Ok(vec!["a".into()])
        }
        fn stats(&self) -> (u64, u64) {
            (0, 0)
        }
    }

    // Budget holds both racing values at once (60 + 20 < 100): the buggy
    // code path is the no-eviction replacement, where `bytes` kept the
    // losing admission's length.
    let budget = 100;
    let cache = Arc::new(CachedStore::new(
        RacingStore { barrier: Barrier::new(2), calls: AtomicU64::new(0) },
        budget,
    ));
    let threads: Vec<_> = (0..2)
        .map(|_| {
            let cache = cache.clone();
            std::thread::spawn(move || cache.read("a").unwrap().len())
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    // Whichever admission won, the resident entry's length must be what
    // the accounting says, and within budget (the 60 B and 20 B values
    // cannot both be charged against a 64 B budget).
    let resident = cache.read("a").unwrap().len();
    assert_eq!(
        cache.cached_bytes(),
        resident,
        "cached_bytes drifted from the resident entry"
    );
    assert!(cache.cached_bytes() <= budget);
}

/// Regression (pipeline/channel.rs recv-wait flush): a consumer blocked
/// on an empty queue until every sender drops must still account that
/// block time — it is exactly the end-of-epoch GPU-starved signal.
#[test]
fn recv_wait_flushed_when_channel_closes_empty() {
    use dpp::pipeline::channel::bounded;
    use std::time::Duration;

    let (tx, rx) = bounded::<u8>(4);
    let producer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(60));
        drop(tx); // end of epoch: close without sending
    });
    assert_eq!(rx.recv(), None);
    producer.join().unwrap();
    assert!(
        rx.recv_wait_secs() > 0.04,
        "drain wait was dropped on the None path: {}",
        rx.recv_wait_secs()
    );
}
