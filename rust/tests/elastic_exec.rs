//! Acceptance tests for the elastic pipeline executor (ISSUE 4):
//!
//! * **device-bound**: `auto` converges to ≤ the fixed-optimal worker
//!   count, with wall-clock within 10% of the best fixed sweep point;
//! * **prep-bound**: `auto` climbs all the way to `workers_max`;
//! * **engine-vs-sim**: the controller's converged `workers_final`
//!   matches the analytic fixed point (`sim::workers_fixed_point`)
//!   within ±1, on both shapes plus a matched middle point.
//!
//! The pipeline here is synthetic — sleep-based stage and sink service
//! times driven through the real `ElasticPool`, channels, and controller
//! — so the tests need no XLA artifacts and pin down exactly the
//! feedback loop, not the codec.

use dpp::metrics::BusyClock;
use dpp::pipeline::channel::bounded;
use dpp::pipeline::exec::{self, ExecConfig, PoolReport};
use dpp::sim::workers_fixed_point;
use std::time::{Duration, Instant};

/// Drive `n_items` through a pool whose stage costs `stage_ms` each,
/// into a sink that drains one item per `sink_ms`.  Returns the wall
/// clock of the whole drain plus the pool's report.
fn drive(cfg: ExecConfig, n_items: usize, stage_ms: f64, sink_ms: f64) -> (f64, PoolReport) {
    let (work_tx, work_rx) = bounded(cfg.work_queue_cap(16));
    let (out_tx, out_rx) = bounded::<u64>(16);
    let clock = if cfg.auto {
        BusyClock::new_live(cfg.workers_initial)
    } else {
        BusyClock::new(cfg.workers_initial)
    };
    let pool = exec::spawn(cfg, work_rx, out_tx, clock.clone(), move |i: u64| {
        std::thread::sleep(Duration::from_secs_f64(stage_ms / 1000.0));
        Ok(Some(i))
    })
    .unwrap();
    let t0 = Instant::now();
    let consumer = std::thread::spawn(move || {
        let mut n = 0usize;
        while out_rx.recv().is_some() {
            if sink_ms > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(sink_ms / 1000.0));
            }
            n += 1;
        }
        n
    });
    for i in 0..n_items {
        work_tx.send(i as u64).unwrap();
    }
    drop(work_tx);
    let consumed = consumer.join().unwrap();
    assert_eq!(consumed, n_items, "sink must see every item exactly once");
    let wall = t0.elapsed().as_secs_f64();
    let out = pool.join();
    out.result.unwrap();
    (wall, out.report)
}

/// Device-bound: the sink (5 ms/item ≈ 200 items/s) is the bottleneck;
/// one 1 ms-stage worker already over-serves it.
#[test]
fn device_bound_auto_converges_at_or_below_fixed_optimum() {
    let (stage_ms, sink_ms, n) = (1.0, 5.0, 250);
    // Explicit fixed sweep: every count is sink-bound, so the best wall
    // is what one worker already achieves.
    let mut walls = Vec::new();
    for w in [1usize, 2, 4] {
        let (wall, rep) = drive(ExecConfig::fixed(w), n, stage_ms, sink_ms);
        assert_eq!(rep.workers_final, w);
        walls.push((w, wall));
    }
    let best = walls.iter().map(|&(_, t)| t).fold(f64::INFINITY, f64::min);
    let fixed_opt = walls
        .iter()
        .filter(|&&(_, t)| t <= best * 1.05)
        .map(|&(w, _)| w)
        .min()
        .unwrap();
    // The analytic fixed point for these service times is one worker.
    let fp = workers_fixed_point(stage_ms, 1000.0 / sink_ms, 1, 4);
    assert_eq!(fp, 1);
    let (auto_wall, rep) = drive(ExecConfig::auto(1, 4, 0.05), n, stage_ms, sink_ms);
    assert!(
        rep.workers_final <= fixed_opt,
        "auto ended at {} workers, fixed optimum is {fixed_opt}",
        rep.workers_final
    );
    assert!(
        rep.workers_final.abs_diff(fp) <= 1,
        "engine {} vs sim fixed point {fp}",
        rep.workers_final
    );
    // The ISSUE's 10% wall-clock bound, plus a small absolute slack:
    // every run here is sink-bound (250 x 5 ms sleeps), but sleep
    // overshoot under CI scheduler pressure is unbounded and need not
    // hit the auto run and the fixed sweep equally.
    assert!(
        auto_wall <= best * 1.10 + 0.20,
        "auto wall {auto_wall:.2}s vs best fixed {best:.2}s (>10% off)"
    );
}

/// Prep-bound: the sink is free, the 4 ms stage is the bottleneck — the
/// controller must climb to `workers_max`, and the sim must predict it.
#[test]
fn prep_bound_auto_reaches_workers_max() {
    let (stage_ms, sink_ms, n) = (4.0, 0.0, 500);
    let (_, rep) = drive(ExecConfig::auto(1, 4, 0.05), n, stage_ms, sink_ms);
    let fp = workers_fixed_point(stage_ms, f64::INFINITY, 1, 4);
    assert_eq!(fp, 4, "an unbounded sink pegs the analytic fixed point at max");
    assert_eq!(
        rep.workers_final, 4,
        "starved batcher must drive the pool to workers_max (timeline {:?})",
        rep.workers_timeline
    );
    // The climb is visible in the timeline: it starts at min and only
    // ever grows on this workload.
    assert_eq!(rep.workers_timeline.first().unwrap().1, 1);
    assert!(
        rep.workers_timeline.windows(2).all(|w| w[1].1 >= w[0].1),
        "prep-bound run must never park: {:?}",
        rep.workers_timeline
    );
}

/// Matched middle point: a sink of ~385 items/s against a 5 ms stage
/// needs two workers — the controller must settle within ±1 of the
/// analytic fixed point instead of pegging at either bound.
#[test]
fn matched_pipeline_settles_at_the_analytic_fixed_point() {
    let (stage_ms, sink_ms, n) = (5.0, 2.6, 400);
    let fp = workers_fixed_point(stage_ms, 1000.0 / sink_ms, 1, 4);
    assert_eq!(fp, 2);
    let (_, rep) = drive(ExecConfig::auto(1, 4, 0.05), n, stage_ms, sink_ms);
    assert!(
        rep.workers_final.abs_diff(fp) <= 1,
        "engine converged to {} workers, sim predicts {fp} (timeline {:?})",
        rep.workers_final,
        rep.workers_timeline
    );
}

/// A fixed pool through the same harness behaves exactly like the old
/// hard-coded worker loop: constant size, untouched by the controller.
#[test]
fn fixed_pool_never_resizes() {
    let (_, rep) = drive(ExecConfig::fixed(3), 100, 0.5, 0.0);
    assert_eq!(rep.workers_final, 3);
    assert_eq!(rep.workers_timeline, vec![(0.0, 3)]);
}
