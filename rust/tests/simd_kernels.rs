//! Scalar-vs-SIMD bit-identity harness for the vectorized kernel layer
//! (`src/simd/`): seeded property tests drive every kernel — fused
//! dequant+IDCT at each fractional scale 8/4/2/1, the fused
//! bilerp+normalize sampler, the plane normalizers, and the
//! table-driven entropy decoder — at every ISA tier the host supports,
//! asserting the outputs are *bit*-identical (`f32::to_bits`, not `==`,
//! so signed zeros cannot hide a divergence).  Odd widths and
//! non-multiple-of-lane tails are enumerated exhaustively on top of the
//! random sweep.  The whole-pipeline loss-curve A/B (`--simd on` vs
//! `off`) lives in `tests/pipeline_e2e.rs` next to the slab A/B, since
//! it needs the trained artifacts.
//!
//! Under miri `detect()` reports `Scalar`, so the vector tier list is
//! empty and these tests check only the dispatch plumbing — which is
//! exactly the part miri *can* validate (the unsafe refill window in
//! the entropy fast path stays exercised: it is safe Rust + one
//! `u64::from_le_bytes`, not a vendor intrinsic).

use dpp::codec::dct;
use dpp::codec::entropy::{EntropyReader, EntropyWriter};
use dpp::codec::qtable_for_quality;
use dpp::ops::{self, AugParams, AugScratch};
use dpp::simd::{self, SimdLevel};
use dpp::testing::{check, PropConfig};
use dpp::util::rng::Rng;

/// Vector tiers the host can actually run (empty under miri and on
/// non-x86-64 targets — the properties then pin scalar==scalar, which
/// still exercises the dispatchers' fallback arms).
fn vector_levels() -> Vec<SimdLevel> {
    [SimdLevel::Sse2, SimdLevel::Avx2]
        .into_iter()
        .filter(|&l| l <= simd::detect())
        .collect()
}

fn cases(n: usize) -> PropConfig {
    PropConfig { cases: if cfg!(miri) { n / 8 + 1 } else { n }, ..Default::default() }
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Random quantized coefficient block with structure the kernels
/// special-case: DC-only (fast path), sparse rows (row-mask skip), and
/// dense (full matrix passes), weighted by the case size.
fn gen_coef_block(rng: &mut Rng, size: usize) -> [f32; 64] {
    let mut coef = [0f32; 64];
    let density = match rng.gen_range(4) {
        0 => 0,                          // DC-only
        1 => 1 + rng.gen_range(4),       // sparse: a few ACs
        _ => 8 + rng.gen_range(1 + size as u64 / 2), // dense-ish
    };
    coef[0] = (rng.gen_range(4001) as f32 - 2000.0).trunc();
    for _ in 0..density {
        let i = 1 + rng.gen_range(63) as usize;
        let mag = 1 + rng.gen_range(50) as i64;
        coef[i] = if rng.bool() { mag as f32 } else { -(mag as f32) };
    }
    coef
}

// ---------------------------------------------------------------------------
// Kernel 1: fused dequant + IDCT at every fractional scale
// ---------------------------------------------------------------------------

#[test]
fn prop_idct_bit_identical_across_levels_and_scales() {
    let levels = vector_levels();
    check(
        "idct-level-identity",
        cases(96),
        |rng, size| {
            let quality = 30 + rng.gen_range(71) as u8;
            (gen_coef_block(rng, size), quality, rng.gen_range(4) as usize)
        },
        |&(coef, quality, scale_log2)| {
            let q = qtable_for_quality(quality);
            let n = 8usize >> scale_log2;
            let mut want = vec![0f32; n * n];
            dct::dequant_idct_block_scaled_level(&coef, &q, scale_log2, &mut want, SimdLevel::Scalar);
            levels.iter().all(|&level| {
                let mut got = vec![f32::NAN; n * n];
                dct::dequant_idct_block_scaled_level(&coef, &q, scale_log2, &mut got, level);
                bits_eq(&want, &got)
            })
        },
    );
}

/// Deterministic sweep: each scale kernel (8/4/2/1-point), dense input
/// (every coefficient nonzero, so no fast path can mask the vector
/// code), every available tier.
#[test]
fn every_scale_kernel_is_bit_identical_on_dense_blocks() {
    let q = qtable_for_quality(85);
    let mut rng = Rng::new(0x51D_1DC7);
    let mut coef = [0f32; 64];
    for v in coef.iter_mut() {
        let mag = 1 + rng.gen_range(50) as i64;
        *v = if rng.bool() { mag as f32 } else { -(mag as f32) };
    }
    for scale_log2 in 0..=3usize {
        let n = 8 >> scale_log2;
        let mut want = vec![0f32; n * n];
        dct::dequant_idct_block_scaled_level(&coef, &q, scale_log2, &mut want, SimdLevel::Scalar);
        for level in vector_levels() {
            let mut got = vec![f32::NAN; n * n];
            dct::dequant_idct_block_scaled_level(&coef, &q, scale_log2, &mut got, level);
            assert!(
                bits_eq(&want, &got),
                "scale 1/{} diverged at {level:?}: {want:?} vs {got:?}",
                1 << scale_log2
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Kernel 2: fused bilinear resize + normalize (the augment sampler)
// ---------------------------------------------------------------------------

#[test]
fn prop_bilerp_norm_bit_identical_on_random_geometry() {
    let levels = vector_levels();
    check(
        "bilerp-norm-level-identity",
        cases(64),
        |rng, size| {
            let c = 1 + rng.gen_range(3) as usize;
            let h = 10 + rng.gen_range(6 + size as u64 / 4) as usize;
            let w = 10 + rng.gen_range(6 + size as u64 / 4) as usize;
            // A decoded sub-view (vy,vx,vh,vw), as the fused ROI decode
            // hands the sampler; sometimes the full image.
            let vy = rng.gen_range(3) as usize;
            let vx = rng.gen_range(3) as usize;
            let vh = h - vy - rng.gen_range(3) as usize;
            let vw = w - vx - rng.gen_range(3) as usize;
            // Crop window inside the view.
            let ch = 2 + rng.gen_range(vh as u64 - 1) as usize;
            let cw = 2 + rng.gen_range(vw as u64 - 1) as usize;
            let y0 = vy + rng.gen_range((vh - ch + 1) as u64) as usize;
            let x0 = vx + rng.gen_range((vw - cw + 1) as u64) as usize;
            // Output sides 1..=40: sub-lane, exact-lane, and ragged-tail
            // widths for both the 4-lane and 8-lane kernels.
            let oh = 1 + rng.gen_range(40) as usize;
            let ow = 1 + rng.gen_range(40) as usize;
            let flip = rng.bool();
            let seed = rng.next_u64();
            (seed, c, (h, w), (vy, vx, vh, vw), (y0, x0, ch, cw, flip), (oh, ow))
        },
        |&(seed, c, (h, w), view, (y0, x0, ch, cw, flip), (oh, ow))| {
            let (_, _, vh, vw) = view;
            let mut rng = Rng::new(seed);
            let img: Vec<f32> =
                (0..c * vh * vw).map(|_| rng.uniform(0.0, 255.0) as f32).collect();
            let p = AugParams {
                y0: y0 as u32,
                x0: x0 as u32,
                crop_h: ch as u32,
                crop_w: cw as u32,
                flip,
            };
            let mut scratch = AugScratch::new();
            let mut want = vec![0f32; c * oh * ow];
            ops::augment_fused_view_into_level(
                &img, c, h, w, view, &p, oh, ow, &mut scratch, &mut want, SimdLevel::Scalar,
            );
            levels.iter().all(|&level| {
                let mut got = vec![f32::NAN; c * oh * ow];
                ops::augment_fused_view_into_level(
                    &img, c, h, w, view, &p, oh, ow, &mut scratch, &mut got, level,
                );
                bits_eq(&want, &got)
            })
        },
    );
}

/// Exhaustive tail sweep: every output width 1..=33 (sub-lane, one
/// full vector, vector+ragged-tail for the 4- and 8-lane row kernels),
/// fixed image, both flip arms.
#[test]
fn every_output_width_tail_is_bit_identical() {
    let widths = if cfg!(miri) { 1..=9usize } else { 1..=33usize };
    let (c, h, w) = (3usize, 21usize, 19usize);
    let mut rng = Rng::new(0xB1_1E2F);
    let img: Vec<f32> = (0..c * h * w).map(|_| rng.uniform(0.0, 255.0) as f32).collect();
    let view = (0usize, 0usize, h, w);
    let mut scratch = AugScratch::new();
    for ow in widths {
        for flip in [false, true] {
            let p = AugParams { y0: 1, x0: 2, crop_h: 17, crop_w: 15, flip };
            let mut want = vec![0f32; c * 7 * ow];
            ops::augment_fused_view_into_level(
                &img, c, h, w, view, &p, 7, ow, &mut scratch, &mut want, SimdLevel::Scalar,
            );
            for level in vector_levels() {
                let mut got = vec![f32::NAN; c * 7 * ow];
                ops::augment_fused_view_into_level(
                    &img, c, h, w, view, &p, 7, ow, &mut scratch, &mut got, level,
                );
                assert!(bits_eq(&want, &got), "ow={ow} flip={flip} diverged at {level:?}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Kernel 3: plane normalize (in-place and copying)
// ---------------------------------------------------------------------------

#[test]
fn prop_normalize_bit_identical_on_odd_lengths() {
    let levels = vector_levels();
    check(
        "normalize-level-identity",
        cases(64),
        |rng, size| (rng.next_u64(), 1 + rng.gen_range(3) as usize, 1 + rng.gen_range(40 + size as u64 * 2) as usize),
        |&(seed, c, hw)| {
            let mut rng = Rng::new(seed);
            let img: Vec<f32> = (0..c * hw).map(|_| rng.uniform(0.0, 255.0) as f32).collect();
            let mut want = vec![0f32; c * hw];
            ops::normalize_into_level(&img, c, hw, &mut want, SimdLevel::Scalar);
            levels.iter().all(|&level| {
                // Copying form...
                let mut got = vec![f32::NAN; c * hw];
                ops::normalize_into_level(&img, c, hw, &mut got, level);
                // ...and the in-place form must match it exactly.
                let mut inplace = img.clone();
                ops::normalize_level(&mut inplace, c, hw, level);
                bits_eq(&want, &got) && bits_eq(&want, &inplace)
            })
        },
    );
}

// ---------------------------------------------------------------------------
// Kernel 4: table-driven entropy decode
// ---------------------------------------------------------------------------

/// Random quantized block shaped for the entropy coder: zigzag runs,
/// large multi-byte varint magnitudes, and occasional all-zero blocks
/// (EOB-only — the shortest symbol the window refill must handle).
fn gen_entropy_block(rng: &mut Rng) -> [i32; 64] {
    let mut q = [0i32; 64];
    if rng.gen_range(8) == 0 {
        return q; // EOB-only block
    }
    q[0] = rng.gen_range(4001) as i32 - 2000;
    for _ in 0..1 + rng.gen_range(14) {
        let i = 1 + rng.gen_range(63) as usize;
        let mag = match rng.gen_range(3) {
            0 => 1 + rng.gen_range(60) as i32,       // 1-byte varint
            1 => 64 + rng.gen_range(8000) as i32,    // 2-byte varint
            _ => 20_000 + rng.gen_range(300_000) as i32, // 3+-byte varint
        };
        q[i] = if rng.bool() { mag } else { -mag };
    }
    q
}

#[test]
fn prop_entropy_fast_and_slow_agree_on_values_positions_and_skips() {
    check(
        "entropy-fast-slow-identity",
        cases(48),
        |rng, size| (rng.next_u64(), 1 + rng.gen_range(2 + size as u64 / 8) as usize),
        |&(seed, nblocks)| {
            let mut rng = Rng::new(seed);
            let blocks: Vec<[i32; 64]> = (0..nblocks).map(|_| gen_entropy_block(&mut rng)).collect();
            let mut buf = Vec::new();
            let mut w = EntropyWriter::new(&mut buf);
            for b in &blocks {
                w.write_block(b).unwrap();
            }
            w.finish().unwrap();

            // Decode parity: values AND the byte position after every
            // block (the fast window refill must not over-consume).
            let mut fast = EntropyReader::with_table_decode(&buf, true);
            let mut slow = EntropyReader::with_table_decode(&buf, false);
            for b in &blocks {
                let (mut qf, mut qs) = ([0i32; 64], [0i32; 64]);
                fast.read_block(&mut qf).unwrap();
                slow.read_block(&mut qs).unwrap();
                if qf != qs || &qf != b || fast.bytes_consumed() != slow.bytes_consumed() {
                    return false;
                }
            }
            if fast.bytes_consumed() != buf.len() {
                return false;
            }

            // Skip parity: same positions without materializing values.
            let mut fast = EntropyReader::with_table_decode(&buf, true);
            let mut slow = EntropyReader::with_table_decode(&buf, false);
            for _ in 0..nblocks {
                fast.skip_block().unwrap();
                slow.skip_block().unwrap();
                if fast.bytes_consumed() != slow.bytes_consumed() {
                    return false;
                }
            }
            true
        },
    );
}

/// Error parity under every possible truncation: cutting the stream at
/// each byte offset must make the fast and slow decoders fail (or
/// succeed) identically — same per-block values, same error text, same
/// final position.  This walks the window path, the byte-tail path,
/// and the boundary between them (`WINDOW_BYTES` from the cut).
#[test]
fn entropy_fast_and_slow_agree_on_every_truncation() {
    let nblocks = if cfg!(miri) { 2 } else { 6 };
    let mut rng = Rng::new(0xE27_0B5);
    let blocks: Vec<[i32; 64]> = (0..nblocks).map(|_| gen_entropy_block(&mut rng)).collect();
    let mut buf = Vec::new();
    let mut w = EntropyWriter::new(&mut buf);
    for b in &blocks {
        w.write_block(b).unwrap();
    }
    w.finish().unwrap();

    let decode_all = |buf: &[u8], fastpath: bool| -> Result<Vec<[i32; 64]>, (usize, String)> {
        let mut r = EntropyReader::with_table_decode(buf, fastpath);
        let mut out = Vec::new();
        for _ in 0..nblocks {
            let mut q = [0i32; 64];
            r.read_block(&mut q).map_err(|e| (r.bytes_consumed(), format!("{e:#}")))?;
            out.push(q);
        }
        Ok(out)
    };
    for cut in 0..=buf.len() {
        let (f, s) = (decode_all(&buf[..cut], true), decode_all(&buf[..cut], false));
        assert_eq!(f, s, "fast/slow diverged with stream cut at byte {cut}");
    }
    // The untruncated stream round-trips on both paths.
    assert_eq!(decode_all(&buf, true).unwrap(), blocks);
}

/// Corrupt-token parity: a byte that is neither a run token nor EOB
/// must fail identically on both paths, at the same position.
#[test]
fn entropy_fast_and_slow_reject_bad_tokens_identically() {
    let mut buf = Vec::new();
    let mut w = EntropyWriter::new(&mut buf);
    w.write_block(&{
        let mut q = [0i32; 64];
        q[0] = 41;
        q[1] = -7;
        q
    })
    .unwrap();
    w.finish().unwrap();
    // Overwrite the first token with an out-of-range byte; pad so the
    // corruption sits inside a full 64-bit window (the fast path's hot
    // arm), not only the byte tail.
    let mut bad = buf.clone();
    bad[0] = 0xC0;
    bad.extend_from_slice(&[0u8; 16]);
    let run = |fastpath: bool| {
        let mut r = EntropyReader::with_table_decode(&bad, fastpath);
        let err = r.read_block(&mut [0i32; 64]).unwrap_err();
        (r.bytes_consumed(), format!("{err:#}"))
    };
    assert_eq!(run(true), run(false));
}

// ---------------------------------------------------------------------------
// Dispatch plumbing
// ---------------------------------------------------------------------------

/// `set_mode` / `active` sequencing.  This is the only test in this
/// binary that touches the process-global mode (every kernel test above
/// pins explicit levels), so the assertions cannot race; and because
/// all tiers are bit-identical, even a hypothetical racing reader in
/// another test could observe only a speed change, never a value change.
#[test]
fn set_mode_sequencing_pins_and_releases_the_active_level() {
    use dpp::simd::SimdMode;
    assert!(simd::active() <= simd::detect(), "active level above hardware");
    assert_eq!(simd::resolve_mode(SimdMode::Off), SimdLevel::Scalar);
    assert_eq!(simd::resolve_mode(SimdMode::On), simd::detect());
    assert_eq!(simd::resolve_mode(SimdMode::Auto), simd::detect());

    simd::set_mode(SimdMode::Off);
    assert_eq!(simd::active(), SimdLevel::Scalar);
    assert!(!simd::entropy_fast(), "--simd off must pin the slow entropy loop");

    simd::set_mode(SimdMode::On);
    assert_eq!(simd::active(), simd::detect());
    assert_eq!(simd::entropy_fast(), simd::detect() != SimdLevel::Scalar);

    simd::set_mode(SimdMode::Auto);
    assert_eq!(simd::active(), simd::detect());
}
