//! ShardReader edge cases: records straddling chunk boundaries, chunk
//! sizes smaller than one record header, and corruption/truncation
//! surfacing as clean errors (not panics or silent data loss).

use dpp::pipeline::quarantine::Quarantine;
use dpp::pipeline::source::StorageReader;
use dpp::record::{parse_shard, RecordEvent, ShardReader, ShardWriter, REC_HEADER_LEN};
use dpp::storage::MemStore;
use std::io::Cursor;
use std::path::PathBuf;
use std::sync::Arc;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dpp-rs-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Build one shard in memory with awkward payload sizes (primes, empty,
/// and exactly-chunk-sized payloads) and return (bytes, payload lens).
fn awkward_shard(tag: &str, chunk_hint: usize) -> (Vec<u8>, Vec<usize>) {
    let dir = tmpdir(tag);
    let path = dir.join("s.rec");
    let mut w = ShardWriter::create(&path).unwrap();
    let mut lens = Vec::new();
    let sizes = [
        0usize,
        1,
        97,
        251,
        chunk_hint - 1,
        chunk_hint,
        chunk_hint + 1,
        2 * chunk_hint + 13,
        1009,
    ];
    for (i, &n) in sizes.iter().cycle().take(60).enumerate() {
        w.append(i as u64, (i % 5) as u16, &vec![(i % 251) as u8; n]).unwrap();
        lens.push(n);
    }
    w.finish().unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_dir_all(dir).ok();
    (bytes, lens)
}

#[test]
fn records_straddling_chunk_boundaries_stream_intact() {
    let (bytes, lens) = awkward_shard("straddle", 100);
    // Chunks chosen so most records cross at least one refill boundary.
    for chunk in [17usize, 100, 101, 256, 1 << 20] {
        let mut r = ShardReader::new(Cursor::new(bytes.clone()), chunk);
        let mut got = 0;
        while let Some(rec) = r.next_record().unwrap() {
            assert_eq!(rec.payload.len(), lens[got], "chunk={chunk} record {got}");
            assert_eq!(rec.id, got as u64);
            assert!(rec.payload.iter().all(|&b| b == (got % 251) as u8));
            got += 1;
        }
        assert_eq!(got, 60, "chunk={chunk}");
    }
}

#[test]
fn chunk_smaller_than_record_header_is_clamped_and_works() {
    let (bytes, _) = awkward_shard("tiny", 64);
    // The 16-byte shard header / 18-byte record meta never fit in these
    // chunks; ShardReader must clamp and keep refilling, not stall.
    for chunk in [0usize, 1, 2, 15] {
        assert!(chunk < REC_HEADER_LEN as usize);
        let mut r = ShardReader::new(Cursor::new(bytes.clone()), chunk);
        let mut got = 0;
        while r.next_record().unwrap().is_some() {
            got += 1;
        }
        assert_eq!(got, 60, "chunk={chunk}");
    }
}

#[test]
fn corrupted_fnv_surfaces_clean_error() {
    let dir = tmpdir("fnv");
    let path = dir.join("s.rec");
    let mut w = ShardWriter::create(&path).unwrap();
    for i in 0..10u64 {
        w.append(i, 0, &vec![i as u8 + 1; 500]).unwrap();
    }
    w.finish().unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Flip one payload byte of a middle record.
    let n = bytes.len();
    bytes[n / 2] ^= 0xFF;
    std::fs::remove_dir_all(dir).ok();

    // Streaming with a small chunk (forces mid-record refills) must
    // yield the intact leading records, then a checksum error.
    let mut r = ShardReader::new(Cursor::new(bytes.clone()), 64);
    let mut ok = 0;
    let err = loop {
        match r.next_record() {
            Ok(Some(_)) => ok += 1,
            Ok(None) => panic!("corruption not detected after {ok} records"),
            Err(e) => break e,
        }
    };
    assert!(ok < 10, "all records delivered despite corruption");
    assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");
    // Whole-shard parsing agrees.
    assert!(parse_shard(&bytes).is_err());
}

#[test]
fn corruption_between_samples_skips_forward_under_budget() {
    let dir = tmpdir("skip");
    let path = dir.join("s.rec");
    let mut w = ShardWriter::create(&path).unwrap();
    for i in 0..20u64 {
        w.append(i, 0, &vec![i as u8 + 1; 400]).unwrap();
    }
    w.finish().unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    std::fs::remove_dir_all(dir).ok();

    // Flip one payload byte in two separate records: frames are 18-byte
    // meta + 400-byte payload after the 16-byte header, so record k's
    // payload spans [16 + k*418 + 18, 16 + k*418 + 418).
    bytes[16 + 3 * 418 + 18 + 57] ^= 0x10; // record 3
    bytes[16 + 11 * 418 + 18 + 200] ^= 0x10; // record 11

    // The fault-tolerant event stream hops both corrupt frames (their
    // intact length headers are the resync points) and delivers every
    // other record in order; a 2-skip quarantine budget absorbs them.
    let q = Quarantine::new(0.1, 20); // floor(0.1 * 20) = 2 skips
    let mut r = ShardReader::new(Cursor::new(bytes.clone()), 64);
    let mut got = Vec::new();
    while let Some(ev) = r.next_event().unwrap() {
        match ev {
            RecordEvent::Record(rec) => got.push(rec.id),
            RecordEvent::Skipped { id, err } => {
                q.admit(format!("record {id}"), anyhow::anyhow!(err)).unwrap();
            }
        }
    }
    let want: Vec<u64> = (0..20).filter(|i| *i != 3 && *i != 11).collect();
    assert_eq!(got, want, "skips must not lose or reorder intact records");
    assert_eq!(q.count(), 2);
    assert_eq!(q.names(), ["record 3", "record 11"]);

    // One more corrupt record than the budget: the third skip fails
    // loudly, naming everything quarantined so far.
    bytes[16 + 15 * 418 + 18 + 9] ^= 0x10; // record 15
    let q1 = Quarantine::new(0.1, 20);
    let mut r = ShardReader::new(Cursor::new(bytes), 64);
    let err = loop {
        match r.next_event().unwrap() {
            Some(RecordEvent::Record(_)) => {}
            Some(RecordEvent::Skipped { id, err }) => {
                if let Err(e) = q1.admit(format!("record {id}"), anyhow::anyhow!(err)) {
                    break e;
                }
            }
            None => panic!("third corrupt record never surfaced"),
        }
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("skip budget exceeded"), "{msg}");
    assert!(msg.contains("record 15"), "{msg}");
}

#[test]
fn truncated_shard_surfaces_clean_error() {
    let dir = tmpdir("trunc");
    let path = dir.join("s.rec");
    let mut w = ShardWriter::create(&path).unwrap();
    for i in 0..5u64 {
        w.append(i, 0, &vec![3u8; 1000]).unwrap();
    }
    w.finish().unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_dir_all(dir).ok();

    // Cut mid-payload of the last record.
    let cut = &bytes[..bytes.len() - 300];
    let mut r = ShardReader::new(Cursor::new(cut.to_vec()), 256);
    let err = loop {
        match r.next_record() {
            Ok(Some(_)) => {}
            Ok(None) => panic!("truncation not detected"),
            Err(e) => break e,
        }
    };
    assert!(format!("{err:#}").contains("truncated"), "{err:#}");

    // Shorter than even the shard header.
    let mut r = ShardReader::new(Cursor::new(bytes[..4].to_vec()), 256);
    assert!(r.next_record().is_err());
}

#[test]
fn chunked_streaming_through_storage_reader_matches_cursor() {
    // Same shard through the pipeline's Storage-backed reader adapter:
    // the access pattern differs (ranged reads), the records must not.
    let (bytes, lens) = awkward_shard("storage", 128);
    let m = MemStore::new();
    m.write("s.rec", bytes.clone());
    let store: Arc<dyn dpp::storage::Storage> = Arc::new(m);
    let reader = StorageReader::open(store, "s.rec").unwrap();
    let mut via_storage = ShardReader::new(reader, 200);
    let mut via_cursor = ShardReader::new(Cursor::new(bytes), 200);
    for want in &lens {
        let a = via_storage.next_record().unwrap().unwrap();
        let b = via_cursor.next_record().unwrap().unwrap();
        assert_eq!(a.payload.len(), *want);
        assert_eq!((a.id, a.label, a.payload), (b.id, b.label, b.payload));
    }
    assert!(via_storage.next_record().unwrap().is_none());
}
