//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check` runs a property over N seeded random cases; on failure it
//! retries with simpler inputs via the generator's built-in size
//! parameter and reports the failing seed so the case is reproducible.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xD0CA7 }
    }
}

/// Run `prop` over `cfg.cases` random cases.  `gen` receives an Rng and a
/// size hint growing from 1 to 100 across the run (small cases first, so
/// failures reproduce minimal-ish inputs).  Panics with the failing seed.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: PropConfig,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let size = 1 + case * 100 / cfg.cases.max(1);
        let input = gen(&mut rng, size);
        if !prop(&input) {
            panic!(
                "property `{name}` failed at case {case} (seed {seed:#x}, size {size}):\n{input:#?}"
            );
        }
    }
}

/// Like `check` but the property returns Result for richer failure info.
pub fn check_result<T: std::fmt::Debug, E: std::fmt::Debug>(
    name: &str,
    cfg: PropConfig,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> Result<(), E>,
) {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let size = 1 + case * 100 / cfg.cases.max(1);
        let input = gen(&mut rng, size);
        if let Err(e) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} (seed {seed:#x}, size {size}):\n\
                 input: {input:#?}\nerror: {e:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(
            "sum-commutes",
            PropConfig { cases: 10, ..Default::default() },
            |rng, _| (rng.gen_range(100) as i64, rng.gen_range(100) as i64),
            |&(a, b)| {
                n += 1;
                a + b == b + a
            },
        );
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-false",
            PropConfig { cases: 3, ..Default::default() },
            |rng, _| rng.gen_range(10),
            |_| false,
        );
    }

    #[test]
    fn sizes_grow() {
        let mut sizes = Vec::new();
        check(
            "size-grows",
            PropConfig { cases: 20, ..Default::default() },
            |_, size| size,
            |&s| {
                sizes.push(s);
                s >= 1 && s <= 100
            },
        );
        assert!(sizes.windows(2).all(|w| w[0] <= w[1]));
    }
}
