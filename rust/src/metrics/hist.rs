//! Log-bucketed (HDR-style) latency histograms.
//!
//! Fixed layout: 8 linear buckets below 8 ns, then 8 sub-buckets per
//! power-of-two octave across the rest of the u64 nanosecond range —
//! 496 buckets (~4 KB), so `record` is O(1), allocation-free after
//! construction, and any reported percentile is exact to within one
//! sub-bucket (≤ 12.5% relative error).  Non-atomic by design: spans are
//! aggregated *after* the per-worker trace rings are drained, on one
//! thread, so the histogram needs no synchronization.

use crate::util::json::Json;
use anyhow::{Context, Result};

/// Sub-buckets per octave (2^3 = 8).
const SUB_BITS: u32 = 3;
const SUB: u64 = 1 << SUB_BITS;
/// 8 linear buckets + 8 per octave for exponents 3..=63.
const BUCKETS: usize = (SUB as usize) * (64 - SUB_BITS as usize + 1);

/// Bucket index for a nanosecond value.  Monotone in `v` and total over
/// the full u64 range (`u64::MAX` lands in the last bucket).
fn bucket(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let e = 63 - v.leading_zeros(); // e >= SUB_BITS
    let oct = (e - SUB_BITS + 1) as usize;
    oct * SUB as usize + ((v >> (e - SUB_BITS)) - SUB) as usize
}

/// Inclusive upper bound of bucket `i` — what percentiles report, so a
/// quoted p99 is never below the true one.
fn bucket_high(i: usize) -> u64 {
    if i < SUB as usize {
        return i as u64;
    }
    let e = (i / SUB as usize) as u32 + SUB_BITS - 1;
    let sub = (i % SUB as usize) as u64;
    let low = (SUB + sub) << (e - SUB_BITS);
    low + ((1u64 << (e - SUB_BITS)) - 1)
}

/// One stage's latency distribution.
#[derive(Clone, Debug, PartialEq)]
pub struct LogHist {
    counts: Vec<u64>,
    total: u64,
    sum_ns: f64,
    max_ns: u64,
}

impl Default for LogHist {
    fn default() -> Self {
        LogHist::new()
    }
}

impl LogHist {
    pub fn new() -> LogHist {
        LogHist { counts: vec![0; BUCKETS], total: 0, sum_ns: 0.0, max_ns: 0 }
    }

    pub fn record(&mut self, ns: u64) {
        self.counts[bucket(ns)] += 1;
        self.total += 1;
        self.sum_ns += ns as f64;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn merge(&mut self, other: &LogHist) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ns / self.total as f64
        }
    }

    /// The latency at or below which `p` percent of recordings fall
    /// (bucket upper bound, clamped to the observed max).  `p` in [0,100].
    pub fn percentile(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_high(i).min(self.max_ns);
            }
        }
        self.max_ns
    }

    /// Sparse export: only occupied buckets, as `[index, count]` pairs.
    pub fn to_json(&self) -> Json {
        let buckets = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Json::arr([Json::num(i as f64), Json::num(c as f64)]));
        Json::obj(vec![
            ("buckets", Json::arr(buckets)),
            ("total", Json::num(self.total as f64)),
            ("sum_ns", Json::num(self.sum_ns)),
            ("max_ns", Json::num(self.max_ns as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<LogHist> {
        let mut h = LogHist::new();
        let buckets = j
            .get("buckets")
            .and_then(|b| b.as_arr())
            .context("histogram `buckets` must be an array")?;
        for pair in buckets {
            let i = pair
                .idx(0)
                .and_then(|v| v.as_usize())
                .context("histogram bucket index")?;
            let c = pair
                .idx(1)
                .and_then(|v| v.as_f64())
                .context("histogram bucket count")? as u64;
            anyhow::ensure!(i < BUCKETS, "histogram bucket index {i} out of range");
            h.counts[i] = c;
        }
        h.total = j.get("total").and_then(|v| v.as_f64()).context("histogram `total`")? as u64;
        h.sum_ns = j.get("sum_ns").and_then(|v| v.as_f64()).context("histogram `sum_ns`")?;
        h.max_ns = j.get("max_ns").and_then(|v| v.as_f64()).context("histogram `max_ns`")? as u64;
        Ok(h)
    }
}

/// Human-scale duration formatting shared by the summary printers.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.1}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucketing_is_monotone_and_total() {
        let probes = [
            0u64,
            1,
            7,
            8,
            9,
            15,
            16,
            17,
            100,
            1_000,
            65_535,
            65_536,
            1 << 30,
            u64::MAX / 2,
            u64::MAX,
        ];
        let mut last = 0usize;
        for &v in &probes {
            let b = bucket(v);
            assert!(b >= last, "bucket({v}) = {b} < {last}");
            assert!(b < BUCKETS);
            assert!(bucket_high(b) >= v, "high({b}) = {} < {v}", bucket_high(b));
            last = b;
        }
        assert_eq!(bucket(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_high(BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn percentiles_within_bucket_resolution() {
        let mut h = LogHist::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 10_000);
        for (p, want) in [(50.0, 5_000.0), (95.0, 9_500.0), (99.0, 9_900.0)] {
            let got = h.percentile(p) as f64;
            // Upper bucket bound: never below the true percentile, at
            // most one sub-bucket (12.5%) above.
            assert!(got >= want, "p{p}: {got} < {want}");
            assert!(got <= want * 1.126, "p{p}: {got} too far above {want}");
        }
        assert_eq!(h.percentile(100.0), 10_000);
        assert!((h.mean_ns() - 5_000.5).abs() < 1e-9);
    }

    #[test]
    fn empty_hist_is_quiet() {
        let h = LogHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn json_roundtrips() {
        let mut h = LogHist::new();
        for v in [3u64, 900, 900, 65_000, 1 << 40] {
            h.record(v);
        }
        let j = h.to_json();
        let back = LogHist::from_json(&Json::parse(&j.dump()).unwrap()).unwrap();
        assert_eq!(back, h);
        assert_eq!(back.percentile(50.0), h.percentile(50.0));
        assert!(LogHist::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn merge_adds_distributions() {
        let mut a = LogHist::new();
        let mut b = LogHist::new();
        let mut all = LogHist::new();
        for v in [10u64, 20, 30] {
            a.record(v);
            all.record(v);
        }
        for v in [1_000u64, 2_000] {
            b.record(v);
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a, all);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1_500.0), "1.5µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.20s");
    }
}
