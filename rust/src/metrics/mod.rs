//! Metrics: counters, busy-time tracking, utilization time series, and the
//! run report — the instrumentation behind the paper's Figs. 2–4.
//!
//! Submodules: `hist` (log-bucketed latency histograms), `trace`
//! (per-stage span tracing, Chrome trace-event export, and DS-Analyzer
//! stall attribution).

pub mod hist;
pub mod trace;

pub use hist::LogHist;
pub use trace::{Stage, StallAttribution, Tracer};

use crate::util::json::Json;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex};
use std::time::Instant;

/// Version stamp of `RunReport::to_json`'s shape.  Bump when a field is
/// added/renamed/removed so saved reports are self-describing (`dpp
/// trace` prints it).  v1 was the unstamped pre-tracing shape; v2 added
/// span histograms and stall attribution; v3 added the fault-tolerance
/// counters (retries, hedges, injected faults, quarantined samples);
/// v4 added the multi-tenant serve per-job sections (`jobs`).
pub const REPORT_SCHEMA_VERSION: u64 = 4;

/// Pipeline-wide event counters (all monotonic).
#[derive(Debug, Default)]
pub struct Counters {
    pub images_read: AtomicU64,
    pub images_decoded: AtomicU64,
    /// Samples served from the decoded-sample cache (decode not paid).
    pub decode_skipped: AtomicU64,
    /// 8x8 blocks dequant+IDCT'd on the CPU (any scale).
    pub idct_blocks: AtomicU64,
    /// Blocks entropy-skipped by the fused ROI decode (never IDCT'd).
    pub idct_blocks_skipped: AtomicU64,
    pub images_augmented: AtomicU64,
    pub batches_built: AtomicU64,
    pub batches_preprocessed_device: AtomicU64,
    pub train_steps: AtomicU64,
    pub bytes_read: AtomicU64,
}

macro_rules! counter_fns {
    ($($field:ident),*) => {
        impl Counters {
            // ordering: Relaxed — monotonic telemetry counters; readers
            // take approximate live values or read after the pipeline
            // threads have joined, so no other data hangs off them.
            $(pub fn $field(&self, n: u64) { self.$field.fetch_add(n, Ordering::Relaxed); })*
            pub fn snapshot(&self) -> CounterSnapshot {
                CounterSnapshot {
                    // ordering: Relaxed — approximate snapshot by design.
                    $($field: self.$field.load(Ordering::Relaxed),)*
                }
            }
        }
        #[derive(Clone, Copy, Debug, Default, PartialEq)]
        pub struct CounterSnapshot {
            $(pub $field: u64,)*
        }
    };
}

counter_fns!(
    images_read,
    images_decoded,
    decode_skipped,
    idct_blocks,
    idct_blocks_skipped,
    images_augmented,
    batches_built,
    batches_preprocessed_device,
    train_steps,
    bytes_read
);

/// Histogram of fused-decode scale choices per decoded image.  Index =
/// the scale exponent (0 → full res, 1 → 1/2, 2 → 1/4, 3 → 1/8): which
/// fraction of the corpus actually decoded at which resolution is what
/// tells you whether `--decode-scale auto` is buying anything.
#[derive(Debug, Default)]
pub struct ScaleHist {
    buckets: [AtomicU64; 4],
}

impl ScaleHist {
    pub fn record(&self, scale_log2: u8) {
        let i = (scale_log2 as usize).min(3);
        // ordering: Relaxed — telemetry histogram bump; read after join.
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> [u64; 4] {
        // ordering: Relaxed — approximate or post-join read; the four
        // buckets need no mutual consistency.
        [
            self.buckets[0].load(Ordering::Relaxed),
            self.buckets[1].load(Ordering::Relaxed),
            self.buckets[2].load(Ordering::Relaxed),
            self.buckets[3].load(Ordering::Relaxed),
        ]
    }
}

/// Level gauge with peak tracking — e.g. in-flight remote connections or
/// prefetch-queue depth.  `value` is the instantaneous level; `peak` is the
/// high-water mark since creation (what the run report cares about: did the
/// prefetcher actually keep N connections busy?).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
    peak: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment and return the new level.
    pub fn inc(&self) -> u64 {
        // ordering: Relaxed — the level is a statistic, not a guard: no
        // consumer dereferences data published by the gauge, and the
        // atomic RMW keeps the count exact at any ordering.  The peak
        // may lag the value by a moment (another thread can observe
        // `value` before this `fetch_max` lands), which the report
        // tolerates — peak is a high-water mark, not a fence.
        let v = self.value.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak.fetch_max(v, Ordering::Relaxed);
        v
    }

    /// Saturating decrement: an unmatched `dec` on a zero gauge must not
    /// wrap to `u64::MAX` (a wrapped level would also poison the peak on
    /// the next `inc`/`set`).
    pub fn dec(&self) {
        // ordering: Relaxed — see `inc`; the saturating CAS loop is
        // exact regardless of ordering.
        let _ = self
            .value
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
    }

    /// Set the level directly (for sampled depths like queue lengths).
    pub fn set(&self, v: u64) {
        // ordering: Relaxed — sampled level overwrite; see `inc`.
        self.value.store(v, Ordering::Relaxed);
        self.peak.fetch_max(v, Ordering::Relaxed);
    }

    pub fn value(&self) -> u64 {
        // ordering: Relaxed — approximate instantaneous read.
        self.value.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> u64 {
        // ordering: Relaxed — high-water mark read, usually post-join.
        self.peak.load(Ordering::Relaxed)
    }
}

/// Busy-time accumulator for a pool of workers (one per resource class).
///
/// Two denominator modes:
/// * **fixed** ([`BusyClock::new`]) — utilization over a window is
///   `busy_time / (window * n_workers)`, the historical behavior.
/// * **live** ([`BusyClock::new_live`], or after any [`set_workers`]
///   call) — the denominator is the *integral of the live worker count*
///   (worker-seconds of offered capacity), so `cpu_util` stays honest
///   while an elastic pool resizes: a pool that ran 10 s at 2 workers
///   then 10 s at 8 workers offers 100 worker-seconds, not `20 * 8`.
///
/// [`set_workers`]: BusyClock::set_workers
#[derive(Debug)]
pub struct BusyClock {
    busy_ns: AtomicU64,
    /// Pool size at creation (the fixed-mode denominator).
    pub workers: usize,
    cap: Mutex<CapState>,
}

#[derive(Debug)]
struct CapState {
    last: Instant,
    cur: usize,
    acc_secs: f64,
    live: bool,
}

impl BusyClock {
    pub fn new(workers: usize) -> Arc<Self> {
        Self::build(workers, false)
    }

    /// Live-denominator mode from the start (elastic pools).
    pub fn new_live(workers: usize) -> Arc<Self> {
        Self::build(workers, true)
    }

    fn build(workers: usize, live: bool) -> Arc<Self> {
        let workers = workers.max(1);
        Arc::new(BusyClock {
            busy_ns: AtomicU64::new(0),
            workers,
            cap: Mutex::new(CapState {
                last: Instant::now(),
                cur: workers,
                acc_secs: 0.0,
                live,
            }),
        })
    }

    pub fn track<R>(&self, f: impl FnOnce() -> R) -> R {
        let t = Instant::now();
        let r = f();
        // ordering: Relaxed — busy-time accumulator; the atomic RMW is
        // exact at any ordering and readers want a statistic, not a
        // synchronized view of the work `f` did.
        self.busy_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        r
    }

    pub fn add_secs(&self, secs: f64) {
        // ordering: Relaxed — accumulator, as in `track`.
        self.busy_ns.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
    }

    pub fn busy_secs(&self) -> f64 {
        // ordering: Relaxed — approximate utilization read.
        self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Record a pool resize (switches the clock into live mode): offered
    /// capacity accrues at the old size up to now, at `n` afterwards.
    pub fn set_workers(&self, n: usize) {
        // poison: every holder of `cap` (here through `utilization`)
        // only does float/int arithmetic under the lock — no panic path.
        let mut c = self.cap.lock().unwrap();
        let now = Instant::now();
        c.acc_secs += c.cur as f64 * now.duration_since(c.last).as_secs_f64();
        c.last = now;
        c.cur = n.max(1);
        c.live = true;
    }

    /// Pool size right now (== `workers` unless resized).
    pub fn current_workers(&self) -> usize {
        // poison: see `set_workers`.
        self.cap.lock().unwrap().cur
    }

    /// Worker-seconds of capacity the pool has offered since creation —
    /// the utilization denominator in live mode, and exactly
    /// `workers * elapsed` for a never-resized clock.
    pub fn capacity_secs(&self) -> f64 {
        // poison: see `set_workers`.
        let c = self.cap.lock().unwrap();
        c.acc_secs + c.cur as f64 * c.last.elapsed().as_secs_f64()
    }

    /// Mean utilization of the pool, in [0,1].  Fixed mode divides by
    /// `elapsed * workers`; live mode divides by the capacity integral
    /// (`elapsed` is ignored — the clock carries its own denominator).
    pub fn utilization(&self, elapsed: f64) -> f64 {
        let (live, cap) = {
            // poison: see `set_workers`.
            let c = self.cap.lock().unwrap();
            (c.live, c.acc_secs + c.cur as f64 * c.last.elapsed().as_secs_f64())
        };
        if live {
            if cap <= 0.0 {
                0.0
            } else {
                (self.busy_secs() / cap).min(1.0)
            }
        } else if elapsed <= 0.0 {
            0.0
        } else {
            (self.busy_secs() / (elapsed * self.workers as f64)).min(1.0)
        }
    }
}

/// Per-epoch wall-clock marks: each CPU worker stamps the epoch of every
/// sample it finishes, so `marks[e]` converges to the time the *last*
/// sample of epoch `e` left preprocessing.  The per-epoch durations are
/// what the decoded-sample cache is expected to shrink from epoch 2 on.
pub struct EpochClock {
    t0: Instant,
    marks: Mutex<Vec<f64>>,
}

impl EpochClock {
    pub fn new() -> Arc<Self> {
        Arc::new(EpochClock { t0: Instant::now(), marks: Mutex::new(Vec::new()) })
    }

    pub fn mark(&self, epoch: usize) {
        let t = self.t0.elapsed().as_secs_f64();
        // poison: Vec resize/index and float max only under this lock
        // (here and in `epoch_secs`).
        let mut marks = self.marks.lock().unwrap();
        if marks.len() <= epoch {
            marks.resize(epoch + 1, 0.0);
        }
        marks[epoch] = marks[epoch].max(t);
    }

    /// Duration of each epoch: the gap between consecutive last-sample
    /// times (epoch 0 is measured from the clock's creation).
    pub fn epoch_secs(&self) -> Vec<f64> {
        // poison: see `mark`.
        let marks = self.marks.lock().unwrap();
        marks
            .iter()
            .enumerate()
            .map(|(e, &t)| if e == 0 { t } else { (t - marks[e - 1]).max(0.0) })
            .collect()
    }
}

/// One utilization sample (Fig. 4 row): time, cpu util, device util, I/O MB/s.
#[derive(Clone, Copy, Debug)]
pub struct UtilSample {
    pub t: f64,
    pub cpu: f64,
    pub device: f64,
    pub io_mbps: f64,
}

/// Collects utilization samples by diffing busy clocks + byte counters.
/// Per-window utilization divides busy-time deltas by *capacity* deltas
/// (worker-seconds offered in the window), so the trace stays correct
/// while an elastic pool resizes; for a fixed pool the capacity delta is
/// exactly `dt * workers`, the historical formula.
pub struct UtilSampler {
    t0: Instant,
    last_t: f64,
    last_cpu_busy: f64,
    last_dev_busy: f64,
    last_cpu_cap: f64,
    last_dev_cap: f64,
    last_bytes: u64,
    pub samples: Vec<UtilSample>,
}

impl UtilSampler {
    pub fn new() -> Self {
        UtilSampler {
            t0: Instant::now(),
            last_t: 0.0,
            last_cpu_busy: 0.0,
            last_dev_busy: 0.0,
            last_cpu_cap: 0.0,
            last_dev_cap: 0.0,
            last_bytes: 0,
            samples: Vec::new(),
        }
    }

    pub fn sample(&mut self, cpu: &BusyClock, device: &BusyClock, bytes_read: u64) {
        let t = self.t0.elapsed().as_secs_f64();
        let dt = (t - self.last_t).max(1e-9);
        let cpu_busy = cpu.busy_secs();
        let dev_busy = device.busy_secs();
        let cpu_cap = cpu.capacity_secs();
        let dev_cap = device.capacity_secs();
        self.samples.push(UtilSample {
            t,
            cpu: ((cpu_busy - self.last_cpu_busy) / (cpu_cap - self.last_cpu_cap).max(1e-9))
                .min(1.0),
            device: ((dev_busy - self.last_dev_busy) / (dev_cap - self.last_dev_cap).max(1e-9))
                .min(1.0),
            io_mbps: (bytes_read - self.last_bytes) as f64 / dt / 1e6,
        });
        self.last_t = t;
        self.last_cpu_busy = cpu_busy;
        self.last_dev_busy = dev_busy;
        self.last_cpu_cap = cpu_cap;
        self.last_dev_cap = dev_cap;
        self.last_bytes = bytes_read;
    }
}

impl Default for UtilSampler {
    fn default() -> Self {
        Self::new()
    }
}

/// One tenant job's report section in serve mode: its own goodput,
/// cache behavior, and fault counters — the per-job failure domain the
/// isolation gates assert on.  Single runs carry an empty `jobs` list.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JobSection {
    pub name: String,
    /// Terminal state: `done`, `left`, `rejected`, or `failed: <why>`.
    pub status: String,
    pub epochs_done: u64,
    /// Steady-state prep-cache hit rate (final completed epoch).
    pub hit_rate: f64,
    /// Items per scheduler round in the final completed epoch.
    pub goodput_ips: f64,
    pub retries: u64,
    pub hedges_won: u64,
    pub faults_injected: u64,
    pub samples_skipped: u64,
}

impl JobSection {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("status", Json::str(&self.status)),
            ("epochs_done", Json::num(self.epochs_done as f64)),
            ("hit_rate", Json::num(self.hit_rate)),
            ("goodput_ips", Json::num(self.goodput_ips)),
            ("retries", Json::num(self.retries as f64)),
            ("hedges_won", Json::num(self.hedges_won as f64)),
            ("faults_injected", Json::num(self.faults_injected as f64)),
            ("samples_skipped", Json::num(self.samples_skipped as f64)),
        ])
    }
}

/// Everything a pipeline run reports (printed and/or JSON-exported).
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub images: u64,
    pub steps: u64,
    pub wall_secs: f64,
    /// Preprocessing throughput (images fully preprocessed / sec).
    pub preproc_ips: f64,
    /// End-to-end training throughput (images trained / sec).
    pub train_ips: f64,
    pub cpu_util: f64,
    pub device_util: f64,
    pub io_bytes: u64,
    pub losses: Vec<(u64, f32)>,
    pub util_trace: Vec<UtilSample>,
    /// Backpressure: seconds producers blocked / consumers starved.
    pub producer_blocked_secs: f64,
    pub consumer_starved_secs: f64,
    /// High-water mark of in-flight remote-store connections (0 when the
    /// run used a local tier) — did the prefetcher keep the pool busy?
    pub net_in_flight_peak: u64,
    /// Decoded-sample cache hit rate over the whole run (0 when disabled).
    pub prep_cache_hit_rate: f64,
    /// Samples whose decode was skipped via the decoded-sample cache.
    pub decode_skipped: u64,
    /// 8x8 blocks dequant+IDCT'd on the CPU; with the fused ROI decode
    /// this is the per-image block work training actually paid for.
    pub idct_blocks: u64,
    /// Blocks the fused ROI decode entropy-skipped (decode work saved).
    pub idct_blocks_skipped: u64,
    /// Decodes per fused scale (index = exponent: 1/1, 1/2, 1/4, 1/8).
    pub decode_scale_hist: [u64; 4],
    /// Wall-clock per epoch (preprocessing completion times); the
    /// decoded-sample cache should make entries 2+ beat entry 1.
    pub epoch_secs: Vec<f64>,
    /// Images actually fetched from storage (counted at the read for
    /// both methods: the record stream callback and the raw worker
    /// read).  Prep-cache hits under the raw method skip the read, so
    /// this can run below `images`.
    pub images_read: u64,
    /// Whether the run used `--workers auto` (the elastic controller).
    pub workers_auto: bool,
    /// CPU-stage pool size when the run ended (the elastic controller's
    /// converged count; equals `cpu_workers` for fixed pools).
    pub workers_final: usize,
    /// Every pool resize as `(secs_since_start, new_worker_count)`,
    /// starting with the spawn size at t≈0.
    pub workers_timeline: Vec<(f64, usize)>,
    /// Occupancy high-water marks of the three pipeline queues
    /// (work / sample / batch) — did backpressure actually engage?
    pub work_queue_peak: u64,
    pub sample_queue_peak: u64,
    pub batch_queue_peak: u64,
    /// Slab-pool telemetry (`--slab-pool`): batch arenas served from the
    /// recycle free list vs freshly allocated.  At steady state hits
    /// dominate and grows stays at the warm-up handful.
    pub slab_hits: u64,
    pub slab_grows: u64,
    /// Heap bytes allocated process-wide during the run (the counting
    /// allocator shim) — the A/B number `--slab-pool off` vs `auto`
    /// moves.  Whole-process, so it includes runtime/engine allocations.
    pub bytes_alloc_hot: u64,
    /// DS-Analyzer stall attribution: wall-clock shares of device
    /// compute, fetch (storage) stall, and prep (CPU) stall.  Always
    /// computed (tracing not required); the three sum to 1.
    pub stall_fetch: f64,
    pub stall_prep: f64,
    pub stall_compute: f64,
    /// Storage reads re-attempted after a transient failure (raw-path
    /// inline retries + prefetcher part re-issues).
    pub retries: u64,
    /// Hedged duplicate range-GETs that beat the original request.
    pub hedges_won: u64,
    /// Faults the `--faults` layer injected (0 when faults are off).
    pub faults_injected: u64,
    /// Undecodable samples quarantined under `--max-skip-rate` instead
    /// of failing the run.
    pub samples_skipped: u64,
    /// Per-stage latency histograms from the span tracer, in pipeline
    /// order (empty when the run was not traced).
    pub stage_hists: Vec<(String, LogHist)>,
    /// Per-job sections in serve mode (schema v4); empty for single
    /// runs, so v3 consumers that ignore unknown keys keep working.
    pub jobs: Vec<JobSection>,
}

/// Render the per-epoch wall times, eliding the middle beyond 8 epochs
/// so a 100-epoch run keeps a one-line summary.
fn format_epochs(secs: &[f64]) -> String {
    let fmt = |s: &f64| format!("{s:.2}s");
    if secs.len() <= 8 {
        return secs.iter().map(fmt).collect::<Vec<_>>().join(", ");
    }
    let head: Vec<String> = secs[..4].iter().map(fmt).collect();
    let tail: Vec<String> = secs[secs.len() - 4..].iter().map(fmt).collect();
    format!("{}, .. {} elided .., {}", head.join(", "), secs.len() - 8, tail.join(", "))
}

impl RunReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::num(REPORT_SCHEMA_VERSION as f64)),
            ("images", Json::num(self.images as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("preproc_ips", Json::num(self.preproc_ips)),
            ("train_ips", Json::num(self.train_ips)),
            ("cpu_util", Json::num(self.cpu_util)),
            ("device_util", Json::num(self.device_util)),
            ("io_bytes", Json::num(self.io_bytes as f64)),
            ("producer_blocked_secs", Json::num(self.producer_blocked_secs)),
            ("consumer_starved_secs", Json::num(self.consumer_starved_secs)),
            ("net_in_flight_peak", Json::num(self.net_in_flight_peak as f64)),
            ("prep_cache_hit_rate", Json::num(self.prep_cache_hit_rate)),
            ("decode_skipped", Json::num(self.decode_skipped as f64)),
            ("idct_blocks", Json::num(self.idct_blocks as f64)),
            ("idct_blocks_skipped", Json::num(self.idct_blocks_skipped as f64)),
            (
                "decode_scale_hist",
                Json::arr(self.decode_scale_hist.iter().map(|&n| Json::num(n as f64))),
            ),
            (
                "epoch_secs",
                Json::arr(self.epoch_secs.iter().map(|&s| Json::num(s))),
            ),
            ("images_read", Json::num(self.images_read as f64)),
            ("workers_auto", Json::Bool(self.workers_auto)),
            ("workers_final", Json::num(self.workers_final as f64)),
            (
                "workers_timeline",
                Json::arr(self.workers_timeline.iter().map(|(t, n)| {
                    Json::arr(vec![Json::num(*t), Json::num(*n as f64)])
                })),
            ),
            ("work_queue_peak", Json::num(self.work_queue_peak as f64)),
            ("sample_queue_peak", Json::num(self.sample_queue_peak as f64)),
            ("batch_queue_peak", Json::num(self.batch_queue_peak as f64)),
            ("slab_hits", Json::num(self.slab_hits as f64)),
            ("slab_grows", Json::num(self.slab_grows as f64)),
            ("bytes_alloc_hot", Json::num(self.bytes_alloc_hot as f64)),
            ("stall_fetch", Json::num(self.stall_fetch)),
            ("stall_prep", Json::num(self.stall_prep)),
            ("stall_compute", Json::num(self.stall_compute)),
            ("retries", Json::num(self.retries as f64)),
            ("hedges_won", Json::num(self.hedges_won as f64)),
            ("faults_injected", Json::num(self.faults_injected as f64)),
            ("samples_skipped", Json::num(self.samples_skipped as f64)),
            (
                "stage_hists",
                Json::arr(self.stage_hists.iter().map(|(stage, h)| {
                    Json::obj(vec![("stage", Json::str(stage)), ("hist", h.to_json())])
                })),
            ),
            ("jobs", Json::arr(self.jobs.iter().map(|j| j.to_json()))),
            (
                "losses",
                Json::arr(self.losses.iter().map(|(s, l)| {
                    Json::arr(vec![Json::num(*s as f64), Json::num(*l as f64)])
                })),
            ),
            (
                "util_trace",
                Json::arr(self.util_trace.iter().map(|u| {
                    Json::obj(vec![
                        ("t", Json::num(u.t)),
                        ("cpu", Json::num(u.cpu)),
                        ("device", Json::num(u.device)),
                        ("io_mbps", Json::num(u.io_mbps)),
                    ])
                })),
            ),
        ])
    }

    pub fn print_summary(&self, tag: &str) {
        println!(
            "[{tag}] images={} steps={} wall={:.2}s preproc={:.1} img/s train={:.1} img/s \
             cpu={:.0}% dev={:.0}% io={} blocked={:.2}s starved={:.2}s",
            self.images,
            self.steps,
            self.wall_secs,
            self.preproc_ips,
            self.train_ips,
            self.cpu_util * 100.0,
            self.device_util * 100.0,
            crate::util::human_bytes(self.io_bytes),
            self.producer_blocked_secs,
            self.consumer_starved_secs,
        );
        println!(
            "  {}",
            StallAttribution {
                fetch: self.stall_fetch,
                prep: self.stall_prep,
                compute: self.stall_compute,
            }
            .summary_line()
        );
        if !self.stage_hists.is_empty() {
            for (stage, h) in &self.stage_hists {
                println!(
                    "  span {:<18} n={:<8} p50={:<9} p95={:<9} p99={}",
                    stage,
                    h.count(),
                    hist::fmt_ns(h.percentile(50.0) as f64),
                    hist::fmt_ns(h.percentile(95.0) as f64),
                    hist::fmt_ns(h.percentile(99.0) as f64),
                );
            }
        }
        if self.net_in_flight_peak > 0 {
            println!("  remote store: peak {} connections in flight", self.net_in_flight_peak);
        }
        // Print for every auto run — a pool that converged without ever
        // resizing is exactly the case the user needs to see — and for
        // any run whose pool moved.
        if self.workers_auto || self.workers_timeline.len() > 1 {
            let steps: Vec<String> = self
                .workers_timeline
                .iter()
                .map(|(t, n)| format!("{n}@{t:.1}s"))
                .collect();
            println!(
                "  elastic workers: final {}, timeline [{}], queue peaks work={} sample={} batch={}",
                self.workers_final,
                steps.join(" -> "),
                self.work_queue_peak,
                self.sample_queue_peak,
                self.batch_queue_peak,
            );
        }
        // Also printed when only the fractional scale engaged (an
        // admission-dominated run skips no blocks yet still decodes at
        // 1/2^k — the realized-scale readout must stay visible).
        if self.idct_blocks_skipped > 0 || self.decode_scale_hist[1..].iter().any(|&n| n > 0) {
            let total = self.idct_blocks + self.idct_blocks_skipped;
            let h = self.decode_scale_hist;
            println!(
                "  fused decode: {} of {} blocks IDCT'd ({:.1}%), scales [1/1:{} 1/2:{} 1/4:{} 1/8:{}]",
                self.idct_blocks,
                total,
                self.idct_blocks as f64 / total.max(1) as f64 * 100.0,
                h[0],
                h[1],
                h[2],
                h[3],
            );
        }
        if self.slab_hits + self.slab_grows > 0 {
            println!(
                "  slab pool: {} arena reuses, {} grows, {} heap-allocated during run",
                self.slab_hits,
                self.slab_grows,
                crate::util::human_bytes(self.bytes_alloc_hot),
            );
        }
        if self.decode_skipped > 0 || self.prep_cache_hit_rate > 0.0 {
            println!(
                "  prep cache: hit rate {:.1}%, {} decodes skipped, epochs [{}]",
                self.prep_cache_hit_rate * 100.0,
                self.decode_skipped,
                format_epochs(&self.epoch_secs)
            );
        }
        if self.retries + self.hedges_won + self.faults_injected + self.samples_skipped > 0 {
            println!(
                "  fault plane: {} faults injected, {} retries, {} hedges won, {} samples quarantined",
                self.faults_injected,
                self.retries,
                self.hedges_won,
                self.samples_skipped,
            );
        }
        for j in &self.jobs {
            println!(
                "  job {:<12} {:<10} epochs {} hit {:.3} goodput {:.1} \
                 retries {} hedges {} faults {} skipped {}",
                j.name,
                j.status.split(':').next().unwrap_or(&j.status),
                j.epochs_done,
                j.hit_rate,
                j.goodput_ips,
                j.retries,
                j.hedges_won,
                j.faults_injected,
                j.samples_skipped,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_accumulate() {
        let c = Counters::default();
        c.images_read(3);
        c.images_read(2);
        c.train_steps(1);
        let s = c.snapshot();
        assert_eq!(s.images_read, 5);
        assert_eq!(s.train_steps, 1);
        assert_eq!(s.images_decoded, 0);
    }

    #[test]
    fn gauge_tracks_level_and_peak() {
        let g = Gauge::new();
        assert_eq!(g.inc(), 1);
        assert_eq!(g.inc(), 2);
        g.dec();
        assert_eq!(g.value(), 1);
        assert_eq!(g.peak(), 2);
        g.set(7);
        g.set(3);
        assert_eq!(g.value(), 3);
        assert_eq!(g.peak(), 7);
    }

    /// Regression: `dec` on a zero gauge used to `fetch_sub` and wrap to
    /// `u64::MAX`; the next `inc` then pushed the poisoned level into
    /// `peak` forever.
    #[test]
    fn gauge_dec_saturates_at_zero() {
        let g = Gauge::new();
        g.dec();
        assert_eq!(g.value(), 0, "dec on empty gauge must saturate");
        assert_eq!(g.inc(), 1);
        assert_eq!(g.peak(), 1, "peak must not see a wrapped level");
        g.dec();
        g.dec();
        g.dec();
        assert_eq!(g.value(), 0);
        assert_eq!(g.inc(), 1);
        assert_eq!(g.peak(), 1);
    }

    #[test]
    fn busy_clock_tracks_time() {
        let b = BusyClock::new(2);
        b.track(|| std::thread::sleep(Duration::from_millis(30)));
        let busy = b.busy_secs();
        assert!(busy >= 0.028, "{busy}");
        // Pool of 2 workers over 0.1s elapsed: utilization ~ busy/(0.1*2).
        let u = b.utilization(0.1);
        assert!((u - busy / 0.2).abs() < 1e-9);
    }

    /// Live-denominator mode: utilization divides by the capacity
    /// integral, so a resize mid-run changes the denominator from the
    /// resize moment on — not retroactively.  Only *floor* bounds and
    /// relational checks here: `thread::sleep` never undersleeps but can
    /// overshoot arbitrarily on loaded CI, so upper bounds would flake.
    #[test]
    fn busy_clock_live_denominator_tracks_resizes() {
        let b = BusyClock::new_live(2);
        std::thread::sleep(Duration::from_millis(30));
        let cap1 = b.capacity_secs();
        assert!(cap1 >= 2.0 * 0.03, "2 workers x >=30ms: {cap1}");
        b.set_workers(8);
        std::thread::sleep(Duration::from_millis(30));
        let cap2 = b.capacity_secs();
        assert_eq!(b.current_workers(), 8);
        assert!(
            cap2 - cap1 >= 8.0 * 0.03,
            "post-resize capacity must accrue at 8 worker-secs/sec: {cap1} -> {cap2}"
        );
        // Utilization is busy/capacity in live mode (elapsed ignored):
        // with busy frozen, more capacity strictly dilutes it.
        b.add_secs(0.05);
        let u1 = b.utilization(123.0);
        let cap = b.capacity_secs();
        assert!((u1 - (0.05 / cap).min(1.0)).abs() < 0.05, "u {u1} vs cap {cap}");
        std::thread::sleep(Duration::from_millis(30));
        let u2 = b.utilization(123.0);
        assert!(u2 < u1, "capacity grew, busy fixed: {u1} -> {u2}");
        // A fixed clock's capacity accrues at its constant size.
        let f = BusyClock::new(2);
        std::thread::sleep(Duration::from_millis(20));
        assert!(f.capacity_secs() >= 2.0 * 0.02);
    }

    #[test]
    fn busy_clock_set_workers_flips_fixed_clock_to_live() {
        let b = BusyClock::new(2);
        b.add_secs(0.1);
        // Fixed mode: denominator is elapsed * workers.
        assert!((b.utilization(0.1) - 0.5).abs() < 1e-9);
        b.set_workers(2);
        // Live mode: denominator is the capacity integral (tiny so far),
        // so the same busy time now saturates.
        assert!(b.utilization(0.1) > 0.9);
    }

    #[test]
    fn sampler_diffs_windows() {
        let cpu = BusyClock::new(1);
        let dev = BusyClock::new(1);
        let mut s = UtilSampler::new();
        cpu.add_secs(0.5);
        std::thread::sleep(Duration::from_millis(10));
        s.sample(&cpu, &dev, 1_000_000);
        assert_eq!(s.samples.len(), 1);
        assert!(s.samples[0].cpu > 0.0);
        assert_eq!(s.samples[0].device, 0.0);
        assert!(s.samples[0].io_mbps > 0.0);
        // Second window with no new activity reads ~zero.
        std::thread::sleep(Duration::from_millis(10));
        s.sample(&cpu, &dev, 1_000_000);
        assert!(s.samples[1].cpu < 0.2);
        assert_eq!(s.samples[1].io_mbps, 0.0);
    }

    #[test]
    fn epoch_clock_tracks_last_sample_per_epoch() {
        let c = EpochClock::new();
        c.mark(0);
        std::thread::sleep(Duration::from_millis(20));
        c.mark(0); // later sample of the same epoch moves the mark
        std::thread::sleep(Duration::from_millis(20));
        c.mark(1);
        let secs = c.epoch_secs();
        assert_eq!(secs.len(), 2);
        assert!(secs[0] >= 0.018, "{secs:?}");
        assert!(secs[1] >= 0.018, "{secs:?}");
        // Marks arriving out of order never produce negative durations.
        c.mark(0);
        assert!(c.epoch_secs().iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn report_json_roundtrips() {
        let mut r = RunReport::default();
        r.images = 10;
        r.losses.push((1, 2.5));
        r.idct_blocks = 75;
        r.idct_blocks_skipped = 117;
        r.decode_scale_hist = [3, 2, 1, 0];
        r.slab_hits = 40;
        r.slab_grows = 5;
        r.bytes_alloc_hot = 1 << 20;
        let j = r.to_json();
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.req("images").as_usize(), Some(10));
        assert_eq!(parsed.req("losses").idx(0).unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(parsed.req("idct_blocks").as_usize(), Some(75));
        assert_eq!(parsed.req("idct_blocks_skipped").as_usize(), Some(117));
        assert_eq!(parsed.req("decode_scale_hist").idx(1).unwrap().as_usize(), Some(2));
        assert_eq!(parsed.req("slab_hits").as_usize(), Some(40));
        assert_eq!(parsed.req("slab_grows").as_usize(), Some(5));
        assert_eq!(parsed.req("bytes_alloc_hot").as_usize(), Some(1 << 20));
    }

    /// Field-parity guard: the exhaustive literal below (no
    /// `..Default::default()`) fails to compile when a field is added to
    /// `RunReport`, forcing this test — and therefore `to_json` — to be
    /// updated in the same change; the key-count assert then catches a
    /// field that was added here but not serialized.
    #[test]
    fn report_serializes_every_field() {
        let mut h = LogHist::new();
        h.record(1_000);
        let r = RunReport {
            images: 1,
            steps: 2,
            wall_secs: 3.5,
            preproc_ips: 4.5,
            train_ips: 5.5,
            cpu_util: 0.25,
            device_util: 0.75,
            io_bytes: 6,
            losses: vec![(1, 2.5)],
            util_trace: vec![UtilSample { t: 0.5, cpu: 0.1, device: 0.2, io_mbps: 3.0 }],
            producer_blocked_secs: 7.5,
            consumer_starved_secs: 8.5,
            net_in_flight_peak: 9,
            prep_cache_hit_rate: 0.125,
            decode_skipped: 10,
            idct_blocks: 11,
            idct_blocks_skipped: 12,
            decode_scale_hist: [13, 14, 15, 16],
            epoch_secs: vec![17.0, 18.0],
            images_read: 19,
            workers_auto: true,
            workers_final: 20,
            workers_timeline: vec![(0.0, 21)],
            work_queue_peak: 22,
            sample_queue_peak: 23,
            batch_queue_peak: 24,
            slab_hits: 25,
            slab_grows: 26,
            bytes_alloc_hot: 27,
            stall_fetch: 0.3,
            stall_prep: 0.2,
            stall_compute: 0.5,
            retries: 28,
            hedges_won: 29,
            faults_injected: 30,
            samples_skipped: 31,
            stage_hists: vec![("decode".to_string(), h)],
            jobs: vec![JobSection {
                name: "tenant_a".into(),
                status: "done".into(),
                epochs_done: 32,
                hit_rate: 0.875,
                goodput_ips: 33.5,
                retries: 34,
                hedges_won: 35,
                faults_injected: 36,
                samples_skipped: 37,
            }],
        };
        let j = Json::parse(&r.to_json().dump()).unwrap();
        let keys = j.as_obj().unwrap();
        // 38 struct fields + schema_version.
        assert_eq!(keys.len(), 39, "RunReport field not serialized: {:?}", keys.keys());
        assert_eq!(j.req("schema_version").as_usize(), Some(REPORT_SCHEMA_VERSION as usize));
        // Spot-check the distinctive values land under the right keys.
        assert_eq!(j.req("retries").as_usize(), Some(28));
        assert_eq!(j.req("hedges_won").as_usize(), Some(29));
        assert_eq!(j.req("faults_injected").as_usize(), Some(30));
        assert_eq!(j.req("samples_skipped").as_usize(), Some(31));
        assert_eq!(j.req("stall_fetch").as_f64(), Some(0.3));
        assert_eq!(j.req("stall_prep").as_f64(), Some(0.2));
        assert_eq!(j.req("stall_compute").as_f64(), Some(0.5));
        let row = j.req("stage_hists").idx(0).unwrap();
        assert_eq!(row.req("stage").as_str(), Some("decode"));
        assert_eq!(
            LogHist::from_json(row.req("hist")).unwrap().count(),
            1,
            "stage hist must round-trip"
        );
        assert_eq!(j.req("bytes_alloc_hot").as_usize(), Some(27));
        assert_eq!(j.req("workers_auto").as_bool(), Some(true));
        // The serve section round-trips field-for-field.
        let job = j.req("jobs").idx(0).unwrap();
        assert_eq!(job.req("name").as_str(), Some("tenant_a"));
        assert_eq!(job.req("status").as_str(), Some("done"));
        assert_eq!(job.req("epochs_done").as_usize(), Some(32));
        assert_eq!(job.req("hit_rate").as_f64(), Some(0.875));
        assert_eq!(job.req("goodput_ips").as_f64(), Some(33.5));
        assert_eq!(job.req("retries").as_usize(), Some(34));
        assert_eq!(job.req("hedges_won").as_usize(), Some(35));
        assert_eq!(job.req("faults_injected").as_usize(), Some(36));
        assert_eq!(job.req("samples_skipped").as_usize(), Some(37));
        assert_eq!(job.as_obj().unwrap().len(), 9, "JobSection field not serialized");
    }

    #[test]
    fn epoch_list_elides_the_middle_beyond_eight() {
        let short: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let s = format_epochs(&short);
        assert!(!s.contains("elided"), "{s}");
        assert_eq!(s.matches("s").count(), 8, "{s}");
        let long: Vec<f64> = (0..30).map(|i| i as f64).collect();
        let s = format_epochs(&long);
        assert!(s.contains("0.00s"), "{s}");
        assert!(s.contains("29.00s"), "{s}");
        assert!(s.contains("22 elided"), "{s}");
        assert!(!s.contains("15.00s"), "middle must be elided: {s}");
        assert_eq!(format_epochs(&[]), "");
    }

    #[test]
    fn scale_hist_buckets_by_exponent() {
        let h = ScaleHist::default();
        h.record(0);
        h.record(0);
        h.record(1);
        h.record(3);
        h.record(9); // defensive clamp into the last bucket
        assert_eq!(h.snapshot(), [2, 1, 0, 2]);
    }
}
