//! Per-stage span tracing with lock-free per-worker rings.
//!
//! Each worker thread records sampled `(stage, start, duration, sample,
//! epoch)` spans into its own fixed-capacity ring of atomic slots: a
//! recording is four `Relaxed` slot stores published by one `Release`
//! cursor store, no locks and no allocation on the hot path (the ring
//! registers itself under a mutex once per thread).  An `off` tracer is a `None` — every hook is a
//! single branch, so untraced runs pay nothing.  When the ring wraps the
//! oldest spans are overwritten and counted as dropped.
//!
//! Two consumers, both offline (after the workers have joined, so the
//! `Relaxed` writes are ordered by the joins):
//! * [`stage_hists`] — per-stage [`LogHist`] latency distributions for
//!   the run report (p50/p95/p99 in `dpp trace` / `print_summary`).
//! * [`chrome_trace`] — Chrome trace-event JSON (open in Perfetto or
//!   chrome://tracing): one track per worker thread, a complete (`"X"`)
//!   event per span, plus counter (`"C"`) tracks for queue depths.  The
//!   DES emits the same format (`sim::des::simulate_traced`), so engine
//!   and simulated timelines diff visually.
//!
//! [`StallAttribution`] is the DS-Analyzer-vocabulary summary (Mohan et
//! al.): wall-clock decomposed into fetch-stall / prep-stall / compute
//! shares that sum to 1.

use super::hist::{fmt_ns, LogHist};
use crate::util::json::Json;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex};
use anyhow::{bail, ensure, Context, Result};
use std::cell::RefCell;
use std::time::Instant;

/// Pipeline stations a span can belong to.  The discriminant is packed
/// into the ring slot, so variants must stay dense from 0.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Storage read: a prefetcher ranged GET or a raw-method file read.
    Fetch = 0,
    /// Entropy walk + dequant/IDCT (whole decode under `cpu`).
    Decode,
    /// CPU-side augmentation (crop/resize/flip/normalize).
    Augment,
    /// Decoded-sample cache hit re-entry (rescale + augment).
    CacheHit,
    /// The DES's aggregate preprocessing station (decode+augment).
    Prep,
    /// Batcher collate/seal.
    Collate,
    /// One training step on the device.
    Train,
    /// Blocked pushing into / pulling from the three pipeline queues.
    WorkSendWait,
    WorkRecvWait,
    SampleSendWait,
    SampleRecvWait,
    BatchSendWait,
    BatchRecvWait,
    /// Fault recovery: a re-issued (retry) or hedged duplicate read —
    /// first attempts stay [`Stage::Fetch`], so the trace separates
    /// recovery work from steady-state fetching.
    Retry,
}

impl Stage {
    pub const COUNT: usize = 14;
    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::Fetch,
        Stage::Decode,
        Stage::Augment,
        Stage::CacheHit,
        Stage::Prep,
        Stage::Collate,
        Stage::Train,
        Stage::WorkSendWait,
        Stage::WorkRecvWait,
        Stage::SampleSendWait,
        Stage::SampleRecvWait,
        Stage::BatchSendWait,
        Stage::BatchRecvWait,
        Stage::Retry,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Fetch => "fetch",
            Stage::Decode => "decode",
            Stage::Augment => "augment",
            Stage::CacheHit => "cache_hit",
            Stage::Prep => "prep",
            Stage::Collate => "collate",
            Stage::Train => "train",
            Stage::WorkSendWait => "work.send_wait",
            Stage::WorkRecvWait => "work.recv_wait",
            Stage::SampleSendWait => "sample.send_wait",
            Stage::SampleRecvWait => "sample.recv_wait",
            Stage::BatchSendWait => "batch.send_wait",
            Stage::BatchRecvWait => "batch.recv_wait",
            Stage::Retry => "retry",
        }
    }

    pub fn from_u8(b: u8) -> Option<Stage> {
        Stage::ALL.get(b as usize).copied()
    }
}

/// Default per-thread ring capacity, in spans (64 Ki × 32 B = 2 MiB).
pub const DEFAULT_RING_CAP: usize = 1 << 16;

/// One thread's span ring: 4 atomic words per span
/// `[start_ns, dur_ns, sample, stage | epoch << 8]`.  Only the owning
/// thread writes; `drain` reads after the writers have joined.
struct Ring {
    label: String,
    slots: Vec<AtomicU64>,
    cursor: AtomicU64,
}

impl Ring {
    fn new(label: String, cap: usize) -> Ring {
        let mut slots = Vec::with_capacity(cap * 4);
        slots.resize_with(cap * 4, || AtomicU64::new(0));
        Ring { label, slots, cursor: AtomicU64::new(0) }
    }

    fn cap(&self) -> usize {
        self.slots.len() / 4
    }

    fn push(&self, start_ns: u64, dur_ns: u64, sample: u64, meta: u64) {
        // Single-writer ring: only the owning thread pushes, so the
        // cursor can be read plainly and *published after* the slot
        // words.  Regression note (PR 7): this used to bump the cursor
        // *before* the four slot stores, all Relaxed — a concurrent
        // drain could then count a span whose words were not yet
        // written and read a torn half-old/half-new record.  Writing
        // the slots first and publishing with a Release store (paired
        // with drain's Acquire load) makes every span the cursor admits
        // fully written.  Wrapped (overwritten) slots still require the
        // documented drain-after-join contract.
        // ordering: Relaxed — own thread's previous store; no other
        // thread ever writes the cursor.
        let idx = self.cursor.load(Ordering::Relaxed);
        let pos = (idx as usize % self.cap()) * 4;
        // ordering: Relaxed — slot words are ordered by the Release
        // cursor publication below, not individually.
        self.slots[pos].store(start_ns, Ordering::Relaxed);
        self.slots[pos + 1].store(dur_ns, Ordering::Relaxed);
        self.slots[pos + 2].store(sample, Ordering::Relaxed);
        self.slots[pos + 3].store(meta, Ordering::Relaxed);
        // ordering: Release — publishes the slot words above to any
        // drain that Acquire-loads a cursor value covering this span.
        self.cursor.store(idx + 1, Ordering::Release);
    }
}

/// Process-unique tracer ids, never reused (ids start at 1 so the
/// thread-local default of 0 can never collide).  A pointer-based key
/// would be ABA-prone: a new tracer allocated where a dropped one lived
/// would silently inherit the old thread-local ring.
static TRACER_IDS: AtomicU64 = AtomicU64::new(1);

struct TracerInner {
    id: u64,
    t0: Instant,
    /// Keep every `stride`-th span per (thread, stage) — `1/sample_rate`.
    stride: u64,
    ring_cap: usize,
    rings: Mutex<Vec<Arc<Ring>>>,
}

#[derive(Default)]
struct TlState {
    /// Which tracer (by unique id) the cached ring belongs to, so
    /// several tracers on one thread re-register cleanly.
    key: u64,
    ring: Option<Arc<Ring>>,
    ctr: [u64; Stage::COUNT],
    epoch: u64,
}

thread_local! {
    static TL: RefCell<TlState> = RefCell::new(TlState::default());
}

/// Cheap cloneable handle; `Tracer::off()` is the no-op default.
#[derive(Clone)]
pub struct Tracer {
    inner: Option<Arc<TracerInner>>,
}

impl Tracer {
    /// Disabled tracer: `start` returns `None` and `record` is a branch.
    pub fn off() -> Tracer {
        Tracer { inner: None }
    }

    pub fn new(sample_rate: f64) -> Tracer {
        Tracer::with_capacity(sample_rate, DEFAULT_RING_CAP)
    }

    pub fn with_capacity(sample_rate: f64, ring_cap: usize) -> Tracer {
        let rate = if sample_rate.is_finite() { sample_rate.clamp(1e-9, 1.0) } else { 1.0 };
        Tracer {
            inner: Some(Arc::new(TracerInner {
                // ordering: Relaxed — only uniqueness of the id matters
                // (atomic RMW at any ordering); it guards no data.
                id: TRACER_IDS.fetch_add(1, Ordering::Relaxed),
                t0: Instant::now(),
                stride: (1.0 / rate).round().max(1.0) as u64,
                ring_cap: ring_cap.max(1),
                rings: Mutex::new(Vec::new()),
            })),
        }
    }

    pub fn is_on(&self) -> bool {
        self.inner.is_some()
    }

    /// Span start: one clock read when tracing, `None` (no clock) when off.
    pub fn start(&self) -> Option<Instant> {
        self.inner.as_ref().map(|_| Instant::now())
    }

    /// Per-thread epoch hint attached to subsequent spans.
    pub fn set_epoch(&self, epoch: u64) {
        if self.inner.is_none() {
            return;
        }
        TL.with(|tl| tl.borrow_mut().epoch = epoch);
    }

    /// Close a span opened by [`start`](Tracer::start).  Sampling is a
    /// per-(thread, stage) stride so every stage keeps uniform coverage
    /// regardless of how stages interleave on a worker.
    pub fn record(&self, stage: Stage, sample: u64, started: Option<Instant>) {
        let (inner, started) = match (&self.inner, started) {
            (Some(i), Some(t)) => (i, t),
            _ => return,
        };
        let dur_ns = started.elapsed().as_nanos() as u64;
        let start_ns = started.saturating_duration_since(inner.t0).as_nanos() as u64;
        TL.with(|tl| {
            let mut tl = tl.borrow_mut();
            let key = inner.id;
            if tl.key != key || tl.ring.is_none() {
                let label =
                    std::thread::current().name().unwrap_or("main").to_string();
                let ring = Arc::new(Ring::new(label, inner.ring_cap));
                // poison: registry of ring handles — only Vec push/iter
                // run under this lock (here and in `drain`).
                inner.rings.lock().unwrap().push(ring.clone());
                tl.key = key;
                tl.ring = Some(ring);
                tl.ctr = [0; Stage::COUNT];
            }
            let n = tl.ctr[stage as usize];
            tl.ctr[stage as usize] += 1;
            if n % inner.stride != 0 {
                return;
            }
            let meta = stage as u64 | (tl.epoch << 8);
            tl.ring.as_ref().unwrap().push(start_ns, dur_ns, sample, meta);
        });
    }

    /// Collect every ring into a plain dump.  Call after the recording
    /// threads have joined (the joins order the `Relaxed` slot writes).
    pub fn drain(&self) -> TraceDump {
        let mut dump = TraceDump::default();
        let inner = match &self.inner {
            Some(i) => i,
            None => return dump,
        };
        // poison: see `record` — Vec ops only under the registry lock.
        for ring in inner.rings.lock().unwrap().iter() {
            // ordering: Acquire — pairs with `push`'s Release cursor
            // store, so every slot word of the spans this count admits
            // is visible below.
            let n = ring.cursor.load(Ordering::Acquire) as usize;
            let cap = ring.cap();
            let kept = n.min(cap);
            dump.dropped += (n as u64).saturating_sub(cap as u64);
            let first = if n > cap { n % cap } else { 0 };
            let mut spans = Vec::with_capacity(kept);
            for k in 0..kept {
                let pos = ((first + k) % cap) * 4;
                // ordering: Relaxed — made visible by the Acquire
                // cursor load above (and by thread join before drain
                // for wrapped slots).
                let word = |o: usize| ring.slots[pos + o].load(Ordering::Relaxed);
                let meta = word(3);
                if let Some(stage) = Stage::from_u8((meta & 0xff) as u8) {
                    spans.push(Span {
                        stage,
                        start_ns: word(0),
                        dur_ns: word(1),
                        sample: word(2),
                        epoch: meta >> 8,
                    });
                }
            }
            dump.tracks.push(Track { label: ring.label.clone(), spans });
        }
        dump
    }
}

#[derive(Clone, Debug)]
pub struct Span {
    pub stage: Stage,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub sample: u64,
    pub epoch: u64,
}

#[derive(Clone, Debug)]
pub struct Track {
    pub label: String,
    pub spans: Vec<Span>,
}

#[derive(Clone, Debug, Default)]
pub struct TraceDump {
    pub tracks: Vec<Track>,
    /// Spans lost to ring wrap-around (oldest-first overwrite).
    pub dropped: u64,
}

impl TraceDump {
    pub fn span_count(&self) -> usize {
        self.tracks.iter().map(|t| t.spans.len()).sum()
    }
}

/// Aggregate a dump into per-stage latency histograms, in [`Stage::ALL`]
/// order, skipping stages with no spans.
pub fn stage_hists(dump: &TraceDump) -> Vec<(String, LogHist)> {
    let mut hists: Vec<Option<LogHist>> = vec![None; Stage::COUNT];
    for track in &dump.tracks {
        for s in &track.spans {
            hists[s.stage as usize].get_or_insert_with(LogHist::new).record(s.dur_ns);
        }
    }
    Stage::ALL
        .iter()
        .zip(hists)
        .filter_map(|(st, h)| h.map(|h| (st.name().to_string(), h)))
        .collect()
}

/// Chrome trace-event JSON for Perfetto / chrome://tracing: one `tid`
/// per track (named via `"M"` thread_name metadata), `"X"` complete
/// events in microseconds, and `"C"` counter events for each
/// `(name, [(t_secs, value)])` series (queue depths, utilization).
pub fn chrome_trace(dump: &TraceDump, counters: &[(String, Vec<(f64, f64)>)]) -> Json {
    let mut events = Vec::new();
    for (tid, track) in dump.tracks.iter().enumerate() {
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(tid as f64)),
            ("name", Json::str("thread_name")),
            ("args", Json::obj(vec![("name", Json::str(&track.label))])),
        ]));
        for s in &track.spans {
            events.push(Json::obj(vec![
                ("ph", Json::str("X")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(tid as f64)),
                ("ts", Json::num(s.start_ns as f64 / 1e3)),
                ("dur", Json::num(s.dur_ns as f64 / 1e3)),
                ("name", Json::str(s.stage.name())),
                ("cat", Json::str("dpp")),
                ("args", Json::obj(vec![
                    ("sample", Json::num(s.sample as f64)),
                    ("epoch", Json::num(s.epoch as f64)),
                ])),
            ]));
        }
    }
    for (name, series) in counters {
        for &(t, v) in series {
            events.push(Json::obj(vec![
                ("ph", Json::str("C")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(0.0)),
                ("ts", Json::num(t * 1e6)),
                ("name", Json::str(name)),
                ("args", Json::obj(vec![("value", Json::num(v))])),
            ]));
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Parse-back validation of the Chrome trace-event format: every event
/// must carry a known phase with the fields that phase requires.
/// Returns the event count.
pub fn validate_chrome_trace(j: &Json) -> Result<usize> {
    let events = j
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .context("trace must carry a `traceEvents` array")?;
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(|p| p.as_str())
            .with_context(|| format!("event {i}: missing `ph`"))?;
        let num = |k: &str| {
            ev.get(k)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("event {i} ({ph}): `{k}` must be numeric"))
        };
        let name = ev.get("name").and_then(|v| v.as_str());
        ensure!(name.is_some(), "event {i} ({ph}): missing `name`");
        match ph {
            "X" => {
                num("pid")?;
                num("tid")?;
                ensure!(num("ts")? >= 0.0, "event {i}: negative ts");
                ensure!(num("dur")? >= 0.0, "event {i}: negative dur");
            }
            "M" => {
                ensure!(ev.get("args").is_some(), "event {i}: M without args");
            }
            "C" => {
                num("ts")?;
                let args = ev.get("args").context("C event without args")?;
                ensure!(
                    args.as_obj().map(|m| !m.is_empty()).unwrap_or(false),
                    "event {i}: C event needs a counter value in args"
                );
            }
            other => bail!("event {i}: unknown phase {other:?}"),
        }
    }
    Ok(events.len())
}

/// DS-Analyzer-style wall-clock decomposition: the share of the run the
/// device spent computing, waiting on data fetch (storage), and waiting
/// on preprocessing.  Shares sum to 1 by construction.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StallAttribution {
    pub fetch: f64,
    pub prep: f64,
    pub compute: f64,
}

impl StallAttribution {
    /// `compute_share` is the device's busy fraction of wall clock; the
    /// remaining stall is split between fetch and prep in proportion to
    /// the two signals (any non-negative, same-unit pair — the engine
    /// uses worker starved-on-storage seconds vs CPU busy seconds).
    pub fn from_signals(compute_share: f64, fetch_signal: f64, prep_signal: f64) -> Self {
        let compute = compute_share.clamp(0.0, 1.0);
        let stall = 1.0 - compute;
        let (f, p) = (fetch_signal.max(0.0), prep_signal.max(0.0));
        let fetch_frac = if f + p > 0.0 { f / (f + p) } else { 0.0 };
        let fetch = stall * fetch_frac;
        StallAttribution { fetch, prep: stall - fetch, compute }
    }

    pub fn sum(&self) -> f64 {
        self.fetch + self.prep + self.compute
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("fetch", Json::num(self.fetch)),
            ("prep", Json::num(self.prep)),
            ("compute", Json::num(self.compute)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<StallAttribution> {
        let f = |k: &str| {
            j.get(k)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("stall attribution missing `{k}`"))
        };
        Ok(StallAttribution { fetch: f("fetch")?, prep: f("prep")?, compute: f("compute")? })
    }

    pub fn summary_line(&self) -> String {
        format!(
            "stalls: fetch {:.1}%  prep {:.1}%  compute {:.1}%",
            self.fetch * 100.0,
            self.prep * 100.0,
            self.compute * 100.0
        )
    }
}

/// Pretty-print the histogram + attribution tables from a saved run
/// report (`dpp trace <run.json>`).  Pure, so it is testable offline.
pub fn report_tables(report: &Json) -> Result<String> {
    let mut out = String::new();
    if let Some(v) = report.get("schema_version").and_then(|v| v.as_f64()) {
        out.push_str(&format!("run report (schema v{v:.0})\n"));
    }
    let stall = StallAttribution {
        fetch: report.get("stall_fetch").and_then(|v| v.as_f64()).unwrap_or(0.0),
        prep: report.get("stall_prep").and_then(|v| v.as_f64()).unwrap_or(0.0),
        compute: report.get("stall_compute").and_then(|v| v.as_f64()).unwrap_or(0.0),
    };
    out.push_str(&stall.summary_line());
    out.push('\n');
    let hists = report.get("stage_hists").and_then(|v| v.as_arr()).unwrap_or(&[]);
    if hists.is_empty() {
        out.push_str("no stage histograms (run with --trace FILE to record spans)\n");
        return Ok(out);
    }
    out.push_str(&format!(
        "{:<18} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}\n",
        "stage", "count", "mean", "p50", "p95", "p99", "max"
    ));
    for row in hists {
        let stage = row
            .get("stage")
            .and_then(|v| v.as_str())
            .context("stage_hists row missing `stage`")?;
        let h = LogHist::from_json(row.get("hist").context("stage_hists row missing `hist`")?)?;
        out.push_str(&format!(
            "{:<18} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}\n",
            stage,
            h.count(),
            fmt_ns(h.mean_ns()),
            fmt_ns(h.percentile(50.0) as f64),
            fmt_ns(h.percentile(95.0) as f64),
            fmt_ns(h.percentile(99.0) as f64),
            fmt_ns(h.max_ns() as f64),
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn off_tracer_records_nothing() {
        let t = Tracer::off();
        assert!(!t.is_on());
        assert!(t.start().is_none());
        t.record(Stage::Decode, 1, t.start());
        t.set_epoch(3);
        let dump = t.drain();
        assert_eq!(dump.span_count(), 0);
        assert_eq!(dump.dropped, 0);
    }

    #[test]
    fn spans_round_trip_through_the_ring() {
        let t = Tracer::new(1.0);
        t.set_epoch(2);
        for i in 0..5u64 {
            let s = t.start();
            assert!(s.is_some());
            std::thread::sleep(Duration::from_millis(1));
            t.record(Stage::Decode, i, s);
        }
        let s = t.start();
        t.record(Stage::Augment, 99, s);
        let dump = t.drain();
        assert_eq!(dump.tracks.len(), 1, "one thread, one ring");
        assert_eq!(dump.span_count(), 6);
        assert_eq!(dump.dropped, 0);
        let spans = &dump.tracks[0].spans;
        let decodes: Vec<_> = spans.iter().filter(|s| s.stage == Stage::Decode).collect();
        assert_eq!(decodes.len(), 5);
        assert!(decodes.iter().all(|s| s.epoch == 2));
        assert_eq!(decodes[3].sample, 3);
        assert!(decodes[3].dur_ns >= 1_000_000, "slept 1ms: {}", decodes[3].dur_ns);
        // Spans are chronological within a track.
        for w in spans.windows(2) {
            assert!(w[0].start_ns <= w[1].start_ns);
        }
    }

    #[test]
    fn per_thread_rings_carry_thread_names() {
        let t = Tracer::new(1.0);
        let t2 = t.clone();
        std::thread::Builder::new()
            .name("cpu-7".into())
            .spawn(move || {
                let s = t2.start();
                t2.record(Stage::Augment, 1, s);
            })
            .unwrap()
            .join()
            .unwrap();
        let s = t.start();
        t.record(Stage::Decode, 0, s);
        let dump = t.drain();
        assert_eq!(dump.tracks.len(), 2);
        let labels: Vec<_> = dump.tracks.iter().map(|tr| tr.label.as_str()).collect();
        assert!(labels.contains(&"cpu-7"), "{labels:?}");
    }

    #[test]
    fn sampling_stride_keeps_every_nth_per_stage() {
        let t = Tracer::new(0.25); // stride 4
        for i in 0..16u64 {
            let s = t.start();
            t.record(Stage::Decode, i, s);
            let s = t.start();
            t.record(Stage::Augment, i, s);
        }
        let dump = t.drain();
        let count = |st: Stage| {
            dump.tracks[0].spans.iter().filter(|s| s.stage == st).count()
        };
        // Per-stage stride: both stages keep 16/4 = 4 spans each, so
        // interleaving cannot bias coverage toward one stage.
        assert_eq!(count(Stage::Decode), 4);
        assert_eq!(count(Stage::Augment), 4);
        let kept: Vec<u64> = dump.tracks[0]
            .spans
            .iter()
            .filter(|s| s.stage == Stage::Decode)
            .map(|s| s.sample)
            .collect();
        assert_eq!(kept, vec![0, 4, 8, 12]);
    }

    #[test]
    fn ring_wrap_overwrites_oldest_and_counts_dropped() {
        let t = Tracer::with_capacity(1.0, 8);
        for i in 0..20u64 {
            let s = t.start();
            t.record(Stage::Fetch, i, s);
        }
        let dump = t.drain();
        assert_eq!(dump.span_count(), 8);
        assert_eq!(dump.dropped, 12);
        let samples: Vec<u64> = dump.tracks[0].spans.iter().map(|s| s.sample).collect();
        assert_eq!(samples, (12..20).collect::<Vec<_>>(), "oldest overwritten first");
    }

    #[test]
    fn stage_hists_aggregate_across_tracks() {
        let mut dump = TraceDump::default();
        let span = |stage, dur_ns| Span { stage, start_ns: 0, dur_ns, sample: 0, epoch: 0 };
        dump.tracks.push(Track {
            label: "cpu-0".into(),
            spans: vec![span(Stage::Decode, 1000), span(Stage::Augment, 500)],
        });
        dump.tracks.push(Track {
            label: "cpu-1".into(),
            spans: vec![span(Stage::Decode, 3000)],
        });
        let hists = stage_hists(&dump);
        assert_eq!(hists.len(), 2);
        assert_eq!(hists[0].0, "decode");
        assert_eq!(hists[0].1.count(), 2);
        assert_eq!(hists[1].0, "augment");
        assert_eq!(hists[1].1.count(), 1);
    }

    #[test]
    fn chrome_trace_validates_and_round_trips() {
        let t = Tracer::new(1.0);
        for i in 0..3u64 {
            let s = t.start();
            t.record(Stage::Decode, i, s);
        }
        let dump = t.drain();
        let counters = vec![("work_queue".to_string(), vec![(0.0, 1.0), (0.5, 3.0)])];
        let j = chrome_trace(&dump, &counters);
        let parsed = Json::parse(&j.dump()).unwrap();
        let n = validate_chrome_trace(&parsed).unwrap();
        // 1 thread-name metadata + 3 spans + 2 counter samples.
        assert_eq!(n, 6);
        // And the validator rejects malformed events.
        assert!(validate_chrome_trace(&Json::parse("{}").unwrap()).is_err());
        let bad = Json::parse(r#"{"traceEvents":[{"ph":"X","name":"x","pid":1,"tid":0}]}"#).unwrap();
        assert!(validate_chrome_trace(&bad).is_err(), "X without ts/dur must fail");
        let unknown = Json::parse(r#"{"traceEvents":[{"ph":"Q","name":"x"}]}"#).unwrap();
        assert!(validate_chrome_trace(&unknown).is_err());
    }

    #[test]
    fn attribution_sums_to_one_and_splits_by_signal() {
        let a = StallAttribution::from_signals(0.6, 3.0, 1.0);
        assert!((a.sum() - 1.0).abs() < 1e-12);
        assert!((a.compute - 0.6).abs() < 1e-12);
        assert!((a.fetch - 0.3).abs() < 1e-12);
        assert!((a.prep - 0.1).abs() < 1e-12);
        // No stall signal: the whole remainder is prep=0/fetch=0 free.
        let b = StallAttribution::from_signals(1.0, 0.0, 0.0);
        assert_eq!(b, StallAttribution { fetch: 0.0, prep: 0.0, compute: 1.0 });
        // Degenerate inputs stay clamped and still sum to 1.
        let c = StallAttribution::from_signals(1.7, -2.0, 0.0);
        assert!((c.sum() - 1.0).abs() < 1e-12);
        let d = StallAttribution::from_signals(0.0, 0.0, 5.0);
        assert!((d.prep - 1.0).abs() < 1e-12);
        // JSON round-trip.
        let j = a.to_json();
        let back = StallAttribution::from_json(&Json::parse(&j.dump()).unwrap()).unwrap();
        assert_eq!(back, a);
    }

    #[test]
    fn report_tables_render_hists_and_attribution() {
        let mut h = LogHist::new();
        for v in [1_000u64, 2_000, 4_000] {
            h.record(v);
        }
        let report = Json::obj(vec![
            ("schema_version", Json::num(2.0)),
            ("stall_fetch", Json::num(0.25)),
            ("stall_prep", Json::num(0.15)),
            ("stall_compute", Json::num(0.6)),
            (
                "stage_hists",
                Json::arr([Json::obj(vec![
                    ("stage", Json::str("decode")),
                    ("hist", h.to_json()),
                ])]),
            ),
        ]);
        let txt = report_tables(&report).unwrap();
        assert!(txt.contains("fetch 25.0%"), "{txt}");
        assert!(txt.contains("decode"), "{txt}");
        assert!(txt.contains("p95"), "{txt}");
        // A report traced off still prints the attribution line.
        let bare = Json::obj(vec![("stall_compute", Json::num(1.0))]);
        let txt = report_tables(&bare).unwrap();
        assert!(txt.contains("no stage histograms"), "{txt}");
    }
}
