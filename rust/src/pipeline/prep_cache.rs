//! Decoded-sample cache — the CoorDL insight (Mohan et al., "Analyzing
//! and Mitigating Data Stalls in DNN Training") applied to this pipeline:
//! decode, not I/O, dominates per-epoch preprocessing cost (paper Fig. 3:
//! 47.7% of 14.26 ms/image), so spare DRAM is best spent on *decoded*
//! pixels, letting epoch N+1 skip read+decode entirely.  Augmentation is
//! NOT cached: a hit re-enters the pipeline with fresh per-epoch aug
//! params, so training randomness is preserved and only decode is
//! amortized.
//!
//! Two admission/eviction policies:
//!
//! * `lru` — classic byte-budgeted LRU.  Under freshly re-shuffled epoch
//!   orders it thrashes: a sample touched early in epoch N is usually
//!   evicted before its epoch-N+1 access, so the steady-state hit rate
//!   collapses toward `f + (1-f)·ln(1-f)` (≈ f²/2 for small cache
//!   fraction f) — ~15% at a half-dataset cache.
//! * `minio` — CoorDL's eviction-free policy: admit until full, then
//!   never evict or replace.  The resident set is stable, so every epoch
//!   ≥ 2 hits exactly `cache_size / dataset_size` of its accesses
//!   regardless of shuffle order.
//!
//! The same closed-form hit-rate model ([`steady_state_hit_rate`]) drives
//! the testbed simulator's decode-service scaling (`sim/`), keeping
//! simulated multi-epoch remote runs comparable to real ones (agreement
//! asserted in `tests/prep_cache.rs`).

use crate::util::bytelru::ByteLru;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Eviction policy of the decoded-sample cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PrepCachePolicy {
    Lru,
    /// Eviction-free (CoorDL MinIO): admit until full, never evict.
    #[default]
    Minio,
}

impl PrepCachePolicy {
    pub fn parse(s: &str) -> Result<PrepCachePolicy> {
        match s {
            "lru" => Ok(PrepCachePolicy::Lru),
            "minio" => Ok(PrepCachePolicy::Minio),
            _ => bail!("prep-cache-policy must be lru|minio, got {s}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PrepCachePolicy::Lru => "lru",
            PrepCachePolicy::Minio => "minio",
        }
    }
}

/// Decoded (post-decode, pre-augment) planar pixels of one sample.
/// Pixels live behind an `Arc` so a cache hit is a refcount bump; the
/// placement-specific augment path copies only when it must.
///
/// `h`×`w` are the *stored* dims; with the fused decoder's fractional
/// scale the pixels may be a 1/2^k downscale of the source image
/// (`scale_log2 = k`), which shrinks the entry by 4^k and raises the
/// MinIO hit fraction for the same DRAM budget.  Augmentation params are
/// sampled against the original dims ([`orig_h`](Self::orig_h)) and
/// rescaled at use, so the aug stream is identical either way.
#[derive(Clone, Debug)]
pub struct DecodedSample {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// Fractional-scale exponent of the stored pixels (0 = full res).
    pub scale_log2: u8,
    pub pixels: Arc<[f32]>,
}

impl DecodedSample {
    pub fn new(c: usize, h: usize, w: usize, pixels: Vec<f32>) -> Self {
        DecodedSample { c, h, w, scale_log2: 0, pixels: pixels.into() }
    }

    /// Bytes this sample charges against the cache budget.
    pub fn byte_size(&self) -> usize {
        self.pixels.len() * std::mem::size_of::<f32>()
    }

    /// Height of the source image these pixels were decoded from.
    pub fn orig_h(&self) -> usize {
        self.h << self.scale_log2
    }

    /// Width of the source image these pixels were decoded from.
    pub fn orig_w(&self) -> usize {
        self.w << self.scale_log2
    }
}

/// Policy-specific resident store: the lru arm delegates recency,
/// eviction, and replacement-credit accounting to the shared
/// [`ByteLru`] core (also behind `storage/cache.rs`); the minio arm is a
/// frozen map that never evicts, so it needs only a byte total.
enum Store {
    Lru(ByteLru<u64, Arc<DecodedSample>>),
    Minio { map: HashMap<u64, Arc<DecodedSample>>, bytes: usize },
}

/// Byte-budgeted, thread-safe decoded-sample store keyed by sample id,
/// shared across CPU workers and epochs.
pub struct PrepCache {
    budget: usize,
    policy: PrepCachePolicy,
    inner: Mutex<Store>,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

impl PrepCache {
    pub fn new(budget_bytes: usize, policy: PrepCachePolicy) -> Self {
        let store = match policy {
            PrepCachePolicy::Lru => Store::Lru(ByteLru::new(budget_bytes)),
            PrepCachePolicy::Minio => Store::Minio { map: HashMap::new(), bytes: 0 },
        };
        PrepCache {
            budget: budget_bytes,
            policy,
            inner: Mutex::new(store),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn policy(&self) -> PrepCachePolicy {
        self.policy
    }

    /// Look a sample up, counting the hit/miss.  LRU refreshes recency;
    /// minio needs no bookkeeping (nothing is ever evicted).
    pub fn get(&self, id: u64) -> Option<Arc<DecodedSample>> {
        // poison: every holder of `inner` (get/would_admit/admit/
        // cached_bytes/len) runs only map/LRU ops and integer arithmetic
        // under the lock — no panic can originate there.
        let out = match &mut *self.inner.lock().unwrap() {
            Store::Lru(lru) => lru.get(&id).cloned(),
            Store::Minio { map, .. } => map.get(&id).cloned(),
        };
        // ordering: Relaxed — hit/miss telemetry counters: exact under
        // atomic RMW, read for ratios only (hit_rate / run report), and
        // never used to publish the cached data itself (the mutex above
        // does that).
        match &out {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        out
    }

    /// Would a sample of `bytes` be admitted right now?  Lets the CPU
    /// worker skip preparing cache-only pixels (the hybrid placement's
    /// extra dequant+IDCT, or a whole-image decode under the fused ROI
    /// path) when admission would be refused anyway.
    pub fn would_admit(&self, bytes: usize) -> bool {
        if bytes > self.budget {
            return false;
        }
        // poison: see `get`.
        match &*self.inner.lock().unwrap() {
            Store::Lru(_) => true,
            Store::Minio { bytes: resident, .. } => resident + bytes <= self.budget,
        }
    }

    pub fn admit(&self, id: u64, sample: Arc<DecodedSample>) {
        let size = sample.byte_size();
        if size > self.budget {
            return;
        }
        // poison: see `get`.
        match &mut *self.inner.lock().unwrap() {
            // Replacement credit + eviction are the shared core's job.
            Store::Lru(lru) => lru.insert(id, sample, size),
            // Eviction-free: first admission wins, nothing leaves.
            Store::Minio { map, bytes } => {
                if map.contains_key(&id) || *bytes + size > self.budget {
                    return;
                }
                *bytes += size;
                map.insert(id, sample);
            }
        }
    }

    pub fn hit_rate(&self) -> f64 {
        // ordering: Relaxed — approximate ratio read; see `get`.
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }

    pub fn cached_bytes(&self) -> usize {
        // poison: see `get`.
        match &*self.inner.lock().unwrap() {
            Store::Lru(lru) => lru.bytes(),
            Store::Minio { bytes, .. } => *bytes,
        }
    }

    pub fn len(&self) -> usize {
        // poison: see `get`.
        match &*self.inner.lock().unwrap() {
            Store::Lru(lru) => lru.len(),
            Store::Minio { map, .. } => map.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// Closed-form hit-rate model (shared with sim/ and autoconf/)
// ---------------------------------------------------------------------------

/// Steady-state (epoch ≥ 2) hit rate under freshly re-shuffled epoch
/// orders, for a cache of `cache_bytes` over a decoded corpus of
/// `dataset_bytes`.
///
/// * minio: the resident set is frozen, so the rate is exactly the cache
///   fraction `f = cache/dataset` (capped at 1).
/// * lru: a sample at position p (fraction u of the epoch) is hit next
///   epoch at fraction v iff the distinct samples touched in between —
///   `n·(u + v - u·v)` — fit in the cache.  With u, v uniform this gives
///   `P((1-u)(1-v) > 1-f) = f + (1-f)·ln(1-f)`, which collapses toward
///   f²/2 for small f: the CoorDL thrash result.
pub fn steady_state_hit_rate(policy: PrepCachePolicy, cache_bytes: f64, dataset_bytes: f64) -> f64 {
    if dataset_bytes <= 0.0 || cache_bytes <= 0.0 {
        return 0.0;
    }
    let f = (cache_bytes / dataset_bytes).min(1.0);
    match policy {
        PrepCachePolicy::Minio => f,
        PrepCachePolicy::Lru => {
            if f >= 1.0 {
                1.0
            } else {
                (f + (1.0 - f) * (1.0 - f).ln()).max(0.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(px: usize) -> Arc<DecodedSample> {
        Arc::new(DecodedSample::new(1, 1, px, vec![0.5; px]))
    }

    #[test]
    fn policy_parse_roundtrips() {
        for p in [PrepCachePolicy::Lru, PrepCachePolicy::Minio] {
            assert_eq!(PrepCachePolicy::parse(p.name()).unwrap(), p);
        }
        assert!(PrepCachePolicy::parse("fifo").is_err());
        assert_eq!(PrepCachePolicy::default(), PrepCachePolicy::Minio);
    }

    #[test]
    fn minio_admits_until_full_then_freezes() {
        // Budget = 2 samples of 100 f32s (400 B each).
        let c = PrepCache::new(800, PrepCachePolicy::Minio);
        c.admit(0, sample(100));
        c.admit(1, sample(100));
        c.admit(2, sample(100)); // refused: full
        assert_eq!(c.len(), 2);
        assert_eq!(c.cached_bytes(), 800);
        assert!(c.get(0).is_some() && c.get(1).is_some());
        assert!(c.get(2).is_none());
        assert!(!c.would_admit(400));
        // Still frozen after any number of accesses.
        for _ in 0..10 {
            c.get(0);
        }
        c.admit(3, sample(100));
        assert!(c.get(3).is_none(), "minio must never evict or replace");
    }

    #[test]
    fn lru_evicts_oldest_sample() {
        let c = PrepCache::new(800, PrepCachePolicy::Lru);
        c.admit(0, sample(100));
        c.admit(1, sample(100));
        assert!(c.get(0).is_some()); // refresh 0
        c.admit(2, sample(100)); // evicts 1
        assert!(c.get(1).is_none());
        assert!(c.get(0).is_some() && c.get(2).is_some());
        assert!(c.cached_bytes() <= 800);
        assert!(c.would_admit(800), "lru always admits what fits the budget");
    }

    #[test]
    fn oversized_samples_bypass() {
        let c = PrepCache::new(100, PrepCachePolicy::Minio);
        c.admit(0, sample(1000));
        assert!(c.is_empty());
        assert!(!c.would_admit(4000));
    }

    #[test]
    fn concurrent_admissions_keep_accounting_exact() {
        let c = Arc::new(PrepCache::new(40_000, PrepCachePolicy::Lru));
        let hs: Vec<_> = (0..4)
            .map(|t| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        if c.get(i).is_none() {
                            c.admit(i, sample(25 + (t * 7 + i as usize) % 50));
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let recount: usize = (0..200).filter_map(|i| c.get(i)).map(|s| s.byte_size()).sum();
        assert_eq!(c.cached_bytes(), recount);
        assert!(c.cached_bytes() <= 40_000);
    }

    #[test]
    fn minio_sustains_hit_rate_under_reshuffled_epochs() {
        // 100 samples, cache fits 50: epochs >= 2 hit exactly 50%.
        let n = 100u64;
        let c = PrepCache::new(50 * 400, PrepCachePolicy::Minio);
        let mut order: Vec<u64> = (0..n).collect();
        for epoch in 0..3u64 {
            Rng::new(7).fork(epoch).shuffle(&mut order);
            let h0 = c.hits.load(Ordering::Relaxed);
            for &id in &order {
                if c.get(id).is_none() {
                    c.admit(id, sample(100));
                }
            }
            let epoch_hits = c.hits.load(Ordering::Relaxed) - h0;
            if epoch == 0 {
                assert_eq!(epoch_hits, 0);
            } else {
                assert_eq!(epoch_hits, 50, "epoch {epoch}");
            }
        }
    }

    #[test]
    fn scaled_samples_charge_fewer_bytes_and_remember_orig_dims() {
        let full = DecodedSample::new(3, 64, 64, vec![0.0; 3 * 64 * 64]);
        let half =
            DecodedSample { scale_log2: 1, ..DecodedSample::new(3, 32, 32, vec![0.0; 3 * 32 * 32]) };
        assert_eq!(full.byte_size(), 4 * half.byte_size());
        assert_eq!((half.orig_h(), half.orig_w()), (64, 64));
        assert_eq!((full.orig_h(), full.orig_w()), (64, 64));
        // The same budget holds 4x the samples at half scale — the fused
        // decoder's cache-entry shrink that lifts the MinIO hit fraction.
        let c = PrepCache::new(full.byte_size() * 2, PrepCachePolicy::Minio);
        for id in 0..8 {
            c.admit(
                id,
                Arc::new(DecodedSample {
                    scale_log2: 1,
                    ..DecodedSample::new(3, 32, 32, vec![0.0; 3 * 32 * 32])
                }),
            );
        }
        assert_eq!(c.len(), 8);
        assert!(!c.would_admit(half.byte_size()), "budget exactly full");
    }

    #[test]
    fn hit_rate_model_shapes() {
        use PrepCachePolicy::{Lru, Minio};
        // Minio is linear in the cache fraction; LRU collapses below it
        // (the lru/minio ratio is (f + (1-f)ln(1-f))/f: 0.14 at f=0.25,
        // 0.31 at f=0.5, 0.54 at f=0.75 — it approaches 1 only as f→1).
        for f in [0.25, 0.5, 0.75] {
            let m = steady_state_hit_rate(Minio, f, 1.0);
            let l = steady_state_hit_rate(Lru, f, 1.0);
            assert!((m - f).abs() < 1e-12);
            assert!(l < m * 0.6, "lru {l} must collapse vs minio {m} at f={f}");
            assert!(l > 0.0);
        }
        // Both policies saturate at 1 when the corpus fits.
        assert_eq!(steady_state_hit_rate(Minio, 2.0, 1.0), 1.0);
        assert_eq!(steady_state_hit_rate(Lru, 1.0, 1.0), 1.0);
        assert_eq!(steady_state_hit_rate(Minio, 0.0, 1.0), 0.0);
    }
}
