//! Shuffle buffer for record streaming.
//!
//! Record shards are read sequentially, but SGD wants randomized sample
//! order; the standard compromise (TFRecord/DALI alike) is a bounded
//! reservoir that emits a uniformly random resident element as new ones
//! stream in — randomness bounded by the buffer size, I/O stays
//! sequential (paper §2.2.2: "some form of randomness ... is required").

use crate::util::rng::Rng;

pub struct ShuffleBuffer<T> {
    buf: Vec<T>,
    cap: usize,
    rng: Rng,
}

impl<T> ShuffleBuffer<T> {
    pub fn new(cap: usize, rng: Rng) -> Self {
        ShuffleBuffer { buf: Vec::with_capacity(cap.max(1)), cap: cap.max(1), rng }
    }

    /// Push an item; returns an evicted random item once the buffer is full.
    pub fn push(&mut self, item: T) -> Option<T> {
        if self.buf.len() < self.cap {
            self.buf.push(item);
            None
        } else {
            let j = self.rng.gen_range(self.cap as u64) as usize;
            let out = std::mem::replace(&mut self.buf[j], item);
            Some(out)
        }
    }

    /// Drain the remaining items in random order.
    pub fn drain(mut self) -> Vec<T> {
        self.rng.shuffle(&mut self.buf);
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_every_item_exactly_once() {
        let mut sb = ShuffleBuffer::new(16, Rng::new(1));
        let mut out = Vec::new();
        for i in 0..100u32 {
            if let Some(v) = sb.push(i) {
                out.push(v);
            }
        }
        out.extend(sb.drain());
        let mut sorted = out.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(out, (0..100).collect::<Vec<_>>(), "no shuffling happened");
    }

    #[test]
    fn small_buffer_passthrough_still_complete() {
        let mut sb = ShuffleBuffer::new(1, Rng::new(2));
        let mut out = Vec::new();
        for i in 0..10u32 {
            if let Some(v) = sb.push(i) {
                out.push(v);
            }
        }
        out.extend(sb.drain());
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed| {
            let mut sb = ShuffleBuffer::new(8, Rng::new(seed));
            let mut out = Vec::new();
            for i in 0..50u32 {
                if let Some(v) = sb.push(i) {
                    out.push(v);
                }
            }
            out.extend(sb.drain());
            out
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }

    /// Satellite: the *eviction order* itself (not just the final
    /// multiset) is a pure function of the seed — same seed ⇒ the same
    /// item leaves on the same push, across both the streaming phase and
    /// the drain.
    #[test]
    fn eviction_order_is_a_pure_function_of_the_seed() {
        let evictions = |seed: u64, n: u32| {
            let mut sb = ShuffleBuffer::new(16, Rng::new(seed));
            let mut streamed = Vec::new();
            for i in 0..n {
                // Record (push index, evicted item) pairs: position
                // matters, not just membership.
                if let Some(v) = sb.push(i) {
                    streamed.push((i, v));
                }
            }
            (streamed, sb.drain())
        };
        let (s1, d1) = evictions(42, 200);
        let (s2, d2) = evictions(42, 200);
        assert_eq!(s1, s2, "same seed must evict the same item on the same push");
        assert_eq!(d1, d2, "same seed must drain in the same order");
        let (s3, d3) = evictions(43, 200);
        assert!(
            s1 != s3 || d1 != d3,
            "different seeds should not reproduce the identical order"
        );
        // Prefix stability: the first half of the stream fully determines
        // the evictions seen so far (no hidden global state).
        let (short, _) = evictions(42, 100);
        assert_eq!(&s1[..short.len()], &short[..]);
    }
}
