//! The staged preprocessing pipeline (Fig. 1 of the paper, made real).
//!
//! Thread topology (all queues bounded — backpressure is load-bearing):
//!
//! ```text
//!  source ──work q──▶ cpu workers ×N ──sample q──▶ batcher ──batch q──▶ device
//!  (epoch order /     (read, entropy/full         (collate B)          (fused HLO
//!   shard streams)     decode, augment)                                 preproc +
//!                                                                       train step)
//! ```
//!
//! Placement decides how much work the CPU stage does per image:
//! * `cpu`     — full decode + augment on CPU; device only trains.
//! * `hybrid`  — entropy decode on CPU; dequant+IDCT+augment on device
//!               (one fused artifact — DALI's hybrid decode).
//! * `hybrid0` — full decode on CPU; augment on device.

pub mod channel;
pub mod prep_cache;
pub mod shuffle;
pub mod source;

use crate::codec::{DecodePlan, DecodeStats};
use crate::config::{Placement, RunConfig};
use crate::ops::{self, AugParams};
use prep_cache::{DecodedSample, PrepCache};
use std::sync::Arc;

/// What the CPU stage produced for one image, by placement.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Fully preprocessed, normalized `[C, OUT, OUT]` pixels (cpu placement).
    Ready(Vec<f32>),
    /// Entropy-decoded coefficients `[C, H/8, W/8, 8, 8]` + aug row (hybrid).
    Coefs { coefs: Vec<f32>, qtable: [f32; 64], aug: [f32; 6] },
    /// Decoded `[C, H, W]` pixels + aug row (hybrid0).  Shared, so a
    /// prep-cache hit hands its resident buffer on as a refcount bump.
    Pixels { pixels: std::sync::Arc<[f32]>, aug: [f32; 6] },
}

#[derive(Clone, Debug)]
pub struct Sample {
    pub id: u64,
    pub label: u16,
    pub payload: Payload,
}

/// A collated batch, homogeneous in payload kind.
#[derive(Clone, Debug)]
pub struct BatchKindError;

#[derive(Clone, Debug)]
pub enum Batch {
    Ready { data: Vec<f32>, labels: Vec<i32> },
    Coefs { data: Vec<f32>, qtable: [f32; 64], aug: Vec<f32>, labels: Vec<i32> },
    Pixels { data: Vec<f32>, aug: Vec<f32>, labels: Vec<i32> },
}

impl Batch {
    pub fn len(&self) -> usize {
        match self {
            Batch::Ready { labels, .. }
            | Batch::Coefs { labels, .. }
            | Batch::Pixels { labels, .. } => labels.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn labels(&self) -> &[i32] {
        match self {
            Batch::Ready { labels, .. }
            | Batch::Coefs { labels, .. }
            | Batch::Pixels { labels, .. } => labels,
        }
    }
}

/// Collate `batch_size` samples into one `Batch`.  Samples must share the
/// payload kind (guaranteed: placement is fixed per run).
///
/// `data`/`aug` are preallocated at exact capacity from the first
/// sample's payload length × batch size (payloads are homogeneous per
/// batch), so the batcher hot path never reallocates mid-collation.
pub fn collate(samples: Vec<Sample>) -> Result<Batch, BatchKindError> {
    let n = samples.len();
    let mut labels = Vec::with_capacity(n);
    match samples.first().map(|s| &s.payload) {
        Some(Payload::Ready(first)) => {
            let mut data = Vec::with_capacity(first.len() * n);
            for s in samples {
                let Payload::Ready(v) = s.payload else { return Err(BatchKindError) };
                data.extend_from_slice(&v);
                labels.push(s.label as i32);
            }
            Ok(Batch::Ready { data, labels })
        }
        Some(Payload::Coefs { coefs: first, qtable, .. }) => {
            let qtable = *qtable;
            let mut data = Vec::with_capacity(first.len() * n);
            let mut aug = Vec::with_capacity(6 * n);
            for s in samples {
                let Payload::Coefs { coefs, aug: a, .. } = s.payload else {
                    return Err(BatchKindError);
                };
                data.extend_from_slice(&coefs);
                aug.extend_from_slice(&a);
                labels.push(s.label as i32);
            }
            Ok(Batch::Coefs { data, qtable, aug, labels })
        }
        Some(Payload::Pixels { pixels: first, .. }) => {
            let mut data = Vec::with_capacity(first.len() * n);
            let mut aug = Vec::with_capacity(6 * n);
            for s in samples {
                let Payload::Pixels { pixels, aug: a } = s.payload else {
                    return Err(BatchKindError);
                };
                data.extend_from_slice(&pixels);
                aug.extend_from_slice(&a);
                labels.push(s.label as i32);
            }
            Ok(Batch::Pixels { data, aug, labels })
        }
        None => Err(BatchKindError),
    }
}

/// The per-image CPU-stage work: decode `bytes` (an MJX bitstream) to the
/// placement's hand-off format.  `aug` was sampled by the coordinator.
pub fn cpu_stage(
    bytes: &[u8],
    placement: Placement,
    aug: AugParams,
    out_hw: usize,
) -> anyhow::Result<Payload> {
    match placement {
        Placement::Cpu => {
            let img = crate::codec::decode_cpu(bytes)?;
            let f = img.to_f32();
            let mut out = vec![0f32; img.c * out_hw * out_hw];
            ops::augment_fused(&f, img.c, img.h, img.w, &aug, out_hw, out_hw, &mut out);
            Ok(Payload::Ready(out))
        }
        Placement::Hybrid => {
            let ci = crate::codec::entropy_decode(bytes)?;
            Ok(Payload::Coefs { coefs: ci.coefs, qtable: ci.qtable, aug: aug.to_row() })
        }
        Placement::Hybrid0 => {
            let img = crate::codec::decode_cpu(bytes)?;
            Ok(Payload::Pixels { pixels: img.to_f32().into(), aug: aug.to_row() })
        }
    }
}

/// Like [`cpu_stage`], but admits the decoded (pre-augment) pixels into
/// the prep cache so later epochs skip the decode.  Under the hybrid
/// placement the entropy path never produces full pixels, so the extra
/// dequant+IDCT is run for admission only when the cache would accept the
/// sample (one-time cost ≪ the per-epoch decode it amortizes away).
pub fn cpu_stage_admitting(
    bytes: &[u8],
    placement: Placement,
    aug: AugParams,
    out_hw: usize,
    cache: &PrepCache,
    id: u64,
) -> anyhow::Result<Payload> {
    let px_bytes = |c: usize, h: usize, w: usize| c * h * w * std::mem::size_of::<f32>();
    match placement {
        Placement::Cpu => {
            let img = crate::codec::decode_cpu(bytes)?;
            // Share one pixel buffer between cache and augment: the
            // admission is a refcount bump, not a second full copy.
            let pixels: Arc<[f32]> = img.to_f32().into();
            if cache.would_admit(px_bytes(img.c, img.h, img.w)) {
                cache.admit(
                    id,
                    Arc::new(DecodedSample {
                        c: img.c,
                        h: img.h,
                        w: img.w,
                        scale_log2: 0,
                        pixels: pixels.clone(),
                    }),
                );
            }
            let mut out = vec![0f32; img.c * out_hw * out_hw];
            ops::augment_fused(&pixels, img.c, img.h, img.w, &aug, out_hw, out_hw, &mut out);
            Ok(Payload::Ready(out))
        }
        Placement::Hybrid => {
            let ci = crate::codec::entropy_decode(bytes)?;
            if cache.would_admit(px_bytes(ci.c, ci.h, ci.w)) {
                let img = crate::codec::coefs_to_image(&ci);
                cache.admit(
                    id,
                    Arc::new(DecodedSample::new(img.c, img.h, img.w, img.to_f32())),
                );
            }
            Ok(Payload::Coefs { coefs: ci.coefs, qtable: ci.qtable, aug: aug.to_row() })
        }
        Placement::Hybrid0 => {
            let img = crate::codec::decode_cpu(bytes)?;
            // Payload and cache share one buffer — admission is free.
            let pixels: Arc<[f32]> = img.to_f32().into();
            if cache.would_admit(px_bytes(img.c, img.h, img.w)) {
                cache.admit(
                    id,
                    Arc::new(DecodedSample {
                        c: img.c,
                        h: img.h,
                        w: img.w,
                        scale_log2: 0,
                        pixels: pixels.clone(),
                    }),
                );
            }
            Ok(Payload::Pixels { pixels, aug: aug.to_row() })
        }
    }
}

/// The CPU-stage work for a prep-cache hit: read+decode are skipped.
/// `cpu` placement augments the cached pixels in place; the device
/// placements re-enter as a hybrid0-style pixel payload (the device runs
/// the augment artifact), so a hybrid run's batches stay homogeneous per
/// batch via the batcher's per-kind collation.
///
/// `aug` is in *original-image* coordinates (sampled against
/// [`DecodedSample::orig_h`]/`orig_w`, so the aug stream is independent
/// of how the pixels were stored); a fractionally-scaled entry rescales
/// it into stored-pixel space here.  Only the `cpu` placement ever
/// admits scaled entries — the device augment artifact's input shape is
/// fixed at full resolution.
pub fn cpu_stage_cached(
    sample: &DecodedSample,
    placement: Placement,
    aug: AugParams,
    out_hw: usize,
) -> Payload {
    match placement {
        Placement::Cpu => {
            let mut out = vec![0f32; sample.c * out_hw * out_hw];
            let aug = rescale_aug(&aug, 0, 0, sample.scale_log2, sample.h, sample.w);
            ops::augment_fused(
                &sample.pixels,
                sample.c,
                sample.h,
                sample.w,
                &aug,
                out_hw,
                out_hw,
                &mut out,
            );
            Payload::Ready(out)
        }
        Placement::Hybrid | Placement::Hybrid0 => {
            debug_assert_eq!(
                sample.scale_log2, 0,
                "device placements never cache scaled pixels"
            );
            // Refcount bump: the warm path never copies the pixels.
            Payload::Pixels { pixels: sample.pixels.clone(), aug: aug.to_row() }
        }
    }
}

// ---------------------------------------------------------------------------
// Fused ROI + fractional-scale decode (plan-driven CPU stages)
// ---------------------------------------------------------------------------

/// Decode policy for the CPU stage (`--fused-decode` / `--decode-scale`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeOpts {
    /// Entropy-skip blocks outside the crop ROI (`cpu`/`hybrid0` paths;
    /// `hybrid` ships whole coefficient grids to the device regardless).
    pub fused: bool,
    /// Largest fractional-scale exponent the plan may pick (0 = full
    /// resolution only; `cpu`-placement path only).
    pub max_scale_log2: u8,
}

impl DecodeOpts {
    /// Full decode everywhere — the pre-fused behavior.
    pub fn off() -> Self {
        DecodeOpts { fused: false, max_scale_log2: 0 }
    }

    pub fn from_config(cfg: &RunConfig) -> Self {
        DecodeOpts {
            fused: cfg.fused_decode,
            max_scale_log2: if cfg.fused_decode { cfg.decode_scale.max_log2() } else { 0 },
        }
    }
}

/// Per-image decode telemetry from the planned CPU stage (feeds the
/// runner's `idct_blocks*` counters and `decode_scale_hist`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageStats {
    pub blocks_idct: u64,
    pub blocks_skipped: u64,
    /// Scale the decode actually ran at (0 when nothing was decoded on
    /// the CPU, e.g. the hybrid entropy-only path).
    pub scale_log2: u8,
}

impl StageStats {
    fn from_decode(d: &DecodeStats, scale_log2: usize) -> Self {
        StageStats {
            blocks_idct: d.blocks_idct,
            blocks_skipped: d.blocks_skipped,
            scale_log2: scale_log2 as u8,
        }
    }
}

/// Map augmentation params from full-image coordinates into the space of
/// pixels stored at `1/2^k` with full-res origin `(vy, vx)`; `vh`x`vw`
/// are the stored dims.  Floor rounding keeps the window inside the
/// view (the fractional-scale path is tolerance-, not bit-, checked, so
/// the sub-pixel shift is acceptable by construction); `k == 0` with a
/// zero origin is the identity.
fn rescale_aug(aug: &AugParams, vy: u32, vx: u32, k: u8, vh: usize, vw: usize) -> AugParams {
    if k == 0 && vy == 0 && vx == 0 {
        return *aug;
    }
    // Fail at the fault, not via wraparound three frames later inside
    // augment_fused's length assertion.
    assert!(
        aug.y0 >= vy && aug.x0 >= vx,
        "crop origin ({},{}) outside view origin ({vy},{vx})",
        aug.y0,
        aug.x0
    );
    let y0 = (aug.y0 - vy) >> k;
    let x0 = (aug.x0 - vx) >> k;
    assert!(
        (y0 as usize) < vh && (x0 as usize) < vw,
        "scaled crop origin ({y0},{x0}) outside {vh}x{vw} view"
    );
    AugParams {
        y0,
        x0,
        crop_h: (aug.crop_h >> k).max(1).min(vh as u32 - y0),
        crop_w: (aug.crop_w >> k).max(1).min(vw as u32 - x0),
        flip: aug.flip,
    }
}

/// Plan-driven variant of [`cpu_stage`]: on the `cpu` path, decode only
/// the blocks the crop consumes (optionally at a fractional scale) and
/// augment the ROI in place; on the `hybrid0` path, decode the ROI
/// blocks at their true offsets into a zeroed full-size canvas (the
/// device augment artifact's input shape is fixed, and it samples only
/// inside the crop window, so the device output is unchanged).  The
/// `hybrid` path and `opts.fused == false` fall back to the full stage.
pub fn cpu_stage_planned(
    bytes: &[u8],
    placement: Placement,
    aug: AugParams,
    out_hw: usize,
    opts: &DecodeOpts,
) -> anyhow::Result<(Payload, StageStats)> {
    if !opts.fused || placement == Placement::Hybrid {
        return full_stage_with_stats(bytes, placement, aug, out_hw);
    }
    let (c, h, w, _q) = crate::codec::probe(bytes)?;
    let crop =
        (aug.y0 as usize, aug.x0 as usize, aug.crop_h as usize, aug.crop_w as usize);
    match placement {
        Placement::Cpu => {
            let plan = DecodePlan::new(c, h, w, crop, out_hw, opts.max_scale_log2 as usize);
            let (roi, dstats) = crate::codec::decode_cpu_planned(bytes, &plan)?;
            let f = roi.to_f32();
            let (vy, vx) = plan.origin();
            let mut out = vec![0f32; c * out_hw * out_hw];
            if plan.scale_log2 == 0 {
                // Bit-identical to full decode + augment (sampling runs
                // in full-image coordinates over the ROI view).
                ops::augment_fused_view(
                    &f,
                    c,
                    h,
                    w,
                    (vy, vx, roi.h, roi.w),
                    &aug,
                    out_hw,
                    out_hw,
                    &mut out,
                );
            } else {
                let aug_s =
                    rescale_aug(&aug, vy as u32, vx as u32, plan.scale_log2 as u8, roi.h, roi.w);
                ops::augment_fused(&f, c, roi.h, roi.w, &aug_s, out_hw, out_hw, &mut out);
            }
            Ok((Payload::Ready(out), StageStats::from_decode(&dstats, plan.scale_log2)))
        }
        Placement::Hybrid0 => {
            let plan = DecodePlan::new(c, h, w, crop, out_hw, 0);
            let (roi, dstats) = crate::codec::decode_cpu_planned(bytes, &plan)?;
            let (vy, vx) = plan.origin();
            let mut full = vec![0f32; c * h * w];
            for ch in 0..c {
                let plane = roi.plane(ch);
                for y in 0..roi.h {
                    let dst = &mut full[ch * h * w + (vy + y) * w + vx..][..roi.w];
                    let src = &plane[y * roi.w..(y + 1) * roi.w];
                    for (d, &s) in dst.iter_mut().zip(src) {
                        *d = s as f32;
                    }
                }
            }
            Ok((
                Payload::Pixels { pixels: full.into(), aug: aug.to_row() },
                StageStats::from_decode(&dstats, 0),
            ))
        }
        Placement::Hybrid => unreachable!("handled above"),
    }
}

/// Plan-driven variant of [`cpu_stage_admitting`].  Cache entries must
/// serve *any* future epoch's crop, so admission decodes whole images:
/// under `cpu` the whole image can still be decoded (and stored) at a
/// fractional scale — bounded by the smallest crop the aug distribution
/// can sample ([`ops::min_crop_side`]), so no future hit ever upsamples
/// stored pixels — shrinking every entry by 4^k and raising the MinIO
/// hit fraction.  `hybrid0` falls back to the full-resolution decode
/// (its device payload shape is fixed).  When admission would be
/// refused anyway, the stage runs the plain fused ROI path instead.
pub fn cpu_stage_admitting_planned(
    bytes: &[u8],
    placement: Placement,
    aug: AugParams,
    out_hw: usize,
    cache: &PrepCache,
    id: u64,
    opts: &DecodeOpts,
) -> anyhow::Result<(Payload, StageStats)> {
    let (c, h, w, _q) = crate::codec::probe(bytes)?;
    let px_bytes = |c: usize, h: usize, w: usize| c * h * w * std::mem::size_of::<f32>();
    if !opts.fused || placement == Placement::Hybrid {
        let mut stats = full_stage_stats(c, h, w, placement);
        // The hybrid arm runs the cache-only dequant+IDCT when the
        // sample will be admitted — count that transform work (the
        // admission decision is re-taken inside `cpu_stage_admitting`,
        // so under concurrency the count is best-effort, like every
        // other relaxed counter here).
        if placement == Placement::Hybrid && cache.would_admit(px_bytes(c, h, w)) {
            stats.blocks_idct = (c * (h / 8) * (w / 8)) as u64;
        }
        let payload = cpu_stage_admitting(bytes, placement, aug, out_hw, cache, id)?;
        return Ok((payload, stats));
    }
    match placement {
        Placement::Cpu => {
            // The admission scale is bounded by the *smallest* crop the
            // aug distribution can draw, not the image dims: a cached
            // entry serves every future epoch's crop, and the resize
            // must only ever downsample stored pixels (the same
            // never-upsample rule the per-crop plan enforces).
            let min_crop = ops::min_crop_side(h as u32, w as u32) as usize;
            let k = DecodePlan::image_scale(min_crop, min_crop, out_hw, opts.max_scale_log2 as usize);
            let (sh, sw) = (h >> k, w >> k);
            if cache.would_admit(px_bytes(c, sh, sw)) {
                let plan = DecodePlan::full_scaled(c, h, w, k);
                let (img, dstats) = crate::codec::decode_cpu_planned(bytes, &plan)?;
                // Share one buffer between cache and augment: admission
                // is a refcount bump, not a second copy.
                let pixels: Arc<[f32]> = img.to_f32().into();
                cache.admit(
                    id,
                    Arc::new(DecodedSample {
                        c,
                        h: sh,
                        w: sw,
                        scale_log2: k as u8,
                        pixels: pixels.clone(),
                    }),
                );
                let aug_s = rescale_aug(&aug, 0, 0, k as u8, sh, sw);
                let mut out = vec![0f32; c * out_hw * out_hw];
                ops::augment_fused(&pixels, c, sh, sw, &aug_s, out_hw, out_hw, &mut out);
                Ok((Payload::Ready(out), StageStats::from_decode(&dstats, k)))
            } else {
                cpu_stage_planned(bytes, placement, aug, out_hw, opts)
            }
        }
        Placement::Hybrid0 => {
            if cache.would_admit(px_bytes(c, h, w)) {
                let stats = full_stage_stats(c, h, w, placement);
                let payload = cpu_stage_admitting(bytes, placement, aug, out_hw, cache, id)?;
                Ok((payload, stats))
            } else {
                cpu_stage_planned(bytes, placement, aug, out_hw, opts)
            }
        }
        Placement::Hybrid => unreachable!("handled above"),
    }
}

/// The full (unfused) stage, with block counters derived from the probe:
/// a full decode dequant+IDCTs every block; the hybrid entropy-only path
/// transforms nothing on the CPU (its admission-time transform is
/// counted by `cpu_stage_admitting_planned` instead).
fn full_stage_with_stats(
    bytes: &[u8],
    placement: Placement,
    aug: AugParams,
    out_hw: usize,
) -> anyhow::Result<(Payload, StageStats)> {
    let (c, h, w, _q) = crate::codec::probe(bytes)?;
    let stats = full_stage_stats(c, h, w, placement);
    let payload = cpu_stage(bytes, placement, aug, out_hw)?;
    Ok((payload, stats))
}

/// Block counters for a full (unplanned) decode of a `c`x`h`x`w` image.
fn full_stage_stats(c: usize, h: usize, w: usize, placement: Placement) -> StageStats {
    let blocks = (c * (h / 8) * (w / 8)) as u64;
    StageStats {
        blocks_idct: if placement == Placement::Hybrid { 0 } else { blocks },
        blocks_skipped: 0,
        scale_log2: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec;
    use crate::dataset;
    use crate::util::rng::Rng;

    fn encoded_image(seed: u64) -> Vec<u8> {
        let img = dataset::gen_image(&mut Rng::new(seed), 3, 3, 64, 64);
        codec::encode(&img, 85).unwrap()
    }

    #[test]
    fn cpu_stage_shapes_per_placement() {
        let bytes = encoded_image(1);
        let aug = AugParams::identity(64, 64);
        match cpu_stage(&bytes, Placement::Cpu, aug, 56).unwrap() {
            Payload::Ready(v) => assert_eq!(v.len(), 3 * 56 * 56),
            other => panic!("{other:?}"),
        }
        match cpu_stage(&bytes, Placement::Hybrid, aug, 56).unwrap() {
            Payload::Coefs { coefs, .. } => assert_eq!(coefs.len(), 3 * 8 * 8 * 64),
            other => panic!("{other:?}"),
        }
        match cpu_stage(&bytes, Placement::Hybrid0, aug, 56).unwrap() {
            Payload::Pixels { pixels, .. } => assert_eq!(pixels.len(), 3 * 64 * 64),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn collate_ready_batch() {
        let samples: Vec<Sample> = (0..4)
            .map(|i| Sample {
                id: i,
                label: i as u16,
                payload: Payload::Ready(vec![i as f32; 8]),
            })
            .collect();
        let b = collate(samples).unwrap();
        assert_eq!(b.len(), 4);
        match b {
            Batch::Ready { data, labels } => {
                assert_eq!(data.len(), 32);
                assert_eq!(labels, vec![0, 1, 2, 3]);
                assert_eq!(data[8], 1.0);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn collate_rejects_mixed_kinds() {
        let samples = vec![
            Sample { id: 0, label: 0, payload: Payload::Ready(vec![0.0]) },
            Sample {
                id: 1,
                label: 0,
                payload: Payload::Pixels { pixels: vec![0.0].into(), aug: [0.0; 6] },
            },
        ];
        assert!(collate(samples).is_err());
        assert!(collate(vec![]).is_err());
    }

    #[test]
    fn cached_cpu_stage_matches_uncached_exactly() {
        // Cache transparency: for the same aug params, a prep-cache hit
        // must produce bit-identical tensors to the decode path.
        let bytes = encoded_image(3);
        let aug = AugParams { y0: 2, x0: 1, crop_h: 48, crop_w: 52, flip: true };
        let img = crate::codec::decode_cpu(&bytes).unwrap();
        let sample = prep_cache::DecodedSample::new(img.c, img.h, img.w, img.to_f32());
        match (
            cpu_stage(&bytes, Placement::Cpu, aug, 56).unwrap(),
            cpu_stage_cached(&sample, Placement::Cpu, aug, 56),
        ) {
            (Payload::Ready(a), Payload::Ready(b)) => assert_eq!(a, b),
            other => panic!("{other:?}"),
        }
        // Device placements re-enter as a hybrid0-style pixel payload.
        for pl in [Placement::Hybrid, Placement::Hybrid0] {
            match cpu_stage_cached(&sample, pl, aug, 56) {
                Payload::Pixels { pixels, aug: row } => {
                    assert_eq!(pixels[..], img.to_f32()[..]);
                    assert_eq!(row, aug.to_row());
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn admitting_stage_populates_cache_per_placement() {
        let bytes = encoded_image(4);
        let aug = AugParams::identity(64, 64);
        for pl in [Placement::Cpu, Placement::Hybrid, Placement::Hybrid0] {
            let cache = prep_cache::PrepCache::new(1 << 20, prep_cache::PrepCachePolicy::Minio);
            let p = cpu_stage_admitting(&bytes, pl, aug, 56, &cache, 9).unwrap();
            // Same hand-off format as the plain stage...
            match (pl, &p) {
                (Placement::Cpu, Payload::Ready(_))
                | (Placement::Hybrid, Payload::Coefs { .. })
                | (Placement::Hybrid0, Payload::Pixels { .. }) => {}
                other => panic!("{other:?}"),
            }
            // ...and the decoded pixels are resident for the next epoch.
            let s = cache.get(9).unwrap_or_else(|| panic!("{pl:?}: nothing admitted"));
            assert_eq!((s.c, s.h, s.w), (3, 64, 64));
            assert_eq!(s.pixels.len(), 3 * 64 * 64);
        }
        // A zero-budget cache admits nothing but the stage still works.
        let cache = prep_cache::PrepCache::new(0, prep_cache::PrepCachePolicy::Minio);
        cpu_stage_admitting(&bytes, Placement::Cpu, aug, 56, &cache, 9).unwrap();
        assert!(cache.is_empty());
    }

    #[test]
    fn fused_cpu_stage_is_bit_identical_to_full_stage() {
        let bytes = encoded_image(7);
        let opts = DecodeOpts { fused: true, max_scale_log2: 0 };
        for aug in [
            AugParams { y0: 3, x0: 11, crop_h: 37, crop_w: 41, flip: true },
            AugParams { y0: 0, x0: 0, crop_h: 40, crop_w: 40, flip: false },
            AugParams::identity(64, 64),
        ] {
            let full = cpu_stage(&bytes, Placement::Cpu, aug, 56).unwrap();
            let (fused, stats) =
                cpu_stage_planned(&bytes, Placement::Cpu, aug, 56, &opts).unwrap();
            match (full, fused) {
                (Payload::Ready(a), Payload::Ready(b)) => assert_eq!(a, b, "{aug:?}"),
                other => panic!("{other:?}"),
            }
            assert_eq!(stats.blocks_idct + stats.blocks_skipped, 3 * 64);
            if aug.crop_h < 60 {
                assert!(stats.blocks_skipped > 0, "{aug:?} skipped nothing");
            }
        }
        // Fused off falls back to the full stage with full-block stats.
        let aug = AugParams { y0: 3, x0: 11, crop_h: 37, crop_w: 41, flip: true };
        let (_, stats) =
            cpu_stage_planned(&bytes, Placement::Cpu, aug, 56, &DecodeOpts::off()).unwrap();
        assert_eq!(stats.blocks_idct, 3 * 64);
        assert_eq!(stats.blocks_skipped, 0);
        // Hybrid ships whole coefficient grids: the plan never applies.
        let (p, stats) =
            cpu_stage_planned(&bytes, Placement::Hybrid, aug, 56, &opts).unwrap();
        assert!(matches!(p, Payload::Coefs { .. }));
        assert_eq!(stats.blocks_idct, 0);
    }

    #[test]
    fn fused_hybrid0_canvas_augments_identically_on_the_device_math() {
        // The hybrid0 fused payload zeroes the skipped blocks; the device
        // augment (same math as ops::augment_fused) samples only inside
        // the crop window, so the augmented output must be identical.
        let bytes = encoded_image(8);
        let opts = DecodeOpts { fused: true, max_scale_log2: 0 };
        let aug = AugParams { y0: 9, x0: 2, crop_h: 33, crop_w: 45, flip: true };
        let full = cpu_stage(&bytes, Placement::Hybrid0, aug, 56).unwrap();
        let (fused, stats) =
            cpu_stage_planned(&bytes, Placement::Hybrid0, aug, 56, &opts).unwrap();
        assert!(stats.blocks_skipped > 0);
        let (Payload::Pixels { pixels: a, aug: ra }, Payload::Pixels { pixels: b, aug: rb }) =
            (full, fused)
        else {
            panic!("expected pixel payloads")
        };
        assert_eq!(ra, rb);
        let mut out_a = vec![0f32; 3 * 56 * 56];
        let mut out_b = vec![0f32; 3 * 56 * 56];
        ops::augment_fused(&a, 3, 64, 64, &aug, 56, 56, &mut out_a);
        ops::augment_fused(&b, 3, 64, 64, &aug, 56, 56, &mut out_b);
        assert_eq!(out_a, out_b);
    }

    #[test]
    fn scaled_admission_shrinks_entries_and_serves_hits() {
        // out_hw 16 on a 64x64 image allows a 1/2-scale cache entry (the
        // admission scale is bounded by the smallest samplable crop —
        // min_crop_side = 32 — so no future crop is ever upsampled):
        // 4x fewer bytes resident, and the hit path rescales the aug
        // params against the stored dims.
        let bytes = encoded_image(9);
        let opts = DecodeOpts { fused: true, max_scale_log2: 3 };
        let cache = prep_cache::PrepCache::new(1 << 20, prep_cache::PrepCachePolicy::Minio);
        let aug = AugParams { y0: 4, x0: 8, crop_h: 48, crop_w: 48, flip: false };
        let (p, stats) =
            cpu_stage_admitting_planned(&bytes, Placement::Cpu, aug, 16, &cache, 5, &opts)
                .unwrap();
        assert!(matches!(p, Payload::Ready(ref v) if v.len() == 3 * 16 * 16));
        assert_eq!(stats.scale_log2, 1);
        assert_eq!(stats.blocks_idct, 3 * 64, "admission decodes the whole image");
        let s = cache.get(5).expect("admitted");
        assert_eq!((s.c, s.h, s.w, s.scale_log2), (3, 32, 32, 1));
        assert_eq!((s.orig_h(), s.orig_w()), (64, 64));
        assert_eq!(s.byte_size(), 3 * 32 * 32 * 4);
        // Every samplable crop (side >= 32) still covers the 16px output
        // at this scale: stored pixels are only ever downsampled.
        assert!(crate::ops::min_crop_side(64, 64) as usize >> s.scale_log2 >= 16);
        // A hit augments the scaled pixels into the same output shape...
        let hit = cpu_stage_cached(&s, Placement::Cpu, aug, 16);
        let Payload::Ready(hit_out) = hit else { panic!() };
        assert_eq!(hit_out.len(), 3 * 16 * 16);
        // ...and matches the miss path exactly (same stored pixels, same
        // rescaled params).
        let Payload::Ready(miss_out) = p else { panic!() };
        assert_eq!(hit_out, miss_out);
        // A zero-budget cache refuses admission; the stage degrades to
        // the plain fused ROI path.
        let empty = prep_cache::PrepCache::new(0, prep_cache::PrepCachePolicy::Minio);
        let (_, stats) =
            cpu_stage_admitting_planned(&bytes, Placement::Cpu, aug, 16, &empty, 5, &opts)
                .unwrap();
        assert!(empty.is_empty());
        assert!(stats.blocks_skipped > 0, "no admission -> ROI skip");
    }

    #[test]
    fn hybrid0_admission_falls_back_to_full_decode() {
        // The hybrid0 device payload shape is fixed at full resolution,
        // so admission decodes (and caches) whole full-res images.
        let bytes = encoded_image(10);
        let opts = DecodeOpts { fused: true, max_scale_log2: 3 };
        let cache = prep_cache::PrepCache::new(1 << 20, prep_cache::PrepCachePolicy::Minio);
        let aug = AugParams { y0: 4, x0: 8, crop_h: 40, crop_w: 40, flip: false };
        let (p, stats) =
            cpu_stage_admitting_planned(&bytes, Placement::Hybrid0, aug, 56, &cache, 6, &opts)
                .unwrap();
        assert!(matches!(p, Payload::Pixels { ref pixels, .. } if pixels.len() == 3 * 64 * 64));
        assert_eq!(stats.blocks_skipped, 0, "whole image admitted");
        let s = cache.get(6).expect("admitted");
        assert_eq!((s.h, s.w, s.scale_log2), (64, 64, 0));
        // Refused admission -> fused ROI canvas, nothing cached.
        let empty = prep_cache::PrepCache::new(0, prep_cache::PrepCachePolicy::Minio);
        let (_, stats) =
            cpu_stage_admitting_planned(&bytes, Placement::Hybrid0, aug, 56, &empty, 6, &opts)
                .unwrap();
        assert!(empty.is_empty());
        assert!(stats.blocks_skipped > 0);
    }

    #[test]
    fn hybrid_admission_counts_its_cache_only_transform() {
        // The hybrid arm's admission runs a full dequant+IDCT to produce
        // cacheable pixels — the idct_blocks counter must see it.
        let bytes = encoded_image(11);
        let opts = DecodeOpts { fused: true, max_scale_log2: 0 };
        let aug = AugParams { y0: 0, x0: 0, crop_h: 40, crop_w: 40, flip: false };
        let cache = prep_cache::PrepCache::new(1 << 20, prep_cache::PrepCachePolicy::Minio);
        let (p, stats) =
            cpu_stage_admitting_planned(&bytes, Placement::Hybrid, aug, 56, &cache, 7, &opts)
                .unwrap();
        assert!(matches!(p, Payload::Coefs { .. }));
        assert_eq!(stats.blocks_idct, 3 * 64, "admission dequant+IDCT must be counted");
        assert!(cache.get(7).is_some());
        // Refused admission: entropy-only, no CPU transform to count.
        let empty = prep_cache::PrepCache::new(0, prep_cache::PrepCachePolicy::Minio);
        let (_, stats) =
            cpu_stage_admitting_planned(&bytes, Placement::Hybrid, aug, 56, &empty, 7, &opts)
                .unwrap();
        assert_eq!(stats.blocks_idct, 0);
        assert!(empty.is_empty());
    }

    #[test]
    fn decode_opts_derive_from_config() {
        use crate::config::{DecodeScale, RunConfig};
        let cfg = RunConfig::default();
        assert_eq!(DecodeOpts::from_config(&cfg), DecodeOpts { fused: true, max_scale_log2: 0 });
        let cfg = RunConfig {
            decode_scale: DecodeScale::Auto,
            ..RunConfig::default()
        };
        assert_eq!(DecodeOpts::from_config(&cfg), DecodeOpts { fused: true, max_scale_log2: 3 });
        let cfg = RunConfig {
            fused_decode: false,
            decode_scale: DecodeScale::Auto,
            ..RunConfig::default()
        };
        assert_eq!(DecodeOpts::from_config(&cfg), DecodeOpts::off());
    }

    #[test]
    fn collate_coefs_carries_qtable_and_aug() {
        let bytes = encoded_image(2);
        let aug = AugParams { y0: 1, x0: 2, crop_h: 50, crop_w: 40, flip: true };
        let p = cpu_stage(&bytes, Placement::Hybrid, aug, 56).unwrap();
        let b = collate(vec![Sample { id: 0, label: 5, payload: p }]).unwrap();
        match b {
            Batch::Coefs { qtable, aug, labels, .. } => {
                assert_eq!(qtable, codec::qtable_for_quality(85));
                assert_eq!(&aug[..5], &[1.0, 2.0, 50.0, 40.0, 1.0]);
                assert_eq!(labels, vec![5]);
            }
            _ => panic!(),
        }
    }
}
