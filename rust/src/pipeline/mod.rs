//! The staged preprocessing pipeline (Fig. 1 of the paper, made real).
//!
//! Thread topology (all queues bounded — backpressure is load-bearing):
//!
//! ```text
//!  source ──work q──▶ cpu workers ×N ──sample q──▶ batcher ──batch q──▶ device
//!  (epoch order /     (read, entropy/full         (collate B)          (fused HLO
//!   shard streams)     decode, augment)                                 preproc +
//!                                                                       train step)
//! ```
//!
//! Placement decides how much work the CPU stage does per image:
//! * `cpu`     — full decode + augment on CPU; device only trains.
//! * `hybrid`  — entropy decode on CPU; dequant+IDCT+augment on device
//!               (one fused artifact — DALI's hybrid decode).
//! * `hybrid0` — full decode on CPU; augment on device.

pub mod channel;
pub mod prep_cache;
pub mod shuffle;
pub mod source;

use crate::config::Placement;
use crate::ops::{self, AugParams};
use prep_cache::{DecodedSample, PrepCache};
use std::sync::Arc;

/// What the CPU stage produced for one image, by placement.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Fully preprocessed, normalized `[C, OUT, OUT]` pixels (cpu placement).
    Ready(Vec<f32>),
    /// Entropy-decoded coefficients `[C, H/8, W/8, 8, 8]` + aug row (hybrid).
    Coefs { coefs: Vec<f32>, qtable: [f32; 64], aug: [f32; 6] },
    /// Decoded `[C, H, W]` pixels + aug row (hybrid0).  Shared, so a
    /// prep-cache hit hands its resident buffer on as a refcount bump.
    Pixels { pixels: std::sync::Arc<[f32]>, aug: [f32; 6] },
}

#[derive(Clone, Debug)]
pub struct Sample {
    pub id: u64,
    pub label: u16,
    pub payload: Payload,
}

/// A collated batch, homogeneous in payload kind.
#[derive(Clone, Debug)]
pub struct BatchKindError;

#[derive(Clone, Debug)]
pub enum Batch {
    Ready { data: Vec<f32>, labels: Vec<i32> },
    Coefs { data: Vec<f32>, qtable: [f32; 64], aug: Vec<f32>, labels: Vec<i32> },
    Pixels { data: Vec<f32>, aug: Vec<f32>, labels: Vec<i32> },
}

impl Batch {
    pub fn len(&self) -> usize {
        match self {
            Batch::Ready { labels, .. }
            | Batch::Coefs { labels, .. }
            | Batch::Pixels { labels, .. } => labels.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn labels(&self) -> &[i32] {
        match self {
            Batch::Ready { labels, .. }
            | Batch::Coefs { labels, .. }
            | Batch::Pixels { labels, .. } => labels,
        }
    }
}

/// Collate `batch_size` samples into one `Batch`.  Samples must share the
/// payload kind (guaranteed: placement is fixed per run).
pub fn collate(samples: Vec<Sample>) -> Result<Batch, BatchKindError> {
    let mut labels = Vec::with_capacity(samples.len());
    match samples.first().map(|s| &s.payload) {
        Some(Payload::Ready(_)) => {
            let mut data = Vec::new();
            for s in samples {
                let Payload::Ready(v) = s.payload else { return Err(BatchKindError) };
                data.extend_from_slice(&v);
                labels.push(s.label as i32);
            }
            Ok(Batch::Ready { data, labels })
        }
        Some(Payload::Coefs { qtable, .. }) => {
            let qtable = *qtable;
            let mut data = Vec::new();
            let mut aug = Vec::new();
            for s in samples {
                let Payload::Coefs { coefs, aug: a, .. } = s.payload else {
                    return Err(BatchKindError);
                };
                data.extend_from_slice(&coefs);
                aug.extend_from_slice(&a);
                labels.push(s.label as i32);
            }
            Ok(Batch::Coefs { data, qtable, aug, labels })
        }
        Some(Payload::Pixels { .. }) => {
            let mut data = Vec::new();
            let mut aug = Vec::new();
            for s in samples {
                let Payload::Pixels { pixels, aug: a } = s.payload else {
                    return Err(BatchKindError);
                };
                data.extend_from_slice(&pixels);
                aug.extend_from_slice(&a);
                labels.push(s.label as i32);
            }
            Ok(Batch::Pixels { data, aug, labels })
        }
        None => Err(BatchKindError),
    }
}

/// The per-image CPU-stage work: decode `bytes` (an MJX bitstream) to the
/// placement's hand-off format.  `aug` was sampled by the coordinator.
pub fn cpu_stage(
    bytes: &[u8],
    placement: Placement,
    aug: AugParams,
    out_hw: usize,
) -> anyhow::Result<Payload> {
    match placement {
        Placement::Cpu => {
            let img = crate::codec::decode_cpu(bytes)?;
            let f = img.to_f32();
            let mut out = vec![0f32; img.c * out_hw * out_hw];
            ops::augment_fused(&f, img.c, img.h, img.w, &aug, out_hw, out_hw, &mut out);
            Ok(Payload::Ready(out))
        }
        Placement::Hybrid => {
            let ci = crate::codec::entropy_decode(bytes)?;
            Ok(Payload::Coefs { coefs: ci.coefs, qtable: ci.qtable, aug: aug.to_row() })
        }
        Placement::Hybrid0 => {
            let img = crate::codec::decode_cpu(bytes)?;
            Ok(Payload::Pixels { pixels: img.to_f32().into(), aug: aug.to_row() })
        }
    }
}

/// Like [`cpu_stage`], but admits the decoded (pre-augment) pixels into
/// the prep cache so later epochs skip the decode.  Under the hybrid
/// placement the entropy path never produces full pixels, so the extra
/// dequant+IDCT is run for admission only when the cache would accept the
/// sample (one-time cost ≪ the per-epoch decode it amortizes away).
pub fn cpu_stage_admitting(
    bytes: &[u8],
    placement: Placement,
    aug: AugParams,
    out_hw: usize,
    cache: &PrepCache,
    id: u64,
) -> anyhow::Result<Payload> {
    let px_bytes = |c: usize, h: usize, w: usize| c * h * w * std::mem::size_of::<f32>();
    match placement {
        Placement::Cpu => {
            let img = crate::codec::decode_cpu(bytes)?;
            // Share one pixel buffer between cache and augment: the
            // admission is a refcount bump, not a second full copy.
            let pixels: Arc<[f32]> = img.to_f32().into();
            if cache.would_admit(px_bytes(img.c, img.h, img.w)) {
                cache.admit(
                    id,
                    Arc::new(DecodedSample {
                        c: img.c,
                        h: img.h,
                        w: img.w,
                        pixels: pixels.clone(),
                    }),
                );
            }
            let mut out = vec![0f32; img.c * out_hw * out_hw];
            ops::augment_fused(&pixels, img.c, img.h, img.w, &aug, out_hw, out_hw, &mut out);
            Ok(Payload::Ready(out))
        }
        Placement::Hybrid => {
            let ci = crate::codec::entropy_decode(bytes)?;
            if cache.would_admit(px_bytes(ci.c, ci.h, ci.w)) {
                let img = crate::codec::coefs_to_image(&ci);
                cache.admit(
                    id,
                    Arc::new(DecodedSample::new(img.c, img.h, img.w, img.to_f32())),
                );
            }
            Ok(Payload::Coefs { coefs: ci.coefs, qtable: ci.qtable, aug: aug.to_row() })
        }
        Placement::Hybrid0 => {
            let img = crate::codec::decode_cpu(bytes)?;
            // Payload and cache share one buffer — admission is free.
            let pixels: Arc<[f32]> = img.to_f32().into();
            if cache.would_admit(px_bytes(img.c, img.h, img.w)) {
                cache.admit(
                    id,
                    Arc::new(DecodedSample {
                        c: img.c,
                        h: img.h,
                        w: img.w,
                        pixels: pixels.clone(),
                    }),
                );
            }
            Ok(Payload::Pixels { pixels, aug: aug.to_row() })
        }
    }
}

/// The CPU-stage work for a prep-cache hit: read+decode are skipped.
/// `cpu` placement augments the cached pixels in place; the device
/// placements re-enter as a hybrid0-style pixel payload (the device runs
/// the augment artifact), so a hybrid run's batches stay homogeneous per
/// batch via the batcher's per-kind collation.
pub fn cpu_stage_cached(
    sample: &DecodedSample,
    placement: Placement,
    aug: AugParams,
    out_hw: usize,
) -> Payload {
    match placement {
        Placement::Cpu => {
            let mut out = vec![0f32; sample.c * out_hw * out_hw];
            ops::augment_fused(
                &sample.pixels,
                sample.c,
                sample.h,
                sample.w,
                &aug,
                out_hw,
                out_hw,
                &mut out,
            );
            Payload::Ready(out)
        }
        Placement::Hybrid | Placement::Hybrid0 => {
            // Refcount bump: the warm path never copies the pixels.
            Payload::Pixels { pixels: sample.pixels.clone(), aug: aug.to_row() }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec;
    use crate::dataset;
    use crate::util::rng::Rng;

    fn encoded_image(seed: u64) -> Vec<u8> {
        let img = dataset::gen_image(&mut Rng::new(seed), 3, 3, 64, 64);
        codec::encode(&img, 85).unwrap()
    }

    #[test]
    fn cpu_stage_shapes_per_placement() {
        let bytes = encoded_image(1);
        let aug = AugParams::identity(64, 64);
        match cpu_stage(&bytes, Placement::Cpu, aug, 56).unwrap() {
            Payload::Ready(v) => assert_eq!(v.len(), 3 * 56 * 56),
            other => panic!("{other:?}"),
        }
        match cpu_stage(&bytes, Placement::Hybrid, aug, 56).unwrap() {
            Payload::Coefs { coefs, .. } => assert_eq!(coefs.len(), 3 * 8 * 8 * 64),
            other => panic!("{other:?}"),
        }
        match cpu_stage(&bytes, Placement::Hybrid0, aug, 56).unwrap() {
            Payload::Pixels { pixels, .. } => assert_eq!(pixels.len(), 3 * 64 * 64),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn collate_ready_batch() {
        let samples: Vec<Sample> = (0..4)
            .map(|i| Sample {
                id: i,
                label: i as u16,
                payload: Payload::Ready(vec![i as f32; 8]),
            })
            .collect();
        let b = collate(samples).unwrap();
        assert_eq!(b.len(), 4);
        match b {
            Batch::Ready { data, labels } => {
                assert_eq!(data.len(), 32);
                assert_eq!(labels, vec![0, 1, 2, 3]);
                assert_eq!(data[8], 1.0);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn collate_rejects_mixed_kinds() {
        let samples = vec![
            Sample { id: 0, label: 0, payload: Payload::Ready(vec![0.0]) },
            Sample {
                id: 1,
                label: 0,
                payload: Payload::Pixels { pixels: vec![0.0].into(), aug: [0.0; 6] },
            },
        ];
        assert!(collate(samples).is_err());
        assert!(collate(vec![]).is_err());
    }

    #[test]
    fn cached_cpu_stage_matches_uncached_exactly() {
        // Cache transparency: for the same aug params, a prep-cache hit
        // must produce bit-identical tensors to the decode path.
        let bytes = encoded_image(3);
        let aug = AugParams { y0: 2, x0: 1, crop_h: 48, crop_w: 52, flip: true };
        let img = crate::codec::decode_cpu(&bytes).unwrap();
        let sample = prep_cache::DecodedSample::new(img.c, img.h, img.w, img.to_f32());
        match (
            cpu_stage(&bytes, Placement::Cpu, aug, 56).unwrap(),
            cpu_stage_cached(&sample, Placement::Cpu, aug, 56),
        ) {
            (Payload::Ready(a), Payload::Ready(b)) => assert_eq!(a, b),
            other => panic!("{other:?}"),
        }
        // Device placements re-enter as a hybrid0-style pixel payload.
        for pl in [Placement::Hybrid, Placement::Hybrid0] {
            match cpu_stage_cached(&sample, pl, aug, 56) {
                Payload::Pixels { pixels, aug: row } => {
                    assert_eq!(pixels[..], img.to_f32()[..]);
                    assert_eq!(row, aug.to_row());
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn admitting_stage_populates_cache_per_placement() {
        let bytes = encoded_image(4);
        let aug = AugParams::identity(64, 64);
        for pl in [Placement::Cpu, Placement::Hybrid, Placement::Hybrid0] {
            let cache = prep_cache::PrepCache::new(1 << 20, prep_cache::PrepCachePolicy::Minio);
            let p = cpu_stage_admitting(&bytes, pl, aug, 56, &cache, 9).unwrap();
            // Same hand-off format as the plain stage...
            match (pl, &p) {
                (Placement::Cpu, Payload::Ready(_))
                | (Placement::Hybrid, Payload::Coefs { .. })
                | (Placement::Hybrid0, Payload::Pixels { .. }) => {}
                other => panic!("{other:?}"),
            }
            // ...and the decoded pixels are resident for the next epoch.
            let s = cache.get(9).unwrap_or_else(|| panic!("{pl:?}: nothing admitted"));
            assert_eq!((s.c, s.h, s.w), (3, 64, 64));
            assert_eq!(s.pixels.len(), 3 * 64 * 64);
        }
        // A zero-budget cache admits nothing but the stage still works.
        let cache = prep_cache::PrepCache::new(0, prep_cache::PrepCachePolicy::Minio);
        cpu_stage_admitting(&bytes, Placement::Cpu, aug, 56, &cache, 9).unwrap();
        assert!(cache.is_empty());
    }

    #[test]
    fn collate_coefs_carries_qtable_and_aug() {
        let bytes = encoded_image(2);
        let aug = AugParams { y0: 1, x0: 2, crop_h: 50, crop_w: 40, flip: true };
        let p = cpu_stage(&bytes, Placement::Hybrid, aug, 56).unwrap();
        let b = collate(vec![Sample { id: 0, label: 5, payload: p }]).unwrap();
        match b {
            Batch::Coefs { qtable, aug, labels, .. } => {
                assert_eq!(qtable, codec::qtable_for_quality(85));
                assert_eq!(&aug[..5], &[1.0, 2.0, 50.0, 40.0, 1.0]);
                assert_eq!(labels, vec![5]);
            }
            _ => panic!(),
        }
    }
}
