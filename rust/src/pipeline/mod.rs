//! The staged preprocessing pipeline (Fig. 1 of the paper, made real).
//!
//! Thread topology (all queues bounded — backpressure is load-bearing):
//!
//! ```text
//!  source ──work q──▶ cpu workers ×[min..max] ──sample q──▶ batcher ──batch q──▶ device
//!  (epoch order /     (elastic pool, exec.rs:       (collate B)          (fused HLO
//!   shard streams)     each worker runs the one                           preproc +
//!                      StageCtx chain: read →                             train step)
//!                      cache-lookup → decode(plan)
//!                      → admit → augment → handoff)
//! ```
//!
//! The worker count is no longer fixed: `exec::ElasticPool` grows and
//! parks workers from live backpressure signals (queue waits + sampled
//! occupancy), so preprocessing capacity tracks what the device actually
//! consumes instead of a preset `--workers` guess.
//!
//! Placement decides how much work the CPU stage does per image:
//! * `cpu`     — full decode + augment on CPU; device only trains.
//! * `hybrid`  — entropy decode on CPU; dequant+IDCT+augment on device
//!               (one fused artifact — DALI's hybrid decode).
//! * `hybrid0` — full decode on CPU; augment on device.

pub mod channel;
pub mod exec;
pub mod prep_cache;
pub mod quarantine;
pub mod shuffle;
pub mod source;

use crate::codec::{DecodePlan, DecodeStats};
use crate::config::{Placement, RunConfig};
use crate::metrics::trace::{Stage, Tracer};
use crate::ops::{self, AugParams};
use prep_cache::{DecodedSample, PrepCache};
use std::sync::Arc;

pub use crate::util::slab::{SlabSlice, TensorBuf};

/// What the CPU stage produced for one image, by placement.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Fully preprocessed, normalized `[C, OUT, OUT]` pixels (cpu placement).
    Ready(Vec<f32>),
    /// Entropy-decoded coefficients `[C, H/8, W/8, 8, 8]` + aug row (hybrid).
    Coefs { coefs: Vec<f32>, qtable: [f32; 64], aug: [f32; 6] },
    /// Decoded `[C, H, W]` pixels + aug row (hybrid0).  Shared, so a
    /// prep-cache hit hands its resident buffer on as a refcount bump.
    Pixels { pixels: std::sync::Arc<[f32]>, aug: [f32; 6] },
    /// Augmented output already resident in its pooled batch-slab slot
    /// (`--slab-pool`, cpu placement): collation is a seal, not a copy.
    Slot(SlabSlice),
}

#[derive(Clone, Debug)]
pub struct Sample {
    pub id: u64,
    pub label: u16,
    pub payload: Payload,
}

/// A collated batch, homogeneous in payload kind.
#[derive(Clone, Debug)]
pub struct BatchKindError;

#[derive(Clone, Debug)]
pub enum Batch {
    /// `data` is a `Vec` on the collate path, a sealed slab on the
    /// zero-copy path; both deref to the same `[B·C·OUT·OUT]` slice.
    Ready { data: TensorBuf, labels: Vec<i32> },
    Coefs { data: Vec<f32>, qtable: [f32; 64], aug: Vec<f32>, labels: Vec<i32> },
    Pixels { data: Vec<f32>, aug: Vec<f32>, labels: Vec<i32> },
}

impl Batch {
    pub fn len(&self) -> usize {
        match self {
            Batch::Ready { labels, .. }
            | Batch::Coefs { labels, .. }
            | Batch::Pixels { labels, .. } => labels.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn labels(&self) -> &[i32] {
        match self {
            Batch::Ready { labels, .. }
            | Batch::Coefs { labels, .. }
            | Batch::Pixels { labels, .. } => labels,
        }
    }
}

/// Collate `batch_size` samples into one `Batch`.  Samples must share the
/// payload kind (guaranteed: placement is fixed per run).
///
/// `data`/`aug` are preallocated at exact capacity from the first
/// sample's payload length × batch size (payloads are homogeneous per
/// batch), so the batcher hot path never reallocates mid-collation.
/// Slab-slot samples never copy at all: their collation is
/// [`seal_slab_batch`] — slot order, one slab, zero memcpy.
pub fn collate(samples: Vec<Sample>) -> Result<Batch, BatchKindError> {
    let n = samples.len();
    let mut labels = Vec::with_capacity(n);
    match samples.first().map(|s| &s.payload) {
        Some(Payload::Slot(_)) => seal_slab_batch(samples),
        Some(Payload::Ready(first)) => {
            let mut data = Vec::with_capacity(first.len() * n);
            for s in samples {
                let Payload::Ready(v) = s.payload else { return Err(BatchKindError) };
                data.extend_from_slice(&v);
                labels.push(s.label as i32);
            }
            Ok(Batch::Ready { data: data.into(), labels })
        }
        Some(Payload::Coefs { coefs: first, qtable, .. }) => {
            let qtable = *qtable;
            let mut data = Vec::with_capacity(first.len() * n);
            let mut aug = Vec::with_capacity(6 * n);
            for s in samples {
                let Payload::Coefs { coefs, aug: a, .. } = s.payload else {
                    return Err(BatchKindError);
                };
                data.extend_from_slice(&coefs);
                aug.extend_from_slice(&a);
                labels.push(s.label as i32);
            }
            Ok(Batch::Coefs { data, qtable, aug, labels })
        }
        Some(Payload::Pixels { pixels: first, .. }) => {
            let mut data = Vec::with_capacity(first.len() * n);
            let mut aug = Vec::with_capacity(6 * n);
            for s in samples {
                let Payload::Pixels { pixels, aug: a } = s.payload else {
                    return Err(BatchKindError);
                };
                data.extend_from_slice(&pixels);
                aug.extend_from_slice(&a);
                labels.push(s.label as i32);
            }
            Ok(Batch::Pixels { data, aug, labels })
        }
        None => Err(BatchKindError),
    }
}

/// Slab finalization: the zero-copy replacement for the Ready arm's
/// O(batch·pixels) memcpy.  The batcher groups slot samples by slab
/// generation (worker interleaving can split consecutive slabs across
/// the sample stream), so the group arriving here must be exactly one
/// fully-filled slab; data position = slab slot, labels fill in slot
/// order, and `seal` verifies completeness before any read exists.
fn seal_slab_batch(samples: Vec<Sample>) -> Result<Batch, BatchKindError> {
    let n = samples.len();
    let mut labels = vec![0i32; n];
    let mut slices = Vec::with_capacity(n);
    for s in samples {
        let Payload::Slot(sl) = s.payload else { return Err(BatchKindError) };
        if sl.slot() >= n {
            return Err(BatchKindError);
        }
        labels[sl.slot()] = s.label as i32;
        slices.push(sl);
    }
    let tensor = crate::util::slab::seal(slices).map_err(|_| BatchKindError)?;
    Ok(Batch::Ready { data: TensorBuf::Slab(tensor), labels })
}

// ---------------------------------------------------------------------------
// The unified per-sample CPU-stage chain
// ---------------------------------------------------------------------------

/// Decode policy for the CPU stage (`--fused-decode` / `--decode-scale`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeOpts {
    /// Entropy-skip blocks outside the crop ROI (`cpu`/`hybrid0` paths;
    /// `hybrid` ships whole coefficient grids to the device regardless).
    pub fused: bool,
    /// Largest fractional-scale exponent the plan may pick (0 = full
    /// resolution only; `cpu`-placement path only).
    pub max_scale_log2: u8,
}

impl DecodeOpts {
    /// Full decode everywhere — the pre-fused behavior.
    pub fn off() -> Self {
        DecodeOpts { fused: false, max_scale_log2: 0 }
    }

    pub fn from_config(cfg: &RunConfig) -> Self {
        DecodeOpts {
            fused: cfg.fused_decode,
            max_scale_log2: if cfg.fused_decode { cfg.decode_scale.max_log2() } else { 0 },
        }
    }
}

/// Per-image decode telemetry from the CPU stage (feeds the runner's
/// `idct_blocks*` counters and `decode_scale_hist`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageStats {
    pub blocks_idct: u64,
    pub blocks_skipped: u64,
    /// Scale the decode actually ran at (0 when nothing was decoded on
    /// the CPU, e.g. the hybrid entropy-only path).
    pub scale_log2: u8,
}

impl StageStats {
    fn from_decode(d: &DecodeStats, scale_log2: usize) -> Self {
        StageStats {
            blocks_idct: d.blocks_idct,
            blocks_skipped: d.blocks_skipped,
            scale_log2: scale_log2 as u8,
        }
    }
}

/// Everything the per-sample chain needs, fixed for the whole run.  The
/// cache × plan × placement axes *compose* here — one context, one
/// entry point per path (miss / hit) — instead of multiplying into
/// per-combination `cpu_stage*` functions (this replaced five of them).
#[derive(Clone)]
pub struct StageCtx {
    pub placement: Placement,
    pub decode_opts: DecodeOpts,
    /// Decoded-sample cache shared across workers and epochs (`None`
    /// disables the cache-lookup/admit links of the chain).
    pub prep_cache: Option<Arc<PrepCache>>,
    /// Training output side (the augment target resolution).
    pub out_hw: usize,
    /// Per-stage span recorder ([`Tracer::off`] by default — the chain
    /// then pays one branch per would-be span).
    pub tracer: Tracer,
}

fn px_bytes(c: usize, h: usize, w: usize) -> usize {
    c * h * w * std::mem::size_of::<f32>()
}

/// Per-worker reusable scratch for the zero-copy chain: the decode
/// target, the u8→f32 conversion buffer, and the augment interpolation
/// tables.  Handed to each worker by the elastic executor's stateful
/// spawn, and dropped when the controller parks the worker — parked
/// capacity holds no scratch memory.
#[derive(Debug)]
pub struct StageScratch {
    img: crate::codec::Image,
    fbuf: Vec<f32>,
    aug: ops::AugScratch,
}

impl StageScratch {
    pub fn new() -> Self {
        StageScratch {
            img: crate::codec::Image::new(0, 0, 0),
            fbuf: Vec::new(),
            aug: ops::AugScratch::new(),
        }
    }
}

impl Default for StageScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl StageCtx {
    /// Plain full-decode chain: no cache, no fused plan (the historical
    /// `cpu_stage` behavior).
    pub fn new(placement: Placement, out_hw: usize) -> Self {
        StageCtx {
            placement,
            decode_opts: DecodeOpts::off(),
            prep_cache: None,
            out_hw,
            tracer: Tracer::off(),
        }
    }

    pub fn with_opts(mut self, opts: DecodeOpts) -> Self {
        self.decode_opts = opts;
        self
    }

    pub fn with_cache(mut self, cache: Arc<PrepCache>) -> Self {
        self.prep_cache = Some(cache);
        self
    }

    pub fn with_tracer(mut self, tracer: Tracer) -> Self {
        self.tracer = tracer;
        self
    }

    pub fn from_config(cfg: &RunConfig, prep_cache: Option<Arc<PrepCache>>, out_hw: usize) -> Self {
        StageCtx {
            placement: cfg.placement,
            decode_opts: DecodeOpts::from_config(cfg),
            prep_cache,
            out_hw,
            tracer: Tracer::off(),
        }
    }

    /// The per-image CPU-stage chain for a cache **miss** (or cache-less
    /// run): `decode(plan) → admit → augment → hand-off payload`.
    /// `bytes` is an MJX bitstream; `aug` was sampled by the worker in
    /// full-image coordinates.
    ///
    /// Behavior is the composition of three orthogonal axes:
    /// * **placement** picks the hand-off format (Ready/Coefs/Pixels);
    /// * **decode_opts** picks whole-image vs ROI/fractional-scale decode
    ///   (the fused path is bit-identical at full scale, property-tested
    ///   in `tests/fused_decode.rs`);
    /// * **prep_cache** inserts the admission link: cache entries must
    ///   serve *any* future epoch's crop, so admission decodes whole
    ///   images — under `cpu` optionally at the largest scale every
    ///   samplable crop tolerates, shrinking entries by 4^k.
    pub fn run_stage(
        &self,
        bytes: &[u8],
        id: u64,
        aug: AugParams,
    ) -> anyhow::Result<(Payload, StageStats)> {
        let (c, h, w, _q) = crate::codec::probe(bytes)?;
        match self.placement {
            Placement::Cpu => self.cpu_chain(bytes, id, c, h, w, aug),
            Placement::Hybrid => self.hybrid_chain(bytes, id, c, h, w, aug),
            Placement::Hybrid0 => self.hybrid0_chain(bytes, id, c, h, w, aug),
        }
    }

    /// The chain for a prep-cache **hit**: read+decode are skipped.
    /// `cpu` placement augments the cached pixels in place; the device
    /// placements re-enter as a hybrid0-style pixel payload (the device
    /// runs the augment artifact), so a hybrid run's batches stay
    /// homogeneous per batch via the batcher's per-kind collation.
    ///
    /// `aug` is in *original-image* coordinates (sampled against
    /// [`DecodedSample::orig_h`]/`orig_w`, so the aug stream is
    /// independent of how the pixels were stored); a fractionally-scaled
    /// entry rescales it into stored-pixel space here.  Only the `cpu`
    /// placement ever admits scaled entries — the device augment
    /// artifact's input shape is fixed at full resolution.
    pub fn run_stage_cached(&self, sample: &DecodedSample, aug: AugParams) -> Payload {
        match self.placement {
            Placement::Cpu => {
                let mut out = vec![0f32; sample.c * self.out_hw * self.out_hw];
                self.cached_chain_into(sample, aug, &mut ops::AugScratch::new(), &mut out);
                Payload::Ready(out)
            }
            Placement::Hybrid | Placement::Hybrid0 => {
                debug_assert_eq!(
                    sample.scale_log2, 0,
                    "device placements never cache scaled pixels"
                );
                let span = self.tracer.start();
                // Refcount bump: the warm path never copies the pixels.
                let p = Payload::Pixels { pixels: sample.pixels.clone(), aug: aug.to_row() };
                self.tracer.record(Stage::CacheHit, 0, span);
                p
            }
        }
    }

    /// The miss chain with caller-owned output and scratch (`--slab-pool`):
    /// identical math to [`run_stage`] — the allocating chain now wraps
    /// this one — but decode lands in per-worker scratch and the
    /// augmented sample lands directly in `out` (its batch-slab slot).
    /// At steady state the only f32s written are the ones training
    /// reads.  Cpu placement only: the device placements hand off
    /// coefficient/pixel payloads, not final tensors.
    pub fn run_stage_into(
        &self,
        bytes: &[u8],
        id: u64,
        aug: AugParams,
        scratch: &mut StageScratch,
        out: &mut [f32],
    ) -> anyhow::Result<StageStats> {
        anyhow::ensure!(
            self.placement == Placement::Cpu,
            "slab hand-off is a cpu-placement path, not {:?}",
            self.placement
        );
        let (c, h, w, _q) = crate::codec::probe(bytes)?;
        self.cpu_chain_into(bytes, id, c, h, w, aug, scratch, out)
    }

    /// The hit chain with caller-owned output: the resident pixels are
    /// read in place and the single write is the augmented sample into
    /// its batch-slab slot — a cache hit copies no pixel bytes beyond
    /// that one write.  Cpu placement only (device placements hand the
    /// resident `Arc` on as a refcount bump instead).
    pub fn run_stage_cached_into(
        &self,
        sample: &DecodedSample,
        aug: AugParams,
        scratch: &mut StageScratch,
        out: &mut [f32],
    ) {
        assert!(
            self.placement == Placement::Cpu,
            "slab hand-off is a cpu-placement path, not {:?}",
            self.placement
        );
        self.cached_chain_into(sample, aug, &mut scratch.aug, out)
    }

    /// Shared hit-path augment: original-coordinate params rescaled into
    /// stored-pixel space, then one fused pass into `out`.
    fn cached_chain_into(
        &self,
        sample: &DecodedSample,
        aug: AugParams,
        scratch: &mut ops::AugScratch,
        out: &mut [f32],
    ) {
        let span = self.tracer.start();
        let aug = rescale_aug(&aug, 0, 0, sample.scale_log2, sample.h, sample.w);
        ops::augment_fused_into(
            &sample.pixels,
            sample.c,
            sample.h,
            sample.w,
            &aug,
            self.out_hw,
            self.out_hw,
            scratch,
            out,
        );
        self.tracer.record(Stage::CacheHit, 0, span);
    }

    /// `cpu` placement: decode + augment both run here.  The allocating
    /// entry point — fresh output + fresh scratch around the shared
    /// zero-copy chain, so the two paths cannot drift.
    fn cpu_chain(
        &self,
        bytes: &[u8],
        id: u64,
        c: usize,
        h: usize,
        w: usize,
        aug: AugParams,
    ) -> anyhow::Result<(Payload, StageStats)> {
        let mut out = vec![0f32; c * self.out_hw * self.out_hw];
        let mut scratch = StageScratch::new();
        let stats = self.cpu_chain_into(bytes, id, c, h, w, aug, &mut scratch, &mut out)?;
        Ok((Payload::Ready(out), stats))
    }

    /// The one cpu chain, allocation-free at steady state: decode into
    /// `scratch` (capacity reused across samples), augment into `out`
    /// (the batch-slab slot on the slab path, a fresh `Vec` on the
    /// legacy one).  The cache-admission link still allocates — the
    /// resident entry is a genuine new allocation, and a full MinIO
    /// cache refuses admission in steady state anyway.
    #[allow(clippy::too_many_arguments)]
    fn cpu_chain_into(
        &self,
        bytes: &[u8],
        id: u64,
        c: usize,
        h: usize,
        w: usize,
        aug: AugParams,
        scratch: &mut StageScratch,
        out: &mut [f32],
    ) -> anyhow::Result<StageStats> {
        // Admission link: whole-image decode so the entry serves any
        // future crop.  Under the fused plan the admission scale is
        // bounded by the *smallest* crop the aug distribution can draw
        // (never the per-crop geometry): stored pixels must only ever be
        // downsampled by future hits.
        if let Some(cache) = &self.prep_cache {
            let k = if self.decode_opts.fused {
                let min_crop = ops::min_crop_side(h as u32, w as u32) as usize;
                DecodePlan::image_scale(
                    min_crop,
                    min_crop,
                    self.out_hw,
                    self.decode_opts.max_scale_log2 as usize,
                )
            } else {
                0
            };
            let (sh, sw) = (h >> k, w >> k);
            if cache.would_admit(px_bytes(c, sh, sw)) {
                let span = self.tracer.start();
                let plan = DecodePlan::full_scaled(c, h, w, k);
                let dstats = crate::codec::decode_cpu_planned_into(bytes, &plan, &mut scratch.img)?;
                scratch.img.to_f32_into(&mut scratch.fbuf);
                self.tracer.record(Stage::Decode, id, span);
                // The one copy the admission pays: scratch → the cache's
                // own resident buffer (which must outlive this sample).
                let pixels: Arc<[f32]> = Arc::from(&scratch.fbuf[..]);
                cache.admit(
                    id,
                    Arc::new(DecodedSample {
                        c,
                        h: sh,
                        w: sw,
                        scale_log2: k as u8,
                        pixels: pixels.clone(),
                    }),
                );
                let span = self.tracer.start();
                let aug_s = rescale_aug(&aug, 0, 0, k as u8, sh, sw);
                ops::augment_fused_into(
                    &pixels,
                    c,
                    sh,
                    sw,
                    &aug_s,
                    self.out_hw,
                    self.out_hw,
                    &mut scratch.aug,
                    out,
                );
                self.tracer.record(Stage::Augment, id, span);
                return Ok(StageStats::from_decode(&dstats, k));
            }
        }
        // Per-crop decode link (admission refused or no cache): fused
        // ROI/fractional-scale plan, or the plain whole-image decode
        // (expressed as the full plan — bit-identical to `decode_cpu`,
        // asserted in codec tests — so one decode path serves both).
        if self.decode_opts.fused {
            let span = self.tracer.start();
            let crop =
                (aug.y0 as usize, aug.x0 as usize, aug.crop_h as usize, aug.crop_w as usize);
            let max_k = self.decode_opts.max_scale_log2 as usize;
            let plan = DecodePlan::new(c, h, w, crop, self.out_hw, max_k);
            let dstats = crate::codec::decode_cpu_planned_into(bytes, &plan, &mut scratch.img)?;
            scratch.img.to_f32_into(&mut scratch.fbuf);
            self.tracer.record(Stage::Decode, id, span);
            let span = self.tracer.start();
            let (roi_h, roi_w) = (scratch.img.h, scratch.img.w);
            let (vy, vx) = plan.origin();
            if plan.scale_log2 == 0 {
                // Bit-identical to full decode + augment (sampling runs
                // in full-image coordinates over the ROI view).
                ops::augment_fused_view_into(
                    &scratch.fbuf,
                    c,
                    h,
                    w,
                    (vy, vx, roi_h, roi_w),
                    &aug,
                    self.out_hw,
                    self.out_hw,
                    &mut scratch.aug,
                    out,
                );
            } else {
                let aug_s =
                    rescale_aug(&aug, vy as u32, vx as u32, plan.scale_log2 as u8, roi_h, roi_w);
                ops::augment_fused_into(
                    &scratch.fbuf,
                    c,
                    roi_h,
                    roi_w,
                    &aug_s,
                    self.out_hw,
                    self.out_hw,
                    &mut scratch.aug,
                    out,
                );
            }
            self.tracer.record(Stage::Augment, id, span);
            Ok(StageStats::from_decode(&dstats, plan.scale_log2))
        } else {
            let span = self.tracer.start();
            let plan = DecodePlan::full(c, h, w);
            crate::codec::decode_cpu_planned_into(bytes, &plan, &mut scratch.img)?;
            scratch.img.to_f32_into(&mut scratch.fbuf);
            self.tracer.record(Stage::Decode, id, span);
            let span = self.tracer.start();
            ops::augment_fused_into(
                &scratch.fbuf,
                c,
                h,
                w,
                &aug,
                self.out_hw,
                self.out_hw,
                &mut scratch.aug,
                out,
            );
            self.tracer.record(Stage::Augment, id, span);
            Ok(full_stage_stats(c, h, w, self.placement))
        }
    }

    /// `hybrid` placement: entropy-only on the CPU; the fused plan never
    /// applies (whole coefficient grids ship to the device).  Admission
    /// runs the cache-only dequant+IDCT when the cache would accept the
    /// sample (one-time cost ≪ the per-epoch decode it amortizes away) —
    /// that transform is real CPU work, so it enters the block counters.
    fn hybrid_chain(
        &self,
        bytes: &[u8],
        id: u64,
        c: usize,
        h: usize,
        w: usize,
        aug: AugParams,
    ) -> anyhow::Result<(Payload, StageStats)> {
        // One Decode span for the CPU-side decode work this placement
        // does: entropy decode plus the admission-time dequant+IDCT.
        let span = self.tracer.start();
        let ci = crate::codec::entropy_decode(bytes)?;
        let mut stats = full_stage_stats(c, h, w, self.placement);
        if let Some(cache) = &self.prep_cache {
            if cache.would_admit(px_bytes(ci.c, ci.h, ci.w)) {
                let img = crate::codec::coefs_to_image(&ci);
                cache.admit(
                    id,
                    Arc::new(DecodedSample::new(img.c, img.h, img.w, img.to_f32())),
                );
                stats.blocks_idct = (c * (h / 8) * (w / 8)) as u64;
            }
        }
        self.tracer.record(Stage::Decode, id, span);
        Ok((Payload::Coefs { coefs: ci.coefs, qtable: ci.qtable, aug: aug.to_row() }, stats))
    }

    /// `hybrid0` placement: full decode on the CPU, pixels to the device.
    /// Admission decodes (and caches) whole full-resolution images — the
    /// device augment artifact's input shape is fixed, so neither the
    /// payload nor the cache entry may shrink.  Without admission the
    /// fused plan decodes only the ROI blocks at their true offsets into
    /// a zeroed full-size canvas (the device samples only inside the
    /// crop window, so its output is unchanged).
    fn hybrid0_chain(
        &self,
        bytes: &[u8],
        id: u64,
        c: usize,
        h: usize,
        w: usize,
        aug: AugParams,
    ) -> anyhow::Result<(Payload, StageStats)> {
        // One Decode span per path — hybrid0's CPU work is all decode
        // (augmentation runs on the device).
        let span = self.tracer.start();
        if let Some(cache) = &self.prep_cache {
            if cache.would_admit(px_bytes(c, h, w)) {
                let img = crate::codec::decode_cpu(bytes)?;
                // Payload and cache share one buffer — admission is free.
                let pixels: Arc<[f32]> = img.to_f32().into();
                cache.admit(
                    id,
                    Arc::new(DecodedSample {
                        c,
                        h,
                        w,
                        scale_log2: 0,
                        pixels: pixels.clone(),
                    }),
                );
                self.tracer.record(Stage::Decode, id, span);
                return Ok((
                    Payload::Pixels { pixels, aug: aug.to_row() },
                    full_stage_stats(c, h, w, self.placement),
                ));
            }
        }
        if self.decode_opts.fused {
            let crop =
                (aug.y0 as usize, aug.x0 as usize, aug.crop_h as usize, aug.crop_w as usize);
            let plan = DecodePlan::new(c, h, w, crop, self.out_hw, 0);
            let (roi, dstats) = crate::codec::decode_cpu_planned(bytes, &plan)?;
            let (vy, vx) = plan.origin();
            let mut full = vec![0f32; c * h * w];
            for ch in 0..c {
                let plane = roi.plane(ch);
                for y in 0..roi.h {
                    let dst = &mut full[ch * h * w + (vy + y) * w + vx..][..roi.w];
                    let src = &plane[y * roi.w..(y + 1) * roi.w];
                    for (d, &s) in dst.iter_mut().zip(src) {
                        *d = s as f32;
                    }
                }
            }
            self.tracer.record(Stage::Decode, id, span);
            Ok((
                Payload::Pixels { pixels: full.into(), aug: aug.to_row() },
                StageStats::from_decode(&dstats, 0),
            ))
        } else {
            let img = crate::codec::decode_cpu(bytes)?;
            self.tracer.record(Stage::Decode, id, span);
            Ok((
                Payload::Pixels { pixels: img.to_f32().into(), aug: aug.to_row() },
                full_stage_stats(c, h, w, self.placement),
            ))
        }
    }
}

/// Map augmentation params from full-image coordinates into the space of
/// pixels stored at `1/2^k` with full-res origin `(vy, vx)`; `vh`x`vw`
/// are the stored dims.  Floor rounding keeps the window inside the
/// view (the fractional-scale path is tolerance-, not bit-, checked, so
/// the sub-pixel shift is acceptable by construction); `k == 0` with a
/// zero origin is the identity.
fn rescale_aug(aug: &AugParams, vy: u32, vx: u32, k: u8, vh: usize, vw: usize) -> AugParams {
    if k == 0 && vy == 0 && vx == 0 {
        return *aug;
    }
    // Fail at the fault, not via wraparound three frames later inside
    // augment_fused's length assertion.
    assert!(
        aug.y0 >= vy && aug.x0 >= vx,
        "crop origin ({},{}) outside view origin ({vy},{vx})",
        aug.y0,
        aug.x0
    );
    let y0 = (aug.y0 - vy) >> k;
    let x0 = (aug.x0 - vx) >> k;
    assert!(
        (y0 as usize) < vh && (x0 as usize) < vw,
        "scaled crop origin ({y0},{x0}) outside {vh}x{vw} view"
    );
    AugParams {
        y0,
        x0,
        crop_h: (aug.crop_h >> k).max(1).min(vh as u32 - y0),
        crop_w: (aug.crop_w >> k).max(1).min(vw as u32 - x0),
        flip: aug.flip,
    }
}

/// Block counters for a full (unplanned) decode of a `c`x`h`x`w` image:
/// a full decode dequant+IDCTs every block; the hybrid entropy-only path
/// transforms nothing on the CPU (its admission-time transform is
/// counted by the hybrid chain instead).
fn full_stage_stats(c: usize, h: usize, w: usize, placement: Placement) -> StageStats {
    let blocks = (c * (h / 8) * (w / 8)) as u64;
    StageStats {
        blocks_idct: if placement == Placement::Hybrid { 0 } else { blocks },
        blocks_skipped: 0,
        scale_log2: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec;
    use crate::dataset;
    use crate::util::rng::Rng;

    fn encoded_image(seed: u64) -> Vec<u8> {
        let img = dataset::gen_image(&mut Rng::new(seed), 3, 3, 64, 64);
        codec::encode(&img, 85).unwrap()
    }

    fn fused(max_scale_log2: u8) -> DecodeOpts {
        DecodeOpts { fused: true, max_scale_log2 }
    }

    fn minio_cache(budget: usize) -> Arc<prep_cache::PrepCache> {
        Arc::new(prep_cache::PrepCache::new(budget, prep_cache::PrepCachePolicy::Minio))
    }

    #[test]
    fn stage_shapes_per_placement() {
        let bytes = encoded_image(1);
        let aug = AugParams::identity(64, 64);
        match StageCtx::new(Placement::Cpu, 56).run_stage(&bytes, 0, aug).unwrap().0 {
            Payload::Ready(v) => assert_eq!(v.len(), 3 * 56 * 56),
            other => panic!("{other:?}"),
        }
        match StageCtx::new(Placement::Hybrid, 56).run_stage(&bytes, 0, aug).unwrap().0 {
            Payload::Coefs { coefs, .. } => assert_eq!(coefs.len(), 3 * 8 * 8 * 64),
            other => panic!("{other:?}"),
        }
        match StageCtx::new(Placement::Hybrid0, 56).run_stage(&bytes, 0, aug).unwrap().0 {
            Payload::Pixels { pixels, .. } => assert_eq!(pixels.len(), 3 * 64 * 64),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn collate_ready_batch() {
        let samples: Vec<Sample> = (0..4)
            .map(|i| Sample {
                id: i,
                label: i as u16,
                payload: Payload::Ready(vec![i as f32; 8]),
            })
            .collect();
        let b = collate(samples).unwrap();
        assert_eq!(b.len(), 4);
        match b {
            Batch::Ready { data, labels } => {
                assert_eq!(data.len(), 32);
                assert_eq!(labels, vec![0, 1, 2, 3]);
                assert_eq!(data[8], 1.0);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn collate_rejects_mixed_kinds() {
        let samples = vec![
            Sample { id: 0, label: 0, payload: Payload::Ready(vec![0.0]) },
            Sample {
                id: 1,
                label: 0,
                payload: Payload::Pixels { pixels: vec![0.0].into(), aug: [0.0; 6] },
            },
        ];
        assert!(collate(samples).is_err());
        assert!(collate(vec![]).is_err());
    }

    /// Satellite coverage: every first-kind × intruder-kind combination
    /// returns `BatchKindError` (not a panic, not a silent mix), the
    /// intruder position doesn't matter, and empty input is an error too.
    #[test]
    fn collate_error_paths_cover_all_kind_pairs() {
        fn mk(kind: usize) -> Payload {
            match kind {
                0 => Payload::Ready(vec![1.0; 4]),
                1 => Payload::Coefs { coefs: vec![1.0; 4], qtable: [0.5; 64], aug: [0.0; 6] },
                _ => Payload::Pixels { pixels: vec![1.0; 4].into(), aug: [0.0; 6] },
            }
        }
        for first in 0..3usize {
            for intruder in 0..3usize {
                if first == intruder {
                    continue;
                }
                // Intruder in the middle and at the tail.
                for pos in [1usize, 2] {
                    let samples: Vec<Sample> = (0..3)
                        .map(|i| Sample {
                            id: i as u64,
                            label: 0,
                            payload: mk(if i == pos { intruder } else { first }),
                        })
                        .collect();
                    assert!(
                        collate(samples).is_err(),
                        "first={first} intruder={intruder} pos={pos} must error"
                    );
                }
            }
            // Homogeneous batches of each kind still collate fine.
            let ok: Vec<Sample> = (0..3)
                .map(|i| Sample { id: i, label: 1, payload: mk(first) })
                .collect();
            let b = collate(ok).unwrap();
            assert_eq!(b.len(), 3);
            assert_eq!(b.labels(), &[1, 1, 1]);
        }
        assert!(matches!(collate(vec![]), Err(BatchKindError)));
    }

    #[test]
    fn cached_stage_matches_uncached_exactly() {
        // Cache transparency: for the same aug params, a prep-cache hit
        // must produce bit-identical tensors to the decode path.
        let bytes = encoded_image(3);
        let aug = AugParams { y0: 2, x0: 1, crop_h: 48, crop_w: 52, flip: true };
        let img = crate::codec::decode_cpu(&bytes).unwrap();
        let sample = prep_cache::DecodedSample::new(img.c, img.h, img.w, img.to_f32());
        let ctx = StageCtx::new(Placement::Cpu, 56);
        match (
            ctx.run_stage(&bytes, 0, aug).unwrap().0,
            ctx.run_stage_cached(&sample, aug),
        ) {
            (Payload::Ready(a), Payload::Ready(b)) => assert_eq!(a, b),
            other => panic!("{other:?}"),
        }
        // Device placements re-enter as a hybrid0-style pixel payload.
        for pl in [Placement::Hybrid, Placement::Hybrid0] {
            match StageCtx::new(pl, 56).run_stage_cached(&sample, aug) {
                Payload::Pixels { pixels, aug: row } => {
                    assert_eq!(pixels[..], img.to_f32()[..]);
                    assert_eq!(row, aug.to_row());
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn admitting_stage_populates_cache_per_placement() {
        let bytes = encoded_image(4);
        let aug = AugParams::identity(64, 64);
        for pl in [Placement::Cpu, Placement::Hybrid, Placement::Hybrid0] {
            let cache = minio_cache(1 << 20);
            let ctx = StageCtx::new(pl, 56).with_cache(cache.clone());
            let (p, _) = ctx.run_stage(&bytes, 9, aug).unwrap();
            // Same hand-off format as the cache-less chain...
            match (pl, &p) {
                (Placement::Cpu, Payload::Ready(_))
                | (Placement::Hybrid, Payload::Coefs { .. })
                | (Placement::Hybrid0, Payload::Pixels { .. }) => {}
                other => panic!("{other:?}"),
            }
            // ...and the decoded pixels are resident for the next epoch.
            let s = cache.get(9).unwrap_or_else(|| panic!("{pl:?}: nothing admitted"));
            assert_eq!((s.c, s.h, s.w), (3, 64, 64));
            assert_eq!(s.pixels.len(), 3 * 64 * 64);
        }
        // A zero-budget cache admits nothing but the chain still works.
        let cache = minio_cache(0);
        let ctx = StageCtx::new(Placement::Cpu, 56).with_cache(cache.clone());
        ctx.run_stage(&bytes, 9, aug).unwrap();
        assert!(cache.is_empty());
    }

    #[test]
    fn fused_stage_is_bit_identical_to_full_stage() {
        let bytes = encoded_image(7);
        let full_ctx = StageCtx::new(Placement::Cpu, 56);
        let fused_ctx = StageCtx::new(Placement::Cpu, 56).with_opts(fused(0));
        for aug in [
            AugParams { y0: 3, x0: 11, crop_h: 37, crop_w: 41, flip: true },
            AugParams { y0: 0, x0: 0, crop_h: 40, crop_w: 40, flip: false },
            AugParams::identity(64, 64),
        ] {
            let (full, _) = full_ctx.run_stage(&bytes, 0, aug).unwrap();
            let (fused, stats) = fused_ctx.run_stage(&bytes, 0, aug).unwrap();
            match (full, fused) {
                (Payload::Ready(a), Payload::Ready(b)) => assert_eq!(a, b, "{aug:?}"),
                other => panic!("{other:?}"),
            }
            assert_eq!(stats.blocks_idct + stats.blocks_skipped, 3 * 64);
            if aug.crop_h < 60 {
                assert!(stats.blocks_skipped > 0, "{aug:?} skipped nothing");
            }
        }
        // Fused off falls back to the full stage with full-block stats.
        let aug = AugParams { y0: 3, x0: 11, crop_h: 37, crop_w: 41, flip: true };
        let (_, stats) = full_ctx.run_stage(&bytes, 0, aug).unwrap();
        assert_eq!(stats.blocks_idct, 3 * 64);
        assert_eq!(stats.blocks_skipped, 0);
        // Hybrid ships whole coefficient grids: the plan never applies.
        let (p, stats) = StageCtx::new(Placement::Hybrid, 56)
            .with_opts(fused(0))
            .run_stage(&bytes, 0, aug)
            .unwrap();
        assert!(matches!(p, Payload::Coefs { .. }));
        assert_eq!(stats.blocks_idct, 0);
    }

    #[test]
    fn fused_hybrid0_canvas_augments_identically_on_the_device_math() {
        // The hybrid0 fused payload zeroes the skipped blocks; the device
        // augment (same math as ops::augment_fused) samples only inside
        // the crop window, so the augmented output must be identical.
        let bytes = encoded_image(8);
        let aug = AugParams { y0: 9, x0: 2, crop_h: 33, crop_w: 45, flip: true };
        let (full, _) = StageCtx::new(Placement::Hybrid0, 56).run_stage(&bytes, 0, aug).unwrap();
        let (fused_p, stats) = StageCtx::new(Placement::Hybrid0, 56)
            .with_opts(fused(0))
            .run_stage(&bytes, 0, aug)
            .unwrap();
        assert!(stats.blocks_skipped > 0);
        let (Payload::Pixels { pixels: a, aug: ra }, Payload::Pixels { pixels: b, aug: rb }) =
            (full, fused_p)
        else {
            panic!("expected pixel payloads")
        };
        assert_eq!(ra, rb);
        let mut out_a = vec![0f32; 3 * 56 * 56];
        let mut out_b = vec![0f32; 3 * 56 * 56];
        ops::augment_fused(&a, 3, 64, 64, &aug, 56, 56, &mut out_a);
        ops::augment_fused(&b, 3, 64, 64, &aug, 56, 56, &mut out_b);
        assert_eq!(out_a, out_b);
    }

    #[test]
    fn scaled_admission_shrinks_entries_and_serves_hits() {
        // out_hw 16 on a 64x64 image allows a 1/2-scale cache entry (the
        // admission scale is bounded by the smallest samplable crop —
        // min_crop_side = 32 — so no future crop is ever upsampled):
        // 4x fewer bytes resident, and the hit path rescales the aug
        // params against the stored dims.
        let bytes = encoded_image(9);
        let cache = minio_cache(1 << 20);
        let ctx = StageCtx::new(Placement::Cpu, 16).with_opts(fused(3)).with_cache(cache.clone());
        let aug = AugParams { y0: 4, x0: 8, crop_h: 48, crop_w: 48, flip: false };
        let (p, stats) = ctx.run_stage(&bytes, 5, aug).unwrap();
        assert!(matches!(p, Payload::Ready(ref v) if v.len() == 3 * 16 * 16));
        assert_eq!(stats.scale_log2, 1);
        assert_eq!(stats.blocks_idct, 3 * 64, "admission decodes the whole image");
        let s = cache.get(5).expect("admitted");
        assert_eq!((s.c, s.h, s.w, s.scale_log2), (3, 32, 32, 1));
        assert_eq!((s.orig_h(), s.orig_w()), (64, 64));
        assert_eq!(s.byte_size(), 3 * 32 * 32 * 4);
        // Every samplable crop (side >= 32) still covers the 16px output
        // at this scale: stored pixels are only ever downsampled.
        assert!(crate::ops::min_crop_side(64, 64) as usize >> s.scale_log2 >= 16);
        // A hit augments the scaled pixels into the same output shape...
        let hit = ctx.run_stage_cached(&s, aug);
        let Payload::Ready(hit_out) = hit else { panic!() };
        assert_eq!(hit_out.len(), 3 * 16 * 16);
        // ...and matches the miss path exactly (same stored pixels, same
        // rescaled params).
        let Payload::Ready(miss_out) = p else { panic!() };
        assert_eq!(hit_out, miss_out);
        // A zero-budget cache refuses admission; the chain degrades to
        // the plain fused ROI path.
        let empty = minio_cache(0);
        let ctx = StageCtx::new(Placement::Cpu, 16).with_opts(fused(3)).with_cache(empty.clone());
        let (_, stats) = ctx.run_stage(&bytes, 5, aug).unwrap();
        assert!(empty.is_empty());
        assert!(stats.blocks_skipped > 0, "no admission -> ROI skip");
    }

    #[test]
    fn hybrid0_admission_falls_back_to_full_decode() {
        // The hybrid0 device payload shape is fixed at full resolution,
        // so admission decodes (and caches) whole full-res images.
        let bytes = encoded_image(10);
        let cache = minio_cache(1 << 20);
        let ctx =
            StageCtx::new(Placement::Hybrid0, 56).with_opts(fused(3)).with_cache(cache.clone());
        let aug = AugParams { y0: 4, x0: 8, crop_h: 40, crop_w: 40, flip: false };
        let (p, stats) = ctx.run_stage(&bytes, 6, aug).unwrap();
        assert!(matches!(p, Payload::Pixels { ref pixels, .. } if pixels.len() == 3 * 64 * 64));
        assert_eq!(stats.blocks_skipped, 0, "whole image admitted");
        let s = cache.get(6).expect("admitted");
        assert_eq!((s.h, s.w, s.scale_log2), (64, 64, 0));
        // Refused admission -> fused ROI canvas, nothing cached.
        let empty = minio_cache(0);
        let ctx =
            StageCtx::new(Placement::Hybrid0, 56).with_opts(fused(3)).with_cache(empty.clone());
        let (_, stats) = ctx.run_stage(&bytes, 6, aug).unwrap();
        assert!(empty.is_empty());
        assert!(stats.blocks_skipped > 0);
    }

    #[test]
    fn hybrid_admission_counts_its_cache_only_transform() {
        // The hybrid chain's admission runs a full dequant+IDCT to
        // produce cacheable pixels — the idct_blocks counter must see it.
        let bytes = encoded_image(11);
        let aug = AugParams { y0: 0, x0: 0, crop_h: 40, crop_w: 40, flip: false };
        let cache = minio_cache(1 << 20);
        let ctx =
            StageCtx::new(Placement::Hybrid, 56).with_opts(fused(0)).with_cache(cache.clone());
        let (p, stats) = ctx.run_stage(&bytes, 7, aug).unwrap();
        assert!(matches!(p, Payload::Coefs { .. }));
        assert_eq!(stats.blocks_idct, 3 * 64, "admission dequant+IDCT must be counted");
        assert!(cache.get(7).is_some());
        // Refused admission: entropy-only, no CPU transform to count.
        let empty = minio_cache(0);
        let ctx =
            StageCtx::new(Placement::Hybrid, 56).with_opts(fused(0)).with_cache(empty.clone());
        let (_, stats) = ctx.run_stage(&bytes, 7, aug).unwrap();
        assert_eq!(stats.blocks_idct, 0);
        assert!(empty.is_empty());
    }

    #[test]
    fn decode_opts_derive_from_config() {
        use crate::config::{DecodeScale, RunConfig};
        let cfg = RunConfig::default();
        assert_eq!(DecodeOpts::from_config(&cfg), DecodeOpts { fused: true, max_scale_log2: 0 });
        let cfg = RunConfig {
            decode_scale: DecodeScale::Auto,
            ..RunConfig::default()
        };
        assert_eq!(DecodeOpts::from_config(&cfg), DecodeOpts { fused: true, max_scale_log2: 3 });
        let cfg = RunConfig {
            fused_decode: false,
            decode_scale: DecodeScale::Auto,
            ..RunConfig::default()
        };
        assert_eq!(DecodeOpts::from_config(&cfg), DecodeOpts::off());
    }

    #[test]
    fn stage_ctx_derives_from_config() {
        use crate::config::RunConfig;
        let cfg = RunConfig { placement: Placement::Hybrid0, ..RunConfig::default() };
        let cache = minio_cache(1 << 20);
        let ctx = StageCtx::from_config(&cfg, Some(cache), 56);
        assert_eq!(ctx.placement, Placement::Hybrid0);
        assert_eq!(ctx.decode_opts, DecodeOpts::from_config(&cfg));
        assert_eq!(ctx.out_hw, 56);
        assert!(ctx.prep_cache.is_some());
        let ctx = StageCtx::from_config(&cfg, None, 56);
        assert!(ctx.prep_cache.is_none());
    }

    /// Tentpole invariant: the zero-copy chain (`run_stage_into` /
    /// `run_stage_cached_into` + slab seal) produces bit-identical
    /// tensors to the allocating chain, with scratch and slabs reused
    /// across samples, across fused × prep-cache combinations.
    #[test]
    fn slab_chain_is_bit_identical_to_vec_chain() {
        use crate::util::slab::SlabPool;
        let b = 4usize;
        for fused_on in [false, true] {
            for cache_on in [false, true] {
                let opts =
                    if fused_on { fused(0) } else { DecodeOpts::off() };
                let mk_ctx = |cache: Option<Arc<prep_cache::PrepCache>>| {
                    let ctx = StageCtx::new(Placement::Cpu, 56).with_opts(opts);
                    match cache {
                        Some(c) => ctx.with_cache(c),
                        None => ctx,
                    }
                };
                let vec_ctx = mk_ctx(cache_on.then(|| minio_cache(1 << 22)));
                let slab_ctx = mk_ctx(cache_on.then(|| minio_cache(1 << 22)));
                let pool = SlabPool::new(3 * 56 * 56, b, 2);
                let mut scratch = StageScratch::new();
                let enc: Vec<Vec<u8>> = (0..b as u64).map(|i| encoded_image(40 + i)).collect();
                // Two epochs: epoch 0 exercises miss+admission, epoch 1
                // the hit chain (when the cache is on).
                for epoch in 0..2u64 {
                    let mut vec_samples = Vec::new();
                    let mut slab_samples = Vec::new();
                    for (i, bytes) in enc.iter().enumerate() {
                        let id = i as u64;
                        let aug = {
                            let mut rng = crate::util::rng::Rng::new(7).fork(id).fork(epoch);
                            ops::sample_aug_params(&mut rng, 64, 64)
                        };
                        let vp = match vec_ctx.prep_cache.as_ref().and_then(|c| c.get(id)) {
                            Some(s) => vec_ctx.run_stage_cached(&s, aug),
                            None => vec_ctx.run_stage(bytes, id, aug).unwrap().0,
                        };
                        let mut slice = pool.slice();
                        match slab_ctx.prep_cache.as_ref().and_then(|c| c.get(id)) {
                            Some(s) => slab_ctx.run_stage_cached_into(
                                &s,
                                aug,
                                &mut scratch,
                                slice.as_mut_slice(),
                            ),
                            None => {
                                slab_ctx
                                    .run_stage_into(
                                        bytes,
                                        id,
                                        aug,
                                        &mut scratch,
                                        slice.as_mut_slice(),
                                    )
                                    .unwrap();
                            }
                        }
                        vec_samples.push(Sample { id, label: i as u16, payload: vp });
                        slab_samples
                            .push(Sample { id, label: i as u16, payload: Payload::Slot(slice) });
                    }
                    let Batch::Ready { data: dv, labels: lv } = collate(vec_samples).unwrap()
                    else {
                        panic!("cpu batches must be Ready")
                    };
                    let Batch::Ready { data: ds, labels: ls } = collate(slab_samples).unwrap()
                    else {
                        panic!("cpu batches must be Ready")
                    };
                    assert_eq!(lv, ls, "fused={fused_on} cache={cache_on} epoch={epoch}");
                    assert_eq!(
                        &dv[..],
                        &ds[..],
                        "fused={fused_on} cache={cache_on} epoch={epoch}"
                    );
                }
                if cache_on {
                    assert!(
                        slab_ctx.prep_cache.as_ref().unwrap().hit_rate() > 0.0,
                        "epoch 1 must have exercised the hit chain"
                    );
                }
                // The second slab came from the recycle path.
                assert!(pool.hits() >= 1, "fused={fused_on} cache={cache_on}");
            }
        }
    }

    #[test]
    fn slab_collate_rejects_partial_mixed_and_foreign_slots() {
        use crate::util::slab::SlabPool;
        let pool = SlabPool::new(4, 2, 1);
        let mk = |slice: SlabSlice, label: u16| Sample {
            id: label as u64,
            label,
            payload: Payload::Slot(slice),
        };
        // Partial slab: one slice of a 2-slot slab.
        let s0 = pool.slice();
        assert!(collate(vec![mk(s0, 0)]).is_err());
        // Mixed slabs: slot 1 of slab A with slot 0 of slab B.
        let a1 = pool.slice();
        let b0 = pool.slice();
        assert!(collate(vec![mk(a1, 1), mk(b0, 0)]).is_err());
        // Mixed kinds: a Slot first sees a Ready intruder.
        let c0 = pool.slice();
        let intruder =
            Sample { id: 9, label: 9, payload: Payload::Ready(vec![0.0; 4]) };
        assert!(collate(vec![mk(c0, 0), intruder]).is_err());
        // And the happy path still seals: a full slab, out of order.
        let mut d0 = pool.slice();
        let mut d1 = pool.slice();
        d0.as_mut_slice().copy_from_slice(&[1.0; 4]);
        d1.as_mut_slice().copy_from_slice(&[2.0; 4]);
        let batch = collate(vec![mk(d1, 7), mk(d0, 3)]).unwrap();
        let Batch::Ready { data, labels } = batch else { panic!() };
        // Slot order, not arrival order.
        assert_eq!(labels, vec![3, 7]);
        assert_eq!(&data[..4], &[1.0; 4]);
        assert_eq!(&data[4..], &[2.0; 4]);
    }

    /// Satellite regression: a prep-cache hit copies no pixel bytes —
    /// admission shares one buffer with the payload, device-placement
    /// hits are refcount bumps, and the cpu hit's single write is the
    /// augmented sample into its output slot.
    #[test]
    fn cache_hits_and_admissions_share_pixels_without_copy() {
        let bytes = encoded_image(12);
        let aug = AugParams::identity(64, 64);
        // hybrid0 admission: payload and resident entry are one buffer.
        let cache = minio_cache(1 << 20);
        let ctx = StageCtx::new(Placement::Hybrid0, 56).with_cache(cache.clone());
        let (p, _) = ctx.run_stage(&bytes, 1, aug).unwrap();
        let Payload::Pixels { pixels, .. } = p else { panic!() };
        let resident = cache.get(1).unwrap();
        assert!(
            Arc::ptr_eq(&pixels, &resident.pixels),
            "hybrid0 admission must share the buffer, not copy it"
        );
        // Device-placement hits: refcount bumps on the resident Arc.
        for pl in [Placement::Hybrid, Placement::Hybrid0] {
            let hit = StageCtx::new(pl, 56).run_stage_cached(&resident, aug);
            let Payload::Pixels { pixels, .. } = hit else { panic!() };
            assert!(Arc::ptr_eq(&pixels, &resident.pixels), "{pl:?} hit copied pixels");
        }
        // Cpu hit into a slot matches the allocating hit bit-for-bit
        // (the one write both paths share is the augment output).
        let cpu = StageCtx::new(Placement::Cpu, 56);
        let mut scratch = StageScratch::new();
        let mut out = vec![0f32; 3 * 56 * 56];
        cpu.run_stage_cached_into(&resident, aug, &mut scratch, &mut out);
        let Payload::Ready(v) = cpu.run_stage_cached(&resident, aug) else { panic!() };
        assert_eq!(v, out);
    }

    #[test]
    fn run_stage_into_rejects_device_placements() {
        let bytes = encoded_image(13);
        let aug = AugParams::identity(64, 64);
        let mut scratch = StageScratch::new();
        let mut out = vec![0f32; 3 * 56 * 56];
        for pl in [Placement::Hybrid, Placement::Hybrid0] {
            assert!(StageCtx::new(pl, 56)
                .run_stage_into(&bytes, 0, aug, &mut scratch, &mut out)
                .is_err());
        }
    }

    /// Every chain variant reports its work as spans when a tracer is
    /// attached: cpu miss = Decode + Augment, hybrid/hybrid0 miss =
    /// Decode, any hit = CacheHit — and the sample id rides along.
    #[test]
    fn stage_chains_record_spans_per_placement() {
        let bytes = encoded_image(21);
        let aug = AugParams { y0: 2, x0: 3, crop_h: 40, crop_w: 44, flip: false };
        let count = |tracer: &Tracer, stage: Stage| {
            tracer
                .drain()
                .tracks
                .iter()
                .flat_map(|t| t.spans.iter())
                .filter(|s| s.stage == stage)
                .count()
        };
        // cpu miss: one Decode + one Augment span carrying the id.
        let tracer = Tracer::new(1.0);
        let ctx = StageCtx::new(Placement::Cpu, 56).with_tracer(tracer.clone());
        ctx.run_stage(&bytes, 17, aug).unwrap();
        let dump = tracer.drain();
        let spans: Vec<_> = dump.tracks.iter().flat_map(|t| t.spans.iter()).collect();
        assert_eq!(
            spans.iter().filter(|s| s.stage == Stage::Decode).count(),
            1,
            "cpu miss records one decode span"
        );
        assert_eq!(
            spans.iter().filter(|s| s.stage == Stage::Augment).count(),
            1,
            "cpu miss records one augment span"
        );
        assert!(spans.iter().all(|s| s.sample == 17));
        // Fused cpu path and both device placements record Decode too.
        for (pl, fused_on) in [
            (Placement::Cpu, true),
            (Placement::Hybrid, false),
            (Placement::Hybrid0, false),
            (Placement::Hybrid0, true),
        ] {
            let tracer = Tracer::new(1.0);
            let mut ctx = StageCtx::new(pl, 56).with_tracer(tracer.clone());
            if fused_on {
                ctx = ctx.with_opts(fused(0));
            }
            ctx.run_stage(&bytes, 1, aug).unwrap();
            assert_eq!(
                count(&tracer, Stage::Decode),
                1,
                "{pl:?} fused={fused_on} missing decode span"
            );
        }
        // Hit paths: CacheHit spans on cpu (augment work) and device
        // (refcount hand-off) placements alike.
        let img = crate::codec::decode_cpu(&bytes).unwrap();
        let sample = prep_cache::DecodedSample::new(img.c, img.h, img.w, img.to_f32());
        for pl in [Placement::Cpu, Placement::Hybrid, Placement::Hybrid0] {
            let tracer = Tracer::new(1.0);
            let ctx = StageCtx::new(pl, 56).with_tracer(tracer.clone());
            ctx.run_stage_cached(&sample, aug);
            assert_eq!(count(&tracer, Stage::CacheHit), 1, "{pl:?} missing hit span");
        }
        // The zero-copy chain records the same spans as the vec chain.
        let tracer = Tracer::new(1.0);
        let ctx = StageCtx::new(Placement::Cpu, 56).with_tracer(tracer.clone());
        let mut scratch = StageScratch::new();
        let mut out = vec![0f32; 3 * 56 * 56];
        ctx.run_stage_into(&bytes, 3, aug, &mut scratch, &mut out).unwrap();
        ctx.run_stage_cached_into(&sample, aug, &mut scratch, &mut out);
        let dump = tracer.drain();
        let spans: Vec<_> = dump.tracks.iter().flat_map(|t| t.spans.iter()).collect();
        assert_eq!(spans.iter().filter(|s| s.stage == Stage::Decode).count(), 1);
        assert_eq!(spans.iter().filter(|s| s.stage == Stage::Augment).count(), 1);
        assert_eq!(spans.iter().filter(|s| s.stage == Stage::CacheHit).count(), 1);
    }

    #[test]
    fn collate_coefs_carries_qtable_and_aug() {
        let bytes = encoded_image(2);
        let aug = AugParams { y0: 1, x0: 2, crop_h: 50, crop_w: 40, flip: true };
        let (p, _) = StageCtx::new(Placement::Hybrid, 56).run_stage(&bytes, 0, aug).unwrap();
        let b = collate(vec![Sample { id: 0, label: 5, payload: p }]).unwrap();
        match b {
            Batch::Coefs { qtable, aug, labels, .. } => {
                assert_eq!(qtable, codec::qtable_for_quality(85));
                assert_eq!(&aug[..5], &[1.0, 2.0, 50.0, 40.0, 1.0]);
                assert_eq!(labels, vec![5]);
            }
            _ => panic!(),
        }
    }
}
