//! Elastic CPU-stage executor: a worker pool that resizes itself from
//! live backpressure signals instead of trusting a preset `--workers`.
//!
//! The paper's bottom line is that preprocessing throughput must be
//! *matched* to training throughput; the right worker count depends on
//! the model, placement, storage tier, and cache warmth, and is best
//! discovered online (tf.data AUTOTUNE, DALI's thread tuning).  The
//! controller here is a small hill climber over two starvation signals,
//! both read from the bounded channels the pipeline already has:
//!
//! * **batcher starved** (sample queue empty, consumer blocked in
//!   `recv`) → preprocessing is the bottleneck → *add* a worker;
//! * **workers starved** (work queue empty — the source/storage cannot
//!   feed the pool) or **workers blocked** (sample queue full — the
//!   device cannot drain the pool) → capacity is wasted → *park* one.
//!
//! Why this converges: let `c` be the per-item CPU cost and `R` the rate
//! the rest of the pipeline (device + storage) can absorb.  Below
//! `k* = ceil(R·c)` workers the batcher starves every interval (add);
//! above it workers block or starve (park); at `k*` neither signal
//! fires.  `k*` clamped to `[min, max]` is therefore the controller's
//! unique fixed point — the same quantity `sim::workers_fixed_point`
//! computes analytically, which is what the engine-vs-sim agreement test
//! in `tests/elastic_exec.rs` pins down.
//!
//! All `workers_max` threads are spawned up front; parked workers wait
//! on a gate instead of exiting, so resizing is a notify, not a thread
//! spawn.  The pool — not the caller — owns its queue bound
//! ([`ExecConfig::work_queue_cap`]), derived from `workers_max` so it
//! cannot go stale as the live count moves.

use crate::config::RunConfig;
use crate::metrics::BusyClock;
use crate::pipeline::channel::{Receiver, Sender};
use crate::util::sync::{thread, Arc, Condvar, Mutex};
use anyhow::{ensure, Result};
use std::time::Instant;

/// Pool geometry + controller cadence.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExecConfig {
    pub workers_min: usize,
    pub workers_max: usize,
    /// Pool size at spawn (fixed pools stay here; auto pools move).
    pub workers_initial: usize,
    /// Controller decision period, seconds.
    pub interval_secs: f64,
    /// Feedback autoscaling on/off (off = fixed pool of `workers_initial`).
    pub auto: bool,
}

/// Starvation fraction of an interval above which the controller adds a
/// worker (the batcher waited ≥ this share of the tick for samples).
pub const ADD_STARVE_FRAC: f64 = 0.10;
/// Per-worker starved/blocked fraction above which one worker is parked
/// (capacity demonstrably wasted on waiting, not preprocessing).
pub const PARK_WASTE_FRAC: f64 = 0.25;
/// Ticks the controller holds the pool size after any resize before it
/// will resize again (cooldown).  One tick: a fresh interval of signals
/// must be observed *at the new size* before the next step, without
/// slowing a sustained ramp by more than 2× — `tests/elastic_exec.rs`
/// still requires a prep-bound pool to reach `workers_max` in a sub-
/// second run.
pub const RESIZE_COOLDOWN_TICKS: u32 = 1;
/// Threshold multiplier for *reversing* the last resize direction
/// (hysteresis).  After an add, a park needs `PARK_WASTE_FRAC × 1.5`;
/// after a park, an add needs `ADD_STARVE_FRAC × 1.5`.  Two out-of-phase
/// jobs whose demand beats near the thresholds then latch onto one size
/// instead of oscillating, while a genuinely reversed load (signal well
/// past threshold) still turns the pool around immediately.
pub const REVERSE_HYSTERESIS: f64 = 1.5;

/// The hill-climb decision core, split from the controller thread so the
/// anti-oscillation behavior is testable tick by tick without spawning a
/// pipeline (`two-tone` test below).  `pub` + `#[doc(hidden)]`: not API.
///
/// Plain hill climbing is memoryless: signals that beat across ticks —
/// two jobs with out-of-phase epochs, a device alternating between
/// compute- and copy-bound steps — make it add and park on alternate
/// ticks forever.  Two pieces of memory stop that: a *cooldown* (after a
/// resize, hold for [`RESIZE_COOLDOWN_TICKS`] ticks so every decision is
/// based on an interval measured at the current size) and *directional
/// hysteresis* (reversing the last move needs [`REVERSE_HYSTERESIS`] ×
/// the normal threshold; continuing in the same direction does not).
#[doc(hidden)]
#[derive(Clone, Copy, Debug)]
pub struct ClimbState {
    min: usize,
    max: usize,
    /// Ticks left to hold after the last resize.
    cooldown_left: u32,
    /// Direction of the last resize: +1 add, -1 park, 0 never resized.
    last_dir: i8,
}

impl ClimbState {
    pub fn new(min: usize, max: usize) -> Self {
        ClimbState { min: min.max(1), max: max.max(min.max(1)), cooldown_left: 0, last_dir: 0 }
    }

    /// One controller tick: decide the next pool size from this
    /// interval's starvation fractions.  `out_len`/`out_cap` gate adds —
    /// a full sample queue means more producers cannot help.
    pub fn decide(
        &mut self,
        cur: usize,
        batcher_starved: f64,
        workers_starved: f64,
        workers_blocked: f64,
        out_len: usize,
        out_cap: usize,
    ) -> usize {
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            return cur;
        }
        // Reversing the previous move needs a stronger signal; moves in
        // the same direction (or from rest) use the base thresholds, so
        // a sustained ramp is never dampened — only direction flips are.
        let park_thresh =
            PARK_WASTE_FRAC * if self.last_dir > 0 { REVERSE_HYSTERESIS } else { 1.0 };
        let add_thresh =
            ADD_STARVE_FRAC * if self.last_dir < 0 { REVERSE_HYSTERESIS } else { 1.0 };
        // One step per tick, park beats add (when both fire the pool is
        // mis-phased, and shrinking is the cheap direction to probe from).
        let next = if workers_starved > park_thresh || workers_blocked > park_thresh {
            cur.saturating_sub(1).max(self.min)
        } else if batcher_starved > add_thresh && out_len < out_cap {
            (cur + 1).min(self.max)
        } else {
            cur
        };
        if next != cur {
            self.cooldown_left = RESIZE_COOLDOWN_TICKS;
            self.last_dir = if next > cur { 1 } else { -1 };
        }
        next
    }
}

impl ExecConfig {
    /// A fixed pool of `n` workers (the pre-elastic behavior).
    pub fn fixed(n: usize) -> Self {
        let n = n.max(1);
        ExecConfig {
            workers_min: n,
            workers_max: n,
            workers_initial: n,
            interval_secs: 0.25,
            auto: false,
        }
    }

    /// An autoscaling pool over `[min, max]`, starting at `min` (the
    /// controller only ever pays for workers the signals justify).
    pub fn auto(min: usize, max: usize, interval_secs: f64) -> Self {
        ExecConfig {
            workers_min: min.max(1),
            workers_max: max.max(min.max(1)),
            workers_initial: min.max(1),
            interval_secs,
            auto: true,
        }
    }

    pub fn from_run_config(cfg: &RunConfig) -> Self {
        if cfg.workers_auto {
            Self::auto(cfg.workers_min, cfg.workers_max, cfg.workers_interval_secs)
        } else {
            ExecConfig { interval_secs: cfg.workers_interval_secs, ..Self::fixed(cfg.cpu_workers) }
        }
    }

    /// The work-queue bound this pool needs: two in-flight items per
    /// worker the pool may *grow to*, plus a batch of slack for the
    /// source.  Owned here — deriving it from a live worker count would
    /// go stale the moment the controller resizes.
    pub fn work_queue_cap(&self, batch_size: usize) -> usize {
        self.workers_max * 2 + batch_size
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.workers_min >= 1, "workers_min must be >= 1");
        ensure!(
            self.workers_max >= self.workers_min,
            "workers_max ({}) must be >= workers_min ({})",
            self.workers_max,
            self.workers_min
        );
        ensure!(
            (self.workers_min..=self.workers_max).contains(&self.workers_initial),
            "workers_initial {} outside [{}, {}]",
            self.workers_initial,
            self.workers_min,
            self.workers_max
        );
        ensure!(self.interval_secs > 0.0, "controller interval must be > 0");
        Ok(())
    }
}

/// What the pool did: final size + every resize, for the run report.
#[derive(Clone, Debug, Default)]
pub struct PoolReport {
    pub workers_final: usize,
    /// `(secs_since_spawn, new_count)`, first entry = the spawn size.
    pub workers_timeline: Vec<(f64, usize)>,
}

/// Join result: the report is always available — a worker error after
/// the device stopped is an expected close, and the caller still wants
/// the telemetry.
pub struct PoolOutcome {
    pub report: PoolReport,
    pub result: Result<()>,
}

/// Park/unpark gate shared by workers and the controller.  Worker `w`
/// processes items only while `w < target`; others wait here.  Shutdown
/// wakes everyone for exit.
///
/// `pub` + `#[doc(hidden)]` so `tests/loom_models.rs` can model-check
/// the park/resize/shutdown protocol in isolation; it is not part of
/// the crate's API surface.
#[doc(hidden)]
pub struct Gate {
    st: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    target: usize,
    shutdown: bool,
}

impl Gate {
    pub fn new(target: usize) -> Arc<Self> {
        Arc::new(Gate { st: Mutex::new(GateState { target, shutdown: false }), cv: Condvar::new() })
    }

    /// Block until worker `w` is active; `false` means shut down instead.
    pub fn wait_active(&self, w: usize) -> bool {
        // poison: every Gate holder (through `sleep`) only reads/writes
        // two plain fields under the lock — no panic can occur there, so
        // poisoning is unreachable.
        let mut st = self.st.lock().unwrap();
        loop {
            if st.shutdown {
                return false;
            }
            if w < st.target {
                return true;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Non-blocking check: is worker `w` currently inside the live
    /// target (and the pool not shut down)?  The worker loop uses this
    /// to release its per-worker scratch *before* parking on
    /// [`wait_active`] — parked capacity holds no memory.
    pub fn is_active(&self, w: usize) -> bool {
        // poison: see `wait_active`.
        let st = self.st.lock().unwrap();
        !st.shutdown && w < st.target
    }

    pub fn set_target(&self, n: usize) {
        // poison: see `wait_active`.
        self.st.lock().unwrap().target = n;
        self.cv.notify_all();
    }

    pub fn target(&self) -> usize {
        // poison: see `wait_active`.
        self.st.lock().unwrap().target
    }

    pub fn shutdown(&self) {
        // poison: see `wait_active`.
        self.st.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }

    /// Controller sleep: returns `true` if shutdown arrived meanwhile.
    #[cfg(not(loom))]
    pub fn sleep(&self, secs: f64) -> bool {
        // poison: see `wait_active`.
        let mut st = self.st.lock().unwrap();
        let deadline = Instant::now() + std::time::Duration::from_secs_f64(secs);
        while !st.shutdown {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        true
    }

    /// Model-checker variant: loom's `wait_timeout` "elapses" the moment
    /// no other task can run, so the real-time deadline loop above would
    /// spin forever at zero elapsed wall time.  One bounded wait per
    /// call keeps the controller's observable protocol — wake on
    /// shutdown, wake-and-recheck on notify, proceed on timeout —
    /// without depending on wall-clock progress.
    #[cfg(loom)]
    pub fn sleep(&self, _secs: f64) -> bool {
        // poison: see `wait_active`.
        let st = self.st.lock().unwrap();
        if st.shutdown {
            return true;
        }
        let (st, _timed_out) =
            self.cv.wait_timeout(st, std::time::Duration::from_millis(1)).unwrap();
        st.shutdown
    }
}

/// The running pool.  `join` after the source has closed the work queue
/// (or the consumer has dropped) to collect the outcome.
pub struct ElasticPool {
    workers: Vec<thread::JoinHandle<Result<()>>>,
    controller: Option<thread::JoinHandle<()>>,
    gate: Arc<Gate>,
    timeline: Arc<Mutex<Vec<(f64, usize)>>>,
}

/// Spawn the pool: `workers_max` threads over `work_rx`, each running
/// `stage` per item and forwarding `Some(out)` to `out_tx` (a `None`
/// result drops the item — e.g. filtered records).  `clock` is resized
/// alongside the pool so its live-denominator utilization stays honest;
/// the *stage itself* decides what counts as busy time (wrap the compute
/// in `clock.track`, not the blocking queue ops).
pub fn spawn<I, O, F>(
    cfg: ExecConfig,
    work_rx: Receiver<I>,
    out_tx: Sender<O>,
    clock: Arc<BusyClock>,
    stage: F,
) -> Result<ElasticPool>
where
    I: Send + 'static,
    O: Send + 'static,
    F: Fn(I) -> Result<Option<O>> + Send + Sync + 'static,
{
    spawn_stateful(cfg, work_rx, out_tx, clock, || (), move |_: &mut (), item| stage(item))
}

/// [`spawn`] with per-worker state: `init` builds a worker's scratch the
/// first time it goes active, `stage` reuses it on every item, and a
/// worker releases its scratch whenever it stops working — parked on
/// the gate, *or about to block on an empty work queue* (the
/// storage-bound stall that triggers parking in the first place; a
/// worker blocked in `recv` cannot observe the gate, so waiting for the
/// park signal alone would leave it holding decode buffers for the
/// whole stall).  A fed steady-state queue never takes either branch,
/// so the zero-allocation property of the hot path is untouched;
/// scratch re-`init`s on the next item.
pub fn spawn_stateful<I, O, S, G, F>(
    cfg: ExecConfig,
    work_rx: Receiver<I>,
    out_tx: Sender<O>,
    clock: Arc<BusyClock>,
    init: G,
    stage: F,
) -> Result<ElasticPool>
where
    I: Send + 'static,
    O: Send + 'static,
    S: Send + 'static,
    G: Fn() -> S + Send + Sync + 'static,
    F: Fn(&mut S, I) -> Result<Option<O>> + Send + Sync + 'static,
{
    spawn_guarded(cfg, work_rx, out_tx, clock, init, stage, None)
}

/// Decides what a contained worker panic does to the pool: `Ok(())`
/// swallows it (the item is poisoned and dropped, the worker keeps
/// serving — graceful degradation under a skip budget), `Err` ends the
/// pool with that error.  `None` means every panic is fatal (the
/// pre-fault-tolerance behavior, minus the lost thread).
pub type PanicGuard = Arc<dyn Fn(String) -> Result<()> + Send + Sync>;

/// Best-effort text of a panic payload for error messages.
fn panic_text(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// [`spawn_stateful`] with panic containment: each stage call runs under
/// `catch_unwind`, so a panicking transform poisons *that item* instead
/// of killing its worker thread.  The worker's scratch is dropped (the
/// panic may have left it mid-mutation) and rebuilt on the next item —
/// the in-place "respawn".  `guard` arbitrates whether the epoch
/// continues; see [`PanicGuard`].
pub fn spawn_guarded<I, O, S, G, F>(
    cfg: ExecConfig,
    work_rx: Receiver<I>,
    out_tx: Sender<O>,
    clock: Arc<BusyClock>,
    init: G,
    stage: F,
    guard: Option<PanicGuard>,
) -> Result<ElasticPool>
where
    I: Send + 'static,
    O: Send + 'static,
    S: Send + 'static,
    G: Fn() -> S + Send + Sync + 'static,
    F: Fn(&mut S, I) -> Result<Option<O>> + Send + Sync + 'static,
{
    cfg.validate()?;
    let gate = Gate::new(cfg.workers_initial);
    let timeline = Arc::new(Mutex::new(vec![(0.0f64, cfg.workers_initial)]));
    let t0 = Instant::now();
    let init = Arc::new(init);
    let stage = Arc::new(stage);
    // Probes, not endpoint clones: the controller must observe the
    // queues without keeping them open (an extra Receiver would stop the
    // source from ever seeing Closed, an extra Sender would stop the
    // batcher from ever seeing None).
    let work_probe = work_rx.probe();
    let out_probe = out_tx.probe();

    let mut workers = Vec::with_capacity(cfg.workers_max);
    for w in 0..cfg.workers_max {
        let gate = gate.clone();
        let work_rx = work_rx.clone();
        let out_tx = out_tx.clone();
        let init = init.clone();
        let stage = stage.clone();
        let guard = guard.clone();
        workers.push(
            thread::Builder::new().name(format!("cpu-{w}")).spawn(move || {
                let res = (|| -> Result<()> {
                    let mut state: Option<S> = None;
                    loop {
                        if !gate.is_active(w) {
                            // About to park (or shut down): release the
                            // scratch first, re-init on unpark.
                            state = None;
                            if !gate.wait_active(w) {
                                return Ok(()); // shut down while parked
                            }
                        }
                        if work_rx.is_empty() {
                            // About to block on a starved queue — the
                            // stall the controller parks for, which a
                            // worker stuck in recv could never see.
                            // Idle capacity holds no scratch either way.
                            state = None;
                        }
                        // recv returns None only when the queue is empty
                        // AND the source is done: nothing is dropped.
                        let Some(item) = work_rx.recv() else { return Ok(()) };
                        let st = state.get_or_insert_with(|| (*init)());
                        let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || (*stage)(st, item),
                        ));
                        match ran {
                            Ok(out) => {
                                if let Some(out) = out? {
                                    if out_tx.send(out).is_err() {
                                        return Ok(()); // consumer gone (early stop)
                                    }
                                }
                            }
                            Err(payload) => {
                                // The item is poisoned; the scratch may be
                                // mid-mutation — drop it and rebuild on the
                                // next item (the in-place worker respawn).
                                state = None;
                                let msg = panic_text(payload);
                                match &guard {
                                    Some(g) => g(msg)?,
                                    None => anyhow::bail!(
                                        "cpu worker panicked: {msg} (item poisoned)"
                                    ),
                                }
                            }
                        }
                    }
                })();
                // Whatever ended this worker ends the pool: wake parked
                // peers and the controller so nobody waits on a gate
                // that will never open.
                gate.shutdown();
                res
            })?,
        );
    }
    drop(work_rx);
    drop(out_tx);

    let controller = if cfg.auto && cfg.workers_max > cfg.workers_min {
        let gate = gate.clone();
        let timeline = timeline.clone();
        let clock = clock.clone();
        Some(thread::Builder::new().name("exec-ctl".into()).spawn(move || {
            let mut last_work = work_probe.stats();
            let mut last_out = out_probe.stats();
            let mut last_t = Instant::now();
            let mut climb = ClimbState::new(cfg.workers_min, cfg.workers_max);
            loop {
                if gate.sleep(cfg.interval_secs) {
                    return;
                }
                let work = work_probe.stats();
                let out = out_probe.stats();
                let now = Instant::now();
                let dt = now.duration_since(last_t).as_secs_f64().max(1e-9);
                let cur = gate.target();
                // Consumer-side starvation of the sample queue: the
                // batcher (1 thread) waited this fraction of the tick.
                let batcher_starved = (out.recv_wait_secs - last_out.recv_wait_secs) / dt;
                // Producer-side waste, per active worker: waiting for
                // work (source/storage-bound) or for queue space
                // (device-bound).
                let per = dt * cur as f64;
                let workers_starved = (work.recv_wait_secs - last_work.recv_wait_secs) / per;
                let workers_blocked = (out.send_wait_secs - last_out.send_wait_secs) / per;
                // Hill climb with cooldown + reversal hysteresis — the
                // memoryful core lives in ClimbState so its
                // anti-oscillation behavior is unit-tested tick by tick.
                let next = climb.decide(
                    cur,
                    batcher_starved,
                    workers_starved,
                    workers_blocked,
                    out.len,
                    out.cap,
                );
                if next != cur {
                    gate.set_target(next);
                    clock.set_workers(next);
                    // poison: Vec push only under the timeline lock.
                    timeline.lock().unwrap().push((t0.elapsed().as_secs_f64(), next));
                }
                last_work = work;
                last_out = out;
                last_t = now;
            }
        })?)
    } else {
        None
    };

    Ok(ElasticPool { workers, controller, gate, timeline })
}

impl ElasticPool {
    /// Current pool target (test/telemetry hook).
    pub fn workers_now(&self) -> usize {
        self.gate.target()
    }

    /// Wait for every worker to finish, stop the controller, and report.
    /// The first worker error (if any) is carried in `result`; the
    /// report is valid either way.
    pub fn join(self) -> PoolOutcome {
        let mut result: Result<()> = Ok(());
        for t in self.workers {
            match t.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if result.is_ok() {
                        result = Err(e);
                    }
                }
                Err(_) => {
                    if result.is_ok() {
                        result = Err(anyhow::anyhow!("cpu worker panicked"));
                    }
                }
            }
        }
        self.gate.shutdown();
        if let Some(c) = self.controller {
            let _ = c.join();
        }
        // poison: Vec take only under the timeline lock.
        let mut timeline = self.timeline.lock().unwrap();
        let report = PoolReport {
            workers_final: self.gate.target(),
            workers_timeline: std::mem::take(&mut *timeline),
        };
        drop(timeline);
        PoolOutcome { report, result }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::channel::bounded;
    use std::time::Duration;

    #[test]
    fn exec_config_validates_and_derives_queue_cap() {
        assert!(ExecConfig::fixed(2).validate().is_ok());
        assert!(ExecConfig::auto(1, 4, 0.1).validate().is_ok());
        assert!(ExecConfig { workers_min: 0, ..ExecConfig::fixed(1) }.validate().is_err());
        let inverted = ExecConfig {
            workers_min: 2,
            workers_max: 1,
            workers_initial: 2,
            interval_secs: 0.1,
            auto: true,
        };
        assert!(inverted.validate().is_err());
        assert!(ExecConfig { interval_secs: 0.0, ..ExecConfig::fixed(1) }.validate().is_err());
        assert!(
            ExecConfig { workers_initial: 9, ..ExecConfig::auto(1, 4, 0.1) }.validate().is_err()
        );
        // The satellite: the queue bound comes from workers_max, never
        // from a live count that autoscaling would stale out.
        let cfg = ExecConfig::auto(1, 8, 0.1);
        assert_eq!(cfg.work_queue_cap(32), 8 * 2 + 32);
        assert_eq!(ExecConfig::fixed(3).work_queue_cap(4), 10);
    }

    #[test]
    fn fixed_pool_processes_everything_and_reports_constant_timeline() {
        let (work_tx, work_rx) = bounded(16);
        let (out_tx, out_rx) = bounded(16);
        let clock = BusyClock::new(2);
        let pool = spawn(ExecConfig::fixed(2), work_rx, out_tx, clock, |x: u32| {
            Ok(Some(x * 2))
        })
        .unwrap();
        for i in 0..100u32 {
            work_tx.send(i).unwrap();
        }
        drop(work_tx);
        let mut got: Vec<u32> = std::iter::from_fn(|| out_rx.recv()).collect();
        got.sort();
        assert_eq!(got, (0..100).map(|i| i * 2).collect::<Vec<_>>());
        let out = pool.join();
        assert!(out.result.is_ok());
        assert_eq!(out.report.workers_final, 2);
        assert_eq!(out.report.workers_timeline, vec![(0.0, 2)]);
    }

    #[test]
    fn stage_errors_surface_in_join_and_release_parked_workers() {
        let (work_tx, work_rx) = bounded(8);
        let (out_tx, out_rx) = bounded::<u32>(8);
        let clock = BusyClock::new_live(1);
        // min 1 of max 4: three workers start parked; the active one
        // errors, and join must not hang on the parked three.
        let pool = spawn(ExecConfig::auto(1, 4, 10.0), work_rx, out_tx, clock, |_x: u32| {
            anyhow::bail!("boom")
        })
        .unwrap();
        work_tx.send(1).unwrap();
        drop(work_tx);
        assert_eq!(out_rx.recv(), None);
        let out = pool.join();
        assert!(out.result.is_err());
        assert!(out.result.unwrap_err().to_string().contains("boom"));
    }

    #[test]
    fn none_outputs_are_dropped_not_forwarded() {
        let (work_tx, work_rx) = bounded(8);
        let (out_tx, out_rx) = bounded(8);
        let clock = BusyClock::new(1);
        let pool = spawn(ExecConfig::fixed(1), work_rx, out_tx, clock, |x: u32| {
            Ok((x % 2 == 0).then_some(x))
        })
        .unwrap();
        for i in 0..10u32 {
            work_tx.send(i).unwrap();
        }
        drop(work_tx);
        let mut got: Vec<u32> = std::iter::from_fn(|| out_rx.recv()).collect();
        got.sort();
        assert_eq!(got, vec![0, 2, 4, 6, 8]);
        assert!(pool.join().result.is_ok());
    }

    #[test]
    fn stateful_workers_reuse_scratch_and_release_it_on_exit() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // Scratch whose liveness we can observe from outside.
        struct Scratch {
            count: u32,
            live: Arc<AtomicUsize>,
        }
        impl Drop for Scratch {
            fn drop(&mut self) {
                self.live.fetch_sub(1, Ordering::SeqCst);
            }
        }
        let live = Arc::new(AtomicUsize::new(0));
        let created = Arc::new(AtomicUsize::new(0));
        let (work_tx, work_rx) = bounded(16);
        let (out_tx, out_rx) = bounded(16);
        let clock = BusyClock::new(1);
        let (l, c) = (live.clone(), created.clone());
        // Pre-load the queue and close it BEFORE spawning: the worker
        // then never observes an empty queue mid-stream (which would —
        // correctly — release its scratch), so the reuse count below is
        // deterministic.
        for i in 0..5u32 {
            work_tx.send(i).unwrap();
        }
        drop(work_tx);
        let pool = spawn_stateful(
            ExecConfig::fixed(1),
            work_rx,
            out_tx,
            clock,
            move || {
                l.fetch_add(1, Ordering::SeqCst);
                c.fetch_add(1, Ordering::SeqCst);
                Scratch { count: 0, live: l.clone() }
            },
            |s: &mut Scratch, _x: u32| {
                s.count += 1;
                Ok(Some(s.count))
            },
        )
        .unwrap();
        let got: Vec<u32> = std::iter::from_fn(|| out_rx.recv()).collect();
        // One worker, one scratch, reused across items: the per-state
        // counter climbs instead of resetting.
        assert_eq!(got, vec![1, 2, 3, 4, 5]);
        assert!(pool.join().result.is_ok());
        assert_eq!(created.load(Ordering::SeqCst), 1, "scratch must be reused, not re-init");
        assert_eq!(live.load(Ordering::SeqCst), 0, "exited workers must release scratch");
    }

    #[test]
    fn parked_workers_never_create_scratch() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let created = Arc::new(AtomicUsize::new(0));
        let (work_tx, work_rx) = bounded(16);
        let (out_tx, out_rx) = bounded(16);
        let clock = BusyClock::new_live(1);
        let c = created.clone();
        // Pre-loaded, closed queue (see the reuse test): the one active
        // worker never blocks on an empty queue, so it builds scratch
        // exactly once.
        for i in 0..10u32 {
            work_tx.send(i).unwrap();
        }
        drop(work_tx);
        // min 1 of max 4 with a controller interval far beyond the test:
        // workers 1..3 park forever and must never pay for scratch.
        let pool = spawn_stateful(
            ExecConfig::auto(1, 4, 60.0),
            work_rx,
            out_tx,
            clock,
            move || {
                c.fetch_add(1, Ordering::SeqCst);
                0u32
            },
            |_s: &mut u32, x: u32| Ok(Some(x)),
        )
        .unwrap();
        let mut got: Vec<u32> = std::iter::from_fn(|| out_rx.recv()).collect();
        got.sort();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        assert!(pool.join().result.is_ok());
        assert_eq!(
            created.load(Ordering::SeqCst),
            1,
            "only the one active worker may hold scratch"
        );
    }

    #[test]
    fn unguarded_panic_is_contained_but_fatal() {
        let (work_tx, work_rx) = bounded(8);
        let (out_tx, out_rx) = bounded::<u32>(8);
        let clock = BusyClock::new(1);
        let pool = spawn(ExecConfig::fixed(1), work_rx, out_tx, clock, |x: u32| {
            if x == 1 {
                panic!("decode exploded on item {x}");
            }
            Ok(Some(x))
        })
        .unwrap();
        work_tx.send(0).unwrap();
        work_tx.send(1).unwrap();
        drop(work_tx);
        assert_eq!(out_rx.recv(), Some(0));
        assert_eq!(out_rx.recv(), None);
        let out = pool.join();
        let msg = format!("{:#}", out.result.unwrap_err());
        // The panic became a proper error (not a dead thread): its
        // message survives into the pool outcome.
        assert!(msg.contains("cpu worker panicked"), "{msg}");
        assert!(msg.contains("decode exploded on item 1"), "{msg}");
    }

    #[test]
    fn guarded_panics_poison_items_and_keep_the_pool_alive() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let poisoned = Arc::new(AtomicUsize::new(0));
        let (work_tx, work_rx) = bounded(32);
        let (out_tx, out_rx) = bounded(32);
        let clock = BusyClock::new(2);
        let p = poisoned.clone();
        let guard: PanicGuard = Arc::new(move |_msg| {
            p.fetch_add(1, Ordering::SeqCst);
            Ok(())
        });
        let pool = spawn_guarded(
            ExecConfig::fixed(2),
            work_rx,
            out_tx,
            clock,
            || 0u32,
            |_s: &mut u32, x: u32| {
                if x % 5 == 0 {
                    panic!("poisoned item {x}");
                }
                Ok(Some(x))
            },
            Some(guard),
        )
        .unwrap();
        for i in 0..20u32 {
            work_tx.send(i).unwrap();
        }
        drop(work_tx);
        let mut got: Vec<u32> = std::iter::from_fn(|| out_rx.recv()).collect();
        got.sort();
        // Every non-poisoned item made it through — the epoch survived
        // four panics without losing a worker.
        assert_eq!(got, (0..20).filter(|i| i % 5 != 0).collect::<Vec<_>>());
        assert_eq!(poisoned.load(Ordering::SeqCst), 4);
        assert!(pool.join().result.is_ok());
    }

    #[test]
    fn guard_error_ends_the_pool() {
        let (work_tx, work_rx) = bounded(8);
        let (out_tx, out_rx) = bounded::<u32>(8);
        let clock = BusyClock::new(1);
        let guard: PanicGuard =
            Arc::new(|msg| anyhow::bail!("skip budget exceeded after: {msg}"));
        let pool = spawn_guarded(
            ExecConfig::fixed(1),
            work_rx,
            out_tx,
            clock,
            || (),
            |_: &mut (), _x: u32| -> Result<Option<u32>> { panic!("always") },
            Some(guard),
        )
        .unwrap();
        work_tx.send(1).unwrap();
        drop(work_tx);
        assert_eq!(out_rx.recv(), None);
        let out = pool.join();
        assert!(format!("{:#}", out.result.unwrap_err()).contains("skip budget exceeded"));
    }

    /// Satellite: two out-of-phase jobs whose demand beats against each
    /// other must not make the controller oscillate.  Synthetic two-tone
    /// load, driven tick by tick through the decision core: phase A is
    /// mildly prep-bound (batcher starvation just over threshold),
    /// phase B mildly device-bound (workers blocked just over
    /// threshold), alternating every 3 ticks.  A memoryless climber
    /// resizes nearly every tick; the hysteresis+cooldown climber makes
    /// one latched ramp and then holds.
    #[test]
    fn two_tone_load_has_bounded_resize_count() {
        let (min, max) = (1usize, 8usize);
        let ticks = 120;
        let phase = |t: usize| (t / 3) % 2 == 0; // true = prep-bound tone
        // Signals just past their thresholds — the beat a shared pool
        // sees from two jobs with out-of-phase epochs, not a regime
        // change (those are well past threshold and SHOULD resize).
        let tone = |prep: bool| if prep { (0.12, 0.0, 0.0) } else { (0.0, 0.0, 0.30) };

        // Memoryless baseline (the pre-satellite decision rule).
        let mut naive_cur = min;
        let mut naive_resizes = 0;
        for t in 0..ticks {
            let (bs, ws, wb) = tone(phase(t));
            let next = if ws > PARK_WASTE_FRAC || wb > PARK_WASTE_FRAC {
                naive_cur.saturating_sub(1).max(min)
            } else if bs > ADD_STARVE_FRAC {
                (naive_cur + 1).min(max)
            } else {
                naive_cur
            };
            if next != naive_cur {
                naive_resizes += 1;
                naive_cur = next;
            }
        }
        assert!(naive_resizes > 40, "baseline must thrash on this load: {naive_resizes}");

        let mut climb = ClimbState::new(min, max);
        let mut cur = min;
        let mut resizes = 0;
        for t in 0..ticks {
            let (bs, ws, wb) = tone(phase(t));
            let next = climb.decide(cur, bs, ws, wb, 0, 16);
            if next != cur {
                resizes += 1;
                cur = next;
            }
        }
        // One monotone ramp at most (plus a step or two of slack): the
        // opposing tone never clears the reversal threshold, so the pool
        // latches instead of beating.
        assert!(
            resizes <= (max - min) + 2,
            "hysteresis controller must not oscillate: {resizes} resizes (baseline {naive_resizes})"
        );
    }

    /// Hysteresis must never dampen a sustained one-direction signal:
    /// a hard-starved pool still ramps min -> max, paying only the
    /// cooldown tick per step, and a genuine load reversal (signal well
    /// past the raised threshold) turns the pool around immediately.
    #[test]
    fn sustained_signals_still_ramp_and_reverse() {
        let (min, max) = (1usize, 4usize);
        let mut climb = ClimbState::new(min, max);
        let mut cur = min;
        let mut ticks_to_max = None;
        for t in 0..32 {
            cur = climb.decide(cur, 1.0, 0.0, 0.0, 0, 16);
            if cur == max {
                ticks_to_max = Some(t + 1);
                break;
            }
        }
        let t = ticks_to_max.expect("hard-starved pool never reached workers_max");
        assert!(
            t as u32 <= (max - min) as u32 * (1 + RESIZE_COOLDOWN_TICKS) + 1,
            "ramp too slow: {t} ticks"
        );
        // Strong reversal: workers fully blocked clears 0.25 * 1.5.
        let mut parked = cur;
        for _ in 0..2 * (1 + RESIZE_COOLDOWN_TICKS) {
            parked = climb.decide(parked, 0.0, 0.0, 1.0, 0, 16);
        }
        assert!(parked < max, "strong reversal must still park: stuck at {parked}");
        // Adds are gated on sample-queue headroom regardless of memory.
        let mut full = ClimbState::new(1, 4);
        assert_eq!(full.decide(2, 1.0, 0.0, 0.0, 16, 16), 2, "full out queue must block adds");
    }

    #[test]
    fn consumer_drop_stops_pool_cleanly() {
        let (work_tx, work_rx) = bounded(4);
        let (out_tx, out_rx) = bounded(1);
        let clock = BusyClock::new(2);
        let pool =
            spawn(ExecConfig::fixed(2), work_rx, out_tx, clock, |x: u32| Ok(Some(x))).unwrap();
        work_tx.send(0).unwrap();
        assert_eq!(out_rx.recv(), Some(0));
        drop(out_rx); // device stops early
        // A worker blocked on an empty work queue only notices the dead
        // consumer when its next item's send fails — exactly the old
        // fixed-pool semantics.  Keep feeding until every worker has
        // exited and the source observes Closed.
        let mut closed = false;
        for i in 1..200u32 {
            if work_tx.send(i).is_err() {
                closed = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(closed, "source never observed the closed pool");
        drop(work_tx);
        let out = pool.join();
        assert!(out.result.is_ok());
    }
}
