//! Pipeline sources: raw-file work items and record-shard streaming,
//! optionally through the parallel range-GET prefetcher (remote tiers).

use crate::metrics::trace::Tracer;
use crate::record::{Record, ShardReader};
use crate::storage::prefetch::Resilience;
use crate::storage::{PrefetchPlan, PrefetchReader, Storage};
use anyhow::Result;
use std::io::Read;
use std::sync::Arc;

/// One unit of work for the CPU stage.  `epoch` rides along so the
/// worker can sample *fresh* per-epoch augmentation parameters even when
/// the decoded-sample cache (`pipeline/prep_cache.rs`) skips the decode.
#[derive(Clone, Debug)]
pub enum WorkItem {
    /// Raw method: the worker random-reads `path` itself (step ❸).
    RawRef { id: u64, label: u16, epoch: u64, path: String },
    /// Record method: payload already streamed sequentially (steps ④–⑤).
    Bytes { id: u64, label: u16, epoch: u64, payload: Vec<u8> },
}

impl WorkItem {
    pub fn id(&self) -> u64 {
        match self {
            WorkItem::RawRef { id, .. } | WorkItem::Bytes { id, .. } => *id,
        }
    }

    pub fn label(&self) -> u16 {
        match self {
            WorkItem::RawRef { label, .. } | WorkItem::Bytes { label, .. } => *label,
        }
    }

    pub fn epoch(&self) -> u64 {
        match self {
            WorkItem::RawRef { epoch, .. } | WorkItem::Bytes { epoch, .. } => *epoch,
        }
    }
}

/// Adapts `Storage::read_range` to `std::io::Read` for `ShardReader`:
/// consecutive `read` calls advance an offset, so the access pattern the
/// storage device sees is sequential chunks.
pub struct StorageReader {
    store: Arc<dyn Storage>,
    name: String,
    pos: u64,
    len: u64,
}

impl StorageReader {
    pub fn open(store: Arc<dyn Storage>, name: &str) -> Result<Self> {
        let len = store.len(name)?;
        Ok(StorageReader { store, name: name.to_string(), pos: 0, len })
    }
}

impl Read for StorageReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.len {
            return Ok(0);
        }
        let want = (buf.len() as u64).min(self.len - self.pos);
        let chunk = self
            .store
            .read_range(&self.name, self.pos, want)
            .map_err(|e| std::io::Error::other(format!("{e:#}")))?;
        let n = chunk.len().min(buf.len());
        buf[..n].copy_from_slice(&chunk[..n]);
        self.pos += n as u64;
        Ok(n)
    }
}

/// Stream every record of `shard_names` (in the given order) through a
/// callback, reading `chunk_size` bytes per I/O.
pub fn stream_shards(
    store: Arc<dyn Storage>,
    shard_names: &[String],
    chunk_size: usize,
    f: impl FnMut(Record) -> Result<bool>,
) -> Result<()> {
    stream_shards_prefetched(store, shard_names, chunk_size, PrefetchPlan::serial(chunk_size), f)
}

/// Like [`stream_shards`], but each shard is fetched through the parallel
/// range-GET prefetcher per `plan` (sliding window of `plan.part_size`
/// parts across `plan.conns` connections, delivered in order).  With a
/// serial plan this degrades to plain sequential `StorageReader` chunks.
pub fn stream_shards_prefetched(
    store: Arc<dyn Storage>,
    shard_names: &[String],
    chunk_size: usize,
    plan: PrefetchPlan,
    f: impl FnMut(Record) -> Result<bool>,
) -> Result<()> {
    stream_shards_prefetched_traced(store, shard_names, chunk_size, plan, Tracer::off(), f)
}

/// [`stream_shards_prefetched`] with a span recorder handed to the
/// prefetch workers: each ranged GET becomes a `fetch` span.  Serial
/// plans read inline on the caller's thread and record nothing here (the
/// raw-method fetch span lives in the runner's per-item read instead).
pub fn stream_shards_prefetched_traced(
    store: Arc<dyn Storage>,
    shard_names: &[String],
    chunk_size: usize,
    plan: PrefetchPlan,
    tracer: Tracer,
    f: impl FnMut(Record) -> Result<bool>,
) -> Result<()> {
    // No fault policy, no skip tolerance: a corrupt record propagates.
    stream_shards_resilient(
        store,
        shard_names,
        chunk_size,
        plan,
        tracer,
        Resilience::none(),
        |_, e| Err(e),
        f,
    )
}

/// [`stream_shards_prefetched_traced`] with fault handling: failed parts
/// are retried through the prefetcher's sliding window and stragglers
/// hedged per `res` (serial plans read inline with no retry machinery —
/// the runner's `with_retry` covers that path), and a corrupt record is
/// handed to `on_skip(record id, cause)` instead of wedging the stream —
/// return `Ok(())` to skip it (quarantine accounting lives with the
/// caller) or `Err` to fail the stream.
#[allow(clippy::too_many_arguments)]
pub fn stream_shards_resilient(
    store: Arc<dyn Storage>,
    shard_names: &[String],
    chunk_size: usize,
    plan: PrefetchPlan,
    tracer: Tracer,
    res: Resilience,
    mut on_skip: impl FnMut(u64, anyhow::Error) -> Result<()>,
    mut f: impl FnMut(Record) -> Result<bool>,
) -> Result<()> {
    for name in shard_names {
        let reader: Box<dyn Read + Send> = if plan.is_serial() {
            Box::new(StorageReader::open(store.clone(), name)?)
        } else {
            Box::new(PrefetchReader::open_resilient(
                store.clone(),
                name,
                plan,
                tracer.clone(),
                res.clone(),
            )?)
        };
        let mut sr = ShardReader::new(reader, chunk_size);
        while let Some(ev) = sr.next_event()? {
            match ev {
                crate::record::RecordEvent::Record(rec) => {
                    if !f(rec)? {
                        return Ok(());
                    }
                }
                crate::record::RecordEvent::Skipped { id, err } => {
                    on_skip(id, anyhow::anyhow!("shard {name}: {err}"))?;
                }
            }
        }
    }
    Ok(())
}

/// List record shards under `prefix` (e.g. "records/"), sorted.
pub fn list_shards(store: &dyn Storage, prefix: &str) -> Result<Vec<String>> {
    let mut shards: Vec<String> = store
        .list()?
        .into_iter()
        .filter(|n| n.starts_with(prefix) && n.ends_with(".rec"))
        .collect();
    shards.sort();
    Ok(shards)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::record::ShardWriter;
    use crate::storage::{DirStore, MemStore};

    #[test]
    fn storage_reader_behaves_like_file() {
        let m = MemStore::new();
        m.write("blob", (0u8..200).collect::<Vec<u8>>());
        let mut r = StorageReader::open(Arc::new(m), "blob").unwrap();
        let mut buf = [0u8; 64];
        let mut total = Vec::new();
        loop {
            let n = r.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            total.extend_from_slice(&buf[..n]);
        }
        assert_eq!(total, (0u8..200).collect::<Vec<_>>());
    }

    #[test]
    fn stream_shards_roundtrips_and_stops_early() {
        let dir = std::env::temp_dir().join(format!("dpp-src-{}", std::process::id()));
        let store = DirStore::new(&dir).unwrap();
        for s in 0..2 {
            let path = dir.join(format!("records/shard-{s:05}.rec"));
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            let mut w = ShardWriter::create(&path).unwrap();
            for i in 0..5u64 {
                w.append(s * 5 + i, 1, &[s as u8, i as u8]).unwrap();
            }
            w.finish().unwrap();
        }
        let store = Arc::new(store);
        let shards = list_shards(store.as_ref(), "records/").unwrap();
        assert_eq!(shards.len(), 2);

        let mut ids = Vec::new();
        stream_shards(store.clone(), &shards, 64, |r| {
            ids.push(r.id);
            Ok(true)
        })
        .unwrap();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());

        let mut n = 0;
        stream_shards(store, &shards, 64, |_| {
            n += 1;
            Ok(n < 3)
        })
        .unwrap();
        assert_eq!(n, 3);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn prefetched_streaming_matches_serial_order() {
        let m = MemStore::new();
        {
            // Build one shard in a temp file, then move the bytes to memory.
            let dir = std::env::temp_dir().join(format!("dpp-pf-{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            let path = dir.join("s.rec");
            let mut w = ShardWriter::create(&path).unwrap();
            for i in 0..200u64 {
                w.append(i, (i % 7) as u16, &vec![i as u8; 100 + (i as usize % 900)]).unwrap();
            }
            w.finish().unwrap();
            m.write("records/shard-00000.rec", std::fs::read(&path).unwrap());
            std::fs::remove_dir_all(dir).ok();
        }
        let store: Arc<dyn Storage> = Arc::new(m);
        let shards = vec!["records/shard-00000.rec".to_string()];
        let collect = |plan: PrefetchPlan| {
            let mut ids = Vec::new();
            stream_shards_prefetched(store.clone(), &shards, 512, plan, |r| {
                ids.push((r.id, r.payload.len()));
                Ok(true)
            })
            .unwrap();
            ids
        };
        let serial = collect(PrefetchPlan::serial(512));
        let parallel = collect(PrefetchPlan::new(6, 512, 16 * 512));
        assert_eq!(serial.len(), 200);
        assert_eq!(serial, parallel, "prefetcher must preserve record order");

        // Early stop through the prefetcher must not hang or error.
        let mut n = 0;
        stream_shards_prefetched(store, &shards, 512, PrefetchPlan::new(4, 512, 8 * 512), |_| {
            n += 1;
            Ok(n < 5)
        })
        .unwrap();
        assert_eq!(n, 5);
    }
}
