//! Sample quarantine: bounded graceful degradation for a faulty data
//! plane.
//!
//! When storage or decode produces an undecodable sample (bit-flipped
//! payload, exhausted retries, a panicking transform), failing the whole
//! epoch for one bad image is the wrong trade — but silently dropping
//! arbitrarily many is worse (the trained distribution drifts).  The
//! quarantine holds the middle ground: each bad sample is *skipped and
//! recorded*, and the total is bounded by `--max-skip-rate` × the
//! expected sample count.  One skip past the budget fails the run
//! loudly, naming what was quarantined — with the default budget of
//! zero, the very first bad sample surfaces (wrapped around its
//! original cause), so fault-free behavior is unchanged.

use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// How many quarantined-sample descriptions are kept verbatim for the
/// failure message; skips beyond this still count, they just aren't
/// named individually.
const NAMED_CAP: usize = 16;

#[derive(Debug)]
pub struct Quarantine {
    /// Max skips tolerated: `floor(max_skip_rate * expected_samples)`.
    limit: u64,
    /// The rate the limit came from (for the failure message).
    rate: f64,
    skipped: AtomicU64,
    names: Mutex<Vec<String>>,
}

impl Quarantine {
    /// Budget for a run expected to process `expected_samples` samples
    /// end to end (dataset size × epochs).  `max_skip_rate` of 0 means
    /// zero tolerance: the first skip attempt returns its cause.
    pub fn new(max_skip_rate: f64, expected_samples: u64) -> Self {
        Quarantine {
            limit: (max_skip_rate * expected_samples as f64).floor() as u64,
            rate: max_skip_rate,
            skipped: AtomicU64::new(0),
            names: Mutex::new(Vec::new()),
        }
    }

    /// Zero-tolerance quarantine (the default-config behavior).
    pub fn zero() -> Self {
        Quarantine::new(0.0, 0)
    }

    /// Try to absorb one bad sample.  Within budget: records it and
    /// returns `Ok(())` — the caller drops the sample and keeps going.
    /// Over budget: returns `cause` wrapped in a loud budget report that
    /// names the quarantined samples, for the caller to propagate.
    pub fn admit(&self, desc: String, cause: anyhow::Error) -> Result<()> {
        // ordering: Relaxed — the count is a budget check, not a
        // synchronization point; concurrent workers racing the last slot
        // may each see a distinct pre-limit value, and whichever
        // increments past the limit fails the run, which is the intent.
        let n = self.skipped.fetch_add(1, Ordering::Relaxed) + 1;
        {
            // poison: holders only push/read a Vec<String>; no panic
            // can originate under the lock.
            let mut names = self.names.lock().unwrap();
            if names.len() < NAMED_CAP {
                names.push(desc);
            }
        }
        if n <= self.limit {
            return Ok(());
        }
        let named = self.names();
        Err(cause.context(format!(
            "skip budget exceeded: {n} sample(s) quarantined, budget {} \
             (--max-skip-rate {}); quarantined: [{}]",
            self.limit,
            self.rate,
            named.join(", "),
        )))
    }

    /// Samples quarantined so far.
    pub fn count(&self) -> u64 {
        // ordering: Relaxed — monotonic telemetry read (see `admit`).
        self.skipped.load(Ordering::Relaxed)
    }

    /// Descriptions of the first [`NAMED_CAP`] quarantined samples.
    pub fn names(&self) -> Vec<String> {
        // poison: see `admit` — only Vec ops run under this lock.
        self.names.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    #[test]
    fn zero_budget_surfaces_the_first_failure() {
        let q = Quarantine::zero();
        let err = q.admit("img/7.mjx".into(), anyhow!("injected: bit flip")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("skip budget exceeded"), "{msg}");
        assert!(msg.contains("img/7.mjx"), "{msg}");
        assert!(msg.contains("injected: bit flip"), "budget report must keep the cause: {msg}");
        assert_eq!(q.count(), 1);
    }

    #[test]
    fn skips_within_budget_are_absorbed_and_counted() {
        // 1% of 1000 expected samples -> 10 skips allowed.
        let q = Quarantine::new(0.01, 1000);
        for i in 0..10 {
            q.admit(format!("sample {i}"), anyhow!("bad")).unwrap();
        }
        assert_eq!(q.count(), 10);
        let err = q.admit("sample 10".into(), anyhow!("bad")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("11 sample(s) quarantined, budget 10"), "{msg}");
        assert!(msg.contains("sample 0") && msg.contains("sample 10"), "{msg}");
    }

    #[test]
    fn named_list_is_capped_but_count_is_not() {
        let q = Quarantine::new(1.0, 100);
        for i in 0..40 {
            q.admit(format!("s{i}"), anyhow!("bad")).unwrap();
        }
        assert_eq!(q.count(), 40);
        assert_eq!(q.names().len(), NAMED_CAP);
    }
}
