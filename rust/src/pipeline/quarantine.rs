//! Sample quarantine: bounded graceful degradation for a faulty data
//! plane.
//!
//! When storage or decode produces an undecodable sample (bit-flipped
//! payload, exhausted retries, a panicking transform), failing the whole
//! epoch for one bad image is the wrong trade — but silently dropping
//! arbitrarily many is worse (the trained distribution drifts).  The
//! quarantine holds the middle ground: each bad sample is *skipped and
//! recorded*, and the skips are bounded by `--max-skip-rate` × the
//! expected sample count **per budget window** (an epoch, for the
//! coordinator; the whole run if the caller never advances the window).
//! One skip past the window's budget fails the run loudly, naming what
//! was quarantined — with the default budget of zero, the very first bad
//! sample surfaces (wrapped around its original cause), so fault-free
//! behavior is unchanged.
//!
//! The window exists for long-lived use (`dpp serve`): a budget derived
//! once from `dataset × epochs` is unbounded when the epoch count is
//! open-ended, so a slow trickle of corruption would never trip it.
//! Calling [`Quarantine::advance_window`] on each epoch boundary resets
//! the windowed count while the cumulative total (what reports print)
//! keeps accruing.

use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// How many quarantined-sample descriptions are kept verbatim for the
/// failure message; skips beyond this still count, they just aren't
/// named individually.
const NAMED_CAP: usize = 16;

#[derive(Debug)]
pub struct Quarantine {
    /// Max skips tolerated per window:
    /// `floor(max_skip_rate * expected_samples)`.
    limit: u64,
    /// The rate the limit came from (for the failure message).
    rate: f64,
    /// Skips charged against the *current* window's budget.
    skipped_window: AtomicU64,
    /// Cumulative skips across all windows (telemetry; never resets).
    skipped_total: AtomicU64,
    names: Mutex<Vec<String>>,
}

impl Quarantine {
    /// Budget for a window expected to process `expected_samples`
    /// samples (one epoch's dataset size for the coordinator; callers
    /// that never advance the window get a whole-run budget, the
    /// pre-windowed behavior).  `max_skip_rate` of 0 means zero
    /// tolerance: the first skip attempt returns its cause.
    pub fn new(max_skip_rate: f64, expected_samples: u64) -> Self {
        Quarantine {
            limit: (max_skip_rate * expected_samples as f64).floor() as u64,
            rate: max_skip_rate,
            skipped_window: AtomicU64::new(0),
            skipped_total: AtomicU64::new(0),
            names: Mutex::new(Vec::new()),
        }
    }

    /// Zero-tolerance quarantine (the default-config behavior).
    pub fn zero() -> Self {
        Quarantine::new(0.0, 0)
    }

    /// Start a fresh budget window (called on epoch boundaries): the
    /// windowed count resets to zero, the cumulative total and the named
    /// list are kept.  Workers still draining the previous window's tail
    /// may charge a stale skip to the new window — windowing is
    /// approximate by one in-flight sample per worker, which a budget
    /// meant to bound *rates* tolerates.
    pub fn advance_window(&self) {
        // ordering: Relaxed — the reset races in-flight `admit` calls by
        // design (approximate windowing, see above); no data is
        // published through this store.
        self.skipped_window.store(0, Ordering::Relaxed);
    }

    /// Try to absorb one bad sample.  Within the window's budget:
    /// records it and returns `Ok(())` — the caller drops the sample and
    /// keeps going.  Over budget: returns `cause` wrapped in a loud
    /// budget report that names the quarantined samples, for the caller
    /// to propagate.
    pub fn admit(&self, desc: String, cause: anyhow::Error) -> Result<()> {
        // ordering: Relaxed — the count is a budget check, not a
        // synchronization point; concurrent workers racing the last slot
        // may each see a distinct pre-limit value, and whichever
        // increments past the limit fails the run, which is the intent.
        let n = self.skipped_window.fetch_add(1, Ordering::Relaxed) + 1;
        // ordering: Relaxed — monotonic telemetry counter.
        self.skipped_total.fetch_add(1, Ordering::Relaxed);
        {
            // poison: holders only push/read a Vec<String>; no panic
            // can originate under the lock.
            let mut names = self.names.lock().unwrap();
            if names.len() < NAMED_CAP {
                names.push(desc);
            }
        }
        if n <= self.limit {
            return Ok(());
        }
        let named = self.names();
        Err(cause.context(format!(
            "skip budget exceeded: {n} sample(s) quarantined, budget {} \
             (--max-skip-rate {}); quarantined: [{}]",
            self.limit,
            self.rate,
            named.join(", "),
        )))
    }

    /// Samples quarantined so far, across all windows.
    pub fn count(&self) -> u64 {
        // ordering: Relaxed — monotonic telemetry read (see `admit`).
        self.skipped_total.load(Ordering::Relaxed)
    }

    /// Skips charged to the current window (budget headroom probes).
    pub fn window_count(&self) -> u64 {
        // ordering: Relaxed — approximate read against a racing reset.
        self.skipped_window.load(Ordering::Relaxed)
    }

    /// Descriptions of the first [`NAMED_CAP`] quarantined samples.
    pub fn names(&self) -> Vec<String> {
        // poison: see `admit` — only Vec ops run under this lock.
        self.names.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    #[test]
    fn zero_budget_surfaces_the_first_failure() {
        let q = Quarantine::zero();
        let err = q.admit("img/7.mjx".into(), anyhow!("injected: bit flip")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("skip budget exceeded"), "{msg}");
        assert!(msg.contains("img/7.mjx"), "{msg}");
        assert!(msg.contains("injected: bit flip"), "budget report must keep the cause: {msg}");
        assert_eq!(q.count(), 1);
    }

    #[test]
    fn skips_within_budget_are_absorbed_and_counted() {
        // 1% of 1000 expected samples -> 10 skips allowed.
        let q = Quarantine::new(0.01, 1000);
        for i in 0..10 {
            q.admit(format!("sample {i}"), anyhow!("bad")).unwrap();
        }
        assert_eq!(q.count(), 10);
        let err = q.admit("sample 10".into(), anyhow!("bad")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("11 sample(s) quarantined, budget 10"), "{msg}");
        assert!(msg.contains("sample 0") && msg.contains("sample 10"), "{msg}");
    }

    #[test]
    fn named_list_is_capped_but_count_is_not() {
        let q = Quarantine::new(1.0, 100);
        for i in 0..40 {
            q.admit(format!("s{i}"), anyhow!("bad")).unwrap();
        }
        assert_eq!(q.count(), 40);
        assert_eq!(q.names().len(), NAMED_CAP);
    }

    /// Regression for the serve-mode fix: the budget is per window, so
    /// an epoch that exhausts it fails, while the next epoch (after
    /// `advance_window`) starts with a clean budget — and the cumulative
    /// count keeps the whole history.
    #[test]
    fn window_resets_on_epoch_boundary_but_total_accrues() {
        // 2 skips allowed per epoch of 100 samples.
        let q = Quarantine::new(0.02, 100);
        q.admit("e0 s0".into(), anyhow!("bad")).unwrap();
        q.admit("e0 s1".into(), anyhow!("bad")).unwrap();
        let err = q.admit("e0 s2".into(), anyhow!("bad")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("3 sample(s) quarantined, budget 2"), "{msg}");
        assert_eq!(q.window_count(), 3);

        // Next epoch: the window resets, so the same trickle is within
        // budget again; the total never forgets.
        q.advance_window();
        assert_eq!(q.window_count(), 0);
        q.admit("e1 s0".into(), anyhow!("bad")).unwrap();
        q.admit("e1 s1".into(), anyhow!("bad")).unwrap();
        assert_eq!(q.window_count(), 2);
        assert_eq!(q.count(), 5, "cumulative total spans windows");

        // And the refreshed budget still enforces its own cap.
        let err = q.admit("e1 s2".into(), anyhow!("bad")).unwrap_err();
        assert!(format!("{err:#}").contains("3 sample(s) quarantined, budget 2"));
    }
}
