//! Bounded MPMC channel with blocking send/recv — the pipeline's
//! backpressure primitive (no crossbeam-channel offline).
//!
//! Bounded queues are what make the paper's producer/consumer story real:
//! when the training stage is slow (ResNet50) the preprocessing stage
//! blocks on `send` (CPU underutilized); when preprocessing is slow
//! (AlexNet) the device blocks on `recv` (GPU starved).  Both wait times
//! are counted and exported to the run report.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

struct State<T> {
    q: VecDeque<T>,
    cap: usize,
    senders: usize,
    receivers: usize,
}

struct Inner<T> {
    st: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    /// Cumulative nanoseconds producers spent blocked on a full queue.
    pub send_wait_ns: AtomicU64,
    /// Cumulative nanoseconds consumers spent blocked on an empty queue.
    pub recv_wait_ns: AtomicU64,
}

pub struct Sender<T>(Arc<Inner<T>>);
pub struct Receiver<T>(Arc<Inner<T>>);

/// Error: all receivers are gone.
#[derive(Debug, PartialEq, Eq)]
pub struct Closed<T>(pub T);

pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let inner = Arc::new(Inner {
        st: Mutex::new(State { q: VecDeque::new(), cap: cap.max(1), senders: 1, receivers: 1 }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        send_wait_ns: AtomicU64::new(0),
        recv_wait_ns: AtomicU64::new(0),
    });
    (Sender(inner.clone()), Receiver(inner))
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.st.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.0.st.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.0.st.lock().unwrap().receivers += 1;
        Receiver(self.0.clone())
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.0.st.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            self.0.not_full.notify_all();
        }
    }
}

impl<T> Sender<T> {
    /// Blocking send; returns `Err(Closed(v))` if all receivers dropped.
    pub fn send(&self, v: T) -> Result<(), Closed<T>> {
        let mut st = self.0.st.lock().unwrap();
        let mut waited: Option<Instant> = None;
        while st.q.len() >= st.cap {
            if st.receivers == 0 {
                return Err(Closed(v));
            }
            waited.get_or_insert_with(Instant::now);
            st = self.0.not_full.wait(st).unwrap();
        }
        if let Some(t) = waited {
            self.0.send_wait_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        if st.receivers == 0 {
            return Err(Closed(v));
        }
        st.q.push_back(v);
        drop(st);
        self.0.not_empty.notify_one();
        Ok(())
    }

    pub fn send_wait_secs(&self) -> f64 {
        self.0.send_wait_ns.load(Ordering::Relaxed) as f64 / 1e9
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` when the queue is empty and all senders
    /// have dropped.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.0.st.lock().unwrap();
        let mut waited: Option<Instant> = None;
        loop {
            if let Some(v) = st.q.pop_front() {
                if let Some(t) = waited {
                    self.0
                        .recv_wait_ns
                        .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
                drop(st);
                self.0.not_full.notify_one();
                return Some(v);
            }
            if st.senders == 0 {
                // End-of-epoch drain: the time spent blocked waiting for
                // producers that never delivered still counts — dropping
                // it here undercounted `recv_wait_ns` exactly when the
                // consumer was starved at shutdown.
                if let Some(t) = waited {
                    self.0
                        .recv_wait_ns
                        .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
                return None;
            }
            waited.get_or_insert_with(Instant::now);
            st = self.0.not_empty.wait(st).unwrap();
        }
    }

    pub fn recv_wait_secs(&self) -> f64 {
        self.0.recv_wait_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn len(&self) -> usize {
        self.0.st.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_single_thread() {
        let (tx, rx) = bounded(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        drop(tx);
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn bounded_blocks_and_counts_backpressure() {
        let (tx, rx) = bounded(1);
        tx.send(0u32).unwrap();
        let t = thread::spawn(move || {
            tx.send(1).unwrap(); // blocks until consumer drains
            tx.send_wait_secs()
        });
        thread::sleep(Duration::from_millis(50));
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), Some(1));
        let waited = t.join().unwrap();
        assert!(waited > 0.03, "send wait {waited}");
    }

    #[test]
    fn mpmc_distributes_all_items() {
        let (tx, rx) = bounded(8);
        let n = 1000;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                thread::spawn(move || {
                    for i in 0..n / 4 {
                        tx.send(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        drop(rx);
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<i32> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort();
        assert_eq!(all.len(), n as usize);
        all.dedup();
        assert_eq!(all.len(), n as usize, "duplicates seen");
    }

    #[test]
    fn send_fails_when_receivers_gone() {
        let (tx, rx) = bounded(2);
        drop(rx);
        assert_eq!(tx.send(7), Err(Closed(7)));
    }

    /// Regression: block time accumulated while waiting on an empty
    /// queue must be flushed into `recv_wait_ns` when the channel closes
    /// (`None`), not dropped — it biases the GPU-starved metric exactly
    /// at end-of-epoch drain.
    #[test]
    fn recv_wait_counted_when_senders_drop_without_sending() {
        let (tx, rx) = bounded::<u32>(2);
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(50));
            drop(tx); // close without ever sending
        });
        assert_eq!(rx.recv(), None);
        t.join().unwrap();
        let waited = rx.recv_wait_secs();
        assert!(waited > 0.03, "drain wait dropped on None path: {waited}");
    }

    #[test]
    fn recv_drains_after_senders_gone() {
        let (tx, rx) = bounded(8);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
    }
}
