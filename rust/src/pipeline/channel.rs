//! Bounded MPMC channel with blocking send/recv — the pipeline's
//! backpressure primitive (no crossbeam-channel offline).
//!
//! Bounded queues are what make the paper's producer/consumer story real:
//! when the training stage is slow (ResNet50) the preprocessing stage
//! blocks on `send` (CPU underutilized); when preprocessing is slow
//! (AlexNet) the device blocks on `recv` (GPU starved).  Both wait times
//! are counted and exported to the run report.

use crate::metrics::trace::{Stage, Tracer};
use crate::metrics::Gauge;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Condvar, Mutex};
use std::collections::VecDeque;
use std::time::Instant;

struct State<T> {
    q: VecDeque<T>,
    cap: usize,
    senders: usize,
    receivers: usize,
    /// Waiters currently blocked in `send`/`recv`, plus the sum of their
    /// wait-start offsets (ns since channel creation).  Together these
    /// let `stats()` charge *in-flight* blocked time — a wait spanning a
    /// controller tick must be visible during the tick, not only after
    /// the blocked call finally returns.
    send_waiters: usize,
    send_wait_start_sum_ns: u128,
    recv_waiters: usize,
    recv_wait_start_sum_ns: u128,
}

/// Optional span hook: when a queue is built with [`bounded_traced`],
/// every *completed* blocking wait is also recorded as a span on the
/// waiting thread's trace ring.  The fast path (no block) records
/// nothing, so an untraced or never-contended channel pays zero cost.
struct ChanTrace {
    tracer: Tracer,
    send_stage: Stage,
    recv_stage: Stage,
}

struct Inner<T> {
    st: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    created: Instant,
    trace: Option<ChanTrace>,
    /// Cumulative nanoseconds producers spent blocked on a full queue
    /// (completed waits only; `stats()` adds the in-flight share).
    pub send_wait_ns: AtomicU64,
    /// Cumulative nanoseconds consumers spent blocked on an empty queue
    /// (completed waits only; `stats()` adds the in-flight share).
    pub recv_wait_ns: AtomicU64,
    /// Sampled queue occupancy (level after every send/recv) with peak
    /// tracking — the elastic executor's backpressure signal alongside
    /// the two wait clocks.
    occupancy: Gauge,
}

impl<T> Inner<T> {
    fn stats(&self) -> ChannelStats {
        // poison: every holder of `st` (stats, send/recv, the drop
        // bookkeeping) runs only queue ops and counter arithmetic under
        // the lock; a worker panic happens in user code *outside* it.
        let st = self.st.lock().unwrap();
        // Read the clock under the lock: every recorded start offset was
        // taken under this lock at an earlier instant, so `now` bounds
        // them all and the in-flight sums cannot go negative.
        let now = self.created.elapsed().as_nanos() as u128;
        let in_flight = |waiters: usize, start_sum: u128| {
            (waiters as u128 * now).saturating_sub(start_sum) as f64 / 1e9
        };
        ChannelStats {
            len: st.q.len(),
            cap: st.cap,
            occupancy_peak: self.occupancy.peak(),
            // ordering: Relaxed — the completed-wait clocks are only
            // ever *added to* under `st`'s lock (see `unregister`), and
            // we hold that lock here, so the lock orders every earlier
            // update; the atomic merely allows the lock-free reads in
            // `send_wait_secs`/`recv_wait_secs`.
            send_wait_secs: self.send_wait_ns.load(Ordering::Relaxed) as f64 / 1e9
                + in_flight(st.send_waiters, st.send_wait_start_sum_ns),
            recv_wait_secs: self.recv_wait_ns.load(Ordering::Relaxed) as f64 / 1e9
                + in_flight(st.recv_waiters, st.recv_wait_start_sum_ns),
        }
    }
}

/// One observation of a channel's health: instantaneous occupancy, the
/// occupancy high-water mark, and the cumulative producer/consumer block
/// times — everything the autoscaling controller diffs per interval.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChannelStats {
    pub len: usize,
    pub cap: usize,
    pub occupancy_peak: u64,
    pub send_wait_secs: f64,
    pub recv_wait_secs: f64,
}

/// A stats-only handle onto a channel.  Unlike cloning an endpoint, a
/// probe does NOT count as a sender or receiver, so holding one never
/// keeps a queue artificially open (the controller and the run report
/// must observe the pipeline without changing its shutdown semantics).
pub struct QueueProbe<T>(Arc<Inner<T>>);

impl<T> Clone for QueueProbe<T> {
    fn clone(&self) -> Self {
        QueueProbe(self.0.clone())
    }
}

impl<T> QueueProbe<T> {
    pub fn stats(&self) -> ChannelStats {
        self.0.stats()
    }
}

pub struct Sender<T>(Arc<Inner<T>>);
pub struct Receiver<T>(Arc<Inner<T>>);

/// Error: all receivers are gone.
#[derive(Debug, PartialEq, Eq)]
pub struct Closed<T>(pub T);

pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    bounded_traced(cap, Tracer::off(), Stage::WorkSendWait, Stage::WorkRecvWait)
}

/// Like [`bounded`], but completed blocking waits are also recorded as
/// spans (`send_stage` / `recv_stage`) on the waiting thread's trace
/// ring, so queue stalls line up against decode/augment/train spans on
/// the same timeline.  With a disabled tracer this is exactly `bounded`.
pub fn bounded_traced<T>(
    cap: usize,
    tracer: Tracer,
    send_stage: Stage,
    recv_stage: Stage,
) -> (Sender<T>, Receiver<T>) {
    let trace = tracer.is_on().then_some(ChanTrace { tracer, send_stage, recv_stage });
    let inner = Arc::new(Inner {
        st: Mutex::new(State {
            q: VecDeque::new(),
            cap: cap.max(1),
            senders: 1,
            receivers: 1,
            send_waiters: 0,
            send_wait_start_sum_ns: 0,
            recv_waiters: 0,
            recv_wait_start_sum_ns: 0,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        created: Instant::now(),
        trace,
        send_wait_ns: AtomicU64::new(0),
        recv_wait_ns: AtomicU64::new(0),
        occupancy: Gauge::new(),
    });
    (Sender(inner.clone()), Receiver(inner))
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        // poison: see `stats` — counter bump only under the lock.
        self.0.st.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        // poison: see `stats` — counter bump only under the lock.
        let mut st = self.0.st.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        // poison: see `stats` — counter bump only under the lock.
        self.0.st.lock().unwrap().receivers += 1;
        Receiver(self.0.clone())
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        // poison: see `stats` — counter bump only under the lock.
        let mut st = self.0.st.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            self.0.not_full.notify_all();
        }
    }
}

impl<T> Sender<T> {
    /// Blocking send; returns `Err(Closed(v))` if all receivers dropped.
    pub fn send(&self, v: T) -> Result<(), Closed<T>> {
        // poison: see `stats` — queue/bookkeeping ops only.
        let mut st = self.0.st.lock().unwrap();
        // (wall-clock anchor, start offset) of an in-progress wait; the
        // offset is registered in the state so `stats()` can see the
        // block while it is still happening.
        let mut waited: Option<(Instant, u128)> = None;
        let unregister = |st: &mut State<T>, waited: &Option<(Instant, u128)>| {
            if let Some((t, start)) = waited {
                st.send_waiters -= 1;
                st.send_wait_start_sum_ns -= start;
                // ordering: Relaxed — updated only while holding `st`'s
                // lock (the caller passes the guard), which serializes
                // all writers; see the note in `stats`.
                self.0.send_wait_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                if let Some(tr) = &self.0.trace {
                    tr.tracer.record(tr.send_stage, 0, Some(*t));
                }
            }
        };
        loop {
            if st.receivers == 0 {
                unregister(&mut st, &waited);
                return Err(Closed(v));
            }
            if st.q.len() < st.cap {
                break;
            }
            if waited.is_none() {
                let start = self.0.created.elapsed().as_nanos();
                st.send_waiters += 1;
                st.send_wait_start_sum_ns += start;
                waited = Some((Instant::now(), start));
            }
            st = self.0.not_full.wait(st).unwrap();
        }
        unregister(&mut st, &waited);
        st.q.push_back(v);
        self.0.occupancy.set(st.q.len() as u64);
        drop(st);
        self.0.not_empty.notify_one();
        Ok(())
    }

    pub fn send_wait_secs(&self) -> f64 {
        // ordering: Relaxed — lock-free approximate read of the
        // completed-wait clock (exact once the waiters have returned).
        self.0.send_wait_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn stats(&self) -> ChannelStats {
        self.0.stats()
    }

    pub fn probe(&self) -> QueueProbe<T> {
        QueueProbe(self.0.clone())
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` when the queue is empty and all senders
    /// have dropped.
    pub fn recv(&self) -> Option<T> {
        // poison: see `stats` — queue/bookkeeping ops only.
        let mut st = self.0.st.lock().unwrap();
        let mut waited: Option<(Instant, u128)> = None;
        let unregister = |st: &mut State<T>, waited: &Option<(Instant, u128)>| {
            if let Some((t, start)) = waited {
                st.recv_waiters -= 1;
                st.recv_wait_start_sum_ns -= start;
                // ordering: Relaxed — updated only under `st`'s lock;
                // see the note in `stats`.
                self.0.recv_wait_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                if let Some(tr) = &self.0.trace {
                    tr.tracer.record(tr.recv_stage, 0, Some(*t));
                }
            }
        };
        loop {
            if let Some(v) = st.q.pop_front() {
                unregister(&mut st, &waited);
                self.0.occupancy.set(st.q.len() as u64);
                drop(st);
                self.0.not_full.notify_one();
                return Some(v);
            }
            if st.senders == 0 {
                // End-of-epoch drain: the time spent blocked waiting for
                // producers that never delivered still counts — dropping
                // it here undercounted `recv_wait_ns` exactly when the
                // consumer was starved at shutdown.
                unregister(&mut st, &waited);
                return None;
            }
            if waited.is_none() {
                let start = self.0.created.elapsed().as_nanos();
                st.recv_waiters += 1;
                st.recv_wait_start_sum_ns += start;
                waited = Some((Instant::now(), start));
            }
            st = self.0.not_empty.wait(st).unwrap();
        }
    }

    pub fn recv_wait_secs(&self) -> f64 {
        // ordering: Relaxed — lock-free approximate read, as in
        // `send_wait_secs`.
        self.0.recv_wait_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn stats(&self) -> ChannelStats {
        self.0.stats()
    }

    pub fn probe(&self) -> QueueProbe<T> {
        QueueProbe(self.0.clone())
    }

    pub fn len(&self) -> usize {
        // poison: see `stats` — queue length read only.
        self.0.st.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_single_thread() {
        let (tx, rx) = bounded(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        drop(tx);
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn bounded_blocks_and_counts_backpressure() {
        let (tx, rx) = bounded(1);
        tx.send(0u32).unwrap();
        let t = thread::spawn(move || {
            tx.send(1).unwrap(); // blocks until consumer drains
            tx.send_wait_secs()
        });
        thread::sleep(Duration::from_millis(50));
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), Some(1));
        let waited = t.join().unwrap();
        assert!(waited > 0.03, "send wait {waited}");
    }

    #[test]
    fn mpmc_distributes_all_items() {
        let (tx, rx) = bounded(8);
        let n = 1000;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                thread::spawn(move || {
                    for i in 0..n / 4 {
                        tx.send(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        drop(rx);
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<i32> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort();
        assert_eq!(all.len(), n as usize);
        all.dedup();
        assert_eq!(all.len(), n as usize, "duplicates seen");
    }

    #[test]
    fn send_fails_when_receivers_gone() {
        let (tx, rx) = bounded(2);
        drop(rx);
        assert_eq!(tx.send(7), Err(Closed(7)));
    }

    /// Regression: block time accumulated while waiting on an empty
    /// queue must be flushed into `recv_wait_ns` when the channel closes
    /// (`None`), not dropped — it biases the GPU-starved metric exactly
    /// at end-of-epoch drain.
    #[test]
    fn recv_wait_counted_when_senders_drop_without_sending() {
        let (tx, rx) = bounded::<u32>(2);
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(50));
            drop(tx); // close without ever sending
        });
        assert_eq!(rx.recv(), None);
        t.join().unwrap();
        let waited = rx.recv_wait_secs();
        assert!(waited > 0.03, "drain wait dropped on None path: {waited}");
    }

    /// The occupancy gauge samples the level after every send/recv and
    /// keeps the high-water mark — what the autoscaler reads per tick.
    #[test]
    fn occupancy_gauge_tracks_level_and_peak() {
        let (tx, rx) = bounded(4);
        assert_eq!(tx.stats().occupancy_peak, 0);
        tx.send(1u32).unwrap();
        tx.send(2).unwrap();
        tx.send(3).unwrap();
        let s = rx.stats();
        assert_eq!((s.len, s.cap, s.occupancy_peak), (3, 4, 3));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        let s = tx.stats();
        assert_eq!(s.len, 1, "level follows drains");
        assert_eq!(s.occupancy_peak, 3, "peak is sticky");
        // Both endpoints and the probe see the same shared stats.
        let probe = rx.probe();
        assert_eq!(probe.stats(), tx.stats());
    }

    /// A probe must NOT count as an endpoint: senders still see Closed
    /// when the real receivers drop, and receivers still see None when
    /// the real senders drop, even with probes alive.
    #[test]
    fn probe_does_not_keep_channel_open() {
        let (tx, rx) = bounded(2);
        let probe_rx = rx.probe();
        drop(rx);
        assert_eq!(tx.send(7), Err(Closed(7)));
        let (tx2, rx2) = bounded::<u32>(2);
        let probe_tx = tx2.probe();
        drop(tx2);
        assert_eq!(rx2.recv(), None);
        // Probes still read stats after the endpoints closed.
        assert_eq!(probe_rx.stats().len, 0);
        assert_eq!(probe_tx.stats().len, 0);
    }

    /// Regression (elastic controller): a wait that is *still blocked*
    /// must already show up in `stats()` — flushing only on wake would
    /// hide a long stall from every controller tick it spans, stalling
    /// scale-up exactly when the pipeline is most starved.
    #[test]
    fn stats_charge_in_flight_blocked_time() {
        // Blocked sender, observed mid-block.
        let (tx, rx) = bounded::<u32>(1);
        tx.send(0).unwrap();
        let probe = tx.probe();
        let t = thread::spawn(move || tx.send(1).unwrap());
        thread::sleep(Duration::from_millis(60));
        let mid = probe.stats().send_wait_secs;
        assert!(mid > 0.03, "in-flight send block invisible: {mid}");
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), Some(1));
        t.join().unwrap();
        // After the wake the time is in the cumulative clock, once.
        let done = probe.stats().send_wait_secs;
        assert!(done >= mid * 0.9, "flush lost the in-flight share: {mid} -> {done}");
        // Blocked receiver, observed mid-block.
        let (tx2, rx2) = bounded::<u32>(1);
        let probe2 = rx2.probe();
        let t2 = thread::spawn(move || rx2.recv());
        thread::sleep(Duration::from_millis(60));
        let mid = probe2.stats().recv_wait_secs;
        assert!(mid > 0.03, "in-flight recv block invisible: {mid}");
        tx2.send(7).unwrap();
        assert_eq!(t2.join().unwrap(), Some(7));
        assert!(probe2.stats().recv_wait_secs >= mid * 0.9);
    }

    #[test]
    fn wait_clocks_surface_in_stats() {
        let (tx, rx) = bounded(1);
        tx.send(0u32).unwrap();
        let t = thread::spawn(move || {
            tx.send(1).unwrap();
            tx.stats().send_wait_secs
        });
        thread::sleep(Duration::from_millis(40));
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), Some(1));
        assert!(t.join().unwrap() > 0.02);
    }

    #[test]
    fn recv_drains_after_senders_gone() {
        let (tx, rx) = bounded(8);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
    }

    /// Several senders blocked *across* a `stats()` call must each be
    /// charged exactly once: `stats()` is a pure read, so two immediate
    /// back-to-back calls see (almost) the same in-flight total, and the
    /// cumulative clock after the wake matches the in-flight view rather
    /// than adding on top of it.
    #[test]
    fn concurrent_waiters_are_not_double_charged() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(0).unwrap(); // fill the queue
        let probe = tx.probe();
        let blocked: Vec<_> = (1..=3)
            .map(|i| {
                let tx = tx.clone();
                thread::spawn(move || tx.send(i).unwrap())
            })
            .collect();
        thread::sleep(Duration::from_millis(100));
        // Three waiters, each blocked ~100ms: the in-flight share is
        // ~0.3s, and reading it twice in a row must not bank it twice.
        let mid = probe.stats().send_wait_secs;
        let again = probe.stats().send_wait_secs;
        assert!(mid > 0.20, "3 blocked senders under-counted: {mid}");
        assert!(again - mid < 0.05, "stats() read banked in-flight time: {mid} -> {again}");
        for _ in 0..4 {
            assert!(rx.recv().is_some());
        }
        for t in blocked {
            t.join().unwrap();
        }
        // All waits completed: the cumulative clock holds each wait once
        // (a double-charge would roughly double the mid-block reading).
        let done = probe.stats().send_wait_secs;
        assert!(done >= mid * 0.9, "flush lost in-flight time: {mid} -> {done}");
        assert!(done < mid * 1.7 + 0.05, "wait charged twice: {mid} -> {done}");
        // And with no waiters left the reading is stable.
        let later = probe.stats().send_wait_secs;
        assert!((later - done).abs() < 1e-6, "idle stats drifted: {done} -> {later}");
    }

    /// A channel built with `bounded_traced` turns completed blocking
    /// waits into spans on the waiting thread's ring; non-blocking
    /// operations emit nothing.
    #[test]
    fn traced_channel_records_wait_spans() {
        use crate::metrics::trace::{Stage, Tracer};
        let tracer = Tracer::new(1.0);
        let (tx, rx) =
            bounded_traced::<u32>(1, tracer.clone(), Stage::BatchSendWait, Stage::BatchRecvWait);
        tx.send(0).unwrap(); // fast path: no span
        let blocked_sender = {
            let tx = tx.clone();
            thread::spawn(move || tx.send(1).unwrap()) // blocks on full queue
        };
        thread::sleep(Duration::from_millis(40));
        assert_eq!(rx.recv(), Some(0));
        assert_eq!(rx.recv(), Some(1));
        blocked_sender.join().unwrap();
        // Now block this thread in `recv` until a delayed producer fires.
        let delayed = {
            let tx = tx.clone();
            thread::spawn(move || {
                thread::sleep(Duration::from_millis(40));
                tx.send(2).unwrap();
            })
        };
        assert_eq!(rx.recv(), Some(2));
        delayed.join().unwrap();
        let dump = tracer.drain();
        let spans: Vec<_> = dump.tracks.iter().flat_map(|t| t.spans.iter()).collect();
        let send_waits: Vec<_> =
            spans.iter().filter(|s| s.stage == Stage::BatchSendWait).collect();
        let recv_waits: Vec<_> =
            spans.iter().filter(|s| s.stage == Stage::BatchRecvWait).collect();
        assert_eq!(send_waits.len(), 1, "one blocked send -> one span");
        assert!(send_waits[0].dur_ns > 20_000_000, "send wait span too short");
        // The handoff after the first recv may add a micro-wait span, so
        // assert on the deliberate 40ms block rather than an exact count.
        assert!(!recv_waits.is_empty(), "blocked recv emitted no span");
        assert!(
            recv_waits.iter().any(|s| s.dur_ns > 20_000_000),
            "recv wait span too short"
        );
    }
}
