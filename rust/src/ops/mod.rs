//! CPU augmentation operators: random crop, horizontal flip, bilinear
//! resize, normalize — the paper's preprocessing pipeline steps 4 (Fig. 1),
//! implemented exactly like the Pallas kernel / jnp oracle so the `cpu`
//! and `hybrid`/`gpu` placements produce identical tensors.
//!
//! Two APIs:
//!   * [`augment_fused`] — the production hot path, one pass per image.
//!   * `crop` / `hflip` / `resize_bilinear` / `normalize` — discrete steps
//!     used by the Fig. 3 latency-breakdown bench (the paper times each
//!     operator separately).

use crate::util::rng::Rng;

/// ImageNet normalization constants scaled to the 0..255 pixel range
/// (must match python/compile/kernels/ref.py).
pub const NORM_MEAN: [f32; 3] = [0.485 * 255.0, 0.456 * 255.0, 0.406 * 255.0];
pub const NORM_STD: [f32; 3] = [0.229 * 255.0, 0.224 * 255.0, 0.225 * 255.0];

/// Augmentation parameters, sampled by the coordinator's RNG.
/// Serialized to the `[6]` f32 row consumed by the augment HLO artifact.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AugParams {
    pub y0: u32,
    pub x0: u32,
    pub crop_h: u32,
    pub crop_w: u32,
    pub flip: bool,
}

impl AugParams {
    /// The identity transform for an `h`x`w` image (full window, no flip).
    pub fn identity(h: u32, w: u32) -> Self {
        AugParams { y0: 0, x0: 0, crop_h: h, crop_w: w, flip: false }
    }

    /// Row layout consumed by the AOT augment artifact: [y0,x0,ch,cw,flip,0].
    pub fn to_row(&self) -> [f32; 6] {
        [
            self.y0 as f32,
            self.x0 as f32,
            self.crop_h as f32,
            self.crop_w as f32,
            if self.flip { 1.0 } else { 0.0 },
            0.0,
        ]
    }
}

/// RandomResizedCrop sampling bounds.  Shared with the fused decoder's
/// cache-admission scale ([`min_crop_side`]), which must never pick a
/// scale that would upsample any crop this sampler can draw.
pub const AUG_MIN_AREA: f64 = 0.35;
pub const AUG_MAX_RATIO: f64 = 4.0 / 3.0;

/// Smallest crop side [`sample_aug_params`] can draw on an `h`x`w`
/// image: `floor(sqrt(min_area·h·w / max_ratio))`, additionally bounded
/// by the 87.5% central-crop fallback — on high-aspect images the
/// sampler's 10 tries can all fail, and the fallback's short side is
/// then the true minimum — and floored at the sampler's 8-px minimum.
pub fn min_crop_side(h: u32, w: u32) -> u32 {
    let s = ((AUG_MIN_AREA * h as f64 * w as f64) / AUG_MAX_RATIO).sqrt().floor() as u32;
    let fallback = (h * 7 / 8).min(w * 7 / 8);
    s.min(fallback).max(8).min(h.min(w))
}

/// RandomResizedCrop-style sampling: area scale in [0.35, 1.0], aspect
/// ratio in [3/4, 4/3], uniform placement, fair-coin flip.
pub fn sample_aug_params(rng: &mut Rng, h: u32, w: u32) -> AugParams {
    for _ in 0..10 {
        let area = (h * w) as f64 * rng.uniform(AUG_MIN_AREA, 1.0);
        let log_ratio = rng.uniform((3f64 / 4.0).ln(), (4f64 / 3.0).ln());
        let ratio = log_ratio.exp();
        let cw = ((area * ratio).sqrt().round() as u32).max(8);
        let ch = ((area / ratio).sqrt().round() as u32).max(8);
        if cw <= w && ch <= h {
            let y0 = rng.gen_range((h - ch + 1) as u64) as u32;
            let x0 = rng.gen_range((w - cw + 1) as u64) as u32;
            return AugParams { y0, x0, crop_h: ch, crop_w: cw, flip: rng.bool() };
        }
    }
    // Fallback: central 87.5% crop.
    let ch = h * 7 / 8;
    let cw = w * 7 / 8;
    AugParams {
        y0: (h - ch) / 2,
        x0: (w - cw) / 2,
        crop_h: ch,
        crop_w: cw,
        flip: rng.bool(),
    }
}

/// Reusable row/column interpolation tables for the fused augment
/// sampler.  The allocating entry points build these per call; the
/// `_into` variants take one from the caller so a worker's steady state
/// allocates nothing (the zero-copy hot path, `util/slab.rs`).
///
/// The column tables are structure-of-arrays and carry `1 − wx`
/// precomputed (`omwx`): the old loop recomputed that subtraction per
/// row × column even though it is row-invariant, and the split arrays
/// are what the SIMD row kernel loads directly (`x0`/`x1` as i32 for
/// the AVX2 gather).  Both changes are value-identical — f32
/// subtraction is deterministic — so outputs stay bit-identical
/// (pinned by `soa_column_tables_match_inline_reference_loop`).
#[derive(Clone, Debug, Default)]
pub struct AugScratch {
    ys: Vec<(usize, usize, f32)>,
    x0: Vec<i32>,
    x1: Vec<i32>,
    wx: Vec<f32>,
    omwx: Vec<f32>,
}

impl AugScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Fused crop+flip+bilinear-resize+normalize. `img` is planar `[C,H,W]`
/// f32 pixels 0..255; output planar `[C,OH,OW]` normalized.
///
/// Math mirrors ref.py `augment_ref` exactly: half-pixel centers, sample
/// coords clamped inside the crop window, then inside the image.
pub fn augment_fused(
    img: &[f32],
    c: usize,
    h: usize,
    w: usize,
    p: &AugParams,
    oh: usize,
    ow: usize,
    out: &mut [f32],
) {
    augment_fused_view(img, c, h, w, (0, 0, h, w), p, oh, ow, out)
}

/// [`augment_fused`] with caller-owned interpolation scratch —
/// bit-identical (it is the same code path; the allocating wrapper
/// merely hands in fresh scratch).
#[allow(clippy::too_many_arguments)]
pub fn augment_fused_into(
    img: &[f32],
    c: usize,
    h: usize,
    w: usize,
    p: &AugParams,
    oh: usize,
    ow: usize,
    scratch: &mut AugScratch,
    out: &mut [f32],
) {
    augment_fused_view_into(img, c, h, w, (0, 0, h, w), p, oh, ow, scratch, out)
}

/// Like [`augment_fused`], but `img` holds only the rectangular view
/// `(vy, vx, vh, vw)` of a full `h`x`w` image — the fused ROI decoder's
/// output, placed at its true offset.  The crop window must lie inside
/// the view.
///
/// All sampling arithmetic runs in *full-image* coordinates — the exact
/// f32 operations of the full path — and only the final integer row/col
/// indices shift into the view, so the result is bit-identical to
/// augmenting the full image (the property `tests/fused_decode.rs`
/// drives).  The one extra clamp (against the view's far edge) can bind
/// only where the lerp weight is exactly 0, which preserves that.
#[allow(clippy::too_many_arguments)]
pub fn augment_fused_view(
    img: &[f32],
    c: usize,
    h: usize,
    w: usize,
    view: (usize, usize, usize, usize),
    p: &AugParams,
    oh: usize,
    ow: usize,
    out: &mut [f32],
) {
    augment_fused_view_into(img, c, h, w, view, p, oh, ow, &mut AugScratch::new(), out)
}

/// [`augment_fused_view`] with caller-owned interpolation scratch (the
/// zero-allocation hot path; bit-identical by construction — the
/// allocating entry points delegate here).
#[allow(clippy::too_many_arguments)]
pub fn augment_fused_view_into(
    img: &[f32],
    c: usize,
    h: usize,
    w: usize,
    view: (usize, usize, usize, usize),
    p: &AugParams,
    oh: usize,
    ow: usize,
    scratch: &mut AugScratch,
    out: &mut [f32],
) {
    augment_fused_view_into_level(img, c, h, w, view, p, oh, ow, scratch, out, crate::simd::active())
}

/// [`augment_fused_view_into`] at an explicit SIMD tier — the A/B entry
/// point for the property harness and `dpp bench simd` (every public
/// wrapper funnels here with the process-active tier).
#[allow(clippy::too_many_arguments)]
pub fn augment_fused_view_into_level(
    img: &[f32],
    c: usize,
    h: usize,
    w: usize,
    view: (usize, usize, usize, usize),
    p: &AugParams,
    oh: usize,
    ow: usize,
    scratch: &mut AugScratch,
    out: &mut [f32],
    level: crate::simd::SimdLevel,
) {
    let (vy, vx, vh, vw) = view;
    assert_eq!(img.len(), c * vh * vw);
    assert_eq!(out.len(), c * oh * ow);
    assert!(vy + vh <= h && vx + vw <= w, "view exceeds image");
    assert!(
        p.y0 as usize >= vy
            && p.x0 as usize >= vx
            && (p.y0 + p.crop_h) as usize <= vy + vh
            && (p.x0 + p.crop_w) as usize <= vx + vw,
        "crop window must lie inside the decoded view"
    );
    let chf = p.crop_h as f32;
    let cwf = p.crop_w as f32;

    // Precompute per-row/col source coords (view-relative) and weights
    // into the caller's scratch (capacity reused across samples).
    scratch.ys.clear();
    scratch.ys.resize(oh, (0usize, 0usize, 0f32));
    for (i, e) in scratch.ys.iter_mut().enumerate() {
        let iy = ((i as f32 + 0.5) * chf / oh as f32 - 0.5).clamp(0.0, chf - 1.0);
        let sy = (iy + p.y0 as f32).clamp(0.0, (h - 1) as f32);
        let y0 = sy.floor() as usize;
        let y1 = (y0 + 1).min(h - 1).min(vy + vh - 1);
        *e = (y0 - vy, y1 - vy, sy - y0 as f32);
    }
    // Column tables are row-invariant: index pairs, the lerp weight,
    // and its complement `1 − wx` are computed once per image here
    // (the inner loop previously redid the subtraction per row).
    scratch.x0.clear();
    scratch.x1.clear();
    scratch.wx.clear();
    scratch.omwx.clear();
    for j in 0..ow {
        let mut ix = (j as f32 + 0.5) * cwf / ow as f32 - 0.5;
        if p.flip {
            ix = (cwf - 1.0) - ix;
        }
        let ix = ix.clamp(0.0, cwf - 1.0);
        let sx = (ix + p.x0 as f32).clamp(0.0, (w - 1) as f32);
        let x0 = sx.floor() as usize;
        let x1 = (x0 + 1).min(w - 1).min(vx + vw - 1);
        let fx = sx - x0 as f32;
        scratch.x0.push((x0 - vx) as i32);
        scratch.x1.push((x1 - vx) as i32);
        scratch.wx.push(fx);
        scratch.omwx.push(1.0 - fx);
    }

    for ch in 0..c {
        let plane = &img[ch * vh * vw..(ch + 1) * vh * vw];
        let mean = NORM_MEAN[ch.min(2)];
        let istd = 1.0 / NORM_STD[ch.min(2)];
        let oplane = &mut out[ch * oh * ow..(ch + 1) * oh * ow];
        for (i, &(y0, y1, wy)) in scratch.ys.iter().enumerate() {
            let r0 = &plane[y0 * vw..y0 * vw + vw];
            let r1 = &plane[y1 * vw..y1 * vw + vw];
            let orow = &mut oplane[i * ow..(i + 1) * ow];
            crate::simd::bilerp_norm_row(
                r0,
                r1,
                &scratch.x0,
                &scratch.x1,
                &scratch.wx,
                &scratch.omwx,
                wy,
                mean,
                istd,
                orow,
                level,
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Discrete operators (Fig. 3 breakdown instrumentation)
// ---------------------------------------------------------------------------

/// Crop `[C,H,W]` -> `[C,ch,cw]` (pixel copy, no resampling).
pub fn crop(img: &[f32], c: usize, h: usize, w: usize, p: &AugParams) -> Vec<f32> {
    let mut out = vec![0f32; c * p.crop_h as usize * p.crop_w as usize];
    crop_into(img, c, h, w, p, &mut out);
    out
}

/// [`crop`] into a caller-owned buffer (bit-identical; the allocating
/// wrapper delegates here).
pub fn crop_into(img: &[f32], c: usize, h: usize, w: usize, p: &AugParams, out: &mut [f32]) {
    let (ch_, cw_) = (p.crop_h as usize, p.crop_w as usize);
    assert_eq!(out.len(), c * ch_ * cw_);
    for ch in 0..c {
        for y in 0..ch_ {
            let src = &img[ch * h * w + (p.y0 as usize + y) * w + p.x0 as usize..][..cw_];
            out[ch * ch_ * cw_ + y * cw_..][..cw_].copy_from_slice(src);
        }
    }
}

/// Horizontal flip in place, planar `[C,H,W]`.
pub fn hflip(img: &mut [f32], c: usize, h: usize, w: usize) {
    for ch in 0..c {
        for y in 0..h {
            img[ch * h * w + y * w..][..w].reverse();
        }
    }
}

/// Bilinear resize `[C,H,W]` -> `[C,OH,OW]` over the full image
/// (half-pixel centers, edge clamp).
pub fn resize_bilinear(
    img: &[f32],
    c: usize,
    h: usize,
    w: usize,
    oh: usize,
    ow: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; c * oh * ow];
    resize_bilinear_into(img, c, h, w, oh, ow, &mut AugScratch::new(), &mut out);
    out
}

/// [`resize_bilinear`] reusing caller scratch for its row-interpolation
/// temporaries instead of allocating them per call (bit-identical; the
/// allocating wrapper delegates here).
#[allow(clippy::too_many_arguments)]
pub fn resize_bilinear_into(
    img: &[f32],
    c: usize,
    h: usize,
    w: usize,
    oh: usize,
    ow: usize,
    scratch: &mut AugScratch,
    out: &mut [f32],
) {
    let p = AugParams::identity(h as u32, w as u32);
    // Resizing the full window with no normalize = fused path with unit norm.
    // Reuse the fused sampler but undo normalization.
    augment_fused_into(img, c, h, w, &p, oh, ow, scratch, out);
    for ch in 0..c {
        let mean = NORM_MEAN[ch.min(2)];
        let std = NORM_STD[ch.min(2)];
        for v in &mut out[ch * oh * ow..(ch + 1) * oh * ow] {
            *v = *v * std + mean;
        }
    }
}

/// Normalize in place with the ImageNet constants.
pub fn normalize(img: &mut [f32], c: usize, hw: usize) {
    normalize_level(img, c, hw, crate::simd::active());
}

/// [`normalize`] at an explicit SIMD tier (lane-parallel `(v−mean)·istd`
/// per plane; scalar tier is the bit-identity reference).
pub fn normalize_level(img: &mut [f32], c: usize, hw: usize, level: crate::simd::SimdLevel) {
    for ch in 0..c {
        let mean = NORM_MEAN[ch.min(2)];
        let istd = 1.0 / NORM_STD[ch.min(2)];
        crate::simd::normalize_inplace(&mut img[ch * hw..(ch + 1) * hw], mean, istd, level);
    }
}

/// Normalized copy into a caller-owned buffer: `out[i] = (img[i] −
/// mean)/std`, the out-of-place sibling of [`normalize`] for hot paths
/// whose destination is a batch-slab slot.
pub fn normalize_into(img: &[f32], c: usize, hw: usize, out: &mut [f32]) {
    normalize_into_level(img, c, hw, out, crate::simd::active());
}

/// [`normalize_into`] at an explicit SIMD tier.
pub fn normalize_into_level(
    img: &[f32],
    c: usize,
    hw: usize,
    out: &mut [f32],
    level: crate::simd::SimdLevel,
) {
    assert_eq!(img.len(), c * hw);
    assert_eq!(out.len(), c * hw);
    for ch in 0..c {
        let mean = NORM_MEAN[ch.min(2)];
        let istd = 1.0 / NORM_STD[ch.min(2)];
        crate::simd::normalize_copy(&img[ch * hw..(ch + 1) * hw], &mut out[ch * hw..(ch + 1) * hw], mean, istd, level);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp_image(c: usize, h: usize, w: usize) -> Vec<f32> {
        let mut v = vec![0f32; c * h * w];
        for ch in 0..c {
            for y in 0..h {
                for x in 0..w {
                    v[ch * h * w + y * w + x] = ((ch * 31 + y * 3 + x * 2) % 256) as f32;
                }
            }
        }
        v
    }

    #[test]
    fn identity_augment_of_same_size_is_normalize() {
        let (c, h, w) = (3, 56, 56);
        let img = ramp_image(c, h, w);
        let p = AugParams::identity(h as u32, w as u32);
        let mut out = vec![0f32; c * h * w];
        augment_fused(&img, c, h, w, &p, h, w, &mut out);
        for ch in 0..c {
            for i in 0..h * w {
                let expect = (img[ch * h * w + i] - NORM_MEAN[ch]) / NORM_STD[ch];
                assert!((out[ch * h * w + i] - expect).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn constant_image_any_crop_is_constant() {
        let (c, h, w) = (3, 64, 64);
        let img = vec![100.0f32; c * h * w];
        let p = AugParams { y0: 5, x0: 9, crop_h: 33, crop_w: 47, flip: true };
        let mut out = vec![0f32; c * 56 * 56];
        augment_fused(&img, c, h, w, &p, 56, 56, &mut out);
        for ch in 0..c {
            let expect = (100.0 - NORM_MEAN[ch]) / NORM_STD[ch];
            for &v in &out[ch * 56 * 56..(ch + 1) * 56 * 56] {
                assert!((v - expect).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn flip_mirrors_fused_output() {
        let (c, h, w) = (1, 64, 64);
        let img = ramp_image(c, h, w);
        let base = AugParams { y0: 4, x0: 6, crop_h: 48, crop_w: 48, flip: false };
        let flip = AugParams { flip: true, ..base };
        let mut o0 = vec![0f32; 56 * 56];
        let mut o1 = vec![0f32; 56 * 56];
        augment_fused(&img, c, h, w, &base, 56, 56, &mut o0);
        augment_fused(&img, c, h, w, &flip, 56, 56, &mut o1);
        for y in 0..56 {
            for x in 0..56 {
                let a = o0[y * 56 + x];
                let b = o1[y * 56 + (55 - x)];
                assert!((a - b).abs() < 1e-3, "({y},{x}): {a} vs {b}");
            }
        }
    }

    #[test]
    fn view_augment_is_bit_identical_to_full_augment() {
        let (c, h, w) = (3, 64, 64);
        let img = ramp_image(c, h, w);
        let mut rng = Rng::new(21);
        for _ in 0..50 {
            let p = sample_aug_params(&mut rng, h as u32, w as u32);
            // A block-aligned view covering the crop (what the fused ROI
            // decoder hands over), plus the full-image view as a control.
            let vy = (p.y0 as usize / 8) * 8;
            let vx = (p.x0 as usize / 8) * 8;
            let vh = ((p.y0 + p.crop_h) as usize).div_ceil(8) * 8 - vy;
            let vw = ((p.x0 + p.crop_w) as usize).div_ceil(8) * 8 - vx;
            let (vh, vw) = (vh.min(h - vy), vw.min(w - vx));
            let mut view = vec![0f32; c * vh * vw];
            for ch in 0..c {
                for y in 0..vh {
                    for x in 0..vw {
                        view[ch * vh * vw + y * vw + x] =
                            img[ch * h * w + (vy + y) * w + (vx + x)];
                    }
                }
            }
            let mut full = vec![0f32; c * 56 * 56];
            let mut roi = vec![0f32; c * 56 * 56];
            let mut ctl = vec![0f32; c * 56 * 56];
            augment_fused(&img, c, h, w, &p, 56, 56, &mut full);
            augment_fused_view(&view, c, h, w, (vy, vx, vh, vw), &p, 56, 56, &mut roi);
            augment_fused_view(&img, c, h, w, (0, 0, h, w), &p, 56, 56, &mut ctl);
            assert_eq!(full, roi, "{p:?} view ({vy},{vx},{vh},{vw})");
            assert_eq!(full, ctl, "{p:?}");
        }
    }

    #[test]
    fn crop_extracts_window() {
        let (c, h, w) = (2, 16, 16);
        let img = ramp_image(c, h, w);
        let p = AugParams { y0: 2, x0: 3, crop_h: 4, crop_w: 5, flip: false };
        let out = crop(&img, c, h, w, &p);
        assert_eq!(out.len(), 2 * 4 * 5);
        assert_eq!(out[0], img[2 * w + 3]);
        assert_eq!(out[4 * 5], img[h * w + 2 * w + 3]);
    }

    #[test]
    fn hflip_involution() {
        let (c, h, w) = (3, 8, 12);
        let img = ramp_image(c, h, w);
        let mut flipped = img.clone();
        hflip(&mut flipped, c, h, w);
        assert_ne!(img, flipped);
        hflip(&mut flipped, c, h, w);
        assert_eq!(img, flipped);
    }

    #[test]
    fn resize_identity_when_same_size() {
        let (c, h, w) = (1, 24, 24);
        let img = ramp_image(c, h, w);
        let out = resize_bilinear(&img, c, h, w, h, w);
        for i in 0..img.len() {
            assert!((img[i] - out[i]).abs() < 1e-3, "{i}: {} vs {}", img[i], out[i]);
        }
    }

    #[test]
    fn normalize_then_denormalize() {
        let (c, hw) = (3, 64);
        let img = ramp_image(c, 8, 8);
        let mut n = img.clone();
        normalize(&mut n, c, hw);
        for ch in 0..c {
            for i in 0..hw {
                let back = n[ch * hw + i] * NORM_STD[ch] + NORM_MEAN[ch];
                assert!((back - img[ch * hw + i]).abs() < 1e-3);
            }
        }
    }

    /// The `_into` satellite: every allocating operator is bit-identical
    /// to its scratch-taking variant, with ONE scratch reused across all
    /// iterations (stale table contents from the previous geometry must
    /// never leak into the next call).
    #[test]
    fn into_variants_are_bit_identical_with_reused_scratch() {
        let mut rng = Rng::new(33);
        let mut scratch = AugScratch::new();
        for round in 0..40usize {
            let (c, h, w) = (3usize, 64usize, 64usize);
            let img = {
                let mut v = ramp_image(c, h, w);
                // Perturb so rounds differ.
                v[round % v.len()] = (round % 255) as f32;
                v
            };
            let p = sample_aug_params(&mut rng, h as u32, w as u32);
            let (oh, ow) = (8 + (round % 3) * 24, 8 + (round % 5) * 12);

            let mut a = vec![0f32; c * oh * ow];
            let mut b = vec![0f32; c * oh * ow];
            augment_fused(&img, c, h, w, &p, oh, ow, &mut a);
            augment_fused_into(&img, c, h, w, &p, oh, ow, &mut scratch, &mut b);
            assert_eq!(a, b, "augment round {round} {p:?}");

            let cr = crop(&img, c, h, w, &p);
            let mut cr2 = vec![0f32; cr.len()];
            crop_into(&img, c, h, w, &p, &mut cr2);
            assert_eq!(cr, cr2, "crop round {round}");

            let rs = resize_bilinear(&img, c, h, w, oh, ow);
            let mut rs2 = vec![0f32; rs.len()];
            resize_bilinear_into(&img, c, h, w, oh, ow, &mut scratch, &mut rs2);
            assert_eq!(rs, rs2, "resize round {round}");

            let mut n = img.clone();
            normalize(&mut n, c, h * w);
            let mut n2 = vec![0f32; img.len()];
            normalize_into(&img, c, h * w, &mut n2);
            assert_eq!(n, n2, "normalize round {round}");
        }
    }

    /// Satellite regression: the SoA column tables with `1 − wx`
    /// precomputed once per image must be bit-identical to the original
    /// inner loop (AoS tuples, the subtraction redone per row × column).
    /// Checked at the scalar tier (isolates the table refactor from the
    /// vector kernels) and at the detected tier (the full stack).
    #[test]
    fn soa_column_tables_match_inline_reference_loop() {
        let mut rng = Rng::new(55);
        let mut scratch = AugScratch::new();
        let rounds = if cfg!(miri) { 4 } else { 40 };
        for round in 0..rounds {
            let (c, h, w) = (3usize, 64usize, 64usize);
            let mut img = ramp_image(c, h, w);
            img[round % img.len()] = (round % 251) as f32;
            let p = sample_aug_params(&mut rng, h as u32, w as u32);
            // Odd output sizes, including non-multiple-of-lane widths.
            let (oh, ow) = (1 + (round % 8) * 9, 1 + (round % 7) * 11);

            // Reference: the pre-SIMD loop, verbatim (full-image view).
            let chf = p.crop_h as f32;
            let cwf = p.crop_w as f32;
            let mut ys = vec![(0usize, 0usize, 0f32); oh];
            for (i, e) in ys.iter_mut().enumerate() {
                let iy = ((i as f32 + 0.5) * chf / oh as f32 - 0.5).clamp(0.0, chf - 1.0);
                let sy = (iy + p.y0 as f32).clamp(0.0, (h - 1) as f32);
                let y0 = sy.floor() as usize;
                let y1 = (y0 + 1).min(h - 1);
                *e = (y0, y1, sy - y0 as f32);
            }
            let mut xs = vec![(0usize, 0usize, 0f32); ow];
            for (j, e) in xs.iter_mut().enumerate() {
                let mut ix = (j as f32 + 0.5) * cwf / ow as f32 - 0.5;
                if p.flip {
                    ix = (cwf - 1.0) - ix;
                }
                let ix = ix.clamp(0.0, cwf - 1.0);
                let sx = (ix + p.x0 as f32).clamp(0.0, (w - 1) as f32);
                let x0 = sx.floor() as usize;
                let x1 = (x0 + 1).min(w - 1);
                *e = (x0, x1, sx - x0 as f32);
            }
            let mut want = vec![0f32; c * oh * ow];
            for ch in 0..c {
                let plane = &img[ch * h * w..(ch + 1) * h * w];
                let mean = NORM_MEAN[ch.min(2)];
                let istd = 1.0 / NORM_STD[ch.min(2)];
                let oplane = &mut want[ch * oh * ow..(ch + 1) * oh * ow];
                for (i, &(y0, y1, wy)) in ys.iter().enumerate() {
                    let r0 = &plane[y0 * w..y0 * w + w];
                    let r1 = &plane[y1 * w..y1 * w + w];
                    let orow = &mut oplane[i * ow..(i + 1) * ow];
                    for (j, &(x0, x1, wx)) in xs.iter().enumerate() {
                        let top = r0[x0] * (1.0 - wx) + r0[x1] * wx;
                        let bot = r1[x0] * (1.0 - wx) + r1[x1] * wx;
                        let v = top * (1.0 - wy) + bot * wy;
                        orow[j] = (v - mean) * istd;
                    }
                }
            }

            for level in [crate::simd::SimdLevel::Scalar, crate::simd::detect()] {
                let mut got = vec![0f32; c * oh * ow];
                augment_fused_view_into_level(
                    &img, c, h, w, (0, 0, h, w), &p, oh, ow, &mut scratch, &mut got, level,
                );
                assert_eq!(want, got, "round {round} {level:?} {p:?} {oh}x{ow}");
            }
        }
    }

    #[test]
    fn sampled_params_always_valid() {
        let mut rng = Rng::new(11);
        let floor = min_crop_side(64, 64);
        assert_eq!(floor, 32, "0.35 area / 4:3 aspect on 64x64");
        // High aspect: sampling always rejects (min ch would exceed h),
        // so the 87.5% fallback's short side is the true minimum.
        assert_eq!(min_crop_side(64, 256), 56);
        for _ in 0..500 {
            let p = sample_aug_params(&mut rng, 64, 64);
            assert!(p.crop_h >= 8 && p.crop_w >= 8);
            assert!(p.y0 + p.crop_h <= 64, "{p:?}");
            assert!(p.x0 + p.crop_w <= 64, "{p:?}");
            // min_crop_side is a true lower bound — what lets the cache
            // admission pick a scale that can never upsample a crop.
            assert!(p.crop_h >= floor && p.crop_w >= floor, "{p:?} below {floor}");
        }
    }
}
