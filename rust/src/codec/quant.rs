//! Quantization tables and zigzag scan order (JPEG Annex K conventions).

/// JPEG luminance base quantization table (natural row-major order).
pub const BASE_QTABLE: [u16; 64] = [
    16, 11, 10, 16, 24, 40, 51, 61,
    12, 12, 14, 19, 26, 58, 60, 55,
    14, 13, 16, 24, 40, 57, 69, 56,
    14, 17, 22, 29, 51, 87, 80, 62,
    18, 22, 37, 56, 68, 109, 103, 77,
    24, 35, 55, 64, 81, 104, 113, 92,
    49, 64, 78, 87, 103, 121, 120, 101,
    72, 92, 95, 98, 112, 100, 103, 99,
];

/// Zigzag order: `ZIGZAG[zi]` = natural index of the zi-th scanned coeff.
pub const ZIGZAG: [usize; 64] = [
    0, 1, 8, 16, 9, 2, 3, 10,
    17, 24, 32, 25, 18, 11, 4, 5,
    12, 19, 26, 33, 40, 48, 41, 34,
    27, 20, 13, 6, 7, 14, 21, 28,
    35, 42, 49, 56, 57, 50, 43, 36,
    29, 22, 15, 23, 30, 37, 44, 51,
    58, 59, 52, 45, 38, 31, 39, 46,
    53, 60, 61, 54, 47, 55, 62, 63,
];

/// Scale the base table by quality 1..=100 (libjpeg convention), returned
/// in natural order as f32 (the dequant factor used by CPU and kernel).
pub fn qtable_for_quality(quality: u8) -> [f32; 64] {
    let q = quality.clamp(1, 100) as i32;
    let scale = if q < 50 { 5000 / q } else { 200 - 2 * q };
    let mut out = [0f32; 64];
    for i in 0..64 {
        let v = (BASE_QTABLE[i] as i32 * scale + 50) / 100;
        out[i] = v.clamp(1, 255) as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zigzag_is_permutation() {
        let mut seen = [false; 64];
        for &i in &ZIGZAG {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn zigzag_first_entries() {
        assert_eq!(&ZIGZAG[..6], &[0, 1, 8, 16, 9, 2]);
    }

    #[test]
    fn quality_50_is_base() {
        let q = qtable_for_quality(50);
        for i in 0..64 {
            assert_eq!(q[i], BASE_QTABLE[i] as f32);
        }
    }

    #[test]
    fn quality_monotone() {
        // Higher quality -> smaller (or equal) quantization steps.
        let q90 = qtable_for_quality(90);
        let q30 = qtable_for_quality(30);
        for i in 0..64 {
            assert!(q90[i] <= q30[i]);
        }
        assert!(qtable_for_quality(100).iter().all(|&v| v == 1.0));
    }

    #[test]
    fn table_clamped() {
        for q in [1u8, 5, 25, 50, 75, 100] {
            for v in qtable_for_quality(q) {
                assert!((1.0..=255.0).contains(&v));
            }
        }
    }
}
