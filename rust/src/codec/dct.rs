//! 8x8 DCT-II / DCT-III via the orthonormal DCT matrix.
//!
//! Same math as `python/compile/kernels/dct.py`: forward F = C·X·Cᵀ,
//! inverse X = Cᵀ·F·C.  Row-pass + column-pass keeps it cache-friendly;
//! the inner loops are plain f32 FMA chains the compiler vectorizes.

use once_cell::sync::Lazy;

/// Orthonormal N-point DCT matrix, `mat[k][n]` (N = 8 for the full-size
/// kernels; 4/2 for the fractional-scale decode).
fn dct_matrix<const N: usize>() -> [[f32; N]; N] {
    let mut c = [[0f32; N]; N];
    for k in 0..N {
        let s = if k == 0 { (1.0f64 / N as f64).sqrt() } else { (2.0f64 / N as f64).sqrt() };
        for n in 0..N {
            c[k][n] = (s
                * ((2.0 * n as f64 + 1.0) * k as f64 * std::f64::consts::PI / (2.0 * N as f64))
                    .cos()) as f32;
        }
    }
    c
}

/// Orthonormal 8x8 DCT matrix, `DCT_MAT[k][n]`.
pub static DCT_MAT: Lazy<[[f32; 8]; 8]> = Lazy::new(dct_matrix::<8>);

/// 4-point basis for the 1/2-scale IDCT.
static DCT_MAT4: Lazy<[[f32; 4]; 4]> = Lazy::new(dct_matrix::<4>);

/// 2-point basis for the 1/4-scale IDCT.
static DCT_MAT2: Lazy<[[f32; 2]; 2]> = Lazy::new(dct_matrix::<2>);

#[inline]
fn mat8_mul(a: &[[f32; 8]; 8], x: &[f32; 64], out: &mut [f32; 64], transpose_a: bool) {
    // out = A · X (or Aᵀ · X), X row-major 8x8.
    for i in 0..8 {
        for j in 0..8 {
            let mut acc = 0f32;
            for k in 0..8 {
                let aik = if transpose_a { a[k][i] } else { a[i][k] };
                acc += aik * x[k * 8 + j];
            }
            out[i * 8 + j] = acc;
        }
    }
}

#[inline]
fn mat8_mul_right(x: &[f32; 64], a: &[[f32; 8]; 8], out: &mut [f32; 64], transpose_a: bool) {
    // out = X · A (or X · Aᵀ).
    for i in 0..8 {
        for j in 0..8 {
            let mut acc = 0f32;
            for k in 0..8 {
                let akj = if transpose_a { a[j][k] } else { a[k][j] };
                acc += x[i * 8 + k] * akj;
            }
            out[i * 8 + j] = acc;
        }
    }
}

/// Forward DCT of a level-shifted 8x8 block: `coef = C · block · Cᵀ`.
pub fn fdct_block(block: &[f32; 64], coef: &mut [f32; 64]) {
    let mut tmp = [0f32; 64];
    mat8_mul(&DCT_MAT, block, &mut tmp, false);
    mat8_mul_right(&tmp, &DCT_MAT, coef, true);
}

/// Inverse DCT: `block = Cᵀ · coef · C` (pixels still level-shifted).
pub fn idct_block(coef: &[f32; 64], block: &mut [f32; 64]) {
    let mut tmp = [0f32; 64];
    mat8_mul(&DCT_MAT, coef, &mut tmp, true);
    mat8_mul_right(&tmp, &DCT_MAT, block, false);
}

/// Fused dequantize + IDCT with a DC-only fast path — the decode hot path
/// (§Perf).  Dispatches to the process-active SIMD tier (`--simd`); the
/// scalar kernel below is the portable fallback and the bit-identity
/// reference (`tests/simd_kernels.rs`).
pub fn dequant_idct_block(coef: &[f32; 64], q: &[f32; 64], block: &mut [f32; 64]) {
    dequant_idct_block_level(coef, q, block, crate::simd::active());
}

/// [`dequant_idct_block`] at an explicit SIMD tier — the per-image hoist
/// for decode loops (one `active()` read per image, not per block) and
/// the A/B entry point for the property harness and `dpp bench simd`.
pub fn dequant_idct_block_level(
    coef: &[f32; 64],
    q: &[f32; 64],
    block: &mut [f32; 64],
    level: crate::simd::SimdLevel,
) {
    if crate::simd::dequant_idct8(coef, q, &DCT_MAT, block, level) {
        return;
    }
    dequant_idct_block_scalar(coef, q, block);
}

/// Scalar reference kernel: quantization zeroes most AC coefficients on
/// natural images, so flat blocks skip both matrix passes entirely, and
/// the general path folds the dequant multiply into the first pass with
/// a contiguous, vectorizable inner loop.
pub fn dequant_idct_block_scalar(coef: &[f32; 64], q: &[f32; 64], block: &mut [f32; 64]) {
    // DC-only check: one pass over the ACs (cheap; usually succeeds on
    // smooth content).
    let mut any_ac = 0f32;
    for i in 1..64 {
        any_ac += coef[i].abs();
    }
    if any_ac == 0.0 {
        // Orthonormal DCT: constant block = DC/8.
        let v = coef[0] * q[0] * 0.125;
        block.fill(v);
        return;
    }

    let c = &*DCT_MAT;
    // Dequantize once per row, tracking which rows are all-zero.
    let mut fq = [0f32; 64];
    let mut row_mask = 0u8;
    for k in 0..8 {
        let row = &coef[k * 8..k * 8 + 8];
        if row.iter().all(|&v| v == 0.0) {
            continue;
        }
        row_mask |= 1 << k;
        let qrow = &q[k * 8..k * 8 + 8];
        let out = &mut fq[k * 8..k * 8 + 8];
        for j in 0..8 {
            out[j] = row[j] * qrow[j];
        }
    }
    // Pass 1: tmp = Cᵀ · fq, register accumulators, zero rows skipped.
    let mut tmp = [0f32; 64];
    for i in 0..8 {
        let mut acc = [0f32; 8];
        for k in 0..8 {
            if row_mask & (1 << k) == 0 {
                continue;
            }
            let a = c[k][i]; // Cᵀ[i][k]
            let frow = &fq[k * 8..k * 8 + 8];
            for j in 0..8 {
                acc[j] += a * frow[j];
            }
        }
        tmp[i * 8..i * 8 + 8].copy_from_slice(&acc);
    }
    // Pass 2: block = tmp · C, register accumulators.
    for i in 0..8 {
        let trow = &tmp[i * 8..i * 8 + 8];
        let mut acc = [0f32; 8];
        for (k, &t) in trow.iter().enumerate() {
            let crow = &c[k];
            for j in 0..8 {
                acc[j] += t * crow[j];
            }
        }
        block[i * 8..i * 8 + 8].copy_from_slice(&acc);
    }
}

/// Fused dequantize + *scaled* IDCT: reconstruct an n×n pixel block
/// (n = 8 >> scale_log2) from the top-left n×n corner of the quantized
/// coefficients — libjpeg's fractional decode (`scaled_size`), the trick
/// nvJPEG/DALI expose as decoder-side downscaling.
///
/// Math: an n-point inverse transform of the low-frequency corner,
/// with each coefficient scaled by n/8 (once per dimension, √(n/8)²) so
/// the orthonormal bases line up.  The result samples the block's cosine
/// series at n half-pixel centers: exact for DC-only blocks, and within
/// the quantization error of a box-downsample for natural content (the
/// dropped coefficients are the frequencies a downsample would alias
/// anyway).  `scale_log2 == 0` delegates to [`dequant_idct_block`].
///
/// `out` must hold exactly n·n values (row-major n×n block).
pub fn dequant_idct_block_scaled(
    coef: &[f32; 64],
    q: &[f32; 64],
    scale_log2: usize,
    out: &mut [f32],
) {
    dequant_idct_block_scaled_level(coef, q, scale_log2, out, crate::simd::active());
}

/// [`dequant_idct_block_scaled`] at an explicit SIMD tier.  The 8- and
/// 4-point kernels vectorize (8 and 4 lanes per row); the 2- and
/// 1-point kernels stay scalar — 4 and 1 outputs leave nothing to
/// vectorize — so they are their own A/B reference.
pub fn dequant_idct_block_scaled_level(
    coef: &[f32; 64],
    q: &[f32; 64],
    scale_log2: usize,
    out: &mut [f32],
    level: crate::simd::SimdLevel,
) {
    match scale_log2 {
        0 => {
            let buf: &mut [f32; 64] = out.try_into().expect("out must be 8x8");
            dequant_idct_block_level(coef, q, buf, level);
        }
        1 => {
            if !crate::simd::dequant_idct4(coef, q, &DCT_MAT4, out, level) {
                idct_corner::<4>(coef, q, &*DCT_MAT4, out);
            }
        }
        2 => idct_corner::<2>(coef, q, &*DCT_MAT2, out),
        3 => {
            assert_eq!(out.len(), 1, "out must be 1x1");
            // 1-point basis is [1], scale (1/8)² per dimension pair = 1/8
            // overall: the block mean, exactly the DC fast path's value.
            out[0] = coef[0] * q[0] * 0.125;
        }
        _ => panic!("scale_log2 must be 0..=3, got {scale_log2}"),
    }
}

/// n-point inverse transform of the dequantized top-left n×n corner:
/// `out = Cnᵀ · (s·F) · Cn` with `s = n/8`.  N is 4 or 2 — small enough
/// that the naive quadruple loop beats setting up row/column passes.
fn idct_corner<const N: usize>(coef: &[f32; 64], q: &[f32; 64], c: &[[f32; N]; N], out: &mut [f32]) {
    assert_eq!(out.len(), N * N, "out must be {N}x{N}");
    let s = N as f32 / 8.0;
    let mut f = [[0f32; N]; N];
    for u in 0..N {
        for v in 0..N {
            f[u][v] = coef[u * 8 + v] * q[u * 8 + v] * s;
        }
    }
    for i in 0..N {
        for j in 0..N {
            let mut acc = 0f32;
            for u in 0..N {
                for v in 0..N {
                    acc += c[u][i] * f[u][v] * c[v][j];
                }
            }
            out[i * N + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dct_matrix_orthonormal() {
        let c = &*DCT_MAT;
        for i in 0..8 {
            for j in 0..8 {
                let dot: f32 = (0..8).map(|k| c[i][k] * c[j][k]).sum();
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-6, "({i},{j}) -> {dot}");
            }
        }
    }

    #[test]
    fn fdct_idct_roundtrip() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let mut block = [0f32; 64];
            for v in block.iter_mut() {
                *v = rng.uniform(-128.0, 127.0) as f32;
            }
            let mut coef = [0f32; 64];
            let mut back = [0f32; 64];
            fdct_block(&block, &mut coef);
            idct_block(&coef, &mut back);
            for i in 0..64 {
                assert!((block[i] - back[i]).abs() < 1e-3, "{} vs {}", block[i], back[i]);
            }
        }
    }

    #[test]
    fn dc_coefficient_is_scaled_mean() {
        let block = [24.0f32; 64];
        let mut coef = [0f32; 64];
        fdct_block(&block, &mut coef);
        // Orthonormal DCT: DC = 8 * mean.
        assert!((coef[0] - 8.0 * 24.0).abs() < 1e-3, "dc={}", coef[0]);
        for (i, &c) in coef.iter().enumerate().skip(1) {
            assert!(c.abs() < 1e-3, "AC[{i}]={c}");
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        let mut rng = Rng::new(2);
        let mut block = [0f32; 64];
        for v in block.iter_mut() {
            *v = rng.uniform(-100.0, 100.0) as f32;
        }
        let mut coef = [0f32; 64];
        fdct_block(&block, &mut coef);
        let e1: f32 = block.iter().map(|v| v * v).sum();
        let e2: f32 = coef.iter().map(|v| v * v).sum();
        assert!((e1 - e2).abs() / e1 < 1e-4, "{e1} vs {e2}");
    }
}

#[cfg(test)]
mod perf_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dequant_idct_block_matches_reference_path() {
        let mut rng = Rng::new(3);
        for case in 0..100 {
            let mut coef = [0f32; 64];
            // Mix of dense, sparse and DC-only blocks.
            let density = match case % 3 {
                0 => 1.0,
                1 => 0.15,
                _ => 0.0,
            };
            coef[0] = rng.uniform(-500.0, 500.0).round() as f32;
            for v in coef.iter_mut().skip(1) {
                if rng.f64() < density {
                    *v = rng.uniform(-200.0, 200.0).round() as f32;
                }
            }
            let mut q = [0f32; 64];
            for v in q.iter_mut() {
                *v = rng.uniform(1.0, 60.0).round() as f32;
            }
            // Reference: explicit dequant then plain idct.
            let mut freq = [0f32; 64];
            for i in 0..64 {
                freq[i] = coef[i] * q[i];
            }
            let mut want = [0f32; 64];
            idct_block(&freq, &mut want);
            // Fused fast path.
            let mut got = [1234f32; 64]; // poison to catch missed writes
            dequant_idct_block(&coef, &q, &mut got);
            for i in 0..64 {
                assert!(
                    (want[i] - got[i]).abs() < 2e-2,
                    "case {case} idx {i}: {} vs {}",
                    want[i],
                    got[i]
                );
            }
        }
    }

    #[test]
    fn dc_only_fast_path_exact() {
        let mut coef = [0f32; 64];
        coef[0] = 24.0;
        let q = [3.0f32; 64];
        let mut out = [0f32; 64];
        dequant_idct_block(&coef, &q, &mut out);
        for &v in &out {
            assert!((v - 24.0 * 3.0 / 8.0).abs() < 1e-5);
        }
    }

    #[test]
    fn scaled_bases_are_orthonormal() {
        fn check<const N: usize>(c: &[[f32; N]; N]) {
            for i in 0..N {
                for j in 0..N {
                    let dot: f32 = (0..N).map(|k| c[i][k] * c[j][k]).sum();
                    let expect = if i == j { 1.0 } else { 0.0 };
                    assert!((dot - expect).abs() < 1e-6, "N={N} ({i},{j}) -> {dot}");
                }
            }
        }
        check::<4>(&DCT_MAT4);
        check::<2>(&DCT_MAT2);
    }

    #[test]
    fn scaled_idct_scale0_is_full_kernel() {
        let mut rng = Rng::new(9);
        let mut coef = [0f32; 64];
        let mut q = [0f32; 64];
        for v in coef.iter_mut() {
            *v = rng.uniform(-80.0, 80.0).round() as f32;
        }
        for v in q.iter_mut() {
            *v = rng.uniform(1.0, 40.0).round() as f32;
        }
        let mut full = [0f32; 64];
        dequant_idct_block(&coef, &q, &mut full);
        let mut via = [0f32; 64];
        dequant_idct_block_scaled(&coef, &q, 0, &mut via);
        assert_eq!(full, via);
    }

    #[test]
    fn scaled_idct_dc_only_is_exact_block_mean_at_every_scale() {
        let mut coef = [0f32; 64];
        coef[0] = -40.0;
        let q = [2.0f32; 64];
        let want = -40.0 * 2.0 / 8.0;
        for k in 0..=3usize {
            let n = 8 >> k;
            let mut out = vec![0f32; n * n];
            dequant_idct_block_scaled(&coef, &q, k, &mut out);
            for &v in &out {
                assert!((v - want).abs() < 1e-4, "scale 1/{}: {v} vs {want}", 1 << k);
            }
        }
    }

    #[test]
    fn simd_idct_levels_are_bit_identical_to_scalar() {
        use crate::simd::{detect, SimdLevel};
        let levels: Vec<SimdLevel> =
            [SimdLevel::Sse2, SimdLevel::Avx2].into_iter().filter(|&l| l <= detect()).collect();
        let mut rng = Rng::new(41);
        let cases = if cfg!(miri) { 8 } else { 200 };
        for case in 0..cases {
            let mut coef = [0f32; 64];
            coef[0] = rng.uniform(-500.0, 500.0).round() as f32;
            match case % 4 {
                0 => {
                    // Dense.
                    for v in coef.iter_mut().skip(1) {
                        *v = rng.uniform(-200.0, 200.0).round() as f32;
                    }
                }
                1 => {
                    // Sparse (exercises the zero-row mask).
                    for v in coef.iter_mut().skip(1) {
                        if rng.f64() < 0.1 {
                            *v = rng.uniform(-200.0, 200.0).round() as f32;
                        }
                    }
                }
                2 => {
                    // Single nonzero row (every mask pattern over cases).
                    let k = case % 8;
                    for j in 0..8 {
                        coef[k * 8 + j] = rng.uniform(-100.0, 100.0).round() as f32;
                    }
                }
                _ => {} // DC-only fast path.
            }
            let mut q = [0f32; 64];
            for v in q.iter_mut() {
                *v = rng.uniform(1.0, 60.0).round() as f32;
            }
            for scale in 0..=3usize {
                let n = 8 >> scale;
                let mut want = vec![0f32; n * n];
                dequant_idct_block_scaled_level(&coef, &q, scale, &mut want, SimdLevel::Scalar);
                for &level in &levels {
                    let mut got = vec![1e9f32; n * n]; // poison
                    dequant_idct_block_scaled_level(&coef, &q, scale, &mut got, level);
                    assert_eq!(want, got, "case {case} scale 1/{} {level:?}", 1 << scale);
                }
            }
        }
    }

    #[test]
    fn scaled_idct_tracks_box_downsample_of_full_idct() {
        // Low-frequency blocks (the post-quantization norm on natural
        // images), confined to the 2x2 corner so every tested scale
        // retains all content: the n-point corner transform must stay
        // close to the box-downsampled full reconstruction.
        let mut rng = Rng::new(10);
        let q = [1.0f32; 64];
        for _ in 0..50 {
            let mut coef = [0f32; 64];
            for u in 0..2 {
                for v in 0..2 {
                    coef[u * 8 + v] = rng.uniform(-60.0, 60.0).round() as f32;
                }
            }
            let mut full = [0f32; 64];
            dequant_idct_block(&coef, &q, &mut full);
            for k in [1usize, 2] {
                let n = 8 >> k;
                let step = 8 / n;
                let mut out = vec![0f32; n * n];
                dequant_idct_block_scaled(&coef, &q, k, &mut out);
                let amp: f32 = coef.iter().map(|v| v.abs()).sum();
                for i in 0..n {
                    for j in 0..n {
                        let mut mean = 0f32;
                        for y in 0..step {
                            for x in 0..step {
                                mean += full[(i * step + y) * 8 + (j * step + x)];
                            }
                        }
                        mean /= (step * step) as f32;
                        // Midpoint-sample vs box-average of a k<=2 cosine
                        // series: bounded by a modest fraction of the
                        // total coefficient amplitude.
                        let tol = 0.08 * amp + 1.0;
                        assert!(
                            (out[i * n + j] - mean).abs() < tol,
                            "scale 1/{}: ({i},{j}) {} vs {mean} (tol {tol})",
                            1 << k,
                            out[i * n + j]
                        );
                    }
                }
            }
        }
    }
}
