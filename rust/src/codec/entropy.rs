//! Entropy coding for quantized coefficient blocks: zigzag scan, zero-run
//! RLE, signed varints.  This is the "CPU half" of hybrid decode (the role
//! Huffman plays in nvJPEG): cheap, branchy, inherently serial per block —
//! exactly the stage the paper leaves on the CPU.
//!
//! Per block (64 coeffs in zigzag order):
//!   token 0x00..=0x3E : run of `token` zeros, then one signed-varint coeff
//!   token 0x3F        : EOB — all remaining coefficients are zero
//! Blocks are byte-aligned; the stream needs no global terminator.

use super::quant::ZIGZAG;
use anyhow::{bail, Result};

pub const EOB: u8 = 0x3F;
const MAX_RUN: u8 = 0x3E;

/// ZigZag-encode a signed int into unsigned LEB128 space.
#[inline]
fn zz_enc(v: i32) -> u32 {
    ((v << 1) ^ (v >> 31)) as u32
}

#[inline]
fn zz_dec(u: u32) -> i32 {
    ((u >> 1) as i32) ^ -((u & 1) as i32)
}

fn put_varint(out: &mut Vec<u8>, mut u: u32) {
    loop {
        let b = (u & 0x7F) as u8;
        u >>= 7;
        if u == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

pub struct EntropyWriter<'a> {
    out: &'a mut Vec<u8>,
}

impl<'a> EntropyWriter<'a> {
    pub fn new(out: &'a mut Vec<u8>) -> Self {
        EntropyWriter { out }
    }

    /// Write one quantized 8x8 block given in *natural* order.
    pub fn write_block(&mut self, quantized: &[i32; 64]) -> Result<()> {
        let mut run: u8 = 0;
        // Find last nonzero in zigzag order for EOB placement.
        let mut last_nz: i32 = -1;
        for zi in (0..64).rev() {
            if quantized[ZIGZAG[zi]] != 0 {
                last_nz = zi as i32;
                break;
            }
        }
        for zi in 0..=last_nz.max(-1) {
            let v = quantized[ZIGZAG[zi as usize]];
            if v == 0 {
                run += 1;
                if run == MAX_RUN {
                    // Emit max-run token with a literal zero to reset.
                    self.out.push(MAX_RUN - 1);
                    put_varint(self.out, zz_enc(0));
                    run = 0;
                }
            } else {
                self.out.push(run);
                put_varint(self.out, zz_enc(v));
                run = 0;
            }
        }
        self.out.push(EOB);
        Ok(())
    }

    pub fn finish(self) -> Result<()> {
        Ok(())
    }
}

/// Token classes of the flat 256-entry decode table: every possible
/// token byte is pre-classified so the hot loop replaces its range
/// compares with one indexed load (the table-driven half of the SIMD
/// PR; the table itself is tiny and read-only, so it lives in rodata).
const TOK_RUN: u8 = 0;
const TOK_EOB: u8 = 1;
const TOK_BAD: u8 = 2;

const fn build_token_class() -> [u8; 256] {
    let mut t = [TOK_BAD; 256];
    let mut i = 0usize;
    while i < 256 {
        if i == EOB as usize {
            t[i] = TOK_EOB;
        } else if i <= MAX_RUN as usize {
            t[i] = TOK_RUN;
        }
        i += 1;
    }
    t
}

static TOKEN_CLASS: [u8; 256] = build_token_class();

/// The longest symbol (token + 5-byte varint) a single 64-bit window
/// load must cover; windows shorter than a full load fall back to the
/// byte-at-a-time tail.
const WINDOW_BYTES: usize = 8;

pub struct EntropyReader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Table-driven fast decode (`--simd`): one unaligned 64-bit load
    /// per symbol + the flat token table.  `false` pins the
    /// byte-at-a-time reference loop.  Both paths produce identical
    /// coefficients, consume identical byte counts, and fail with
    /// identical errors at identical positions (`tests/simd_kernels.rs`
    /// drives the A/B).
    fast: bool,
}

impl<'a> EntropyReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self::with_table_decode(buf, crate::simd::entropy_fast())
    }

    /// [`EntropyReader::new`] with the fast path pinned explicitly —
    /// the A/B constructor for tests and `dpp bench simd`.
    pub fn with_table_decode(buf: &'a [u8], fast: bool) -> Self {
        EntropyReader { buf, pos: 0, fast }
    }

    #[inline]
    fn byte(&mut self) -> Result<u8> {
        match self.buf.get(self.pos) {
            Some(&b) => {
                self.pos += 1;
                Ok(b)
            }
            None => bail!("entropy stream truncated at {}", self.pos),
        }
    }

    fn get_varint(&mut self) -> Result<u32> {
        let mut u: u32 = 0;
        let mut shift = 0;
        loop {
            let b = self.byte()?;
            u |= ((b & 0x7F) as u32) << shift;
            if b & 0x80 == 0 {
                return Ok(u);
            }
            shift += 7;
            if shift > 28 {
                bail!("varint overflow");
            }
        }
    }

    /// Read one block into `quantized` (natural order, zigzag inverted
    /// by the caller if it wants scan order — we fill natural directly).
    pub fn read_block(&mut self, quantized: &mut [i32; 64]) -> Result<()> {
        if self.fast {
            return self.read_block_table(quantized);
        }
        self.read_block_slow(quantized)
    }

    /// Byte-at-a-time reference decode — the `--simd off` path and the
    /// oracle the table path is A/B'd against.
    fn read_block_slow(&mut self, quantized: &mut [i32; 64]) -> Result<()> {
        quantized.fill(0);
        let mut zi = 0usize;
        loop {
            let tok = self.byte()?;
            if tok == EOB {
                return Ok(());
            }
            let run = tok as usize;
            if run > MAX_RUN as usize {
                bail!("bad entropy token {tok:#x}");
            }
            zi += run;
            if zi >= 64 {
                bail!("zero run past block end");
            }
            let v = zz_dec(self.get_varint()?);
            quantized[zi] = v; // zigzag position; caller maps via ZIGZAG
            zi += 1;
            if zi > 64 {
                bail!("block overflow");
            }
        }
    }

    /// Table-driven decode: while ≥ 8 bytes remain, one unaligned
    /// little-endian `u64` window covers the longest possible symbol
    /// (token + 5 varint bytes), the flat table classifies the token,
    /// and the varint peels off the window without re-touching memory.
    /// The validation sequence — token class, run bound *before* the
    /// varint, varint length, overflow position — replicates
    /// [`read_block_slow`] exactly, so errors, messages, and
    /// `bytes_consumed` cannot diverge between the paths.
    fn read_block_table(&mut self, quantized: &mut [i32; 64]) -> Result<()> {
        quantized.fill(0);
        // Per-block hoist: one table borrow for the whole coefficient
        // loop instead of a static re-borrow per symbol.
        let class = &TOKEN_CLASS;
        let mut zi = 0usize;
        loop {
            if self.buf.len() - self.pos < WINDOW_BYTES {
                // Near EOF the window no longer fits — finish with the
                // byte-at-a-time refill (identical semantics).
                if self.read_pair_slow(quantized, &mut zi)? {
                    return Ok(());
                }
                continue;
            }
            let w = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
            let tok = w as u8;
            match class[tok as usize] {
                TOK_RUN => {}
                TOK_EOB => {
                    self.pos += 1;
                    return Ok(());
                }
                _ => {
                    self.pos += 1;
                    bail!("bad entropy token {tok:#x}");
                }
            }
            zi += tok as usize;
            if zi >= 64 {
                self.pos += 1;
                bail!("zero run past block end");
            }
            // Varint from the window: byte k of w, k = 1..=5.
            let mut u: u32 = 0;
            let mut shift = 0;
            let mut k = 1usize;
            loop {
                let b = (w >> (8 * k)) as u8;
                u |= ((b & 0x7F) as u32) << shift;
                if b & 0x80 == 0 {
                    break;
                }
                shift += 7;
                if shift > 28 {
                    // Same position the slow path stops at: token + 5
                    // varint bytes consumed.
                    self.pos += k + 1;
                    bail!("varint overflow");
                }
                k += 1;
            }
            self.pos += k + 1;
            quantized[zi] = zz_dec(u); // zigzag position, as in the slow path
            zi += 1;
            if zi > 64 {
                bail!("block overflow");
            }
        }
    }

    /// One (token, varint) step of the byte-at-a-time loop — the cold
    /// refill tail the fast path takes only inside the final 8 bytes of
    /// the stream.  Returns `true` on EOB.
    #[cold]
    fn read_pair_slow(&mut self, quantized: &mut [i32; 64], zi: &mut usize) -> Result<bool> {
        let tok = self.byte()?;
        if tok == EOB {
            return Ok(true);
        }
        if tok > MAX_RUN {
            bail!("bad entropy token {tok:#x}");
        }
        *zi += tok as usize;
        if *zi >= 64 {
            bail!("zero run past block end");
        }
        let v = zz_dec(self.get_varint()?);
        quantized[*zi] = v;
        *zi += 1;
        if *zi > 64 {
            bail!("block overflow");
        }
        Ok(false)
    }

    /// Advance past one block without materializing coefficients — the
    /// fused-decode fast path for blocks outside the crop ROI (§Perf):
    /// the stream is still walked token by token (blocks are
    /// variable-length), but no varint is decoded, no zigzag scatter
    /// happens, and no dequant+IDCT follows.  Applies the same
    /// validation as [`read_block`] (token range, run bounds, varint
    /// length, truncation), so a corrupt stream fails identically
    /// whether a block is decoded or skipped.
    pub fn skip_block(&mut self) -> Result<()> {
        if self.fast {
            return self.skip_block_table();
        }
        self.skip_block_slow()
    }

    fn skip_block_slow(&mut self) -> Result<()> {
        let mut zi = 0usize;
        loop {
            let tok = self.byte()?;
            if tok == EOB {
                return Ok(());
            }
            if tok > MAX_RUN {
                bail!("bad entropy token {tok:#x}");
            }
            zi += tok as usize;
            if zi >= 64 {
                bail!("zero run past block end");
            }
            self.skip_varint()?;
            zi += 1;
            if zi > 64 {
                bail!("block overflow");
            }
        }
    }

    /// Table-driven [`skip_block`]: the window walk of
    /// [`read_block_table`] minus the value materialization — same
    /// validation, same positions.
    fn skip_block_table(&mut self) -> Result<()> {
        let class = &TOKEN_CLASS; // hoisted per block, as in read
        let mut zi = 0usize;
        loop {
            if self.buf.len() - self.pos < WINDOW_BYTES {
                if self.skip_pair_slow(&mut zi)? {
                    return Ok(());
                }
                continue;
            }
            let w = u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().unwrap());
            let tok = w as u8;
            match class[tok as usize] {
                TOK_RUN => {}
                TOK_EOB => {
                    self.pos += 1;
                    return Ok(());
                }
                _ => {
                    self.pos += 1;
                    bail!("bad entropy token {tok:#x}");
                }
            }
            zi += tok as usize;
            if zi >= 64 {
                self.pos += 1;
                bail!("zero run past block end");
            }
            let mut shift = 0;
            let mut k = 1usize;
            loop {
                let b = (w >> (8 * k)) as u8;
                if b & 0x80 == 0 {
                    break;
                }
                shift += 7;
                if shift > 28 {
                    self.pos += k + 1;
                    bail!("varint overflow");
                }
                k += 1;
            }
            self.pos += k + 1;
            zi += 1;
            if zi > 64 {
                bail!("block overflow");
            }
        }
    }

    /// Cold byte-at-a-time step for [`skip_block_table`]'s EOF tail.
    #[cold]
    fn skip_pair_slow(&mut self, zi: &mut usize) -> Result<bool> {
        let tok = self.byte()?;
        if tok == EOB {
            return Ok(true);
        }
        if tok > MAX_RUN {
            bail!("bad entropy token {tok:#x}");
        }
        *zi += tok as usize;
        if *zi >= 64 {
            bail!("zero run past block end");
        }
        self.skip_varint()?;
        *zi += 1;
        if *zi > 64 {
            bail!("block overflow");
        }
        Ok(false)
    }

    /// Skip one varint, enforcing the same length bound as `get_varint`.
    fn skip_varint(&mut self) -> Result<()> {
        let mut shift = 0;
        loop {
            let b = self.byte()?;
            if b & 0x80 == 0 {
                return Ok(());
            }
            shift += 7;
            if shift > 28 {
                bail!("varint overflow");
            }
        }
    }

    pub fn bytes_consumed(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(blocks: &[[i32; 64]]) {
        let mut out = Vec::new();
        {
            let mut w = EntropyWriter::new(&mut out);
            for b in blocks {
                w.write_block(b).unwrap();
            }
            w.finish().unwrap();
        }
        let mut r = EntropyReader::new(&out);
        for b in blocks {
            let mut got = [0i32; 64];
            r.read_block(&mut got).unwrap();
            // Writer takes natural order; reader returns zigzag positions.
            let mut expect = [0i32; 64];
            for zi in 0..64 {
                expect[zi] = b[ZIGZAG[zi]];
            }
            assert_eq!(got, expect);
        }
        assert_eq!(r.bytes_consumed(), out.len());
    }

    #[test]
    fn zz_int_codec() {
        for v in [0i32, 1, -1, 2, -2, 127, -128, 30_000, -30_000, i32::MAX / 2] {
            assert_eq!(zz_dec(zz_enc(v)), v);
        }
    }

    #[test]
    fn roundtrip_zero_block() {
        roundtrip(&[[0i32; 64]]);
    }

    #[test]
    fn roundtrip_dense_block() {
        let mut b = [0i32; 64];
        for (i, v) in b.iter_mut().enumerate() {
            *v = (i as i32 - 32) * 3;
        }
        roundtrip(&[b]);
    }

    #[test]
    fn roundtrip_sparse_random_blocks() {
        let mut rng = Rng::new(5);
        let mut blocks = Vec::new();
        for _ in 0..200 {
            let mut b = [0i32; 64];
            for v in b.iter_mut() {
                if rng.f64() < 0.15 {
                    *v = rng.uniform(-500.0, 500.0) as i32;
                }
            }
            blocks.push(b);
        }
        roundtrip(&blocks);
    }

    #[test]
    fn trailing_zeros_cost_one_byte() {
        let mut b = [0i32; 64];
        b[0] = 5;
        let mut out = Vec::new();
        let mut w = EntropyWriter::new(&mut out);
        w.write_block(&b).unwrap();
        // run=0 token + 1-byte varint + EOB = 3 bytes.
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn truncated_stream_errors() {
        let mut out = Vec::new();
        let mut w = EntropyWriter::new(&mut out);
        let mut b = [0i32; 64];
        b[63] = 9;
        w.write_block(&b).unwrap();
        let mut r = EntropyReader::new(&out[..out.len() - 2]);
        let mut got = [0i32; 64];
        assert!(r.read_block(&mut got).is_err());
    }

    #[test]
    fn skip_block_lands_exactly_where_read_block_does() {
        // Seeded property: for any prefix split, skipping the first j
        // blocks then reading the rest yields the same coefficients and
        // the same stream position as reading everything.
        let mut rng = Rng::new(11);
        let mut blocks = Vec::new();
        for _ in 0..40 {
            let mut b = [0i32; 64];
            for v in b.iter_mut() {
                if rng.f64() < 0.2 {
                    *v = rng.uniform(-900.0, 900.0) as i32;
                }
            }
            blocks.push(b);
        }
        blocks.push([0i32; 64]); // all-zero block
        let mut dense = [0i32; 64];
        dense.fill(7);
        blocks.push(dense); // fully dense block
        let mut out = Vec::new();
        let mut w = EntropyWriter::new(&mut out);
        for b in &blocks {
            w.write_block(b).unwrap();
        }
        for j in [0usize, 1, 7, blocks.len() - 1, blocks.len()] {
            let mut skip = EntropyReader::new(&out);
            let mut read = EntropyReader::new(&out);
            let mut got = [0i32; 64];
            for _ in 0..j {
                skip.skip_block().unwrap();
                read.read_block(&mut got).unwrap();
                assert_eq!(skip.bytes_consumed(), read.bytes_consumed(), "prefix {j}");
            }
            for _ in j..blocks.len() {
                let mut a = [0i32; 64];
                let mut b2 = [0i32; 64];
                skip.read_block(&mut a).unwrap();
                read.read_block(&mut b2).unwrap();
                assert_eq!(a, b2, "prefix {j}");
            }
            assert_eq!(skip.bytes_consumed(), out.len());
        }
    }

    #[test]
    fn table_decode_matches_slow_decode_values_positions_and_errors() {
        // Valid streams: identical coefficients and positions per block.
        let mut rng = Rng::new(17);
        let mut blocks = Vec::new();
        let n_blocks = if cfg!(miri) { 6 } else { 60 };
        for _ in 0..n_blocks {
            let mut b = [0i32; 64];
            for v in b.iter_mut() {
                if rng.f64() < 0.2 {
                    *v = rng.uniform(-100_000.0, 100_000.0) as i32; // multi-byte varints
                }
            }
            blocks.push(b);
        }
        blocks.push([0i32; 64]);
        let mut out = Vec::new();
        let mut w = EntropyWriter::new(&mut out);
        for b in &blocks {
            w.write_block(b).unwrap();
        }
        let mut fast = EntropyReader::with_table_decode(&out, true);
        let mut slow = EntropyReader::with_table_decode(&out, false);
        for i in 0..blocks.len() {
            let mut a = [0i32; 64];
            let mut b2 = [0i32; 64];
            fast.read_block(&mut a).unwrap();
            slow.read_block(&mut b2).unwrap();
            assert_eq!(a, b2, "block {i}");
            assert_eq!(fast.bytes_consumed(), slow.bytes_consumed(), "block {i}");
        }
        assert_eq!(fast.bytes_consumed(), out.len());
        // Skip path lands at the same positions too.
        let mut fs = EntropyReader::with_table_decode(&out, true);
        let mut ss = EntropyReader::with_table_decode(&out, false);
        for i in 0..blocks.len() {
            fs.skip_block().unwrap();
            ss.skip_block().unwrap();
            assert_eq!(fs.bytes_consumed(), ss.bytes_consumed(), "skip block {i}");
        }
        // Every truncation cut and every corrupt prefix must fail both
        // paths with the same message at the same position.
        let mut corrupt: Vec<Vec<u8>> = (1..out.len().min(24)).map(|c| out[..out.len() - c].to_vec()).collect();
        corrupt.push(vec![0x41, 0x00]); // bad token
        corrupt.push(vec![MAX_RUN - 1, 0x00, MAX_RUN - 1, 0x00, MAX_RUN - 1, 0x00]); // run past end (tail path)
        corrupt.push(vec![MAX_RUN - 1, 0x00, MAX_RUN - 1, 0x00, MAX_RUN - 1, 0x00, 0, 0, 0, 0]); // run past end (window path)
        corrupt.push(vec![0x41, 0x00, 0, 0, 0, 0, 0, 0, 0]); // bad token (window path)
        corrupt.push(vec![0x00, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x00, 0x00]); // varint overflow
        corrupt.push(vec![0x00, 0x80, 0x80, 0x80, 0x80, 0x80]); // overflow inside the EOF tail
        corrupt.push(Vec::new()); // empty stream
        for (ci, bad) in corrupt.iter().enumerate() {
            let mut fast = EntropyReader::with_table_decode(bad, true);
            let mut slow = EntropyReader::with_table_decode(bad, false);
            let mut a = [0i32; 64];
            let mut b2 = [0i32; 64];
            let (ea, eb) = loop {
                match (fast.read_block(&mut a), slow.read_block(&mut b2)) {
                    (Ok(()), Ok(())) => {
                        assert_eq!(a, b2, "corrupt case {ci}");
                        assert_eq!(fast.bytes_consumed(), slow.bytes_consumed(), "case {ci}");
                    }
                    (Err(ea), Err(eb)) => break (ea, eb),
                    (a, b) => panic!("case {ci}: paths diverged: {a:?} vs {b:?}"),
                }
            };
            assert_eq!(format!("{ea:#}"), format!("{eb:#}"), "case {ci}");
            assert_eq!(fast.bytes_consumed(), slow.bytes_consumed(), "case {ci} error position");
            // skip_block fails identically too.
            let mut fast = EntropyReader::with_table_decode(bad, true);
            let mut slow = EntropyReader::with_table_decode(bad, false);
            let (ea, eb) = loop {
                match (fast.skip_block(), slow.skip_block()) {
                    (Ok(()), Ok(())) => {
                        assert_eq!(fast.bytes_consumed(), slow.bytes_consumed(), "case {ci}");
                    }
                    (Err(ea), Err(eb)) => break (ea, eb),
                    (a, b) => panic!("case {ci}: skip paths diverged: {a:?} vs {b:?}"),
                }
            };
            assert_eq!(format!("{ea:#}"), format!("{eb:#}"), "skip case {ci}");
            assert_eq!(fast.bytes_consumed(), slow.bytes_consumed(), "skip case {ci}");
        }
    }

    #[test]
    fn table_decode_refill_at_eof_boundary() {
        // The fast path's 64-bit window stops fitting inside the last 8
        // bytes of the stream; the tail refill must decode a symbol that
        // ends with *exactly* the bytes remaining.  Build a block whose
        // final coefficient's varint runs flush to the buffer end, and
        // pad the front so the window path is exercised first.
        let mut b = [0i32; 64];
        b[ZIGZAG[0]] = 1_000_000; // earlier symbols keep the window busy
        b[ZIGZAG[1]] = -2_000_000;
        b[ZIGZAG[63]] = 100_000; // last symbol: 3-byte varint + EOB at EOF
        let mut out = Vec::new();
        EntropyWriter::new(&mut out).write_block(&b).unwrap();
        for fast in [true, false] {
            let mut r = EntropyReader::with_table_decode(&out, fast);
            let mut got = [0i32; 64];
            r.read_block(&mut got).unwrap();
            assert_eq!(r.bytes_consumed(), out.len(), "fast={fast}");
            assert_eq!(got[63], 100_000, "fast={fast}");
            let mut s = EntropyReader::with_table_decode(&out, fast);
            s.skip_block().unwrap();
            assert_eq!(s.bytes_consumed(), out.len(), "skip fast={fast}");
        }
        // Streams shorter than one window take the tail refill from the
        // very first symbol: a 7-byte stream decoded entirely cold.
        let mut tiny = [0i32; 64];
        tiny[ZIGZAG[0]] = 70; // 1-byte varint
        tiny[ZIGZAG[1]] = -900; // 2-byte varint
        let mut out2 = Vec::new();
        EntropyWriter::new(&mut out2).write_block(&tiny).unwrap();
        assert!(out2.len() < 8, "{} bytes", out2.len());
        for fast in [true, false] {
            let mut r = EntropyReader::with_table_decode(&out2, fast);
            let mut got = [0i32; 64];
            r.read_block(&mut got).unwrap();
            assert_eq!(got[0], 70, "fast={fast}");
            assert_eq!(got[1], -900, "fast={fast}");
            assert_eq!(r.bytes_consumed(), out2.len(), "fast={fast}");
        }
    }

    #[test]
    fn skip_block_rejects_what_read_block_rejects() {
        // Truncation mid-varint and mid-block.
        let mut out = Vec::new();
        let mut w = EntropyWriter::new(&mut out);
        let mut b = [0i32; 64];
        b[0] = 100_000; // multi-byte varint
        b[63] = 9;
        w.write_block(&b).unwrap();
        for cut in 1..out.len() {
            let mut r = EntropyReader::new(&out[..out.len() - cut]);
            assert!(r.skip_block().is_err(), "cut {cut} must error");
        }
        // Bad token (> MAX_RUN, not EOB).
        let mut r = EntropyReader::new(&[0x41, 0x00]);
        assert!(r.skip_block().is_err());
        // Zero run past the block end.
        let mut bad = Vec::new();
        for _ in 0..3 {
            bad.push(MAX_RUN - 1); // 61-zero run + literal, thrice > 64
            bad.push(0x00);
        }
        let mut r = EntropyReader::new(&bad);
        assert!(r.skip_block().is_err());
        // Unterminated varint (all continuation bits).
        let mut r = EntropyReader::new(&[0x00, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80]);
        assert!(r.skip_block().is_err());
        // Empty stream.
        let mut r = EntropyReader::new(&[]);
        assert!(r.skip_block().is_err());
    }
}
