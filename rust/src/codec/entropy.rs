//! Entropy coding for quantized coefficient blocks: zigzag scan, zero-run
//! RLE, signed varints.  This is the "CPU half" of hybrid decode (the role
//! Huffman plays in nvJPEG): cheap, branchy, inherently serial per block —
//! exactly the stage the paper leaves on the CPU.
//!
//! Per block (64 coeffs in zigzag order):
//!   token 0x00..=0x3E : run of `token` zeros, then one signed-varint coeff
//!   token 0x3F        : EOB — all remaining coefficients are zero
//! Blocks are byte-aligned; the stream needs no global terminator.

use super::quant::ZIGZAG;
use anyhow::{bail, Result};

pub const EOB: u8 = 0x3F;
const MAX_RUN: u8 = 0x3E;

/// ZigZag-encode a signed int into unsigned LEB128 space.
#[inline]
fn zz_enc(v: i32) -> u32 {
    ((v << 1) ^ (v >> 31)) as u32
}

#[inline]
fn zz_dec(u: u32) -> i32 {
    ((u >> 1) as i32) ^ -((u & 1) as i32)
}

fn put_varint(out: &mut Vec<u8>, mut u: u32) {
    loop {
        let b = (u & 0x7F) as u8;
        u >>= 7;
        if u == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

pub struct EntropyWriter<'a> {
    out: &'a mut Vec<u8>,
}

impl<'a> EntropyWriter<'a> {
    pub fn new(out: &'a mut Vec<u8>) -> Self {
        EntropyWriter { out }
    }

    /// Write one quantized 8x8 block given in *natural* order.
    pub fn write_block(&mut self, quantized: &[i32; 64]) -> Result<()> {
        let mut run: u8 = 0;
        // Find last nonzero in zigzag order for EOB placement.
        let mut last_nz: i32 = -1;
        for zi in (0..64).rev() {
            if quantized[ZIGZAG[zi]] != 0 {
                last_nz = zi as i32;
                break;
            }
        }
        for zi in 0..=last_nz.max(-1) {
            let v = quantized[ZIGZAG[zi as usize]];
            if v == 0 {
                run += 1;
                if run == MAX_RUN {
                    // Emit max-run token with a literal zero to reset.
                    self.out.push(MAX_RUN - 1);
                    put_varint(self.out, zz_enc(0));
                    run = 0;
                }
            } else {
                self.out.push(run);
                put_varint(self.out, zz_enc(v));
                run = 0;
            }
        }
        self.out.push(EOB);
        Ok(())
    }

    pub fn finish(self) -> Result<()> {
        Ok(())
    }
}

pub struct EntropyReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> EntropyReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        EntropyReader { buf, pos: 0 }
    }

    #[inline]
    fn byte(&mut self) -> Result<u8> {
        match self.buf.get(self.pos) {
            Some(&b) => {
                self.pos += 1;
                Ok(b)
            }
            None => bail!("entropy stream truncated at {}", self.pos),
        }
    }

    fn get_varint(&mut self) -> Result<u32> {
        let mut u: u32 = 0;
        let mut shift = 0;
        loop {
            let b = self.byte()?;
            u |= ((b & 0x7F) as u32) << shift;
            if b & 0x80 == 0 {
                return Ok(u);
            }
            shift += 7;
            if shift > 28 {
                bail!("varint overflow");
            }
        }
    }

    /// Read one block into `quantized` (natural order, zigzag inverted
    /// by the caller if it wants scan order — we fill natural directly).
    pub fn read_block(&mut self, quantized: &mut [i32; 64]) -> Result<()> {
        quantized.fill(0);
        let mut zi = 0usize;
        loop {
            let tok = self.byte()?;
            if tok == EOB {
                return Ok(());
            }
            let run = tok as usize;
            if run > MAX_RUN as usize {
                bail!("bad entropy token {tok:#x}");
            }
            zi += run;
            if zi >= 64 {
                bail!("zero run past block end");
            }
            let v = zz_dec(self.get_varint()?);
            quantized[zi] = v; // zigzag position; caller maps via ZIGZAG
            zi += 1;
            if zi > 64 {
                bail!("block overflow");
            }
        }
    }

    /// Advance past one block without materializing coefficients — the
    /// fused-decode fast path for blocks outside the crop ROI (§Perf):
    /// the stream is still walked token by token (blocks are
    /// variable-length), but no varint is decoded, no zigzag scatter
    /// happens, and no dequant+IDCT follows.  Applies the same
    /// validation as [`read_block`] (token range, run bounds, varint
    /// length, truncation), so a corrupt stream fails identically
    /// whether a block is decoded or skipped.
    pub fn skip_block(&mut self) -> Result<()> {
        let mut zi = 0usize;
        loop {
            let tok = self.byte()?;
            if tok == EOB {
                return Ok(());
            }
            if tok > MAX_RUN {
                bail!("bad entropy token {tok:#x}");
            }
            zi += tok as usize;
            if zi >= 64 {
                bail!("zero run past block end");
            }
            self.skip_varint()?;
            zi += 1;
            if zi > 64 {
                bail!("block overflow");
            }
        }
    }

    /// Skip one varint, enforcing the same length bound as `get_varint`.
    fn skip_varint(&mut self) -> Result<()> {
        let mut shift = 0;
        loop {
            let b = self.byte()?;
            if b & 0x80 == 0 {
                return Ok(());
            }
            shift += 7;
            if shift > 28 {
                bail!("varint overflow");
            }
        }
    }

    pub fn bytes_consumed(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(blocks: &[[i32; 64]]) {
        let mut out = Vec::new();
        {
            let mut w = EntropyWriter::new(&mut out);
            for b in blocks {
                w.write_block(b).unwrap();
            }
            w.finish().unwrap();
        }
        let mut r = EntropyReader::new(&out);
        for b in blocks {
            let mut got = [0i32; 64];
            r.read_block(&mut got).unwrap();
            // Writer takes natural order; reader returns zigzag positions.
            let mut expect = [0i32; 64];
            for zi in 0..64 {
                expect[zi] = b[ZIGZAG[zi]];
            }
            assert_eq!(got, expect);
        }
        assert_eq!(r.bytes_consumed(), out.len());
    }

    #[test]
    fn zz_int_codec() {
        for v in [0i32, 1, -1, 2, -2, 127, -128, 30_000, -30_000, i32::MAX / 2] {
            assert_eq!(zz_dec(zz_enc(v)), v);
        }
    }

    #[test]
    fn roundtrip_zero_block() {
        roundtrip(&[[0i32; 64]]);
    }

    #[test]
    fn roundtrip_dense_block() {
        let mut b = [0i32; 64];
        for (i, v) in b.iter_mut().enumerate() {
            *v = (i as i32 - 32) * 3;
        }
        roundtrip(&[b]);
    }

    #[test]
    fn roundtrip_sparse_random_blocks() {
        let mut rng = Rng::new(5);
        let mut blocks = Vec::new();
        for _ in 0..200 {
            let mut b = [0i32; 64];
            for v in b.iter_mut() {
                if rng.f64() < 0.15 {
                    *v = rng.uniform(-500.0, 500.0) as i32;
                }
            }
            blocks.push(b);
        }
        roundtrip(&blocks);
    }

    #[test]
    fn trailing_zeros_cost_one_byte() {
        let mut b = [0i32; 64];
        b[0] = 5;
        let mut out = Vec::new();
        let mut w = EntropyWriter::new(&mut out);
        w.write_block(&b).unwrap();
        // run=0 token + 1-byte varint + EOB = 3 bytes.
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn truncated_stream_errors() {
        let mut out = Vec::new();
        let mut w = EntropyWriter::new(&mut out);
        let mut b = [0i32; 64];
        b[63] = 9;
        w.write_block(&b).unwrap();
        let mut r = EntropyReader::new(&out[..out.len() - 2]);
        let mut got = [0i32; 64];
        assert!(r.read_block(&mut got).is_err());
    }

    #[test]
    fn skip_block_lands_exactly_where_read_block_does() {
        // Seeded property: for any prefix split, skipping the first j
        // blocks then reading the rest yields the same coefficients and
        // the same stream position as reading everything.
        let mut rng = Rng::new(11);
        let mut blocks = Vec::new();
        for _ in 0..40 {
            let mut b = [0i32; 64];
            for v in b.iter_mut() {
                if rng.f64() < 0.2 {
                    *v = rng.uniform(-900.0, 900.0) as i32;
                }
            }
            blocks.push(b);
        }
        blocks.push([0i32; 64]); // all-zero block
        let mut dense = [0i32; 64];
        dense.fill(7);
        blocks.push(dense); // fully dense block
        let mut out = Vec::new();
        let mut w = EntropyWriter::new(&mut out);
        for b in &blocks {
            w.write_block(b).unwrap();
        }
        for j in [0usize, 1, 7, blocks.len() - 1, blocks.len()] {
            let mut skip = EntropyReader::new(&out);
            let mut read = EntropyReader::new(&out);
            let mut got = [0i32; 64];
            for _ in 0..j {
                skip.skip_block().unwrap();
                read.read_block(&mut got).unwrap();
                assert_eq!(skip.bytes_consumed(), read.bytes_consumed(), "prefix {j}");
            }
            for _ in j..blocks.len() {
                let mut a = [0i32; 64];
                let mut b2 = [0i32; 64];
                skip.read_block(&mut a).unwrap();
                read.read_block(&mut b2).unwrap();
                assert_eq!(a, b2, "prefix {j}");
            }
            assert_eq!(skip.bytes_consumed(), out.len());
        }
    }

    #[test]
    fn skip_block_rejects_what_read_block_rejects() {
        // Truncation mid-varint and mid-block.
        let mut out = Vec::new();
        let mut w = EntropyWriter::new(&mut out);
        let mut b = [0i32; 64];
        b[0] = 100_000; // multi-byte varint
        b[63] = 9;
        w.write_block(&b).unwrap();
        for cut in 1..out.len() {
            let mut r = EntropyReader::new(&out[..out.len() - cut]);
            assert!(r.skip_block().is_err(), "cut {cut} must error");
        }
        // Bad token (> MAX_RUN, not EOB).
        let mut r = EntropyReader::new(&[0x41, 0x00]);
        assert!(r.skip_block().is_err());
        // Zero run past the block end.
        let mut bad = Vec::new();
        for _ in 0..3 {
            bad.push(MAX_RUN - 1); // 61-zero run + literal, thrice > 64
            bad.push(0x00);
        }
        let mut r = EntropyReader::new(&bad);
        assert!(r.skip_block().is_err());
        // Unterminated varint (all continuation bits).
        let mut r = EntropyReader::new(&[0x00, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80]);
        assert!(r.skip_block().is_err());
        // Empty stream.
        let mut r = EntropyReader::new(&[]);
        assert!(r.skip_block().is_err());
    }
}
