//! MJX: a JPEG-like block-DCT image codec built for this reproduction.
//!
//! The paper's preprocessing hot spot is JPEG decode (Fig. 3: 47.7 % of
//! per-image CPU time), and DALI's key trick is *hybrid* decode: CPU
//! entropy (Huffman) stage + GPU dequant/IDCT stage.  MJX mirrors that
//! structure with a self-contained format:
//!
//! ```text
//! encode:  pixels --level-shift--> fDCT (8x8) --quantize--> zigzag
//!          --RLE+varint entropy code--> bitstream
//! decode:  bitstream --entropy decode--> coefficients
//!          --dequant + IDCT--> pixels            (decode_cpu: all on CPU)
//!          `--> ship coefficients to accelerator (entropy_decode: hybrid)
//! ```
//!
//! The accelerator half of the hybrid path is the Pallas kernel in
//! `python/compile/kernels/dct.py`, compiled into `artifacts/decode_*.hlo.txt`;
//! the CPU IDCT here implements the *same math* so both paths agree
//! (cross-checked in `rust/tests/artifact_parity.rs`).

mod dct;
mod entropy;
mod quant;

pub use dct::{dequant_idct_block, fdct_block, idct_block, DCT_MAT};
pub use entropy::{EntropyReader, EntropyWriter};
pub use quant::{qtable_for_quality, BASE_QTABLE, ZIGZAG};

use anyhow::{bail, ensure, Context, Result};

pub const MAGIC: &[u8; 4] = b"MJX1";

/// A decoded planar image: `data[c*h*w + y*w + x]`, pixel range 0..=255.
#[derive(Clone, Debug, PartialEq)]
pub struct Image {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<u8>,
}

impl Image {
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        Image { c, h, w, data: vec![0; c * h * w] }
    }

    pub fn plane(&self, ch: usize) -> &[u8] {
        &self.data[ch * self.h * self.w..(ch + 1) * self.h * self.w]
    }

    pub fn pixel(&self, ch: usize, y: usize, x: usize) -> u8 {
        self.data[ch * self.h * self.w + y * self.w + x]
    }

    /// Convert to f32 pixels (same planar layout), for the augment ops.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&b| b as f32).collect()
    }
}

/// Entropy-decoded (but not yet inverse-transformed) coefficients —
/// what the CPU hands to the accelerator in hybrid decode.
///
/// `coefs` holds quantized coefficients in natural (row-major) block
/// order, laid out `[c][by][bx][8][8]`, ready for the `decode_*` HLO
/// artifact (shape `[B, C, H/8, W/8, 8, 8]` once batched).
#[derive(Clone, Debug)]
pub struct CoefImage {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub quality: u8,
    pub coefs: Vec<f32>,
    pub qtable: [f32; 64],
}

/// Encode a planar image into an MJX bitstream.
///
/// Header: MAGIC, version-free (quality determines the qtable), then
/// `h:u16 w:u16 c:u8 quality:u8`, then entropy-coded blocks channel-major.
pub fn encode(img: &Image, quality: u8) -> Result<Vec<u8>> {
    ensure!(img.h % 8 == 0 && img.w % 8 == 0, "MJX requires 8-aligned dims");
    ensure!(img.h <= u16::MAX as usize && img.w <= u16::MAX as usize, "image too large");
    ensure!((1..=100).contains(&quality), "quality must be 1..=100");
    let q = qtable_for_quality(quality);
    let mut out = Vec::with_capacity(img.data.len() / 4 + 16);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(img.h as u16).to_le_bytes());
    out.extend_from_slice(&(img.w as u16).to_le_bytes());
    out.push(img.c as u8);
    out.push(quality);

    let mut writer = EntropyWriter::new(&mut out);
    let (bh, bw) = (img.h / 8, img.w / 8);
    let mut block = [0f32; 64];
    let mut coef = [0f32; 64];
    let mut quantized = [0i32; 64];
    for ch in 0..img.c {
        let plane = img.plane(ch);
        for by in 0..bh {
            for bx in 0..bw {
                // Gather + level shift.
                for y in 0..8 {
                    let row = &plane[(by * 8 + y) * img.w + bx * 8..][..8];
                    for x in 0..8 {
                        block[y * 8 + x] = row[x] as f32 - 128.0;
                    }
                }
                fdct_block(&block, &mut coef);
                for i in 0..64 {
                    quantized[i] = (coef[i] / q[i]).round() as i32;
                }
                writer.write_block(&quantized)?;
            }
        }
    }
    writer.finish()?;
    Ok(out)
}

fn parse_header(bytes: &[u8]) -> Result<(usize, usize, usize, u8, usize)> {
    ensure!(bytes.len() >= 10, "truncated MJX header");
    if &bytes[..4] != MAGIC {
        bail!("bad MJX magic {:02x?}", &bytes[..4]);
    }
    let h = u16::from_le_bytes([bytes[4], bytes[5]]) as usize;
    let w = u16::from_le_bytes([bytes[6], bytes[7]]) as usize;
    let c = bytes[8] as usize;
    let quality = bytes[9];
    ensure!(h % 8 == 0 && w % 8 == 0 && h > 0 && w > 0, "bad dims {h}x{w}");
    ensure!(c >= 1 && c <= 4, "bad channel count {c}");
    ensure!((1..=100).contains(&quality), "bad quality {quality}");
    Ok((h, w, c, quality, 10))
}

/// Stage 1 of decode: entropy decode only (the CPU half of hybrid decode).
pub fn entropy_decode(bytes: &[u8]) -> Result<CoefImage> {
    let (h, w, c, quality, off) = parse_header(bytes)?;
    let q = qtable_for_quality(quality);
    let nblocks = c * (h / 8) * (w / 8);
    let mut coefs = vec![0f32; nblocks * 64];
    let mut reader = EntropyReader::new(&bytes[off..]);
    let mut quantized = [0i32; 64];
    for b in 0..nblocks {
        reader.read_block(&mut quantized).with_context(|| format!("block {b}"))?;
        let dst = &mut coefs[b * 64..][..64];
        // Inverse zigzag into natural order, as f32 (artifact input format).
        for (zi, &nat) in ZIGZAG.iter().enumerate() {
            dst[nat] = quantized[zi] as f32;
        }
    }
    Ok(CoefImage { c, h, w, quality, coefs, qtable: q })
}

/// Stage 2 of decode on the CPU: dequantize + IDCT (mirror of the Pallas
/// kernel's math).  Shared by `decode_cpu`.
pub fn coefs_to_image(ci: &CoefImage) -> Image {
    let mut img = Image::new(ci.c, ci.h, ci.w);
    let (bh, bw) = (ci.h / 8, ci.w / 8);
    let mut pix = [0f32; 64];
    for ch in 0..ci.c {
        for by in 0..bh {
            for bx in 0..bw {
                let b = (ch * bh + by) * bw + bx;
                let src: &[f32; 64] = ci.coefs[b * 64..][..64].try_into().unwrap();
                dequant_idct_block(src, &ci.qtable, &mut pix);
                let base = ch * ci.h * ci.w + by * 8 * ci.w + bx * 8;
                for y in 0..8 {
                    let prow = &pix[y * 8..y * 8 + 8];
                    let orow = &mut img.data[base + y * ci.w..base + y * ci.w + 8];
                    for x in 0..8 {
                        orow[x] = (prow[x] + 128.0).clamp(0.0, 255.0).round() as u8;
                    }
                }
            }
        }
    }
    img
}

/// Full CPU decode (entropy + dequant + IDCT) — the `cpu` placement path.
pub fn decode_cpu(bytes: &[u8]) -> Result<Image> {
    let ci = entropy_decode(bytes)?;
    Ok(coefs_to_image(&ci))
}

/// Peek image dims without decoding.
pub fn probe(bytes: &[u8]) -> Result<(usize, usize, usize, u8)> {
    let (h, w, c, q, _) = parse_header(bytes)?;
    Ok((c, h, w, q))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn smooth_image(seed: u64, c: usize, h: usize, w: usize) -> Image {
        // Smooth content compresses like natural images (codec-friendly).
        let mut img = Image::new(c, h, w);
        let mut rng = Rng::new(seed);
        let fx = rng.uniform(0.02, 0.2);
        let fy = rng.uniform(0.02, 0.2);
        for ch in 0..c {
            for y in 0..h {
                for x in 0..w {
                    let v = 128.0
                        + 80.0 * ((x as f64 * fx).sin() * (y as f64 * fy).cos())
                        + 20.0 * ((ch + 1) as f64);
                    img.data[ch * h * w + y * w + x] = v.clamp(0.0, 255.0) as u8;
                }
            }
        }
        img
    }

    #[test]
    fn roundtrip_high_quality_is_close() {
        let img = smooth_image(1, 3, 64, 64);
        let bytes = encode(&img, 95).unwrap();
        let dec = decode_cpu(&bytes).unwrap();
        assert_eq!((dec.c, dec.h, dec.w), (3, 64, 64));
        let max_err = img
            .data
            .iter()
            .zip(&dec.data)
            .map(|(&a, &b)| (a as i32 - b as i32).abs())
            .max()
            .unwrap();
        assert!(max_err <= 12, "max pixel error {max_err}");
    }

    #[test]
    fn roundtrip_error_grows_as_quality_drops() {
        let img = smooth_image(2, 3, 64, 64);
        let err = |q: u8| {
            let dec = decode_cpu(&encode(&img, q).unwrap()).unwrap();
            img.data
                .iter()
                .zip(&dec.data)
                .map(|(&a, &b)| (a as i64 - b as i64).pow(2))
                .sum::<i64>() as f64
                / img.data.len() as f64
        };
        let (e95, e50, e10) = (err(95), err(50), err(10));
        assert!(e95 <= e50 && e50 <= e10, "{e95} {e50} {e10}");
        assert!(e95 < 20.0, "high quality MSE too big: {e95}");
    }

    #[test]
    fn lower_quality_compresses_smaller() {
        let img = smooth_image(3, 3, 64, 64);
        let hi = encode(&img, 95).unwrap().len();
        let lo = encode(&img, 20).unwrap().len();
        assert!(lo < hi, "q20 {lo} >= q95 {hi}");
        assert!(hi < img.data.len(), "no compression at q95: {hi}");
    }

    #[test]
    fn hybrid_path_equals_cpu_path() {
        let img = smooth_image(4, 3, 64, 64);
        let bytes = encode(&img, 80).unwrap();
        let full = decode_cpu(&bytes).unwrap();
        let staged = coefs_to_image(&entropy_decode(&bytes).unwrap());
        assert_eq!(full, staged);
    }

    #[test]
    fn probe_reads_header() {
        let img = smooth_image(5, 1, 16, 24);
        let bytes = encode(&img, 70).unwrap();
        assert_eq!(probe(&bytes).unwrap(), (1, 16, 24, 70));
    }

    #[test]
    fn rejects_corruption() {
        let img = smooth_image(6, 1, 16, 16);
        let mut bytes = encode(&img, 70).unwrap();
        assert!(decode_cpu(&bytes[..5]).is_err());
        bytes[0] = b'X';
        assert!(decode_cpu(&bytes).is_err());
    }

    #[test]
    fn random_noise_roundtrips_dims() {
        // Noise is worst-case for the codec but must still round-trip shape.
        let mut rng = Rng::new(7);
        let mut img = Image::new(2, 32, 40);
        for b in img.data.iter_mut() {
            *b = rng.next_u32() as u8;
        }
        let dec = decode_cpu(&encode(&img, 50).unwrap()).unwrap();
        assert_eq!((dec.c, dec.h, dec.w), (2, 32, 40));
    }
}
