//! MJX: a JPEG-like block-DCT image codec built for this reproduction.
//!
//! The paper's preprocessing hot spot is JPEG decode (Fig. 3: 47.7 % of
//! per-image CPU time), and DALI's key trick is *hybrid* decode: CPU
//! entropy (Huffman) stage + GPU dequant/IDCT stage.  MJX mirrors that
//! structure with a self-contained format:
//!
//! ```text
//! encode:  pixels --level-shift--> fDCT (8x8) --quantize--> zigzag
//!          --RLE+varint entropy code--> bitstream
//! decode:  bitstream --entropy decode--> coefficients
//!          --dequant + IDCT--> pixels            (decode_cpu: all on CPU)
//!          `--> ship coefficients to accelerator (entropy_decode: hybrid)
//! ```
//!
//! The accelerator half of the hybrid path is the Pallas kernel in
//! `python/compile/kernels/dct.py`, compiled into `artifacts/decode_*.hlo.txt`;
//! the CPU IDCT here implements the *same math* so both paths agree
//! (cross-checked in `rust/tests/artifact_parity.rs`).

pub mod dct;
pub mod entropy;
mod quant;

pub use dct::{dequant_idct_block, dequant_idct_block_scaled, fdct_block, idct_block, DCT_MAT};
pub use entropy::{EntropyReader, EntropyWriter};
pub use quant::{qtable_for_quality, BASE_QTABLE, ZIGZAG};

use anyhow::{bail, ensure, Context, Result};

pub const MAGIC: &[u8; 4] = b"MJX1";

/// A decoded planar image: `data[c*h*w + y*w + x]`, pixel range 0..=255.
#[derive(Clone, Debug, PartialEq)]
pub struct Image {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<u8>,
}

impl Image {
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        Image { c, h, w, data: vec![0; c * h * w] }
    }

    pub fn plane(&self, ch: usize) -> &[u8] {
        &self.data[ch * self.h * self.w..(ch + 1) * self.h * self.w]
    }

    pub fn pixel(&self, ch: usize, y: usize, x: usize) -> u8 {
        self.data[ch * self.h * self.w + y * self.w + x]
    }

    /// Convert to f32 pixels (same planar layout), for the augment ops.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&b| b as f32).collect()
    }

    /// [`to_f32`](Self::to_f32) into caller scratch: `out`'s capacity is
    /// reused, so a worker converting same-sized images allocates once.
    pub fn to_f32_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend(self.data.iter().map(|&b| b as f32));
    }

    /// Reshape in place, reusing the pixel buffer's capacity — the
    /// decode-scratch reset of the zero-copy hot path.  Contents are
    /// zeroed (same as a fresh [`Image::new`]).
    pub fn reset(&mut self, c: usize, h: usize, w: usize) {
        self.c = c;
        self.h = h;
        self.w = w;
        self.data.clear();
        self.data.resize(c * h * w, 0);
    }
}

/// Entropy-decoded (but not yet inverse-transformed) coefficients —
/// what the CPU hands to the accelerator in hybrid decode.
///
/// `coefs` holds quantized coefficients in natural (row-major) block
/// order, laid out `[c][by][bx][8][8]`, ready for the `decode_*` HLO
/// artifact (shape `[B, C, H/8, W/8, 8, 8]` once batched).
#[derive(Clone, Debug)]
pub struct CoefImage {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub quality: u8,
    pub coefs: Vec<f32>,
    pub qtable: [f32; 64],
}

/// Encode a planar image into an MJX bitstream.
///
/// Header: MAGIC, version-free (quality determines the qtable), then
/// `h:u16 w:u16 c:u8 quality:u8`, then entropy-coded blocks channel-major.
pub fn encode(img: &Image, quality: u8) -> Result<Vec<u8>> {
    ensure!(img.h % 8 == 0 && img.w % 8 == 0, "MJX requires 8-aligned dims");
    ensure!(img.h <= u16::MAX as usize && img.w <= u16::MAX as usize, "image too large");
    ensure!((1..=100).contains(&quality), "quality must be 1..=100");
    let q = qtable_for_quality(quality);
    let mut out = Vec::with_capacity(img.data.len() / 4 + 16);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(img.h as u16).to_le_bytes());
    out.extend_from_slice(&(img.w as u16).to_le_bytes());
    out.push(img.c as u8);
    out.push(quality);

    let mut writer = EntropyWriter::new(&mut out);
    let (bh, bw) = (img.h / 8, img.w / 8);
    let mut block = [0f32; 64];
    let mut coef = [0f32; 64];
    let mut quantized = [0i32; 64];
    for ch in 0..img.c {
        let plane = img.plane(ch);
        for by in 0..bh {
            for bx in 0..bw {
                // Gather + level shift.
                for y in 0..8 {
                    let row = &plane[(by * 8 + y) * img.w + bx * 8..][..8];
                    for x in 0..8 {
                        block[y * 8 + x] = row[x] as f32 - 128.0;
                    }
                }
                fdct_block(&block, &mut coef);
                for i in 0..64 {
                    quantized[i] = (coef[i] / q[i]).round() as i32;
                }
                writer.write_block(&quantized)?;
            }
        }
    }
    writer.finish()?;
    Ok(out)
}

fn parse_header(bytes: &[u8]) -> Result<(usize, usize, usize, u8, usize)> {
    ensure!(bytes.len() >= 10, "truncated MJX header");
    if &bytes[..4] != MAGIC {
        bail!("bad MJX magic {:02x?}", &bytes[..4]);
    }
    let h = u16::from_le_bytes([bytes[4], bytes[5]]) as usize;
    let w = u16::from_le_bytes([bytes[6], bytes[7]]) as usize;
    let c = bytes[8] as usize;
    let quality = bytes[9];
    ensure!(h % 8 == 0 && w % 8 == 0 && h > 0 && w > 0, "bad dims {h}x{w}");
    ensure!(c >= 1 && c <= 4, "bad channel count {c}");
    ensure!((1..=100).contains(&quality), "bad quality {quality}");
    Ok((h, w, c, quality, 10))
}

/// Stage 1 of decode: entropy decode only (the CPU half of hybrid decode).
pub fn entropy_decode(bytes: &[u8]) -> Result<CoefImage> {
    let (h, w, c, quality, off) = parse_header(bytes)?;
    let q = qtable_for_quality(quality);
    let nblocks = c * (h / 8) * (w / 8);
    let mut coefs = vec![0f32; nblocks * 64];
    let mut reader = EntropyReader::new(&bytes[off..]);
    let mut quantized = [0i32; 64];
    for b in 0..nblocks {
        reader.read_block(&mut quantized).with_context(|| format!("block {b}"))?;
        let dst = &mut coefs[b * 64..][..64];
        // Inverse zigzag into natural order, as f32 (artifact input format).
        for (zi, &nat) in ZIGZAG.iter().enumerate() {
            dst[nat] = quantized[zi] as f32;
        }
    }
    Ok(CoefImage { c, h, w, quality, coefs, qtable: q })
}

/// Stage 2 of decode on the CPU: dequantize + IDCT (mirror of the Pallas
/// kernel's math).  Shared by `decode_cpu`.
pub fn coefs_to_image(ci: &CoefImage) -> Image {
    let mut img = Image::new(ci.c, ci.h, ci.w);
    let (bh, bw) = (ci.h / 8, ci.w / 8);
    let mut pix = [0f32; 64];
    // One atomic read of the SIMD mode per image, not per block.
    let level = crate::simd::active();
    for ch in 0..ci.c {
        for by in 0..bh {
            for bx in 0..bw {
                let b = (ch * bh + by) * bw + bx;
                let src: &[f32; 64] = ci.coefs[b * 64..][..64].try_into().unwrap();
                dct::dequant_idct_block_level(src, &ci.qtable, &mut pix, level);
                let base = ch * ci.h * ci.w + by * 8 * ci.w + bx * 8;
                for y in 0..8 {
                    let prow = &pix[y * 8..y * 8 + 8];
                    let orow = &mut img.data[base + y * ci.w..base + y * ci.w + 8];
                    for x in 0..8 {
                        orow[x] = (prow[x] + 128.0).clamp(0.0, 255.0).round() as u8;
                    }
                }
            }
        }
    }
    img
}

/// Full CPU decode (entropy + dequant + IDCT) — the `cpu` placement path.
pub fn decode_cpu(bytes: &[u8]) -> Result<Image> {
    let ci = entropy_decode(bytes)?;
    Ok(coefs_to_image(&ci))
}

// ---------------------------------------------------------------------------
// Fused ROI + fractional-scale decode (§Perf)
// ---------------------------------------------------------------------------

/// How much of a bitstream to actually decode: the block-aligned cover
/// of the crop window, and the fractional IDCT scale.  Computed from
/// [`probe`] dims + the augmentation crop + the training output size —
/// the DALI/nvJPEG insight that a decoder feeding RandomResizedCrop
/// should only reconstruct the blocks (and the resolution) training
/// consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodePlan {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// ROI block bounds: rows `[by0, by1)`, cols `[bx0, bx1)`.
    pub by0: usize,
    pub by1: usize,
    pub bx0: usize,
    pub bx1: usize,
    /// Fractional-scale exponent: each ROI block reconstructs at
    /// `8 >> scale_log2` pixels per side (0 = full resolution).
    pub scale_log2: usize,
}

/// Counters from a planned decode (the fused path's acceptance metric:
/// block operations, not wall clock).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecodeStats {
    /// 8x8 blocks dequantized + inverse-transformed (any scale).
    pub blocks_idct: u64,
    /// Blocks entropy-skipped without materializing coefficients.
    pub blocks_skipped: u64,
    /// IDCT blocks by fractional scale (`blocks_by_scale[k]` counts the
    /// `1/2^k` kernel, i.e. 8/4/2/1-pixel output), so a bench can
    /// attribute per-kernel time instead of guessing the scale mix.
    /// Sums to `blocks_idct`.
    pub blocks_by_scale: [u64; 4],
}

impl DecodePlan {
    /// Plan for a crop window `(y0, x0, crop_h, crop_w)` over a `h`x`w`
    /// image that will be resized to `out_hw`: the ROI is the
    /// block-aligned cover of the crop, and the scale is the largest
    /// `1/2^k` (k ≤ `max_scale_log2` ≤ 3) whose scaled crop still covers
    /// the output in both dims (`crop/2^k >= out_hw`) — the resize then
    /// only ever *downsamples* decoded pixels, never invents them.
    pub fn new(
        c: usize,
        h: usize,
        w: usize,
        crop: (usize, usize, usize, usize),
        out_hw: usize,
        max_scale_log2: usize,
    ) -> DecodePlan {
        let (bh, bw) = (h / 8, w / 8);
        let (y0, x0, ch, cw) = crop;
        let y0 = y0.min(h.saturating_sub(1));
        let x0 = x0.min(w.saturating_sub(1));
        let ch = ch.max(1).min(h - y0);
        let cw = cw.max(1).min(w - x0);
        let by0 = y0 / 8;
        let by1 = ((y0 + ch + 7) / 8).min(bh).max(by0 + 1);
        let bx0 = x0 / 8;
        let bx1 = ((x0 + cw + 7) / 8).min(bw).max(bx0 + 1);
        let k = largest_scale(ch, cw, out_hw, max_scale_log2);
        DecodePlan { c, h, w, by0, by1, bx0, bx1, scale_log2: k }
    }

    /// Whole-image plan at full resolution (equivalent to [`decode_cpu`]).
    pub fn full(c: usize, h: usize, w: usize) -> DecodePlan {
        Self::full_scaled(c, h, w, 0)
    }

    /// Whole-image plan at `1/2^k` — the prep-cache admission shape: the
    /// cached pixels must serve *any* future crop, so no blocks are
    /// skipped, but they can still be stored downscaled.
    pub fn full_scaled(c: usize, h: usize, w: usize, scale_log2: usize) -> DecodePlan {
        DecodePlan {
            c,
            h,
            w,
            by0: 0,
            by1: h / 8,
            bx0: 0,
            bx1: w / 8,
            scale_log2: scale_log2.min(3),
        }
    }

    /// Largest image-level scale `k ≤ max_scale_log2` keeping both
    /// scaled dims at least `out_hw` — the admission-path analogue of
    /// the per-crop choice in [`DecodePlan::new`] (one shared rule, so
    /// plan and admission cannot desynchronize).
    pub fn image_scale(h: usize, w: usize, out_hw: usize, max_scale_log2: usize) -> usize {
        largest_scale(h, w, out_hw, max_scale_log2)
    }

    /// Pixels per reconstructed block side at this plan's scale.
    pub fn block_size(&self) -> usize {
        8 >> self.scale_log2
    }

    /// ROI extent in blocks, `(rows, cols)`.
    pub fn roi_blocks(&self) -> (usize, usize) {
        (self.by1 - self.by0, self.bx1 - self.bx0)
    }

    /// Decoded output dims, `(h, w)` in (scaled) pixels.
    pub fn out_dims(&self) -> (usize, usize) {
        let (rbh, rbw) = self.roi_blocks();
        (rbh * self.block_size(), rbw * self.block_size())
    }

    /// ROI origin in full-resolution pixel coordinates.
    pub fn origin(&self) -> (usize, usize) {
        (self.by0 * 8, self.bx0 * 8)
    }

    /// Fraction of the image's blocks this plan dequant+IDCTs — what the
    /// simulator scales the decode transform service time by.
    pub fn block_fraction(&self) -> f64 {
        let (rbh, rbw) = self.roi_blocks();
        (rbh * rbw) as f64 / ((self.h / 8) * (self.w / 8)) as f64
    }
}

/// The one scale-selection rule: largest `k ≤ max_scale_log2` (≤ 3)
/// with both `dh >> k` and `dw >> k` still at least `out_hw`.
fn largest_scale(dh: usize, dw: usize, out_hw: usize, max_scale_log2: usize) -> usize {
    let mut k = 0usize;
    let max_k = max_scale_log2.min(3);
    while k < max_k && out_hw > 0 && (dh >> (k + 1)) >= out_hw && (dw >> (k + 1)) >= out_hw {
        k += 1;
    }
    k
}

/// Fused ROI + fractional-scale decode: entropy-skip every block outside
/// the plan's ROI, dequant+IDCT the rest (with the scaled kernels when
/// `scale_log2 > 0`), and return the ROI as a standalone image of
/// [`DecodePlan::out_dims`].
///
/// At full scale the output is **bit-identical** to the same window of
/// [`decode_cpu`]'s image — each 8x8 block transforms independently, so
/// skipping its neighbors cannot change it (asserted by a property
/// harness in `tests/fused_decode.rs`).
pub fn decode_cpu_planned(bytes: &[u8], plan: &DecodePlan) -> Result<(Image, DecodeStats)> {
    let mut img = Image::new(0, 0, 0);
    let stats = decode_cpu_planned_into(bytes, plan, &mut img)?;
    Ok((img, stats))
}

/// [`decode_cpu_planned`] into caller-provided scratch: `img` is reshaped
/// to the plan's output dims reusing its buffer capacity, so a worker
/// decoding a stream of same-sized images allocates once and then never
/// again (the pooled per-worker decode scratch of the zero-copy hot
/// path).  Bit-identical by construction — the allocating entry point
/// delegates here with a fresh image.
pub fn decode_cpu_planned_into(
    bytes: &[u8],
    plan: &DecodePlan,
    img: &mut Image,
) -> Result<DecodeStats> {
    let (h, w, c, quality, off) = parse_header(bytes)?;
    ensure!(
        (c, h, w) == (plan.c, plan.h, plan.w),
        "plan dims {}x{}x{} do not match image {c}x{h}x{w}",
        plan.c,
        plan.h,
        plan.w
    );
    ensure!(
        plan.by0 < plan.by1 && plan.by1 <= h / 8 && plan.bx0 < plan.bx1 && plan.bx1 <= w / 8,
        "plan ROI out of range"
    );
    let q = qtable_for_quality(quality);
    let bs = plan.block_size();
    let (oh, ow) = plan.out_dims();
    img.reset(c, oh, ow);
    let (bh, bw) = (h / 8, w / 8);
    let mut reader = EntropyReader::new(&bytes[off..]);
    let mut quantized = [0i32; 64];
    let mut coef = [0f32; 64];
    let mut pix = [0f32; 64]; // scaled kernels fill only the bs*bs prefix
    let mut stats = DecodeStats::default();
    // One atomic read of the SIMD mode per image, not per block.
    let level = crate::simd::active();
    for ch in 0..c {
        for by in 0..bh {
            let in_rows = by >= plan.by0 && by < plan.by1;
            for bx in 0..bw {
                if !in_rows || bx < plan.bx0 || bx >= plan.bx1 {
                    reader
                        .skip_block()
                        .with_context(|| format!("block ({ch},{by},{bx})"))?;
                    stats.blocks_skipped += 1;
                    continue;
                }
                reader
                    .read_block(&mut quantized)
                    .with_context(|| format!("block ({ch},{by},{bx})"))?;
                // Inverse zigzag into natural order (covers all 64).
                for (zi, &nat) in ZIGZAG.iter().enumerate() {
                    coef[nat] = quantized[zi] as f32;
                }
                dct::dequant_idct_block_scaled_level(
                    &coef,
                    &q,
                    plan.scale_log2,
                    &mut pix[..bs * bs],
                    level,
                );
                stats.blocks_idct += 1;
                stats.blocks_by_scale[plan.scale_log2] += 1;
                // Same clamp/round as `coefs_to_image`, which is what
                // keeps the full-scale path bit-identical to it.
                let base = ch * oh * ow + (by - plan.by0) * bs * ow + (bx - plan.bx0) * bs;
                for y in 0..bs {
                    let prow = &pix[y * bs..y * bs + bs];
                    let orow = &mut img.data[base + y * ow..base + y * ow + bs];
                    for x in 0..bs {
                        orow[x] = (prow[x] + 128.0).clamp(0.0, 255.0).round() as u8;
                    }
                }
            }
        }
    }
    Ok(stats)
}

/// Peek image dims without decoding.
pub fn probe(bytes: &[u8]) -> Result<(usize, usize, usize, u8)> {
    let (h, w, c, q, _) = parse_header(bytes)?;
    Ok((c, h, w, q))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn smooth_image(seed: u64, c: usize, h: usize, w: usize) -> Image {
        // Smooth content compresses like natural images (codec-friendly).
        let mut img = Image::new(c, h, w);
        let mut rng = Rng::new(seed);
        let fx = rng.uniform(0.02, 0.2);
        let fy = rng.uniform(0.02, 0.2);
        for ch in 0..c {
            for y in 0..h {
                for x in 0..w {
                    let v = 128.0
                        + 80.0 * ((x as f64 * fx).sin() * (y as f64 * fy).cos())
                        + 20.0 * ((ch + 1) as f64);
                    img.data[ch * h * w + y * w + x] = v.clamp(0.0, 255.0) as u8;
                }
            }
        }
        img
    }

    #[test]
    fn roundtrip_high_quality_is_close() {
        let img = smooth_image(1, 3, 64, 64);
        let bytes = encode(&img, 95).unwrap();
        let dec = decode_cpu(&bytes).unwrap();
        assert_eq!((dec.c, dec.h, dec.w), (3, 64, 64));
        let max_err = img
            .data
            .iter()
            .zip(&dec.data)
            .map(|(&a, &b)| (a as i32 - b as i32).abs())
            .max()
            .unwrap();
        assert!(max_err <= 12, "max pixel error {max_err}");
    }

    #[test]
    fn roundtrip_error_grows_as_quality_drops() {
        let img = smooth_image(2, 3, 64, 64);
        let err = |q: u8| {
            let dec = decode_cpu(&encode(&img, q).unwrap()).unwrap();
            img.data
                .iter()
                .zip(&dec.data)
                .map(|(&a, &b)| (a as i64 - b as i64).pow(2))
                .sum::<i64>() as f64
                / img.data.len() as f64
        };
        let (e95, e50, e10) = (err(95), err(50), err(10));
        assert!(e95 <= e50 && e50 <= e10, "{e95} {e50} {e10}");
        assert!(e95 < 20.0, "high quality MSE too big: {e95}");
    }

    #[test]
    fn lower_quality_compresses_smaller() {
        let img = smooth_image(3, 3, 64, 64);
        let hi = encode(&img, 95).unwrap().len();
        let lo = encode(&img, 20).unwrap().len();
        assert!(lo < hi, "q20 {lo} >= q95 {hi}");
        assert!(hi < img.data.len(), "no compression at q95: {hi}");
    }

    #[test]
    fn hybrid_path_equals_cpu_path() {
        let img = smooth_image(4, 3, 64, 64);
        let bytes = encode(&img, 80).unwrap();
        let full = decode_cpu(&bytes).unwrap();
        let staged = coefs_to_image(&entropy_decode(&bytes).unwrap());
        assert_eq!(full, staged);
    }

    #[test]
    fn probe_reads_header() {
        let img = smooth_image(5, 1, 16, 24);
        let bytes = encode(&img, 70).unwrap();
        assert_eq!(probe(&bytes).unwrap(), (1, 16, 24, 70));
    }

    #[test]
    fn rejects_corruption() {
        let img = smooth_image(6, 1, 16, 16);
        let mut bytes = encode(&img, 70).unwrap();
        assert!(decode_cpu(&bytes[..5]).is_err());
        bytes[0] = b'X';
        assert!(decode_cpu(&bytes).is_err());
    }

    #[test]
    fn plan_geometry_and_scale_selection() {
        // Non-aligned crop: ROI is the block cover.
        let p = DecodePlan::new(3, 64, 64, (5, 9, 40, 40), 56, 3);
        assert_eq!((p.by0, p.by1, p.bx0, p.bx1), (0, 6, 1, 7));
        assert_eq!(p.scale_log2, 0, "crop 40 < out 56 cannot scale");
        assert_eq!(p.out_dims(), (48, 48));
        assert_eq!(p.origin(), (0, 8));
        assert!((p.block_fraction() - 36.0 / 64.0).abs() < 1e-12);
        // Scale picks the largest 1/2^k with crop/2^k >= out_hw.
        let p = DecodePlan::new(3, 64, 64, (0, 0, 32, 32), 16, 3);
        assert_eq!(p.scale_log2, 1);
        assert_eq!(p.block_size(), 4);
        assert_eq!(p.out_dims(), (16, 16));
        let p = DecodePlan::new(3, 64, 64, (0, 0, 64, 64), 8, 3);
        assert_eq!(p.scale_log2, 3);
        assert_eq!(p.out_dims(), (8, 8));
        // The cap clamps the choice.
        let p = DecodePlan::new(3, 64, 64, (0, 0, 64, 64), 8, 1);
        assert_eq!(p.scale_log2, 1);
        // Whole-image plans and the admission-path scale helper.
        assert_eq!(DecodePlan::full(3, 64, 64).block_fraction(), 1.0);
        assert_eq!(DecodePlan::image_scale(64, 64, 16, 3), 2);
        assert_eq!(DecodePlan::image_scale(64, 64, 56, 3), 0);
        // Out-of-range crops clamp instead of panicking.
        let p = DecodePlan::new(3, 64, 64, (200, 200, 10, 10), 8, 0);
        assert!(p.by0 < p.by1 && p.by1 <= 8 && p.bx1 <= 8);
    }

    #[test]
    fn planned_full_roi_decode_equals_decode_cpu() {
        let img = smooth_image(8, 3, 64, 48);
        let bytes = encode(&img, 85).unwrap();
        let full = decode_cpu(&bytes).unwrap();
        let (planned, stats) =
            decode_cpu_planned(&bytes, &DecodePlan::full(3, 64, 48)).unwrap();
        assert_eq!(full, planned);
        assert_eq!(stats.blocks_idct, 3 * 8 * 6);
        assert_eq!(stats.blocks_skipped, 0);
        assert_eq!(stats.blocks_by_scale, [3 * 8 * 6, 0, 0, 0]);
    }

    #[test]
    fn per_scale_block_counters_attribute_each_kernel() {
        let img = smooth_image(11, 3, 64, 64);
        let bytes = encode(&img, 80).unwrap();
        for k in 0..4usize {
            let plan = DecodePlan::full_scaled(3, 64, 64, k);
            let (_, stats) = decode_cpu_planned(&bytes, &plan).unwrap();
            let mut want = [0u64; 4];
            want[k] = 3 * 8 * 8;
            assert_eq!(stats.blocks_by_scale, want, "scale {k}");
            assert_eq!(stats.blocks_by_scale.iter().sum::<u64>(), stats.blocks_idct);
        }
    }

    #[test]
    fn planned_roi_decode_is_window_of_full_decode() {
        let img = smooth_image(9, 3, 64, 64);
        let bytes = encode(&img, 80).unwrap();
        let full = decode_cpu(&bytes).unwrap();
        let plan = DecodePlan::new(3, 64, 64, (13, 22, 30, 27), 56, 0);
        let (roi, stats) = decode_cpu_planned(&bytes, &plan).unwrap();
        let (oy, ox) = plan.origin();
        let (rh, rw) = plan.out_dims();
        assert_eq!((roi.h, roi.w), (rh, rw));
        for ch in 0..3 {
            for y in 0..rh {
                for x in 0..rw {
                    assert_eq!(
                        roi.pixel(ch, y, x),
                        full.pixel(ch, oy + y, ox + x),
                        "({ch},{y},{x})"
                    );
                }
            }
        }
        let total = 3 * 8 * 8;
        assert_eq!(stats.blocks_idct + stats.blocks_skipped, total);
        assert!(stats.blocks_skipped > 0);
    }

    /// Scratch-decode satellite: one reused `Image` across plans of
    /// different geometry stays bit-identical to fresh decodes (stale
    /// pixels from a larger previous plan must never survive a reset).
    #[test]
    fn planned_decode_into_reused_scratch_matches_fresh_decode() {
        let mut scratch = Image::new(0, 0, 0);
        let mut fbuf = Vec::new();
        for (seed, crop) in [
            (20u64, (0usize, 0usize, 64usize, 64usize)),
            (21, (13, 22, 30, 27)),
            (22, (5, 9, 40, 40)),
            (23, (0, 0, 16, 16)),
        ] {
            let img = smooth_image(seed, 3, 64, 64);
            let bytes = encode(&img, 85).unwrap();
            let plan = DecodePlan::new(3, 64, 64, crop, 56, 0);
            let (fresh, fresh_stats) = decode_cpu_planned(&bytes, &plan).unwrap();
            let stats = decode_cpu_planned_into(&bytes, &plan, &mut scratch).unwrap();
            assert_eq!(fresh, scratch, "seed {seed} crop {crop:?}");
            assert_eq!(fresh_stats, stats);
            scratch.to_f32_into(&mut fbuf);
            assert_eq!(fbuf, fresh.to_f32());
        }
        // Shrinking reuse: a tiny image after a big one.
        let small = smooth_image(24, 1, 16, 16);
        let bytes = encode(&small, 85).unwrap();
        decode_cpu_planned_into(&bytes, &DecodePlan::full(1, 16, 16), &mut scratch).unwrap();
        assert_eq!((scratch.c, scratch.h, scratch.w), (1, 16, 16));
        assert_eq!(scratch.data.len(), 16 * 16);
        assert_eq!(scratch, decode_cpu(&bytes).unwrap());
    }

    #[test]
    fn planned_decode_rejects_corruption_and_dim_mismatch() {
        let img = smooth_image(10, 1, 16, 16);
        let bytes = encode(&img, 70).unwrap();
        let plan = DecodePlan::full(1, 16, 16);
        assert!(decode_cpu_planned(&bytes[..bytes.len() - 1], &plan).is_err());
        assert!(decode_cpu_planned(&bytes, &DecodePlan::full(1, 16, 24)).is_err());
        assert!(decode_cpu_planned(&bytes, &DecodePlan::full(3, 16, 16)).is_err());
    }

    #[test]
    fn random_noise_roundtrips_dims() {
        // Noise is worst-case for the codec but must still round-trip shape.
        let mut rng = Rng::new(7);
        let mut img = Image::new(2, 32, 40);
        for b in img.data.iter_mut() {
            *b = rng.next_u32() as u8;
        }
        let dec = decode_cpu(&encode(&img, 50).unwrap()).unwrap();
        assert_eq!((dec.c, dec.h, dec.w), (2, 32, 40));
    }
}
