//! Run configuration: the paper's experimental axes as a first-class
//! config object (JSON-serializable, CLI-overridable).

use crate::pipeline::prep_cache::PrepCachePolicy;
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// Loading method (paper §2.2): raw files vs record shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Raw,
    Record,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        match s {
            "raw" => Ok(Method::Raw),
            "record" => Ok(Method::Record),
            _ => bail!("method must be raw|record, got {s}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Raw => "raw",
            Method::Record => "record",
        }
    }
}

/// Operator placement (paper §2.2.3, §4):
/// * `Cpu`     — whole pipeline on CPU (the frameworks' built-in loaders).
/// * `Hybrid`  — entropy decode on CPU, dequant+IDCT+augment on the device
///               (DALI's hybrid decode).
/// * `Hybrid0` — full decode on CPU, only augmentation on the device
///               (the paper's "hybrid-0" that saves device cycles).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    Cpu,
    Hybrid,
    Hybrid0,
}

impl Placement {
    pub fn parse(s: &str) -> Result<Placement> {
        match s {
            "cpu" => Ok(Placement::Cpu),
            "hybrid" => Ok(Placement::Hybrid),
            "hybrid0" | "hybrid-0" => Ok(Placement::Hybrid0),
            _ => bail!("placement must be cpu|hybrid|hybrid0, got {s}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Placement::Cpu => "cpu",
            Placement::Hybrid => "hybrid",
            Placement::Hybrid0 => "hybrid0",
        }
    }

    /// Does this placement run anything on the device before training?
    pub fn uses_device_preproc(&self) -> bool {
        !matches!(self, Placement::Cpu)
    }
}

/// Fractional-scale cap for the fused decoder (`--decode-scale`):
/// * `auto` — per image, pick the largest `1/2^k` (k ≤ 3) whose scaled
///   crop still covers the training output.
/// * `1 | 2 | 4 | 8` — never scale past `1/n` (`1` = full resolution
///   only, the default: the ROI skip is bit-exact, the fractional scale
///   is a tolerance-checked quality trade-off the user opts into).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeScale {
    Auto,
    Fixed(u8),
}

impl DecodeScale {
    pub fn parse(s: &str) -> Result<DecodeScale> {
        match s {
            "auto" => Ok(DecodeScale::Auto),
            "1" => Ok(DecodeScale::Fixed(1)),
            "2" => Ok(DecodeScale::Fixed(2)),
            "4" => Ok(DecodeScale::Fixed(4)),
            "8" => Ok(DecodeScale::Fixed(8)),
            _ => bail!("decode-scale must be auto|1|2|4|8, got {s}"),
        }
    }

    /// Canonical flag value.  Total: a hand-built `Fixed` payload that
    /// `parse` would reject (only 1|2|4|8 are meaningful) renders as its
    /// normalized denominator rather than panicking mid-report.
    pub fn name(&self) -> &'static str {
        match self {
            DecodeScale::Auto => "auto",
            DecodeScale::Fixed(_) => match self.max_log2() {
                0 => "1",
                1 => "2",
                2 => "4",
                _ => "8",
            },
        }
    }

    /// Largest scale exponent the decode plan may pick (0..=3).
    /// Payloads `parse` would reject normalize to the nearest lower
    /// power of two, so invalid states degrade instead of panicking.
    pub fn max_log2(&self) -> u8 {
        match self {
            DecodeScale::Auto => 3,
            DecodeScale::Fixed(n) => ((*n).max(1).ilog2() as u8).min(3),
        }
    }
}

/// Batch-slab pool mode (`--slab-pool`) — the zero-copy hot path's
/// memory knob, `cpu` placement only (the one whose CPU hand-off is the
/// final batch tensor; device placements ignore it):
/// * `auto` — pool on; the idle-arena bound derives from the prefetch
///   depth (one slab per in-flight batch plus slack).
/// * `N` — pool on; keep at most `N` idle arenas for reuse.
/// * `off` — the per-sample `Vec` path (pre-slab behavior, kept for A/B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlabPoolCfg {
    Auto,
    Fixed(usize),
    Off,
}

impl SlabPoolCfg {
    pub fn parse(s: &str) -> Result<SlabPoolCfg> {
        match s {
            "auto" => Ok(SlabPoolCfg::Auto),
            "off" => Ok(SlabPoolCfg::Off),
            _ => match s.parse::<usize>() {
                Ok(n) if n > 0 => Ok(SlabPoolCfg::Fixed(n)),
                _ => bail!("slab-pool must be auto|N|off (N >= 1), got {s}"),
            },
        }
    }

    /// Canonical flag value (round-trips through [`parse`](Self::parse)).
    pub fn name(&self) -> String {
        match self {
            SlabPoolCfg::Auto => "auto".into(),
            SlabPoolCfg::Off => "off".into(),
            SlabPoolCfg::Fixed(n) => n.to_string(),
        }
    }

    pub fn enabled(&self) -> bool {
        !matches!(self, SlabPoolCfg::Off)
    }

    /// Idle arenas the pool keeps for reuse.  `auto` covers every slab
    /// the pipeline can hold in flight at once — the sample queue
    /// (`queue_depth` batches' worth of slot samples) plus the batch
    /// queue (`queue_depth` sealed batches) plus the open slab, the
    /// batch on the device, and one of slack — so even a full drain
    /// burst recycles without freeing, and the steady state never
    /// allocates.  A burst beyond it frees on recycle.
    pub fn free_cap(&self, queue_depth: usize) -> usize {
        match self {
            SlabPoolCfg::Auto => 2 * queue_depth + 3,
            SlabPoolCfg::Fixed(n) => *n,
            SlabPoolCfg::Off => 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Directory holding the raw corpus (img/*.mjx + metadata.tsv) and/or
    /// the `records/` subdirectory with shards.
    pub data_dir: PathBuf,
    /// Directory with AOT artifacts (manifest.json + *.hlo.txt).
    pub artifact_dir: PathBuf,
    pub method: Method,
    pub placement: Placement,
    /// Storage emulation: "local" (no throttle), the local device tiers
    /// ("ebs", "nvme", "dram"), or the remote object-store tiers
    /// ("s3", "s3-cold") — see `RunConfig::accepted_storage`.
    pub storage: String,
    /// Concurrent ranged-read connections for record streaming against a
    /// remote tier (the parallel range-GET prefetcher; 1 = serial reads;
    /// local tiers always stream serially).
    pub net_conns: usize,
    /// Prefetcher readahead window, MiB (bounds parts fetched ahead of
    /// the consumer).
    pub readahead_mb: usize,
    /// Scale factor on emulated storage delays (test speed knob).
    pub time_scale: f64,
    pub model: String,
    pub batch_size: usize,
    /// CPU worker threads for read+decode+augment (`--workers N`): the
    /// fixed pool size, or the starting point ignored under `--workers
    /// auto` (the elastic executor starts at `workers_min`).
    pub cpu_workers: usize,
    /// `--workers auto`: let the elastic executor scale the pool between
    /// `workers_min` and `workers_max` from live backpressure signals.
    pub workers_auto: bool,
    /// Elastic pool floor (`--workers-min`).
    pub workers_min: usize,
    /// Elastic pool ceiling (`--workers-max`) — also sizes the work
    /// queue, which belongs to the executor.
    pub workers_max: usize,
    /// Autoscale controller decision period, seconds (`--workers-interval`).
    pub workers_interval_secs: f64,
    /// Bounded queue depth, in batches, between stages (prefetch depth).
    pub queue_depth: usize,
    /// Stop after this many train steps (0 = run exactly one epoch).
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// Ideal mode: preload one batch and train on it repeatedly (the
    /// paper's "ideal" upper-bound line in Fig. 2).
    pub ideal: bool,
    /// If false, run preprocessing only (Fig. 3 breakdown mode).
    pub train: bool,
    /// Record-method chunk size in bytes (sequential read unit).
    pub record_chunk: usize,
    /// Shuffle-buffer size (in samples) for record streaming.
    pub shuffle_buffer: usize,
    /// Utilization sampling period in seconds (0 = no trace).
    pub sample_period: f64,
    /// Epochs to run when `steps == 0` (each is a full pass).
    pub epochs: usize,
    /// DRAM cache budget over the storage backend, MiB (0 = no cache) —
    /// the OneAccess/HiPC'19-style cache from the paper's related work.
    pub cache_mb: usize,
    /// Decoded-sample (post-decode, pre-augment) cache budget, MiB
    /// (0 = disabled) — the CoorDL-style cache that lets epoch ≥ 2 skip
    /// read+decode while keeping augmentation randomness fresh.
    pub prep_cache_mb: usize,
    /// Eviction policy of the decoded-sample cache: `minio`
    /// (eviction-free, shuffle-proof) or `lru` (thrashes under
    /// re-shuffled epochs; kept for comparison).
    pub prep_cache_policy: PrepCachePolicy,
    /// Fused ROI decode (`--fused-decode on|off`): entropy-skip blocks
    /// outside the crop window on the `cpu`/`hybrid0` paths instead of
    /// dequant+IDCTing the whole image.  Bit-exact vs. full decode, so
    /// on by default.
    pub fused_decode: bool,
    /// Fractional-scale cap for the fused decoder (`--decode-scale`);
    /// only scales past 1/1 when `fused_decode` is on.
    pub decode_scale: DecodeScale,
    /// Batch-slab pool (`--slab-pool auto|N|off`): workers write
    /// augmented output directly into their batch slot and collate
    /// becomes a seal — `off` preserves the per-sample Vec path for A/B.
    pub slab_pool: SlabPoolCfg,
    /// SIMD kernel dispatch (`--simd on|off|auto`): `auto` (default)
    /// uses the best ISA tier the CPU reports, `off` pins the scalar
    /// reference kernels for A/B.  Outputs are bit-identical either
    /// way (see DESIGN.md "SIMD kernels"), so this is a speed knob,
    /// never a quality knob.
    pub simd: crate::simd::SimdMode,
    /// Span tracing (`--trace off|PATH`): `off` (default) disables the
    /// tracer entirely; any other value enables per-stage span recording
    /// and writes a Chrome trace-event JSON (open in Perfetto or
    /// chrome://tracing) to that path when the run ends.
    pub trace: String,
    /// Fraction of spans kept per (thread, stage) when tracing is on
    /// (`--trace-sample-rate`, in (0, 1]); implemented as a keep-every-
    /// Nth stride so sampled histograms stay unbiased per stage.
    pub trace_sample_rate: f64,
    /// Seeded fault injection on the storage layer (`--faults off|SPEC`
    /// where SPEC is `k=v,...` over `transient`, `throttle`, `burst`,
    /// `straggler`, `slowdown`, `corrupt`, `seed` — see
    /// `storage::FaultProfile::parse`).  `off` (default) injects nothing.
    pub faults: String,
    /// Graceful-degradation budget: max fraction of expected samples that
    /// may be quarantined (skipped) before the run fails loudly.  0
    /// (default) = zero tolerance: the first undecodable sample errors.
    pub max_skip_rate: f64,
    /// Extra read attempts after the first on transient storage errors
    /// (`--retries N`; 0 disables retrying — the pre-fault behavior).
    pub retries: u32,
    /// Hedged duplicate range-GETs for straggler parts in the prefetcher
    /// (`--hedge on|off`): duplicate a part once its latency passes the
    /// trailing p95, first answer wins.
    pub hedge: bool,
    /// Per-request retry budget, seconds (`--retry-deadline`): a request
    /// failing for this long stops retrying even with attempts left.
    pub retry_deadline: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            data_dir: PathBuf::from("data"),
            artifact_dir: PathBuf::from("artifacts"),
            method: Method::Record,
            placement: Placement::Hybrid,
            storage: "local".into(),
            net_conns: 8,
            readahead_mb: 8,
            time_scale: 1.0,
            model: "resnet_t".into(),
            batch_size: 32,
            cpu_workers: 2,
            workers_auto: false,
            workers_min: 1,
            workers_max: 8,
            workers_interval_secs: 0.2,
            queue_depth: 4,
            steps: 0,
            lr: 0.05,
            seed: 42,
            ideal: false,
            train: true,
            record_chunk: 1 << 20,
            shuffle_buffer: 256,
            sample_period: 0.0,
            epochs: 1,
            cache_mb: 0,
            prep_cache_mb: 0,
            prep_cache_policy: PrepCachePolicy::Minio,
            fused_decode: true,
            decode_scale: DecodeScale::Fixed(1),
            slab_pool: SlabPoolCfg::Auto,
            simd: crate::simd::SimdMode::Auto,
            trace: "off".into(),
            trace_sample_rate: 1.0,
            faults: "off".into(),
            max_skip_rate: 0.0,
            retries: 3,
            hedge: true,
            retry_deadline: 30.0,
        }
    }
}

impl RunConfig {
    /// Every storage name the engine accepts, derived from the actual
    /// tier registries so this list cannot drift from what
    /// `coordinator::build_storage` can construct: "local" plus
    /// `StorageProfile::names()` plus `NetProfile::names()`.
    pub fn accepted_storage() -> Vec<&'static str> {
        let mut names = vec!["local"];
        names.extend_from_slice(crate::storage::StorageProfile::names());
        names.extend_from_slice(crate::storage::NetProfile::names());
        names
    }

    /// The boolean (value-less) flags among [`Self::accepted_flags`].
    pub fn boolean_flags() -> &'static [&'static str] {
        &["ideal", "no-train"]
    }

    /// Every CLI key the `run` subcommand accepts — options and boolean
    /// flags alike.  `apply_args` rejects anything outside this list, so
    /// a new `args.get("...")` in `apply_args` *must* be registered here
    /// (or it is dead on arrival at runtime), and the help-drift test
    /// requires every registered flag to appear in `dpp::CLI_HELP` — the
    /// two together keep code, list, and docs from diverging.
    pub fn accepted_flags() -> &'static [&'static str] {
        &[
            "data-dir",
            "artifacts",
            "method",
            "placement",
            "storage",
            "model",
            "time-scale",
            "batch",
            "workers",
            "workers-min",
            "workers-max",
            "workers-interval",
            "queue-depth",
            "steps",
            "lr",
            "seed",
            "epochs",
            "cache-mb",
            "prep-cache-mb",
            "prep-cache-policy",
            "net-conns",
            "readahead-mb",
            "fused-decode",
            "decode-scale",
            "slab-pool",
            "simd",
            "trace",
            "trace-sample-rate",
            "faults",
            "max-skip-rate",
            "retries",
            "hedge",
            "retry-deadline",
            "ideal",
            "no-train",
            // Consumed by the `run` driver (report export), not RunConfig.
            "report-json",
        ]
    }

    pub fn validate(&self) -> Result<()> {
        if self.batch_size == 0 {
            bail!("batch_size must be > 0");
        }
        if self.cpu_workers == 0 {
            bail!("cpu_workers must be > 0");
        }
        if self.workers_min == 0 {
            bail!("workers_min must be > 0");
        }
        if self.workers_max < self.workers_min {
            bail!(
                "workers_max ({}) must be >= workers_min ({})",
                self.workers_max,
                self.workers_min
            );
        }
        if !(self.workers_interval_secs > 0.0) {
            bail!("workers-interval must be > 0 seconds");
        }
        if self.epochs == 0 {
            bail!("epochs must be >= 1");
        }
        if self.train && self.model.is_empty() {
            bail!("train=true requires a model");
        }
        let accepted = Self::accepted_storage();
        if !accepted.contains(&self.storage.as_str()) {
            bail!("storage must be {}, got {}", accepted.join("|"), self.storage);
        }
        if self.net_conns == 0 {
            bail!("net_conns must be > 0");
        }
        if self.readahead_mb == 0 {
            bail!("readahead_mb must be > 0");
        }
        if self.trace.is_empty() {
            bail!("trace must be `off` or an output path");
        }
        if !(self.trace_sample_rate > 0.0 && self.trace_sample_rate <= 1.0) {
            bail!(
                "trace-sample-rate must be in (0, 1], got {}",
                self.trace_sample_rate
            );
        }
        // Parse (and thereby validate) the fault spec; the storage
        // builder re-parses the same string, so a bad spec fails here,
        // before any data is touched.
        crate::storage::FaultProfile::parse(&self.faults)?;
        if !(0.0..1.0).contains(&self.max_skip_rate) {
            bail!("max-skip-rate must be in [0, 1), got {}", self.max_skip_rate);
        }
        if !(self.retry_deadline > 0.0) {
            bail!("retry-deadline must be > 0 seconds, got {}", self.retry_deadline);
        }
        Ok(())
    }

    /// Apply CLI overrides (--model, --method, --placement, ...).
    /// Unknown keys are rejected up front — typos fail loudly instead of
    /// silently running the default configuration.
    pub fn apply_args(&mut self, args: &crate::util::cli::Args) -> Result<()> {
        let accepted = Self::accepted_flags();
        let boolean = Self::boolean_flags();
        for key in args.options.keys().map(String::as_str) {
            if !accepted.contains(&key) {
                bail!("unknown flag --{key} (see `dpp --help` for the run flags)");
            }
            if boolean.contains(&key) {
                bail!("--{key} takes no value");
            }
        }
        for key in args.flags.iter().map(String::as_str) {
            if !accepted.contains(&key) {
                bail!("unknown flag --{key} (see `dpp --help` for the run flags)");
            }
            // A value-taking key that parsed as a bare flag means the
            // value was forgotten (`--steps --no-train`): without this
            // check it would silently run on the default, exactly what
            // the loud-failure contract above exists to prevent.
            if !boolean.contains(&key) {
                bail!("--{key} requires a value");
            }
        }
        if let Some(v) = args.get("data-dir") {
            self.data_dir = PathBuf::from(v);
        }
        if let Some(v) = args.get("artifacts") {
            self.artifact_dir = PathBuf::from(v);
        }
        if let Some(v) = args.get("method") {
            self.method = Method::parse(v)?;
        }
        if let Some(v) = args.get("placement") {
            self.placement = Placement::parse(v)?;
        }
        if let Some(v) = args.get("storage") {
            self.storage = v.to_string();
        }
        if let Some(v) = args.get("model") {
            self.model = v.to_string();
        }
        // Strict numeric parsing: a malformed value (`--workers-max 1O`)
        // must fail loudly, not silently fall back to the default — the
        // same contract as the unknown-flag rejection above.
        fn num<T: std::str::FromStr>(
            args: &crate::util::cli::Args,
            key: &str,
            cur: T,
        ) -> Result<T> {
            match args.get(key) {
                None => Ok(cur),
                Some(v) => v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--{key}: expected a number, got {v:?}")),
            }
        }
        self.time_scale = num(args, "time-scale", self.time_scale)?;
        self.batch_size = num(args, "batch", self.batch_size)?;
        if let Some(v) = args.get("workers") {
            if v == "auto" {
                self.workers_auto = true;
            } else {
                self.cpu_workers = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("workers must be auto|N, got {v}"))?;
                self.workers_auto = false;
            }
        }
        self.workers_min = num(args, "workers-min", self.workers_min)?;
        self.workers_max = num(args, "workers-max", self.workers_max)?;
        self.workers_interval_secs =
            num(args, "workers-interval", self.workers_interval_secs)?;
        self.queue_depth = num(args, "queue-depth", self.queue_depth)?;
        self.steps = num(args, "steps", self.steps)?;
        self.lr = num(args, "lr", self.lr as f64)? as f32;
        self.seed = num(args, "seed", self.seed)?;
        self.epochs = num(args, "epochs", self.epochs)?.max(1);
        self.cache_mb = num(args, "cache-mb", self.cache_mb)?;
        self.prep_cache_mb = num(args, "prep-cache-mb", self.prep_cache_mb)?;
        if let Some(v) = args.get("prep-cache-policy") {
            self.prep_cache_policy = PrepCachePolicy::parse(v)?;
        }
        self.net_conns = num(args, "net-conns", self.net_conns)?;
        self.readahead_mb = num(args, "readahead-mb", self.readahead_mb)?;
        if let Some(v) = args.get("fused-decode") {
            self.fused_decode = match v {
                "on" | "true" => true,
                "off" | "false" => false,
                _ => bail!("fused-decode must be on|off, got {v}"),
            };
        }
        if let Some(v) = args.get("decode-scale") {
            self.decode_scale = DecodeScale::parse(v)?;
        }
        if let Some(v) = args.get("slab-pool") {
            self.slab_pool = SlabPoolCfg::parse(v)?;
        }
        if let Some(v) = args.get("simd") {
            self.simd = crate::simd::SimdMode::parse(v)?;
        }
        if let Some(v) = args.get("trace") {
            self.trace = v.to_string();
        }
        self.trace_sample_rate =
            num(args, "trace-sample-rate", self.trace_sample_rate)?;
        if let Some(v) = args.get("faults") {
            self.faults = v.to_string();
        }
        self.max_skip_rate = num(args, "max-skip-rate", self.max_skip_rate)?;
        self.retries = num(args, "retries", self.retries)?;
        if let Some(v) = args.get("hedge") {
            self.hedge = match v {
                "on" | "true" => true,
                "off" | "false" => false,
                _ => bail!("hedge must be on|off, got {v}"),
            };
        }
        self.retry_deadline = num(args, "retry-deadline", self.retry_deadline)?;
        if args.has_flag("ideal") {
            self.ideal = true;
        }
        if args.has_flag("no-train") {
            self.train = false;
        }
        self.validate()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("data_dir", Json::str(&self.data_dir.to_string_lossy())),
            ("method", Json::str(self.method.name())),
            ("placement", Json::str(self.placement.name())),
            ("storage", Json::str(&self.storage)),
            ("net_conns", Json::num(self.net_conns as f64)),
            ("readahead_mb", Json::num(self.readahead_mb as f64)),
            ("model", Json::str(&self.model)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("cpu_workers", Json::num(self.cpu_workers as f64)),
            ("workers_auto", Json::Bool(self.workers_auto)),
            ("workers_min", Json::num(self.workers_min as f64)),
            ("workers_max", Json::num(self.workers_max as f64)),
            ("workers_interval_secs", Json::num(self.workers_interval_secs)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("ideal", Json::Bool(self.ideal)),
            ("train", Json::Bool(self.train)),
            ("epochs", Json::num(self.epochs as f64)),
            ("cache_mb", Json::num(self.cache_mb as f64)),
            ("prep_cache_mb", Json::num(self.prep_cache_mb as f64)),
            ("prep_cache_policy", Json::str(self.prep_cache_policy.name())),
            ("fused_decode", Json::Bool(self.fused_decode)),
            ("decode_scale", Json::str(self.decode_scale.name())),
            ("slab_pool", Json::str(&self.slab_pool.name())),
            ("simd", Json::str(self.simd.name())),
            ("trace", Json::str(&self.trace)),
            ("trace_sample_rate", Json::num(self.trace_sample_rate)),
            ("faults", Json::str(&self.faults)),
            ("max_skip_rate", Json::num(self.max_skip_rate)),
            ("retries", Json::num(self.retries as f64)),
            ("hedge", Json::Bool(self.hedge)),
            ("retry_deadline", Json::num(self.retry_deadline)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    #[test]
    fn parse_enums() {
        assert_eq!(Method::parse("raw").unwrap(), Method::Raw);
        assert!(Method::parse("zip").is_err());
        assert_eq!(Placement::parse("hybrid-0").unwrap(), Placement::Hybrid0);
        assert!(Placement::Cpu.uses_device_preproc() == false);
        assert!(Placement::Hybrid.uses_device_preproc());
    }

    #[test]
    fn args_override() {
        let mut cfg = RunConfig::default();
        let args = Args::parse(
            "run --model alexnet_t --method raw --placement cpu --workers 4 --steps 7 --ideal"
                .split_whitespace()
                .map(String::from),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.model, "alexnet_t");
        assert_eq!(cfg.method, Method::Raw);
        assert_eq!(cfg.placement, Placement::Cpu);
        assert_eq!(cfg.cpu_workers, 4);
        assert_eq!(cfg.steps, 7);
        assert!(cfg.ideal);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = RunConfig::default();
        cfg.batch_size = 0;
        assert!(cfg.validate().is_err());
        cfg = RunConfig::default();
        cfg.storage = "tape".into();
        assert!(cfg.validate().is_err());
        cfg = RunConfig::default();
        cfg.net_conns = 0;
        assert!(cfg.validate().is_err());
        cfg = RunConfig::default();
        cfg.readahead_mb = 0;
        assert!(cfg.validate().is_err());
        assert!(RunConfig::default().validate().is_ok());
    }

    #[test]
    fn storage_validation_matches_tier_lookup_exactly() {
        use crate::storage::{NetProfile, StorageProfile};
        // Every accepted name must validate AND resolve through exactly
        // one tier registry ("local" is the unthrottled passthrough).
        for name in RunConfig::accepted_storage() {
            let cfg = RunConfig { storage: name.into(), ..Default::default() };
            cfg.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            let resolvers = [
                name == "local",
                StorageProfile::by_name(name).is_some(),
                NetProfile::by_name(name).is_some(),
            ];
            assert_eq!(
                resolvers.iter().filter(|&&r| r).count(),
                1,
                "{name} must resolve via exactly one registry"
            );
        }
        // Conversely: names no registry knows must fail validation.
        for name in ["tape", "efs", "S3", "s3cold", ""] {
            let cfg = RunConfig { storage: name.into(), ..Default::default() };
            assert!(cfg.validate().is_err(), "{name:?} accepted");
            assert!(StorageProfile::by_name(name).is_none());
            assert!(NetProfile::by_name(name).is_none());
        }
        // The error message enumerates the full accepted set.
        let cfg = RunConfig { storage: "tape".into(), ..Default::default() };
        let msg = cfg.validate().unwrap_err().to_string();
        for name in RunConfig::accepted_storage() {
            assert!(msg.contains(name), "error message misses {name}: {msg}");
        }
    }

    #[test]
    fn remote_tiers_accept_conn_flags() {
        let mut cfg = RunConfig::default();
        let args = Args::parse(
            "run --storage s3 --net-conns 16 --readahead-mb 32"
                .split_whitespace()
                .map(String::from),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.storage, "s3");
        assert_eq!(cfg.net_conns, 16);
        assert_eq!(cfg.readahead_mb, 32);
    }

    #[test]
    fn prep_cache_flags_parse_validate_and_roundtrip() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.prep_cache_mb, 0);
        assert_eq!(cfg.prep_cache_policy, PrepCachePolicy::Minio);
        let args = Args::parse(
            "run --prep-cache-mb 256 --prep-cache-policy lru"
                .split_whitespace()
                .map(String::from),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.prep_cache_mb, 256);
        assert_eq!(cfg.prep_cache_policy, PrepCachePolicy::Lru);
        // Bad policy names are rejected at apply time.
        let mut bad = RunConfig::default();
        let args = Args::parse(
            "run --prep-cache-policy fifo".split_whitespace().map(String::from),
        );
        assert!(bad.apply_args(&args).is_err());
        // JSON round-trip carries both fields.
        let parsed = Json::parse(&cfg.to_json().dump()).unwrap();
        assert_eq!(parsed.req("prep_cache_mb").as_usize(), Some(256));
        assert_eq!(parsed.req("prep_cache_policy").as_str(), Some("lru"));
    }

    #[test]
    fn fused_decode_flags_parse_validate_and_roundtrip() {
        let cfg = RunConfig::default();
        assert!(cfg.fused_decode, "ROI skip is bit-exact, so on by default");
        assert_eq!(cfg.decode_scale, DecodeScale::Fixed(1));
        assert_eq!(cfg.decode_scale.max_log2(), 0);
        let mut cfg = RunConfig::default();
        let args = Args::parse(
            "run --fused-decode off --decode-scale auto".split_whitespace().map(String::from),
        );
        cfg.apply_args(&args).unwrap();
        assert!(!cfg.fused_decode);
        assert_eq!(cfg.decode_scale, DecodeScale::Auto);
        assert_eq!(cfg.decode_scale.max_log2(), 3);
        // Every fixed denominator maps to its exponent.
        for (s, k) in [("1", 0u8), ("2", 1), ("4", 2), ("8", 3)] {
            assert_eq!(DecodeScale::parse(s).unwrap().max_log2(), k);
            assert_eq!(DecodeScale::parse(s).unwrap().name(), s);
        }
        assert!(DecodeScale::parse("3").is_err());
        assert!(DecodeScale::parse("").is_err());
        // Hand-built payloads parse would reject degrade, never panic.
        assert_eq!(DecodeScale::Fixed(3).max_log2(), 1);
        assert_eq!(DecodeScale::Fixed(3).name(), "2");
        assert_eq!(DecodeScale::Fixed(0).max_log2(), 0);
        assert_eq!(DecodeScale::Fixed(255).max_log2(), 3);
        let mut bad = RunConfig::default();
        let args =
            Args::parse("run --fused-decode maybe".split_whitespace().map(String::from));
        assert!(bad.apply_args(&args).is_err());
        let mut bad = RunConfig::default();
        let args =
            Args::parse("run --decode-scale 16".split_whitespace().map(String::from));
        assert!(bad.apply_args(&args).is_err());
        // JSON round-trip carries both fields.
        let mut cfg = RunConfig::default();
        cfg.fused_decode = false;
        cfg.decode_scale = DecodeScale::Fixed(4);
        let parsed = Json::parse(&cfg.to_json().dump()).unwrap();
        assert_eq!(parsed.req("fused_decode").as_bool(), Some(false));
        assert_eq!(parsed.req("decode_scale").as_str(), Some("4"));
    }

    #[test]
    fn slab_pool_flag_parses_validates_and_roundtrips() {
        // Default: pooled slabs on with the auto free-list bound.
        let cfg = RunConfig::default();
        assert_eq!(cfg.slab_pool, SlabPoolCfg::Auto);
        assert!(cfg.slab_pool.enabled());
        // Auto bound = every in-flight slab (sample queue + batch queue
        // + open + device + slack), so a drain burst recycles fully.
        assert_eq!(cfg.slab_pool.free_cap(cfg.queue_depth), 2 * cfg.queue_depth + 3);
        // auto | N | off all parse and round-trip through name().
        for (s, want) in [
            ("auto", SlabPoolCfg::Auto),
            ("off", SlabPoolCfg::Off),
            ("3", SlabPoolCfg::Fixed(3)),
            ("16", SlabPoolCfg::Fixed(16)),
        ] {
            let parsed = SlabPoolCfg::parse(s).unwrap();
            assert_eq!(parsed, want);
            assert_eq!(SlabPoolCfg::parse(&parsed.name()).unwrap(), parsed);
        }
        assert!(!SlabPoolCfg::Off.enabled());
        assert_eq!(SlabPoolCfg::Fixed(5).free_cap(4), 5);
        assert_eq!(SlabPoolCfg::Off.free_cap(4), 0);
        // Garbage values fail loudly (0 idle arenas = just say off).
        for bad in ["0", "on", "", "-1", "2.5"] {
            assert!(SlabPoolCfg::parse(bad).is_err(), "{bad:?} accepted");
        }
        // CLI → config → JSON.
        let mut cfg = RunConfig::default();
        let args =
            Args::parse("run --slab-pool off".split_whitespace().map(String::from));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.slab_pool, SlabPoolCfg::Off);
        let parsed = Json::parse(&cfg.to_json().dump()).unwrap();
        assert_eq!(parsed.req("slab_pool").as_str(), Some("off"));
        let args = Args::parse("run --slab-pool 8".split_whitespace().map(String::from));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.slab_pool, SlabPoolCfg::Fixed(8));
        let mut bad = RunConfig::default();
        let args =
            Args::parse("run --slab-pool maybe".split_whitespace().map(String::from));
        assert!(bad.apply_args(&args).is_err());
    }

    #[test]
    fn simd_flag_parses_validates_and_roundtrips() {
        use crate::simd::SimdMode;
        // Default: auto — best detected ISA, bit-identical to scalar.
        let cfg = RunConfig::default();
        assert_eq!(cfg.simd, SimdMode::Auto);
        // on | off | auto all parse and round-trip through name().
        for (s, want) in [
            ("on", SimdMode::On),
            ("off", SimdMode::Off),
            ("auto", SimdMode::Auto),
        ] {
            let parsed = SimdMode::parse(s).unwrap();
            assert_eq!(parsed, want);
            assert_eq!(SimdMode::parse(parsed.name()).unwrap(), parsed);
        }
        for bad in ["", "avx2", "sse2", "1", "maybe"] {
            assert!(SimdMode::parse(bad).is_err(), "{bad:?} accepted");
        }
        // CLI → config → JSON.
        let mut cfg = RunConfig::default();
        let args = Args::parse("run --simd off".split_whitespace().map(String::from));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.simd, SimdMode::Off);
        let parsed = Json::parse(&cfg.to_json().dump()).unwrap();
        assert_eq!(parsed.req("simd").as_str(), Some("off"));
        let args = Args::parse("run --simd on".split_whitespace().map(String::from));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.simd, SimdMode::On);
        let mut bad = RunConfig::default();
        let args = Args::parse("run --simd fast".split_whitespace().map(String::from));
        assert!(bad.apply_args(&args).is_err());
    }

    #[test]
    fn elastic_worker_flags_parse_validate_and_roundtrip() {
        let cfg = RunConfig::default();
        assert!(!cfg.workers_auto);
        assert_eq!((cfg.workers_min, cfg.workers_max), (1, 8));
        assert!(cfg.workers_interval_secs > 0.0);
        // `--workers N` pins a fixed pool; `--workers auto` frees it.
        let mut cfg = RunConfig::default();
        let args = Args::parse("run --workers 6".split_whitespace().map(String::from));
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.cpu_workers, 6);
        assert!(!cfg.workers_auto);
        let args = Args::parse(
            "run --workers auto --workers-min 2 --workers-max 12 --workers-interval 0.1"
                .split_whitespace()
                .map(String::from),
        );
        cfg.apply_args(&args).unwrap();
        assert!(cfg.workers_auto);
        assert_eq!((cfg.workers_min, cfg.workers_max), (2, 12));
        assert_eq!(cfg.workers_interval_secs, 0.1);
        // Garbage worker counts are rejected, not silently defaulted.
        let mut bad = RunConfig::default();
        let args = Args::parse("run --workers many".split_whitespace().map(String::from));
        assert!(bad.apply_args(&args).is_err());
        // Inverted bounds and zero interval fail validation.
        let bad = RunConfig { workers_min: 4, workers_max: 2, ..RunConfig::default() };
        assert!(bad.validate().is_err());
        let bad = RunConfig { workers_min: 0, ..RunConfig::default() };
        assert!(bad.validate().is_err());
        let bad = RunConfig { workers_interval_secs: 0.0, ..RunConfig::default() };
        assert!(bad.validate().is_err());
        // JSON round-trip carries the elastic fields.
        let parsed = Json::parse(&cfg.to_json().dump()).unwrap();
        assert_eq!(parsed.req("workers_auto").as_bool(), Some(true));
        assert_eq!(parsed.req("workers_min").as_usize(), Some(2));
        assert_eq!(parsed.req("workers_max").as_usize(), Some(12));
        assert_eq!(parsed.req("workers_interval_secs").as_f64(), Some(0.1));
    }

    #[test]
    fn unknown_flags_are_rejected() {
        for bad in ["run --workerz 3", "run --tracing on", "run --prep-cache 64"] {
            let mut cfg = RunConfig::default();
            let args = Args::parse(bad.split_whitespace().map(String::from));
            let err = cfg.apply_args(&args).unwrap_err().to_string();
            assert!(err.contains("unknown flag"), "{bad}: {err}");
        }
        // A value-taking key with its value forgotten parses as a bare
        // flag — it must fail loudly, not silently run on the default.
        // `--trace` included: it takes `off` or a path, never stands alone.
        for bad in
            ["run --steps --no-train", "run --workers", "run --seed --ideal", "run --trace"]
        {
            let mut cfg = RunConfig::default();
            let args = Args::parse(bad.split_whitespace().map(String::from));
            let err = cfg.apply_args(&args).unwrap_err().to_string();
            assert!(err.contains("requires a value"), "{bad}: {err}");
        }
        // And the converse: boolean flags take no value.
        let mut cfg = RunConfig::default();
        let args = Args::parse("run --ideal yes".split_whitespace().map(String::from));
        let err = cfg.apply_args(&args).unwrap_err().to_string();
        assert!(err.contains("takes no value"), "{err}");
        // Malformed numeric values fail loudly too — never a silent
        // fallback to the default.
        for bad in [
            "run --workers-max 1O",
            "run --workers-interval 0,5",
            "run --batch x",
            "run --seed 1e3",
        ] {
            let mut cfg = RunConfig::default();
            let args = Args::parse(bad.split_whitespace().map(String::from));
            let err = cfg.apply_args(&args).unwrap_err().to_string();
            assert!(err.contains("expected a number"), "{bad}: {err}");
        }
    }

    /// The help-vs-`apply_args` drift gate: every flag `apply_args`
    /// accepts must be documented in `dpp --help`.  Combined with
    /// `apply_args`' unknown-flag rejection (which forces new keys into
    /// `accepted_flags`), code, flag list, and help cannot diverge.
    #[test]
    fn every_accepted_run_flag_is_documented_in_help() {
        for flag in RunConfig::accepted_flags() {
            // Delimited match: a bare substring would let `--workers` be
            // "documented" by the `--workers-min` line alone.
            let documented = [" ", "]", "\n"]
                .iter()
                .any(|d| crate::CLI_HELP.contains(&format!("--{flag}{d}")));
            assert!(
                documented,
                "--{flag} accepted by apply_args but missing from CLI_HELP"
            );
        }
        // Boolean flags must be registered as accepted too.
        for flag in RunConfig::boolean_flags() {
            assert!(RunConfig::accepted_flags().contains(flag));
        }
        // And every documented run flag parses without an unknown-flag
        // error when given a plausible value (spot checks).
        for (flag, val) in [
            ("workers", "auto"),
            ("workers-min", "1"),
            ("workers-max", "4"),
            ("workers-interval", "0.5"),
            ("queue-depth", "2"),
            ("report-json", "/tmp/r.json"),
        ] {
            let mut cfg = RunConfig::default();
            let args =
                Args::parse(format!("run --{flag} {val}").split_whitespace().map(String::from));
            cfg.apply_args(&args).unwrap_or_else(|e| panic!("--{flag} {val}: {e}"));
        }
    }

    #[test]
    fn trace_flags_parse_validate_and_roundtrip() {
        // Default: tracing fully off, full sampling once enabled.
        let cfg = RunConfig::default();
        assert_eq!(cfg.trace, "off");
        assert_eq!(cfg.trace_sample_rate, 1.0);
        assert!(cfg.validate().is_ok());
        // `--trace PATH` enables the tracer and names the export file.
        let mut cfg = RunConfig::default();
        let args = Args::parse(
            "run --trace /tmp/spans.json --trace-sample-rate 0.25"
                .split_whitespace()
                .map(String::from),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.trace, "/tmp/spans.json");
        assert_eq!(cfg.trace_sample_rate, 0.25);
        // Out-of-range rates fail validation, malformed ones fail parse.
        for bad_rate in [0.0, -0.5, 1.5] {
            let bad =
                RunConfig { trace_sample_rate: bad_rate, ..RunConfig::default() };
            assert!(bad.validate().is_err(), "rate {bad_rate} accepted");
        }
        let bad = RunConfig { trace: String::new(), ..RunConfig::default() };
        assert!(bad.validate().is_err(), "empty trace value accepted");
        let mut bad = RunConfig::default();
        let args = Args::parse(
            "run --trace-sample-rate lots".split_whitespace().map(String::from),
        );
        assert!(bad.apply_args(&args).is_err());
        // JSON round-trip carries both fields.
        let parsed = Json::parse(&cfg.to_json().dump()).unwrap();
        assert_eq!(parsed.req("trace").as_str(), Some("/tmp/spans.json"));
        assert_eq!(parsed.req("trace_sample_rate").as_f64(), Some(0.25));
    }

    #[test]
    fn fault_flags_parse_validate_and_roundtrip() {
        // Defaults: no injection, zero skip tolerance, retry+hedge armed
        // (they only engage when something actually fails).
        let cfg = RunConfig::default();
        assert_eq!(cfg.faults, "off");
        assert_eq!(cfg.max_skip_rate, 0.0);
        assert_eq!(cfg.retries, 3);
        assert!(cfg.hedge);
        assert_eq!(cfg.retry_deadline, 30.0);
        assert!(cfg.validate().is_ok());
        // CLI → config.
        let mut cfg = RunConfig::default();
        let args = Args::parse(
            "run --faults transient=0.01,seed=7 --max-skip-rate 0.02 \
             --retries 5 --hedge off --retry-deadline 10"
                .split_whitespace()
                .map(String::from),
        );
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.faults, "transient=0.01,seed=7");
        assert_eq!(cfg.max_skip_rate, 0.02);
        assert_eq!(cfg.retries, 5);
        assert!(!cfg.hedge);
        assert_eq!(cfg.retry_deadline, 10.0);
        // Bad values fail loudly at apply/validate time.
        let mut bad = RunConfig::default();
        let args =
            Args::parse("run --faults transient=2".split_whitespace().map(String::from));
        assert!(bad.apply_args(&args).is_err(), "rate > 1 accepted");
        let mut bad = RunConfig::default();
        let args = Args::parse("run --faults gremlins=1".split_whitespace().map(String::from));
        assert!(bad.apply_args(&args).is_err(), "unknown fault key accepted");
        let mut bad = RunConfig::default();
        let args = Args::parse("run --hedge maybe".split_whitespace().map(String::from));
        assert!(bad.apply_args(&args).is_err());
        let bad = RunConfig { max_skip_rate: 1.0, ..RunConfig::default() };
        assert!(bad.validate().is_err(), "skip rate 1.0 would allow dropping everything");
        let bad = RunConfig { retry_deadline: 0.0, ..RunConfig::default() };
        assert!(bad.validate().is_err());
        // JSON round-trip carries all five fields.
        let parsed = Json::parse(&cfg.to_json().dump()).unwrap();
        assert_eq!(parsed.req("faults").as_str(), Some("transient=0.01,seed=7"));
        assert_eq!(parsed.req("max_skip_rate").as_f64(), Some(0.02));
        assert_eq!(parsed.req("retries").as_usize(), Some(5));
        assert_eq!(parsed.req("hedge").as_bool(), Some(false));
        assert_eq!(parsed.req("retry_deadline").as_f64(), Some(10.0));
    }

    #[test]
    fn json_dump_contains_axes() {
        let j = RunConfig::default().to_json().dump();
        assert!(j.contains("\"method\":\"record\""));
        assert!(j.contains("\"placement\":\"hybrid\""));
    }

    #[test]
    fn json_roundtrips_remote_fields() {
        use crate::util::json::Json;
        let cfg = RunConfig {
            storage: "s3-cold".into(),
            net_conns: 24,
            readahead_mb: 64,
            ..Default::default()
        };
        let parsed = Json::parse(&cfg.to_json().dump()).unwrap();
        assert_eq!(parsed.req("storage").as_str(), Some("s3-cold"));
        assert_eq!(parsed.req("net_conns").as_usize(), Some(24));
        assert_eq!(parsed.req("readahead_mb").as_usize(), Some(64));
    }
}
