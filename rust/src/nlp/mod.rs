//! NLP preprocessing pipeline — the paper's §5 future work ("we will
//! extend our performance analysis to both NLP and video processing
//! models"), implemented as a second front-end over the same substrates:
//! the record format, storage backends, shuffle buffer and batcher are
//! shared; only the per-sample CPU stage differs (tokenize+encode+pad
//! instead of decode+augment).
//!
//! Pipeline: raw text / record shards → normalize (lowercase, strip
//! punctuation) → tokenize (whitespace) → vocabulary lookup → pad or
//! truncate to a fixed length → `[B, L]` i32 batches.

use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::collections::HashMap;

pub const PAD_ID: i32 = 0;
pub const UNK_ID: i32 = 1;
pub const FIRST_WORD_ID: i32 = 2;

/// Normalization: lowercase, keep alphanumerics, everything else → space.
pub fn normalize(text: &str) -> String {
    text.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() {
                c.to_ascii_lowercase()
            } else {
                ' '
            }
        })
        .collect()
}

/// Whitespace tokenizer over normalized text.
pub fn tokenize(text: &str) -> Vec<&str> {
    text.split_whitespace().collect()
}

/// Frequency-built vocabulary with a max size; ties broken alphabetically
/// so builds are deterministic.
#[derive(Clone, Debug)]
pub struct Vocab {
    map: HashMap<String, i32>,
    pub size: usize,
}

impl Vocab {
    pub fn build<'a>(docs: impl IntoIterator<Item = &'a str>, max_words: usize) -> Vocab {
        let mut freq: HashMap<String, u64> = HashMap::new();
        for d in docs {
            let norm = normalize(d);
            for t in tokenize(&norm) {
                *freq.entry(t.to_string()).or_default() += 1;
            }
        }
        let mut words: Vec<(String, u64)> = freq.into_iter().collect();
        words.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        words.truncate(max_words);
        let mut map = HashMap::new();
        for (i, (w, _)) in words.into_iter().enumerate() {
            map.insert(w, FIRST_WORD_ID + i as i32);
        }
        let size = map.len() + 2; // + PAD, UNK
        Vocab { map, size }
    }

    pub fn id(&self, token: &str) -> i32 {
        *self.map.get(token).unwrap_or(&UNK_ID)
    }

    /// Encode a document: normalize → tokenize → ids, padded/truncated to
    /// `seq_len` (the NLP analogue of crop+resize to a fixed shape).
    pub fn encode(&self, text: &str, seq_len: usize) -> Vec<i32> {
        let norm = normalize(text);
        let mut ids: Vec<i32> =
            tokenize(&norm).into_iter().take(seq_len).map(|t| self.id(t)).collect();
        ids.resize(seq_len, PAD_ID);
        ids
    }
}

/// Collate encoded sequences into a `[B, L]` row-major batch.
pub fn collate_text(seqs: Vec<Vec<i32>>, labels: Vec<i32>) -> Result<(Vec<i32>, Vec<i32>)> {
    ensure!(!seqs.is_empty(), "empty text batch");
    let l = seqs[0].len();
    ensure!(seqs.iter().all(|s| s.len() == l), "ragged batch");
    ensure!(seqs.len() == labels.len(), "labels/seqs length mismatch");
    let mut flat = Vec::with_capacity(seqs.len() * l);
    for s in seqs {
        flat.extend_from_slice(&s);
    }
    Ok((flat, labels))
}

/// Synthetic labeled text corpus: each class has a signature word
/// distribution (topic words occur far more often), so classes are
/// learnable — mirrors dataset::gen_image.
pub fn gen_document(rng: &mut Rng, class: u16, words: usize) -> String {
    const TOPICS: [&[&str]; 4] = [
        &["storage", "disk", "bandwidth", "iops", "ebs"],
        &["gpu", "kernel", "tensor", "cuda", "batch"],
        &["cache", "memory", "dram", "latency", "hit"],
        &["decode", "image", "crop", "resize", "flip"],
    ];
    const COMMON: &[&str] =
        &["the", "a", "of", "and", "to", "in", "is", "for", "with", "on", "at", "we"];
    let topic = TOPICS[class as usize % TOPICS.len()];
    let mut out = String::new();
    for i in 0..words {
        if i > 0 {
            out.push(' ');
        }
        // Class-dependent mix: 40% topic words (+ class-salted suffix word).
        if rng.f64() < 0.4 {
            out.push_str(topic[rng.gen_range(topic.len() as u64) as usize]);
            if rng.f64() < 0.3 {
                out.push_str(&format!(" c{class}"));
            }
        } else {
            out.push_str(COMMON[rng.gen_range(COMMON.len() as u64) as usize]);
        }
    }
    out
}

/// Per-sample CPU stage timing hooks, mirroring ops::* for the Fig. 3
/// style breakdown of the text pipeline.
pub struct TextStageTimes {
    pub normalize_ns: u64,
    pub tokenize_ns: u64,
    pub encode_ns: u64,
}

pub fn timed_encode(vocab: &Vocab, text: &str, seq_len: usize) -> (Vec<i32>, TextStageTimes) {
    let t0 = std::time::Instant::now();
    let norm = normalize(text);
    let t1 = std::time::Instant::now();
    let toks = tokenize(&norm);
    let t2 = std::time::Instant::now();
    let mut ids: Vec<i32> = toks.into_iter().take(seq_len).map(|t| vocab.id(t)).collect();
    ids.resize(seq_len, PAD_ID);
    let t3 = std::time::Instant::now();
    (
        ids,
        TextStageTimes {
            normalize_ns: (t1 - t0).as_nanos() as u64,
            tokenize_ns: (t2 - t1).as_nanos() as u64,
            encode_ns: (t3 - t2).as_nanos() as u64,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_and_tokenize() {
        let n = normalize("Hello, GPU-World!  42x");
        assert_eq!(n, "hello  gpu world   42x");
        assert_eq!(tokenize(&n), vec!["hello", "gpu", "world", "42x"]);
    }

    #[test]
    fn vocab_build_deterministic_and_frequency_ordered() {
        let docs = ["b b b a a c", "a b"];
        let v = Vocab::build(docs.iter().copied(), 10);
        // b (4) before a (3) before c (1).
        assert_eq!(v.id("b"), FIRST_WORD_ID);
        assert_eq!(v.id("a"), FIRST_WORD_ID + 1);
        assert_eq!(v.id("c"), FIRST_WORD_ID + 2);
        assert_eq!(v.id("zzz"), UNK_ID);
        assert_eq!(v.size, 5);
        let v2 = Vocab::build(docs.iter().copied(), 10);
        assert_eq!(v2.id("c"), v.id("c"));
    }

    #[test]
    fn vocab_max_words_truncates() {
        let v = Vocab::build(["a a a b b c"].into_iter(), 2);
        assert_ne!(v.id("a"), UNK_ID);
        assert_ne!(v.id("b"), UNK_ID);
        assert_eq!(v.id("c"), UNK_ID);
    }

    #[test]
    fn encode_pads_and_truncates() {
        let v = Vocab::build(["alpha beta gamma"].into_iter(), 10);
        let short = v.encode("alpha beta", 5);
        assert_eq!(short.len(), 5);
        assert_eq!(&short[2..], &[PAD_ID; 3]);
        let long = v.encode("alpha beta gamma alpha beta gamma", 4);
        assert_eq!(long.len(), 4);
        assert!(long.iter().all(|&id| id != PAD_ID));
    }

    #[test]
    fn collate_checks_shapes() {
        let (flat, labels) =
            collate_text(vec![vec![1, 2], vec![3, 4]], vec![0, 1]).unwrap();
        assert_eq!(flat, vec![1, 2, 3, 4]);
        assert_eq!(labels, vec![0, 1]);
        assert!(collate_text(vec![vec![1], vec![2, 3]], vec![0, 1]).is_err());
        assert!(collate_text(vec![], vec![]).is_err());
    }

    #[test]
    fn documents_are_class_separable() {
        let mut rng = Rng::new(3);
        let a1 = gen_document(&mut rng, 0, 200);
        let a2 = gen_document(&mut rng, 0, 200);
        let b = gen_document(&mut rng, 1, 200);
        let overlap = |x: &str, y: &str| {
            let xs: std::collections::HashSet<&str> = tokenize(x).into_iter().collect();
            let ys: std::collections::HashSet<&str> = tokenize(y).into_iter().collect();
            xs.intersection(&ys).count()
        };
        assert!(overlap(&a1, &a2) > overlap(&a1, &b));
    }

    #[test]
    fn timed_encode_matches_encode() {
        let v = Vocab::build(["x y z"].into_iter(), 10);
        let (ids, t) = timed_encode(&v, "x q z", 4);
        assert_eq!(ids, v.encode("x q z", 4));
        assert!(t.normalize_ns > 0 || t.tokenize_ns > 0 || t.encode_ns > 0);
    }
}
