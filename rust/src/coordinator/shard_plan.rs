//! Leader-side shard planning for multi-worker (data-parallel) ingestion.
//!
//! The paper's pipeline runs per training node; at multiple nodes the
//! record shards must be partitioned so every worker streams a disjoint,
//! size-balanced subset per epoch (and rotation across epochs so every
//! worker eventually sees all data — the MXNet/DALI convention).
//!
//! Balancing is greedy LPT (longest-processing-time first) over shard
//! byte sizes, which is within 4/3 of optimal makespan.

use anyhow::{ensure, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct ShardAssignment {
    pub worker: usize,
    pub shards: Vec<String>,
    pub bytes: u64,
}

/// Partition `shards` (name, bytes) across `workers`, balancing bytes.
pub fn plan(shards: &[(String, u64)], workers: usize) -> Result<Vec<ShardAssignment>> {
    ensure!(workers >= 1, "need at least one worker");
    ensure!(
        shards.len() >= workers,
        "cannot split {} shards across {workers} workers — reshard the dataset",
        shards.len()
    );
    let mut sorted: Vec<(String, u64)> = shards.to_vec();
    sorted.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let mut out: Vec<ShardAssignment> = (0..workers)
        .map(|w| ShardAssignment { worker: w, shards: Vec::new(), bytes: 0 })
        .collect();
    for (name, bytes) in sorted {
        let tgt = out.iter_mut().min_by_key(|a| (a.bytes, a.worker)).unwrap();
        tgt.shards.push(name);
        tgt.bytes += bytes;
    }
    Ok(out)
}

/// Rotate a plan for `epoch`: worker w takes the assignment of
/// `(w + epoch) % workers`, so every worker cycles through all subsets.
pub fn rotate(plan: &[ShardAssignment], epoch: u64) -> Vec<ShardAssignment> {
    let n = plan.len();
    (0..n)
        .map(|w| ShardAssignment {
            worker: w,
            shards: plan[(w + epoch as usize) % n].shards.clone(),
            bytes: plan[(w + epoch as usize) % n].bytes,
        })
        .collect()
}

/// Max/min byte imbalance of a plan (1.0 = perfectly balanced).
pub fn imbalance(plan: &[ShardAssignment]) -> f64 {
    let max = plan.iter().map(|a| a.bytes).max().unwrap_or(0) as f64;
    let min = plan.iter().map(|a| a.bytes).min().unwrap_or(0).max(1) as f64;
    max / min
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, PropConfig};
    use crate::util::rng::Rng;

    fn mk(n: usize, seed: u64) -> Vec<(String, u64)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| (format!("records/shard-{i:05}.rec"), 1_000_000 + rng.gen_range(9_000_000)))
            .collect()
    }

    #[test]
    fn covers_all_shards_disjointly() {
        let shards = mk(13, 1);
        let plan = plan(&shards, 4).unwrap();
        let mut seen: Vec<&str> = plan.iter().flat_map(|a| a.shards.iter().map(|s| s.as_str())).collect();
        seen.sort();
        let mut want: Vec<&str> = shards.iter().map(|(n, _)| n.as_str()).collect();
        want.sort();
        assert_eq!(seen, want);
    }

    #[test]
    fn balanced_within_lpt_bound() {
        let shards = mk(40, 2);
        let p = plan(&shards, 8).unwrap();
        assert!(imbalance(&p) < 1.5, "imbalance {}", imbalance(&p));
    }

    #[test]
    fn rejects_more_workers_than_shards() {
        assert!(plan(&mk(3, 3), 4).is_err());
        assert!(plan(&mk(3, 3), 0).is_err());
    }

    #[test]
    fn rotation_cycles_assignments() {
        let shards = mk(9, 4);
        let p = plan(&shards, 3).unwrap();
        let e1 = rotate(&p, 1);
        assert_eq!(e1[0].shards, p[1].shards);
        assert_eq!(e1[2].shards, p[0].shards);
        // Full cycle returns to the original.
        let e3 = rotate(&p, 3);
        assert_eq!(e3, p);
    }

    #[test]
    fn prop_every_worker_sees_every_shard_across_a_cycle() {
        check(
            "rotation-coverage",
            PropConfig { cases: 25, ..Default::default() },
            |rng, size| {
                let workers = 1 + rng.gen_range(6) as usize;
                let shards = workers + rng.gen_range(3 * size as u64 + 1) as usize;
                (workers, shards, rng.next_u64())
            },
            |&(workers, nshards, seed)| {
                let shards = mk(nshards, seed);
                let p = plan(&shards, workers).unwrap();
                // Over `workers` epochs, worker 0 must see every shard.
                let mut seen: Vec<String> = (0..workers as u64)
                    .flat_map(|e| rotate(&p, e)[0].shards.clone())
                    .collect();
                seen.sort();
                let mut want: Vec<String> = shards.iter().map(|(n, _)| n.clone()).collect();
                want.sort();
                seen == want
            },
        );
    }
}
