//! The run coordinator: builds the whole pipeline from a `RunConfig`,
//! spawns the stage threads, runs one epoch (or `steps` train steps), and
//! assembles the `RunReport`.  This is the L3 entry point used by the CLI,
//! the examples and the benches.

pub mod runner;
pub mod shard_plan;

pub use runner::{prepare_data, run, DataLayout};
