//! Pipeline assembly and execution.

use crate::config::{Method, Placement, RunConfig};
use crate::dataset::{self, GenConfig, MetaEntry};
use crate::metrics::trace::{self, Stage, StallAttribution, Tracer};
use crate::metrics::{BusyClock, Counters, EpochClock, RunReport, ScaleHist, UtilSampler};
use crate::ops::sample_aug_params;
use crate::pipeline::channel::{bounded_traced, Receiver};
use crate::pipeline::exec::{self, ExecConfig, PanicGuard};
use crate::pipeline::prep_cache::PrepCache;
use crate::pipeline::quarantine::Quarantine;
use crate::pipeline::shuffle::ShuffleBuffer;
use crate::pipeline::source::{list_shards, stream_shards_resilient, WorkItem};
use crate::pipeline::{collate, Batch, Payload, Sample, StageCtx, StageScratch};
use crate::runtime::{lit_f32, Engine};
use crate::storage::prefetch::Resilience;
use crate::storage::retry::with_retry;
use crate::storage::{
    CachedStore, DirStore, FaultProfile, FaultyStore, MemStore, NetProfile, PrefetchPlan,
    RemoteStore, RetryPolicy, RetryStats, Storage, StorageProfile, ThrottledStore,
};
use crate::trainer::TrainSession;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Where a corpus lives on disk after `prepare_data`.
#[derive(Clone, Debug)]
pub struct DataLayout {
    pub entries: Vec<MetaEntry>,
    pub shards: Vec<String>,
}

/// Generate the synthetic corpus + record shards under `dir` (idempotent:
/// re-generates only when missing).  The offline phase of the paper.
pub fn prepare_data(dir: &std::path::Path, gen: &GenConfig, n_shards: usize) -> Result<DataLayout> {
    let store = DirStore::new(dir)?;
    let entries = if dir.join(dataset::META_FILE).exists() {
        dataset::parse_metadata(std::str::from_utf8(&store.read(dataset::META_FILE)?)?)?
    } else {
        dataset::generate_raw(&store, gen)?
    };
    let rec_dir = dir.join("records");
    let shards = if rec_dir.exists() {
        list_shards(&store, "records/")?
    } else {
        dataset::build_records(&store, &entries, &rec_dir, n_shards)?
            .into_iter()
            .map(|s| format!("records/{s}"))
            .collect()
    };
    Ok(DataLayout { entries, shards })
}

/// The assembled storage stack, plus a concrete handle onto the remote
/// layer (when one exists) so the run report can surface its telemetry.
struct StorageStack {
    store: Arc<dyn Storage>,
    remote: Option<Arc<RemoteStore<DirStore>>>,
    /// Fault-injection layer (when `--faults` is active), kept concrete
    /// so the run report can read its injection counters.
    faults: Option<Arc<FaultyStore<Arc<dyn Storage>>>>,
}

fn build_storage(cfg: &RunConfig) -> Result<StorageStack> {
    let base = DirStore::new(&cfg.data_dir)?;
    let mut remote = None;
    let store: Arc<dyn Storage> = match cfg.storage.as_str() {
        "local" => Arc::new(base),
        "dram" => Arc::new(MemStore::preload_from(&base)?),
        name => {
            if let Some(net) = NetProfile::by_name(name) {
                // Remote object-store tier: latency/connection emulation.
                let r = Arc::new(RemoteStore::with_time_scale(base, net, cfg.time_scale));
                remote = Some(r.clone());
                r
            } else {
                let prof = StorageProfile::by_name(name)
                    .with_context(|| format!("unknown storage {name}"))?;
                Arc::new(ThrottledStore::with_time_scale(base, prof, cfg.time_scale))
            }
        }
    };
    // The fault layer wraps the tier itself, *beneath* the cache: a
    // cache hit never touched the (faulty) device, so it must not draw
    // a fault — exactly like a real SSD cache in front of flaky S3.
    let mut faults = None;
    let store: Arc<dyn Storage> = if let Some(profile) = FaultProfile::parse(&cfg.faults)? {
        let f = Arc::new(FaultyStore::new(store, profile));
        faults = Some(f.clone());
        f
    } else {
        store
    };
    let store = if cfg.cache_mb > 0 {
        Arc::new(CachedStore::new(store, cfg.cache_mb << 20)) as Arc<dyn Storage>
    } else {
        store
    };
    Ok(StorageStack { store, remote, faults })
}

/// Run the full pipeline per the config; returns the run report.
pub fn run(cfg: &RunConfig) -> Result<RunReport> {
    cfg.validate()?;
    // Pin the kernel dispatch tier for every worker this run spawns.
    // Safe even if runs overlap in one process: all tiers are
    // bit-identical, so a racing mode switch can change speed only.
    crate::simd::set_mode(cfg.simd);
    let StorageStack { store: storage, remote, faults } = build_storage(cfg)?;
    // Fault tolerance: one retry policy for every storage read — the
    // metadata read below included, since it goes through the (possibly
    // faulty) tier too — and one quarantine bounding how many
    // undecodable samples the whole run may skip before failing loudly.
    let retry_policy = if cfg.retries > 0 {
        RetryPolicy::with_retries(cfg.retries, cfg.retry_deadline, cfg.seed)
    } else {
        RetryPolicy::none()
    };
    let retry_stats = Arc::new(RetryStats::default());
    let meta = dataset::parse_metadata(std::str::from_utf8(&with_retry(
        &retry_policy,
        &retry_stats,
        0,
        || storage.read(dataset::META_FILE),
    )?)?)?;
    ensure!(!meta.is_empty(), "empty dataset at {:?}", cfg.data_dir);

    let counters = Arc::new(Counters::default());
    // The skip budget is windowed per epoch (one dataset pass), reset on
    // epoch boundaries by the source thread — a whole-run budget scales
    // with the epoch count, which is unbounded in serve mode.
    let quarantine = Arc::new(Quarantine::new(cfg.max_skip_rate, meta.len() as u64));
    // The elastic executor owns the pool geometry; a live-denominator
    // clock keeps cpu_util honest while the pool resizes.
    let exec_cfg = ExecConfig::from_run_config(cfg);
    let cpu_clock = if exec_cfg.auto {
        BusyClock::new_live(exec_cfg.workers_initial)
    } else {
        BusyClock::new(exec_cfg.workers_initial)
    };
    let dev_clock = BusyClock::new(1);
    let epoch_clock = EpochClock::new();
    let scale_hist = Arc::new(ScaleHist::default());
    // Decoded-sample cache, shared across CPU workers and epochs: epoch
    // N+1 skips read+decode for resident samples (augmentation stays
    // fresh per epoch — only decode is amortized).
    let prep_cache = (cfg.prep_cache_mb > 0)
        .then(|| Arc::new(PrepCache::new(cfg.prep_cache_mb << 20, cfg.prep_cache_policy)));

    // Zero-copy hot path (`--slab-pool`, cpu placement): workers write
    // augmented output straight into pooled batch-slab slots, the
    // batcher seals instead of memcpying, drained batches recycle their
    // arena via RAII.  Device placements keep their payload hand-offs.
    let out_hw = 56; // manifest.out_hw; validated on the device side
    let slab_pool = (cfg.placement == Placement::Cpu && cfg.slab_pool.enabled()).then(|| {
        crate::util::slab::SlabPool::new(
            3 * out_hw * out_hw,
            cfg.batch_size,
            cfg.slab_pool.free_cap(cfg.queue_depth),
        )
    });
    let alloc0 = crate::util::alloc_count::snapshot();

    // Span tracer: a disabled tracer is a single `None` branch per
    // would-be span, so untraced runs pay nothing.  One instance
    // threads through the channels, the prefetch workers, the stage
    // closure, the batcher, and the device loop, so `drain()` at the
    // end sees every per-thread track on one timeline.
    let tracer = if cfg.trace == "off" {
        Tracer::off()
    } else {
        Tracer::new(cfg.trace_sample_rate)
    };

    // Queue bounds: the executor derives the work-queue capacity from
    // `workers_max` (a live worker count would go stale under
    // autoscaling); the sample/batch queues stay sized by prefetch depth.
    let (work_tx, work_rx) = bounded_traced::<WorkItem>(
        exec_cfg.work_queue_cap(cfg.batch_size),
        tracer.clone(),
        Stage::WorkSendWait,
        Stage::WorkRecvWait,
    );
    let (sample_tx, sample_rx) = bounded_traced::<Sample>(
        cfg.queue_depth * cfg.batch_size,
        tracer.clone(),
        Stage::SampleSendWait,
        Stage::SampleRecvWait,
    );
    let (batch_tx, batch_rx) = bounded_traced::<Batch>(
        cfg.queue_depth.max(1),
        tracer.clone(),
        Stage::BatchSendWait,
        Stage::BatchRecvWait,
    );
    let (work_probe, sample_probe, batch_probe) =
        (work_rx.probe(), sample_rx.probe(), batch_rx.probe());

    let t0 = Instant::now();
    let mut threads: Vec<std::thread::JoinHandle<Result<()>>> = Vec::new();

    // ---- source ---------------------------------------------------------
    {
        let cfg = cfg.clone();
        let storage = storage.clone();
        let meta = meta.clone();
        let counters = counters.clone();
        let tracer = tracer.clone();
        let res = Resilience::new(retry_policy, cfg.hedge, retry_stats.clone());
        let quarantine = quarantine.clone();
        threads.push(std::thread::Builder::new().name("source".into()).spawn(move || {
            'epochs: for epoch in 0..cfg.epochs as u64 {
                if epoch > 0 {
                    // Fresh per-epoch skip budget; workers draining the
                    // previous epoch's tail make this approximate by one
                    // in-flight sample each (see Quarantine docs).
                    quarantine.advance_window();
                }
                match cfg.method {
                    Method::Raw => {
                        let sampler = dataset::EpochSampler::new(
                            meta.iter().map(|e| e.id).collect(),
                            cfg.batch_size * 4,
                            cfg.seed,
                        );
                        for id in sampler.epoch_order(epoch) {
                            let e = &meta[id as usize];
                            let item = WorkItem::RawRef {
                                id: e.id,
                                label: e.label,
                                epoch,
                                path: e.path.clone(),
                            };
                            if work_tx.send(item).is_err() {
                                break 'epochs; // downstream hit its budget
                            }
                        }
                    }
                    Method::Record => {
                        let mut shards = list_shards(storage.as_ref(), "records/")?;
                        ensure!(!shards.is_empty(), "no record shards under {:?}", cfg.data_dir);
                        let mut rng = Rng::new(cfg.seed).fork(epoch);
                        rng.shuffle(&mut shards);
                        let mut sb = ShuffleBuffer::new(cfg.shuffle_buffer, rng.fork(1));
                        let mut open = true;
                        // Parallel range-GETs only pay off where latency
                        // overlaps — the remote tiers.  Local tiers
                        // serialize in one token bucket, so extra
                        // connections would be pure thread overhead.
                        let plan = if let Some(net) = NetProfile::by_name(&cfg.storage) {
                            // Clamp to the pool size: beyond it, extra
                            // worker threads would only queue on the
                            // connection semaphore (the sim clamps the
                            // same way).
                            PrefetchPlan::new(
                                cfg.net_conns.min(net.max_conns),
                                cfg.record_chunk,
                                cfg.readahead_mb << 20,
                            )
                        } else {
                            PrefetchPlan::serial(cfg.record_chunk)
                        };
                        stream_shards_resilient(storage.clone(), &shards, cfg.record_chunk, plan, tracer.clone(), res.clone(), |id, e| {
                            // A record whose payload arrived corrupt
                            // (bit flip survived the fetch) is skipped
                            // under the quarantine budget instead of
                            // wedging the shard stream.
                            quarantine.admit(format!("record {id} (epoch {epoch})"), e)
                        }, |rec| {
                            // Counted at the actual storage read (the
                            // record just left the shard stream) — the
                            // raw path's counterpart lives at the worker
                            // read; parity is tested in pipeline_e2e.
                            counters.images_read(1);
                            if let Some(evicted) = sb.push(rec) {
                                let item = WorkItem::Bytes {
                                    id: evicted.id,
                                    label: evicted.label,
                                    epoch,
                                    payload: evicted.payload,
                                };
                                if work_tx.send(item).is_err() {
                                    open = false;
                                    return Ok(false);
                                }
                            }
                            Ok(true)
                        })?;
                        if open {
                            for rec in sb.drain() {
                                let item = WorkItem::Bytes {
                                    id: rec.id,
                                    label: rec.label,
                                    epoch,
                                    payload: rec.payload,
                                };
                                if work_tx.send(item).is_err() {
                                    break 'epochs;
                                }
                            }
                        } else {
                            break 'epochs;
                        }
                    }
                }
            }
            Ok(())
        })?);
    }

    // ---- cpu workers (elastic pool) ---------------------------------------
    // One stage closure runs the unified per-sample chain; the executor
    // owns the threads, the park/unpark gate, the per-worker scratch
    // lifecycle (parked workers release theirs), and — under `--workers
    // auto` — the feedback controller that resizes the pool.
    let pool = {
        let storage = storage.clone();
        let counters = counters.clone();
        let retry_stats = retry_stats.clone();
        let quarantine = quarantine.clone();
        // One shared clock: the stage closure tracks busy time on it,
        // the executor's controller resizes its live denominator.
        let stage_clock = cpu_clock.clone();
        let epoch_clock = epoch_clock.clone();
        let scale_hist = scale_hist.clone();
        let ctx = StageCtx::from_config(cfg, prep_cache.clone(), out_hw).with_tracer(tracer.clone());
        let slab = slab_pool.clone();
        // The closure lives in every pool worker for the whole run:
        // capture only the two scalars it needs, not a RunConfig clone.
        let seed = cfg.seed;
        let stage = move |scratch: &mut StageScratch, item: WorkItem| -> Result<Option<Sample>> {
            let (id, label, epoch) = (item.id(), item.label(), item.epoch());
            ctx.tracer.set_epoch(epoch);
            // The aug stream forks on (id, epoch): a prep-cache hit in
            // epoch N+1 samples *fresh* params, and hit/miss paths draw
            // identical params for the same sample.
            let mut rng = Rng::new(seed ^ 0x5EED).fork(id).fork(epoch);

            // Hit: skip the raw read (raw method) and the decode.
            if let Some(sample) = ctx.prep_cache.as_ref().and_then(|c| c.get(id)) {
                // Params are sampled against the *original* dims, so
                // the aug stream is the same whether the resident
                // pixels are full-res or fractionally scaled.
                let aug = sample_aug_params(
                    &mut rng,
                    sample.orig_h() as u32,
                    sample.orig_w() as u32,
                );
                let payload = if let Some(pool) = &slab {
                    // Zero-copy hit: the single write is the augmented
                    // sample into its batch slot.
                    let mut slice = pool.slice();
                    stage_clock.track(|| {
                        ctx.run_stage_cached_into(&sample, aug, scratch, slice.as_mut_slice())
                    });
                    Payload::Slot(slice)
                } else {
                    stage_clock.track(|| ctx.run_stage_cached(&sample, aug))
                };
                counters.decode_skipped(1);
                counters.images_decoded(1);
                if matches!(ctx.placement, Placement::Cpu) {
                    counters.images_augmented(1);
                }
                epoch_clock.mark(epoch as usize);
                return Ok(Some(Sample { id, label, payload }));
            }

            // Keep whichever buffer the arm produced — both views
            // borrow it as &[u8] with no copy.
            let (raw_buf, rec_buf);
            let bytes: &[u8] = match item {
                WorkItem::RawRef { path, .. } => {
                    let span = ctx.tracer.start();
                    // Transient storage faults retry with backoff under
                    // the per-request deadline; a read that exhausts its
                    // attempts is quarantined (skip-budget permitting)
                    // rather than killing the worker.
                    raw_buf = match with_retry(&retry_policy, &retry_stats, id, || {
                        storage.read(&path)
                    }) {
                        Ok(buf) => buf,
                        Err(e) => {
                            quarantine.admit(format!("raw {path}"), e)?;
                            return Ok(None);
                        }
                    };
                    ctx.tracer.record(Stage::Fetch, id, span);
                    // `images_read` counts at the actual storage read on
                    // both paths: here for raw (a prep-cache hit above
                    // never touches storage), and in the source's stream
                    // callback for records (shards stream regardless of
                    // residency).  Raw-vs-record parity over a full
                    // epoch is asserted in tests/pipeline_e2e.rs.
                    counters.images_read(1);
                    &raw_buf
                }
                WorkItem::Bytes { payload, .. } => {
                    rec_buf = payload;
                    &rec_buf
                }
            };
            // This probe is a few-byte header parse; run_stage re-probes
            // internally — the accepted price of keeping the chain at
            // two public entry points (no pre-probed variant).  An
            // undecodable payload (corrupt header or pixel data) is
            // quarantined under the skip budget, not a worker error.
            let probed = crate::codec::probe(bytes).and_then(|(c, h, wid, _q)| {
                ensure!(c == 3, "expected RGB, got {c} channels");
                Ok((h, wid))
            });
            let (h, wid) = match probed {
                Ok(dims) => dims,
                Err(e) => {
                    quarantine.admit(format!("sample {id} (epoch {epoch})"), e)?;
                    return Ok(None);
                }
            };
            let aug = sample_aug_params(&mut rng, h as u32, wid as u32);
            let staged = if let Some(pool) = &slab {
                // Zero-copy miss: decode into worker scratch, augment
                // into the batch slot — no per-sample allocation.  An
                // error drops `slice` unfilled; its slab recycles once
                // the remaining slices drop.
                let mut slice = pool.slice();
                stage_clock
                    .track(|| ctx.run_stage_into(bytes, id, aug, scratch, slice.as_mut_slice()))
                    .map(|dstats| (Payload::Slot(slice), dstats))
            } else {
                stage_clock.track(|| ctx.run_stage(bytes, id, aug))
            };
            let (payload, dstats) = match staged {
                Ok(out) => out,
                Err(e) => {
                    quarantine.admit(format!("sample {id} (epoch {epoch})"), e)?;
                    return Ok(None);
                }
            };
            counters.idct_blocks(dstats.blocks_idct);
            counters.idct_blocks_skipped(dstats.blocks_skipped);
            // Only decodes that ran a CPU transform enter the scale
            // histogram — the hybrid entropy-only path decodes
            // nothing here, and counting it as "full resolution"
            // would corrupt the realized-scale readout DESIGN.md
            // tells users to feed back into the sim.
            if dstats.blocks_idct > 0 {
                scale_hist.record(dstats.scale_log2);
            }
            counters.images_decoded(1);
            if matches!(ctx.placement, Placement::Cpu) {
                counters.images_augmented(1);
            }
            epoch_clock.mark(epoch as usize);
            Ok(Some(Sample { id, label, payload }))
        };
        // A panicking transform poisons just that item: the worker's
        // scratch is rebuilt in place and the panic is charged to the
        // same skip budget as any other undecodable sample.
        let guard: PanicGuard = {
            let quarantine = quarantine.clone();
            Arc::new(move |msg: String| {
                quarantine
                    .admit(format!("worker panic: {msg}"), anyhow::anyhow!("worker panicked: {msg}"))
            })
        };
        exec::spawn_guarded(
            exec_cfg,
            work_rx,
            sample_tx,
            cpu_clock.clone(),
            StageScratch::new,
            stage,
            Some(guard),
        )?
    };

    // ---- batcher ----------------------------------------------------------
    {
        let b = cfg.batch_size;
        let counters = counters.clone();
        let tracer = tracer.clone();
        threads.push(std::thread::Builder::new().name("batcher".into()).spawn(move || {
            // Collate spans carry a running batch index as their sample.
            let mut built = 0u64;
            // One accumulator per payload kind: under the hybrid placement
            // a prep-cache hit re-enters as a pixel payload, so the sample
            // stream can interleave kinds while every collated batch must
            // stay homogeneous.  Single-kind runs behave exactly as before.
            fn kind(p: &Payload) -> usize {
                match p {
                    Payload::Ready(_) => 0,
                    Payload::Coefs { .. } => 1,
                    Payload::Pixels { .. } => 2,
                    // Slot samples are routed to the slab groups before
                    // this accumulator path ever sees them.
                    Payload::Slot(_) => unreachable!("slot samples group by slab"),
                }
            }
            let mut accs: [Vec<Sample>; 3] = Default::default();
            // Slab-slot samples group by slab generation: with several
            // workers in flight, slices of consecutive slabs interleave
            // in the sample stream, and a batch must be exactly one
            // fully-filled slab for the zero-copy seal.
            let mut slabs: std::collections::HashMap<u64, Vec<Sample>> =
                std::collections::HashMap::new();
            while let Some(s) = sample_rx.recv() {
                if let Payload::Slot(ref sl) = s.payload {
                    let seq = sl.slab_seq();
                    let acc = slabs.entry(seq).or_insert_with(|| Vec::with_capacity(b));
                    acc.push(s);
                    if acc.len() == b {
                        let group = slabs.remove(&seq).expect("group just filled");
                        let span = tracer.start();
                        let batch = collate(group)
                            .map_err(|_| anyhow::anyhow!("slab batch failed to seal"))?;
                        tracer.record(Stage::Collate, built, span);
                        built += 1;
                        counters.batches_built(1);
                        if batch_tx.send(batch).is_err() {
                            return Ok(());
                        }
                    }
                    continue;
                }
                let k = kind(&s.payload);
                accs[k].push(s);
                if accs[k].len() == b {
                    let span = tracer.start();
                    let batch = collate(std::mem::take(&mut accs[k]))
                        .map_err(|_| anyhow::anyhow!("mixed payload kinds in batch"))?;
                    tracer.record(Stage::Collate, built, span);
                    built += 1;
                    counters.batches_built(1);
                    if batch_tx.send(batch).is_err() {
                        return Ok(());
                    }
                }
            }
            // Partial trailing batches are dropped (standard drop_last=True)
            // — a trailing partial slab recycles once its slices drop.
            Ok(())
        })?);
    }

    // ---- utilization sampler ---------------------------------------------
    let stop = Arc::new(AtomicBool::new(false));
    let util = Arc::new(Mutex::new(UtilSampler::new()));
    // Queue-depth series for the trace export's counter tracks:
    // `(t_secs, depth)` per queue, sampled on the same thread.  Depth
    // counters are what make stalls legible in the viewer — a span says
    // a worker waited, the counter says which queue ran dry or full.
    let queue_series: Arc<Mutex<[Vec<(f64, f64)>; 3]>> = Arc::new(Mutex::new(Default::default()));
    if cfg.sample_period > 0.0 || tracer.is_on() {
        let stop = stop.clone();
        let util = util.clone();
        let cpu_clock = cpu_clock.clone();
        let dev_clock = dev_clock.clone();
        let storage = storage.clone();
        let sample_util = cfg.sample_period > 0.0;
        let period = if sample_util { cfg.sample_period } else { 0.05 };
        let trace_on = tracer.is_on();
        let probes = (work_probe.clone(), sample_probe.clone(), batch_probe.clone());
        let series = queue_series.clone();
        std::thread::Builder::new().name("sampler".into()).spawn(move || {
            // ordering: Relaxed — shutdown flag polled once per sample
            // period; the sampler carries no data dependent on it, so
            // observing the store one sleep late is harmless.
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_secs_f64(period));
                if sample_util {
                    // poison: the sampler owns both locks' panic surface —
                    // only Vec pushes and float math run under them (here
                    // and in the drains below), neither can panic.
                    util.lock().unwrap().sample(&cpu_clock, &dev_clock, storage.stats().0);
                }
                if trace_on {
                    let t = t0.elapsed().as_secs_f64();
                    // poison: see above — Vec pushes only.
                    let mut s = series.lock().unwrap();
                    s[0].push((t, probes.0.stats().len as f64));
                    s[1].push((t, probes.1.stats().len as f64));
                    s[2].push((t, probes.2.stats().len as f64));
                }
            }
        })?;
    }

    // ---- device thread (runs inline on this thread) -----------------------
    let device_out = device_loop(cfg, batch_rx, &dev_clock, &counters, &tracer)?;
    // ordering: Relaxed — the sampler only polls this flag (see above);
    // no memory is published through it, so no Release edge is needed.
    stop.store(true, Ordering::Relaxed);

    for t in threads {
        match t.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                // Source/worker errors after device stop are expected closes.
                if !device_out.finished_early {
                    return Err(e);
                }
            }
            Err(_) => bail!("pipeline thread panicked"),
        }
    }
    // The pool's telemetry is wanted even when a worker was cut off by
    // an early device stop (an expected close, like the threads above).
    let pool_out = pool.join();
    if let Err(e) = pool_out.result {
        if !device_out.finished_early {
            return Err(e);
        }
    }

    let wall = t0.elapsed().as_secs_f64();
    let snap = counters.snapshot();
    let (io_bytes, _) = storage.stats();
    let trained_images = device_out.steps * cfg.batch_size as u64;
    // poison: see the sampler thread — Vec ops only under this lock.
    let util_trace = std::mem::take(&mut util.lock().unwrap().samples);

    // Wall-clock stall attribution (DS-Analyzer vocabulary): the
    // device's busy share is "compute"; the remaining wall clock is
    // stall, split between "fetch" (workers starved waiting for work
    // items — upstream storage couldn't keep up) and "prep" (the CPU
    // transforms themselves are the limit), pro rata by their observed
    // signals.  Shares sum to 1 by construction.
    let stall = StallAttribution::from_signals(
        dev_clock.utilization(wall),
        work_probe.stats().recv_wait_secs,
        cpu_clock.utilization(wall) * wall,
    );

    // Drain spans once, after every producer thread has joined.
    let dump = tracer.drain();
    let stage_hists = trace::stage_hists(&dump);
    if cfg.trace != "off" {
        // poison: see the sampler thread — Vec ops only under this lock.
        let qs = std::mem::take(&mut *queue_series.lock().unwrap());
        let counter_tracks: Vec<(String, Vec<(f64, f64)>)> = ["work", "sample", "batch"]
            .iter()
            .zip(qs)
            .map(|(n, pts)| (format!("{n} queue depth"), pts))
            .collect();
        let json = trace::chrome_trace(&dump, &counter_tracks);
        std::fs::write(&cfg.trace, json.pretty())
            .with_context(|| format!("writing trace to {}", cfg.trace))?;
    }

    Ok(RunReport {
        images: snap.images_decoded,
        steps: device_out.steps,
        wall_secs: wall,
        preproc_ips: snap.images_decoded as f64 / wall,
        train_ips: trained_images as f64 / wall,
        cpu_util: cpu_clock.utilization(wall),
        device_util: dev_clock.utilization(wall),
        io_bytes,
        losses: device_out.losses,
        util_trace,
        producer_blocked_secs: device_out.producer_blocked_secs,
        consumer_starved_secs: device_out.consumer_starved_secs,
        net_in_flight_peak: remote.map(|r| r.in_flight.peak()).unwrap_or(0),
        prep_cache_hit_rate: prep_cache.as_ref().map(|c| c.hit_rate()).unwrap_or(0.0),
        decode_skipped: snap.decode_skipped,
        idct_blocks: snap.idct_blocks,
        idct_blocks_skipped: snap.idct_blocks_skipped,
        decode_scale_hist: scale_hist.snapshot(),
        epoch_secs: epoch_clock.epoch_secs(),
        images_read: snap.images_read,
        workers_auto: exec_cfg.auto,
        workers_final: pool_out.report.workers_final,
        workers_timeline: pool_out.report.workers_timeline,
        work_queue_peak: work_probe.stats().occupancy_peak,
        sample_queue_peak: sample_probe.stats().occupancy_peak,
        batch_queue_peak: batch_probe.stats().occupancy_peak,
        slab_hits: slab_pool.as_ref().map(|p| p.hits()).unwrap_or(0),
        slab_grows: slab_pool.as_ref().map(|p| p.grows()).unwrap_or(0),
        bytes_alloc_hot: crate::util::alloc_count::delta(alloc0).bytes,
        stall_fetch: stall.fetch,
        stall_prep: stall.prep,
        stall_compute: stall.compute,
        retries: retry_stats.snapshot().0,
        hedges_won: retry_stats.snapshot().1,
        faults_injected: faults.as_ref().map(|f| f.counts().total()).unwrap_or(0),
        samples_skipped: quarantine.count(),
        stage_hists,
    })
}

struct DeviceOut {
    steps: u64,
    losses: Vec<(u64, f32)>,
    finished_early: bool,
    producer_blocked_secs: f64,
    consumer_starved_secs: f64,
}

/// Consume batches: run device-side preprocessing artifacts as needed,
/// then the train step.  Owns the PJRT engine (single-threaded).
fn device_loop(
    cfg: &RunConfig,
    batch_rx: Receiver<Batch>,
    dev_clock: &BusyClock,
    counters: &Counters,
    tracer: &Tracer,
) -> Result<DeviceOut> {
    let mut engine = Engine::new(&cfg.artifact_dir)?;
    let m = &engine.manifest;
    let b = cfg.batch_size;
    let (img_hw, out_hw) = (m.img_hw, m.out_hw);
    let fused = m.fused_artifact(b);
    let augment = m.augment_artifact(b);
    if cfg.placement.uses_device_preproc() {
        let name = if cfg.placement == Placement::Hybrid { &fused } else { &augment };
        m.artifact(name).with_context(|| {
            format!("placement {} needs artifact {name}", cfg.placement.name())
        })?;
        // Prep-cache hits under hybrid re-enter as pixel payloads, which
        // the device augments with the hybrid0 artifact — require it up
        // front rather than failing mid-epoch on the first warm batch.
        if cfg.placement == Placement::Hybrid && cfg.prep_cache_mb > 0 {
            m.artifact(&augment).with_context(|| {
                format!("prep cache under hybrid needs artifact {augment}")
            })?;
        }
    }
    let mut session = if cfg.train {
        Some(TrainSession::new(&mut engine, &cfg.model, b, cfg.lr)?)
    } else {
        None
    };

    let mut steps = 0u64;
    let mut finished_early = false;

    // Ideal mode: take one batch, drop the pipeline, spin on it.
    if cfg.ideal {
        ensure!(cfg.train, "ideal mode requires train=true");
        ensure!(cfg.steps > 0, "ideal mode requires an explicit --steps");
        let first = batch_rx.recv().context("no batch for ideal mode")?;
        let starved = batch_rx.recv_wait_secs();
        drop(batch_rx);
        let (images, labels) =
            device_preprocess(&mut engine, cfg, &first, &fused, &augment, dev_clock, img_hw, out_hw)?;
        let pixels = crate::runtime::to_vec_f32(&images)?;
        let shape = [b, 3, out_hw, out_hw];
        let sess = session.as_mut().unwrap();
        for _ in 0..cfg.steps {
            let img = lit_f32(&shape, &pixels)?;
            let span = tracer.start();
            dev_clock.track(|| sess.step(&mut engine, img, &labels))?;
            tracer.record(Stage::Train, steps, span);
            steps += 1;
        }
        return Ok(DeviceOut {
            steps,
            losses: session.map(|s| s.losses).unwrap_or_default(),
            finished_early: true,
            producer_blocked_secs: 0.0,
            consumer_starved_secs: starved,
        });
    }

    while let Some(batch) = batch_rx.recv() {
        let (images, labels) =
            device_preprocess(&mut engine, cfg, &batch, &fused, &augment, dev_clock, img_hw, out_hw)?;
        counters.images_augmented(batch.len() as u64);
        if let Some(sess) = session.as_mut() {
            let span = tracer.start();
            dev_clock.track(|| sess.step(&mut engine, images, &labels))?;
            tracer.record(Stage::Train, steps, span);
            counters.train_steps(1);
        }
        steps += 1;
        if cfg.steps > 0 && steps >= cfg.steps as u64 {
            finished_early = true;
            break;
        }
    }
    let consumer_starved_secs = batch_rx.recv_wait_secs();
    Ok(DeviceOut {
        steps,
        losses: session.map(|s| s.losses).unwrap_or_default(),
        finished_early,
        producer_blocked_secs: 0.0,
        consumer_starved_secs,
    })
}

/// Turn a batch into the `[B,3,OUT,OUT]` images literal, running the
/// device-side preprocessing artifact when the placement calls for it.
#[allow(clippy::too_many_arguments)]
fn device_preprocess(
    engine: &mut Engine,
    cfg: &RunConfig,
    batch: &Batch,
    fused: &str,
    augment: &str,
    dev_clock: &BusyClock,
    img_hw: usize,
    out_hw: usize,
) -> Result<(xla::Literal, Vec<i32>)> {
    let b = batch.len();
    ensure!(b == cfg.batch_size, "partial batch reached device");
    let labels = batch.labels().to_vec();
    let images = match batch {
        Batch::Ready { data, .. } => lit_f32(&[b, 3, out_hw, out_hw], data)?,
        Batch::Coefs { data, qtable, aug, .. } => {
            let bh = img_hw / 8;
            let coefs = lit_f32(&[b, 3, bh, bh, 8, 8], data)?;
            let q = lit_f32(&[8, 8], qtable)?;
            let a = lit_f32(&[b, 6], aug)?;
            let mut outs = dev_clock.track(|| engine.execute(fused, &[coefs, q, a]))?;
            outs.remove(0)
        }
        Batch::Pixels { data, aug, .. } => {
            let imgs = lit_f32(&[b, 3, img_hw, img_hw], data)?;
            let a = lit_f32(&[b, 6], aug)?;
            let mut outs = dev_clock.track(|| engine.execute(augment, &[imgs, a]))?;
            outs.remove(0)
        }
    };
    Ok((images, labels))
}
