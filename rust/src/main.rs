//! `dpp` — CLI launcher for the data-preprocessing-pipeline framework.
//!
//! Subcommands:
//!   gen-data    generate the synthetic corpus + record shards
//!   run         run the real pipeline (optionally training) per config
//!   sim         run the calibrated testbed simulator for one scenario
//!   serve       run N jobs as tenants of one shared preprocessing tier
//!   reproduce   regenerate a paper figure/table (--fig 2|3|4|5|6|t1)
//!   autoconf    search resource configurations for a model/objective
//!   bench       microbenches: decode, workers, alloc, trace-overhead, chaos, simd, serve
//!   trace       pretty-print latency/stall tables from a saved run report
//!   audit       lint the sources for correctness-convention violations
//!   inspect     print manifest/artifact info

use anyhow::{bail, Result};
use dpp::config::RunConfig;
use dpp::dataset::GenConfig;
use dpp::util::cli::Args;
use std::path::PathBuf;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("gen-data") => gen_data(args),
        Some("run") => run(args),
        Some("sim") => sim(args),
        Some("serve") => serve(args),
        Some("reproduce") => reproduce(args),
        Some("autoconf") => autoconf(args),
        Some("bench") => bench(args),
        Some("trace") => trace(args),
        Some("audit") => audit(),
        Some("inspect") => inspect(args),
        Some(other) => bail!("unknown subcommand {other}; see --help"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!("{}", dpp::CLI_HELP);
}

fn gen_data(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get_or("data-dir", "data"));
    let gen = GenConfig {
        n_images: args.get_usize("images", 512),
        classes: args.get_usize("classes", 16) as u16,
        img_hw: args.get_usize("img-hw", 64),
        quality: args.get_usize("quality", 85) as u8,
        seed: args.get_u64("seed", 1234),
    };
    let shards = args.get_usize("shards", 4);
    let layout = dpp::coordinator::prepare_data(&dir, &gen, shards)?;
    println!(
        "corpus ready: {} images, {} classes, {} shards at {dir:?}",
        layout.entries.len(),
        gen.classes,
        layout.shards.len()
    );
    Ok(())
}

fn run(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_args(args)?;
    let report = dpp::coordinator::run(&cfg)?;
    report.print_summary(&format!(
        "{} {}/{} {}",
        cfg.model,
        cfg.method.name(),
        cfg.placement.name(),
        cfg.storage
    ));
    if let Some(path) = args.get("report-json") {
        std::fs::write(path, report.to_json().pretty())?;
        println!("report written to {path}");
    }
    Ok(())
}

fn sim(args: &Args) -> Result<()> {
    let scenario = dpp::sim::Scenario::from_args(args)?;
    // --trace-json also wants the synthetic span timeline, so it picks
    // the traced solver; the plain path stays span-free.
    let out = if let Some(path) = args.get("trace-json") {
        let (out, json) = dpp::sim::simulate_traced(&scenario);
        std::fs::write(path, json.pretty())?;
        println!("sim trace written to {path}");
        out
    } else {
        dpp::sim::simulate(&scenario)
    };
    println!("{}", out.summary_line(&scenario));
    println!("{}", out.stall.summary_line());
    if args.has_flag("trace") {
        for s in &out.util_trace {
            println!(
                "t={:.1}s cpu={:.2} gpu={:.2} io={:.1}MB/s",
                s.t, s.cpu, s.device, s.io_mbps
            );
        }
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let cfg = dpp::service::ServeConfig::from_args(args)?;
    let text = std::fs::read_to_string(&cfg.scenario)
        .map_err(|e| anyhow::anyhow!("cannot read scenario {:?}: {e}", cfg.scenario))?;
    let mut sc = dpp::service::engine::ServeScenario::parse(&text)?;
    cfg.apply_to(&mut sc)?;
    let report = dpp::service::engine::run(&sc)?;
    report.print_summary();
    if let Some(path) = &cfg.report_json {
        std::fs::write(path, report.to_json().pretty())?;
        println!("serve report written to {path}");
    }
    Ok(())
}

fn reproduce(args: &Args) -> Result<()> {
    match args.get_or("fig", "") {
        "2" => dpp::bench::figures::fig2(),
        "3" => dpp::bench::figures::fig3(args.get("data-dir").map(PathBuf::from)),
        "4" => dpp::bench::figures::fig4(),
        "5" => dpp::bench::figures::fig5(),
        "6" => dpp::bench::figures::fig6(),
        "t1" | "table1" => dpp::bench::figures::table1(),
        other => bail!("--fig must be 2|3|4|5|6|t1 (got {other:?})"),
    }
}

fn autoconf(args: &Args) -> Result<()> {
    let model = args.get_or("model", "resnet50");
    let objective = dpp::autoconf::Objective::parse(args.get_or("objective", "throughput"))?;
    let budget = args.get_f64("budget", f64::INFINITY);
    let rec = dpp::autoconf::recommend(model, objective, budget)?;
    println!("{}", rec.render());
    Ok(())
}

fn bench(args: &Args) -> Result<()> {
    match args.positionals.first().map(|s| s.as_str()) {
        Some("decode") => {
            let out = PathBuf::from(args.get_or("out", "BENCH_decode.json"));
            dpp::bench::decode::run(Some(&out))?;
            Ok(())
        }
        Some("workers") => {
            let out = PathBuf::from(args.get_or("out", "BENCH_workers.json"));
            dpp::bench::workers::run(Some(&out))?;
            Ok(())
        }
        Some("alloc") => {
            let out = PathBuf::from(args.get_or("out", "BENCH_alloc.json"));
            dpp::bench::alloc::run(Some(&out))?;
            Ok(())
        }
        Some("trace-overhead") => {
            let out = PathBuf::from(args.get_or("out", "BENCH_trace.json"));
            dpp::bench::trace::run(Some(&out))?;
            Ok(())
        }
        Some("chaos") => {
            let out = PathBuf::from(args.get_or("out", "BENCH_chaos.json"));
            dpp::bench::chaos::run(Some(&out))?;
            Ok(())
        }
        Some("simd") => {
            let out = PathBuf::from(args.get_or("out", "BENCH_simd.json"));
            dpp::bench::simd::run(Some(&out))?;
            Ok(())
        }
        Some("serve") => {
            let out = PathBuf::from(args.get_or("out", "BENCH_serve.json"));
            dpp::bench::serve::run(Some(&out))?;
            Ok(())
        }
        other => bail!(
            "bench target must be `decode`, `workers`, `alloc`, `trace-overhead`, `chaos`, `simd`, or `serve`, got {other:?}"
        ),
    }
}

fn trace(args: &Args) -> Result<()> {
    let path = args
        .positionals
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: dpp trace <run.json> (from `dpp run --report-json`)"))?;
    let raw = std::fs::read_to_string(path)?;
    let report = dpp::util::json::Json::parse(&raw)
        .map_err(|e| anyhow::anyhow!("{path} is not valid JSON: {e}"))?;
    print!("{}", dpp::metrics::trace::report_tables(&report)?);
    Ok(())
}

fn audit() -> Result<()> {
    let n = dpp::audit::run_self_audit()?;
    if n > 0 {
        bail!("audit: {n} finding(s)");
    }
    println!("audit: clean");
    Ok(())
}

fn inspect(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let m = dpp::runtime::Manifest::load(&dir)?;
    println!(
        "manifest: {} artifacts, {} models, batch sizes {}/{}, img {} -> {}",
        m.artifacts.len(),
        m.models.len(),
        m.batch_test,
        m.batch_main,
        m.img_hw,
        m.out_hw
    );
    for (name, a) in &m.artifacts {
        println!("  {name}: {} args -> {} outs [{}]", a.args.len(), a.outs.len(), a.file);
    }
    for (name, s) in &m.models {
        println!("  model {name}: {} params in {} leaves", s.param_count, s.leaves.len());
    }
    Ok(())
}
