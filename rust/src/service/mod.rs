//! Service mode: the long-lived multi-tenant preprocessing service
//! (`dpp serve --scenario FILE`).
//!
//! The single-run coordinator answers "how fast can *one* job train?";
//! this layer answers "how many jobs can *share* the preprocessing
//! tier without hurting each other?" — the ROADMAP's north star and
//! the CoorDL result from Mohan et al. (one dataset feeding many jobs
//! wants one shared decoded cache), made robust:
//!
//! * [`registry`] — membership + per-job byte quotas on the shared
//!   prep cache, rebalanced atomically on join/leave (hit-rate
//!   isolation: one job's shuffle order cannot evict another's working
//!   set);
//! * [`drr`] — deficit round-robin over the pool's per-tick work
//!   capacity (fair scheduling: a large-batch job cannot monopolize
//!   workers);
//! * [`engine`] — the deterministic virtual-time execution: admission
//!   control via the closed-form [`crate::sim::serve`] cost model
//!   (jobs are rejected up front, never silently degraded), per-job
//!   quarantine budgets windowed per epoch (failure isolation: a job
//!   exhausting its skip budget fails alone).
//!
//! `ServeConfig` holds the CLI surface: `--scenario` names the file,
//! and every other flag is an *override* of the scenario's own
//! settings (flags win, so one file serves quota-on/off A/Bs).

pub mod drr;
pub mod engine;
pub mod registry;

use crate::pipeline::prep_cache::PrepCachePolicy;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// CLI configuration for `dpp serve`.  Every field but the scenario
/// path is optional: `None` defers to the scenario file's value (or
/// the [`engine::ServeScenario`] default).
#[derive(Clone, Debug, Default)]
pub struct ServeConfig {
    pub scenario: PathBuf,
    pub goodput_floor: Option<f64>,
    pub quotas: Option<bool>,
    pub cache_mb: Option<usize>,
    pub workers_min: Option<usize>,
    pub workers_max: Option<usize>,
    pub seed: Option<u64>,
    pub policy: Option<PrepCachePolicy>,
    /// Consumed by the `serve` driver (report export), like `run`'s.
    pub report_json: Option<String>,
}

impl ServeConfig {
    /// Every CLI key the `serve` subcommand accepts.  Mirrors
    /// `RunConfig::accepted_flags`' contract: `from_args` rejects
    /// anything outside this list, and the help-drift test below
    /// requires each entry in `dpp::CLI_HELP`.
    pub fn accepted_flags() -> &'static [&'static str] {
        &[
            "scenario",
            "goodput-floor",
            "quotas",
            "cache-mb",
            "workers-min",
            "workers-max",
            "seed",
            "prep-cache-policy",
            "report-json",
        ]
    }

    /// Build from CLI args.  Unknown keys are rejected up front and
    /// value-less keys fail loudly — the same typo contract as `run`.
    pub fn from_args(args: &crate::util::cli::Args) -> Result<ServeConfig> {
        let accepted = Self::accepted_flags();
        for key in args.options.keys().map(String::as_str) {
            if !accepted.contains(&key) {
                bail!("unknown flag --{key} (see `dpp --help` for the serve flags)");
            }
        }
        // Every serve flag takes a value; a bare one means the value
        // was forgotten.
        for key in args.flags.iter().map(String::as_str) {
            if accepted.contains(&key) {
                bail!("--{key} requires a value");
            }
            bail!("unknown flag --{key} (see `dpp --help` for the serve flags)");
        }
        let Some(scenario) = args.get("scenario") else {
            bail!("serve requires --scenario FILE (see `dpp --help`)");
        };
        fn num<T: std::str::FromStr>(
            args: &crate::util::cli::Args,
            key: &str,
        ) -> Result<Option<T>> {
            match args.get(key) {
                None => Ok(None),
                Some(v) => v
                    .parse()
                    .map(Some)
                    .map_err(|_| anyhow::anyhow!("--{key}: expected a number, got {v:?}")),
            }
        }
        let quotas = match args.get("quotas") {
            None => None,
            Some("on") | Some("true") => Some(true),
            Some("off") | Some("false") => Some(false),
            Some(v) => bail!("--quotas must be on|off, got {v}"),
        };
        let policy = match args.get("prep-cache-policy") {
            None => None,
            Some(v) => Some(PrepCachePolicy::parse(v)?),
        };
        let cfg = ServeConfig {
            scenario: PathBuf::from(scenario),
            goodput_floor: num(args, "goodput-floor")?,
            quotas,
            cache_mb: num(args, "cache-mb")?,
            workers_min: num(args, "workers-min")?,
            workers_max: num(args, "workers-max")?,
            seed: num(args, "seed")?,
            policy,
            report_json: args.get("report-json").map(String::from),
        };
        if let Some(f) = cfg.goodput_floor {
            if !(f > 0.0 && f <= 1.0) {
                bail!("--goodput-floor must be in (0, 1], got {f}");
            }
        }
        Ok(cfg)
    }

    /// Overlay the CLI overrides onto a parsed scenario (flags win),
    /// then re-validate the combined result.
    pub fn apply_to(&self, sc: &mut engine::ServeScenario) -> Result<()> {
        if let Some(f) = self.goodput_floor {
            sc.goodput_floor = f;
        }
        if let Some(q) = self.quotas {
            sc.quotas = q;
        }
        if let Some(mb) = self.cache_mb {
            sc.cache_bytes = mb << 20;
        }
        if let Some(w) = self.workers_min {
            sc.workers_min = w;
        }
        if let Some(w) = self.workers_max {
            sc.workers_max = w;
        }
        if let Some(s) = self.seed {
            sc.seed = s;
        }
        if let Some(p) = self.policy {
            sc.policy = p;
        }
        sc.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    fn parse(cmd: &str) -> Result<ServeConfig> {
        ServeConfig::from_args(&Args::parse(cmd.split_whitespace().map(String::from)))
    }

    #[test]
    fn serve_flags_parse_and_overlay_the_scenario() {
        let cfg = parse(
            "serve --scenario churn.txt --goodput-floor 0.6 --quotas off \
             --cache-mb 8 --workers-min 2 --workers-max 16 --seed 9 \
             --prep-cache-policy lru --report-json out.json",
        )
        .unwrap();
        assert_eq!(cfg.scenario, PathBuf::from("churn.txt"));
        assert_eq!(cfg.report_json.as_deref(), Some("out.json"));
        let mut sc = engine::ServeScenario {
            jobs: vec![engine::JobSpec { name: "j".into(), ..Default::default() }],
            ..Default::default()
        };
        cfg.apply_to(&mut sc).unwrap();
        assert_eq!(sc.goodput_floor, 0.6);
        assert!(!sc.quotas);
        assert_eq!(sc.cache_bytes, 8 << 20);
        assert_eq!((sc.workers_min, sc.workers_max), (2, 16));
        assert_eq!(sc.seed, 9);
        assert_eq!(sc.policy, PrepCachePolicy::Lru);
        // No overrides: the scenario's own values survive.
        let plain = parse("serve --scenario churn.txt").unwrap();
        let mut sc2 = sc.clone();
        plain.apply_to(&mut sc2).unwrap();
        assert_eq!(sc2.goodput_floor, sc.goodput_floor);
    }

    #[test]
    fn serve_rejects_unknown_bare_and_malformed_flags() {
        assert!(parse("serve").is_err(), "missing --scenario accepted");
        let err = parse("serve --scenario s.txt --floor 0.5").unwrap_err().to_string();
        assert!(err.contains("unknown flag"), "{err}");
        let err = parse("serve --scenario s.txt --quotas").unwrap_err().to_string();
        assert!(err.contains("requires a value"), "{err}");
        assert!(parse("serve --scenario s.txt --quotas maybe").is_err());
        assert!(parse("serve --scenario s.txt --goodput-floor 1.5").is_err());
        assert!(parse("serve --scenario s.txt --workers-min two").is_err());
        assert!(parse("serve --scenario s.txt --prep-cache-policy fifo").is_err());
        // An override that breaks the combined scenario fails at
        // apply time (min > max).
        let cfg = parse("serve --scenario s.txt --workers-min 9 --workers-max 2").unwrap();
        let mut sc = engine::ServeScenario {
            jobs: vec![engine::JobSpec { name: "j".into(), ..Default::default() }],
            ..Default::default()
        };
        assert!(cfg.apply_to(&mut sc).is_err());
    }

    /// Serve's help-drift gate, mirroring `RunConfig`'s: every accepted
    /// serve flag must appear (delimited) in `dpp::CLI_HELP`.
    #[test]
    fn every_accepted_serve_flag_is_documented_in_help() {
        for flag in ServeConfig::accepted_flags() {
            let documented = [" ", "]", "\n"]
                .iter()
                .any(|d| crate::CLI_HELP.contains(&format!("--{flag}{d}")));
            assert!(documented, "--{flag} accepted by serve but missing from CLI_HELP");
        }
    }
}
