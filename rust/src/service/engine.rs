//! The serve engine: a deterministic, virtual-time execution of N
//! training jobs sharing one prep cache and one elastic worker pool.
//!
//! Time advances in *rounds* (one scheduler tick).  Each round the pool
//! delivers `workers × WORKER_UNITS` work units, split across the
//! admitted jobs by deficit round-robin ([`super::drr`]); a cache-hit
//! item costs [`HIT_COST`] units (augment only), a miss costs
//! [`MISS_COST`] (read+decode+augment) and inserts into the job's quota
//! slice.  The worker count follows the PR 4 fixed-point
//! ([`crate::sim::workers_fixed_point`]) on the aggregate demand, so
//! the pool grows and shrinks with churn like the elastic executor
//! does under `--workers auto`.
//!
//! The engine deliberately runs the *robustness surfaces* of the real
//! pipeline rather than mocks: per-job skip budgets are
//! [`Quarantine`] itself (windowed per epoch via `advance_window`),
//! quota slices are [`ByteLru`] with `set_budget` rebalancing, quota
//! accounting is the [`JobRegistry`], and admission is the closed-form
//! [`crate::sim::serve`] model — so the isolation gates in
//! `tests/serve.rs` exercise the same code a long-lived `dpp serve`
//! process runs, without wall-clock time or real image data.

use crate::metrics::JobSection;
use crate::pipeline::prep_cache::{steady_state_hit_rate, PrepCachePolicy};
use crate::pipeline::quarantine::Quarantine;
use crate::sim::serve::{admissible, standalone_goodput, SharedTier, TenantJob};
use crate::sim::workers_fixed_point;
use crate::util::bytelru::ByteLru;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// Work units one cache-hit item costs (augment only).
pub const HIT_COST: u64 = 1;
/// Work units one cache-miss item costs (read + decode + augment).
pub const MISS_COST: u64 = 8;
/// Work units one worker delivers per round.
pub const WORKER_UNITS: u64 = 32;
/// DRR quantum: one miss's worth, so per-round unfairness is at most
/// one expensive item.
const DRR_QUANTUM: u64 = MISS_COST;
/// Hard stop against scenario bugs (a job that can never finish).
const MAX_ROUNDS: u64 = 100_000;

/// One tenant job of a serve scenario.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub name: String,
    /// Dataset identity: jobs naming the same dataset share decoded
    /// samples across slices (the CoorDL cross-job win); an empty
    /// string means a private dataset (defaults to the job name).
    pub dataset: String,
    pub dataset_items: usize,
    pub bytes_per_item: usize,
    /// Items per round the job's trainer can consume.
    pub demand: u64,
    pub epochs: u64,
    /// Round at which the job asks to join.
    pub join_round: u64,
    /// Round at which the job leaves voluntarily (mid-epoch churn).
    pub leave_round: Option<u64>,
    /// Per-item probability of an injected fault (per attempt).
    pub fault_rate: f64,
    /// Per-item probability of a straggler read rescued by a hedge.
    pub straggler_rate: f64,
    /// Per-epoch skip budget rate (see [`Quarantine`]).
    pub max_skip_rate: f64,
    /// Retry attempts after a faulted read.
    pub retries: u32,
    /// DRR weight.
    pub weight: u64,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            name: String::new(),
            dataset: String::new(),
            dataset_items: 256,
            bytes_per_item: 8 << 10,
            demand: 16,
            epochs: 2,
            join_round: 0,
            leave_round: None,
            fault_rate: 0.0,
            straggler_rate: 0.0,
            max_skip_rate: 0.0,
            retries: 0,
            weight: 1,
        }
    }
}

/// A full serve scenario: the shared tier plus its tenants.
#[derive(Clone, Debug)]
pub struct ServeScenario {
    pub jobs: Vec<JobSpec>,
    pub seed: u64,
    /// Shared prep-cache budget in bytes.
    pub cache_bytes: usize,
    /// Per-job byte quotas on (isolation) or off (one shared pool —
    /// the collapse mode the isolation gate demonstrates).
    pub quotas: bool,
    /// Admission floor: every admitted job must keep at least this
    /// fraction of its standalone goodput.
    pub goodput_floor: f64,
    pub workers_min: usize,
    pub workers_max: usize,
    pub policy: PrepCachePolicy,
}

impl Default for ServeScenario {
    fn default() -> Self {
        ServeScenario {
            jobs: Vec::new(),
            seed: 42,
            cache_bytes: 4 << 20,
            quotas: true,
            goodput_floor: 0.5,
            workers_min: 1,
            workers_max: 8,
            policy: PrepCachePolicy::Minio,
        }
    }
}

impl ServeScenario {
    /// Parse the `--scenario` file format: one statement per line,
    /// `#` starts a comment.  A line containing a `name=` key defines a
    /// job (keys: `name dataset items item_kb demand epochs join leave
    /// fault_rate straggler_rate max_skip_rate retries weight`); any
    /// other non-empty line sets scenario keys (`seed cache_mb quotas
    /// goodput_floor workers_min workers_max policy`).  Unknown keys
    /// fail loudly, like the CLI's unknown-flag rejection.
    pub fn parse(text: &str) -> Result<ServeScenario> {
        fn num<T: std::str::FromStr>(line: usize, k: &str, v: &str) -> Result<T> {
            v.parse()
                .map_err(|_| anyhow!("line {line}: {k} expects a number, got {v:?}"))
        }
        fn on_off(line: usize, k: &str, v: &str) -> Result<bool> {
            match v {
                "on" | "true" => Ok(true),
                "off" | "false" => Ok(false),
                _ => bail!("line {line}: {k} must be on|off, got {v:?}"),
            }
        }
        let mut sc = ServeScenario::default();
        for (i, raw) in text.lines().enumerate() {
            let ln = i + 1;
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let kvs = line
                .split_whitespace()
                .map(|tok| {
                    tok.split_once('=')
                        .ok_or_else(|| anyhow!("line {ln}: expected key=value, got {tok:?}"))
                })
                .collect::<Result<Vec<_>>>()?;
            if kvs.iter().any(|(k, _)| *k == "name") {
                let mut job = JobSpec::default();
                for (k, v) in kvs {
                    match k {
                        "name" => job.name = v.to_string(),
                        "dataset" => job.dataset = v.to_string(),
                        "items" => job.dataset_items = num(ln, k, v)?,
                        "item_kb" => job.bytes_per_item = num::<usize>(ln, k, v)? << 10,
                        "demand" => job.demand = num(ln, k, v)?,
                        "epochs" => job.epochs = num(ln, k, v)?,
                        "join" => job.join_round = num(ln, k, v)?,
                        "leave" => job.leave_round = Some(num(ln, k, v)?),
                        "fault_rate" => job.fault_rate = num(ln, k, v)?,
                        "straggler_rate" => job.straggler_rate = num(ln, k, v)?,
                        "max_skip_rate" => job.max_skip_rate = num(ln, k, v)?,
                        "retries" => job.retries = num(ln, k, v)?,
                        "weight" => job.weight = num(ln, k, v)?,
                        other => bail!("line {ln}: unknown job key {other:?}"),
                    }
                }
                sc.jobs.push(job);
            } else {
                for (k, v) in kvs {
                    match k {
                        "seed" => sc.seed = num(ln, k, v)?,
                        "cache_mb" => sc.cache_bytes = num::<usize>(ln, k, v)? << 20,
                        "quotas" => sc.quotas = on_off(ln, k, v)?,
                        "goodput_floor" => sc.goodput_floor = num(ln, k, v)?,
                        "workers_min" => sc.workers_min = num(ln, k, v)?,
                        "workers_max" => sc.workers_max = num(ln, k, v)?,
                        "policy" => sc.policy = PrepCachePolicy::parse(v)?,
                        other => bail!("line {ln}: unknown scenario key {other:?}"),
                    }
                }
            }
        }
        sc.validate()?;
        Ok(sc)
    }

    pub fn validate(&self) -> Result<()> {
        if self.jobs.is_empty() {
            bail!("scenario defines no jobs");
        }
        let mut seen = std::collections::HashSet::new();
        for j in &self.jobs {
            if j.name.is_empty() {
                bail!("every job needs a non-empty name");
            }
            if !seen.insert(j.name.as_str()) {
                bail!("duplicate job name {:?}", j.name);
            }
            if j.dataset_items == 0 || j.bytes_per_item == 0 {
                bail!("job {}: items and item_kb must be > 0", j.name);
            }
            if j.demand == 0 {
                bail!("job {}: demand must be > 0", j.name);
            }
            if j.epochs == 0 {
                bail!("job {}: epochs must be >= 1", j.name);
            }
            if !(0.0..1.0).contains(&j.fault_rate) {
                bail!("job {}: fault_rate must be in [0, 1)", j.name);
            }
            if !(0.0..1.0).contains(&j.max_skip_rate) {
                bail!("job {}: max_skip_rate must be in [0, 1)", j.name);
            }
        }
        if !(self.goodput_floor > 0.0 && self.goodput_floor <= 1.0) {
            bail!("goodput_floor must be in (0, 1], got {}", self.goodput_floor);
        }
        if self.workers_min == 0 || self.workers_max < self.workers_min {
            bail!(
                "workers_min/workers_max must satisfy 1 <= min <= max, got {}/{}",
                self.workers_min,
                self.workers_max
            );
        }
        Ok(())
    }

    /// The admission model's view of the tier: the pool priced at its
    /// elastic ceiling (admission asks "can the pool, fully grown,
    /// carry everyone?" — the fixed-point controller handles how far it
    /// actually grows).
    fn tier(&self) -> SharedTier {
        SharedTier {
            cache_bytes: self.cache_bytes as f64,
            capacity_units: (self.workers_max as u64 * WORKER_UNITS) as f64,
            hit_cost: HIT_COST as f64,
            miss_cost: MISS_COST as f64,
            policy: self.policy,
        }
    }
}

fn tenant_of(spec: &JobSpec) -> TenantJob {
    TenantJob {
        dataset_bytes: (spec.dataset_items * spec.bytes_per_item) as f64,
        demand_items: spec.demand as f64,
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Status {
    Waiting,
    Running,
    Done,
    /// Left voluntarily (churn) before finishing its epochs.
    Left,
    Failed,
    Rejected,
}

impl Status {
    fn name(self) -> &'static str {
        match self {
            Status::Waiting => "waiting",
            Status::Running => "running",
            Status::Done => "done",
            Status::Left => "left",
            Status::Failed => "failed",
            Status::Rejected => "rejected",
        }
    }

    fn terminal(self) -> bool {
        matches!(self, Status::Done | Status::Left | Status::Failed | Status::Rejected)
    }
}

struct JobState {
    spec: JobSpec,
    id: u64,
    dataset_idx: usize,
    status: Status,
    /// Still holds registry/DRR/slice resources (cleanup pending).
    enrolled: bool,
    seed: u64,
    /// Fault/straggler draw stream (forked off the scenario seed).
    rng: Rng,
    order: Vec<u64>,
    cursor: usize,
    epoch: u64,
    epochs_done: u64,
    epoch_start_round: u64,
    epoch_items: u64,
    epoch_hits: u64,
    epoch_misses: u64,
    /// Final completed epoch's steady-state stats (what reports carry).
    last_hit_rate: f64,
    last_goodput: f64,
    retries: u64,
    hedges_won: u64,
    faults_injected: u64,
    quarantine: Quarantine,
    failure: Option<String>,
}

impl JobState {
    fn new(spec: JobSpec, id: u64, dataset_idx: usize, seed: u64) -> Self {
        let quarantine = Quarantine::new(spec.max_skip_rate, spec.dataset_items as u64);
        JobState {
            rng: Rng::new(seed).fork(0x0F + id),
            quarantine,
            spec,
            id,
            dataset_idx,
            status: Status::Waiting,
            enrolled: false,
            seed,
            order: Vec::new(),
            cursor: 0,
            epoch: 0,
            epochs_done: 0,
            epoch_start_round: 0,
            epoch_items: 0,
            epoch_hits: 0,
            epoch_misses: 0,
            last_hit_rate: 0.0,
            last_goodput: 0.0,
            retries: 0,
            hedges_won: 0,
            faults_injected: 0,
            failure: None,
        }
    }

    fn start_epoch(&mut self, round: u64) {
        self.order = (0..self.spec.dataset_items as u64).collect();
        let mut shuffler = Rng::new(self.seed).fork(self.id).fork(self.epoch);
        shuffler.shuffle(&mut self.order);
        self.cursor = 0;
        self.epoch_items = 0;
        self.epoch_hits = 0;
        self.epoch_misses = 0;
        self.epoch_start_round = round;
    }

    /// Close the current epoch's books; returns whether the job is done.
    fn finish_epoch(&mut self, round: u64) -> bool {
        self.epochs_done += 1;
        let lookups = self.epoch_hits + self.epoch_misses;
        if lookups > 0 {
            self.last_hit_rate = self.epoch_hits as f64 / lookups as f64;
        }
        let rounds = (round - self.epoch_start_round + 1).max(1);
        self.last_goodput = self.epoch_items as f64 / rounds as f64;
        // Fresh per-epoch skip budget (the satellite-1 windowing).
        self.quarantine.advance_window();
        if self.epochs_done >= self.spec.epochs {
            self.status = Status::Done;
            return true;
        }
        self.epoch += 1;
        self.start_epoch(round);
        false
    }

    fn section(&self) -> JobSection {
        JobSection {
            name: self.spec.name.clone(),
            status: match &self.failure {
                Some(f) => format!("{}: {}", self.status.name(), f),
                None => self.status.name().to_string(),
            },
            epochs_done: self.epochs_done,
            hit_rate: self.last_hit_rate,
            goodput_ips: self.last_goodput,
            retries: self.retries,
            hedges_won: self.hedges_won,
            faults_injected: self.faults_injected,
            samples_skipped: self.quarantine.count(),
        }
    }
}

/// What a serve run reports: the service-level outcome plus one
/// [`JobSection`] per job (the per-job failure domains the isolation
/// gates assert on).
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub rounds: u64,
    pub workers_final: usize,
    pub rejected: Vec<String>,
    pub jobs: Vec<JobSection>,
}

impl ServeReport {
    pub fn section(&self, name: &str) -> Option<&JobSection> {
        self.jobs.iter().find(|j| j.name == name)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::num(crate::metrics::REPORT_SCHEMA_VERSION as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("workers_final", Json::num(self.workers_final as f64)),
            ("rejected", Json::arr(self.rejected.iter().map(|s| Json::str(s)))),
            ("jobs", Json::arr(self.jobs.iter().map(|j| j.to_json()))),
        ])
    }

    pub fn print_summary(&self) {
        println!(
            "[serve] {} job(s), {} rejected, {} round(s), {} worker(s) at exit",
            self.jobs.len(),
            self.rejected.len(),
            self.rounds,
            self.workers_final
        );
        for j in &self.jobs {
            println!(
                "  {:<12} {:<10} epochs {} hit {:.3} goodput {:.1} it/round \
                 retries {} hedges {} faults {} skipped {}",
                j.name,
                j.status.split(':').next().unwrap_or(&j.status),
                j.epochs_done,
                j.hit_rate,
                j.goodput_ips,
                j.retries,
                j.hedges_won,
                j.faults_injected,
                j.samples_skipped
            );
        }
    }
}

/// Run a scenario to completion (every job done, left, failed, or
/// rejected) and report per-job outcomes.  Deterministic in the
/// scenario (virtual time, seeded draws): the same input always yields
/// the same report.
pub fn run(sc: &ServeScenario) -> Result<ServeReport> {
    sc.validate()?;
    let tier = sc.tier();
    let registry = super::registry::JobRegistry::new(sc.cache_bytes);
    let mut drr = super::drr::Drr::new(DRR_QUANTUM);

    // Dataset identities (cross-job sharing key): empty = private.
    let mut dataset_ids: BTreeMap<String, usize> = BTreeMap::new();
    let mut jobs: Vec<JobState> = Vec::new();
    for (idx, spec) in sc.jobs.iter().enumerate() {
        let mut spec = spec.clone();
        if spec.dataset.is_empty() {
            spec.dataset = spec.name.clone();
        }
        let next = dataset_ids.len();
        let ds = *dataset_ids.entry(spec.dataset.clone()).or_insert(next);
        jobs.push(JobState::new(spec, idx as u64, ds, sc.seed));
    }

    // Quota slices (quotas on) or the one shared pool (quotas off),
    // keyed by (dataset, sample) so shared datasets alias across jobs.
    let mut slices: BTreeMap<u64, ByteLru<(usize, u64), ()>> = BTreeMap::new();
    let mut shared: ByteLru<(usize, u64), ()> =
        ByteLru::new(if sc.quotas { 0 } else { sc.cache_bytes });

    let mut workers = sc.workers_min;
    let mut rejected: Vec<String> = Vec::new();
    let mut round: u64 = 0;

    while round < MAX_ROUNDS {
        // 1. Voluntary leaves (mid-epoch churn).
        let mut churn = false;
        for job in jobs.iter_mut() {
            if job.status == Status::Running {
                if let Some(at) = job.spec.leave_round {
                    if round >= at {
                        job.status = Status::Left;
                    }
                }
            }
        }

        // 2. Joins, gated by the admission model over the running set
        //    plus the candidate.
        for i in 0..jobs.len() {
            if jobs[i].status != Status::Waiting || round < jobs[i].spec.join_round {
                continue;
            }
            let mut tenants: Vec<TenantJob> = jobs
                .iter()
                .filter(|j| j.status == Status::Running)
                .map(|j| tenant_of(&j.spec))
                .collect();
            tenants.push(tenant_of(&jobs[i].spec));
            let admitted =
                registry.join_with(jobs[i].id, |_| admissible(&tier, &tenants, sc.goodput_floor));
            if admitted {
                jobs[i].status = Status::Running;
                jobs[i].enrolled = true;
                jobs[i].epoch_start_round = round;
                jobs[i].start_epoch(round);
                drr.add(jobs[i].id, jobs[i].spec.weight);
                if sc.quotas {
                    slices.insert(jobs[i].id, ByteLru::new(0));
                }
                churn = true;
            } else {
                jobs[i].status = Status::Rejected;
                rejected.push(jobs[i].spec.name.clone());
            }
        }

        // 3. Retire jobs that went terminal while still enrolled, and
        //    rebalance every surviving slice to its new quota.
        for job in jobs.iter_mut() {
            if job.status.terminal() && job.enrolled {
                registry.leave(job.id);
                drr.remove(job.id);
                slices.remove(&job.id);
                job.enrolled = false;
                churn = true;
            }
        }
        if churn && sc.quotas {
            for entry in registry.quotas() {
                if let Some(slice) = slices.get_mut(&entry.id) {
                    slice.set_budget(entry.quota);
                }
            }
        }

        if jobs.iter().all(|j| j.status.terminal()) {
            break;
        }

        // 4. Elastic pool: the fixed-point worker count for the
        //    aggregate demand at the closed-form per-slice hit rates.
        let running: Vec<&JobState> = jobs.iter().filter(|j| j.status == Status::Running).collect();
        if !running.is_empty() {
            let n = running.len() as f64;
            let mut total_units = 0.0;
            let mut total_demand = 0.0;
            for j in &running {
                let slice_bytes = if sc.quotas { tier.cache_bytes / n } else { tier.cache_bytes };
                let h = steady_state_hit_rate(
                    sc.policy,
                    slice_bytes,
                    (j.spec.dataset_items * j.spec.bytes_per_item) as f64,
                );
                let cost = h * tier.hit_cost + (1.0 - h) * tier.miss_cost;
                total_units += j.spec.demand as f64 * cost;
                total_demand += j.spec.demand as f64;
            }
            let stage_ms = 1000.0 * total_units / (total_demand * WORKER_UNITS as f64);
            workers = workers_fixed_point(stage_ms, total_demand, sc.workers_min, sc.workers_max);
        }
        let capacity = workers as u64 * WORKER_UNITS;

        // 5. Fair-schedule the round's capacity and let each grant
        //    process items against the shared cache.
        for job in jobs.iter_mut() {
            if job.status == Status::Running {
                let left = (job.spec.dataset_items - job.cursor) as u64;
                drr.set_pending(job.id, job.spec.demand.min(left) * MISS_COST);
            }
        }
        let grants = drr.schedule(capacity);
        for (id, units) in grants {
            let job = &mut jobs[id as usize];
            if job.status != Status::Running {
                continue;
            }
            let mut budget = units as i64;
            let mut served: u64 = 0;
            while budget > 0 && served < job.spec.demand && job.status == Status::Running {
                let sample = job.order[job.cursor];
                // Fault plane: draw per attempt; exhausted retries send
                // the sample to this job's quarantine — and only this
                // job's (failure isolation).
                if job.spec.fault_rate > 0.0 && job.rng.f64() < job.spec.fault_rate {
                    job.faults_injected += 1;
                    let mut recovered = false;
                    for _ in 0..job.spec.retries {
                        job.retries += 1;
                        if job.rng.f64() >= job.spec.fault_rate {
                            recovered = true;
                            break;
                        }
                        job.faults_injected += 1;
                    }
                    if !recovered {
                        let desc = format!("{}#e{}s{}", job.spec.name, job.epoch, sample);
                        let cause =
                            anyhow!("injected fault after {} attempt(s)", job.spec.retries + 1);
                        if let Err(e) = job.quarantine.admit(desc, cause) {
                            job.failure = Some(format!("{e:#}"));
                            job.status = Status::Failed;
                            break;
                        }
                        // The skipped sample consumed a miss's work but
                        // yields no goodput item.
                        budget -= MISS_COST as i64;
                        job.cursor += 1;
                        if job.cursor == job.spec.dataset_items {
                            if job.finish_epoch(round) {
                                break;
                            }
                        }
                        continue;
                    }
                }
                if job.spec.straggler_rate > 0.0 && job.rng.f64() < job.spec.straggler_rate {
                    // A hedged duplicate wins the race; counted, not
                    // charged (the straggler's cost is latency, which
                    // virtual rounds don't model).
                    job.hedges_won += 1;
                }
                let key = (job.dataset_idx, sample);
                let size = job.spec.bytes_per_item;
                let hit = if sc.quotas {
                    let own = slices.get_mut(&job.id).map_or(false, |s| s.get(&key).is_some());
                    // CoorDL cross-job sharing: a sibling slice holding
                    // the same dataset's sample serves the hit.
                    own || slices
                        .iter()
                        .any(|(oid, s)| *oid != job.id && s.peek(&key).is_some())
                } else {
                    shared.get(&key).is_some()
                };
                if hit {
                    budget -= HIT_COST as i64;
                    job.epoch_hits += 1;
                } else {
                    budget -= MISS_COST as i64;
                    job.epoch_misses += 1;
                    let store = if sc.quotas { slices.get_mut(&job.id) } else { Some(&mut shared) };
                    if let Some(store) = store {
                        let fits = store.bytes() + size <= store.budget();
                        // MinIO admits until full and never evicts; LRU
                        // always admits and evicts the coldest.
                        if sc.policy == PrepCachePolicy::Lru || fits {
                            store.insert(key, (), size);
                        }
                    }
                }
                served += 1;
                job.epoch_items += 1;
                job.cursor += 1;
                if job.cursor == job.spec.dataset_items && job.finish_epoch(round) {
                    break;
                }
            }
        }
        round += 1;
    }

    if !jobs.iter().all(|j| j.status.terminal()) {
        bail!("serve engine hit the {MAX_ROUNDS}-round guard with jobs still active");
    }
    Ok(ServeReport {
        rounds: round,
        workers_final: workers,
        rejected,
        jobs: jobs.iter().map(JobState::section).collect(),
    })
}

/// Convenience: parse a scenario file and run it.
pub fn run_file(path: &std::path::Path) -> Result<ServeReport> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading scenario {}", path.display()))?;
    run(&ServeScenario::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(name: &str) -> JobSpec {
        JobSpec { name: name.into(), ..JobSpec::default() }
    }

    #[test]
    fn scenario_parser_reads_jobs_and_settings_and_rejects_junk() {
        let text = "\
# shared tier
seed=7 cache_mb=2 quotas=off goodput_floor=0.4
workers_min=2 workers_max=16 policy=lru

name=alpha items=64 item_kb=4 demand=8 epochs=3 join=0
name=beta dataset=alpha items=64 item_kb=4 demand=4 epochs=2 join=5 leave=40 \
fault_rate=0.1 retries=2 max_skip_rate=0.05 weight=2
";
        let sc = ServeScenario::parse(text).unwrap();
        assert_eq!(sc.seed, 7);
        assert_eq!(sc.cache_bytes, 2 << 20);
        assert!(!sc.quotas);
        assert_eq!(sc.goodput_floor, 0.4);
        assert_eq!((sc.workers_min, sc.workers_max), (2, 16));
        assert_eq!(sc.policy, PrepCachePolicy::Lru);
        assert_eq!(sc.jobs.len(), 2);
        let beta = &sc.jobs[1];
        assert_eq!(beta.dataset, "alpha");
        assert_eq!(beta.leave_round, Some(40));
        assert_eq!(beta.retries, 2);
        assert_eq!(beta.weight, 2);

        for bad in [
            "name=a items=0",                   // zero items
            "name=a gremlin=1",                 // unknown job key
            "cache_gb=1\nname=a",               // unknown scenario key
            "name=a\nname=a",                   // duplicate name
            "name=a items=ten",                 // malformed number
            "quotas=maybe\nname=a",             // malformed bool
            "",                                 // no jobs at all
        ] {
            assert!(ServeScenario::parse(bad).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn single_job_runs_all_epochs_and_warms_the_cache() {
        let sc = ServeScenario {
            jobs: vec![JobSpec { epochs: 3, ..job("solo") }],
            ..ServeScenario::default()
        };
        let r = run(&sc).unwrap();
        let s = r.section("solo").unwrap();
        assert_eq!(s.status, "done");
        assert_eq!(s.epochs_done, 3);
        // Dataset (256 × 8 KiB = 2 MiB) fits the 4 MiB cache: after the
        // cold first epoch, steady state hits everything.
        assert!(s.hit_rate > 0.99, "steady-state hit rate {}", s.hit_rate);
        assert!(s.goodput_ips > 0.0);
        assert_eq!(s.samples_skipped, 0);
        assert!(r.rejected.is_empty());
        // Determinism: the same scenario reports identically.
        let r2 = run(&sc).unwrap();
        assert_eq!(r2.section("solo").unwrap().hit_rate, s.hit_rate);
        assert_eq!(r2.rounds, r.rounds);
    }

    #[test]
    fn shared_dataset_jobs_hit_each_others_slices() {
        // Both jobs stream the same dataset; the second joins after the
        // first has warmed its slice, so its *first* epoch already hits
        // (the CoorDL cross-job win).
        let base = JobSpec { dataset: "imagenet_t".into(), epochs: 2, ..JobSpec::default() };
        let sc = ServeScenario {
            jobs: vec![
                JobSpec { join_round: 0, epochs: 4, ..base.clone() }.named("warm"),
                JobSpec { join_round: 20, ..base }.named("rider"),
            ],
            ..ServeScenario::default()
        };
        let r = run(&sc).unwrap();
        let rider = r.section("rider").unwrap();
        assert_eq!(rider.status, "done");
        assert!(rider.hit_rate > 0.9, "cross-job sharing missing: {}", rider.hit_rate);
    }

    #[test]
    fn voluntary_leave_frees_quota_for_the_survivors() {
        let sc = ServeScenario {
            jobs: vec![
                JobSpec { epochs: 8, ..job("stayer") },
                JobSpec { epochs: 8, leave_round: Some(4), ..job("churner") },
            ],
            ..ServeScenario::default()
        };
        let r = run(&sc).unwrap();
        assert_eq!(r.section("stayer").unwrap().status, "done");
        let churner = r.section("churner").unwrap();
        assert_eq!(churner.status, "left");
        assert!(churner.epochs_done < 8);
    }

    impl JobSpec {
        fn named(mut self, name: &str) -> JobSpec {
            self.name = name.into();
            self
        }
    }
}
