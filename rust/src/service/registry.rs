//! Job registry: the service's single source of truth for which jobs
//! are running and how the shared prep-cache budget is split among
//! them.
//!
//! Invariants (model-checked in `tests/loom_models.rs`):
//!
//! * **quota conservation** — whenever at least one job is registered,
//!   the per-job quotas sum to *exactly* the total budget (the
//!   rebalance distributes the remainder byte-by-byte instead of
//!   rounding it away), and an empty registry holds zero quota out;
//! * **atomic join/leave** — admission decision, membership update, and
//!   quota rebalance happen under one lock, so a racing join and leave
//!   can never observe (or produce) a half-rebalanced split, lose a
//!   rebalance, or double-admit an id;
//! * **the in-flight gauge drains** — every join attempt increments
//!   [`JobRegistry::in_flight`] on entry and decrements it on exit
//!   (admitted or not), so a quiesced service always reads zero.
//!
//! Sync primitives come from the `util::sync` facade, so the loom
//! models check the exact code that ships.

use crate::util::sync::atomic::{AtomicUsize, Ordering};
use crate::util::sync::Mutex;

/// One registered job and its byte quota of the shared cache.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobEntry {
    pub id: u64,
    /// Bytes of the shared prep cache this job's slice may hold.
    pub quota: usize,
}

/// Registry of admitted jobs with fair byte-quota rebalancing.
#[derive(Debug)]
pub struct JobRegistry {
    total_quota: usize,
    jobs: Mutex<Vec<JobEntry>>,
    in_flight: AtomicUsize,
}

impl JobRegistry {
    pub fn new(total_quota: usize) -> Self {
        JobRegistry {
            total_quota,
            jobs: Mutex::new(Vec::new()),
            in_flight: AtomicUsize::new(0),
        }
    }

    /// Total cache budget the quotas always sum to (when non-empty).
    pub fn total_quota(&self) -> usize {
        self.total_quota
    }

    /// Attempt to join: `admit` inspects the current membership (under
    /// the registry lock, so the set it sees is the set the rebalance
    /// applies to) and returns whether the candidate may enter.  On
    /// admission the job is registered and every quota is rebalanced
    /// before the lock drops.  A duplicate id is refused without
    /// consulting `admit`.
    pub fn join_with<F>(&self, id: u64, admit: F) -> bool
    where
        F: FnOnce(&[JobEntry]) -> bool,
    {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        let admitted = {
            // poison: holders only mutate the Vec and recompute integer
            // quotas; no panic can originate under the lock (the admit
            // closure runs before any mutation, so even a panicking
            // closure leaves the membership unchanged).
            let mut jobs = self.jobs.lock().unwrap();
            if jobs.iter().any(|j| j.id == id) {
                false
            } else if admit(&jobs) {
                jobs.push(JobEntry { id, quota: 0 });
                Self::rebalance(&mut jobs, self.total_quota);
                true
            } else {
                false
            }
        };
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
        admitted
    }

    /// Remove a job and rebalance the survivors' quotas atomically.
    /// Returns whether the id was present.
    pub fn leave(&self, id: u64) -> bool {
        // poison: see `join_with` — Vec ops and integer math only.
        let mut jobs = self.jobs.lock().unwrap();
        let before = jobs.len();
        jobs.retain(|j| j.id != id);
        let removed = jobs.len() != before;
        if removed {
            Self::rebalance(&mut jobs, self.total_quota);
        }
        removed
    }

    /// Even split with the remainder spread one byte at a time over the
    /// first `total % n` jobs — so the quotas sum to `total` exactly,
    /// never `total - n + 1` (integer division alone would leak up to
    /// `n - 1` bytes of budget per rebalance).
    fn rebalance(jobs: &mut [JobEntry], total: usize) {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        let base = total / n;
        let rem = total % n;
        for (i, j) in jobs.iter_mut().enumerate() {
            j.quota = base + usize::from(i < rem);
        }
    }

    /// Snapshot of the current membership and quotas (join order).
    pub fn quotas(&self) -> Vec<JobEntry> {
        // poison: see `join_with`.
        self.jobs.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        // poison: see `join_with`.
        self.jobs.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Join attempts currently inside [`Self::join_with`] — the
    /// admission gauge the loom model drains to zero.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quota_sum(r: &JobRegistry) -> usize {
        r.quotas().iter().map(|j| j.quota).sum()
    }

    #[test]
    fn join_rebalances_and_conserves_the_budget_exactly() {
        let r = JobRegistry::new(100);
        assert!(r.join_with(1, |cur| cur.is_empty()));
        assert_eq!(r.quotas(), vec![JobEntry { id: 1, quota: 100 }]);
        assert!(r.join_with(2, |_| true));
        assert!(r.join_with(3, |_| true));
        // 100 over 3 jobs: 34 + 33 + 33, never 33 × 3 (a leaked byte).
        let q: Vec<usize> = r.quotas().iter().map(|j| j.quota).collect();
        assert_eq!(q, vec![34, 33, 33]);
        assert_eq!(quota_sum(&r), 100);
        assert_eq!(r.len(), 3);
        assert_eq!(r.in_flight(), 0);
    }

    #[test]
    fn rejection_and_duplicates_leave_the_registry_untouched() {
        let r = JobRegistry::new(64);
        assert!(r.join_with(1, |_| true));
        // The admission closure sees the current membership.
        assert!(!r.join_with(2, |cur| cur.len() < 1));
        assert_eq!(r.len(), 1);
        assert_eq!(quota_sum(&r), 64);
        // Duplicate ids are refused before the closure runs.
        assert!(!r.join_with(1, |_| panic!("closure must not run for a duplicate id")));
        assert_eq!(r.len(), 1);
        assert_eq!(r.in_flight(), 0);
    }

    #[test]
    fn leave_rebalances_survivors_and_empty_registry_holds_nothing() {
        let r = JobRegistry::new(90);
        for id in 1..=3 {
            assert!(r.join_with(id, |_| true));
        }
        assert!(r.leave(2));
        let q = r.quotas();
        assert_eq!(q.len(), 2);
        assert!(q.iter().all(|j| j.quota == 45));
        assert!(!r.leave(2), "double-leave must report absence");
        assert!(r.leave(1));
        assert!(r.leave(3));
        assert!(r.is_empty());
        assert_eq!(quota_sum(&r), 0);
        assert_eq!(r.total_quota(), 90);
    }

    #[test]
    fn quota_conservation_holds_under_churn() {
        let r = JobRegistry::new(1009); // prime: every split has remainder
        let mut rng = crate::util::rng::Rng::new(0x5EB5);
        let mut next_id = 0u64;
        for _ in 0..200 {
            if rng.bool() {
                next_id += 1;
                r.join_with(next_id, |_| true);
            } else if next_id > 0 {
                r.leave(1 + rng.gen_range(next_id));
            }
            if r.len() > 0 {
                assert_eq!(quota_sum(&r), 1009);
            } else {
                assert_eq!(quota_sum(&r), 0);
            }
        }
        assert_eq!(r.in_flight(), 0);
    }
}
