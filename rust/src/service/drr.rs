//! Deficit round-robin over the shared worker pool's per-tick work
//! capacity: each job (flow) carries a deficit counter topped up by
//! `quantum × weight` once per round, and may consume pool units up to
//! its deficit — so a large-batch job can never take more than its
//! weighted share while others have work queued, yet idle jobs' unused
//! capacity flows to busy ones (work-conserving).
//!
//! The scheduler is single-threaded by design: the engine calls
//! [`Drr::schedule`] once per tick from its driver loop, and the grants
//! say how many units each job may spend this tick.  Concurrency lives
//! in the registry and the pool, not here — keeping the fairness logic
//! deterministic and directly testable.

/// One scheduled job.
#[derive(Debug)]
struct Flow {
    id: u64,
    weight: u64,
    /// Unspent credit carried between rounds (bounded by construction:
    /// reset whenever the flow's backlog empties, so an idle flow can
    /// never hoard credit and burst later).
    deficit: u64,
    /// Work units the flow wants this tick.
    pending: u64,
}

/// Deficit round-robin scheduler over abstract work units.
#[derive(Debug)]
pub struct Drr {
    quantum: u64,
    flows: Vec<Flow>,
}

impl Drr {
    /// `quantum` is the per-round credit of a weight-1 flow; it bounds
    /// per-round unfairness (a flow can overdraw its share by at most
    /// one quantum).  Clamped to ≥ 1 so every backlogged flow always
    /// makes progress.
    pub fn new(quantum: u64) -> Self {
        Drr { quantum: quantum.max(1), flows: Vec::new() }
    }

    /// Register a flow (idempotent on `id`).  Weight is clamped to ≥ 1:
    /// a zero-weight flow would starve, and starvation-freedom is the
    /// scheduler's contract.
    pub fn add(&mut self, id: u64, weight: u64) {
        if self.flows.iter().any(|f| f.id == id) {
            return;
        }
        self.flows.push(Flow { id, weight: weight.max(1), deficit: 0, pending: 0 });
    }

    /// Deregister a flow; its pending work and credit vanish with it.
    pub fn remove(&mut self, id: u64) {
        self.flows.retain(|f| f.id != id);
    }

    pub fn len(&self) -> usize {
        self.flows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.flows.is_empty()
    }

    /// Declare how many units `id` wants in the next [`Self::schedule`]
    /// call (overwrites the previous declaration).
    pub fn set_pending(&mut self, id: u64, units: u64) {
        if let Some(f) = self.flows.iter_mut().find(|f| f.id == id) {
            f.pending = units;
        }
    }

    /// Split `capacity` units across the backlogged flows.  Returns
    /// `(id, units)` grants in flow order (flows granted zero are
    /// omitted).  Work-conserving: the grant total is
    /// `min(capacity, Σ pending)` — deficits only shape *who* gets the
    /// units, never leave capacity idle while work is queued.
    pub fn schedule(&mut self, capacity: u64) -> Vec<(u64, u64)> {
        let mut granted: Vec<u64> = vec![0; self.flows.len()];
        let mut remaining = capacity;
        while remaining > 0 && self.flows.iter().any(|f| f.pending > 0) {
            for (i, f) in self.flows.iter_mut().enumerate() {
                if f.pending == 0 {
                    // Empty backlog forfeits accumulated credit (the
                    // classic DRR rule that stops idle flows bursting).
                    f.deficit = 0;
                    continue;
                }
                f.deficit = f.deficit.saturating_add(self.quantum * f.weight);
                let grant = f.deficit.min(f.pending).min(remaining);
                f.deficit -= grant;
                f.pending -= grant;
                remaining -= grant;
                granted[i] += grant;
                if f.pending == 0 {
                    f.deficit = 0;
                }
                if remaining == 0 {
                    break;
                }
            }
        }
        self.flows
            .iter()
            .zip(granted)
            .filter(|(_, g)| *g > 0)
            .map(|(f, g)| (f.id, g))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grant_of(grants: &[(u64, u64)], id: u64) -> u64 {
        grants.iter().find(|(i, _)| *i == id).map_or(0, |(_, g)| *g)
    }

    #[test]
    fn work_conserving_and_demand_capped() {
        let mut d = Drr::new(4);
        d.add(1, 1);
        d.add(2, 1);
        // Spare capacity: everyone gets exactly what they asked.
        d.set_pending(1, 10);
        d.set_pending(2, 3);
        let g = d.schedule(100);
        assert_eq!(grant_of(&g, 1), 10);
        assert_eq!(grant_of(&g, 2), 3);
        // Scarce capacity: the total is exactly the capacity.
        d.set_pending(1, 100);
        d.set_pending(2, 100);
        let g = d.schedule(50);
        assert_eq!(g.iter().map(|(_, u)| u).sum::<u64>(), 50);
        // One idle flow: the busy one takes the whole pool.
        d.set_pending(1, 0);
        d.set_pending(2, 80);
        let g = d.schedule(64);
        assert_eq!(grant_of(&g, 1), 0);
        assert_eq!(grant_of(&g, 2), 64);
    }

    #[test]
    fn equal_weights_split_scarce_capacity_evenly() {
        let mut d = Drr::new(4);
        d.add(1, 1);
        d.add(2, 1);
        let (mut total1, mut total2) = (0u64, 0u64);
        for _ in 0..100 {
            d.set_pending(1, 1_000);
            d.set_pending(2, 1_000);
            let g = d.schedule(64);
            total1 += grant_of(&g, 1);
            total2 += grant_of(&g, 2);
        }
        // A greedy backlog on both sides ends in an even split to
        // within one quantum of rounding.
        assert!(total1.abs_diff(total2) <= 4, "{total1} vs {total2}");
        assert_eq!(total1 + total2, 6_400);
    }

    #[test]
    fn weights_shape_the_split_proportionally() {
        let mut d = Drr::new(4);
        d.add(1, 2);
        d.add(2, 1);
        let (mut total1, mut total2) = (0u64, 0u64);
        for _ in 0..100 {
            d.set_pending(1, 1_000);
            d.set_pending(2, 1_000);
            let g = d.schedule(60);
            total1 += grant_of(&g, 1);
            total2 += grant_of(&g, 2);
        }
        let ratio = total1 as f64 / total2 as f64;
        assert!((ratio - 2.0).abs() < 0.1, "2:1 weights gave {ratio}");
    }

    #[test]
    fn a_large_job_cannot_monopolize_and_nobody_starves() {
        let mut d = Drr::new(4);
        d.add(1, 1); // elephant
        d.add(2, 1); // mouse
        for round in 0..50 {
            d.set_pending(1, 1_000_000);
            d.set_pending(2, 8);
            let g = d.schedule(64);
            // The mouse's whole (small) demand is met every round even
            // though the elephant could absorb the pool many times over.
            assert_eq!(grant_of(&g, 2), 8, "round {round}: mouse starved");
            assert_eq!(grant_of(&g, 1), 56, "round {round}: capacity leaked");
        }
    }

    #[test]
    fn idle_flows_forfeit_credit_instead_of_bursting() {
        let mut d = Drr::new(4);
        d.add(1, 1);
        d.add(2, 1);
        // Flow 2 idles for many rounds while 1 works.
        for _ in 0..50 {
            d.set_pending(1, 100);
            d.set_pending(2, 0);
            d.schedule(16);
        }
        // When 2 wakes up it competes from zero credit: the split of a
        // contended round is even, not a 50-round burst for flow 2.
        d.set_pending(1, 1_000);
        d.set_pending(2, 1_000);
        let g = d.schedule(64);
        assert!(grant_of(&g, 2) <= 36, "idle flow burst past its share: {g:?}");
    }

    #[test]
    fn add_remove_are_idempotent_and_scoped() {
        let mut d = Drr::new(4);
        d.add(7, 1);
        d.add(7, 3); // ignored: id already present
        assert_eq!(d.len(), 1);
        d.set_pending(7, 5);
        assert_eq!(d.schedule(10), vec![(7, 5)]);
        d.remove(7);
        assert!(d.is_empty());
        assert!(d.schedule(10).is_empty());
        // set_pending on an unknown id is a no-op, not a panic.
        d.set_pending(9, 5);
        assert!(d.schedule(10).is_empty());
    }
}
