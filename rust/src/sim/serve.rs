//! Closed-form shared-tier model for multi-tenant serve mode: given N
//! jobs sharing one prep cache and one worker pool, predict each job's
//! steady-state hit rate and goodput, and decide whether admitting one
//! more job would push any tenant below its goodput floor.
//!
//! The model composes two pieces the repo already trusts:
//!
//! * the PR 2 closed-form cache model
//!   ([`steady_state_hit_rate`](crate::pipeline::prep_cache::steady_state_hit_rate)),
//!   applied to each job's *quota slice* (cache ÷ N under the fair
//!   rebalance the registry enforces);
//! * max-min fair sharing of the pool's work capacity (a continuous
//!   stand-in for the engine's deficit round-robin), via [`water_fill`].
//!
//! Admission control calls [`admissible`] with the currently running
//! jobs plus the candidate; the service engine cross-checks the
//! prediction against its discrete round-based execution (the
//! `tests/serve.rs` gate), and the unit test here cross-checks the
//! closed form against a literal round-by-round allocator.

use crate::pipeline::prep_cache::{steady_state_hit_rate, PrepCachePolicy};

/// One tenant, as the cost model sees it.
#[derive(Clone, Copy, Debug)]
pub struct TenantJob {
    /// Decoded working-set size the job cycles through each epoch.
    pub dataset_bytes: f64,
    /// Items per tick the job's trainer can consume (its goodput when
    /// preprocessing is never the bottleneck).
    pub demand_items: f64,
}

/// The shared preprocessing tier: one cache, one pool.
#[derive(Clone, Copy, Debug)]
pub struct SharedTier {
    /// Prep-cache budget shared by all tenants (split into per-job
    /// quota slices by the registry).
    pub cache_bytes: f64,
    /// Work units per tick the whole pool delivers.
    pub capacity_units: f64,
    /// Work units one cache-hit item costs (augment only).
    pub hit_cost: f64,
    /// Work units one cache-miss item costs (read+decode+augment).
    pub miss_cost: f64,
    pub policy: PrepCachePolicy,
}

/// Max-min fair allocation of `capacity` across `needs`: repeatedly
/// split the remaining capacity evenly over the still-unsatisfied
/// demands, cap each at its need, and recurse on the leftovers.  Jobs
/// asking less than the fair share get exactly what they asked; the
/// surplus is re-split among the rest — the continuous limit of the
/// engine's deficit round-robin under equal weights.
pub fn water_fill(capacity: f64, needs: &[f64]) -> Vec<f64> {
    let mut alloc = vec![0.0; needs.len()];
    let mut remaining = capacity.max(0.0);
    let mut active: Vec<usize> = (0..needs.len()).filter(|&i| needs[i] > 0.0).collect();
    while !active.is_empty() && remaining > 0.0 {
        let share = remaining / active.len() as f64;
        let satisfied: Vec<usize> = active
            .iter()
            .copied()
            .filter(|&i| needs[i] - alloc[i] <= share)
            .collect();
        if satisfied.is_empty() {
            // Everyone wants more than the fair share: split evenly.
            for &i in &active {
                alloc[i] += share;
            }
            break;
        }
        for &i in &satisfied {
            remaining -= needs[i] - alloc[i];
            alloc[i] = needs[i];
        }
        active.retain(|i| !satisfied.contains(i));
    }
    alloc
}

/// Per-job steady-state hit rate when the cache is split into equal
/// quota slices, one per job (the registry's fair rebalance).
pub fn quota_hit_rates(tier: &SharedTier, jobs: &[TenantJob]) -> Vec<f64> {
    if jobs.is_empty() {
        return Vec::new();
    }
    let slice = tier.cache_bytes / jobs.len() as f64;
    jobs.iter()
        .map(|j| steady_state_hit_rate(tier.policy, slice, j.dataset_bytes))
        .collect()
}

/// Expected work units per item at hit rate `h`.
fn unit_cost(tier: &SharedTier, h: f64) -> f64 {
    (h * tier.hit_cost + (1.0 - h) * tier.miss_cost).max(f64::MIN_POSITIVE)
}

/// Predicted goodput (items per tick) of each job when all of `jobs`
/// share the tier: per-quota hit rates set each job's unit cost, demand
/// converts to a work-unit need, the pool capacity is water-filled over
/// the needs, and the allocation converts back to items.
pub fn shared_goodputs(tier: &SharedTier, jobs: &[TenantJob]) -> Vec<f64> {
    let hits = quota_hit_rates(tier, jobs);
    let costs: Vec<f64> = hits.iter().map(|&h| unit_cost(tier, h)).collect();
    let needs: Vec<f64> = jobs
        .iter()
        .zip(&costs)
        .map(|(j, &c)| j.demand_items * c)
        .collect();
    let alloc = water_fill(tier.capacity_units, &needs);
    alloc.iter().zip(&costs).map(|(&a, &c)| a / c).collect()
}

/// Goodput the job would get with the tier to itself (full cache, full
/// pool) — the denominator of the floor check.
pub fn standalone_goodput(tier: &SharedTier, job: &TenantJob) -> f64 {
    let h = steady_state_hit_rate(tier.policy, tier.cache_bytes, job.dataset_bytes);
    let c = unit_cost(tier, h);
    job.demand_items.min(tier.capacity_units / c)
}

/// Admission predicate: every job in `jobs` (the running set plus the
/// candidate) must keep at least `floor` × its standalone goodput.
/// `floor` in (0, 1]; a floor of 1 admits only jobs that lose nothing
/// to sharing.
pub fn admissible(tier: &SharedTier, jobs: &[TenantJob], floor: f64) -> bool {
    let shared = shared_goodputs(tier, jobs);
    jobs.iter().zip(&shared).all(|(j, &g)| {
        let alone = standalone_goodput(tier, j);
        alone <= 0.0 || g + 1e-9 >= floor * alone
    })
}

/// Largest N ≤ `cap` such that N copies of `job` are jointly
/// admissible.  For identical jobs both the per-slice hit rate and the
/// fair share shrink monotonically with N, so the admissible set is a
/// prefix and a linear scan finds its edge.
pub fn max_admissible_jobs(tier: &SharedTier, job: &TenantJob, floor: f64, cap: usize) -> usize {
    let mut best = 0;
    for n in 1..=cap {
        let jobs = vec![*job; n];
        if admissible(tier, &jobs, floor) {
            best = n;
        } else {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tier() -> SharedTier {
        SharedTier {
            cache_bytes: 4e6,
            capacity_units: 256.0,
            hit_cost: 1.0,
            miss_cost: 8.0,
            policy: PrepCachePolicy::Minio,
        }
    }

    #[test]
    fn water_fill_is_fair_and_work_conserving() {
        // Plenty of capacity: everyone is satisfied exactly.
        let a = water_fill(100.0, &[10.0, 20.0, 30.0]);
        assert_eq!(a, vec![10.0, 20.0, 30.0]);
        // Scarce capacity, equal demands: even split.
        let a = water_fill(30.0, &[100.0, 100.0, 100.0]);
        assert_eq!(a, vec![10.0, 10.0, 10.0]);
        // A small demand is capped at its need; the surplus goes to the
        // big ones (max-min fairness), and nothing is wasted.
        let a = water_fill(90.0, &[10.0, 100.0, 100.0]);
        assert!((a[0] - 10.0).abs() < 1e-9);
        assert!((a[1] - 40.0).abs() < 1e-9 && (a[2] - 40.0).abs() < 1e-9);
        let total: f64 = a.iter().sum();
        assert!((total - 90.0).abs() < 1e-9, "work-conserving");
        // Zero-demand jobs get nothing and absorb nothing.
        let a = water_fill(50.0, &[0.0, 25.0]);
        assert_eq!(a, vec![0.0, 25.0]);
    }

    #[test]
    fn hit_rate_and_goodput_degrade_monotonically_with_job_count() {
        let t = tier();
        let job = TenantJob { dataset_bytes: 8e6, demand_items: 64.0 };
        let mut prev_h = f64::INFINITY;
        let mut prev_g = f64::INFINITY;
        for n in 1..=8 {
            let jobs = vec![job; n];
            let h = quota_hit_rates(&t, &jobs)[0];
            let g = shared_goodputs(&t, &jobs)[0];
            assert!(h <= prev_h + 1e-12, "hit rate rose at n={n}");
            assert!(g <= prev_g + 1e-12, "goodput rose at n={n}");
            prev_h = h;
            prev_g = g;
        }
        // A dataset that fits its slice at n=2 hits perfectly there.
        let small = TenantJob { dataset_bytes: 1e6, demand_items: 8.0 };
        let h = quota_hit_rates(&t, &[small, small])[0];
        assert!((h - 1.0).abs() < 1e-12);
    }

    #[test]
    fn standalone_goodput_is_demand_or_capacity_bound() {
        let t = tier();
        // Tiny dataset: hit rate 1, cost 1 — demand-bound.
        let small = TenantJob { dataset_bytes: 1e6, demand_items: 16.0 };
        assert!((standalone_goodput(&t, &small) - 16.0).abs() < 1e-9);
        // Huge demand: capacity-bound at capacity / unit cost.
        let greedy = TenantJob { dataset_bytes: 1e6, demand_items: 1e9 };
        assert!((standalone_goodput(&t, &greedy) - 256.0).abs() < 1e-9);
    }

    /// Cross-check the closed form against a literal round-based
    /// allocator: each round splits the pool's units evenly over the
    /// jobs (the DRR limit for equal weights), each job converts its
    /// units to items at the quota-slice hit rate's expected cost, and
    /// measured goodput over many rounds must match the prediction —
    /// so the admission threshold derived from either agrees within
    /// one job.
    #[test]
    fn discrete_rounds_confirm_the_closed_form_and_admission_edge() {
        let t = tier();
        let job = TenantJob { dataset_bytes: 16e6, demand_items: 48.0 };
        for n in 1..=6usize {
            let jobs = vec![job; n];
            let predicted = shared_goodputs(&t, &jobs)[0];
            let h = quota_hit_rates(&t, &jobs)[0];
            let cost = h * t.hit_cost + (1.0 - h) * t.miss_cost;
            // Discrete rounds: fair share of units, demand-capped items.
            let rounds = 1000;
            let mut items = 0.0;
            for _ in 0..rounds {
                let share = t.capacity_units / n as f64;
                items += (share / cost).min(job.demand_items);
            }
            let measured = items / rounds as f64;
            let rel = (measured - predicted).abs() / predicted.max(1e-9);
            assert!(rel < 0.01, "n={n}: measured {measured} vs predicted {predicted}");
        }
        // The admission edge from the closed form matches the edge a
        // direct floor-check over the discrete goodputs would pick.
        let floor = 0.5;
        let n_star = max_admissible_jobs(&t, &job, floor, 16);
        assert!(n_star >= 1, "at least the first job must be admissible");
        let alone = standalone_goodput(&t, &job);
        for n in 1..=n_star {
            let g = shared_goodputs(&t, &vec![job; n])[0];
            assert!(g + 1e-9 >= floor * alone, "n={n} admitted but below floor");
        }
        let over = shared_goodputs(&t, &vec![job; n_star + 1])[0];
        assert!(over < floor * alone + 1e-9, "n*+1 should violate the floor");
    }

    #[test]
    fn lru_policy_prices_slices_more_pessimistically_than_minio() {
        let mut t = tier();
        let job = TenantJob { dataset_bytes: 12e6, demand_items: 64.0 };
        let minio = quota_hit_rates(&t, &[job, job])[0];
        t.policy = PrepCachePolicy::Lru;
        let lru = quota_hit_rates(&t, &[job, job])[0];
        assert!(lru < minio, "LRU slice must price below MinIO ({lru} vs {minio})");
    }
}
