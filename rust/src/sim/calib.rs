//! Calibration of the testbed model (paper §3.1 hardware: AWS p3.16xlarge,
//! 8×V100, 64 vCPU, EBS; §4 adds p3dn.24xlarge).
//!
//! Primary anchors from the paper:
//! * Fig. 3 — preprocessing one image on one vCPU costs **14.26 ms**, with
//!   decode = **47.7 %**; we split decode into an *entropy* part (serial,
//!   stays on CPU under DALI's hybrid mode, like nvJPEG's Huffman stage)
//!   and a *transform* part (offloadable dequant+IDCT).
//! * Fig. 5a — AlexNet/4 GPU: hybrid saturates at 24 vCPU, hybrid-0 at 44,
//!   hybrid-0 wins by 7.86 %.
//! * Fig. 5b — ResNet50/8 GPU: hybrid saturates at 16 vCPU, cpu at 48,
//!   cpu wins by 3.03 %.
//! * Fig. 2 — AlexNet record-hybrid peaks at 23 % of ideal; hybrid beats
//!   record-cpu by 98–114 % for the three fast consumers.
//! * Fig. 6 — DRAM: 1.84× for AlexNet, +8.8 % for ResNet18 (p3dn, 4 GPU).
//!
//! The constants below were solved jointly from those anchors (see
//! EXPERIMENTS.md for the residuals — e.g. hybrid-0's saturation lands at
//! ~30 vCPU where the paper reads 44; everything else is within a few %).
//! The model:
//!
//! ```text
//!  T = min( gpus / (t_train + g_visible),        — device cap
//!           eff(vcpus) / c_cpu,                  — CPU cap
//!           storage_bandwidth / image_bytes,     — sequential I/O cap
//!           iops                 [raw method] )  — random I/O cap
//! ```
//! with `g_visible = g · min(1, T_REF / t_train)` modelling how GPU-side
//! preprocessing hides inside long training kernels (ResNet50 barely sees
//! it; AlexNet pays it in full), and `eff(n)` a NUMA knee at 48 vCPUs.

/// Full CPU preprocessing cost of one image on one vCPU (paper Fig. 3).
pub const CPU_PREPROC_MS: f64 = 14.26;

/// Fig. 3 operator shares of `CPU_PREPROC_MS` (sum = 1.0).
pub const SHARE_READ: f64 = 0.050;
/// Entropy (Huffman-like) half of decode — serial, CPU-resident in hybrid.
pub const SHARE_ENTROPY: f64 = 0.402;
/// Transform half of decode (dequant+IDCT) — offloaded in hybrid.
pub const SHARE_XFORM: f64 = 0.075;
pub const SHARE_CROP: f64 = 0.095;
pub const SHARE_RESIZE: f64 = 0.173;
pub const SHARE_FLIP: f64 = 0.065;
pub const SHARE_NORM: f64 = 0.140;

/// Decode share (entropy + transform) = 47.7 % (paper Fig. 3).
pub const SHARE_DECODE: f64 = SHARE_ENTROPY + SHARE_XFORM;
/// Augmentation share (crop+resize+flip+normalize).
pub const SHARE_AUG: f64 = SHARE_CROP + SHARE_RESIZE + SHARE_FLIP + SHARE_NORM;

/// GPU-side preprocessing cost per image, hybrid placement (xform + aug).
pub const GPU_HYBRID_PRE_MS: f64 = 0.825;
/// GPU-side preprocessing cost per image, hybrid-0 placement (aug only).
pub const GPU_AUG_PRE_MS: f64 = 0.747;
/// Reference training time for the preproc-overlap model (≈ AlexNet's).
pub const OVERLAP_REF_MS: f64 = 0.25;

/// Extra CPU cost per image for the raw method (per-file metadata lookup
/// + open + random read issue) — 2× the read share.
pub const RAW_EXTRA_CPU_MS: f64 = 2.0 * SHARE_READ * CPU_PREPROC_MS;

/// Fraction of an image's 8×8 blocks the fused ROI decode dequant+IDCTs
/// under the RandomResizedCrop distribution (area ∈ [0.35, 1.0], aspect
/// ∈ [3/4, 4/3], uniform placement): the expected block-aligned cover of
/// the crop.  Only the transform (`SHARE_XFORM`) thins — the entropy
/// walk still visits every block to skip it (we conservatively charge
/// `skip_block` at full entropy cost).  Validated against the engine's
/// measured plan fraction in `tests/fused_decode.rs` (within 20%).
pub const FUSED_BLOCK_FRACTION: f64 = 0.85;

/// Collate-copy share of the cpu hot path's per-sample memory traffic.
/// The per-sample `Vec` path writes each decoded pixel four ways in
/// bytes-per-pixel terms — the u8 decode plane (1 B/px), the f32
/// conversion (4), the augment output (4), and the collate batch memcpy
/// (4) — so the collate copy is 4/13 of the hot-path write traffic.
/// `--slab-pool` eliminates exactly that write (augment lands directly
/// in the batch slot), and the sim thins the transform share by this
/// fraction when modeling the slab engine (augmentation at paper scale
/// is memory-bandwidth-bound, so traffic share ≈ time share).
/// Validated against the engine's measured per-sample traffic in
/// `dpp bench alloc` within 20% — the bench geometry decodes 64×64 into
/// 56×56 rather than 224×224 into 224×224, which shifts the measured
/// ratio a few points but must stay inside the band.
pub const COPY_SHARE: f64 = 4.0 / 13.0;

/// Measured speedups of the `--simd` kernel tier over the scalar
/// reference, from `dpp bench simd` on the dev box (AVX2), used by the
/// sim to thin the corresponding Fig. 3 shares when modeling `--simd
/// on`.  Calibrated like `COPY_SHARE`/`FUSED_BLOCK_FRACTION`: a single
/// committed number per share, validated against the live bench's
/// regression gate (BENCH_simd.json, +10% band) rather than remeasured
/// per run.
///
/// Entropy decode: the 64-bit-window + flat-class-table reader is
/// refill-bound, not lane-parallel, so its gain is the smallest.
pub const SIMD_ENTROPY_SPEEDUP: f64 = 1.5;
/// Dequant+IDCT transform: 8-lane row/column passes (the bench's
/// >=2x-at-AVX2 gate, plus headroom measured on dense blocks).
pub const SIMD_XFORM_SPEEDUP: f64 = 2.6;
/// Resize+normalize (the vectorizable augment sub-shares; crop and flip
/// are index shuffles the vector ISA does not help): fused
/// gather-bilerp-normalize rows at 8 pixels per iteration.
pub const SIMD_AUG_SPEEDUP: f64 = 2.3;

/// Mean encoded image size (ImageNet-train JPEG average ≈ 110 KB).
pub const IMG_BYTES: f64 = 110_000.0;

/// Decoded (post-decode, pre-augment) sample size at paper scale:
/// 3×224×224 f32 pixels — what the decoded-sample cache holds per image.
pub const DECODED_SAMPLE_BYTES: f64 = (3 * 224 * 224 * 4) as f64;

/// ImageNet-1k train-set size — the corpus the paper's testbed trains on,
/// and the denominator of the decoded-cache hit-rate model.
pub const DATASET_IMAGES: f64 = 1_281_167.0;

/// Decoded size of the full corpus (≈ 770 GB): a half-corpus decoded
/// cache is a few hundred GB of DRAM, which the auto-configurator prices
/// against simply hosting the *encoded* data on a faster storage tier.
pub fn decoded_dataset_bytes() -> f64 {
    DATASET_IMAGES * DECODED_SAMPLE_BYTES
}

/// vCPU scaling: linear to the NUMA knee, 0.3 marginal efficiency beyond
/// (two-socket E5-2686v4; data-loading workers contend for memory bw).
pub const VCPU_KNEE: f64 = 48.0;
pub const VCPU_SLOPE_BEYOND: f64 = 0.3;

pub fn eff_vcpus(n: f64) -> f64 {
    if n <= VCPU_KNEE {
        n
    } else {
        VCPU_KNEE + VCPU_SLOPE_BEYOND * (n - VCPU_KNEE)
    }
}

/// Per-model calibration: training time per image per V100 (FP16, the
/// paper's batch sizes).  Solved from the Fig. 2 ideal bars + Fig. 5/6
/// anchors; relative speeds follow the models' FLOP counts.
#[derive(Clone, Copy, Debug)]
pub struct ModelCalib {
    pub name: &'static str,
    pub t_train_ms: f64,
    /// The paper's batch size for this model (§3.1).
    pub batch: usize,
    /// Approx GPU memory per sample at FP16+activations, MB — drives the
    /// OOM model of §2.2.3.
    pub mem_mb_per_sample: f64,
}

pub const MODELS: [ModelCalib; 5] = [
    ModelCalib { name: "alexnet", t_train_ms: 0.25, batch: 512, mem_mb_per_sample: 9.0 },
    ModelCalib { name: "shufflenet", t_train_ms: 0.32, batch: 512, mem_mb_per_sample: 12.0 },
    ModelCalib { name: "resnet18", t_train_ms: 0.45, batch: 512, mem_mb_per_sample: 14.0 },
    ModelCalib { name: "resnet50", t_train_ms: 2.38, batch: 192, mem_mb_per_sample: 58.0 },
    ModelCalib { name: "resnet152", t_train_ms: 5.50, batch: 128, mem_mb_per_sample: 95.0 },
];

pub fn model(name: &str) -> Option<ModelCalib> {
    MODELS.iter().find(|m| m.name == name).copied()
}

/// V100 memory (GB) for the OOM model.
pub const GPU_MEM_GB: f64 = 16.0;
/// GPU memory DALI's device-side preprocessing claims per sample (MB):
/// decoded 224×224×3 FP32 intermediates ×4 pipeline stages.
pub const HYBRID_MEM_MB_PER_SAMPLE: f64 = 2.4;

/// Does (model, batch, placement-uses-device) fit in GPU memory?
/// Reproduces §2.2.3: ResNet18 @ 512 FP32 with hybrid OOMs; 384 fits.
pub fn fits_gpu_mem(m: &ModelCalib, batch: usize, hybrid: bool, fp32: bool) -> bool {
    let scale = if fp32 { 2.0 } else { 1.0 };
    let train = m.mem_mb_per_sample * scale * batch as f64;
    let pre = if hybrid { HYBRID_MEM_MB_PER_SAMPLE * scale * batch as f64 } else { 0.0 };
    let fixed = 1_500.0; // weights/optimizer/workspace
    train + pre + fixed < GPU_MEM_GB * 1024.0
}

/// Storage device models at paper scale.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StorageCalib {
    pub name: &'static str,
    pub seq_bw_mbs: f64,
    pub rand_iops: f64,
}

/// p3.16xlarge EBS (Figs. 2/4/5): sequential streaming can use the
/// instance-level EBS throughput (14 Gbps ≈ 1750 MB/s); the random-IOPS
/// number is the *effective* sustained rate for ~110 KB reads (the paper's
/// raw method is I/O bound near this — Fig. 2 discussion).
pub const EBS_P3: StorageCalib =
    StorageCalib { name: "ebs", seq_bw_mbs: 1750.0, rand_iops: 3000.0 };
/// p3dn.24xlarge volume (Fig. 6): a single gp2-class volume; the paper
/// observes EBS ≈ NVMe there.  Calibrated to Fig. 6's ResNet18 +8.8 %.
pub const EBS_P3DN: StorageCalib =
    StorageCalib { name: "ebs", seq_bw_mbs: 445.0, rand_iops: 7500.0 };

/// Fig. 6 calibration override: AlexNet's measured 1.84× DRAM speedup on
/// p3dn implies a far lower visible GPU-preproc cost there than Fig. 5a
/// implies on p3.16xlarge (the paper's figures are not jointly consistent
/// under one linear model — see EXPERIMENTS.md §Deviations).  We scale
/// AlexNet's GPU preprocessing cost on p3dn to match the measured ratio.
pub fn p3dn_gpu_pre_scale(model: &str) -> f64 {
    if model == "alexnet" {
        0.348
    } else {
        1.0
    }
}
pub const NVME_P3DN: StorageCalib =
    StorageCalib { name: "nvme", seq_bw_mbs: 450.0, rand_iops: 200_000.0 };
pub const DRAM: StorageCalib =
    StorageCalib { name: "dram", seq_bw_mbs: 60_000.0, rand_iops: 50_000_000.0 };

pub fn storage(name: &str, p3dn: bool) -> Option<StorageCalib> {
    match (name, p3dn) {
        ("ebs", false) => Some(EBS_P3),
        ("ebs", true) => Some(EBS_P3DN),
        ("nvme", _) => Some(NVME_P3DN),
        ("dram", _) => Some(DRAM),
        _ => None,
    }
}

/// Remote object-store tiers (`s3`, `s3-cold`) share one profile registry
/// with the real engine (`storage/remote.rs`): the analytic service-time
/// model and the sleep-based emulation are two views of the same numbers,
/// which is what keeps real and simulated remote runs comparable.
pub use crate::storage::remote::NetProfile;

pub fn remote(name: &str) -> Option<NetProfile> {
    NetProfile::by_name(name)
}

/// Ranged-GET part size the record loader issues against remote tiers
/// (matches `RunConfig::record_chunk`'s default of 1 MiB).
pub const REMOTE_PART_BYTES: f64 = (1u64 << 20) as f64;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_sum_to_one() {
        let s = SHARE_READ + SHARE_DECODE + SHARE_AUG;
        assert!((s - 1.0).abs() < 1e-9, "{s}");
        assert!((SHARE_DECODE - 0.477).abs() < 1e-9, "decode share must be 47.7%");
        assert!((0.0..=1.0).contains(&FUSED_BLOCK_FRACTION));
        // COPY_SHARE derives from the 1+4+4+4 bytes-per-pixel traffic
        // split — pin the closed form so a drive-by edit cannot silently
        // desynchronize it from the bench-alloc validation band.
        assert!((COPY_SHARE - 4.0 / 13.0).abs() < 1e-12);
        assert!((0.0..1.0).contains(&COPY_SHARE));
        // SIMD speedups are ratios > 1 (a value < 1 would model the
        // vector tier as a slowdown — a calibration typo, not a tune).
        for (name, s) in [
            ("entropy", SIMD_ENTROPY_SPEEDUP),
            ("xform", SIMD_XFORM_SPEEDUP),
            ("aug", SIMD_AUG_SPEEDUP),
        ] {
            assert!(s > 1.0 && s < 10.0, "SIMD_{name}_SPEEDUP = {s} out of range");
        }
    }

    #[test]
    fn eff_vcpus_knee() {
        assert_eq!(eff_vcpus(16.0), 16.0);
        assert_eq!(eff_vcpus(48.0), 48.0);
        assert!((eff_vcpus(64.0) - 52.8).abs() < 1e-9);
    }

    #[test]
    fn model_lookup() {
        assert!(model("alexnet").is_some());
        assert!(model("resnet50").unwrap().t_train_ms > model("resnet18").unwrap().t_train_ms);
        assert!(model("vgg").is_none());
    }

    #[test]
    fn oom_model_matches_paper_anecdote() {
        // §2.2.3: ResNet18, batch 512, FP32 + hybrid => OOM; 384 fits.
        let r18 = model("resnet18").unwrap();
        assert!(!fits_gpu_mem(&r18, 512, true, true), "512 FP32 hybrid must OOM");
        assert!(fits_gpu_mem(&r18, 384, true, true), "384 FP32 hybrid must fit");
        // FP16 at the paper's Fig. 2 batch sizes always fits.
        for m in &MODELS {
            assert!(fits_gpu_mem(m, m.batch, true, false), "{} fig2 config OOMs", m.name);
        }
    }

    #[test]
    fn storage_lookup() {
        assert_eq!(storage("ebs", false).unwrap().seq_bw_mbs, 1750.0);
        assert_eq!(storage("ebs", true).unwrap().seq_bw_mbs, 445.0);
        assert!(storage("dram", false).unwrap().seq_bw_mbs > 1000.0);
        assert!(storage("tape", false).is_none());
    }

    #[test]
    fn remote_lookup_is_disjoint_from_local() {
        assert_eq!(remote("s3").unwrap().name, "s3");
        assert_eq!(remote("s3-cold").unwrap().name, "s3-cold");
        for name in ["s3", "s3-cold"] {
            assert!(storage(name, false).is_none(), "{name} must not be a local tier");
        }
        for name in ["ebs", "nvme", "dram"] {
            assert!(remote(name).is_none(), "{name} must not be a remote tier");
        }
    }
}
