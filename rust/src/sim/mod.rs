//! Testbed simulator: reproduces the paper's evaluation at the paper's
//! scale (8×V100, 64 vCPU, ImageNet) — hardware we cannot run.
//!
//! Two solvers over one calibration (`calib`):
//! * [`analytic_throughput`] — closed-form steady-state bottleneck model
//!   (fast; used by the auto-configurator and the sweep benches).
//! * [`simulate`] — discrete-event simulation of the closed pipeline
//!   (storage → vCPU pool → batcher → GPUs), producing utilization time
//!   series (Fig. 4) and validating the analytic model against queueing
//!   effects.

pub mod calib;
pub mod des;
pub mod serve;

pub use des::{simulate, simulate_traced};

use crate::config::{Method, Placement};
use crate::metrics::trace::StallAttribution;
use crate::metrics::UtilSample;
use crate::pipeline::prep_cache::{self, PrepCachePolicy};
use crate::util::cli::Args;
use anyhow::{Context, Result};

/// One simulated experiment configuration.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub model: String,
    pub gpus: usize,
    pub vcpus: usize,
    pub method: Method,
    pub placement: Placement,
    /// Local device tier ("ebs"/"nvme"/"dram") or remote object-store
    /// tier ("s3"/"s3-cold").
    pub storage: String,
    /// Concurrent range-GET connections against a remote tier (ignored
    /// for local tiers).
    pub net_conns: usize,
    /// p3dn instance profile (Fig. 6) vs p3.16xlarge (Figs. 2/4/5).
    pub p3dn: bool,
    /// Ideal mode: single preloaded batch (no preprocessing at all).
    pub ideal: bool,
    /// Decoded-sample cache budget, GB (0 = disabled).  Models the
    /// steady state (epoch ≥ 2): decode and storage service times scale
    /// by `1 - hit_rate` with the MinIO-vs-LRU hit-rate model, so
    /// simulated multi-epoch runs stay comparable to real ones.
    pub prep_cache_gb: f64,
    pub prep_cache_policy: PrepCachePolicy,
    /// Fused ROI decode (`--fused-decode on`): the dequant+IDCT service
    /// share thins to the decoded-block fraction on the `cpu`/`hybrid0`
    /// paths.  Off by default — the sim's baseline is the paper's
    /// whole-image decoder; turn it on to model our fused engine.
    pub fused_decode: bool,
    /// Fused fractional-scale denominator (1|2|4|8): divides the
    /// remaining per-block transform cost by `scale²` and shrinks
    /// decoded cache entries by the same factor (`cpu` placement).
    ///
    /// NOTE: this is the *realized* per-image scale to model, not the
    /// engine's `--decode-scale` **cap** — the engine only scales when
    /// the crop/output geometry allows (`crop/2^k >= out`), and the sim
    /// has no image geometry to derive that from.  Read the achieved
    /// scale off a real run's `decode_scale_hist` and pass that here;
    /// passing an unachievable scale models a decoder the engine would
    /// not run, and the engine-vs-sim agreement contract is asserted
    /// only for the unscaled path.
    pub decode_scale: u8,
    /// Batch-slab pool (`--slab-pool on`): the cpu-placement transform
    /// share thins by the collate-copy fraction (`calib::COPY_SHARE`) —
    /// workers write augmented output straight into the batch slot, so
    /// the per-sample collate memcpy disappears.  Off by default: the
    /// sim's baseline is the paper's per-sample-buffer loader; turn it
    /// on to model our slab engine.
    pub slab_pool: bool,
    /// SIMD kernels (`--simd on`): the entropy, transform, and
    /// resize+normalize shares thin by the bench-calibrated speedups
    /// (`calib::SIMD_*_SPEEDUP`, measured by `dpp bench simd`).  Crop
    /// and flip are index shuffles the vector ISA does not help, so
    /// their shares are untouched.  Off by default: the sim's baseline
    /// is the paper's scalar loader; turn it on to model our kernels.
    pub simd: bool,
    /// Transient-fault rate on storage reads (`--faults transient=p` in
    /// the engine, with retries on): each faulted read is re-attempted,
    /// so the mean storage service time inflates by `1/(1-p)` — the
    /// storage ceiling scales by `(1-p)`.  Models only the retry-path
    /// capacity cost; backoff sleeps overlap other reads and are ignored.
    pub fault_rate: f64,
    /// Simulated duration in seconds (DES only).
    pub seconds: f64,
    pub seed: u64,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            model: "resnet50".into(),
            gpus: 8,
            vcpus: 64,
            method: Method::Record,
            placement: Placement::Hybrid,
            storage: "ebs".into(),
            net_conns: 8,
            p3dn: false,
            ideal: false,
            prep_cache_gb: 0.0,
            prep_cache_policy: PrepCachePolicy::Minio,
            fused_decode: false,
            decode_scale: 1,
            slab_pool: false,
            simd: false,
            fault_rate: 0.0,
            seconds: 60.0,
            seed: 7,
        }
    }
}

impl Scenario {
    pub fn from_args(args: &Args) -> Result<Scenario> {
        let mut s = Scenario::default();
        if let Some(m) = args.get("model") {
            s.model = m.to_string();
        }
        s.gpus = args.get_usize("gpus", s.gpus);
        s.vcpus = args.get_usize("vcpus", s.vcpus);
        if let Some(v) = args.get("method") {
            s.method = Method::parse(v)?;
        }
        if let Some(v) = args.get("placement") {
            s.placement = Placement::parse(v)?;
        }
        if let Some(v) = args.get("storage") {
            s.storage = v.to_string();
        }
        s.net_conns = args.get_usize("net-conns", s.net_conns);
        s.p3dn = args.has_flag("p3dn");
        s.ideal = args.has_flag("ideal");
        s.prep_cache_gb = args.get_f64("prep-cache-gb", s.prep_cache_gb);
        if let Some(v) = args.get("prep-cache-policy") {
            s.prep_cache_policy = PrepCachePolicy::parse(v)?;
        }
        if let Some(v) = args.get("fused-decode") {
            s.fused_decode = match v {
                "on" | "true" => true,
                "off" | "false" => false,
                _ => anyhow::bail!("fused-decode must be on|off, got {v}"),
            };
        }
        if let Some(v) = args.get("decode-scale") {
            s.decode_scale = v
                .parse()
                .map_err(|_| anyhow::anyhow!("sim decode-scale must be 1|2|4|8, got {v}"))?;
        }
        if let Some(v) = args.get("slab-pool") {
            s.slab_pool = match v {
                "on" | "true" => true,
                "off" | "false" => false,
                _ => anyhow::bail!("sim slab-pool must be on|off, got {v}"),
            };
        }
        if let Some(v) = args.get("simd") {
            s.simd = match v {
                "on" | "true" => true,
                "off" | "false" => false,
                _ => anyhow::bail!("sim simd must be on|off, got {v}"),
            };
        }
        s.fault_rate = args.get_f64("fault-rate", s.fault_rate);
        s.seconds = args.get_f64("seconds", s.seconds);
        s.seed = args.get_u64("seed", s.seed);
        s.validate()?;
        Ok(s)
    }

    pub fn validate(&self) -> Result<()> {
        calib::model(&self.model).with_context(|| format!("unknown sim model {}", self.model))?;
        if calib::storage(&self.storage, self.p3dn).is_none()
            && calib::remote(&self.storage).is_none()
        {
            anyhow::bail!("unknown sim storage {}", self.storage);
        }
        anyhow::ensure!(self.gpus >= 1 && self.vcpus >= 1, "need >=1 gpu and vcpu");
        anyhow::ensure!(self.net_conns >= 1, "need >=1 net connection");
        anyhow::ensure!(self.prep_cache_gb >= 0.0, "prep_cache_gb must be >= 0");
        anyhow::ensure!(
            matches!(self.decode_scale, 1 | 2 | 4 | 8),
            "decode_scale must be 1|2|4|8, got {}",
            self.decode_scale
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.fault_rate),
            "fault_rate must be in [0, 1), got {}",
            self.fault_rate
        );
        Ok(())
    }

    /// Steady-state (epoch ≥ 2) decoded-cache hit rate for this scenario
    /// — the same closed-form model the engine's cache converges to
    /// (`pipeline::prep_cache::steady_state_hit_rate`).  With the fused
    /// decoder's fractional scale, the `cpu` placement stores entries at
    /// `1/scale²` of full size — same DRAM, scale²× the resident
    /// fraction (exactly what the engine's admission path does).
    pub fn prep_cache_hit(&self) -> f64 {
        let mut dataset = calib::decoded_dataset_bytes();
        if self.fused_decode && self.placement == Placement::Cpu {
            let s = self.decode_scale as f64;
            dataset /= s * s;
        }
        prep_cache::steady_state_hit_rate(
            self.prep_cache_policy,
            self.prep_cache_gb * 1e9,
            dataset,
        )
    }

    /// CPU preprocessing cost per image (ms/vCPU) for this scenario.
    /// With a decoded-sample cache, a hit skips read+decode: under `cpu`
    /// only the augment share remains on the CPU; under the device
    /// placements a hit costs the CPU essentially nothing (the pixels go
    /// straight to collation).
    pub fn cpu_cost_ms(&self) -> f64 {
        // Fused ROI decode: the entropy walk still visits every block
        // (skip_block is charged at full entropy cost, conservatively),
        // but only the decoded-block fraction pays the dequant+IDCT, and
        // a fractional scale divides that per-block cost by scale².  The
        // scale applies on the cpu path only — hybrid0's device payload
        // shape pins it to full resolution, exactly like the engine.
        let xform_share = |scaled: bool| -> f64 {
            let mut x = calib::SHARE_XFORM;
            if self.fused_decode {
                x *= calib::FUSED_BLOCK_FRACTION;
                if scaled {
                    x /= (self.decode_scale as f64).powi(2);
                }
            }
            // SIMD: the vectorized dequant+IDCT thins whatever per-block
            // work the fused plan left (the two knobs compose).
            if self.simd {
                x /= calib::SIMD_XFORM_SPEEDUP;
            }
            x
        };
        // SIMD: the table-driven 64-bit-window entropy reader thins the
        // entropy walk in every placement (it always runs on the CPU).
        let entropy_share = if self.simd {
            calib::SHARE_ENTROPY / calib::SIMD_ENTROPY_SPEEDUP
        } else {
            calib::SHARE_ENTROPY
        };
        let base = match self.placement {
            Placement::Cpu => {
                (calib::SHARE_READ + entropy_share + xform_share(true) + self.aug_share())
                    * calib::CPU_PREPROC_MS
            }
            Placement::Hybrid => (calib::SHARE_READ + entropy_share) * calib::CPU_PREPROC_MS,
            Placement::Hybrid0 => {
                (calib::SHARE_READ + entropy_share + xform_share(false))
                    * calib::CPU_PREPROC_MS
            }
        };
        let miss_cost = match self.method {
            Method::Raw => base + calib::RAW_EXTRA_CPU_MS,
            Method::Record => base,
        };
        let hit = self.prep_cache_hit();
        // A cpu-placement hit still augments on the CPU, so the slab
        // pool's collate-copy saving applies to hits and misses alike.
        let hit_cost = match self.placement {
            Placement::Cpu => self.aug_share() * calib::CPU_PREPROC_MS,
            Placement::Hybrid | Placement::Hybrid0 => 0.0,
        };
        // Admission cost: a hybrid miss must run the cache-only
        // dequant+IDCT to produce pixels to admit.  Minio freezes once
        // full, so steady-state misses skip it (the engine's
        // `would_admit` refuses them); LRU re-admits every miss, paying
        // the transform forever — a small cache can make hybrid+lru
        // slower than no cache at all, in the engine and here alike.
        let admit_cost = match (self.placement, self.prep_cache_policy) {
            (Placement::Hybrid, PrepCachePolicy::Lru) if self.prep_cache_gb > 0.0 => {
                // The cache-only dequant+IDCT is a CPU transform too, so
                // the SIMD kernels thin it the same way.
                let x = if self.simd {
                    calib::SHARE_XFORM / calib::SIMD_XFORM_SPEEDUP
                } else {
                    calib::SHARE_XFORM
                };
                x * calib::CPU_PREPROC_MS
            }
            _ => 0.0,
        };
        (1.0 - hit) * (miss_cost + admit_cost) + hit * hit_cost
    }

    /// CPU augment share for this scenario: with the slab pool on, the
    /// transform share thins by the collate-copy fraction — the batch
    /// memcpy the zero-copy hot path no longer performs.  Only the cpu
    /// placement carries an augment share on the CPU, so the device
    /// placements are modeled no-ops (exactly like the engine, whose
    /// slab path exists only where the CPU hand-off is the final
    /// tensor).
    fn aug_share(&self) -> f64 {
        // SIMD thins only the lane-parallel augment sub-shares (the
        // fused resize+normalize rows); crop and flip are index
        // shuffles the vector ISA does not accelerate.
        let aug = if self.simd {
            calib::SHARE_CROP
                + calib::SHARE_FLIP
                + (calib::SHARE_RESIZE + calib::SHARE_NORM) / calib::SIMD_AUG_SPEEDUP
        } else {
            calib::SHARE_AUG
        };
        if self.slab_pool {
            aug * (1.0 - calib::COPY_SHARE)
        } else {
            aug
        }
    }

    /// Visible GPU preprocessing cost per image (ms): the raw kernel cost
    /// scaled by how little of it hides behind this model's training
    /// kernels (long ResNet50 kernels hide nearly all of it).
    pub fn gpu_pre_ms(&self) -> f64 {
        let m = calib::model(&self.model).expect("validated");
        let g = match self.placement {
            Placement::Cpu => 0.0,
            // Cache hits under hybrid take the hybrid0 path on the device
            // (augment only, no dequant+IDCT) — blend by the hit rate.
            Placement::Hybrid => {
                let hit = self.prep_cache_hit();
                (1.0 - hit) * calib::GPU_HYBRID_PRE_MS + hit * calib::GPU_AUG_PRE_MS
            }
            Placement::Hybrid0 => calib::GPU_AUG_PRE_MS,
        };
        let scale = if self.p3dn { calib::p3dn_gpu_pre_scale(&self.model) } else { 1.0 };
        g * scale * (calib::OVERLAP_REF_MS / m.t_train_ms).min(1.0)
    }

    /// Per-image service time on one GPU (train + visible preproc), ms.
    pub fn gpu_cost_ms(&self) -> f64 {
        let m = calib::model(&self.model).expect("validated");
        m.t_train_ms + self.gpu_pre_ms()
    }

    /// Storage throughput ceiling, images/s.  Under the raw method a
    /// decoded-cache hit skips the per-file GET, so the ceiling on
    /// *delivered* images rises by `1 / (1 - hit_rate)` (unbounded when
    /// the whole corpus is cached).  Record streaming reads whole shards
    /// sequentially regardless of which samples are resident — exactly
    /// what the engine does — so its storage demand is NOT reduced; only
    /// the decode is amortized.
    pub fn storage_cap_ips(&self) -> f64 {
        // Transient faults under retry: a read draws a fault with
        // probability p and re-issues, so the device serves `1/(1-p)`
        // attempts per delivered image — the ceiling thins by `(1-p)`.
        let fault_scale = 1.0 - self.fault_rate;
        if self.method != Method::Raw {
            return self.storage_cap_ips_cold() * fault_scale;
        }
        let hit = self.prep_cache_hit();
        if hit >= 1.0 {
            // Fully resident corpus: storage (and its faults) are out of
            // the picture entirely.
            return f64::INFINITY;
        }
        self.storage_cap_ips_cold() / (1.0 - hit) * fault_scale
    }

    /// Storage ceiling without the decoded cache (every image fetched).
    fn storage_cap_ips_cold(&self) -> f64 {
        if let Some(net) = calib::remote(&self.storage) {
            return match self.method {
                // Record shards stream as part-sized ranged GETs fanned
                // across `net_conns` connections: latency overlaps until
                // the aggregate-bandwidth or request-rate ceiling binds
                // (same formula the real engine's emulation converges to).
                Method::Record => {
                    net.throughput_bps(self.net_conns, calib::REMOTE_PART_BYTES as u64)
                        / calib::IMG_BYTES
                }
                // Raw method: one GET per image — every small request pays
                // the full first-byte latency and the GET-rate cap binds,
                // the remote analogue of being IOPS-bound.
                Method::Raw => {
                    let conns = self.net_conns.max(1).min(net.max_conns.max(1)) as f64;
                    let mut ips = conns / net.request_time(calib::IMG_BYTES as u64);
                    if net.max_rps > 0.0 {
                        ips = ips.min(net.max_rps);
                    }
                    ips.min(net.agg_bw / calib::IMG_BYTES)
                }
            };
        }
        let st = calib::storage(&self.storage, self.p3dn).expect("validated");
        let bw_cap = st.seq_bw_mbs * 1e6 / calib::IMG_BYTES;
        match self.method {
            Method::Record => bw_cap,
            Method::Raw => bw_cap.min(st.rand_iops),
        }
    }
}

/// The elastic executor's fixed point for primitive service rates: the
/// fewest workers whose combined stage throughput matches what the rest
/// of the pipeline can absorb (`sink_ips`), clamped to the pool bounds.
///
/// Why this is the controller's fixed point: below `ceil(sink·c)` workers
/// the sample queue runs empty and the batcher starves every interval
/// (the controller adds); above it workers demonstrably wait — starved
/// by the source or blocked by the full sample queue (the controller
/// parks).  Neither signal fires exactly at the match point, so the hill
/// climb settles there.  An unbounded sink (`inf`) means preprocessing
/// itself is the bottleneck: the pool pegs at `workers_max`.
///
/// This knee-free primitive is what `tests/elastic_exec.rs` checks the
/// real executor against (engine `workers_final` within ±1); the
/// paper-scale wrapper with the NUMA knee is
/// [`Scenario::autoscale_workers`].
pub fn workers_fixed_point(
    stage_ms_per_item: f64,
    sink_ips: f64,
    workers_min: usize,
    workers_max: usize,
) -> usize {
    let lo = workers_min.max(1);
    let hi = workers_max.max(lo);
    if stage_ms_per_item <= 0.0 {
        return lo;
    }
    if !sink_ips.is_finite() {
        return hi;
    }
    let need = (sink_ips * stage_ms_per_item / 1000.0).ceil() as usize;
    need.clamp(lo, hi)
}

impl Scenario {
    /// What `--workers auto` converges to on this scenario: the vCPU
    /// count matching the device/storage rate, via the same fixed-point
    /// argument as [`workers_fixed_point`] but through the calibrated
    /// vCPU-efficiency knee (beyond [`calib::VCPU_KNEE`] each nominal
    /// worker only delivers [`calib::VCPU_SLOPE_BEYOND`] of capacity, so
    /// more nominal workers are needed per unit of demand).
    pub fn autoscale_workers(&self, workers_min: usize, workers_max: usize) -> usize {
        let lo = workers_min.max(1);
        let hi = workers_max.max(lo);
        let gpu_cap = self.gpus as f64 / (self.gpu_cost_ms() / 1000.0);
        let sink = gpu_cap.min(self.storage_cap_ips());
        if !sink.is_finite() {
            return hi;
        }
        // Effective workers demanded, then inverted through eff_vcpus.
        let need_eff = sink * self.cpu_cost_ms() / 1000.0;
        let need = if need_eff <= calib::VCPU_KNEE {
            need_eff
        } else {
            calib::VCPU_KNEE + (need_eff - calib::VCPU_KNEE) / calib::VCPU_SLOPE_BEYOND
        };
        (need.ceil() as usize).clamp(lo, hi)
    }
}

/// Steady-state end-to-end throughput (images/s): bottleneck of the three
/// resources.  Ideal mode bypasses preprocessing and storage entirely.
pub fn analytic_throughput(s: &Scenario) -> f64 {
    let m = calib::model(&s.model).expect("validated scenario");
    if s.ideal {
        return s.gpus as f64 / (m.t_train_ms / 1000.0);
    }
    let gpu_cap = s.gpus as f64 / (s.gpu_cost_ms() / 1000.0);
    let cpu_cap = calib::eff_vcpus(s.vcpus as f64) / (s.cpu_cost_ms() / 1000.0);
    gpu_cap.min(cpu_cap).min(s.storage_cap_ips())
}

/// Closed-form stall attribution for the analytic model, in the same
/// vocabulary the engine's run report uses (DS-Analyzer): `compute` is
/// the GPUs' busy share at the steady-state rate; `fetch` is the extra
/// stall the storage ceiling adds on top of an infinitely fast storage
/// tier; `prep` absorbs the rest (the CPU transform limit).  Shares sum
/// to 1 by construction, so the engine's measured split and the DES's
/// can be compared against this per scenario.
pub fn stall_attribution_analytic(s: &Scenario) -> StallAttribution {
    if s.ideal {
        // Ideal mode bypasses storage and preprocessing: all compute.
        return StallAttribution { fetch: 0.0, prep: 0.0, compute: 1.0 };
    }
    let gpu_cap = s.gpus as f64 / (s.gpu_cost_ms() / 1000.0);
    let st_cap = s.storage_cap_ips();
    let t = analytic_throughput(s);
    // Busy share of the GPUs at the realized rate.
    let compute = (t / gpu_cap).clamp(0.0, 1.0);
    // Storage's marginal contribution to the stall: how much worse the
    // GPU's idle share gets when the storage ceiling is applied on top
    // of the compute ceiling alone.
    let fetch = ((t / gpu_cap.min(st_cap)).clamp(0.0, 1.0) - compute).max(0.0);
    let prep = (1.0 - compute - fetch).max(0.0);
    StallAttribution { fetch, prep, compute }
}

/// What limits this scenario?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bottleneck {
    Gpu,
    Cpu,
    Storage,
}

pub fn bottleneck(s: &Scenario) -> Bottleneck {
    let gpu_cap = s.gpus as f64 / (s.gpu_cost_ms() / 1000.0);
    let cpu_cap = calib::eff_vcpus(s.vcpus as f64) / (s.cpu_cost_ms() / 1000.0);
    let st = s.storage_cap_ips();
    if gpu_cap <= cpu_cap && gpu_cap <= st {
        Bottleneck::Gpu
    } else if cpu_cap <= st {
        Bottleneck::Cpu
    } else {
        Bottleneck::Storage
    }
}

/// DES output.
#[derive(Clone, Debug, Default)]
pub struct SimOutput {
    pub images_done: u64,
    pub throughput_ips: f64,
    pub cpu_util: f64,
    pub gpu_util: f64,
    pub io_mbps: f64,
    pub util_trace: Vec<UtilSample>,
    /// Measured wall-clock stall attribution (same vocabulary as the
    /// engine's run report and [`stall_attribution_analytic`]).
    pub stall: StallAttribution,
}

impl SimOutput {
    pub fn summary_line(&self, s: &Scenario) -> String {
        format!(
            "[sim {} {}/{} {} gpus={} vcpus={}] {:.0} img/s  cpu={:.0}% gpu={:.0}% io={:.0} MB/s",
            s.model,
            s.method.name(),
            s.placement.name(),
            s.storage,
            s.gpus,
            s.vcpus,
            self.throughput_ips,
            self.cpu_util * 100.0,
            self.gpu_util * 100.0,
            self.io_mbps
        )
    }
}

#[cfg(test)]
mod stall_tests {
    use super::*;
    use crate::config::{Method, Placement};

    fn fig2_scenarios() -> Vec<Scenario> {
        let mut v = Vec::new();
        for model in ["alexnet", "shufflenet", "resnet18", "resnet50", "resnet152"] {
            for pl in [Placement::Cpu, Placement::Hybrid] {
                for m in [Method::Record, Method::Raw] {
                    v.push(Scenario {
                        model: model.into(),
                        gpus: 8,
                        vcpus: 64,
                        placement: pl,
                        method: m,
                        ..Default::default()
                    });
                }
            }
        }
        v
    }

    #[test]
    fn analytic_attribution_sums_to_one_on_fig2_grid() {
        for s in fig2_scenarios() {
            let a = stall_attribution_analytic(&s);
            assert!(
                (a.sum() - 1.0).abs() < 1e-9,
                "{} {:?} {:?}: sum {}",
                s.model,
                s.placement,
                s.method,
                a.sum()
            );
            assert!(a.fetch >= 0.0 && a.prep >= 0.0 && a.compute >= 0.0);
        }
    }

    #[test]
    fn analytic_attribution_names_the_bottleneck() {
        // GPU-bound: all compute, no stall.
        let gpu = Scenario {
            model: "resnet152".into(),
            gpus: 8,
            vcpus: 64,
            ..Default::default()
        };
        assert_eq!(bottleneck(&gpu), Bottleneck::Gpu);
        let a = stall_attribution_analytic(&gpu);
        assert!((a.compute - 1.0).abs() < 1e-9, "gpu-bound compute {}", a.compute);

        // CPU-bound (fast model, few vCPUs): prep dominates the stall.
        let cpu = Scenario {
            model: "alexnet".into(),
            gpus: 8,
            vcpus: 16,
            ..Default::default()
        };
        assert_eq!(bottleneck(&cpu), Bottleneck::Cpu);
        let a = stall_attribution_analytic(&cpu);
        assert!(a.prep > a.fetch && a.prep > a.compute, "cpu-bound split {a:?}");

        // Storage-bound (raw from s3, 1 conn): fetch dominates.
        let st = Scenario {
            model: "alexnet".into(),
            gpus: 8,
            vcpus: 64,
            method: Method::Raw,
            storage: "s3".into(),
            net_conns: 1,
            ..Default::default()
        };
        assert_eq!(bottleneck(&st), Bottleneck::Storage);
        let a = stall_attribution_analytic(&st);
        assert!(a.fetch > a.prep && a.fetch > a.compute, "storage-bound split {a:?}");

        // Ideal mode: pure compute by definition.
        let ideal = Scenario { ideal: true, ..Default::default() };
        let a = stall_attribution_analytic(&ideal);
        assert_eq!(a, StallAttribution { fetch: 0.0, prep: 0.0, compute: 1.0 });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scen(model: &str, gpus: usize, vcpus: usize, pl: Placement, m: Method) -> Scenario {
        Scenario {
            model: model.into(),
            gpus,
            vcpus,
            placement: pl,
            method: m,
            ..Default::default()
        }
    }

    // ---- the paper's headline anchors, checked against the analytic model

    #[test]
    fn fig2_alexnet_record_hybrid_is_23pct_of_ideal() {
        let s = scen("alexnet", 8, 64, Placement::Hybrid, Method::Record);
        let t = analytic_throughput(&s);
        let ideal = analytic_throughput(&Scenario { ideal: true, ..s.clone() });
        let ratio = t / ideal;
        assert!((0.20..0.27).contains(&ratio), "AlexNet hybrid/ideal = {ratio:.3}");
    }

    #[test]
    fn fig2_hybrid_roughly_doubles_fast_consumers() {
        for m in ["alexnet", "shufflenet", "resnet18"] {
            let cpu = analytic_throughput(&scen(m, 8, 64, Placement::Cpu, Method::Record));
            let hyb = analytic_throughput(&scen(m, 8, 64, Placement::Hybrid, Method::Record));
            let gain = hyb / cpu - 1.0;
            assert!((0.85..1.35).contains(&gain), "{m}: hybrid gain {gain:.2}");
        }
    }

    #[test]
    fn fig2_slow_consumers_insensitive_to_placement() {
        for m in ["resnet50", "resnet152"] {
            let cpu = analytic_throughput(&scen(m, 8, 64, Placement::Cpu, Method::Record));
            let hyb = analytic_throughput(&scen(m, 8, 64, Placement::Hybrid, Method::Record));
            let rel = (hyb - cpu).abs() / cpu;
            assert!(rel < 0.08, "{m}: |Δ| {rel:.3}");
        }
    }

    #[test]
    fn fig2_raw_hybrid_no_better_than_raw_cpu_for_fast_models() {
        // Random I/O dominates raw loading; hybrid cannot help (paper §3.2).
        for m in ["alexnet", "shufflenet", "resnet18"] {
            let rc = analytic_throughput(&scen(m, 8, 64, Placement::Cpu, Method::Raw));
            let rh = analytic_throughput(&scen(m, 8, 64, Placement::Hybrid, Method::Raw));
            assert!((rh / rc - 1.0).abs() < 0.05, "{m}: raw hybrid gain {:.3}", rh / rc - 1.0);
            let rec = analytic_throughput(&scen(m, 8, 64, Placement::Hybrid, Method::Record));
            assert!(rec > rh, "{m}: record-hybrid must beat raw-hybrid");
        }
    }

    #[test]
    fn fig5a_alexnet_saturation_points() {
        // hybrid saturates ≈24 vCPUs on 4 GPUs: below, CPU-bound; above, flat.
        let t = |v, pl| analytic_throughput(&scen("alexnet", 4, v, pl, Method::Record));
        assert_eq!(bottleneck(&scen("alexnet", 4, 20, Placement::Hybrid, Method::Record)),
                   Bottleneck::Cpu);
        assert_eq!(bottleneck(&scen("alexnet", 4, 28, Placement::Hybrid, Method::Record)),
                   Bottleneck::Gpu);
        assert!(t(48, Placement::Hybrid) - t(28, Placement::Hybrid) < 1.0);
        // hybrid-0 saturates later and ends ~7.9% higher.
        assert_eq!(bottleneck(&scen("alexnet", 4, 28, Placement::Hybrid0, Method::Record)),
                   Bottleneck::Cpu);
        let gain = t(64, Placement::Hybrid0) / t(64, Placement::Hybrid) - 1.0;
        assert!((0.05..0.11).contains(&gain), "hybrid0 gain {gain:.4} (paper: 7.86%)");
    }

    #[test]
    fn fig5b_resnet50_saturation_points() {
        let t = |v, pl| analytic_throughput(&scen("resnet50", 8, v, pl, Method::Record));
        // cpu placement saturates at ~48 vCPUs (paper: 48).
        assert!(t(48, Placement::Cpu) / t(40, Placement::Cpu) > 1.05);
        assert!(t(64, Placement::Cpu) - t(48, Placement::Cpu) < 1.0);
        // hybrid saturates much earlier (paper: 16; model: ~21).
        assert!(t(24, Placement::Hybrid) - t(22, Placement::Hybrid) < 1.0);
        // cpu beats hybrid by ~3% once saturated (paper: 3.03%).
        let gain = t(64, Placement::Cpu) / t(64, Placement::Hybrid) - 1.0;
        assert!((0.01..0.06).contains(&gain), "cpu gain {gain:.4}");
    }

    #[test]
    fn fig6_storage_effects() {
        // p3dn, 4 GPUs, 48 vCPUs (12 per GPU).
        let t = |model: &str, storage: &str| {
            analytic_throughput(&Scenario {
                model: model.into(),
                gpus: 4,
                vcpus: 48,
                storage: storage.into(),
                p3dn: true,
                ..Default::default()
            })
        };
        // EBS ≈ NVMe for both models (paper: "almost the same").
        for m in ["alexnet", "resnet18"] {
            let r = t(m, "ebs") / t(m, "nvme");
            assert!((0.95..1.05).contains(&r), "{m} ebs/nvme {r:.3}");
        }
        // DRAM: big for AlexNet (paper 1.84×), small for ResNet18 (8.8%).
        let a = t("alexnet", "dram") / t("alexnet", "ebs");
        assert!((1.6..2.1).contains(&a), "alexnet dram speedup {a:.3}");
        let r = t("resnet18", "dram") / t("resnet18", "ebs");
        assert!((1.02..1.18).contains(&r), "resnet18 dram speedup {r:.3}");
    }

    #[test]
    fn workers_fixed_point_matches_its_definition() {
        // Sink 200 ips at 5 ms/item needs exactly 1 worker; 380 needs 2.
        assert_eq!(workers_fixed_point(5.0, 200.0, 1, 8), 1);
        assert_eq!(workers_fixed_point(5.0, 380.0, 1, 8), 2);
        // Unbounded sink (prep-bound pipeline): peg at the ceiling.
        assert_eq!(workers_fixed_point(5.0, f64::INFINITY, 1, 8), 8);
        // Clamping at both ends, and degenerate stage cost.
        assert_eq!(workers_fixed_point(5.0, 10_000.0, 1, 4), 4);
        assert_eq!(workers_fixed_point(5.0, 1.0, 2, 8), 2);
        assert_eq!(workers_fixed_point(0.0, 500.0, 1, 8), 1);
    }

    #[test]
    fn autoscale_workers_lands_at_the_vcpu_saturation_point() {
        // ResNet50 record-hybrid on the 8-GPU box: the Fig. 5b sweep
        // saturates around 21 vCPUs in our calibration — the controller's
        // fixed point must land there, and running *at* the fixed point
        // must keep essentially the whole 64-vCPU rate.
        let s = scen("resnet50", 8, 64, Placement::Hybrid, Method::Record);
        let fp = s.autoscale_workers(1, 64);
        assert!((18..=24).contains(&fp), "resnet50 hybrid fixed point {fp}");
        let at_fp = analytic_throughput(&Scenario { vcpus: fp, ..s.clone() });
        let at_64 = analytic_throughput(&s);
        assert!(at_fp >= 0.97 * at_64, "{at_fp:.0} vs {at_64:.0}");
        // One vCPU below the fixed point must already cost throughput
        // (the point is a knee, not a plateau entry).
        let below = analytic_throughput(&Scenario { vcpus: fp - 2, ..s.clone() });
        assert!(below < at_fp, "{below:.0} !< {at_fp:.0}");
        // AlexNet hybrid needs ~48: a small ceiling pegs (prep-bound).
        let a = scen("alexnet", 8, 64, Placement::Hybrid, Method::Record);
        assert_eq!(a.autoscale_workers(1, 16), 16, "prep-bound run must peg at max");
        let fp_a = a.autoscale_workers(1, 64);
        assert!((44..=52).contains(&fp_a), "alexnet hybrid fixed point {fp_a}");
        // Storage-bound remote raw run: the sink is the GET rate, so the
        // fixed point sits far below the GPU-matching count.
        let st = Scenario {
            model: "alexnet".into(),
            gpus: 8,
            vcpus: 64,
            method: Method::Raw,
            storage: "s3".into(),
            net_conns: 1,
            ..Default::default()
        };
        assert_eq!(bottleneck(&st), Bottleneck::Storage);
        assert!(st.autoscale_workers(1, 64) < fp_a);
    }

    #[test]
    fn scenario_validation() {
        assert!(Scenario { model: "vgg".into(), ..Default::default() }.validate().is_err());
        assert!(Scenario::default().validate().is_ok());
        assert!(Scenario { storage: "s3".into(), ..Default::default() }.validate().is_ok());
        assert!(Scenario { storage: "s3-cold".into(), ..Default::default() }
            .validate()
            .is_ok());
        assert!(Scenario { storage: "s3".into(), net_conns: 0, ..Default::default() }
            .validate()
            .is_err());
        assert!(Scenario { storage: "efs".into(), ..Default::default() }.validate().is_err());
    }

    #[test]
    fn prep_cache_lifts_preprocessing_bound_models() {
        // AlexNet record-hybrid at 24 vCPUs on the 8-GPU box is CPU-bound
        // (saturation is ~48); a warm half-corpus minio cache halves the
        // decode demand and must raise throughput; LRU at the same size
        // must thrash toward baseline.
        let half = calib::decoded_dataset_bytes() / 2.0 / 1e9;
        let base = scen("alexnet", 8, 24, Placement::Hybrid, Method::Record);
        let minio = Scenario { prep_cache_gb: half, ..base.clone() };
        let lru = Scenario {
            prep_cache_gb: half,
            prep_cache_policy: PrepCachePolicy::Lru,
            ..base.clone()
        };
        let (tb, tm, tl) = (
            analytic_throughput(&base),
            analytic_throughput(&minio),
            analytic_throughput(&lru),
        );
        assert!(tm > tb * 1.3, "minio warm epoch must beat cold: {tm:.0} vs {tb:.0}");
        assert!(tl < tm, "lru must trail minio: {tl:.0} vs {tm:.0}");
        // Hybrid+LRU pays the cache-only dequant+IDCT on every re-admit;
        // at a small cache the thrash makes it strictly WORSE than no
        // cache (matching the engine), which is the CoorDL argument for
        // the eviction-free policy.
        let small_lru = Scenario {
            prep_cache_gb: calib::decoded_dataset_bytes() / 4.0 / 1e9,
            prep_cache_policy: PrepCachePolicy::Lru,
            ..base.clone()
        };
        assert!(
            analytic_throughput(&small_lru) < tb,
            "small hybrid+lru cache must cost more than it saves"
        );
        // GPU-bound ResNet50 barely moves.
        let r50_base = scen("resnet50", 8, 64, Placement::Hybrid, Method::Record);
        let r50_cache = Scenario { prep_cache_gb: half, ..r50_base.clone() };
        let rel = (analytic_throughput(&r50_cache) / analytic_throughput(&r50_base)) - 1.0;
        assert!(rel < 0.05, "resnet50 gain {rel:.3} should be marginal");
    }

    #[test]
    fn prep_cache_relieves_storage_bound_remote_runs() {
        // Raw loading from s3 with 1 connection is storage-bound; cached
        // samples skip the per-file GET, so the effective ceiling rises
        // by 1/(1-hit).
        let mk = |gb: f64| Scenario {
            model: "alexnet".into(),
            gpus: 8,
            vcpus: 64,
            method: Method::Raw,
            storage: "s3".into(),
            net_conns: 1,
            prep_cache_gb: gb,
            ..Default::default()
        };
        assert_eq!(bottleneck(&mk(0.0)), Bottleneck::Storage);
        let half = calib::decoded_dataset_bytes() / 2.0 / 1e9;
        let cold = mk(0.0).storage_cap_ips();
        let warm = mk(half).storage_cap_ips();
        assert!((warm / cold - 2.0).abs() < 1e-6, "half-corpus cache doubles the cap");
        assert!(analytic_throughput(&mk(half)) > analytic_throughput(&mk(0.0)) * 1.5);
        // Full-corpus cache removes storage from the picture entirely.
        let full = mk(2.0 * half);
        assert!(full.storage_cap_ips().is_infinite());
        assert_ne!(bottleneck(&full), Bottleneck::Storage);
        // Record streaming reads whole shards regardless of residency
        // (exactly what the engine does), so its storage cap must NOT be
        // credited with cache savings.
        let rec = Scenario { method: Method::Record, ..mk(half) };
        let rec_cold = Scenario { method: Method::Record, ..mk(0.0) };
        assert!((rec.storage_cap_ips() - rec_cold.storage_cap_ips()).abs() < 1e-9);
    }

    #[test]
    fn fused_decode_thins_exactly_the_transform_share() {
        // The model: only SHARE_XFORM scales (by the block fraction, and
        // by 1/scale² on the cpu path); read/entropy/aug are untouched.
        for pl in [Placement::Cpu, Placement::Hybrid0] {
            let base = scen("alexnet", 8, 24, pl, Method::Record);
            let fused = Scenario { fused_decode: true, ..base.clone() };
            let saved = base.cpu_cost_ms() - fused.cpu_cost_ms();
            let want = calib::SHARE_XFORM * (1.0 - calib::FUSED_BLOCK_FRACTION)
                * calib::CPU_PREPROC_MS;
            assert!((saved - want).abs() < 1e-9, "{pl:?}: saved {saved} want {want}");
        }
        // Hybrid ships whole coefficient grids: fused changes nothing.
        let hy = scen("alexnet", 8, 24, Placement::Hybrid, Method::Record);
        let hy_f = Scenario { fused_decode: true, ..hy.clone() };
        assert_eq!(hy.cpu_cost_ms(), hy_f.cpu_cost_ms());
        // Fractional scale divides the remaining per-block cost by
        // scale² on the cpu path only.
        let cpu2 = Scenario {
            fused_decode: true,
            decode_scale: 2,
            ..scen("alexnet", 8, 24, Placement::Cpu, Method::Record)
        };
        let cpu1 = Scenario { decode_scale: 1, ..cpu2.clone() };
        let xform1 = calib::SHARE_XFORM * calib::FUSED_BLOCK_FRACTION * calib::CPU_PREPROC_MS;
        let extra = xform1 * (1.0 - 1.0 / 4.0);
        assert!((cpu1.cpu_cost_ms() - cpu2.cpu_cost_ms() - extra).abs() < 1e-9);
        let h02 = Scenario {
            fused_decode: true,
            decode_scale: 2,
            ..scen("alexnet", 8, 24, Placement::Hybrid0, Method::Record)
        };
        let h01 = Scenario { decode_scale: 1, ..h02.clone() };
        assert_eq!(h01.cpu_cost_ms(), h02.cpu_cost_ms(), "hybrid0 never scales");
        // Throughput on a CPU-bound scenario strictly improves.
        let cold = scen("alexnet", 8, 24, Placement::Cpu, Method::Record);
        let warm = Scenario { fused_decode: true, ..cold.clone() };
        assert!(analytic_throughput(&warm) > analytic_throughput(&cold));
        // And validation rejects bad scales.
        assert!(Scenario { decode_scale: 3, ..Default::default() }.validate().is_err());
        assert!(Scenario { decode_scale: 8, ..Default::default() }.validate().is_ok());
    }

    #[test]
    fn slab_pool_thins_exactly_the_collate_copy_share() {
        // The model: only SHARE_AUG scales, by COPY_SHARE, on the cpu
        // placement — read/decode are untouched and the device
        // placements (no CPU augment share) are modeled no-ops.
        let base = scen("alexnet", 8, 24, Placement::Cpu, Method::Record);
        let slab = Scenario { slab_pool: true, ..base.clone() };
        let saved = base.cpu_cost_ms() - slab.cpu_cost_ms();
        let want = calib::SHARE_AUG * calib::COPY_SHARE * calib::CPU_PREPROC_MS;
        assert!((saved - want).abs() < 1e-9, "saved {saved} want {want}");
        for pl in [Placement::Hybrid, Placement::Hybrid0] {
            let b = scen("alexnet", 8, 24, pl, Method::Record);
            let s = Scenario { slab_pool: true, ..b.clone() };
            assert_eq!(b.cpu_cost_ms(), s.cpu_cost_ms(), "{pl:?} must be a no-op");
        }
        // A CPU-bound scenario strictly speeds up; the default stays the
        // paper's per-sample-buffer baseline.
        assert!(analytic_throughput(&slab) > analytic_throughput(&base));
        assert!(!Scenario::default().slab_pool);
        // The hit path thins too: cpu hits still augment on the CPU.
        let half = calib::decoded_dataset_bytes() / 2.0 / 1e9;
        let warm = Scenario { prep_cache_gb: half, ..base.clone() };
        let warm_slab = Scenario { slab_pool: true, ..warm.clone() };
        let warm_saved = warm.cpu_cost_ms() - warm_slab.cpu_cost_ms();
        assert!((warm_saved - want).abs() < 1e-9, "hit+miss blend must both thin");
        // And it composes with the fused decoder: the two savings stack.
        let both = Scenario { fused_decode: true, slab_pool: true, ..base.clone() };
        let fused_only = Scenario { fused_decode: true, ..base.clone() };
        assert!((fused_only.cpu_cost_ms() - both.cpu_cost_ms() - want).abs() < 1e-9);
    }

    #[test]
    fn simd_thins_exactly_the_vectorized_shares() {
        // The model: entropy thins by SIMD_ENTROPY_SPEEDUP everywhere
        // (the entropy walk is always on the CPU), the transform by
        // SIMD_XFORM_SPEEDUP where the placement runs it on the CPU,
        // and only the resize+normalize sub-shares of augment by
        // SIMD_AUG_SPEEDUP (crop/flip are index shuffles); read is
        // untouched, as are GPU cost and the storage ceiling.
        let e_saved = calib::SHARE_ENTROPY * (1.0 - 1.0 / calib::SIMD_ENTROPY_SPEEDUP);
        let x_saved = calib::SHARE_XFORM * (1.0 - 1.0 / calib::SIMD_XFORM_SPEEDUP);
        let a_saved = (calib::SHARE_RESIZE + calib::SHARE_NORM)
            * (1.0 - 1.0 / calib::SIMD_AUG_SPEEDUP);
        let ms = calib::CPU_PREPROC_MS;
        for (pl, want) in [
            (Placement::Cpu, (e_saved + x_saved + a_saved) * ms),
            (Placement::Hybrid, e_saved * ms),
            (Placement::Hybrid0, (e_saved + x_saved) * ms),
        ] {
            let base = scen("alexnet", 8, 24, pl, Method::Record);
            let simd = Scenario { simd: true, ..base.clone() };
            let saved = base.cpu_cost_ms() - simd.cpu_cost_ms();
            assert!((saved - want).abs() < 1e-9, "{pl:?}: saved {saved} want {want}");
            assert_eq!(base.gpu_cost_ms(), simd.gpu_cost_ms(), "{pl:?} GPU untouched");
            assert!(
                (base.storage_cap_ips() - simd.storage_cap_ips()).abs() < 1e-9,
                "{pl:?} storage untouched"
            );
        }
        // A CPU-bound scenario strictly speeds up; the default stays the
        // paper's scalar baseline.
        let base = scen("alexnet", 8, 24, Placement::Cpu, Method::Record);
        let simd = Scenario { simd: true, ..base.clone() };
        assert!(analytic_throughput(&simd) > analytic_throughput(&base));
        assert!(!Scenario::default().simd);
        // Composes with the slab pool: the thinned augment share is what
        // the collate-copy fraction multiplies.
        let slab = Scenario { slab_pool: true, ..base.clone() };
        let both = Scenario { simd: true, ..slab.clone() };
        let want_slab =
            (e_saved + x_saved + a_saved * (1.0 - calib::COPY_SHARE)) * ms;
        let saved_slab = slab.cpu_cost_ms() - both.cpu_cost_ms();
        assert!(
            (saved_slab - want_slab).abs() < 1e-9,
            "slab+simd saved {saved_slab} want {want_slab}"
        );
        // Composes with the fused decoder: SIMD divides whatever
        // per-block transform work the fused plan left behind.
        let fused = Scenario { fused_decode: true, ..base.clone() };
        let fused_simd = Scenario { simd: true, ..fused.clone() };
        let want_fused = (e_saved
            + calib::SHARE_XFORM
                * calib::FUSED_BLOCK_FRACTION
                * (1.0 - 1.0 / calib::SIMD_XFORM_SPEEDUP)
            + a_saved)
            * ms;
        let saved_fused = fused.cpu_cost_ms() - fused_simd.cpu_cost_ms();
        assert!(
            (saved_fused - want_fused).abs() < 1e-9,
            "fused+simd saved {saved_fused} want {want_fused}"
        );
        // The hit path thins too: cpu-placement cache hits still run the
        // (now vectorized) resize+normalize on the CPU.
        let half = calib::decoded_dataset_bytes() / 2.0 / 1e9;
        let warm = Scenario { prep_cache_gb: half, ..base.clone() };
        let warm_simd = Scenario { simd: true, ..warm.clone() };
        let hit = warm.prep_cache_hit();
        let want_warm =
            ((1.0 - hit) * (e_saved + x_saved + a_saved) + hit * a_saved) * ms;
        let saved_warm = warm.cpu_cost_ms() - warm_simd.cpu_cost_ms();
        assert!(
            (saved_warm - want_warm).abs() < 1e-9,
            "warm saved {saved_warm} want {want_warm}"
        );
    }

    #[test]
    fn fused_scale_multiplies_cache_capacity_on_the_cpu_path() {
        let quarter = calib::decoded_dataset_bytes() / 4.0 / 1e9;
        let base = Scenario {
            prep_cache_gb: quarter,
            ..scen("alexnet", 8, 24, Placement::Cpu, Method::Record)
        };
        assert!((base.prep_cache_hit() - 0.25).abs() < 1e-9);
        // 1/2-scale entries: same DRAM holds 4x the samples.
        let scaled = Scenario { fused_decode: true, decode_scale: 2, ..base.clone() };
        assert!((scaled.prep_cache_hit() - 1.0).abs() < 1e-9);
        // hybrid0 entries stay full-res, so nothing changes there.
        let h0 = Scenario {
            placement: Placement::Hybrid0,
            ..scaled.clone()
        };
        assert!((h0.prep_cache_hit() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn fault_rate_thins_exactly_the_storage_ceiling() {
        // The model: only the storage cap scales, by (1-p); CPU and GPU
        // costs are untouched.
        let base = Scenario { storage: "s3".into(), net_conns: 8, ..Default::default() };
        let faulty = Scenario { fault_rate: 0.01, ..base.clone() };
        assert!((faulty.storage_cap_ips() - base.storage_cap_ips() * 0.99).abs() < 1e-9);
        assert_eq!(base.cpu_cost_ms(), faulty.cpu_cost_ms());
        assert_eq!(base.gpu_cost_ms(), faulty.gpu_cost_ms());
        // A storage-bound scenario's throughput scales by exactly (1-p).
        let st = Scenario {
            model: "alexnet".into(),
            gpus: 8,
            vcpus: 64,
            method: Method::Raw,
            storage: "s3".into(),
            net_conns: 1,
            ..Default::default()
        };
        assert_eq!(bottleneck(&st), Bottleneck::Storage);
        let stf = Scenario { fault_rate: 0.25, ..st.clone() };
        let r = analytic_throughput(&stf) / analytic_throughput(&st);
        assert!((r - 0.75).abs() < 1e-9, "{r}");
        // A fully resident raw corpus never touches storage, faults or
        // not — the cap stays infinite.
        let full = calib::decoded_dataset_bytes() / 1e9;
        let resident = Scenario { prep_cache_gb: full, fault_rate: 0.5, ..st.clone() };
        assert!(resident.storage_cap_ips().is_infinite());
        // And validation rejects out-of-range rates.
        assert!(Scenario { fault_rate: 1.0, ..Default::default() }.validate().is_err());
        assert!(Scenario { fault_rate: -0.1, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn prep_cache_hit_uses_shared_model_and_validates() {
        let s = Scenario { prep_cache_gb: 300.0, ..Default::default() };
        let want = crate::pipeline::prep_cache::steady_state_hit_rate(
            PrepCachePolicy::Minio,
            300.0e9,
            calib::decoded_dataset_bytes(),
        );
        assert!((s.prep_cache_hit() - want).abs() < 1e-12);
        assert!(Scenario { prep_cache_gb: -1.0, ..Default::default() }.validate().is_err());
        assert_eq!(Scenario::default().prep_cache_hit(), 0.0);
    }

    #[test]
    fn remote_cap_uses_shared_net_profile_formula() {
        let s = Scenario { storage: "s3".into(), net_conns: 8, ..Default::default() };
        let want = calib::remote("s3")
            .unwrap()
            .throughput_bps(8, calib::REMOTE_PART_BYTES as u64)
            / calib::IMG_BYTES;
        assert!((s.storage_cap_ips() - want).abs() < 1e-9);
    }

    #[test]
    fn remote_conns_hide_latency_until_caps_bind() {
        let cap = |conns| {
            Scenario { storage: "s3".into(), net_conns: conns, ..Default::default() }
                .storage_cap_ips()
        };
        // Below the ceilings the cap is linear in connections...
        assert!((cap(8) / cap(1) - 8.0).abs() < 1e-6);
        // ...and the cold tier is strictly slower at equal concurrency.
        let cold = Scenario { storage: "s3-cold".into(), ..Default::default() };
        let warm = Scenario { storage: "s3".into(), ..Default::default() };
        assert!(cold.storage_cap_ips() < warm.storage_cap_ips());
    }

    #[test]
    fn remote_raw_method_is_request_bound() {
        // One GET per 110 KB image pays 30 ms latency each: raw loading
        // from S3 must be far below record streaming at equal conns.
        let raw = Scenario {
            storage: "s3".into(),
            method: Method::Raw,
            ..Default::default()
        };
        let rec = Scenario {
            storage: "s3".into(),
            method: Method::Record,
            ..Default::default()
        };
        assert!(raw.storage_cap_ips() < rec.storage_cap_ips() * 0.5);
        // End-to-end: a fast consumer on s3 is storage-bound with few
        // conns and recovers with many.
        let t = |conns| {
            analytic_throughput(&Scenario {
                model: "alexnet".into(),
                gpus: 8,
                vcpus: 64,
                storage: "s3".into(),
                net_conns: conns,
                ..Default::default()
            })
        };
        assert_eq!(
            bottleneck(&Scenario {
                model: "alexnet".into(),
                gpus: 8,
                vcpus: 64,
                storage: "s3".into(),
                net_conns: 1,
                ..Default::default()
            }),
            Bottleneck::Storage
        );
        assert!(t(32) > t(1) * 3.0, "conns must buy throughput: {} vs {}", t(32), t(1));
    }
}
