//! Discrete-event simulation of the closed preprocessing→training pipeline.
//!
//! Entities are images flowing through three service stations:
//!
//! ```text
//!   storage (1 device) → vCPU pool (k servers) → batcher → GPUs (g servers)
//! ```
//!
//! The network is *closed*: a bounded population of in-flight images
//! models the bounded prefetch queues of the real engine; an image
//! re-enters at the source when it leaves the GPU.  Steady-state
//! throughput converges to the analytic bottleneck model (tested), and
//! the per-second busy-time samples give the Fig. 4 utilization traces,
//! including the warm-up ramp.

use super::{calib, Scenario, SimOutput};
use crate::config::Method;
use crate::metrics::trace::{self, Span, Stage, StallAttribution, TraceDump, Track};
use crate::metrics::UtilSample;
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

#[derive(Clone, Copy, Debug, PartialEq)]
enum Ev {
    ReadDone,
    CpuDone,
    GpuDone(usize), // images in the finished batch
    Sample,
}

#[derive(Clone, Copy, PartialEq)]
struct Event {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .partial_cmp(&other.t)
            .unwrap()
            .then(self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

struct Station {
    servers: usize,
    busy: usize,
    queue: usize,
    busy_time: f64,
    last_change: f64,
}

impl Station {
    fn new(servers: usize) -> Self {
        Station { servers, busy: 0, queue: 0, busy_time: 0.0, last_change: 0.0 }
    }

    fn account(&mut self, now: f64) {
        self.busy_time += self.busy as f64 * (now - self.last_change);
        self.last_change = now;
    }

    /// Try to start one queued job; returns true if a server was grabbed.
    fn try_start(&mut self, now: f64) -> bool {
        if self.queue > 0 && self.busy < self.servers {
            self.account(now);
            self.queue -= 1;
            self.busy += 1;
            true
        } else {
            false
        }
    }

    fn finish(&mut self, now: f64) {
        self.account(now);
        self.busy -= 1;
    }

    fn utilization(&self, elapsed: f64) -> f64 {
        if elapsed <= 0.0 {
            0.0
        } else {
            self.busy_time / (elapsed * self.servers as f64)
        }
    }
}

/// Synthetic span collector for [`simulate_traced`]: the DES knows a
/// job's service start and duration at schedule time, so spans are
/// recorded as jobs start.  Jobs are assigned to display lanes (one
/// track per server) greedily by free time, mirroring how the real
/// engine's per-thread rings render in the viewer.
struct SpanLog {
    stage: Stage,
    label: &'static str,
    lanes: Vec<Vec<Span>>,
    lane_free_ns: Vec<u64>,
    budget: usize,
    dropped: u64,
    n: u64,
}

impl SpanLog {
    fn new(stage: Stage, label: &'static str, servers: usize, budget: usize) -> SpanLog {
        SpanLog {
            stage,
            label,
            lanes: vec![Vec::new(); servers.max(1)],
            lane_free_ns: vec![0; servers.max(1)],
            budget,
            dropped: 0,
            n: 0,
        }
    }

    fn record(&mut self, start_s: f64, dur_s: f64) {
        let sample = self.n;
        self.n += 1;
        if self.lanes.iter().map(Vec::len).sum::<usize>() >= self.budget {
            self.dropped += 1;
            return;
        }
        let start_ns = (start_s * 1e9) as u64;
        let dur_ns = (dur_s * 1e9).max(1.0) as u64;
        // First lane free at this start; the station is FIFO so one
        // exists whenever a server was free — fall back to the
        // earliest-free lane on float rounding.
        let lane = (0..self.lanes.len())
            .find(|&i| self.lane_free_ns[i] <= start_ns)
            .or_else(|| (0..self.lanes.len()).min_by_key(|&i| self.lane_free_ns[i]))
            .unwrap_or(0);
        self.lane_free_ns[lane] = start_ns + dur_ns;
        self.lanes[lane].push(Span { stage: self.stage, start_ns, dur_ns, sample, epoch: 0 });
    }

    fn drain_into(self, dump: &mut TraceDump) {
        dump.dropped += self.dropped;
        for (i, spans) in self.lanes.into_iter().enumerate() {
            if !spans.is_empty() {
                dump.tracks.push(Track { label: format!("{}-{i}", self.label), spans });
            }
        }
    }
}

/// Run the DES for `scenario.seconds` of simulated time.
pub fn simulate(s: &Scenario) -> SimOutput {
    simulate_inner(s, false).0
}

/// [`simulate`] plus a Chrome trace-event export of the run: synthetic
/// fetch/prep/train spans on per-server lanes and counter tracks from
/// the utilization time series — the same JSON shape the engine writes
/// for `--trace`, so one viewer and one validator cover both.
pub fn simulate_traced(s: &Scenario) -> (SimOutput, Json) {
    let (out, dump) = simulate_inner(s, true);
    let dump = dump.unwrap_or_default();
    let counters: Vec<(String, Vec<(f64, f64)>)> = vec![
        ("cpu util".into(), out.util_trace.iter().map(|u| (u.t, u.cpu)).collect()),
        ("gpu util".into(), out.util_trace.iter().map(|u| (u.t, u.device)).collect()),
        ("io MB/s".into(), out.util_trace.iter().map(|u| (u.t, u.io_mbps)).collect()),
    ];
    let json = trace::chrome_trace(&dump, &counters);
    (out, json)
}

fn simulate_inner(s: &Scenario, want_spans: bool) -> (SimOutput, Option<TraceDump>) {
    let m = calib::model(&s.model).expect("validated scenario");
    let batch = m.batch;

    // Service times (seconds), jittered ±10% for realism.  Local tiers
    // are one device (1 server); remote tiers are a connection pool of
    // `net_conns` servers whose per-request latency overlaps — the
    // aggregate-bandwidth and request-rate ceilings are far from binding
    // at part-sized GETs, so the pool model is the whole story.
    let (read_base, storage_servers) = if let Some(net) = calib::remote(&s.storage) {
        let conns = s.net_conns.max(1).min(net.max_conns.max(1));
        let base = match s.method {
            // Per-image share of a part-sized ranged GET on one connection.
            Method::Record => {
                net.request_time(calib::REMOTE_PART_BYTES as u64)
                    * (calib::IMG_BYTES / calib::REMOTE_PART_BYTES)
            }
            // One GET per image.
            Method::Raw => net.request_time(calib::IMG_BYTES as u64),
        };
        (base, conns)
    } else {
        let st = calib::storage(&s.storage, s.p3dn).expect("validated scenario");
        let base = match s.method {
            Method::Record => calib::IMG_BYTES / (st.seq_bw_mbs * 1e6),
            Method::Raw => calib::IMG_BYTES / (st.seq_bw_mbs * 1e6) + 1.0 / st.rand_iops,
        };
        (base, 1)
    };
    // Decoded-sample cache (steady state): a raw-method hit skips the
    // per-file fetch, thinning the mean per-image storage service time;
    // record streaming reads whole shards regardless of residency (as
    // the engine does), so only the CPU cost — which already carries the
    // hit-rate scaling via `cpu_cost_ms` — is reduced there.
    let read_base = if s.method == Method::Raw {
        read_base * (1.0 - s.prep_cache_hit())
    } else {
        read_base
    };
    // Transient faults under retry (`fault_rate`): each read re-issues
    // with probability p, so expected attempts per delivered image is
    // 1/(1-p) — service-time inflation, matching the analytic model's
    // (1-p) ceiling scaling.
    let read_base = read_base / (1.0 - s.fault_rate);
    // vCPU efficiency knee: inflate per-image cost so k nominal servers
    // deliver eff(k) worth of capacity.
    let cpu_scale = s.vcpus as f64 / calib::eff_vcpus(s.vcpus as f64);
    let cpu_base = s.cpu_cost_ms() / 1000.0 * cpu_scale;
    let gpu_img = s.gpu_cost_ms() / 1000.0;

    // Closed population: enough in-flight images to keep every stage fed.
    let population = batch * (s.gpus * 3) + s.vcpus * 2 + 32;

    let mut rng = Rng::new(s.seed);
    let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
    let mut seq = 0u64;
    let push = |heap: &mut BinaryHeap<Reverse<Event>>, t: f64, ev: Ev, seq: &mut u64| {
        *seq += 1;
        heap.push(Reverse(Event { t, seq: *seq, ev }));
    };

    let mut storage = Station::new(storage_servers);
    let mut cpus = Station::new(s.vcpus);
    let mut gpus = Station::new(s.gpus);
    let mut ready: usize = 0; // images waiting at the batcher
    let mut gpu_ready: VecDeque<usize> = VecDeque::new(); // queued batches
    let mut done: u64 = 0;
    let mut bytes_read: f64 = 0.0;
    let mut util_trace: Vec<UtilSample> = Vec::new();
    let (mut last_cpu, mut last_gpu, mut last_bytes, mut last_t) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);

    let jitter = |rng: &mut Rng| 0.9 + 0.2 * rng.f64();

    if s.ideal {
        // Ideal mode: every GPU spins on one resident batch; nothing flows.
        let t_batch = m.t_train_ms / 1000.0 * batch as f64;
        let steps = (s.seconds / t_batch.max(1e-12)).floor() * s.gpus as f64;
        let out = SimOutput {
            images_done: (steps * batch as f64) as u64,
            throughput_ips: steps * batch as f64 / s.seconds,
            cpu_util: 0.0,
            gpu_util: 1.0,
            io_mbps: 0.0,
            util_trace: vec![],
            stall: StallAttribution { fetch: 0.0, prep: 0.0, compute: 1.0 },
        };
        return (out, want_spans.then(TraceDump::default));
    }

    // Span logs, one per station (bounded — a long sim drops the tail
    // rather than ballooning the export).
    let mut logs = want_spans.then(|| {
        [
            SpanLog::new(Stage::Fetch, "sim-storage", storage_servers, 8_000),
            SpanLog::new(Stage::Prep, "sim-cpu", s.vcpus, 20_000),
            SpanLog::new(Stage::Train, "sim-gpu", s.gpus, 8_000),
        ]
    });

    // Prime the closed network: all images start at the storage queue,
    // and every storage server (1 device, or the remote connection pool)
    // begins busy.
    storage.queue = population;
    while storage.try_start(0.0) {
        let d = read_base * jitter(&mut rng);
        if let Some(l) = logs.as_mut() {
            l[0].record(0.0, d);
        }
        push(&mut heap, d, Ev::ReadDone, &mut seq);
    }
    push(&mut heap, 1.0, Ev::Sample, &mut seq);

    let horizon = s.seconds;
    while let Some(Reverse(Event { t, ev, .. })) = heap.pop() {
        if t > horizon {
            break;
        }
        match ev {
            Ev::ReadDone => {
                storage.finish(t);
                bytes_read += calib::IMG_BYTES;
                cpus.queue += 1;
                while cpus.try_start(t) {
                    let d = cpu_base * jitter(&mut rng);
                    if let Some(l) = logs.as_mut() {
                        l[1].record(t, d);
                    }
                    push(&mut heap, t + d, Ev::CpuDone, &mut seq);
                }
                if storage.try_start(t) {
                    let d = read_base * jitter(&mut rng);
                    if let Some(l) = logs.as_mut() {
                        l[0].record(t, d);
                    }
                    push(&mut heap, t + d, Ev::ReadDone, &mut seq);
                }
            }
            Ev::CpuDone => {
                cpus.finish(t);
                // A server freed: start the next queued CPU job, if any.
                while cpus.try_start(t) {
                    let d = cpu_base * jitter(&mut rng);
                    if let Some(l) = logs.as_mut() {
                        l[1].record(t, d);
                    }
                    push(&mut heap, t + d, Ev::CpuDone, &mut seq);
                }
                ready += 1;
                if ready >= batch {
                    ready -= batch;
                    gpus.queue += 1;
                    gpu_ready.push_back(batch);
                    while gpus.try_start(t) {
                        let b = gpu_ready.pop_front().unwrap_or(batch);
                        let d = gpu_img * b as f64 * jitter(&mut rng);
                        if let Some(l) = logs.as_mut() {
                            l[2].record(t, d);
                        }
                        push(&mut heap, t + d, Ev::GpuDone(b), &mut seq);
                    }
                }
            }
            Ev::GpuDone(b) => {
                gpus.finish(t);
                done += b as u64;
                // Closed loop: images re-enter at the storage stage.
                storage.queue += b;
                while storage.try_start(t) {
                    let d = read_base * jitter(&mut rng);
                    if let Some(l) = logs.as_mut() {
                        l[0].record(t, d);
                    }
                    push(&mut heap, t + d, Ev::ReadDone, &mut seq);
                }
                while gpus.try_start(t) {
                    let nb = gpu_ready.pop_front().unwrap_or(batch);
                    let d = gpu_img * nb as f64 * jitter(&mut rng);
                    if let Some(l) = logs.as_mut() {
                        l[2].record(t, d);
                    }
                    push(&mut heap, t + d, Ev::GpuDone(nb), &mut seq);
                }
            }
            Ev::Sample => {
                storage.account(t);
                cpus.account(t);
                gpus.account(t);
                let dt = (t - last_t).max(1e-12);
                util_trace.push(UtilSample {
                    t,
                    cpu: (cpus.busy_time - last_cpu) / (dt * cpus.servers as f64),
                    device: (gpus.busy_time - last_gpu) / (dt * gpus.servers as f64),
                    io_mbps: (bytes_read - last_bytes) / dt / 1e6,
                });
                last_cpu = cpus.busy_time;
                last_gpu = gpus.busy_time;
                last_bytes = bytes_read;
                last_t = t;
                if t + 1.0 <= horizon {
                    push(&mut heap, t + 1.0, Ev::Sample, &mut seq);
                }
            }
        }
    }

    storage.account(horizon);
    cpus.account(horizon);
    gpus.account(horizon);

    // Measured wall-clock stall attribution, mirroring the analytic
    // decomposition (`sim::stall_attribution_analytic`): the GPUs' busy
    // share is compute; storage's utilization in excess of the GPUs' is
    // the fetch stall; prep absorbs the rest.  Both utilizations are
    // ≤ 1, so the shares sum to exactly 1.
    let gpu_util = gpus.utilization(horizon);
    let fetch = (storage.utilization(horizon) - gpu_util).max(0.0);
    let stall = StallAttribution {
        fetch,
        prep: (1.0 - gpu_util - fetch).max(0.0),
        compute: gpu_util,
    };

    let out = SimOutput {
        images_done: done,
        throughput_ips: done as f64 / horizon,
        cpu_util: cpus.utilization(horizon),
        gpu_util,
        io_mbps: bytes_read / horizon / 1e6,
        util_trace,
        stall,
    };
    let dump = logs.map(|ls| {
        let mut dump = TraceDump::default();
        for l in ls {
            l.drain_into(&mut dump);
        }
        dump
    });
    (out, dump)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Placement;
    use crate::sim::analytic_throughput;

    fn run(model: &str, gpus: usize, vcpus: usize, pl: Placement) -> (f64, f64) {
        let s = Scenario {
            model: model.into(),
            gpus,
            vcpus,
            placement: pl,
            seconds: 40.0,
            ..Default::default()
        };
        (simulate(&s).throughput_ips, analytic_throughput(&s))
    }

    #[test]
    fn des_matches_analytic_within_tolerance() {
        for (m, g, v, pl) in [
            ("alexnet", 8, 64, Placement::Hybrid),
            ("alexnet", 4, 24, Placement::Hybrid),
            ("resnet50", 8, 64, Placement::Cpu),
            ("resnet50", 8, 16, Placement::Hybrid),
            ("shufflenet", 8, 64, Placement::Hybrid0),
        ] {
            let (des, ana) = run(m, g, v, pl);
            let rel = (des - ana).abs() / ana;
            assert!(rel < 0.15, "{m} {pl:?} g={g} v={v}: des {des:.0} vs ana {ana:.0}");
        }
    }

    #[test]
    fn des_remote_tier_matches_analytic() {
        // Remote storage station = a connection pool: the DES must agree
        // with the closed-form latency/conns overlap model across the
        // storage-bound range.
        for conns in [1usize, 4, 16] {
            let s = Scenario {
                model: "alexnet".into(),
                gpus: 8,
                vcpus: 64,
                storage: "s3".into(),
                net_conns: conns,
                seconds: 60.0,
                ..Default::default()
            };
            let des = simulate(&s).throughput_ips;
            let ana = analytic_throughput(&s);
            let rel = (des - ana).abs() / ana;
            assert!(rel < 0.15, "s3 conns={conns}: des {des:.0} vs ana {ana:.0} ({rel:.3})");
        }
    }

    #[test]
    fn des_prep_cache_matches_analytic() {
        // Warm decoded cache (steady state): the DES's thinned service
        // times must agree with the analytic hit-rate model, for both
        // policies and for a storage-bound remote scenario.
        use crate::pipeline::prep_cache::PrepCachePolicy;
        let half = crate::sim::calib::decoded_dataset_bytes() / 2.0 / 1e9;
        for (storage, conns, method, policy) in [
            ("ebs", 8usize, Method::Record, PrepCachePolicy::Minio),
            ("ebs", 8, Method::Record, PrepCachePolicy::Lru),
            // Raw method: cache hits also skip the per-file remote GET.
            ("s3", 1, Method::Raw, PrepCachePolicy::Minio),
        ] {
            let s = Scenario {
                model: "alexnet".into(),
                gpus: 8,
                vcpus: 24,
                method,
                storage: storage.into(),
                net_conns: conns,
                prep_cache_gb: half,
                prep_cache_policy: policy,
                seconds: 40.0,
                ..Default::default()
            };
            let des = simulate(&s).throughput_ips;
            let ana = analytic_throughput(&s);
            let rel = (des - ana).abs() / ana;
            assert!(
                rel < 0.15,
                "{storage}/{policy:?}: des {des:.0} vs ana {ana:.0} ({rel:.3})"
            );
            // The warm minio run must clearly beat the cold run (LRU's
            // gain at this size is within jitter noise — its admission
            // transform eats most of the thrashed hit savings).
            if policy == PrepCachePolicy::Minio {
                let cold =
                    simulate(&Scenario { prep_cache_gb: 0.0, ..s.clone() }).throughput_ips;
                assert!(des > cold * 1.2, "warm {des:.0} vs cold {cold:.0}");
            }
        }
    }

    #[test]
    fn des_fused_decode_matches_analytic_and_speeds_cpu_bound_runs() {
        // The fused-decode service-time thinning flows into the DES via
        // cpu_cost_ms: agreement with the analytic model must hold, and
        // a CPU-bound scenario must gain measurable throughput.
        let cold = Scenario {
            model: "alexnet".into(),
            gpus: 8,
            vcpus: 24,
            placement: Placement::Cpu,
            seconds: 40.0,
            ..Default::default()
        };
        let fused = Scenario { fused_decode: true, ..cold.clone() };
        let scaled = Scenario { fused_decode: true, decode_scale: 4, ..cold.clone() };
        for s in [&cold, &fused, &scaled] {
            let des = simulate(s).throughput_ips;
            let ana = analytic_throughput(s);
            let rel = (des - ana).abs() / ana;
            assert!(
                rel < 0.15,
                "fused={}/s{}: des {des:.0} vs ana {ana:.0}",
                s.fused_decode,
                s.decode_scale
            );
        }
        assert!(
            simulate(&scaled).throughput_ips > simulate(&cold).throughput_ips,
            "fused 1/4-scale decode must raise a CPU-bound run's throughput"
        );
    }

    #[test]
    fn des_utilization_identifies_bottleneck() {
        // ResNet50 record-hybrid (Fig. 4 right): GPU ~saturated, CPU low.
        let s = Scenario { model: "resnet50".into(), seconds: 40.0, ..Default::default() };
        let out = simulate(&s);
        assert!(out.gpu_util > 0.85, "gpu {:.2}", out.gpu_util);
        assert!(out.cpu_util < 0.55, "cpu {:.2}", out.cpu_util);
        // AlexNet record-hybrid (Fig. 4 left): CPU much busier than r50's.
        let s2 = Scenario { model: "alexnet".into(), seconds: 40.0, ..Default::default() };
        let out2 = simulate(&s2);
        assert!(out2.cpu_util > out.cpu_util + 0.2, "al cpu {:.2}", out2.cpu_util);
        assert!(out2.io_mbps > out.io_mbps, "al io should exceed r50 io");
    }

    #[test]
    fn des_trace_has_per_second_samples() {
        let s = Scenario { model: "resnet50".into(), seconds: 10.0, ..Default::default() };
        let out = simulate(&s);
        assert!(out.util_trace.len() >= 8, "{} samples", out.util_trace.len());
        // Steady-state samples should be positive for all resources.
        let last = out.util_trace.last().unwrap();
        assert!(last.device > 0.5 && last.io_mbps > 0.0);
    }

    #[test]
    fn des_ideal_mode_is_gpu_only() {
        let s = Scenario { model: "alexnet".into(), ideal: true, seconds: 10.0, ..Default::default() };
        let out = simulate(&s);
        assert!(out.cpu_util == 0.0 && out.io_mbps == 0.0);
        let ana = analytic_throughput(&s);
        assert!((out.throughput_ips - ana).abs() / ana < 0.1);
    }

    #[test]
    fn des_fault_inflation_matches_analytic() {
        // Storage-bound remote raw run at a 25% transient rate: the
        // DES's retried-read service inflation must agree with the
        // analytic (1-p) ceiling scaling.
        let st = Scenario {
            model: "alexnet".into(),
            gpus: 8,
            vcpus: 64,
            method: Method::Raw,
            storage: "s3".into(),
            net_conns: 1,
            fault_rate: 0.25,
            seconds: 30.0,
            ..Default::default()
        };
        let des = simulate(&st).throughput_ips;
        let ana = analytic_throughput(&st);
        let rel = (des - ana).abs() / ana;
        assert!(rel < 0.15, "faulty storage-bound: des {des:.0} ana {ana:.0} rel {rel:.3}");
        // The chaos gate at paper scale: a 1% transient rate under
        // retry costs a GPU-bound run under 10% end to end.
        let base = Scenario { model: "resnet50".into(), seconds: 30.0, ..Default::default() };
        let faulty = Scenario { fault_rate: 0.01, ..base.clone() };
        let t0 = simulate(&base).throughput_ips;
        let t1 = simulate(&faulty).throughput_ips;
        assert!(t1 > t0 * 0.9, "1% faults must stay within 10%: {t1:.0} vs {t0:.0}");
    }

    #[test]
    fn des_deterministic_per_seed() {
        let s = Scenario { model: "resnet18".into(), seconds: 15.0, ..Default::default() };
        let a = simulate(&s).images_done;
        let b = simulate(&s).images_done;
        assert_eq!(a, b);
    }

    #[test]
    fn des_attribution_sums_to_one_and_matches_analytic_on_fig2_grid() {
        // The measured split (station utilizations) must agree with the
        // closed-form decomposition per component on the Fig. 2 grid —
        // 20 scenarios spanning gpu-, cpu-, and storage-bound regimes.
        use crate::sim::stall_attribution_analytic;
        for model in ["alexnet", "shufflenet", "resnet18", "resnet50", "resnet152"] {
            for pl in [Placement::Cpu, Placement::Hybrid] {
                for method in [Method::Record, Method::Raw] {
                    let s = Scenario {
                        model: model.into(),
                        gpus: 8,
                        vcpus: 64,
                        placement: pl,
                        method,
                        seconds: 30.0,
                        ..Default::default()
                    };
                    let des = simulate(&s).stall;
                    let ana = stall_attribution_analytic(&s);
                    assert!(
                        (des.sum() - 1.0).abs() < 0.01,
                        "{model} {pl:?} {method:?}: sum {}",
                        des.sum()
                    );
                    for (name, d, a) in [
                        ("fetch", des.fetch, ana.fetch),
                        ("prep", des.prep, ana.prep),
                        ("compute", des.compute, ana.compute),
                    ] {
                        assert!(
                            (d - a).abs() <= 0.20,
                            "{model} {pl:?} {method:?} {name}: des {d:.3} vs ana {a:.3}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn des_attribution_tracks_the_bottleneck() {
        // Storage-bound raw-from-s3: the measured split must put the
        // stall on fetch, not prep.
        let st = Scenario {
            model: "alexnet".into(),
            gpus: 8,
            vcpus: 64,
            method: Method::Raw,
            storage: "s3".into(),
            net_conns: 1,
            seconds: 30.0,
            ..Default::default()
        };
        let out = simulate(&st);
        assert!(
            out.stall.fetch > out.stall.prep && out.stall.fetch > out.stall.compute,
            "storage-bound split {:?}",
            out.stall
        );
        // GPU-bound resnet152: essentially all compute.
        let gpu = Scenario { model: "resnet152".into(), seconds: 30.0, ..Default::default() };
        assert!(simulate(&gpu).stall.compute > 0.8);
        // Ideal mode is pure compute by definition.
        let ideal = Scenario { ideal: true, seconds: 5.0, ..Default::default() };
        assert_eq!(simulate(&ideal).stall, StallAttribution { fetch: 0.0, prep: 0.0, compute: 1.0 });
    }

    #[test]
    fn simulate_traced_exports_valid_chrome_json() {
        let s = Scenario { model: "alexnet".into(), seconds: 5.0, ..Default::default() };
        let (out, json) = simulate_traced(&s);
        assert!(out.images_done > 0);
        let n = trace::validate_chrome_trace(&json).expect("sim trace must validate");
        assert!(n > 100, "expected a populated trace, got {n} events");
        // Every station shows up as named lanes, and spans carry the
        // engine's stage names so one viewer config covers both.
        let events = json.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        let labels: Vec<&str> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M"))
            .filter_map(|e| e.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str()))
            .collect();
        for prefix in ["sim-storage", "sim-cpu", "sim-gpu"] {
            assert!(
                labels.iter().any(|l| l.starts_with(prefix)),
                "missing {prefix} lane in {labels:?}"
            );
        }
        let span_names: std::collections::BTreeSet<&str> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
            .collect();
        for want in ["fetch", "prep", "train"] {
            assert!(span_names.contains(want), "missing {want} spans in {span_names:?}");
        }
        // Counter tracks from the utilization series ride along.
        assert!(events.iter().any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("C")));
        // The traced run is the same simulation: identical image count.
        assert_eq!(out.images_done, simulate(&s).images_done);
    }
}
