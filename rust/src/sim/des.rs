//! Discrete-event simulation of the closed preprocessing→training pipeline.
//!
//! Entities are images flowing through three service stations:
//!
//! ```text
//!   storage (1 device) → vCPU pool (k servers) → batcher → GPUs (g servers)
//! ```
//!
//! The network is *closed*: a bounded population of in-flight images
//! models the bounded prefetch queues of the real engine; an image
//! re-enters at the source when it leaves the GPU.  Steady-state
//! throughput converges to the analytic bottleneck model (tested), and
//! the per-second busy-time samples give the Fig. 4 utilization traces,
//! including the warm-up ramp.

use super::{calib, Scenario, SimOutput};
use crate::config::Method;
use crate::metrics::UtilSample;
use crate::util::rng::Rng;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

#[derive(Clone, Copy, Debug, PartialEq)]
enum Ev {
    ReadDone,
    CpuDone,
    GpuDone(usize), // images in the finished batch
    Sample,
}

#[derive(Clone, Copy, PartialEq)]
struct Event {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .partial_cmp(&other.t)
            .unwrap()
            .then(self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

struct Station {
    servers: usize,
    busy: usize,
    queue: usize,
    busy_time: f64,
    last_change: f64,
}

impl Station {
    fn new(servers: usize) -> Self {
        Station { servers, busy: 0, queue: 0, busy_time: 0.0, last_change: 0.0 }
    }

    fn account(&mut self, now: f64) {
        self.busy_time += self.busy as f64 * (now - self.last_change);
        self.last_change = now;
    }

    /// Try to start one queued job; returns true if a server was grabbed.
    fn try_start(&mut self, now: f64) -> bool {
        if self.queue > 0 && self.busy < self.servers {
            self.account(now);
            self.queue -= 1;
            self.busy += 1;
            true
        } else {
            false
        }
    }

    fn finish(&mut self, now: f64) {
        self.account(now);
        self.busy -= 1;
    }

    fn utilization(&self, elapsed: f64) -> f64 {
        if elapsed <= 0.0 {
            0.0
        } else {
            self.busy_time / (elapsed * self.servers as f64)
        }
    }
}

/// Run the DES for `scenario.seconds` of simulated time.
pub fn simulate(s: &Scenario) -> SimOutput {
    let m = calib::model(&s.model).expect("validated scenario");
    let batch = m.batch;

    // Service times (seconds), jittered ±10% for realism.  Local tiers
    // are one device (1 server); remote tiers are a connection pool of
    // `net_conns` servers whose per-request latency overlaps — the
    // aggregate-bandwidth and request-rate ceilings are far from binding
    // at part-sized GETs, so the pool model is the whole story.
    let (read_base, storage_servers) = if let Some(net) = calib::remote(&s.storage) {
        let conns = s.net_conns.max(1).min(net.max_conns.max(1));
        let base = match s.method {
            // Per-image share of a part-sized ranged GET on one connection.
            Method::Record => {
                net.request_time(calib::REMOTE_PART_BYTES as u64)
                    * (calib::IMG_BYTES / calib::REMOTE_PART_BYTES)
            }
            // One GET per image.
            Method::Raw => net.request_time(calib::IMG_BYTES as u64),
        };
        (base, conns)
    } else {
        let st = calib::storage(&s.storage, s.p3dn).expect("validated scenario");
        let base = match s.method {
            Method::Record => calib::IMG_BYTES / (st.seq_bw_mbs * 1e6),
            Method::Raw => calib::IMG_BYTES / (st.seq_bw_mbs * 1e6) + 1.0 / st.rand_iops,
        };
        (base, 1)
    };
    // Decoded-sample cache (steady state): a raw-method hit skips the
    // per-file fetch, thinning the mean per-image storage service time;
    // record streaming reads whole shards regardless of residency (as
    // the engine does), so only the CPU cost — which already carries the
    // hit-rate scaling via `cpu_cost_ms` — is reduced there.
    let read_base = if s.method == Method::Raw {
        read_base * (1.0 - s.prep_cache_hit())
    } else {
        read_base
    };
    // vCPU efficiency knee: inflate per-image cost so k nominal servers
    // deliver eff(k) worth of capacity.
    let cpu_scale = s.vcpus as f64 / calib::eff_vcpus(s.vcpus as f64);
    let cpu_base = s.cpu_cost_ms() / 1000.0 * cpu_scale;
    let gpu_img = s.gpu_cost_ms() / 1000.0;

    // Closed population: enough in-flight images to keep every stage fed.
    let population = batch * (s.gpus * 3) + s.vcpus * 2 + 32;

    let mut rng = Rng::new(s.seed);
    let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
    let mut seq = 0u64;
    let push = |heap: &mut BinaryHeap<Reverse<Event>>, t: f64, ev: Ev, seq: &mut u64| {
        *seq += 1;
        heap.push(Reverse(Event { t, seq: *seq, ev }));
    };

    let mut storage = Station::new(storage_servers);
    let mut cpus = Station::new(s.vcpus);
    let mut gpus = Station::new(s.gpus);
    let mut ready: usize = 0; // images waiting at the batcher
    let mut gpu_ready: VecDeque<usize> = VecDeque::new(); // queued batches
    let mut done: u64 = 0;
    let mut bytes_read: f64 = 0.0;
    let mut trace: Vec<UtilSample> = Vec::new();
    let (mut last_cpu, mut last_gpu, mut last_bytes, mut last_t) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);

    let jitter = |rng: &mut Rng| 0.9 + 0.2 * rng.f64();

    // Prime the closed network: all images start at the storage queue,
    // and every storage server (1 device, or the remote connection pool)
    // begins busy.
    storage.queue = population;
    while storage.try_start(0.0) {
        push(&mut heap, read_base * jitter(&mut rng), Ev::ReadDone, &mut seq);
    }
    push(&mut heap, 1.0, Ev::Sample, &mut seq);

    if s.ideal {
        // Ideal mode: every GPU spins on one resident batch; nothing flows.
        let t_batch = m.t_train_ms / 1000.0 * batch as f64;
        let steps = (s.seconds / t_batch.max(1e-12)).floor() * s.gpus as f64;
        return SimOutput {
            images_done: (steps * batch as f64) as u64,
            throughput_ips: steps * batch as f64 / s.seconds,
            cpu_util: 0.0,
            gpu_util: 1.0,
            io_mbps: 0.0,
            util_trace: vec![],
        };
    }

    let horizon = s.seconds;
    while let Some(Reverse(Event { t, ev, .. })) = heap.pop() {
        if t > horizon {
            break;
        }
        match ev {
            Ev::ReadDone => {
                storage.finish(t);
                bytes_read += calib::IMG_BYTES;
                cpus.queue += 1;
                while cpus.try_start(t) {
                    push(&mut heap, t + cpu_base * jitter(&mut rng), Ev::CpuDone, &mut seq);
                }
                if storage.try_start(t) {
                    push(&mut heap, t + read_base * jitter(&mut rng), Ev::ReadDone, &mut seq);
                }
            }
            Ev::CpuDone => {
                cpus.finish(t);
                // A server freed: start the next queued CPU job, if any.
                while cpus.try_start(t) {
                    push(&mut heap, t + cpu_base * jitter(&mut rng), Ev::CpuDone, &mut seq);
                }
                ready += 1;
                if ready >= batch {
                    ready -= batch;
                    gpus.queue += 1;
                    gpu_ready.push_back(batch);
                    while gpus.try_start(t) {
                        let b = gpu_ready.pop_front().unwrap_or(batch);
                        push(
                            &mut heap,
                            t + gpu_img * b as f64 * jitter(&mut rng),
                            Ev::GpuDone(b),
                            &mut seq,
                        );
                    }
                }
            }
            Ev::GpuDone(b) => {
                gpus.finish(t);
                done += b as u64;
                // Closed loop: images re-enter at the storage stage.
                storage.queue += b;
                while storage.try_start(t) {
                    push(&mut heap, t + read_base * jitter(&mut rng), Ev::ReadDone, &mut seq);
                }
                while gpus.try_start(t) {
                    let nb = gpu_ready.pop_front().unwrap_or(batch);
                    push(
                        &mut heap,
                        t + gpu_img * nb as f64 * jitter(&mut rng),
                        Ev::GpuDone(nb),
                        &mut seq,
                    );
                }
            }
            Ev::Sample => {
                storage.account(t);
                cpus.account(t);
                gpus.account(t);
                let dt = (t - last_t).max(1e-12);
                trace.push(UtilSample {
                    t,
                    cpu: (cpus.busy_time - last_cpu) / (dt * cpus.servers as f64),
                    device: (gpus.busy_time - last_gpu) / (dt * gpus.servers as f64),
                    io_mbps: (bytes_read - last_bytes) / dt / 1e6,
                });
                last_cpu = cpus.busy_time;
                last_gpu = gpus.busy_time;
                last_bytes = bytes_read;
                last_t = t;
                if t + 1.0 <= horizon {
                    push(&mut heap, t + 1.0, Ev::Sample, &mut seq);
                }
            }
        }
    }

    storage.account(horizon);
    cpus.account(horizon);
    gpus.account(horizon);
    SimOutput {
        images_done: done,
        throughput_ips: done as f64 / horizon,
        cpu_util: cpus.utilization(horizon),
        gpu_util: gpus.utilization(horizon),
        io_mbps: bytes_read / horizon / 1e6,
        util_trace: trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Placement;
    use crate::sim::analytic_throughput;

    fn run(model: &str, gpus: usize, vcpus: usize, pl: Placement) -> (f64, f64) {
        let s = Scenario {
            model: model.into(),
            gpus,
            vcpus,
            placement: pl,
            seconds: 40.0,
            ..Default::default()
        };
        (simulate(&s).throughput_ips, analytic_throughput(&s))
    }

    #[test]
    fn des_matches_analytic_within_tolerance() {
        for (m, g, v, pl) in [
            ("alexnet", 8, 64, Placement::Hybrid),
            ("alexnet", 4, 24, Placement::Hybrid),
            ("resnet50", 8, 64, Placement::Cpu),
            ("resnet50", 8, 16, Placement::Hybrid),
            ("shufflenet", 8, 64, Placement::Hybrid0),
        ] {
            let (des, ana) = run(m, g, v, pl);
            let rel = (des - ana).abs() / ana;
            assert!(rel < 0.15, "{m} {pl:?} g={g} v={v}: des {des:.0} vs ana {ana:.0}");
        }
    }

    #[test]
    fn des_remote_tier_matches_analytic() {
        // Remote storage station = a connection pool: the DES must agree
        // with the closed-form latency/conns overlap model across the
        // storage-bound range.
        for conns in [1usize, 4, 16] {
            let s = Scenario {
                model: "alexnet".into(),
                gpus: 8,
                vcpus: 64,
                storage: "s3".into(),
                net_conns: conns,
                seconds: 60.0,
                ..Default::default()
            };
            let des = simulate(&s).throughput_ips;
            let ana = analytic_throughput(&s);
            let rel = (des - ana).abs() / ana;
            assert!(rel < 0.15, "s3 conns={conns}: des {des:.0} vs ana {ana:.0} ({rel:.3})");
        }
    }

    #[test]
    fn des_prep_cache_matches_analytic() {
        // Warm decoded cache (steady state): the DES's thinned service
        // times must agree with the analytic hit-rate model, for both
        // policies and for a storage-bound remote scenario.
        use crate::pipeline::prep_cache::PrepCachePolicy;
        let half = crate::sim::calib::decoded_dataset_bytes() / 2.0 / 1e9;
        for (storage, conns, method, policy) in [
            ("ebs", 8usize, Method::Record, PrepCachePolicy::Minio),
            ("ebs", 8, Method::Record, PrepCachePolicy::Lru),
            // Raw method: cache hits also skip the per-file remote GET.
            ("s3", 1, Method::Raw, PrepCachePolicy::Minio),
        ] {
            let s = Scenario {
                model: "alexnet".into(),
                gpus: 8,
                vcpus: 24,
                method,
                storage: storage.into(),
                net_conns: conns,
                prep_cache_gb: half,
                prep_cache_policy: policy,
                seconds: 40.0,
                ..Default::default()
            };
            let des = simulate(&s).throughput_ips;
            let ana = analytic_throughput(&s);
            let rel = (des - ana).abs() / ana;
            assert!(
                rel < 0.15,
                "{storage}/{policy:?}: des {des:.0} vs ana {ana:.0} ({rel:.3})"
            );
            // The warm minio run must clearly beat the cold run (LRU's
            // gain at this size is within jitter noise — its admission
            // transform eats most of the thrashed hit savings).
            if policy == PrepCachePolicy::Minio {
                let cold =
                    simulate(&Scenario { prep_cache_gb: 0.0, ..s.clone() }).throughput_ips;
                assert!(des > cold * 1.2, "warm {des:.0} vs cold {cold:.0}");
            }
        }
    }

    #[test]
    fn des_fused_decode_matches_analytic_and_speeds_cpu_bound_runs() {
        // The fused-decode service-time thinning flows into the DES via
        // cpu_cost_ms: agreement with the analytic model must hold, and
        // a CPU-bound scenario must gain measurable throughput.
        let cold = Scenario {
            model: "alexnet".into(),
            gpus: 8,
            vcpus: 24,
            placement: Placement::Cpu,
            seconds: 40.0,
            ..Default::default()
        };
        let fused = Scenario { fused_decode: true, ..cold.clone() };
        let scaled = Scenario { fused_decode: true, decode_scale: 4, ..cold.clone() };
        for s in [&cold, &fused, &scaled] {
            let des = simulate(s).throughput_ips;
            let ana = analytic_throughput(s);
            let rel = (des - ana).abs() / ana;
            assert!(
                rel < 0.15,
                "fused={}/s{}: des {des:.0} vs ana {ana:.0}",
                s.fused_decode,
                s.decode_scale
            );
        }
        assert!(
            simulate(&scaled).throughput_ips > simulate(&cold).throughput_ips,
            "fused 1/4-scale decode must raise a CPU-bound run's throughput"
        );
    }

    #[test]
    fn des_utilization_identifies_bottleneck() {
        // ResNet50 record-hybrid (Fig. 4 right): GPU ~saturated, CPU low.
        let s = Scenario { model: "resnet50".into(), seconds: 40.0, ..Default::default() };
        let out = simulate(&s);
        assert!(out.gpu_util > 0.85, "gpu {:.2}", out.gpu_util);
        assert!(out.cpu_util < 0.55, "cpu {:.2}", out.cpu_util);
        // AlexNet record-hybrid (Fig. 4 left): CPU much busier than r50's.
        let s2 = Scenario { model: "alexnet".into(), seconds: 40.0, ..Default::default() };
        let out2 = simulate(&s2);
        assert!(out2.cpu_util > out.cpu_util + 0.2, "al cpu {:.2}", out2.cpu_util);
        assert!(out2.io_mbps > out.io_mbps, "al io should exceed r50 io");
    }

    #[test]
    fn des_trace_has_per_second_samples() {
        let s = Scenario { model: "resnet50".into(), seconds: 10.0, ..Default::default() };
        let out = simulate(&s);
        assert!(out.util_trace.len() >= 8, "{} samples", out.util_trace.len());
        // Steady-state samples should be positive for all resources.
        let last = out.util_trace.last().unwrap();
        assert!(last.device > 0.5 && last.io_mbps > 0.0);
    }

    #[test]
    fn des_ideal_mode_is_gpu_only() {
        let s = Scenario { model: "alexnet".into(), ideal: true, seconds: 10.0, ..Default::default() };
        let out = simulate(&s);
        assert!(out.cpu_util == 0.0 && out.io_mbps == 0.0);
        let ana = analytic_throughput(&s);
        assert!((out.throughput_ips - ana).abs() / ana < 0.1);
    }

    #[test]
    fn des_deterministic_per_seed() {
        let s = Scenario { model: "resnet18".into(), seconds: 15.0, ..Default::default() };
        let a = simulate(&s).images_done;
        let b = simulate(&s).images_done;
        assert_eq!(a, b);
    }
}
