//! Recycling slab pool for batch tensors — the zero-copy hot path's
//! memory substrate (`--slab-pool`).
//!
//! The per-sample `Vec` path allocates a fresh augment output per image
//! and then `collate()` memcpys all of it again into the batch buffer;
//! Mohan et al. ("Analyzing and Mitigating Data Stalls in DNN Training")
//! show exactly this allocator/memory churn stealing cycles from the
//! stall-critical preprocessing path.  The slab pool removes both costs:
//!
//! ```text
//!  SlabPool::slice()  ──▶  SlabSlice (one batch slot, exclusive)
//!        │                     │  worker augments *into* the slot
//!        │                     ▼
//!        │                batcher collects batch slices, seal()
//!        │                     │
//!        │                     ▼
//!        │                SlabTensor (read-only [B·C·H·W] view)
//!        │                     │  device trains on it, drops it
//!        ▼                     ▼
//!    free list  ◀──── arena recycles via RAII (Drop), bounded
//! ```
//!
//! At steady state the only f32 writes on the sample path are the ones
//! training reads, and the only allocation is one `Arc` per *batch*
//! (the open-slab handle) — no per-sample buffers, no collate memcpy.
//!
//! Exclusivity model: each slot is handed out exactly once per slab
//! generation, writers go through `SlabSlice::as_mut_slice` (`&mut
//! self`), and `seal` consumes every slice before the shared read-only
//! view exists — so writes never alias reads.  The pool bounds its idle
//! arenas (`max_free`); arenas beyond the bound free normally, so a
//! transient burst cannot pin memory forever.

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex, Weak};
use std::ops::Deref;

/// Arena alignment: batch tensors feed SIMD-friendly kernels, and a
/// cache-line start keeps neighboring slots from sharing a line head.
pub const SLAB_ALIGN: usize = 64;

/// A cache-line-aligned heap block of f32s.  Ownership and aliasing are
/// enforced by the pool/slice layer above; the arena itself is inert.
struct Arena {
    ptr: std::ptr::NonNull<f32>,
    len: usize,
}

// SAFETY: a plain owned heap block (no thread affinity); all access
// goes through raw pointers the slice/tensor layer guards.
unsafe impl Send for Arena {}
// SAFETY: the arena itself exposes no interior mutation — every write
// goes through an exclusive `SlabSlice` (`&mut self`) covering a
// disjoint slot range, so sharing `&Arena` across threads is sound.
unsafe impl Sync for Arena {}

impl Arena {
    fn layout(len: usize) -> std::alloc::Layout {
        std::alloc::Layout::from_size_align(len * std::mem::size_of::<f32>(), SLAB_ALIGN)
            .expect("slab layout")
    }

    fn new(len: usize) -> Arena {
        assert!(len > 0, "empty slab arena");
        let layout = Self::layout(len);
        // Zeroed on first allocation so a never-filled slot can never
        // leak unrelated heap contents; recycled arenas are fully
        // overwritten slot by slot before they are ever read.
        // SAFETY: `layout` has non-zero size (`len > 0` asserted above)
        // and a valid power-of-two alignment (SLAB_ALIGN).
        let raw = unsafe { std::alloc::alloc_zeroed(layout) } as *mut f32;
        let Some(ptr) = std::ptr::NonNull::new(raw) else {
            std::alloc::handle_alloc_error(layout)
        };
        Arena { ptr, len }
    }
}

impl Drop for Arena {
    fn drop(&mut self) {
        // SAFETY: `ptr` came from `alloc_zeroed` with exactly this
        // layout (`len` is immutable after construction), and Drop runs
        // at most once on the sole owner.
        unsafe { std::alloc::dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.len)) }
    }
}

/// One checked-out batch arena.  Dropping the last handle (slices or the
/// sealed tensor) returns the arena to its pool's free list — the RAII
/// recycle path.
struct SlabInner {
    /// `None` only transiently inside `drop` (the arena moves back to
    /// the pool's free list).
    arena: Option<Arena>,
    seq: u64,
    batch: usize,
    sample_len: usize,
    pool: Weak<SlabPool>,
}

impl SlabInner {
    fn base(&self) -> *mut f32 {
        self.arena.as_ref().expect("arena live").ptr.as_ptr()
    }

    fn slot_ptr(&self, slot: usize) -> *mut f32 {
        debug_assert!(slot < self.batch, "slot {slot} out of {}", self.batch);
        // SAFETY: slot < batch, arena holds batch * sample_len floats.
        unsafe { self.base().add(slot * self.sample_len) }
    }
}

impl Drop for SlabInner {
    fn drop(&mut self) {
        if let (Some(pool), Some(arena)) = (self.pool.upgrade(), self.arena.take()) {
            pool.recycle(arena);
        }
        // Pool already gone (run teardown): the arena frees normally.
    }
}

struct OpenSlab {
    inner: Arc<SlabInner>,
    next_slot: usize,
}

/// Recycling pool of batch-sized, cache-line-aligned f32 arenas.
/// Shared across CPU workers (`Arc<SlabPool>`); `slice()` hands out the
/// next batch slot, one writer each.
pub struct SlabPool {
    sample_len: usize,
    batch: usize,
    /// Idle arenas kept for reuse; recycles beyond this free instead.
    max_free: usize,
    free: Mutex<Vec<Arena>>,
    open: Mutex<Option<OpenSlab>>,
    next_seq: AtomicU64,
    hits: AtomicU64,
    grows: AtomicU64,
}

impl SlabPool {
    /// `sample_len` floats per slot, `batch` slots per slab, at most
    /// `max_free` idle arenas retained for reuse.
    pub fn new(sample_len: usize, batch: usize, max_free: usize) -> Arc<SlabPool> {
        assert!(sample_len > 0 && batch > 0, "degenerate slab geometry");
        Arc::new(SlabPool {
            sample_len,
            batch,
            max_free,
            free: Mutex::new(Vec::new()),
            open: Mutex::new(None),
            next_seq: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            grows: AtomicU64::new(0),
        })
    }

    pub fn sample_len(&self) -> usize {
        self.sample_len
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Check out the next batch slot.  Slots of one slab are handed out
    /// exactly once each, in order; when the slab is fully handed out
    /// the pool drops its reference, so the consumers alone decide when
    /// it recycles.  Never blocks — outstanding slabs are bounded by the
    /// pipeline's bounded queues, not by the pool.
    pub fn slice(self: &Arc<Self>) -> SlabSlice {
        // poison: holders of `open` and `free` (here, `recycle`,
        // `free_len`) only move arenas and bump counters — allocation
        // aside, nothing under either lock can panic, and an allocation
        // failure aborts rather than poisons.
        let mut open = self.open.lock().unwrap();
        if open.is_none() {
            // poison: see above.
            let arena = match self.free.lock().unwrap().pop() {
                Some(a) => {
                    // ordering: Relaxed — monotonic telemetry counter,
                    // only read after the threads quiesce (or as an
                    // approximate live stat); orders nothing.
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    a
                }
                None => {
                    // ordering: Relaxed — telemetry counter, as above.
                    self.grows.fetch_add(1, Ordering::Relaxed);
                    Arena::new(self.sample_len * self.batch)
                }
            };
            // ordering: Relaxed — uniqueness of the sequence number is
            // all that matters (fetch_add is atomic at any ordering);
            // callers never infer cross-thread visibility from it, and
            // this call already runs under the `open` mutex.
            let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
            *open = Some(OpenSlab {
                inner: Arc::new(SlabInner {
                    arena: Some(arena),
                    seq,
                    batch: self.batch,
                    sample_len: self.sample_len,
                    pool: Arc::downgrade(self),
                }),
                next_slot: 0,
            });
        }
        let os = open.as_mut().unwrap();
        let slot = os.next_slot;
        os.next_slot += 1;
        let slice = SlabSlice { inner: os.inner.clone(), slot };
        let exhausted = os.next_slot == self.batch;
        if exhausted {
            *open = None;
        }
        slice
    }

    fn recycle(&self, arena: Arena) {
        // poison: see `slice` — Vec ops only under this lock.
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_free {
            free.push(arena);
        }
        // else: drop — the pool never pins more than max_free idle arenas.
    }

    /// Arenas served from the free list (recycles that saved an alloc).
    pub fn hits(&self) -> u64 {
        // ordering: Relaxed — approximate telemetry read; see `slice`.
        self.hits.load(Ordering::Relaxed)
    }

    /// Fresh arena allocations (pool cold or burst beyond the free list).
    pub fn grows(&self) -> u64 {
        // ordering: Relaxed — approximate telemetry read; see `slice`.
        self.grows.load(Ordering::Relaxed)
    }

    /// Idle arenas currently held (≤ `max_free` by construction).
    pub fn free_len(&self) -> usize {
        // poison: see `slice` — Vec ops only under this lock.
        self.free.lock().unwrap().len()
    }
}

/// Exclusive write handle onto one batch slot of a slab.  Produced by
/// [`SlabPool::slice`], consumed by [`seal`]; the worker writes its
/// augmented sample through [`as_mut_slice`](Self::as_mut_slice).
pub struct SlabSlice {
    inner: Arc<SlabInner>,
    slot: usize,
}

impl SlabSlice {
    /// Slab generation this slot belongs to — the batcher's group key.
    pub fn slab_seq(&self) -> u64 {
        self.inner.seq
    }

    pub fn slot(&self) -> usize {
        self.slot
    }

    pub fn len(&self) -> usize {
        self.inner.sample_len
    }

    pub fn is_empty(&self) -> bool {
        self.inner.sample_len == 0
    }

    /// The writable slot.
    ///
    /// SAFETY argument: the pool hands each (slab, slot) pair to exactly
    /// one `SlabSlice`, sibling slices cover disjoint ranges, and the
    /// shared read view ([`SlabTensor`]) only exists after `seal`
    /// consumed every slice — so this `&mut` never aliases.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        // SAFETY: `slot_ptr` is in-bounds and aligned; exclusivity per
        // the doc argument above (one slice per slot, disjoint ranges,
        // no reader until `seal`), and `&mut self` pins this slice.
        unsafe {
            std::slice::from_raw_parts_mut(self.inner.slot_ptr(self.slot), self.inner.sample_len)
        }
    }

    pub fn as_slice(&self) -> &[f32] {
        // SAFETY: in-bounds slot range; the only possible writer is
        // this same slice via `&mut self`, which cannot coexist with
        // this `&self` borrow.
        unsafe {
            std::slice::from_raw_parts(self.inner.slot_ptr(self.slot), self.inner.sample_len)
        }
    }
}

impl Clone for SlabSlice {
    /// A *detached* deep copy: slot exclusivity cannot be shared, so the
    /// clone gets its own single-slot arena (same bytes, same `slab_seq`
    /// label, slot 0, no pool link).  Exists only because `Payload`
    /// derives `Clone`; the hot path never clones a slice, and clones
    /// are not sealable alongside the originals.
    fn clone(&self) -> Self {
        let arena = Arena::new(self.inner.sample_len);
        // SAFETY: source is this slice's own in-bounds slot, the
        // destination is a freshly allocated arena of the same length —
        // distinct allocations cannot overlap, both are valid for
        // `sample_len` f32s.
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.inner.slot_ptr(self.slot),
                arena.ptr.as_ptr(),
                self.inner.sample_len,
            );
        }
        SlabSlice {
            inner: Arc::new(SlabInner {
                arena: Some(arena),
                seq: self.inner.seq,
                batch: 1,
                sample_len: self.inner.sample_len,
                pool: Weak::new(),
            }),
            slot: 0,
        }
    }
}

impl std::fmt::Debug for SlabSlice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SlabSlice {{ slab: {}, slot: {} }}", self.inner.seq, self.slot)
    }
}

/// Why a seal was refused (maps to `BatchKindError` at the batcher).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlabSealError(pub &'static str);

/// Seal a fully-handed-out slab into its read-only batch view.  Requires
/// every slot of the slab, exactly once, all from the same generation —
/// anything else means the batcher grouped wrong, and reading unfilled
/// slots would serve stale pixels.
pub fn seal(slices: Vec<SlabSlice>) -> Result<SlabTensor, SlabSealError> {
    let Some(first) = slices.first() else {
        return Err(SlabSealError("empty slab batch"));
    };
    let inner = first.inner.clone();
    if slices.len() != inner.batch {
        return Err(SlabSealError("slab not fully filled"));
    }
    let mut seen = vec![false; inner.batch];
    for s in &slices {
        if !Arc::ptr_eq(&s.inner, &inner) {
            return Err(SlabSealError("slices from different slabs"));
        }
        if seen[s.slot] {
            return Err(SlabSealError("duplicate slot"));
        }
        seen[s.slot] = true;
    }
    drop(slices); // last writers gone: the read-only view is now sound
    Ok(SlabTensor { inner })
}

/// Read-only view of a sealed slab: `batch * sample_len` contiguous
/// f32s, slot-major.  Clones are refcount bumps (sealed = immutable);
/// dropping the last handle recycles the arena.
pub struct SlabTensor {
    inner: Arc<SlabInner>,
}

impl SlabTensor {
    pub fn slab_seq(&self) -> u64 {
        self.inner.seq
    }
}

impl Deref for SlabTensor {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        // SAFETY: seal consumed every slice — no writer exists, shared
        // reads only from here on.
        unsafe {
            std::slice::from_raw_parts(self.inner.base(), self.inner.batch * self.inner.sample_len)
        }
    }
}

impl Clone for SlabTensor {
    fn clone(&self) -> Self {
        SlabTensor { inner: self.inner.clone() }
    }
}

impl std::fmt::Debug for SlabTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SlabTensor {{ slab: {}, len: {} }}",
            self.inner.seq,
            self.inner.batch * self.inner.sample_len
        )
    }
}

/// Batch tensor storage: an owned `Vec` (the historical collate path) or
/// a sealed slab (the zero-copy path).  Derefs to `[f32]` either way, so
/// consumers (the device literal builder, the tests) never branch.
pub enum TensorBuf {
    Vec(Vec<f32>),
    Slab(SlabTensor),
}

impl TensorBuf {
    pub fn as_slice(&self) -> &[f32] {
        self
    }
}

impl Deref for TensorBuf {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        match self {
            TensorBuf::Vec(v) => v,
            TensorBuf::Slab(s) => s,
        }
    }
}

impl From<Vec<f32>> for TensorBuf {
    fn from(v: Vec<f32>) -> Self {
        TensorBuf::Vec(v)
    }
}

impl Clone for TensorBuf {
    fn clone(&self) -> Self {
        match self {
            TensorBuf::Vec(v) => TensorBuf::Vec(v.clone()),
            // Sealed slabs are immutable: refcount bump, no pixel copy.
            TensorBuf::Slab(s) => TensorBuf::Slab(s.clone()),
        }
    }
}

impl std::fmt::Debug for TensorBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TensorBuf::Vec(v) => write!(f, "TensorBuf::Vec(len {})", v.len()),
            TensorBuf::Slab(s) => write!(f, "TensorBuf::Slab({s:?})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn slot_lifecycle_fill_seal_read_recycle() {
        let pool = SlabPool::new(4, 3, 2);
        let mut slices: Vec<SlabSlice> = (0..3).map(|_| pool.slice()).collect();
        assert_eq!(pool.grows(), 1);
        assert_eq!(pool.hits(), 0);
        for (i, s) in slices.iter_mut().enumerate() {
            assert_eq!(s.slot(), i);
            assert_eq!(s.len(), 4);
            s.as_mut_slice().copy_from_slice(&[i as f32; 4]);
        }
        let seq = slices[0].slab_seq();
        let t = seal(slices).unwrap();
        assert_eq!(t.slab_seq(), seq);
        assert_eq!(t.len(), 12);
        assert_eq!(&t[4..8], &[1.0; 4]);
        // Dropping the tensor recycles the arena; the next slab reuses it.
        drop(t);
        assert_eq!(pool.free_len(), 1);
        let s = pool.slice();
        assert_eq!(pool.hits(), 1);
        assert_eq!(pool.grows(), 1);
        assert_eq!(s.slab_seq(), seq + 1);
    }

    #[test]
    fn seal_rejects_partial_mixed_and_duplicate() {
        let pool = SlabPool::new(2, 2, 2);
        let a0 = pool.slice();
        let a1 = pool.slice();
        let b0 = pool.slice(); // next slab
        assert_ne!(a0.slab_seq(), b0.slab_seq());
        assert_eq!(seal(vec![]).unwrap_err(), SlabSealError("empty slab batch"));
        let a0b = a0.clone(); // detached copy, not the real slot
        assert!(seal(vec![a0, b0]).is_err(), "mixed slabs must not seal");
        assert!(seal(vec![a1, a0b]).is_err(), "a clone is not the original slot");
    }

    #[test]
    fn clone_is_a_detached_deep_copy() {
        let pool = SlabPool::new(3, 1, 1);
        let mut s = pool.slice();
        s.as_mut_slice().copy_from_slice(&[7.0, 8.0, 9.0]);
        let c = s.clone();
        assert_eq!(c.as_slice(), &[7.0, 8.0, 9.0]);
        assert_eq!(c.slab_seq(), s.slab_seq());
        // Writing the original does not move the clone.
        s.as_mut_slice()[0] = 0.0;
        assert_eq!(c.as_slice()[0], 7.0);
    }

    #[test]
    fn free_list_stays_bounded() {
        let pool = SlabPool::new(2, 1, 2);
        // Five concurrent slabs, all recycled: only max_free survive.
        let slabs: Vec<SlabSlice> = (0..5).map(|_| pool.slice()).collect();
        assert_eq!(pool.grows(), 5);
        drop(slabs);
        assert_eq!(pool.free_len(), 2);
        // Reuse serves from the free list before growing again.
        let _a = pool.slice();
        let _b = pool.slice();
        let _c = pool.slice();
        assert_eq!(pool.hits(), 2);
        assert_eq!(pool.grows(), 6);
    }

    #[test]
    fn tensor_buf_derefs_both_arms() {
        let v: TensorBuf = vec![1.0f32, 2.0].into();
        assert_eq!(v.len(), 2);
        assert_eq!(v[1], 2.0);
        assert_eq!(v.as_slice(), &[1.0, 2.0]);
        let pool = SlabPool::new(1, 1, 1);
        let mut s = pool.slice();
        s.as_mut_slice()[0] = 5.0;
        let t = TensorBuf::Slab(seal(vec![s]).unwrap());
        assert_eq!(&t[..], &[5.0]);
        let t2 = t.clone();
        assert_eq!(&t2[..], &[5.0]);
        assert!(format!("{t:?}").contains("Slab"));
    }

    /// The ISSUE's concurrency satellite: checkout/recycle under
    /// `workers_max` threads — no slot handed out twice, every write
    /// lands where its slot says, and the pool stays bounded.
    #[test]
    fn concurrent_checkout_never_double_hands_a_slot() {
        let workers = 8usize;
        // Miri interprets every access; keep its schedule short (the
        // full-size run still executes under plain `cargo test`).
        let per_worker = if cfg!(miri) { 16usize } else { 200usize };
        let pool = SlabPool::new(4, 8, 3);
        let seen = std::sync::Arc::new(Mutex::new(HashSet::new()));
        let hs: Vec<_> = (0..workers)
            .map(|t| {
                let pool = pool.clone();
                let seen = seen.clone();
                std::thread::spawn(move || {
                    for i in 0..per_worker {
                        let mut s = pool.slice();
                        let tag = (t * per_worker + i) as f32;
                        for v in s.as_mut_slice() {
                            *v = tag;
                        }
                        assert!(
                            seen.lock().unwrap().insert((s.slab_seq(), s.slot())),
                            "slot ({}, {}) handed out twice",
                            s.slab_seq(),
                            s.slot()
                        );
                        // The write stayed in this slot.
                        assert!(s.as_slice().iter().all(|&v| v == tag));
                        // Dropped here: partial slabs recycle once every
                        // sibling slice drops too.
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(seen.lock().unwrap().len(), workers * per_worker);
        assert!(pool.free_len() <= 3, "free list exceeded its bound");
        assert!(pool.hits() + pool.grows() >= (workers * per_worker / 8) as u64);
    }
}
