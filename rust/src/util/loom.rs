//! In-tree bounded model checker behind the `util::sync` facade.
//!
//! The build container has no network access, so the real `loom` crate
//! cannot be added as a dev-dependency.  This module provides the
//! subset of loom's interface the codebase needs — `model()`, checked
//! `Mutex`/`Condvar`, checked atomics, and a `thread` facade —
//! implemented as a depth-first enumeration of thread interleavings
//! with a bounded number of preemptions (`LOOM_MAX_PREEMPTIONS`,
//! default 2), the same exploration strategy loom uses for schedule
//! nondeterminism.
//!
//! Honest scope statement: this checker explores **sequentially
//! consistent** interleavings only.  Every modeled atomic op maps to a
//! `SeqCst` op on a real atomic with a scheduler yield point in front,
//! so it finds lost updates, statement-level publication-before-init
//! races, lost notifications, double-handouts, and deadlocks — but it
//! does not model C++11 weak memory (store buffering, IRIW).  Weak
//! memory is instead covered by the `// ordering:` audit rule
//! (`dpp audit`) plus the ThreadSanitizer CI job.
//!
//! How a model runs: `model(f)` executes `f` once per explored
//! schedule.  Model tasks run on real OS threads, but a global
//! scheduler serializes them: exactly one task is runnable at a time,
//! and at every yield point (each atomic op, lock acquire, condvar op,
//! spawn/join) the scheduler consults a recorded decision path to pick
//! the next task.  After each execution the last not-yet-exhausted
//! decision is advanced DFS-style until the bounded space is drained
//! (hard iteration cap `LOOM_MAX_ITERS`, default 100 000).
//!
//! Rules for writing models:
//! * create all shared state *inside* the closure — resource ids are
//!   registered per execution;
//! * join every spawned thread — a detached, permanently-blocked
//!   thread is reported as a deadlock;
//! * don't assert on wall-clock time (`Instant` is real time, which is
//!   meaningless under the model); assert on counters instead;
//! * `Condvar::wait_timeout` "fires" its timeout only when no other
//!   task can make progress, so timeout-based control loops terminate
//!   without producing false lost-wakeup reports.

use std::cell::{RefCell, UnsafeCell};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, OnceLock};
use std::time::Duration;

const DEFAULT_MAX_PREEMPTIONS: usize = 2;
const DEFAULT_MAX_ITERS: usize = 100_000;

/// Sentinel unwind payload used to tear down tasks once an execution
/// aborts (failure found elsewhere).  Raised with `resume_unwind` so
/// the global panic hook stays quiet.
struct ModelAbort;

fn abort_unwind() -> ! {
    resume_unwind(Box::new(ModelAbort))
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Wait {
    Mutex(usize),
    Cond(usize),
    CondTimed(usize),
    Join(usize),
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum TaskState {
    Runnable,
    Blocked(Wait),
    Finished,
}

struct SchedState {
    tasks: Vec<TaskState>,
    current: usize,
    /// DFS decision path: `(choice_taken, n_options)` per choice point.
    path: Vec<(usize, usize)>,
    cursor: usize,
    preemptions: usize,
    max_preemptions: usize,
    abort: bool,
    failure: Option<String>,
    /// One entry per registered mutex: the owning task, if locked.
    mutex_owners: Vec<Option<usize>>,
    n_condvars: usize,
    /// Per task: did the last `wait_timeout` end by timeout?
    timed_out: Vec<bool>,
}

struct Sched {
    m: StdMutex<SchedState>,
    cv: StdCondvar,
}

type StGuard<'a> = std::sync::MutexGuard<'a, SchedState>;

impl Sched {
    fn new(max_preemptions: usize) -> Self {
        Sched {
            m: StdMutex::new(SchedState {
                tasks: Vec::new(),
                current: 0,
                path: Vec::new(),
                cursor: 0,
                preemptions: 0,
                max_preemptions,
                abort: false,
                failure: None,
                mutex_owners: Vec::new(),
                n_condvars: 0,
                timed_out: Vec::new(),
            }),
            cv: StdCondvar::new(),
        }
    }

    fn lock_state(&self) -> StGuard<'_> {
        self.m.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Consume (replay) or append (extend) one DFS decision.
    fn choose(st: &mut SchedState, n: usize) -> usize {
        if n <= 1 {
            return 0;
        }
        if st.cursor < st.path.len() {
            let (c, m) = st.path[st.cursor];
            if m != n {
                st.failure
                    .get_or_insert_with(|| "nondeterministic replay (schedule shape changed between executions)".into());
                st.abort = true;
                return 0;
            }
            st.cursor += 1;
            c
        } else {
            st.path.push((0, n));
            st.cursor += 1;
            0
        }
    }

    /// Pick the next task to run.  Fires pending timeouts only when
    /// nothing else is runnable; declares deadlock when no task can
    /// ever run again.
    fn schedule(&self, st: &mut SchedState) {
        loop {
            let runnable: Vec<usize> = (0..st.tasks.len())
                .filter(|&t| st.tasks[t] == TaskState::Runnable)
                .collect();
            if runnable.is_empty() {
                let timed: Vec<usize> = (0..st.tasks.len())
                    .filter(|&t| matches!(st.tasks[t], TaskState::Blocked(Wait::CondTimed(_))))
                    .collect();
                if !timed.is_empty() {
                    for t in timed {
                        st.timed_out[t] = true;
                        st.tasks[t] = TaskState::Runnable;
                    }
                    continue;
                }
                if st.tasks.iter().all(|t| *t == TaskState::Finished) {
                    return;
                }
                st.failure
                    .get_or_insert_with(|| format!("deadlock: all live tasks blocked ({:?})", st.tasks));
                st.abort = true;
                return;
            }
            let cur = st.current;
            let cur_runnable = st.tasks.get(cur) == Some(&TaskState::Runnable);
            let chosen = if cur_runnable {
                if st.preemptions >= st.max_preemptions {
                    cur
                } else {
                    let mut cands = vec![cur];
                    cands.extend(runnable.iter().copied().filter(|&t| t != cur));
                    let c = Self::choose(st, cands.len());
                    if st.abort {
                        return;
                    }
                    let ch = cands[c];
                    if ch != cur {
                        st.preemptions += 1;
                    }
                    ch
                }
            } else {
                let c = Self::choose(st, runnable.len());
                if st.abort {
                    return;
                }
                runnable[c]
            };
            st.current = chosen;
            return;
        }
    }

    /// Block until this task is scheduled (or the execution aborts).
    fn wait_mine<'a>(&'a self, mut st: StGuard<'a>, me: usize) -> StGuard<'a> {
        loop {
            if st.abort {
                drop(st);
                abort_unwind();
            }
            if st.current == me && st.tasks[me] == TaskState::Runnable {
                return st;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// A plain interleaving point: offer the scheduler a chance to
    /// switch to another task, then wait until this task runs again.
    fn yield_point(&self, me: usize) {
        let mut st = self.lock_state();
        if st.abort {
            drop(st);
            abort_unwind();
        }
        self.schedule(&mut st);
        self.cv.notify_all();
        let st = self.wait_mine(st, me);
        drop(st);
    }

    /// Mark `me` blocked on `w`, schedule someone else, and return once
    /// `me` has been made runnable and scheduled again.
    fn block_on(&self, me: usize, w: Wait) {
        let mut st = self.lock_state();
        if st.abort {
            drop(st);
            abort_unwind();
        }
        st.tasks[me] = TaskState::Blocked(w);
        self.schedule(&mut st);
        self.cv.notify_all();
        let st = self.wait_mine(st, me);
        drop(st);
    }
}

#[derive(Clone)]
struct TaskCtx {
    sched: Arc<Sched>,
    id: usize,
}

thread_local! {
    static TASK: RefCell<Option<TaskCtx>> = const { RefCell::new(None) };
}

fn cur_ctx() -> Option<TaskCtx> {
    TASK.with(|t| t.borrow().clone())
}

fn require_ctx(what: &str) -> TaskCtx {
    cur_ctx().unwrap_or_else(|| panic!("loom {what} used outside model()"))
}

/// Yield point used by the checked atomics: interleave only when
/// running inside a model; a no-op otherwise so const-init statics and
/// non-model code keep working in `--cfg loom` builds.
fn hook() {
    if let Some(ctx) = cur_ctx() {
        ctx.sched.yield_point(ctx.id);
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "model task panicked".to_string()
    }
}

/// Spawn the real OS thread backing model task `id` (already
/// registered in the scheduler).  Returns the real handle and the slot
/// the task's return value is parked in.
fn spawn_task<T: Send + 'static>(
    sched: &Arc<Sched>,
    id: usize,
    f: impl FnOnce() -> T + Send + 'static,
    name: Option<String>,
) -> (std::thread::JoinHandle<()>, Arc<StdMutex<Option<T>>>) {
    let slot = Arc::new(StdMutex::new(None));
    let slot2 = Arc::clone(&slot);
    let s2 = Arc::clone(sched);
    let mut b = std::thread::Builder::new();
    if let Some(n) = &name {
        b = b.name(n.clone());
    }
    let h = b
        .spawn(move || {
            TASK.with(|t| *t.borrow_mut() = Some(TaskCtx { sched: Arc::clone(&s2), id }));
            let res = catch_unwind(AssertUnwindSafe(|| {
                let st = s2.lock_state();
                let st = s2.wait_mine(st, id);
                drop(st);
                f()
            }));
            let mut st = s2.lock_state();
            match res {
                Ok(v) => {
                    *slot2.lock().unwrap_or_else(|e| e.into_inner()) = Some(v);
                }
                Err(p) => {
                    if p.downcast_ref::<ModelAbort>().is_none() {
                        let msg = panic_msg(p.as_ref());
                        st.failure.get_or_insert(msg);
                        st.abort = true;
                    }
                }
            }
            st.tasks[id] = TaskState::Finished;
            for t in 0..st.tasks.len() {
                if st.tasks[t] == TaskState::Blocked(Wait::Join(id)) {
                    st.tasks[t] = TaskState::Runnable;
                }
            }
            if st.current == id && !st.abort {
                s2.schedule(&mut st);
            }
            s2.cv.notify_all();
        })
        .expect("spawn model task thread");
    (h, slot)
}

/// Advance the DFS path to the next unexplored schedule.  Returns
/// false when the space is exhausted.
fn advance(path: &mut Vec<(usize, usize)>) -> bool {
    while let Some(&(c, n)) = path.last() {
        if c + 1 < n {
            path.last_mut().expect("non-empty").0 = c + 1;
            return true;
        }
        path.pop();
    }
    false
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Run `f` under every schedule the bounded explorer can reach,
/// returning the number of executions explored.  Panics (on the
/// calling thread) with the recorded failure if any execution asserts,
/// panics, or deadlocks.
pub fn explore<F>(f: F) -> usize
where
    F: Fn() + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let max_preemptions = env_usize("LOOM_MAX_PREEMPTIONS", DEFAULT_MAX_PREEMPTIONS);
    let max_iters = env_usize("LOOM_MAX_ITERS", DEFAULT_MAX_ITERS);
    let sched = Arc::new(Sched::new(max_preemptions));
    let mut iters = 0usize;
    loop {
        iters += 1;
        if iters > max_iters {
            panic!("loom: exceeded LOOM_MAX_ITERS={max_iters} executions; shrink the model or raise the cap");
        }
        {
            let mut st = sched.lock_state();
            st.tasks.clear();
            st.tasks.push(TaskState::Runnable); // task 0: the model body
            st.current = 0;
            st.cursor = 0;
            st.preemptions = 0;
            st.abort = false;
            for o in &mut st.mutex_owners {
                *o = None;
            }
            st.timed_out.clear();
            st.timed_out.push(false);
        }
        let body = Arc::clone(&f);
        let (h, _slot) = spawn_task(&sched, 0, move || (&*body)(), Some("main".into()));
        {
            let mut st = sched.lock_state();
            while !st.tasks.iter().all(|t| *t == TaskState::Finished) {
                st = sched.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }
        let _ = h.join();
        let mut st = sched.lock_state();
        if let Some(fail) = st.failure.clone() {
            panic!(
                "loom model failed after {iters} execution(s): {fail}\nschedule path: {:?}",
                st.path
            );
        }
        if !advance(&mut st.path) {
            return iters;
        }
    }
}

/// loom-compatible entry point: explore every bounded schedule of `f`.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    explore(f);
}

// ---------------------------------------------------------------------------
// Checked sync primitives
// ---------------------------------------------------------------------------

/// Mirror of `std::sync::PoisonError`, so `.lock().unwrap()` and
/// `.unwrap_or_else(|e| e.into_inner())` both compile against the shim.
#[derive(Debug)]
pub struct PoisonError<T>(T);

impl<T> PoisonError<T> {
    pub fn into_inner(self) -> T {
        self.0
    }
}

pub type LockResult<T> = Result<T, PoisonError<T>>;

/// Model-checked mutex: ownership lives in the scheduler, data behind
/// an `UnsafeCell`.  Barging (unfair): unlock wakes every waiter and
/// lets the scheduler pick who retries first.
pub struct Mutex<T> {
    id: OnceLock<usize>,
    data: UnsafeCell<T>,
}

// SAFETY: the model scheduler runs exactly one task at a time and the
// `mutex_owners` table grants at most one task ownership of `data`
// between lock and unlock, so sharing the cell across model threads
// cannot produce concurrent access; `T: Send` keeps the payload itself
// movable across those threads.
unsafe impl<T: Send> Send for Mutex<T> {}
// SAFETY: see the `Send` impl above — scheduler-serialized exclusive
// ownership stands in for the real mutex's synchronization.
unsafe impl<T: Send> Sync for Mutex<T> {}

impl<T> Mutex<T> {
    pub const fn new(data: T) -> Self {
        Mutex { id: OnceLock::new(), data: UnsafeCell::new(data) }
    }

    fn mid(&self, st: &mut SchedState) -> usize {
        *self.id.get_or_init(|| {
            st.mutex_owners.push(None);
            st.mutex_owners.len() - 1
        })
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        let ctx = require_ctx("Mutex");
        ctx.sched.yield_point(ctx.id);
        loop {
            let mut st = ctx.sched.lock_state();
            if st.abort {
                drop(st);
                abort_unwind();
            }
            let mid = self.mid(&mut st);
            if st.mutex_owners[mid].is_none() {
                st.mutex_owners[mid] = Some(ctx.id);
                drop(st);
                return Ok(MutexGuard { lock: self });
            }
            st.tasks[ctx.id] = TaskState::Blocked(Wait::Mutex(mid));
            ctx.sched.schedule(&mut st);
            ctx.sched.cv.notify_all();
            let st = ctx.sched.wait_mine(st, ctx.id);
            drop(st);
        }
    }
}

pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // SAFETY: this guard exists only while the scheduler's
        // `mutex_owners` entry names the current task, and the
        // scheduler serializes all model tasks, so no other reference
        // to the cell's contents can be live.
        unsafe { &*self.lock.data.get() }
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: as in `deref` — exclusive, scheduler-enforced
        // ownership for the guard's lifetime.
        unsafe { &mut *self.lock.data.get() }
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Must stay panic-free: guards also drop while unwinding a
        // ModelAbort.  Releases ownership and wakes all waiters.
        if let Some(ctx) = cur_ctx() {
            let mut st = ctx.sched.lock_state();
            if let Some(&mid) = self.lock.id.get() {
                if mid < st.mutex_owners.len() {
                    st.mutex_owners[mid] = None;
                    for t in 0..st.tasks.len() {
                        if st.tasks[t] == TaskState::Blocked(Wait::Mutex(mid)) {
                            st.tasks[t] = TaskState::Runnable;
                        }
                    }
                }
            }
            ctx.sched.cv.notify_all();
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct WaitTimeoutResult {
    timed_out: bool,
}

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.timed_out
    }
}

/// Model-checked condvar.  Timeouts fire only when every other task is
/// blocked (see module docs).
pub struct Condvar {
    id: OnceLock<usize>,
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl Condvar {
    pub const fn new() -> Self {
        Condvar { id: OnceLock::new() }
    }

    fn cid(&self, st: &mut SchedState) -> usize {
        *self.id.get_or_init(|| {
            st.n_condvars += 1;
            st.n_condvars - 1
        })
    }

    fn release_mutex(st: &mut SchedState, mid: usize) {
        st.mutex_owners[mid] = None;
        for t in 0..st.tasks.len() {
            if st.tasks[t] == TaskState::Blocked(Wait::Mutex(mid)) {
                st.tasks[t] = TaskState::Runnable;
            }
        }
    }

    fn wait_inner<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        timed: bool,
    ) -> (MutexGuard<'a, T>, bool) {
        let ctx = require_ctx("Condvar");
        let lock = guard.lock;
        std::mem::forget(guard); // release manually below; avoid double-unlock
        {
            let mut st = ctx.sched.lock_state();
            if st.abort {
                drop(st);
                abort_unwind();
            }
            let cid = self.cid(&mut st);
            let mid = lock.mid(&mut st);
            Self::release_mutex(&mut st, mid);
            st.timed_out[ctx.id] = false;
            let wait = if timed { Wait::CondTimed(cid) } else { Wait::Cond(cid) };
            st.tasks[ctx.id] = TaskState::Blocked(wait);
            ctx.sched.schedule(&mut st);
            ctx.sched.cv.notify_all();
            let st = ctx.sched.wait_mine(st, ctx.id);
            drop(st);
        }
        let reacquired = lock.lock().unwrap_or_else(|e| e.into_inner());
        let fired = {
            let st = ctx.sched.lock_state();
            st.timed_out[ctx.id]
        };
        (reacquired, fired)
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let (g, _) = self.wait_inner(guard, false);
        Ok(g)
    }

    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        _dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        let (g, fired) = self.wait_inner(guard, true);
        Ok((g, WaitTimeoutResult { timed_out: fired }))
    }

    fn wake(&self, all: bool) {
        let ctx = require_ctx("Condvar");
        ctx.sched.yield_point(ctx.id);
        let mut st = ctx.sched.lock_state();
        if st.abort {
            drop(st);
            abort_unwind();
        }
        let cid = self.cid(&mut st);
        let waiters: Vec<usize> = (0..st.tasks.len())
            .filter(|&t| {
                st.tasks[t] == TaskState::Blocked(Wait::Cond(cid))
                    || st.tasks[t] == TaskState::Blocked(Wait::CondTimed(cid))
            })
            .collect();
        if waiters.is_empty() {
            return;
        }
        if all {
            for t in waiters {
                st.timed_out[t] = false;
                st.tasks[t] = TaskState::Runnable;
            }
        } else {
            let c = Sched::choose(&mut st, waiters.len());
            if st.abort {
                drop(st);
                abort_unwind();
            }
            let t = waiters[c];
            st.timed_out[t] = false;
            st.tasks[t] = TaskState::Runnable;
        }
        ctx.sched.cv.notify_all();
    }

    pub fn notify_one(&self) {
        self.wake(false);
    }

    pub fn notify_all(&self) {
        self.wake(true);
    }
}

// ---------------------------------------------------------------------------
// Checked atomics
// ---------------------------------------------------------------------------

pub mod atomic {
    //! Checked atomics: every op is a yield point, executed `SeqCst` on
    //! a real atomic regardless of the ordering the caller asked for
    //! (sequentially-consistent exploration only; see module docs).
    use super::hook;
    pub use std::sync::atomic::Ordering;
    use std::sync::atomic::Ordering::SeqCst;

    macro_rules! int_atomic {
        ($name:ident, $std:path, $int:ty) => {
            #[derive(Debug, Default)]
            pub struct $name {
                v: $std,
            }

            impl $name {
                pub const fn new(v: $int) -> Self {
                    Self { v: <$std>::new(v) }
                }

                pub fn load(&self, _o: Ordering) -> $int {
                    hook();
                    self.v.load(SeqCst)
                }

                pub fn store(&self, x: $int, _o: Ordering) {
                    hook();
                    self.v.store(x, SeqCst)
                }

                pub fn swap(&self, x: $int, _o: Ordering) -> $int {
                    hook();
                    self.v.swap(x, SeqCst)
                }

                pub fn fetch_add(&self, x: $int, _o: Ordering) -> $int {
                    hook();
                    self.v.fetch_add(x, SeqCst)
                }

                pub fn fetch_sub(&self, x: $int, _o: Ordering) -> $int {
                    hook();
                    self.v.fetch_sub(x, SeqCst)
                }

                pub fn fetch_max(&self, x: $int, _o: Ordering) -> $int {
                    hook();
                    self.v.fetch_max(x, SeqCst)
                }

                pub fn fetch_min(&self, x: $int, _o: Ordering) -> $int {
                    hook();
                    self.v.fetch_min(x, SeqCst)
                }

                pub fn compare_exchange(
                    &self,
                    cur: $int,
                    new: $int,
                    _ok: Ordering,
                    _err: Ordering,
                ) -> Result<$int, $int> {
                    hook();
                    self.v.compare_exchange(cur, new, SeqCst, SeqCst)
                }

                pub fn fetch_update<F: FnMut($int) -> Option<$int>>(
                    &self,
                    _ok: Ordering,
                    _err: Ordering,
                    f: F,
                ) -> Result<$int, $int> {
                    hook();
                    self.v.fetch_update(SeqCst, SeqCst, f)
                }
            }
        };
    }

    int_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
    int_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
    int_atomic!(AtomicU32, std::sync::atomic::AtomicU32, u32);

    #[derive(Debug, Default)]
    pub struct AtomicBool {
        v: std::sync::atomic::AtomicBool,
    }

    impl AtomicBool {
        pub const fn new(v: bool) -> Self {
            Self { v: std::sync::atomic::AtomicBool::new(v) }
        }

        pub fn load(&self, _o: Ordering) -> bool {
            hook();
            self.v.load(SeqCst)
        }

        pub fn store(&self, x: bool, _o: Ordering) {
            hook();
            self.v.store(x, SeqCst)
        }

        pub fn swap(&self, x: bool, _o: Ordering) -> bool {
            hook();
            self.v.swap(x, SeqCst)
        }
    }
}

// ---------------------------------------------------------------------------
// Thread facade
// ---------------------------------------------------------------------------

pub mod thread {
    //! Scheduler-controlled stand-ins for `std::thread`.  Tasks run on
    //! real OS threads (so `std::thread::current().name()` still works
    //! for the tracer) but only ever one at a time.
    use super::{
        abort_unwind, hook, require_ctx, spawn_task, Arc, Sched, StdMutex, TaskState, Wait,
    };
    use std::time::Duration;

    pub struct JoinHandle<T> {
        sched: Arc<Sched>,
        id: usize,
        slot: Arc<StdMutex<Option<T>>>,
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            let ctx = require_ctx("JoinHandle::join");
            loop {
                let mut st = self.sched.lock_state();
                if st.abort {
                    drop(st);
                    abort_unwind();
                }
                if st.tasks[self.id] == TaskState::Finished {
                    drop(st);
                    let v = self
                        .slot
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .take()
                        .expect("loom join: task finished without a result");
                    return Ok(v);
                }
                st.tasks[ctx.id] = TaskState::Blocked(Wait::Join(self.id));
                self.sched.schedule(&mut st);
                self.sched.cv.notify_all();
                let st = self.sched.wait_mine(st, ctx.id);
                drop(st);
            }
        }
    }

    #[derive(Default)]
    pub struct Builder {
        name: Option<String>,
    }

    impl Builder {
        pub fn new() -> Self {
            Builder { name: None }
        }

        pub fn name(mut self, name: String) -> Self {
            self.name = Some(name);
            self
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            let ctx = require_ctx("thread::spawn");
            let id = {
                let mut st = ctx.sched.lock_state();
                st.tasks.push(TaskState::Runnable);
                st.timed_out.push(false);
                st.tasks.len() - 1
            };
            let (real, slot) = spawn_task(&ctx.sched, id, f, self.name);
            drop(real); // detach; the scheduler tracks task lifetime
            Ok(JoinHandle { sched: Arc::clone(&ctx.sched), id, slot })
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        Builder::new().spawn(f).expect("loom spawn")
    }

    /// Model time does not advance: sleeping is just a yield point.
    pub fn sleep(_dur: Duration) {
        hook();
    }

    pub fn yield_now() {
        hook();
    }

    // Re-exported so callers can keep `thread::current().name()`:
    // model tasks run on real named OS threads.
    pub use std::thread::current;
}

#[cfg(test)]
mod tests {
    use super::atomic::{AtomicU64, Ordering};
    use super::{explore, model, thread, Condvar, Mutex};
    use std::sync::Arc;

    #[test]
    fn fetch_add_is_atomic_and_explores_multiple_schedules() {
        let n = explore(|| {
            let c = Arc::new(AtomicU64::new(0));
            let c2 = Arc::clone(&c);
            let t = thread::spawn(move || {
                c2.fetch_add(1, Ordering::Relaxed);
            });
            c.fetch_add(1, Ordering::Relaxed);
            t.join().unwrap();
            assert_eq!(c.load(Ordering::Relaxed), 2);
        });
        assert!(n > 1, "expected >1 interleaving, got {n}");
    }

    #[test]
    #[should_panic(expected = "loom model failed")]
    fn lost_update_is_found() {
        model(|| {
            let c = Arc::new(AtomicU64::new(0));
            let c2 = Arc::clone(&c);
            let t = thread::spawn(move || {
                let v = c2.load(Ordering::Relaxed);
                c2.store(v + 1, Ordering::Relaxed);
            });
            let v = c.load(Ordering::Relaxed);
            c.store(v + 1, Ordering::Relaxed);
            t.join().unwrap();
            // Non-atomic read-modify-write: some schedule loses one.
            assert_eq!(c.load(Ordering::Relaxed), 2);
        });
    }

    #[test]
    fn mutex_provides_mutual_exclusion() {
        model(|| {
            let m = Arc::new(Mutex::new(0u64));
            let m2 = Arc::clone(&m);
            let t = thread::spawn(move || {
                let mut g = m2.lock().unwrap();
                let v = *g;
                *g = v + 1;
            });
            {
                let mut g = m.lock().unwrap();
                let v = *g;
                *g = v + 1;
            }
            t.join().unwrap();
            assert_eq!(*m.lock().unwrap(), 2);
        });
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn ab_ba_deadlock_is_detected() {
        model(|| {
            let a = Arc::new(Mutex::new(()));
            let b = Arc::new(Mutex::new(()));
            let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
            let t = thread::spawn(move || {
                let _gb = b2.lock().unwrap();
                let _ga = a2.lock().unwrap();
            });
            {
                let _ga = a.lock().unwrap();
                let _gb = b.lock().unwrap();
            }
            t.join().unwrap();
        });
    }

    #[test]
    fn condvar_handoff_is_never_lost() {
        model(|| {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let pair2 = Arc::clone(&pair);
            let t = thread::spawn(move || {
                let (m, cv) = &*pair2;
                let mut ready = m.lock().unwrap();
                while !*ready {
                    ready = cv.wait(ready).unwrap();
                }
            });
            {
                let (m, cv) = &*pair;
                *m.lock().unwrap() = true;
                cv.notify_one();
            }
            t.join().unwrap();
        });
    }

    #[test]
    fn wait_timeout_fires_when_nothing_else_can_run() {
        model(|| {
            let pair = Arc::new((Mutex::new(()), Condvar::new()));
            let (m, cv) = &*pair;
            let g = m.lock().unwrap();
            let (_g, res) = cv
                .wait_timeout(g, std::time::Duration::from_millis(1))
                .unwrap();
            assert!(res.timed_out());
        });
    }

    #[test]
    fn join_returns_the_value() {
        model(|| {
            let t = thread::spawn(|| 41u64 + 1);
            assert_eq!(t.join().unwrap(), 42);
        });
    }
}
