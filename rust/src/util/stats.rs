//! Small statistics helpers shared by metrics, benches, and the simulator.

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile of a sample (linear interpolation). `p` in [0,100].
pub fn percentile(xs: &mut [f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (xs.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        xs[lo] + (xs[hi] - xs[lo]) * (rank - lo as f64)
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 6.2).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 6.2f64).powi(2)).sum::<f64>() / 4.0;
        assert!((w.var() - direct_var).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let mut xs = vec![4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut xs, 100.0), 4.0);
        assert_eq!(percentile(&mut xs, 50.0), 2.5);
    }

    #[test]
    fn empty_mean_is_zero() {
        assert_eq!(mean(&[]), 0.0);
    }
}
