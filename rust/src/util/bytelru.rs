//! Shared byte-budgeted LRU core — the invariant that used to live twice
//! (in `storage/cache.rs` and `pipeline/prep_cache.rs`'s lru arm):
//!
//! * **byte accounting is exact**: `bytes` always equals the sum of the
//!   resident entries' charged sizes, and never exceeds the budget;
//! * **replacement credits the old entry** before the eviction loop sizes
//!   its target, so racing admissions of one key neither leak bytes nor
//!   over-evict neighbors;
//! * **eviction is O(log n)** via a tick-ordered `BTreeMap` index (ticks
//!   are unique: every get/insert takes a fresh one), not a map scan.
//!
//! The core is single-threaded; callers wrap it in their own `Mutex` and
//! keep policy-specific concerns (hit counters, admission gates, the
//! MinIO eviction-free arm) outside.

use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;

struct Entry<V> {
    value: V,
    /// Bytes this entry charges against the budget (supplied at insert:
    /// values know their own size only at the caller's layer).
    size: usize,
    /// Last-use tick, the key into the eviction index.
    tick: u64,
}

/// Byte-budgeted LRU store keyed by `K`, charging caller-supplied sizes.
pub struct ByteLru<K, V> {
    budget: usize,
    map: HashMap<K, Entry<V>>,
    by_tick: BTreeMap<u64, K>,
    bytes: usize,
    tick: u64,
}

impl<K: Eq + Hash + Clone, V> ByteLru<K, V> {
    pub fn new(budget_bytes: usize) -> Self {
        ByteLru {
            budget: budget_bytes,
            map: HashMap::new(),
            by_tick: BTreeMap::new(),
            bytes: 0,
            tick: 0,
        }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Re-budget in place (quota rebalance on job join/leave in serve
    /// mode).  Shrinking evicts least-recently-used entries until the
    /// resident bytes fit the new budget — the accounting invariant
    /// (`bytes == Σ resident sizes <= budget`) holds on return; growing
    /// just raises the ceiling and lets future inserts use it.
    pub fn set_budget(&mut self, new_budget: usize) {
        self.budget = new_budget;
        while self.bytes > self.budget {
            let Some((&victim_tick, _)) = self.by_tick.iter().next() else {
                break;
            };
            let victim = self.by_tick.remove(&victim_tick).expect("index entry");
            if let Some(e) = self.map.remove(&victim) {
                self.bytes -= e.size;
            }
        }
    }

    /// Exact resident byte count (the invariant the property tests drive).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look a key up and refresh its recency (one hash lookup: map and
    /// index are split-borrowed, as both pre-extraction call sites did).
    pub fn get(&mut self, key: &K) -> Option<&V> {
        self.tick += 1;
        let tick = self.tick;
        let ByteLru { map, by_tick, .. } = self;
        let e = map.get_mut(key)?;
        let old = std::mem::replace(&mut e.tick, tick);
        by_tick.remove(&old);
        by_tick.insert(tick, key.clone());
        Some(&e.value)
    }

    /// Look a key up without touching recency (inspection/tests).
    pub fn peek(&self, key: &K) -> Option<&V> {
        self.map.get(key).map(|e| &e.value)
    }

    /// Admit `value` charging `size` bytes.  An entry already resident
    /// under the same key is credited (removed from the accounting)
    /// *before* the eviction loop sizes its target — replacement only
    /// needs room for the size delta.  Values larger than the whole
    /// budget are never admitted.
    pub fn insert(&mut self, key: K, value: V, size: usize) {
        if size > self.budget {
            return;
        }
        self.tick += 1;
        let tick = self.tick;
        if let Some(old) = self.map.remove(&key) {
            self.by_tick.remove(&old.tick);
            self.bytes -= old.size;
        }
        // Evict least-recently-used entries until the value fits.
        while self.bytes + size > self.budget {
            let Some((&victim_tick, _)) = self.by_tick.iter().next() else {
                break;
            };
            let victim = self.by_tick.remove(&victim_tick).expect("index entry");
            if let Some(e) = self.map.remove(&victim) {
                self.bytes -= e.size;
            }
        }
        self.bytes += size;
        self.map.insert(key.clone(), Entry { value, size, tick });
        self.by_tick.insert(tick, key);
    }

    /// Iterate resident entries (arbitrary order).
    pub fn iter(&self) -> impl Iterator<Item = (&K, &V)> {
        self.map.iter().map(|(k, e)| (k, &e.value))
    }

    /// Resident keys in eviction order: least-recently-used first.  The
    /// reference-model harness (`tests/bytelru_model.rs`) compares this
    /// against a naive recency list, pinning not just *what* is resident
    /// but *who goes next* — a recency bug that happens to keep byte
    /// accounting intact still fails here.
    pub fn lru_order(&self) -> Vec<K> {
        self.by_tick.values().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn get_refreshes_recency_and_insert_evicts_lru() {
        let mut l: ByteLru<u32, &'static str> = ByteLru::new(120);
        l.insert(1, "a", 60);
        l.insert(2, "b", 60);
        assert_eq!(l.get(&1), Some(&"a")); // refresh 1
        l.insert(3, "c", 60); // evicts 2
        assert!(l.peek(&2).is_none());
        assert_eq!(l.peek(&1), Some(&"a"));
        assert_eq!(l.peek(&3), Some(&"c"));
        assert_eq!(l.bytes(), 120);
        assert_eq!(l.len(), 2);
    }

    #[test]
    fn oversized_values_bypass() {
        let mut l: ByteLru<u32, ()> = ByteLru::new(100);
        l.insert(1, (), 101);
        assert!(l.is_empty());
        assert_eq!(l.bytes(), 0);
        assert_eq!(l.budget(), 100);
    }

    #[test]
    fn replacement_credits_old_entry_and_does_not_over_evict() {
        let mut l: ByteLru<u32, u8> = ByteLru::new(120);
        l.insert(1, 1, 60);
        l.insert(2, 2, 60); // full: 120/120
        // Same-size replacement needs no room: 2 must survive.
        l.insert(1, 9, 60);
        assert_eq!(l.peek(&2), Some(&2), "neighbor was needlessly evicted");
        assert_eq!(l.bytes(), 120);
        // Shrinking replacement frees bytes exactly.
        l.insert(1, 7, 20);
        assert_eq!(l.bytes(), 80);
        // Growing replacement evicts only what the delta requires.
        l.insert(1, 8, 60);
        assert_eq!(l.bytes(), 120);
        assert_eq!(l.peek(&2), Some(&2));
    }

    #[test]
    fn set_budget_shrinks_by_evicting_lru_and_grows_for_free() {
        let mut l: ByteLru<u32, u8> = ByteLru::new(300);
        l.insert(1, 1, 100);
        l.insert(2, 2, 100);
        l.insert(3, 3, 100);
        assert_eq!(l.get(&1), Some(&1)); // 1 is now most recent
        // Shrink below residency: LRU victims (2 then 3) go, 1 stays.
        l.set_budget(150);
        assert_eq!(l.budget(), 150);
        assert_eq!(l.bytes(), 100);
        assert_eq!(l.peek(&1), Some(&1));
        assert!(l.peek(&2).is_none() && l.peek(&3).is_none());
        // Growing changes only the ceiling; nothing reappears.
        l.set_budget(400);
        assert_eq!(l.len(), 1);
        l.insert(4, 4, 300);
        assert_eq!(l.bytes(), 400);
        // Shrink to zero evicts everything.
        l.set_budget(0);
        assert!(l.is_empty());
        assert_eq!(l.bytes(), 0);
    }

    #[test]
    fn prop_accounting_is_exact_under_random_workloads() {
        // Seeded random insert/get workload with varying sizes: after
        // every operation, bytes == Σ resident sizes <= budget.
        let mut rng = Rng::new(0xB17E);
        for case in 0..50 {
            let budget = 64 + rng.gen_range(512) as usize;
            let mut l: ByteLru<u64, usize> = ByteLru::new(budget);
            let mut sizes: std::collections::HashMap<u64, usize> =
                std::collections::HashMap::new();
            for _ in 0..200 {
                let key = rng.gen_range(12);
                if rng.bool() {
                    let size = 1 + rng.gen_range(128) as usize;
                    l.insert(key, size, size);
                    if size <= budget {
                        sizes.insert(key, size);
                    }
                } else {
                    l.get(&key);
                }
                // Resident set may be a subset of `sizes` (evictions),
                // but every resident entry's charge must match and the
                // totals must reconcile.
                let recount: usize = l.iter().map(|(_, &s)| s).sum();
                assert_eq!(l.bytes(), recount, "case {case}");
                assert!(l.bytes() <= budget, "case {case}");
                for (k, v) in l.iter() {
                    assert_eq!(sizes.get(k), Some(v), "case {case}: stale entry");
                }
            }
        }
    }
}
