//! Minimal JSON parser/emitter (no serde available offline).
//!
//! Covers the full JSON grammar we produce and consume: the AOT
//! `manifest.json`, run configs, and metrics export.  Numbers are kept as
//! f64 (integers round-trip exactly up to 2^53, far above anything in a
//! manifest).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that panics with a useful message — for trusted manifests.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key `{key}` in {self:.60?}"))
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 1-space indent (matches python json.dump(indent=1)).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected byte")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        assert_eq!(v.req("a").idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(v.req("a").idx(1).unwrap().req("b").as_str(), Some("x"));
        assert_eq!(v.req("c").as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s\"q"],"nested":{"x":null,"y":true}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integers_exact() {
        let v = Json::parse("9007199254740992").unwrap();
        assert_eq!(v.as_i64(), Some(9007199254740992));
        assert_eq!(v.dump(), "9007199254740992");
    }
}
