//! Sync facade: `std::sync`/`std::thread` primitives normally, the
//! in-tree bounded model checker (`util::loom`) under `--cfg loom`.
//!
//! Every concurrent core in the crate (`util::slab`, `metrics::trace`,
//! `metrics` gauges/`BusyClock`, `pipeline::channel`,
//! `pipeline::prep_cache`, `pipeline::exec`, `storage::cache`,
//! `storage` IoStats) imports its primitives from here instead of
//! `std::sync`, so `RUSTFLAGS="--cfg loom" cargo test --test
//! loom_models` model-checks the exact code that ships.  `Arc`/`Weak`
//! stay `std` in both configurations (reference counting is not part
//! of the modeled state), as does `Instant`.

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, WaitTimeoutResult, Weak};

#[cfg(not(loom))]
pub mod atomic {
    pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
}

#[cfg(not(loom))]
pub mod thread {
    pub use std::thread::{current, sleep, spawn, yield_now, Builder, JoinHandle};
}

#[cfg(loom)]
pub use crate::util::loom::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

#[cfg(loom)]
pub use std::sync::{Arc, Weak};

#[cfg(loom)]
pub mod atomic {
    pub use crate::util::loom::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
}

#[cfg(loom)]
pub mod thread {
    pub use crate::util::loom::thread::{current, sleep, spawn, yield_now, Builder, JoinHandle};
}
