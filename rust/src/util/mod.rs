//! Self-contained utility substrates (the offline environment ships no
//! serde / rand / clap — see DESIGN.md "Offline-environment substitutions").

pub mod alloc_count;
pub mod bytelru;
pub mod cli;
pub mod json;
pub mod loom;
pub mod rng;
pub mod slab;
pub mod stats;
pub mod sync;

/// Human-readable byte count.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable rate.
pub fn human_rate(per_sec: f64, unit: &str) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G{unit}/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M{unit}/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} K{unit}/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2} {unit}/s")
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn bytes_format() {
        assert_eq!(super::human_bytes(512), "512 B");
        assert_eq!(super::human_bytes(2048), "2.00 KiB");
        assert_eq!(super::human_bytes(3 << 20), "3.00 MiB");
    }

    #[test]
    fn rate_format() {
        assert_eq!(super::human_rate(1500.0, "img"), "1.50 Kimg/s");
        assert_eq!(super::human_rate(2.5e9, "B"), "2.50 GB/s");
    }
}
