//! Counting global-allocator shim: process-wide allocation telemetry
//! behind `dpp bench alloc` and the run report's `bytes_alloc_hot`.
//!
//! Every allocation goes through [`CountingAllocator`] (registered as
//! the global allocator for the whole crate): two relaxed atomic adds
//! per `alloc`, nothing on `dealloc` — cheap enough to leave on
//! unconditionally, which is what lets the run report carry an A/B-able
//! allocation figure for `--slab-pool off` vs `auto` without a special
//! build.
//!
//! Counters are process-global, so a measurement window taken while
//! other threads allocate is an over-count, never an under-count.  The
//! alloc bench takes the minimum over several rounds to shed that noise
//! (see `bench/alloc.rs`); the run report's delta is labeled as a
//! whole-process number.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// System allocator plus monotonic alloc/byte counters.
pub struct CountingAllocator;

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

/// Monotonic counter reading (process-wide, since start).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocSnapshot {
    pub allocs: u64,
    pub bytes: u64,
}

pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot { allocs: ALLOCS.load(Ordering::Relaxed), bytes: BYTES.load(Ordering::Relaxed) }
}

/// Counter movement since `since` (saturating: counters are monotonic,
/// so this only guards against a stale snapshot from another process
/// image — in practice it is an exact delta).
pub fn delta(since: AllocSnapshot) -> AllocSnapshot {
    let now = snapshot();
    AllocSnapshot {
        allocs: now.allocs.saturating_sub(since.allocs),
        bytes: now.bytes.saturating_sub(since.bytes),
    }
}

/// Run `f`, returning what it allocated (plus whatever *other threads*
/// allocated meanwhile — callers wanting a clean number measure on a
/// quiet process or take a min over rounds).
pub fn measure<R>(f: impl FnOnce() -> R) -> (AllocSnapshot, R) {
    let s0 = snapshot();
    let r = f();
    (delta(s0), r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_sees_allocations() {
        let (d, v) = measure(|| vec![0u8; 4096]);
        assert_eq!(v.len(), 4096);
        assert!(d.allocs >= 1, "{d:?}");
        assert!(d.bytes >= 4096, "{d:?}");
    }

    #[test]
    fn delta_is_monotone() {
        let s0 = snapshot();
        let _v = vec![0u64; 100];
        let d = delta(s0);
        assert!(d.allocs >= 1);
        // A later snapshot never reads below an earlier one.
        let s1 = snapshot();
        assert!(s1.allocs >= s0.allocs && s1.bytes >= s0.bytes);
    }
}
