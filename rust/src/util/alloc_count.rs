//! Counting global-allocator shim: process-wide allocation telemetry
//! behind `dpp bench alloc` and the run report's `bytes_alloc_hot`.
//!
//! Every allocation goes through [`CountingAllocator`] (registered as
//! the global allocator for the whole crate): two relaxed atomic adds
//! per `alloc`, nothing on `dealloc` — cheap enough to leave on
//! unconditionally, which is what lets the run report carry an A/B-able
//! allocation figure for `--slab-pool off` vs `auto` without a special
//! build.
//!
//! Counters are process-global, so a measurement window taken while
//! other threads allocate is an over-count, never an under-count.  The
//! alloc bench takes the minimum over several rounds to shed that noise
//! (see `bench/alloc.rs`); the run report's delta is labeled as a
//! whole-process number.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// System allocator plus monotonic alloc/byte counters.
pub struct CountingAllocator;

// SAFETY: every method delegates verbatim to `System`, which upholds the
// GlobalAlloc contract; the only additions are atomic counter bumps that
// never allocate, never touch the returned pointer, and cannot unwind.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // ordering: Relaxed — monotonic telemetry counters; readers only
        // need an eventually-consistent total, never cross-counter or
        // cross-thread consistency with the allocation itself.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        // SAFETY: caller upholds GlobalAlloc's `alloc` contract (non-zero
        // sized, valid layout); we forward it unchanged to System.
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: `ptr`/`layout` come from the caller under dealloc's
        // contract (allocated by this allocator — which is System — with
        // this layout); forwarded unchanged.
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        // ordering: Relaxed — monotonic telemetry (see `alloc`).
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        // SAFETY: caller upholds `alloc_zeroed`'s contract; forwarded
        // unchanged to System.
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // ordering: Relaxed — monotonic telemetry (see `alloc`).
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        // SAFETY: caller upholds `realloc`'s contract (`ptr` from this
        // allocator with `layout`, `new_size` non-zero); forwarded
        // unchanged to System.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

// Miri interprets `#[global_allocator]` hooks on every interpreter-level
// allocation, which both slows the whole suite an order of magnitude and
// trips its leak-check bookkeeping on the registration itself.  Under
// miri the crate falls back to the default allocator and these counters
// simply stay at zero — `snapshot`/`delta`/`measure` keep their types
// and monotonicity, only the values are degenerate (tests gate on this).
#[cfg(not(miri))]
#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

/// Monotonic counter reading (process-wide, since start).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocSnapshot {
    pub allocs: u64,
    pub bytes: u64,
}

pub fn snapshot() -> AllocSnapshot {
    // ordering: Relaxed — approximate paired read of two monotonic
    // telemetry counters; a one-allocation skew between them is within
    // the documented process-wide noise of this instrument.
    AllocSnapshot { allocs: ALLOCS.load(Ordering::Relaxed), bytes: BYTES.load(Ordering::Relaxed) }
}

/// Counter movement since `since` (saturating: counters are monotonic,
/// so this only guards against a stale snapshot from another process
/// image — in practice it is an exact delta).
pub fn delta(since: AllocSnapshot) -> AllocSnapshot {
    let now = snapshot();
    AllocSnapshot {
        allocs: now.allocs.saturating_sub(since.allocs),
        bytes: now.bytes.saturating_sub(since.bytes),
    }
}

/// Run `f`, returning what it allocated (plus whatever *other threads*
/// allocated meanwhile — callers wanting a clean number measure on a
/// quiet process or take a min over rounds).
pub fn measure<R>(f: impl FnOnce() -> R) -> (AllocSnapshot, R) {
    let s0 = snapshot();
    let r = f();
    (delta(s0), r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_sees_allocations() {
        let (d, v) = measure(|| vec![0u8; 4096]);
        assert_eq!(v.len(), 4096);
        // Under miri the counting allocator is not registered (see the
        // `#[cfg(not(miri))]` note above) and the counters stay at zero.
        if !cfg!(miri) {
            assert!(d.allocs >= 1, "{d:?}");
            assert!(d.bytes >= 4096, "{d:?}");
        }
    }

    #[test]
    fn delta_is_monotone() {
        let s0 = snapshot();
        let _v = vec![0u64; 100];
        let d = delta(s0);
        if !cfg!(miri) {
            assert!(d.allocs >= 1);
        }
        // A later snapshot never reads below an earlier one.
        let s1 = snapshot();
        assert!(s1.allocs >= s0.allocs && s1.bytes >= s0.bytes);
    }
}
