//! Minimal CLI argument parser (no clap offline): subcommand + `--key value`
//! options + `--flag` booleans + positionals.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse argv (without the binary name). First bare word becomes the
    /// subcommand; `--key value` fills options unless `value` starts with
    /// `--` (then `key` is a flag).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() && out.positionals.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE grammar: a bare word directly after `--key` is its value,
        // so positionals go before flags (or use --key=value).
        let a = parse("run extra --model alexnet_t --steps 10 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("model"), Some("alexnet_t"));
        assert_eq!(a.get_usize("steps", 0), 10);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn eq_form_and_defaults() {
        let a = parse("bench --fig=2 --time-scale=0.5");
        assert_eq!(a.get("fig"), Some("2"));
        assert_eq!(a.get_f64("time-scale", 1.0), 0.5);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("storage", "ebs"), "ebs");
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --check");
        assert!(a.has_flag("check"));
        assert!(a.get("check").is_none());
    }
}
