//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! The offline environment ships no `rand` crate, so the pipeline's
//! randomness (epoch shuffles, crop/flip sampling, synthetic data) uses
//! this self-contained generator.  Determinism matters: the coordinator
//! owns all randomness so AOT artifacts stay pure (see kernels/augment.py),
//! and a run is reproducible from a single seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (e.g. per worker, per epoch).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3])).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn fork_streams_differ() {
        let root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(3);
        for n in [1u64, 2, 7, 1000] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn unit_floats_in_range() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
            let g = r.f32();
            assert!((0.0..1.0).contains(&g));
        }
    }

    #[test]
    fn uniform_mean_sane() {
        let mut r = Rng::new(5);
        let m: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(6);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((v - 1.0).abs() < 0.1, "var {v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle left identity");
    }
}
